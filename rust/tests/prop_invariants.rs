//! Property-based tests of the paper's formal invariants (DESIGN.md §6).

use quartz::linalg::{
    cholesky_jittered, diag_dominance_margin, eig_sym, fro_norm, matmul, matmul_nt, syrk, Matrix,
};
use quartz::metrics::MemoryModel;
use quartz::optim::graft;
use quartz::quant::{
    dequantize_offdiag, quantize_offdiag, BlockQuantizer, Mapping, QuantConfig, TriJointStore,
};
use quartz::shampoo::{Blocking, ShampooConfig, ShampooVariant};
use quartz::util::prop::{run_prop, Gen};

fn quantizer(g: &mut Gen) -> BlockQuantizer {
    let block = *g.choice(&[4usize, 8, 16, 32, 64]);
    let mapping = *g.choice(&[Mapping::Linear, Mapping::Linear2, Mapping::Dynamic]);
    BlockQuantizer::new(QuantConfig { block, mapping, bits: 4, min_quant_elems: 0 })
}

/// Proposition B.1: ‖D(Q(x)) − x‖∞ ≤ ‖x‖∞-per-block · max-half-gap.
/// (The paper states the bound with 2^{-b} for the linear codebook; we use
/// the exact codebook geometry, which covers linear-2 and dynamic too.)
#[test]
fn prop_b1_quantization_error_bound() {
    run_prop("prop B.1 error bound", 60, |g| {
        let q = quantizer(g);
        let rows = g.usize_in(1, 40);
        let cols = g.usize_in(1, 40);
        let vals = g.wide_range_vec(rows * cols, 2.0);
        let x = Matrix::from_vec(rows, cols, vals);
        let back = q.roundtrip(&x);
        let half_gap = q.codebook().max_abs_error();
        let b = q.cfg.block;
        let bn = cols.div_ceil(b);
        let qx = q.quantize(&x);
        for i in 0..rows {
            for j in 0..cols {
                let scale = qx.scales[(i / b) * bn + j / b];
                let err = (back[(i, j)] - x[(i, j)]).abs();
                assert!(
                    err <= scale * half_gap + 1e-5 * scale.max(1.0),
                    "err {err} scale {scale} at ({i},{j})"
                );
            }
        }
    });
}

/// CQ reconstruction D(C̄)·D(C̄)ᵀ is symmetric PSD for any stored factor —
/// the structural reason CQ preserves spectra (Sec. 4.2).
#[test]
fn prop_cq_reconstruction_is_psd() {
    run_prop("CQ reconstruction PSD", 40, |g| {
        let q = quantizer(g);
        let n = g.usize_in(2, 24);
        // Random SPD input.
        let gmat = Matrix::from_vec(n, n + 4, g.normal_vec(n * (n + 4), 1.0));
        let mut a = syrk(&gmat);
        a.add_diag(g.f32_in(1e-4, 1.0));
        let (c, _) = cholesky_jittered(&a, 1e-6, 10).unwrap();
        let store = TriJointStore::store(&c, &Matrix::zeros(n, n), &q);
        let (cb, _) = store.load(&q);
        let recon = matmul_nt(&cb, &cb);
        // Symmetry.
        assert!(recon.max_abs_diff(&recon.transpose()) < 1e-5);
        // PSD via eigensolver.
        let (vals, _) = eig_sym(&recon, 1e-10, 100);
        assert!(vals[0] >= -1e-4 * vals[vals.len() - 1].abs().max(1.0), "λmin = {}", vals[0]);
    });
}

/// Packed triangular joint storage round-trips C and E independently.
#[test]
fn prop_tri_store_roundtrip_isolation() {
    run_prop("tri store isolation", 40, |g| {
        let q = quantizer(g);
        let n = g.usize_in(2, 32);
        let mut c = Matrix::zeros(n, n);
        let mut e = Matrix::zeros(n, n);
        for i in 0..n {
            c[(i, i)] = g.f32_in(0.5, 5.0);
            for j in 0..i {
                c[(i, j)] = g.rng.normal_f32(1.0);
                e[(i, j)] = g.rng.normal_f32(0.1);
            }
        }
        let store = TriJointStore::store(&c, &e, &q);
        let (c2, e2) = store.load(&q);
        // Diagonal is exact; structure is preserved.
        for i in 0..n {
            assert_eq!(c2[(i, i)], c[(i, i)]);
            for j in (i + 1)..n {
                assert_eq!(c2[(i, j)], 0.0);
                assert_eq!(e2[(i, j)], 0.0);
            }
        }
        // Same C with a different E loads the same C codes.
        let mut e3 = e.clone();
        for i in 1..n {
            e3[(i, 0)] += 1.0;
        }
        let store3 = TriJointStore::store(&c, &e3, &q);
        let (c3, _) = store3.load(&q);
        assert_eq!(c2, c3, "E must not leak into C");
    });
}

/// Gershgorin PD certificate (Proposition 5.1): when the diagonal dominates
/// by the 1 + 2/(2^b−1) factor, the off-diagonal-quantized matrix is PD.
#[test]
fn prop_gershgorin_pd_certificate() {
    run_prop("Gershgorin PD after quantization", 40, |g| {
        let q = BlockQuantizer::new(QuantConfig {
            block: *g.choice(&[8usize, 16, 64]),
            ..Default::default()
        });
        let n = g.usize_in(2, 24);
        // Build a strongly diagonally dominant symmetric matrix.
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                let v = g.rng.normal_f32(1.0);
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        let t = 1.0 + 2.0 / 15.0;
        for i in 0..n {
            let off: f32 = (0..n).filter(|&j| j != i).map(|j| m[(i, j)].abs()).sum();
            m[(i, i)] = t * off + g.f32_in(0.1, 1.0);
        }
        assert!(diag_dominance_margin(&m, t as f64) > 0.0);
        let back = dequantize_offdiag(&quantize_offdiag(&m, &q), &q);
        let (vals, _) = eig_sym(&back, 1e-10, 100);
        assert!(vals[0] > 0.0, "Prop 5.1 violated: λmin = {}", vals[0]);
    });
}

/// Blocking covers every parameter cell exactly once for arbitrary shapes.
#[test]
fn prop_blocking_is_partition() {
    run_prop("blocking partition", 100, |g| {
        let m = g.usize_in(1, 300);
        let n = g.usize_in(1, 300);
        let cap = g.usize_in(1, 128);
        let blocking = Blocking::new(m, n, cap);
        let mut count = vec![0u8; m * n];
        for b in &blocking.blocks {
            assert!(b.rows <= cap && b.cols <= cap);
            for i in b.r0..b.r0 + b.rows {
                for j in b.c0..b.c0 + b.cols {
                    count[i * n + j] += 1;
                }
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    });
}

/// Grafting preserves the raw gradient's Frobenius norm (Eq. 13).
#[test]
fn prop_grafting_preserves_norm() {
    run_prop("grafting norm", 60, |g| {
        let rows = g.usize_in(1, 20);
        let cols = g.usize_in(1, 20);
        let raw = Matrix::from_vec(rows, cols, g.normal_vec(rows * cols, 2.0));
        let mut pre = Matrix::from_vec(rows, cols, g.wide_range_vec(rows * cols, 3.0));
        if fro_norm(&pre) == 0.0 {
            return;
        }
        let dir_before = pre.clone();
        graft(&raw, &mut pre);
        let n_raw = fro_norm(&raw);
        assert!((fro_norm(&pre) - n_raw).abs() <= 1e-4 * n_raw.max(1e-6));
        // Direction unchanged: pre is a non-negative multiple of dir_before.
        let dot = quartz::linalg::inner(&dir_before, &pre);
        assert!(dot >= 0.0);
    });
}

/// The memory accountant equals measured bytes for arbitrary shapes and
/// every variant (no drift between model and implementation).
#[test]
fn prop_memory_model_matches_measured() {
    run_prop("memory model exactness", 12, |g| {
        let n_layers = g.usize_in(1, 3);
        let shapes: Vec<(usize, usize)> = (0..n_layers)
            .map(|_| (g.usize_in(2, 80), g.usize_in(2, 80)))
            .collect();
        let variant = *g.choice(&[
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: false },
            ShampooVariant::Cq4 { error_feedback: true },
            ShampooVariant::Bw8,
        ]);
        let cfg = ShampooConfig {
            variant,
            t1: 1,
            t2: 1,
            max_order: 64,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut sh = quartz::shampoo::Shampoo::new(
            quartz::optim::BaseOptimizer::sgd(0.01, 0.0),
            cfg,
            &shapes,
        );
        let mut params: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n)| Matrix::from_vec(m, n, g.normal_vec(m * n, 0.3)))
            .collect();
        let grads: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n)| Matrix::from_vec(m, n, g.normal_vec(m * n, 0.3)))
            .collect();
        sh.step(&mut params, &grads, 1, 1.0);
        let measured = sh.shampoo_state_bytes();
        let modeled = MemoryModel::new(&shapes).shampoo_bytes(&cfg);
        assert_eq!(modeled, measured, "shapes {shapes:?} variant {variant:?}");
    });
}

/// The cache-blocked (and, above the FLOP threshold, multi-threaded) matmul
/// must agree with a naive f64 triple loop for arbitrary shapes — including
/// shapes large enough to take the parallel path (2·m·n·k ≥ 2²⁰ FLOPs).
#[test]
fn prop_matmul_parallel_matches_naive() {
    run_prop("matmul parallel vs naive", 30, |g| {
        // Mix small shapes (single-threaded path, ragged tails) with large
        // ones (threaded row-block path).
        let (m, k, n) = if g.bool() {
            (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40))
        } else {
            (g.usize_in(80, 130), g.usize_in(80, 130), g.usize_in(80, 130))
        };
        let a = Matrix::from_vec(m, k, g.normal_vec(m * k, 1.0));
        let b = Matrix::from_vec(k, n, g.normal_vec(k * n, 1.0));
        let c = matmul(&a, &b);
        let mut want = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for l in 0..k {
                    s += a[(i, l)] as f64 * b[(l, j)] as f64;
                }
                want[(i, j)] = s as f32;
            }
        }
        let diff = c.max_abs_diff(&want);
        assert!(diff < 1e-3 * k as f32, "shape {m}x{k}x{n}: diff {diff}");
    });
}

/// Quantized matmul sanity: D(Q(A))·D(Q(B)) stays close to A·B in relative
/// Frobenius terms for well-scaled inputs.
#[test]
fn prop_quantized_product_close() {
    run_prop("quantized product", 30, |g| {
        let q = BlockQuantizer::new(QuantConfig {
            block: 64,
            min_quant_elems: 0,
            ..Default::default()
        });
        let n = g.usize_in(4, 32);
        let a = Matrix::from_vec(n, n, g.normal_vec(n * n, 1.0));
        let b = Matrix::from_vec(n, n, g.normal_vec(n * n, 1.0));
        let exact = matmul(&a, &b);
        let approx = matmul(&q.roundtrip(&a), &q.roundtrip(&b));
        let rel = quartz::linalg::relative_error(&exact, &approx);
        assert!(rel < 0.25, "relative error {rel}");
    });
}

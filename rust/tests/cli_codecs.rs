//! Snapshot test for the `quartz codecs` CLI listing (`report::codecs`).
//!
//! Runs in its own test binary so the registries hold exactly the built-ins
//! (other integration suites register test-only codecs/stacks in *their*
//! processes). Pins the grouped section structure, every built-in key, and
//! the bytes-per-element column values at the reference order — the same
//! closed-form byte costs the memory model and the codec-generic property
//! suite assert, so a formula drift fails three independent gates.

use quartz::report::codecs::{codec_listing, REFERENCE_ORDER};

fn row_for<'a>(out: &'a str, section_start: usize, key: &str) -> &'a str {
    out[section_start..]
        .lines()
        .find(|l| {
            let cells: Vec<&str> = l.split('|').map(str::trim).collect();
            cells.len() > 1 && cells[1] == key
        })
        .unwrap_or_else(|| panic!("no row for key '{key}'"))
}

#[test]
fn listing_groups_sections_in_order() {
    let out = codec_listing();
    let stacks = out.find("== optimizer stacks (train::registry) ==").expect("stacks header");
    let codecs = out
        .find("== preconditioner codecs (quant::codec) — bytes/elem at order 256 ==")
        .expect("codecs header");
    let policies =
        out.find("== refresh policies (shampoo::scheduler) ==").expect("policies header");
    let grafts = out.find("== grafts (optim::grafting) ==").expect("grafts header");
    assert!(
        stacks < codecs && codecs < policies && policies < grafts,
        "sections must be grouped in order"
    );
    assert_eq!(REFERENCE_ORDER, 256, "snapshot below prices order 256");
}

#[test]
fn listing_contains_every_builtin_key() {
    let out = codec_listing();
    let stacks = out.find("== optimizer stacks").unwrap();
    let codecs = out.find("== preconditioner codecs").unwrap();
    let policies = out.find("== refresh policies").unwrap();
    for key in ["none", "32bit", "vq", "cq", "cq-ef", "bw8", "ec4", "f16", "cq-r1"] {
        let row = row_for(&out, stacks, key);
        assert!(out[stacks..codecs].contains(row), "stack '{key}' outside its section");
    }
    for key in ["f32", "vq4", "vq4-full", "cq4", "cq4-ef", "bw8", "ec4", "f16", "cq-r1"] {
        let row = row_for(&out, codecs, key);
        assert!(out[codecs..policies].contains(row), "codec '{key}' outside its section");
    }
    let grafts = out.find("== grafts").unwrap();
    for key in ["every-n", "staggered", "staleness"] {
        let row = row_for(&out, policies, key);
        assert!(out[policies..grafts].contains(row), "policy '{key}' outside its section");
    }
    for key in ["none", "sgd", "adagrad", "rmsprop", "sqrt-n"] {
        row_for(&out, grafts, key);
    }
}

/// The bytes-per-element snapshot at order 256, block 64 (the experiment
/// default): codes + block scales + f32 side-bands, per codec, side and
/// root constructors separately.
#[test]
fn listing_bytes_per_element_snapshot() {
    let out = codec_listing();
    let codecs = out.find("== preconditioner codecs").unwrap();
    for (key, side, root) in [
        ("f32", "4.000", "4.000"),
        ("vq4", "0.517", "0.517"),
        ("vq4-full", "0.501", "0.501"),
        ("cq4", "0.268", "0.517"),
        ("cq4-ef", "0.518", "0.517"),
        ("bw8", "1.017", "1.017"),
        ("ec4", "0.517", "0.517"),
        ("f16", "2.000", "2.000"),
        ("cq-r1", "0.283", "0.517"),
    ] {
        let row = row_for(&out, codecs, key);
        let cells: Vec<&str> = row.split('|').map(str::trim).collect();
        assert_eq!(cells[2], side, "side B/elem for '{key}' in {row:?}");
        assert_eq!(cells[3], root, "root B/elem for '{key}' in {row:?}");
    }
}

//! Kernel-equivalence suite: every fused/parallel hot-path kernel of the
//! perf pass is pinned against its scalar reference.
//!
//! * fused block-wise quantize/dequantize (streamed nibble packing, boundary
//!   -table encode, per-block dequant tables) vs the scalar
//!   `CodeStore::get`/`set` + midpoint-encode reference — **bit-exact**;
//! * parallel quantize/dequantize vs single-threaded — **bit-identical**;
//! * the fused joint triangular store vs masked-matrix reference — exact;
//! * blocked right-looking Cholesky vs the naive kernel — ≤1e-5 relative
//!   Frobenius on random SPD, divisible and non-divisible orders;
//! * the packed-panel GEMM tier: AVX2 vs scalar microkernel ≤1e-5 relative
//!   Frobenius across rectangular/odd/non-tile-multiple shapes and all
//!   N/T operand combos, SYRK writing only the lower triangle, and
//!   parallel-vs-sequential **bit-identity**;
//! * the steady-state Shampoo refresh pipeline — zero scratch-pool misses
//!   *and* zero GEMM packing-buffer growths after warm-up (the
//!   allocation-free store/load/root contract).

use quartz::linalg::gemm::{avx2_available, gemm_with, syrk_lower_with, Microkernel};
use quartz::linalg::{
    cholesky, cholesky_naive, fro_norm, relative_error, syrk, syrk_lower_into, Matrix, MatmulPlan,
    CHOLESKY_BLOCKED_MIN,
};
use quartz::optim::BaseOptimizer;
use quartz::quant::{BlockQuantizer, CodeStore, Mapping, QuantConfig, QuantizedMatrix};
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::util::rng::Rng;

/// The pre-fusion scalar quantizer: per-block absmax, midpoint-scan encode,
/// one `CodeStore::set` per element. The fused kernel must reproduce its
/// codes and scales bit-for-bit.
fn reference_quantize(q: &BlockQuantizer, x: &Matrix) -> QuantizedMatrix {
    let (m, n) = (x.rows(), x.cols());
    let b = q.cfg.block.max(1);
    let bm = m.div_ceil(b);
    let bn = n.div_ceil(b);
    let mut scales = vec![0.0f32; bm * bn];
    let mut codes = CodeStore::zeros(m * n, q.cfg.bits);
    let cb = q.codebook();
    let zero_code = cb.encode_scalar(0.0);
    for bi in 0..bm {
        for bj in 0..bn {
            let (r0, c0) = (bi * b, bj * b);
            let (r1, c1) = ((r0 + b).min(m), (c0 + b).min(n));
            let mut amax = 0.0f32;
            for i in r0..r1 {
                for &v in &x.row(i)[c0..c1] {
                    amax = amax.max(v.abs());
                }
            }
            scales[bi * bn + bj] = amax;
            if amax == 0.0 {
                for i in r0..r1 {
                    for j in c0..c1 {
                        codes.set(i * n + j, zero_code);
                    }
                }
                continue;
            }
            let inv = 1.0 / amax;
            for i in r0..r1 {
                let row = x.row(i);
                for j in c0..c1 {
                    codes.set(i * n + j, cb.encode_scalar(row[j] * inv));
                }
            }
        }
    }
    QuantizedMatrix {
        rows: m,
        cols: n,
        block: b,
        bits: q.cfg.bits,
        mapping: q.cfg.mapping,
        codes,
        scales,
    }
}

/// The pre-fusion scalar dequantizer: `scale · decode(get(i·n+j))`.
fn reference_dequantize(q: &BlockQuantizer, qm: &QuantizedMatrix) -> Matrix {
    let (m, n, b) = (qm.rows, qm.cols, qm.block);
    let bn = n.div_ceil(b);
    let cb = q.codebook();
    Matrix::from_fn(m, n, |i, j| {
        qm.scales[(i / b) * bn + j / b] * cb.decode(qm.codes.get(i * n + j))
    })
}

fn quantizer(bits: u32, block: usize, mapping: Mapping) -> BlockQuantizer {
    BlockQuantizer::new(QuantConfig { bits, block, mapping, min_quant_elems: 0 })
}

const SHAPES: [(usize, usize); 6] = [(1, 1), (5, 3), (16, 16), (33, 17), (64, 63), (7, 129)];

#[test]
fn fused_quantize_is_bit_exact_vs_scalar_reference() {
    let mut rng = Rng::new(1);
    for &(m, n) in &SHAPES {
        for block in [1usize, 7, 8, 64] {
            for (bits, mapping) in
                [(4u32, Mapping::Linear2), (4, Mapping::Linear), (8, Mapping::Linear2)]
            {
                let q = quantizer(bits, block, mapping);
                let x = Matrix::randn(m, n, 1.0, &mut rng);
                let fused = q.quantize(&x);
                let want = reference_quantize(&q, &x);
                assert_eq!(fused.scales, want.scales, "{m}x{n} b={block} bits={bits}");
                assert_eq!(fused.codes, want.codes, "{m}x{n} b={block} bits={bits}");
            }
        }
    }
}

#[test]
fn fused_quantize_handles_zero_blocks_and_outliers() {
    // All-zero blocks (zero scale) and single-block outliers exercise the
    // zero_code fill path and block isolation.
    let q = quantizer(4, 8, Mapping::Linear2);
    let mut x = Matrix::zeros(24, 24);
    x[(0, 0)] = 1e6;
    x[(17, 3)] = -2.5;
    let fused = q.quantize(&x);
    let want = reference_quantize(&q, &x);
    assert_eq!(fused.scales, want.scales);
    assert_eq!(fused.codes, want.codes);
    assert_eq!(q.dequantize(&fused), reference_dequantize(&q, &want));
}

#[test]
fn fused_dequantize_is_bit_exact_vs_scalar_reference() {
    let mut rng = Rng::new(2);
    for &(m, n) in &SHAPES {
        for (bits, block) in [(4u32, 8usize), (4, 7), (8, 16)] {
            let q = quantizer(bits, block, Mapping::Linear2);
            let x = Matrix::randn(m, n, 2.0, &mut rng);
            let qm = q.quantize(&x);
            let mut fused = Matrix::zeros(m, n);
            q.dequantize_into(&qm, &mut fused);
            let want = reference_dequantize(&q, &qm);
            assert_eq!(fused, want, "{m}x{n} bits={bits} block={block}");
        }
    }
}

#[test]
fn parallel_quantize_is_bit_identical_to_sequential() {
    let mut rng = Rng::new(3);
    // Odd column counts make rows start mid-byte — the even-aligned
    // chunking guard is exactly what keeps the parallel result identical.
    for &(m, n) in &[(33usize, 17usize), (64, 63), (128, 129), (96, 96)] {
        for bits in [4u32, 8] {
            let q = quantizer(bits, 16, Mapping::Linear2);
            let x = Matrix::randn(m, n, 1.0, &mut rng);
            let mut seq = q.quantize(&x); // shell
            let mut par = q.quantize(&x);
            q.quantize_into_threaded(&x, &mut seq, 1);
            for threads in [2usize, 3, 8] {
                q.quantize_into_threaded(&x, &mut par, threads);
                assert_eq!(par.scales, seq.scales, "{m}x{n} t={threads} bits={bits}");
                assert_eq!(par.codes, seq.codes, "{m}x{n} t={threads} bits={bits}");
            }

            let mut out_seq = Matrix::zeros(m, n);
            let mut out_par = Matrix::zeros(m, n);
            q.dequantize_into_threaded(&seq, &mut out_seq, 1);
            for threads in [2usize, 5, 8] {
                q.dequantize_into_threaded(&seq, &mut out_par, threads);
                assert_eq!(out_par, out_seq, "{m}x{n} t={threads} bits={bits}");
            }
        }
    }
}

#[test]
fn quantize_into_reuses_buffers_and_matches_fresh() {
    let mut rng = Rng::new(4);
    let q = quantizer(4, 8, Mapping::Linear2);
    // Warm a shell on a larger shape, then reuse it for smaller/equal ones:
    // stale codes, scales and metadata must be fully overwritten.
    let mut shell = q.quantize(&Matrix::randn(64, 63, 1.0, &mut rng));
    for &(m, n) in &[(64usize, 63usize), (33, 17), (16, 16)] {
        let x = Matrix::randn(m, n, 1.0, &mut rng);
        q.quantize_into(&x, &mut shell);
        let fresh = q.quantize(&x);
        assert_eq!(shell.scales, fresh.scales, "{m}x{n}");
        assert_eq!(shell.codes, fresh.codes, "{m}x{n}");
        assert_eq!((shell.rows, shell.cols, shell.block), (m, n, 8));
        assert_eq!(q.dequantize(&shell), q.dequantize(&fresh));
    }
}

#[test]
fn tri_store_matches_masked_matrix_reference() {
    // The fused joint store must equal the unfused recipe: quantize the
    // masked triangles with the scalar reference, dequantize, re-mask.
    use quartz::quant::TriJointStore;
    let mut rng = Rng::new(5);
    for n in [9usize, 17, 33] {
        for block in [4usize, 8, 64] {
            let q = quantizer(4, block, Mapping::Linear2);
            let c = Matrix::from_fn(n, n, |i, j| {
                if i > j {
                    rng.normal_f32(1.0)
                } else if i == j {
                    2.0 + (i as f32) * 0.1
                } else {
                    0.0
                }
            });
            let e = Matrix::from_fn(n, n, |i, j| if i > j { rng.normal_f32(0.1) } else { 0.0 });
            let store = TriJointStore::store(&c, &e, &q);
            let (cl, el) = store.load(&q);

            let mask = |x: &Matrix, keep_diag: Option<&Matrix>| {
                let deq = reference_dequantize(&q, &reference_quantize(&q, x));
                Matrix::from_fn(n, n, |i, j| {
                    if i > j {
                        deq[(i, j)]
                    } else if i == j {
                        keep_diag.map(|d| d[(i, i)]).unwrap_or(0.0)
                    } else {
                        0.0
                    }
                })
            };
            let c_off = Matrix::from_fn(n, n, |i, j| if i > j { c[(i, j)] } else { 0.0 });
            let e_off = Matrix::from_fn(n, n, |i, j| if i > j { e[(i, j)] } else { 0.0 });
            assert_eq!(cl, mask(&c_off, Some(&c)), "C n={n} block={block}");
            assert_eq!(el, mask(&e_off, None), "E n={n} block={block}");
        }
    }
}

#[test]
fn blocked_cholesky_matches_naive_within_1e5() {
    let mut rng = Rng::new(6);
    // Orders straddling the crossover, panel-divisible and not.
    for n in [CHOLESKY_BLOCKED_MIN, 127, 160, 257] {
        for trial in 0..2 {
            let g = Matrix::randn(n, n + 16, 1.0, &mut rng);
            let mut a = syrk(&g);
            a.add_diag(1.0);
            let fast = cholesky(&a).expect("blocked factor");
            let slow = cholesky_naive(&a).expect("naive factor");
            let rel = relative_error(&slow, &fast);
            assert!(
                rel < 1e-5,
                "n={n} trial={trial}: blocked deviates from naive, rel Frobenius {rel}"
            );
            // And it is a genuine factor of A.
            let recon = quartz::linalg::matmul_nt(&fast, &fast);
            let err = relative_error(&a, &recon);
            assert!(err < 1e-4, "n={n}: reconstruction error {err}");
        }
    }
    // Sanity on the metric itself.
    assert!(fro_norm(&Matrix::eye(4)) > 1.0);
}

#[test]
fn steady_state_refresh_reuses_scratch() {
    // The acceptance contract: once warmed up, a refresh step's
    // store/load/root pipeline performs zero scratch-pool misses — every
    // matrix temporary is a reused buffer. One layer ⇒ one worker ⇒ one
    // arena, so the stats are deterministic.
    let cfg = ShampooConfig {
        t1: 1,
        t2: 1,
        variant: ShampooVariant::Cq4 { error_feedback: true },
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let mut sh = Shampoo::new(BaseOptimizer::sgd(0.05, 0.0), cfg, &[(48, 32)]);
    let mut rng = Rng::new(7);
    let mut params = vec![Matrix::randn(48, 32, 0.5, &mut rng)];
    let mut step = |sh: &mut Shampoo, k: u64, rng: &mut Rng| {
        let grads = vec![Matrix::randn(48, 32, 0.5, rng)];
        sh.step(&mut params, &grads, k, 1.0);
    };
    // Warm-up: first refresh swaps root codecs f32→vq4 and sizes buffers.
    step(&mut sh, 1, &mut rng);
    step(&mut sh, 2, &mut rng);
    let warm = sh.scratch_stats();
    assert_eq!(warm.arenas, 1, "single layer must use a single arena");
    for k in 3..=10u64 {
        step(&mut sh, k, &mut rng);
    }
    let steady = sh.scratch_stats();
    assert_eq!(steady.arenas, 1);
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state refresh allocated scratch (misses {} → {})",
        warm.misses, steady.misses
    );
    assert_eq!(
        steady.plan_grows, warm.plan_grows,
        "steady-state refresh regrew GEMM packing buffers ({} → {})",
        warm.plan_grows, steady.plan_grows
    );
    assert!(steady.hits > 0, "refresh pipeline must actually draw from the pool");
    for p in &params {
        assert!(!p.has_non_finite());
    }
}

// ---------------------------------------------------------------------------
// Packed-panel GEMM tier
// ---------------------------------------------------------------------------

/// Shapes chosen to stress every packing edge: below the small-dispatch
/// floor, exact register-tile multiples, one-past-a-tile odd sizes, shapes
/// crossing the `KC` slab boundary, and tall/wide rectangles.
const GEMM_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (5, 3, 2),
    (6, 16, 240),
    (7, 17, 241),
    (64, 64, 64),
    (97, 50, 193),
    (130, 200, 70),
];

fn naive_gemm(a: &Matrix, ta: bool, b: &Matrix, tb: bool) -> Matrix {
    let (m, k) = if ta { (a.cols(), a.rows()) } else { (a.rows(), a.cols()) };
    let n = if tb { b.rows() } else { b.cols() };
    Matrix::from_fn(m, n, |i, j| {
        let mut acc = 0.0f32;
        for p in 0..k {
            let x = if ta { a[(p, i)] } else { a[(i, p)] };
            let y = if tb { b[(j, p)] } else { b[(p, j)] };
            acc += x * y;
        }
        acc
    })
}

#[test]
fn avx2_gemm_matches_scalar_oracle_within_1e5() {
    if !avx2_available() {
        eprintln!("avx2+fma unavailable; skipping AVX2-vs-scalar equivalence");
        return;
    }
    let mut rng = Rng::new(40);
    for &(m, n, k) in GEMM_SHAPES {
        for (ta, tb) in [(false, false), (true, false), (false, true), (true, true)] {
            let (ar, ac) = if ta { (k, m) } else { (m, k) };
            let (br, bc) = if tb { (n, k) } else { (k, n) };
            let a = Matrix::randn(ar, ac, 1.0, &mut rng);
            let b = Matrix::randn(br, bc, 1.0, &mut rng);
            let mut plan = MatmulPlan::new();
            let mut fast = Matrix::zeros(m, n);
            let mut slow = Matrix::zeros(m, n);
            gemm_with(&a, ta, &b, tb, &mut fast, &mut plan, Microkernel::Avx2, 1);
            gemm_with(&a, ta, &b, tb, &mut slow, &mut plan, Microkernel::Scalar, 1);
            let rel = relative_error(&slow, &fast);
            assert!(
                rel < 1e-5,
                "{m}x{n}x{k} ta={ta} tb={tb}: AVX2 vs scalar rel Frobenius {rel}"
            );
            // And the scalar kernel against the textbook triple loop.
            let oracle = naive_gemm(&a, ta, &b, tb);
            let rel = relative_error(&oracle, &slow);
            assert!(rel < 1e-5, "{m}x{n}x{k} ta={ta} tb={tb}: scalar vs naive rel {rel}");
        }
    }
}

#[test]
fn gemm_parallel_is_bit_identical_to_sequential() {
    // (150, 500, 410) exercises the jc column-slab grain; (500, 300, 64)
    // is tall-skinny (single jc slab) and exercises the ic row-stripe
    // grain. Both must be bit-identical to the sequential run.
    let mut rng = Rng::new(41);
    for (m, k, n) in [(150usize, 500usize, 410usize), (500, 300, 64)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        for kernel in [Microkernel::Scalar, Microkernel::Avx2] {
            if kernel == Microkernel::Avx2 && !avx2_available() {
                continue;
            }
            let mut plan = MatmulPlan::new();
            let mut seq = Matrix::zeros(m, n);
            gemm_with(&a, false, &b, false, &mut seq, &mut plan, kernel, 1);
            for threads in [2, 4, 7] {
                let mut par = Matrix::zeros(m, n);
                gemm_with(&a, false, &b, false, &mut par, &mut plan, kernel, threads);
                assert_eq!(seq, par, "{kernel:?} {m}x{k}x{n} threads={threads}");
            }
        }
    }
}

#[test]
fn syrk_writes_only_the_lower_triangle() {
    let mut rng = Rng::new(42);
    let a = Matrix::randn(37, 29, 1.0, &mut rng);
    // Via the public routing entry point…
    let mut c = Matrix::from_fn(37, 37, |_, _| 7.5);
    syrk_lower_into(&a, &mut c);
    // …and via the tier directly with an explicit kernel.
    let mut plan = MatmulPlan::new();
    let mut c2 = Matrix::from_fn(37, 37, |_, _| 7.5);
    syrk_lower_with(&a, &mut c2, &mut plan, Microkernel::Scalar, 1);
    let full = naive_gemm(&a, false, &a, true);
    for i in 0..37 {
        for j in 0..37 {
            if j > i {
                assert_eq!(c[(i, j)], 7.5, "upper ({i},{j}) clobbered by syrk_lower_into");
                assert_eq!(c2[(i, j)], 7.5, "upper ({i},{j}) clobbered by syrk_lower_with");
            } else {
                let want = full[(i, j)];
                assert!((c[(i, j)] - want).abs() <= 1e-4 * want.abs().max(1.0));
                assert!((c2[(i, j)] - want).abs() <= 1e-4 * want.abs().max(1.0));
            }
        }
    }
}

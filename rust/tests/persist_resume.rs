//! The bit-identical resume oracle (the persistence layer's pinned
//! contract): training N steps produces exactly the same parameter and
//! optimizer-state bytes as training k steps, being killed, and resuming
//! from the newest valid checkpoint for the remaining N−k — for full
//! quantized Shampoo stacks (packed 4-bit codes, scales, EF triangles,
//! eigen factors, momentum, refresh-scheduler metadata, RNG stream) under
//! the staleness refresh policy. Also pins the corruption story: a
//! CRC-broken newest checkpoint falls back to the previous valid one, and
//! a spec-hash mismatch restarts from scratch instead of restoring
//! incompatible state.

use quartz::optim::BaseOptimizer;
use quartz::persist::{list_checkpoints, spec_hash};
use quartz::quant::QuantConfig;
use quartz::shampoo::ShampooConfig;
use quartz::train::registry;
use quartz::train::synthetic::final_params_synthetic;
use quartz::train::{OptimizerStack, SyntheticSpec, TrainConfig};
use quartz::util::bytes::ByteWriter;
use std::path::PathBuf;

fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("quartz-resume-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn spec() -> SyntheticSpec {
    SyntheticSpec { shapes: vec![(12, 8), (8, 8), (6, 4)], noise: 0.05, pace_ms: 0 }
}

/// A small quantized-Shampoo stack under the staleness refresh policy;
/// `min_quant_elems: 0` so even these tiny blocks actually quantize.
fn stack(key: &str) -> OptimizerStack {
    let cfg = ShampooConfig {
        t1: 2,
        t2: 4,
        max_order: 8,
        refresh_policy: "staleness",
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    registry::build(key, BaseOptimizer::sgdm(0.05, 0.9, 0.0), &cfg, &spec().shapes)
        .unwrap_or_else(|| panic!("stack key '{key}' not registered"))
}

/// Like [`stack`] but with workload knobs layered on: a (possibly
/// stateful) graft and a `start_preconditioning_step` warmup window.
fn stack_workload(key: &str, graft: &'static str, warmup: u64) -> OptimizerStack {
    let cfg = ShampooConfig {
        t1: 2,
        t2: 4,
        max_order: 8,
        refresh_policy: "staleness",
        graft,
        start_preconditioning_step: warmup,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    registry::build(key, BaseOptimizer::sgdm(0.05, 0.9, 0.0), &cfg, &spec().shapes)
        .unwrap_or_else(|| panic!("stack key '{key}' not registered"))
}

fn cfg(steps: u64, dir: Option<PathBuf>, hash: u64) -> TrainConfig {
    TrainConfig {
        steps,
        seed: 7,
        log_every: 5,
        checkpoint_every: 5,
        checkpoint_dir: dir,
        spec_hash: hash,
        ..Default::default()
    }
}

fn opt_state_bytes(stack: &OptimizerStack) -> Vec<u8> {
    let mut w = ByteWriter::new();
    stack.save_state(&mut w).unwrap();
    w.into_bytes()
}

/// train N ≡ train k + kill + resume + train N−k, byte-exactly.
fn oracle(key: &str) {
    let dir = test_dir(key);
    let hash = spec_hash(&format!("oracle|{key}"));
    let spec = spec();

    // Uninterrupted reference: 20 steps straight through.
    let (pa, oa) = final_params_synthetic(&spec, stack(key), &cfg(20, None, hash)).unwrap();

    // Interrupted run: killed after step 12, checkpoints at 5 and 10.
    final_params_synthetic(&spec, stack(key), &cfg(12, Some(dir.clone()), hash)).unwrap();
    let steps: Vec<u64> = list_checkpoints(&dir).iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![5, 10], "{key}: unexpected checkpoints");

    // Resume: restores step 10, trains 11..=20.
    let (pb, ob) =
        final_params_synthetic(&spec, stack(key), &cfg(20, Some(dir.clone()), hash)).unwrap();

    for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(a.max_abs_diff(b), 0.0, "{key}: param {i} diverged after resume");
    }
    assert_eq!(opt_state_bytes(&oa), opt_state_bytes(&ob), "{key}: optimizer state diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_is_bit_identical_for_cq_ef() {
    oracle("cq-ef");
}

#[test]
fn resume_is_bit_identical_for_ec4() {
    oracle("ec4");
}

#[test]
fn resume_is_bit_identical_for_f16() {
    oracle("f16");
}

/// A stateful graft's accumulators are optimizer state: the kill/resume
/// oracle must hold bit-exactly with `adagrad` grafting on (accumulator
/// bytes ride in the checkpoint and the serialized-state comparison).
#[test]
fn resume_is_bit_identical_with_adagrad_graft() {
    let dir = test_dir("graft-ada");
    let hash = spec_hash("oracle|graft-ada");
    let spec = spec();
    let mk = || stack_workload("cq-ef", "adagrad", 0);

    let (pa, oa) = final_params_synthetic(&spec, mk(), &cfg(20, None, hash)).unwrap();
    final_params_synthetic(&spec, mk(), &cfg(12, Some(dir.clone()), hash)).unwrap();
    let steps: Vec<u64> = list_checkpoints(&dir).iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![5, 10], "unexpected checkpoints");
    let (pb, ob) =
        final_params_synthetic(&spec, mk(), &cfg(20, Some(dir.clone()), hash)).unwrap();

    for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(a.max_abs_diff(b), 0.0, "param {i} diverged after resume");
    }
    assert_eq!(opt_state_bytes(&oa), opt_state_bytes(&ob), "graft accumulators diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A checkpoint taken INSIDE the `start_preconditioning_step` window (root
/// slots never computed, `root_live` false) must resume bit-identically:
/// the continuation neither re-runs nor skips warmup steps, and crosses
/// the warmup boundary exactly where the uninterrupted run does.
#[test]
fn resume_from_mid_warmup_checkpoint_is_bit_identical() {
    let dir = test_dir("graft-warmup");
    let hash = spec_hash("oracle|graft-warmup");
    let spec = spec();
    let mk = || stack_workload("cq-ef", "adagrad", 8);

    let (pa, oa) = final_params_synthetic(&spec, mk(), &cfg(20, None, hash)).unwrap();
    // Killed at step 7 — the only checkpoint (step 5) sits mid-warmup.
    final_params_synthetic(&spec, mk(), &cfg(7, Some(dir.clone()), hash)).unwrap();
    let steps: Vec<u64> = list_checkpoints(&dir).iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![5], "expected a single mid-warmup checkpoint");
    // Resume restores step 5 and trains 6..=20, entering preconditioning
    // at step 8 exactly once.
    let (pb, ob) =
        final_params_synthetic(&spec, mk(), &cfg(20, Some(dir.clone()), hash)).unwrap();

    for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(a.max_abs_diff(b), 0.0, "param {i} diverged after mid-warmup resume");
    }
    assert_eq!(opt_state_bytes(&oa), opt_state_bytes(&ob), "optimizer state diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_newest_checkpoint_falls_back_to_previous_valid_one() {
    let key = "cq-ef";
    let dir = test_dir("crc");
    let hash = spec_hash("oracle|crc");
    let spec = spec();

    let (pa, oa) = final_params_synthetic(&spec, stack(key), &cfg(20, None, hash)).unwrap();
    final_params_synthetic(&spec, stack(key), &cfg(12, Some(dir.clone()), hash)).unwrap();

    // Flip one bit in the newest checkpoint (step 10): its CRC fails and
    // the resume scan must fall back to step 5 — and still reproduce the
    // uninterrupted run exactly.
    let ckpts = list_checkpoints(&dir);
    let (newest_step, newest_path) = ckpts.last().unwrap();
    assert_eq!(*newest_step, 10);
    let mut bytes = std::fs::read(newest_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(newest_path, &bytes).unwrap();

    let (pb, ob) =
        final_params_synthetic(&spec, stack(key), &cfg(20, Some(dir.clone()), hash)).unwrap();
    for (a, b) in pa.iter().zip(pb.iter()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(opt_state_bytes(&oa), opt_state_bytes(&ob));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spec_hash_mismatch_restarts_instead_of_restoring() {
    let key = "cq-ef";
    let dir = test_dir("hash");
    let spec = spec();
    let hash_a = spec_hash("spec-a");
    let hash_b = spec_hash("spec-b");

    // Checkpoints written under spec A…
    final_params_synthetic(&spec, stack(key), &cfg(12, Some(dir.clone()), hash_a)).unwrap();
    assert!(!list_checkpoints(&dir).is_empty());

    // …are invisible to a run pinned to spec B: it trains from scratch and
    // matches a fresh uninterrupted run exactly.
    let (pa, _) = final_params_synthetic(&spec, stack(key), &cfg(20, None, hash_b)).unwrap();
    let (pb, _) =
        final_params_synthetic(&spec, stack(key), &cfg(20, Some(dir.clone()), hash_b)).unwrap();
    for (a, b) in pa.iter().zip(pb.iter()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

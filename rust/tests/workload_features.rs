//! Oracle-grade coverage for the scalable-Shampoo workload features:
//!
//! 1. **Graft variants** — every registered graft key's trajectory pinned
//!    bit-for-bit against a naive sequential per-layer reference written
//!    here (accumulator math included), with refresh work scheduled so the
//!    work-queue executor's `parallel_for` path is the one under test.
//! 2. **`shape_interpretation`** — a synthetic 4-D layer stepped through
//!    `Shampoo::new_nd` equals the same run hand-reshaped into a matrix
//!    list, bit-for-bit; knob off equals the classic flatten.
//! 3. **`start_preconditioning_step`** — warmup steps are bit-identical to
//!    the bare base optimizer and the scheduler plans zero units; the
//!    threshold step engages preconditioning.
//! 4. **`no_preconditioning_for_layers_with_dim_gt`** — opted-out layers
//!    hold exactly zero codec state and follow the grafted base path.

use quartz::linalg::{fro_norm, Matrix, ScratchArena};
use quartz::optim::BaseOptimizer;
use quartz::quant::{BlockQuantizer, CodecCtx, QuantConfig};
use quartz::shampoo::{LayerState, Shampoo, ShampooConfig};
use quartz::util::rng::Rng;
use std::sync::Arc;

/// In-test reference for the graft family (mirrors `optim::grafting`'s
/// per-element accumulator order exactly — bit-identity depends on it).
fn ref_graft(key: &str, g: &Matrix, ghat: &mut Matrix, acc: &mut Matrix, eps: f32, beta: f32) {
    let m: f64 = match key {
        "none" => return,
        "sgd" => fro_norm(g),
        "sqrt-n" => ((g.rows() * g.cols()) as f64).sqrt(),
        "adagrad" | "rmsprop" => {
            let mut sum = 0.0f64;
            for (a, &gi) in acc.data_mut().iter_mut().zip(g.data()) {
                *a = if key == "adagrad" {
                    *a + gi * gi
                } else {
                    beta * *a + (1.0 - beta) * (gi * gi)
                };
                let ratio = gi / (a.sqrt() + eps);
                sum += ratio as f64 * ratio as f64;
            }
            sum.sqrt()
        }
        other => panic!("unknown graft '{other}'"),
    };
    let np = fro_norm(ghat);
    if np > 0.0 && m.is_finite() && np.is_finite() {
        ghat.scale((m / np) as f32);
    }
}

fn graft_cfg(graft: &'static str) -> ShampooConfig {
    ShampooConfig {
        t1: 1,
        t2: 2,
        max_order: 8,
        graft,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    }
}

fn randn_set(shapes: &[(usize, usize)], scale: f32, rng: &mut Rng) -> Vec<Matrix> {
    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, scale, rng)).collect()
}

/// The fanned-out engine (multi-block layers, refresh tasks every step at
/// t1 = 1) must reproduce a hand-written sequential per-layer loop — the
/// public `update_gram` / `update_inv_roots` / `precondition` operations
/// plus [`ref_graft`] — bit-for-bit, for every graft variant, including a
/// passthrough vector layer where the graft acts on the raw gradient.
fn graft_oracle(graft_key: &'static str) {
    let shapes = [(12usize, 8usize), (8, 8), (16, 4), (5, 1)];
    let cfg = graft_cfg(graft_key);
    let mut rng = Rng::new(51);
    let params0 = randn_set(&shapes, 0.5, &mut rng);
    let grads: Vec<Vec<Matrix>> = (0..8).map(|_| randn_set(&shapes, 0.5, &mut rng)).collect();

    // Engine under test: the work-queue executor.
    let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &shapes);
    let mut pa = params0.clone();
    for k in 1..=8u64 {
        sh.step(&mut pa, &grads[k as usize - 1], k, 1.0);
    }
    assert!(sh.refresh_stats().gram_units > 0, "oracle must cover refresh steps");

    // Naive sequential reference over the same public per-layer operations.
    let ctx = CodecCtx::new(cfg.eps, cfg.beta_e, Arc::new(BlockQuantizer::new(cfg.quant)));
    let mut layers: Vec<LayerState> =
        shapes.iter().map(|&(m, n)| LayerState::new(m, n, &cfg, &ctx)).collect();
    let mut accs: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut base = BaseOptimizer::sgdm(0.05, 0.9, 0.0);
    base.init(shapes.len());
    let mut pb = params0.clone();
    let mut scratch = ScratchArena::new();
    for k in 1..=8u64 {
        for i in 0..shapes.len() {
            let g = &grads[k as usize - 1][i];
            if k % cfg.t1 == 0 {
                layers[i].update_gram(g, &cfg, &mut scratch);
            }
            if k % cfg.t2 == 0 {
                layers[i].update_inv_roots(&cfg, &ctx, &mut scratch);
            }
            let mut ghat = layers[i].precondition(g);
            ref_graft(graft_key, g, &mut ghat, &mut accs[i], cfg.eps, cfg.beta);
            base.step_param(i, &mut pb[i], &ghat, 1.0);
        }
    }

    for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(
            a.max_abs_diff(b),
            0.0,
            "graft '{graft_key}': param {i} diverged from the sequential oracle"
        );
        assert!(!a.has_non_finite(), "graft '{graft_key}': param {i} not finite");
    }
}

#[test]
fn none_graft_matches_sequential_oracle() {
    graft_oracle("none");
}

#[test]
fn sgd_graft_matches_sequential_oracle() {
    graft_oracle("sgd");
}

#[test]
fn adagrad_graft_matches_sequential_oracle() {
    graft_oracle("adagrad");
}

#[test]
fn rmsprop_graft_matches_sequential_oracle() {
    graft_oracle("rmsprop");
}

#[test]
fn sqrt_n_graft_matches_sequential_oracle() {
    graft_oracle("sqrt-n");
}

/// A 4-D `[2, 2, 8, 6]` layer under `shape_interpretation` must follow the
/// same trajectory as the run hand-reshaped into four independent `[8, 6]`
/// layers (grafting off — graft norms are whole-variable by contract), and
/// with the knob off must equal the classic flatten, both bit-for-bit.
#[test]
fn shape_interpretation_matches_hand_reshaped_matrix_list() {
    let cfg = ShampooConfig {
        t1: 1,
        t2: 2,
        max_order: 8,
        grafting: false,
        shape_interpretation: true,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let (c, m, n) = (4usize, 8usize, 6usize); // [2, 2, 8, 6] → 4 chunks
    let mut rng = Rng::new(61);
    let chunk_params: Vec<Matrix> = (0..c).map(|_| Matrix::randn(m, n, 0.5, &mut rng)).collect();
    let chunk_grads: Vec<Vec<Matrix>> =
        (0..6).map(|_| (0..c).map(|_| Matrix::randn(m, n, 0.5, &mut rng)).collect()).collect();
    let stack = |parts: &[Matrix]| Matrix::from_fn(c * m, n, |i, j| parts[i / m][(i % m, j)]);

    // ND optimizer stepping the collapsed (32, 6) parameter.
    let mut nd =
        Shampoo::new_nd(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &[vec![2, 2, m, n]]);
    assert_eq!(nd.unit_count(), 2 * c, "each chunk carries its own (L, R) pair");
    let mut p_nd = vec![stack(&chunk_params)];
    for k in 1..=6u64 {
        let g = vec![stack(&chunk_grads[k as usize - 1])];
        nd.step(&mut p_nd, &g, k, 1.0);
    }

    // Control: the same run as four independent matrix layers.
    let shapes: Vec<(usize, usize)> = (0..c).map(|_| (m, n)).collect();
    let mut ctrl = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &shapes);
    let mut p_ctrl = chunk_params.clone();
    for k in 1..=6u64 {
        ctrl.step(&mut p_ctrl, &chunk_grads[k as usize - 1], k, 1.0);
    }

    let expect = stack(&p_ctrl);
    assert_eq!(
        p_nd[0].max_abs_diff(&expect),
        0.0,
        "chunked ND trajectory must equal the hand-reshaped matrix list"
    );

    // Knob off: the ND shape flattens to one (32, 6) layer, bit-identical
    // to `Shampoo::new` on the collapsed shape.
    let cfg_off = ShampooConfig { shape_interpretation: false, ..cfg };
    let mut nd_off =
        Shampoo::new_nd(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg_off, &[vec![2, 2, m, n]]);
    let mut flat = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg_off, &[(c * m, n)]);
    let mut p_a = vec![stack(&chunk_params)];
    let mut p_b = vec![stack(&chunk_params)];
    for k in 1..=6u64 {
        let g = vec![stack(&chunk_grads[k as usize - 1])];
        nd_off.step(&mut p_a, &g, k, 1.0);
        flat.step(&mut p_b, &g, k, 1.0);
    }
    assert_eq!(p_a[0].max_abs_diff(&p_b[0]), 0.0, "knob off must be the classic flatten");
}

/// Steps below `start_preconditioning_step` must be bit-identical to the
/// bare base optimizer (the default sgd graft rescales by exactly 1.0 on
/// the raw gradient) with zero planned refresh units; the threshold step
/// engages preconditioning and the trajectory departs.
#[test]
fn warmup_steps_are_bit_identical_to_bare_base_optimizer() {
    let shapes = [(12usize, 8usize), (8, 8), (5, 1)];
    let cfg = ShampooConfig {
        t1: 1,
        t2: 1,
        max_order: 8,
        start_preconditioning_step: 5,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let mut rng = Rng::new(71);
    let params0 = randn_set(&shapes, 0.5, &mut rng);
    let grads: Vec<Vec<Matrix>> = (0..5).map(|_| randn_set(&shapes, 0.5, &mut rng)).collect();

    let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &shapes);
    let mut pa = params0.clone();
    let mut base = BaseOptimizer::sgdm(0.05, 0.9, 0.0);
    base.init(shapes.len());
    let mut pb = params0;
    for k in 1..=4u64 {
        sh.step(&mut pa, &grads[k as usize - 1], k, 1.0);
        for i in 0..shapes.len() {
            base.step_param(i, &mut pb[i], &grads[k as usize - 1][i], 1.0);
        }
        for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
            assert_eq!(a.max_abs_diff(b), 0.0, "warmup step {k}: param {i} departed from base");
        }
    }
    let s = sh.refresh_stats();
    assert_eq!(s.steps, 4);
    assert_eq!((s.gram_units, s.root_units), (0, 0), "warmup must plan zero refresh units");

    // Threshold step: (t1, t2) = (1, 1) refreshes gram and roots
    // immediately and preconditioning engages.
    sh.step(&mut pa, &grads[4], 5, 1.0);
    for i in 0..shapes.len() {
        base.step_param(i, &mut pb[i], &grads[4][i], 1.0);
    }
    let s = sh.refresh_stats();
    assert!(s.gram_units > 0 && s.root_units > 0, "threshold step must schedule refreshes");
    let departed = pa.iter().zip(pb.iter()).any(|(a, b)| a.max_abs_diff(b) > 0.0);
    assert!(departed, "preconditioning must engage at the threshold step");
}

/// A layer over the `no_preconditioning_for_layers_with_dim_gt` bound holds
/// exactly zero codec state (no blocks, no refresh units) and its update
/// equals the grafted base path on the raw gradient, bit-for-bit.
#[test]
fn dim_gt_opt_out_takes_grafted_base_path_with_zero_codec_state() {
    let shapes = [(40usize, 8usize), (8, 8)];
    let cfg = ShampooConfig {
        t1: 1,
        t2: 1,
        max_order: 8,
        graft: "adagrad",
        no_preconditioning_for_layers_with_dim_gt: 32,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let mut rng = Rng::new(81);
    let params0 = randn_set(&shapes, 0.5, &mut rng);
    let grads: Vec<Vec<Matrix>> = (0..4).map(|_| randn_set(&shapes, 0.5, &mut rng)).collect();

    let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &shapes);
    let mut pa = params0.clone();
    for k in 1..=4u64 {
        sh.step(&mut pa, &grads[k as usize - 1], k, 1.0);
    }

    // The opted-out (40, 8) layer: passthrough, zero units, zero codec
    // bytes — its graft accumulator lives outside the layer state.
    assert!(sh.layers[0].passthrough);
    assert_eq!(sh.layers[0].unit_count(), 0);
    assert_eq!(sh.layers[0].size_bytes(), 0, "opted-out layer must hold zero codec state");
    assert!(!sh.layers[1].passthrough, "under-bound layer is still preconditioned");

    // Its trajectory is the grafted base path on the raw gradient.
    let mut base = BaseOptimizer::sgdm(0.05, 0.9, 0.0);
    base.init(1);
    let mut pb = params0[0].clone();
    let mut acc = Matrix::zeros(40, 8);
    for k in 1..=4u64 {
        let g = &grads[k as usize - 1][0];
        let mut ghat = g.clone();
        ref_graft("adagrad", g, &mut ghat, &mut acc, cfg.eps, cfg.beta);
        base.step_param(0, &mut pb, &ghat, 1.0);
    }
    assert_eq!(pa[0].max_abs_diff(&pb), 0.0, "opted-out layer must take the grafted base path");
}

//! Fault-tolerance soak suite: deterministic chaos via `util::fault`.
//!
//! The guard engine's contract under injected faults:
//! * training stays finite — screened gradients never reach params,
//!   momentum, or preconditioner state;
//! * health counters match the injected schedule *exactly* (the fault plan
//!   is a pure function of `(seed, step)`, so tests replay it);
//! * quarantined units are released by probation once faults stop — no
//!   unit is permanently degraded;
//! * kill + resume under an active fault plan is bit-identical to the
//!   uninterrupted run, and bit-flipped checkpoints are detected by the
//!   CRC so resume falls back to the newest intact snapshot.

use quartz::linalg::Matrix;
use quartz::optim::{BaseOptimizer, Optimizer};
use quartz::persist::{latest_valid, list_checkpoints};
use quartz::quant::QuantConfig;
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::train::synthetic::{final_params_synthetic, train_synthetic, SyntheticSpec};
use quartz::train::trainer::TrainConfig;
use quartz::train::OptimizerStack;
use quartz::util::fault::FaultPlan;

fn shampoo_cfg() -> ShampooConfig {
    ShampooConfig {
        variant: ShampooVariant::Cq4 { error_feedback: true },
        t1: 1,
        t2: 4,
        max_order: 64,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    }
}

fn cq_stack(cfg: &ShampooConfig, shapes: &[(usize, usize)]) -> OptimizerStack {
    OptimizerStack::shampoo(Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), *cfg, shapes))
}

#[test]
fn soak_stays_finite_and_counters_match_injected_schedule() {
    const STEPS: u64 = 240;
    let spec = SyntheticSpec::default();
    let plan = FaultPlan {
        seed: 7,
        nan_grad_every: 13,
        inf_grad_every: 29,
        force_fail_every: 17,
        fail_one_in: 2,
        until_step: 120,
        ..Default::default()
    };
    let shcfg = shampoo_cfg();
    let cfg = TrainConfig {
        steps: STEPS,
        seed: 11,
        log_every: 10,
        faults: Some(plan.clone()),
        ..Default::default()
    };
    let m = train_synthetic(&spec, cq_stack(&shcfg, &spec.shapes), &cfg).unwrap();

    // Finite throughout: a screened step applies nothing, so the loss
    // (a mean over every parameter) would go NaN if poison ever landed.
    assert!(m.final_metric.is_finite(), "final metric {}", m.final_metric);
    for &(k, l) in &m.loss_curve {
        assert!(l.is_finite(), "loss at step {k} is {l}");
    }

    // Screening counter == the plan's gradient-fault schedule, replayed.
    let expected_screens = (1..=STEPS).filter(|&k| plan.grad_fault(k).is_some()).count() as u64;
    assert_eq!(expected_screens, 13, "fixture: 9 NaN steps + 4 Inf steps in the window");
    assert_eq!(m.health.grads_screened, expected_screens);

    // Stale-root counter == the forced-failure schedule, replayed over the
    // every-n root cadence and the optimizer's actual unit addresses
    // (minus units whose layer was screened that step).
    let probe = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), shcfg, &spec.shapes);
    let mut expected_stale = 0u64;
    for k in 1..=STEPS {
        if k % 4 != 0 {
            continue; // t2 = 4: every-n roots only on these steps
        }
        let poisoned = plan.grad_target(k, spec.shapes.len());
        for (id, _) in probe.unit_metas() {
            if poisoned == Some(id.layer as usize) {
                continue;
            }
            if plan.forces_root_failure(k, id.layer, id.block, id.side.index()) {
                expected_stale += 1;
            }
        }
    }
    assert_eq!(m.health.stale_root_serves, expected_stale);

    // One forced failure per unit at most (17 ∤ consecutive root steps), so
    // nothing ever reaches the quarantine threshold or the floor rung.
    assert_eq!(m.health.quarantines, 0);
    assert_eq!(m.health.releases, 0);
    assert_eq!(m.health.floor_serves, 0);

    // The whole soak — faults included — is bit-deterministic.
    let m2 = train_synthetic(&spec, cq_stack(&shampoo_cfg(), &spec.shapes), &cfg).unwrap();
    assert_eq!(m.final_metric, m2.final_metric);
    assert_eq!(m.loss_curve, m2.loss_curve);
    assert_eq!(m.health, m2.health);
}

#[test]
fn forced_failure_counters_match_replayed_schedule_exactly() {
    // t1 = t2 = 1: every unit refreshes every step, so every forced
    // failure in the active window lands — 10 forced steps × 4 units.
    let shapes = [(8usize, 8usize), (10, 4)];
    let c = ShampooConfig {
        variant: ShampooVariant::Cq4 { error_feedback: true },
        t1: 1,
        t2: 1,
        max_order: 64,
        quarantine_after: 1000,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let plan = FaultPlan { seed: 11, force_fail_every: 3, until_step: 30, ..Default::default() };
    let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), c, &shapes);
    sh.set_fault_plan(Some(&plan));

    let mut params = vec![Matrix::eye(8), Matrix::zeros(10, 4)];
    let grads = vec![
        Matrix::from_fn(8, 8, |i, j| 0.05 * ((i + 2 * j + 1) as f32).sin()),
        Matrix::from_fn(10, 4, |i, j| 0.05 * ((3 * i + j + 1) as f32).cos()),
    ];
    for k in 1..=60u64 {
        sh.step(&mut params, &grads, k, 1.0);
    }

    let expected: u64 = (1..=60u64)
        .map(|k| {
            sh.unit_metas()
                .iter()
                .filter(|(id, _)| plan.forces_root_failure(k, id.layer, id.block, id.side.index()))
                .count() as u64
        })
        .sum();
    assert_eq!(expected, 40, "fixture: steps 3,6,…,30 × 4 units (fail_one_in = 1)");
    assert_eq!(sh.health().stale_root_serves, expected);
    assert_eq!(sh.health().floor_serves, 0, "the stale cache always exists and is finite");
    assert_eq!(sh.health().quarantines, 0, "failures are never consecutive enough");
    assert_eq!(sh.health().grads_screened, 0);
    for p in &params {
        assert!(!p.has_non_finite());
    }

    // Clearing the plan stops the chaos: counters freeze.
    sh.set_fault_plan(None);
    let frozen = sh.health().clone();
    for k in 61..=70u64 {
        sh.step(&mut params, &grads, k, 1.0);
    }
    assert_eq!(*sh.health(), frozen);
}

/// A finite-but-huge gradient is the graft edge the gradient screen cannot
/// catch: the raw gradient passes `has_non_finite`, but its gram products
/// and its preconditioned norm overflow f32. Every overflow site must
/// screen through the health ledger — the stored gram keeps its last
/// finite value and the base update is skipped — instead of poisoning
/// params, momentum, or preconditioner state.
#[test]
fn finite_overflow_gradient_is_screened_at_gram_and_graft() {
    let c = ShampooConfig {
        variant: ShampooVariant::Full32,
        t1: 1,
        t2: 1,
        max_order: 64,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), c, &[(4, 4)]);
    let mut params = vec![Matrix::eye(4)];

    // Step 1: a tiny diagonal gradient caches finite grams and diagonal
    // roots with entries ≈ (λ·ε)^{-1/4} ≫ 1.
    let g_tiny = Matrix::from_fn(4, 4, |i, j| if i == j { 1e-3 * (i + 1) as f32 } else { 0.0 });
    sh.step(&mut params, std::slice::from_ref(&g_tiny), 1, 1.0);
    assert_eq!(sh.health().grads_screened, 0);
    let before = params[0].clone();

    // Step 2: every entry 3e38 — finite in f32, so the gradient screen
    // passes, but G·Gᵀ and L·G·R overflow to Inf.
    let g_huge = Matrix::from_fn(4, 4, |_, _| 3e38);
    sh.step(&mut params, std::slice::from_ref(&g_huge), 2, 1.0);

    // Exactly three screens: both gram products (L and R) and the graft's
    // non-finite preconditioned norm. No fallback-ladder rung fires — the
    // stored gram stayed finite, so the roots recompute healthily.
    assert_eq!(sh.health().grads_screened, 3);
    assert_eq!(sh.health().stale_root_serves, 0);
    assert_eq!(sh.health().floor_serves, 0);
    assert_eq!(sh.health().quarantines, 0);

    // The screened step applied nothing: params bit-unchanged and finite.
    assert_eq!(params[0].max_abs_diff(&before), 0.0);
    assert!(!params[0].has_non_finite());

    // A later finite step recovers without residue.
    sh.step(&mut params, std::slice::from_ref(&g_tiny), 3, 1.0);
    assert_eq!(sh.health().grads_screened, 3);
    assert!(params[0].max_abs_diff(&before) > 0.0);
    assert!(!params[0].has_non_finite());
}

#[test]
fn quarantine_lifecycle_releases_every_unit_once_faults_stop() {
    // Every refresh fails during the fault window: both units hit the
    // quarantine threshold, floor-serve through the window, fail two
    // probation retries while faults are live, and are released by the
    // first post-window probation. Nothing stays quarantined.
    let c = ShampooConfig {
        variant: ShampooVariant::Full32,
        t1: 1,
        t2: 1,
        max_order: 64,
        quarantine_after: 2,
        probation_interval: 5,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let plan = FaultPlan { seed: 3, force_fail_every: 1, until_step: 15, ..Default::default() };
    let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), c, &[(6, 6)]);
    sh.set_fault_plan(Some(&plan));
    assert_eq!(sh.unit_metas().len(), 2);

    let mut params = vec![Matrix::eye(6)];
    let g = Matrix::from_fn(6, 6, |i, j| 0.1 * ((i * 6 + j + 1) as f32).sin());
    for k in 1..=40u64 {
        sh.step(&mut params, std::slice::from_ref(&g), k, 1.0);
        assert!(!params[0].has_non_finite(), "step {k}");
    }

    // Exactly one quarantine entry and one release per unit: probation
    // failures restart the window without re-counting.
    assert_eq!(sh.health().quarantines, 2);
    assert_eq!(sh.health().releases, 2);
    assert!(sh.health().floor_serves > 0, "quarantined units must floor-serve");
    for (id, meta) in sh.unit_metas() {
        assert!(
            !meta.health.is_quarantined(),
            "{id:?} still quarantined after probation: {:?}",
            meta.health
        );
        assert_eq!(meta.health.consecutive_failures, 0, "{id:?}");
        assert_eq!(meta.health.quarantines, 1, "{id:?}");
        assert_eq!(meta.health.releases, 1, "{id:?}");
    }
}

#[test]
fn faulted_run_resumes_bit_identically() {
    let dir = std::env::temp_dir().join(format!("quartz-fault-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = SyntheticSpec::default();
    let shcfg = ShampooConfig { t2: 2, ..shampoo_cfg() };
    let plan = FaultPlan { seed: 5, force_fail_every: 3, fail_one_in: 2, ..Default::default() };

    let straight =
        TrainConfig { steps: 40, seed: 3, faults: Some(plan.clone()), ..Default::default() };
    let (pa, _) = final_params_synthetic(&spec, cq_stack(&shcfg, &spec.shapes), &straight).unwrap();

    // Same run, checkpointed every 15 steps and killed after 30, then
    // resumed to 40 — the fault schedule is a pure function of (plan,
    // step), so the replayed tail corrupts identically.
    let ck = TrainConfig {
        steps: 30,
        seed: 3,
        checkpoint_every: 15,
        checkpoint_dir: Some(dir.clone()),
        faults: Some(plan),
        ..Default::default()
    };
    train_synthetic(&spec, cq_stack(&shcfg, &spec.shapes), &ck).unwrap();
    let resumed = TrainConfig { steps: 40, ..ck };
    let (pb, _) = final_params_synthetic(&spec, cq_stack(&shcfg, &spec.shapes), &resumed).unwrap();
    for (a, b) in pa.iter().zip(pb.iter()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flipped_checkpoints_are_detected_and_resume_falls_back() {
    let dir = std::env::temp_dir().join(format!("quartz-fault-flip-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = SyntheticSpec::default();
    let shcfg = ShampooConfig { t2: 2, ..shampoo_cfg() };
    // Root failures make the trajectory fault-dependent; every second
    // checkpoint (steps 20, 40) takes a single bit flip after writing.
    let plan = FaultPlan {
        seed: 9,
        force_fail_every: 4,
        fail_one_in: 2,
        ckpt_flip_every: 20,
        ..Default::default()
    };
    let ck = TrainConfig {
        steps: 50,
        seed: 9,
        checkpoint_every: 10,
        checkpoint_dir: Some(dir.clone()),
        keep_checkpoints: 3,
        faults: Some(plan.clone()),
        ..Default::default()
    };
    train_synthetic(&spec, cq_stack(&shcfg, &spec.shapes), &ck).unwrap();

    // Retention kept the newest three snapshots (10 was pruned)…
    let steps: Vec<u64> = list_checkpoints(&dir).into_iter().map(|(s, _)| s).collect();
    assert_eq!(steps, vec![20, 30, 40]);
    // …and the CRC rejects the flipped tail (40), falling back to 30.
    let (step, _) = latest_valid(&dir, 0).unwrap().expect("an intact checkpoint survives");
    assert_eq!(step, 30, "bit-flipped step-40 checkpoint must be skipped");

    // Resuming (from 30) and finishing to 60 matches the uninterrupted
    // run bit-for-bit: the flips only ever damaged at-rest files.
    let resumed = TrainConfig { steps: 60, ..ck };
    let (pb, _) = final_params_synthetic(&spec, cq_stack(&shcfg, &spec.shapes), &resumed).unwrap();
    let straight =
        TrainConfig { steps: 60, seed: 9, faults: Some(plan), ..Default::default() };
    let (pa, _) = final_params_synthetic(&spec, cq_stack(&shcfg, &spec.shapes), &straight).unwrap();
    for (a, b) in pa.iter().zip(pb.iter()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

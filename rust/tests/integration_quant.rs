//! Cross-module quantization integration: quantizers ↔ linalg ↔ shampoo
//! state, plus the paper's qualitative claims at integration scope.

use quartz::analysis::{cq_roundtrip, nre_ae, synthetic_pd, vq_roundtrip};
use quartz::linalg::{eig_sym, Matrix};
use quartz::quant::{BlockQuantizer, ErrorFeedback, Mapping, QuantConfig};
use quartz::util::rng::Rng;

#[test]
fn cq_dominates_vq_across_mappings_and_blocks() {
    // The Sec. 4.2 claim must hold regardless of codebook/block choice.
    let mut rng = Rng::new(1);
    let mats: Vec<Matrix> = (0..3).map(|_| synthetic_pd(32, 1e-2, 1e2, &mut rng)).collect();
    for mapping in [Mapping::Linear, Mapping::Linear2, Mapping::Dynamic] {
        for block in [8usize, 32, 64] {
            let q = BlockQuantizer::new(QuantConfig {
                mapping,
                block,
                min_quant_elems: 0,
                ..Default::default()
            });
            let mut vq_sum = 0.0;
            let mut cq_sum = 0.0;
            for a in &mats {
                vq_sum += nre_ae(a, &vq_roundtrip(a, &q)).0;
                cq_sum += nre_ae(a, &cq_roundtrip(a, 1e-6, &q)).0;
            }
            assert!(
                cq_sum < vq_sum,
                "CQ must beat VQ for {mapping:?}/B={block}: cq={cq_sum:.3} vq={vq_sum:.3}"
            );
        }
    }
}

#[test]
fn error_feedback_improves_time_averaged_fidelity() {
    // Sec. 4.3: EF's EMA compensation reduces the time-averaged factor error.
    let q = BlockQuantizer::new(QuantConfig { block: 16, min_quant_elems: 0, ..Default::default() });
    let mut rng = Rng::new(2);
    let n = 24;
    let c = Matrix::from_fn(n, n, |i, j| {
        if i > j {
            rng.normal_f32(1.0)
        } else if i == j {
            2.5
        } else {
            0.0
        }
    });
    for beta_e in [0.5f32, 0.9, 0.95] {
        let ef = ErrorFeedback::new(beta_e);
        let steps = 150;
        let mut e = Matrix::zeros(n, n);
        let mut avg_ef = Matrix::zeros(n, n);
        for _ in 0..steps {
            let comp = ef.compensate(&c, &e);
            let back = q.roundtrip(&comp);
            e = ef.update(&c, &e, &back);
            avg_ef.axpy(1.0 / steps as f32, &back);
        }
        let plain = q.roundtrip(&c);
        let mut err_ef = 0.0f64;
        let mut err_plain = 0.0f64;
        for i in 0..n {
            for j in 0..i {
                err_ef += ((avg_ef[(i, j)] - c[(i, j)]) as f64).powi(2);
                err_plain += ((plain[(i, j)] - c[(i, j)]) as f64).powi(2);
            }
        }
        assert!(
            err_ef < err_plain * 0.6,
            "βₑ={beta_e}: ef={err_ef:.3e} plain={err_plain:.3e}"
        );
    }
}

#[test]
fn quantized_preconditioner_spectra_stay_positive_cq() {
    // Fig. 3's claim at unit scope: CQ-reconstructed preconditioners and
    // their quantized inverse roots have positive spectra.
    let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
    let mut rng = Rng::new(3);
    for _ in 0..5 {
        let a = synthetic_pd(48, 1e-2, 1e2, &mut rng);
        let recon = cq_roundtrip(&a, 1e-6, &q);
        let (vals, _) = eig_sym(&recon, 1e-10, 100);
        assert!(vals[0] > -1e-5, "λmin={}", vals[0]);
    }
}

#[test]
fn four_bit_shampoo_state_is_eighth_of_f32() {
    // End-to-end byte check on a realistic layer: 4-bit codes + scales +
    // diag must land near 1/8 of the f32 PRECONDITIONER payload.
    let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
    let mut rng = Rng::new(4);
    let a = Matrix::randn(512, 512, 1.0, &mut rng);
    let quantized = q.quantize(&a);
    let f32_bytes = 512 * 512 * 4;
    let ratio = quantized.size_bytes() as f64 / f32_bytes as f64;
    assert!((0.12..0.14).contains(&ratio), "ratio {ratio}");
}

//! Cross-module quantization integration: quantizers ↔ linalg ↔ shampoo
//! state, the paper's qualitative claims at integration scope, and the
//! codec-generic property suite every registered `PrecondCodec` must pass.

use quartz::analysis::{cq_roundtrip, nre_ae, synthetic_pd, vq_roundtrip};
use quartz::linalg::{eig_sym, Matrix};
use quartz::quant::codec::{codec_keys, lookup, register, CodecBuilder};
use quartz::quant::{BlockQuantizer, CodecCtx, ErrorFeedback, Mapping, PrecondCodec, QuantConfig};
use quartz::util::rng::Rng;
use std::sync::Arc;

#[test]
fn cq_dominates_vq_across_mappings_and_blocks() {
    // The Sec. 4.2 claim must hold regardless of codebook/block choice.
    let mut rng = Rng::new(1);
    let mats: Vec<Matrix> = (0..3).map(|_| synthetic_pd(32, 1e-2, 1e2, &mut rng)).collect();
    for mapping in [Mapping::Linear, Mapping::Linear2, Mapping::Dynamic] {
        for block in [8usize, 32, 64] {
            let q = BlockQuantizer::new(QuantConfig {
                mapping,
                block,
                min_quant_elems: 0,
                ..Default::default()
            });
            let mut vq_sum = 0.0;
            let mut cq_sum = 0.0;
            for a in &mats {
                vq_sum += nre_ae(a, &vq_roundtrip(a, &q)).0;
                cq_sum += nre_ae(a, &cq_roundtrip(a, 1e-6, &q)).0;
            }
            assert!(
                cq_sum < vq_sum,
                "CQ must beat VQ for {mapping:?}/B={block}: cq={cq_sum:.3} vq={vq_sum:.3}"
            );
        }
    }
}

#[test]
fn error_feedback_improves_time_averaged_fidelity() {
    // Sec. 4.3: EF's EMA compensation reduces the time-averaged factor error.
    let q =
        BlockQuantizer::new(QuantConfig { block: 16, min_quant_elems: 0, ..Default::default() });
    let mut rng = Rng::new(2);
    let n = 24;
    let c = Matrix::from_fn(n, n, |i, j| {
        if i > j {
            rng.normal_f32(1.0)
        } else if i == j {
            2.5
        } else {
            0.0
        }
    });
    for beta_e in [0.5f32, 0.9, 0.95] {
        let ef = ErrorFeedback::new(beta_e);
        let steps = 150;
        let mut e = Matrix::zeros(n, n);
        let mut avg_ef = Matrix::zeros(n, n);
        for _ in 0..steps {
            let comp = ef.compensate(&c, &e);
            let back = q.roundtrip(&comp);
            e = ef.update(&c, &e, &back);
            avg_ef.axpy(1.0 / steps as f32, &back);
        }
        let plain = q.roundtrip(&c);
        let mut err_ef = 0.0f64;
        let mut err_plain = 0.0f64;
        for i in 0..n {
            for j in 0..i {
                err_ef += ((avg_ef[(i, j)] - c[(i, j)]) as f64).powi(2);
                err_plain += ((plain[(i, j)] - c[(i, j)]) as f64).powi(2);
            }
        }
        assert!(
            err_ef < err_plain * 0.6,
            "βₑ={beta_e}: ef={err_ef:.3e} plain={err_plain:.3e}"
        );
    }
}

#[test]
fn quantized_preconditioner_spectra_stay_positive_cq() {
    // Fig. 3's claim at unit scope: CQ-reconstructed preconditioners and
    // their quantized inverse roots have positive spectra.
    let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
    let mut rng = Rng::new(3);
    for _ in 0..5 {
        let a = synthetic_pd(48, 1e-2, 1e2, &mut rng);
        let recon = cq_roundtrip(&a, 1e-6, &q);
        let (vals, _) = eig_sym(&recon, 1e-10, 100);
        assert!(vals[0] > -1e-5, "λmin={}", vals[0]);
    }
}

// ---------------------------------------------------------------------
// Codec-generic property suite: every registered PrecondCodec (including
// any added at runtime) must satisfy the same invariants the shampoo state
// layer relies on. Runs over the registry, so new codecs are covered the
// moment they are registered.
// ---------------------------------------------------------------------

const BLOCK: usize = 16;

fn codec_ctx() -> CodecCtx {
    let q = BlockQuantizer::new(QuantConfig {
        block: BLOCK,
        min_quant_elems: 0,
        ..Default::default()
    });
    CodecCtx::new(1e-6, 0.95, Arc::new(q))
}

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    synthetic_pd(n, 1e-1, 1e1, &mut rng)
}

/// `load(store(x))` stays within the representation's error bound, for
/// every registered codec, at several sizes (including non-block-divisible).
#[test]
fn codec_roundtrip_error_bounds() {
    let ctx = codec_ctx();
    for key in codec_keys() {
        let b = lookup(key).unwrap();
        for (n, seed) in [(12usize, 1u64), (33, 2), (64, 3)] {
            let a = spd(n, seed);
            for ctor in [b.side, b.root] {
                let mut codec = ctor(&ctx);
                codec.store(&a);
                let back = codec.load();
                assert!(!back.has_non_finite(), "{key}/{n}: non-finite");
                let rel = quartz::linalg::relative_error(&a, &back);
                // f32 must be exact; quantized codecs within a loose 4-bit
                // bound (8-bit and CQ are far tighter).
                let bound = if key == "f32" { 1e-12 } else { 0.35 };
                assert!(rel < bound, "{key}/{n}: relative error {rel}");
            }
        }
    }
}

/// `size_bytes` is exact — byte-identical to the closed-form accounting the
/// paper's memory tables use (and `metrics::MemoryModel` mirrors).
#[test]
fn codec_size_bytes_exactness() {
    let ctx = codec_ctx();
    for n in [32usize, 48] {
        let scales = n.div_ceil(BLOCK) * n.div_ceil(BLOCK) * 4;
        let expected: &[(&str, usize)] = &[
            ("f32", n * n * 4),
            ("vq4", (n * n).div_ceil(2) + scales + n * 4),
            ("vq4-full", (n * n).div_ceil(2) + scales),
            ("cq4", ((n * (n + 1)) / 2).div_ceil(2) + n * 4 + scales),
            ("cq4-ef", (n * n).div_ceil(2) + n * 4 + 2 * scales),
            ("bw8", n * n + scales + n * 4),
            // 4-bit eigenvector grid + scales + f32 eigenvalue vector.
            ("ec4", (n * n).div_ceil(2) + scales + n * 4),
            // Two bytes per element, no side-bands.
            ("f16", n * n * 2),
            // The cq4 triangular payload + the per-row f32 scale vector.
            ("cq-r1", ((n * (n + 1)) / 2).div_ceil(2) + n * 4 + scales + n * 4),
        ];
        for &(key, want) in expected {
            let mut codec = (lookup(key).unwrap().side)(&ctx);
            codec.store(&spd(n, 4));
            assert_eq!(codec.size_bytes(), want, "{key} at n={n}");
        }
    }
}

/// The EF codec preserves its error state across stores (it compensates
/// next time), and repeated re-quantization of the same factor converges
/// in time-average — the Sec. 4.3 claim expressed through the trait.
#[test]
fn codec_ef_state_preserved_and_effective() {
    let ctx = codec_ctx();
    let a = spd(24, 5);
    let mut ef = (lookup("cq4-ef").unwrap().side)(&ctx);
    let mut plain = (lookup("cq4").unwrap().side)(&ctx);
    ef.init(24, 1e-6);
    plain.init(24, 1e-6);
    assert!(plain.error_state().is_none());
    let e0 = ef.error_state().expect("EF codec must expose its error state");
    assert_eq!(quartz::linalg::max_abs(&e0), 0.0, "initial error state is zero");

    let steps = 60;
    let mut avg_ef = Matrix::zeros(24, 24);
    let mut avg_plain = Matrix::zeros(24, 24);
    for _ in 0..steps {
        ef.store(&a);
        plain.store(&a);
        avg_ef.axpy(1.0 / steps as f32, &ef.load());
        avg_plain.axpy(1.0 / steps as f32, &plain.load());
    }
    let e = ef.error_state().unwrap();
    assert!(quartz::linalg::max_abs(&e) > 0.0, "error state must accumulate");
    let err_ef = quartz::linalg::relative_error(&a, &avg_ef);
    let err_plain = quartz::linalg::relative_error(&a, &avg_plain);
    assert!(
        err_ef < err_plain,
        "EF time-average must beat plain CQ: ef={err_ef:.4} plain={err_plain:.4}"
    );
}

/// The `ec4` spectral-fidelity claim (arXiv 2405.18144): storing an exact
/// inverse 4-th root through the eigenvalue-corrected codec reconstructs a
/// matrix whose eigenvalues track `inverse_pth_root_eig`'s **relatively,
/// per mode** (the reconstruction is congruent to `ŨᵀŨ` through `Λ^½`, so
/// Ostrowski bounds every mode by the multiplicative factor `‖ŨᵀŨ − I‖`) —
/// and it stays PSD, which a raw 4-bit round-trip does not guarantee.
#[test]
fn ec4_reconstructed_root_spectrum_matches_exact_root() {
    use quartz::linalg::inverse_pth_root_eig;

    let ctx = codec_ctx();
    let mut rng = Rng::new(11);
    for trial in 0..3 {
        let a = synthetic_pd(32, 1e-1, 1e1, &mut rng);
        let exact = inverse_pth_root_eig(&a, 4.0, 1e-12);
        let (want, _) = eig_sym(&exact, 1e-12, 100);

        let mut codec = (lookup("ec4").unwrap().root)(&ctx);
        codec.store(&exact);
        let back = codec.load();
        let (got, _) = eig_sym(&back, 1e-12, 100);

        assert!(got[0] >= -1e-5, "trial {trial}: PSD reconstruction, λmin={}", got[0]);
        for (j, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() <= 0.35 * w.abs() + 1e-4,
                "trial {trial}, mode {j}: reconstructed λ {g} vs exact {w}"
            );
        }
    }
}

/// EF interaction across the new family: none of `ec4`/`f16`/`cq-r1` keeps
/// an error state (their corrections are recomputed per store, not
/// accumulated), so the state layer must see `None` — the EF contract is
/// exclusive to `cq4-ef`.
#[test]
fn codec_family_has_no_hidden_ef_state() {
    let ctx = codec_ctx();
    let a = spd(24, 9);
    for key in ["ec4", "f16", "cq-r1"] {
        let b = lookup(key).unwrap();
        for ctor in [b.side, b.root] {
            let mut codec = ctor(&ctx);
            codec.init(24, 1e-6);
            codec.store(&a);
            assert!(codec.error_state().is_none(), "{key}: unexpected EF state");
        }
    }
}

/// Every new codec key drives a full Shampoo run under every registered
/// refresh-scheduler policy (the PR 4 engine): plan → unit-level refresh →
/// precondition stays finite, and the preconditioner state is non-trivial.
/// The `(side, root)` pairs come from the registry's codec metadata, so a
/// future family key is crossed with every policy automatically.
#[test]
fn codec_family_runs_under_every_refresh_policy() {
    use quartz::optim::BaseOptimizer;
    use quartz::shampoo::{Shampoo, ShampooConfig};
    use quartz::train::registry;

    let family: Vec<(&str, &str)> = registry::stack_keys()
        .into_iter()
        .filter_map(|key| registry::lookup(key)?.codecs)
        .collect();
    assert!(family.len() >= 3, "ec4/f16/cq-r1 must declare codec metadata");
    for (side, root) in family {
        for policy in ["every-n", "staggered", "staleness"] {
            let cfg = ShampooConfig {
                t1: 1,
                t2: 2,
                side_codec: Some(side),
                root_codec: Some(root),
                refresh_policy: policy,
                quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
                ..Default::default()
            };
            let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, &[(12, 8), (9, 1)]);
            let mut rng = Rng::new(13);
            let mut params =
                vec![Matrix::randn(12, 8, 0.5, &mut rng), Matrix::randn(9, 1, 0.5, &mut rng)];
            let grads =
                vec![Matrix::randn(12, 8, 0.5, &mut rng), Matrix::randn(9, 1, 0.5, &mut rng)];
            for k in 1..=6 {
                sh.step(&mut params, &grads, k, 1.0);
            }
            assert!(
                params.iter().all(|p| !p.has_non_finite()),
                "codecs {side}/{root} under '{policy}' produced non-finite parameters"
            );
            assert!(sh.shampoo_state_bytes() > 0);
        }
    }
}

/// `init` always reconstructs ≈ ε·I, and a second `init` resets state.
#[test]
fn codec_init_is_reset() {
    let ctx = codec_ctx();
    for key in codec_keys() {
        let mut codec = (lookup(key).unwrap().side)(&ctx);
        codec.init(16, 1e-6);
        codec.store(&spd(16, 6));
        codec.init(16, 1e-6);
        let back = codec.load();
        assert!(
            back.max_abs_diff(&Matrix::eye_scaled(16, 1e-6)) < 1e-5,
            "{key}: re-init must reset to ε·I"
        );
    }
}

// A codec the core crate has never heard of: stores f32 but rounds to a
// fixed grid. Registering it makes it constructible by key and subject to
// the same suite — the open-world property the redesign exists for.
#[derive(Clone, Debug, Default)]
struct RoundedCodec {
    m: Option<Matrix>,
}

impl PrecondCodec for RoundedCodec {
    fn key(&self) -> &'static str {
        "test-rounded"
    }
    fn store(&mut self, x: &Matrix) {
        self.m = Some(Matrix::from_fn(x.rows(), x.cols(), |i, j| {
            (x[(i, j)] * 256.0).round() / 256.0
        }));
    }
    fn load(&self) -> Matrix {
        self.m.clone().expect("load before store")
    }
    fn size_bytes(&self) -> usize {
        self.m.as_ref().map(|m| m.size_bytes()).unwrap_or(0)
    }
    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

fn rounded_ctor(_ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::<RoundedCodec>::default()
}

#[test]
fn runtime_registered_codec_is_a_first_class_citizen() {
    register(CodecBuilder {
        key: "test-rounded",
        summary: "f32 rounded to 1/256 grid (test codec)",
        side: rounded_ctor,
        root: rounded_ctor,
    });
    assert!(codec_keys().contains(&"test-rounded"));

    // Constructible by string key, round-trips within its grid error.
    let ctx = codec_ctx();
    let b = lookup("test-rounded").unwrap();
    let mut codec = (b.side)(&ctx);
    let a = spd(20, 7);
    codec.store(&a);
    assert!(codec.load().max_abs_diff(&a) <= 0.5 / 256.0 + 1e-6);

    // And it drives a full Shampoo run through the config override — no
    // enum arm, no state-layer edit, just the registry key.
    use quartz::optim::BaseOptimizer;
    use quartz::shampoo::{Shampoo, ShampooConfig};
    let cfg = ShampooConfig {
        t1: 1,
        t2: 2,
        side_codec: Some("test-rounded"),
        root_codec: Some("test-rounded"),
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, &[(12, 8)]);
    let mut rng = Rng::new(8);
    let mut params = vec![Matrix::randn(12, 8, 0.5, &mut rng)];
    let grads = vec![Matrix::randn(12, 8, 0.5, &mut rng)];
    for k in 1..=4 {
        sh.step(&mut params, &grads, k, 1.0);
    }
    assert!(!params[0].has_non_finite());
}

#[test]
fn four_bit_shampoo_state_is_eighth_of_f32() {
    // End-to-end byte check on a realistic layer: 4-bit codes + scales +
    // diag must land near 1/8 of the f32 PRECONDITIONER payload.
    let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
    let mut rng = Rng::new(4);
    let a = Matrix::randn(512, 512, 1.0, &mut rng);
    let quantized = q.quantize(&a);
    let f32_bytes = 512 * 512 * 4;
    let ratio = quantized.size_bytes() as f64 / f32_bytes as f64;
    assert!((0.12..0.14).contains(&ratio), "ratio {ratio}");
}

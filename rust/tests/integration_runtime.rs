//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These require `make artifacts` to have run; they are skipped (pass
//! trivially with a note) when the artifact directory is missing so plain
//! `cargo test` works in a fresh checkout.

use quartz::data::synthetic::{ClusterDataset, ClusterSpec};
use quartz::data::tokens::{CorpusSpec, TokenCorpus};
use quartz::linalg::Matrix;
use quartz::optim::BaseOptimizer;
use quartz::runtime::literal::{literal_to_vec_f32, matrix_to_literal, scalar_f32};
use quartz::runtime::Runtime;
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::train::{train_classifier, train_lm, ClassifierData, OptimizerStack, TrainConfig};
use quartz::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("runtime open"))
}

#[test]
fn kernel_quant_roundtrip_via_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(1);
    let x = Matrix::randn(128, 128, 2.0, &mut rng);
    let out = rt
        .execute("kernel.quant_roundtrip", &[matrix_to_literal(&x).unwrap()])
        .expect("execute");
    let back = literal_to_vec_f32(&out[0]).unwrap();
    // Cross-validate the Pallas kernel (through PJRT!) against the rust
    // quantizer implementation — two independent implementations of Sec. 3.2.
    let q = quartz::quant::BlockQuantizer::new(quartz::quant::QuantConfig {
        block: 64,
        ..Default::default()
    });
    let rust_back = q.roundtrip(&x);
    let mut max_diff = 0.0f32;
    for (i, &v) in back.iter().enumerate() {
        max_diff = max_diff.max((v - rust_back.data()[i]).abs());
    }
    assert!(
        max_diff < 1e-5,
        "pallas and rust quantizers must agree: max diff {max_diff}"
    );
}

#[test]
fn kernel_precond_apply_via_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(2);
    let l = Matrix::randn(64, 64, 1.0, &mut rng);
    let g = Matrix::randn(64, 48, 1.0, &mut rng);
    let r = Matrix::randn(48, 48, 1.0, &mut rng);
    let out = rt
        .execute(
            "kernel.precond_apply",
            &[
                matrix_to_literal(&l).unwrap(),
                matrix_to_literal(&g).unwrap(),
                matrix_to_literal(&r).unwrap(),
            ],
        )
        .expect("execute");
    let got = literal_to_vec_f32(&out[0]).unwrap();
    let want = quartz::linalg::matmul(&quartz::linalg::matmul(&l, &g), &r);
    for (i, &v) in got.iter().enumerate() {
        assert!((v - want.data()[i]).abs() < 1e-2, "elem {i}: {v} vs {}", want.data()[i]);
    }
}

#[test]
fn kernel_gram_ema_via_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(3);
    let prev = Matrix::eye_scaled(64, 0.5);
    let g = Matrix::randn(64, 48, 1.0, &mut rng);
    let out = rt
        .execute(
            "kernel.gram_ema_left",
            &[
                matrix_to_literal(&prev).unwrap(),
                matrix_to_literal(&g).unwrap(),
                scalar_f32(0.95),
            ],
        )
        .expect("execute");
    let got = literal_to_vec_f32(&out[0]).unwrap();
    let mut want = quartz::linalg::syrk(&g);
    want.scale(0.05);
    want.axpy(0.95, &prev);
    for (i, &v) in got.iter().enumerate() {
        assert!((v - want.data()[i]).abs() < 1e-2);
    }
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime_or_skip() else { return };
    assert_eq!(rt.compiled_count(), 0);
    rt.load("kernel.precond_apply").unwrap();
    rt.load("kernel.precond_apply").unwrap();
    assert_eq!(rt.compiled_count(), 1);
}

#[test]
fn classifier_training_reduces_loss() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest.models["mlp_vgg_c32"].clone();
    let spec = ClusterSpec {
        classes: 32,
        dim: 64,
        train: 2048,
        test: 512,
        seed: 11,
        ..Default::default()
    };
    let (tr, te) = ClusterDataset::generate(&spec);
    let data = ClassifierData::from((&tr, &te));
    let opt = OptimizerStack::base(BaseOptimizer::sgdm(0.05, 0.9, 5e-4));
    let cfg = TrainConfig { steps: 150, log_every: 10, ..Default::default() };
    let m = train_classifier(&rt, &model, &data, opt, &cfg).expect("train");
    let first = m.loss_curve.first().unwrap().1;
    let last = m.loss_curve.last().unwrap().1;
    assert!(last < first * 0.9, "loss must drop: {first} → {last}");
    assert!(m.final_metric > 2.0 / 32.0, "better than chance: {}", m.final_metric);
}

#[test]
fn shampoo_cqef_trains_classifier() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest.models["mlp_vgg_c32"].clone();
    let spec = ClusterSpec {
        classes: 32,
        dim: 64,
        train: 2048,
        test: 512,
        seed: 12,
        ..Default::default()
    };
    let (tr, te) = ClusterDataset::generate(&spec);
    let data = ClassifierData::from((&tr, &te));
    let scfg = ShampooConfig {
        variant: ShampooVariant::Cq4 { error_feedback: true },
        t1: 5,
        t2: 10,
        max_order: 96,
        ..Default::default()
    };
    let sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 5e-4), scfg, &model.shapes());
    let opt = OptimizerStack::shampoo(sh);
    let cfg = TrainConfig { steps: 60, log_every: 5, ..Default::default() };
    let m = train_classifier(&rt, &model, &data, opt, &cfg).expect("train");
    let first = m.loss_curve.first().unwrap().1;
    let last = m.loss_curve.last().unwrap().1;
    assert!(last < first, "loss must drop: {first} → {last}");
    assert!(m.state_bytes > 0);
}

#[test]
fn lm_training_reduces_nll() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest.models["lm_s"].clone();
    let corpus =
        TokenCorpus::generate(&CorpusSpec { length: 50_000, seed: 5, ..Default::default() });
    let opt = OptimizerStack::base(BaseOptimizer::adamw(3e-3, 0.9, 0.999, 1e-8, 0.0));
    let cfg = TrainConfig { steps: 80, log_every: 10, ..Default::default() };
    let m = train_lm(&rt, &model, &corpus, opt, &cfg).expect("train");
    let first = m.loss_curve.first().unwrap().1;
    let last = m.loss_curve.last().unwrap().1;
    assert!(last < first, "nll must drop: {first} → {last}");
    // PPL must beat the uniform bound (vocab 64).
    assert!(m.final_metric < 64.0, "ppl {}", m.final_metric);
}

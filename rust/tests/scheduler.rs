//! Refresh-scheduler correctness: the `every-n` policy reproduces the
//! pre-scheduler step path bit-for-bit, `staggered` gives exact once-per-
//! interval coverage under the ⌈units/T⌉ per-step bound, `staleness` honors
//! its budget without starving any unit, and runtime-registered policies
//! drive `Shampoo` through the same string-keyed path as the built-ins.

use quartz::linalg::{Matrix, ScratchArena};
use quartz::optim::{graft, BaseOptimizer};
use quartz::quant::{BlockQuantizer, CodecCtx, QuantConfig};
use quartz::shampoo::scheduler::{
    self, RefreshPlan, RefreshScheduler, SchedulerBuilder, UnitInfo,
};
use quartz::shampoo::{LayerState, Shampoo, ShampooConfig, ShampooVariant};
use quartz::util::rng::Rng;
use std::sync::Arc;

fn sgd_base() -> BaseOptimizer {
    BaseOptimizer::sgd(0.05, 0.0)
}

/// Deterministic per-step gradients for a shape set.
fn grads_at(shapes: &[(usize, usize)], k: u64, seed: u64) -> Vec<Matrix> {
    let mut rng = Rng::new(seed ^ (k * 0x9E37_79B9));
    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.4, &mut rng)).collect()
}

/// With `refresh_policy = "every-n"`, parameter trajectories are
/// bit-identical to the pre-refactor `Shampoo::step`: all units' Gram EMAs
/// at `k % T1 == 0`, all units' roots at `k % T2 == 0`, precondition after.
/// The oracle below IS that seed behavior, hand-written over the public
/// per-layer operations — including blocked and passthrough layers.
#[test]
fn every_n_is_bit_identical_to_the_sequential_seed_oracle() {
    let cfg = ShampooConfig {
        t1: 2,
        t2: 3,
        max_order: 8, // (20,12) → 3×2 block grid
        variant: ShampooVariant::Cq4 { error_feedback: true },
        refresh_policy: "every-n",
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let shapes = [(12usize, 8usize), (8, 8), (20, 12), (5, 1)];
    let mut rng = Rng::new(3);
    let params0: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();

    // Scheduler-driven optimizer.
    let mut sh = Shampoo::new(sgd_base(), cfg, &shapes);
    let mut pa = params0.clone();
    for k in 1..=9u64 {
        let grads = grads_at(&shapes, k, 42);
        sh.step(&mut pa, &grads, k, 1.0);
    }

    // Sequential oracle (pre-refactor step semantics).
    let ctx = CodecCtx::new(cfg.eps, cfg.beta_e, Arc::new(BlockQuantizer::new(cfg.quant)));
    let mut layers: Vec<LayerState> =
        shapes.iter().map(|&(m, n)| LayerState::new(m, n, &cfg, &ctx)).collect();
    let mut base = sgd_base();
    base.init(shapes.len());
    let mut pb = params0.clone();
    let mut scratch = ScratchArena::new();
    for k in 1..=9u64 {
        let grads = grads_at(&shapes, k, 42);
        for i in 0..shapes.len() {
            if k % cfg.t1 == 0 {
                layers[i].update_gram(&grads[i], &cfg, &mut scratch);
            }
            if k % cfg.t2 == 0 {
                layers[i].update_inv_roots(&cfg, &ctx, &mut scratch);
            }
            let mut ghat = layers[i].precondition(&grads[i]);
            if cfg.grafting {
                graft(&grads[i], &mut ghat);
            }
            base.step_param(i, &mut pb[i], &ghat, 1.0);
        }
    }

    for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(
            a.max_abs_diff(b),
            0.0,
            "layer {i}: every-n must match the sequential seed oracle bit-for-bit"
        );
    }
}

/// `staggered` refreshes every unit exactly once per `T2` interval (and
/// every Gram side once per `T1` interval) — the coverage-counter contract.
#[test]
fn staggered_refreshes_every_unit_exactly_once_per_interval() {
    let cfg = ShampooConfig {
        t1: 2,
        t2: 4,
        max_order: 8, // 16×16 → 2×2 blocks → 8 units
        variant: ShampooVariant::Full32,
        refresh_policy: "staggered",
        ..Default::default()
    };
    let shapes = [(16usize, 16usize)];
    let mut sh = Shampoo::new(sgd_base(), cfg, &shapes);
    assert_eq!(sh.unit_count(), 8);
    let mut params: Vec<Matrix> = {
        let mut rng = Rng::new(5);
        shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect()
    };
    for interval in 1..=3u64 {
        for k in (interval - 1) * 4 + 1..=interval * 4 {
            let grads = grads_at(&shapes, k, 7);
            sh.step(&mut params, &grads, k, 1.0);
        }
        for (id, meta) in sh.unit_metas() {
            assert_eq!(
                meta.refreshes,
                interval as u32,
                "{id:?}: must refresh exactly once per interval"
            );
        }
    }
    // The spread never exceeds ⌈units/T₂⌉ per step (here 8/4 = 2), while
    // the total work equals the every-n schedule's (one refresh per unit
    // per interval).
    let stats = sh.refresh_stats();
    assert_eq!(stats.max_root_units, 2);
    assert_eq!(stats.root_units, 3 * 8);
    assert!(!params[0].has_non_finite());
}

/// `staleness` never exceeds its per-step budget and never lets a unit go
/// unrefreshed for more than `2 × T2` steps.
#[test]
fn staleness_respects_budget_and_never_starves() {
    let cfg = ShampooConfig {
        t1: 1,
        t2: 4,
        max_order: 8, // (16,16) → 4 blocks, (16,8) → 2 blocks ⇒ 12 units
        variant: ShampooVariant::Full32,
        refresh_policy: "staleness",
        ..Default::default()
    };
    let shapes = [(16usize, 16usize), (16, 8)];
    let mut sh = Shampoo::new(sgd_base(), cfg, &shapes);
    assert_eq!(sh.unit_count(), 12);
    let budget = scheduler::effective_budget(&cfg, sh.unit_count());
    assert_eq!(budget, 3);
    let mut params: Vec<Matrix> = {
        let mut rng = Rng::new(9);
        shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect()
    };
    for k in 1..=24u64 {
        let grads = grads_at(&shapes, k, 11);
        sh.step(&mut params, &grads, k, 1.0);
        let stats = sh.refresh_stats();
        assert!(
            stats.last_root_units <= budget,
            "step {k}: {} root units over budget {budget}",
            stats.last_root_units
        );
        for (id, meta) in sh.unit_metas() {
            let stale = k - meta.last_root.min(k);
            assert!(
                stale <= 2 * cfg.t2,
                "step {k}: unit {id:?} starved for {stale} steps (limit {})",
                2 * cfg.t2
            );
        }
    }
    assert_eq!(sh.refresh_stats().max_root_units, budget);
    assert!(!params[0].has_non_finite());
}

/// Acceptance criterion on the (scaled) bench layer mix: with `staggered`,
/// the max per-step refresh-unit count is ≤ ⌈total_units / refresh_every⌉,
/// while `every-n` concentrates ALL units in single steps — the latency
/// spike the scheduler exists to flatten. Total work is identical.
#[test]
fn staggered_bounds_per_step_units_on_the_bench_layer_mix() {
    // Transformer-ish mix (4096×1024 / 1024×4096 / 512×512×n scaled 1/16,
    // matching bench_shampoo's `step_mix` shapes at max_order 64).
    let shapes = [(256usize, 64usize), (64, 256), (128, 128), (128, 128)];
    let t2 = 8u64;
    let run = |policy: &'static str| {
        let cfg = ShampooConfig {
            t1: 4,
            t2,
            max_order: 64,
            variant: ShampooVariant::Full32,
            refresh_policy: policy,
            ..Default::default()
        };
        let mut sh = Shampoo::new(sgd_base(), cfg, &shapes);
        let mut params: Vec<Matrix> = {
            let mut rng = Rng::new(13);
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect()
        };
        for k in 1..=2 * t2 {
            let grads = grads_at(&shapes, k, 17);
            sh.step(&mut params, &grads, k, 1.0);
        }
        let stats = sh.refresh_stats().clone();
        (sh.unit_count(), stats)
    };

    let (units, every_n) = run("every-n");
    assert_eq!(units, 32);
    let bound = (units as u64).div_ceil(t2) as usize;
    let (_, staggered) = run("staggered");

    assert_eq!(every_n.max_root_units, units, "every-n refreshes everything at once");
    assert!(
        staggered.max_root_units <= bound,
        "staggered spike {} exceeds ⌈units/T₂⌉ = {bound}",
        staggered.max_root_units
    );
    // Same amortized work, flatter profile.
    assert_eq!(every_n.root_units, staggered.root_units);
}

/// A runtime-registered policy drives `Shampoo` exactly like the built-ins:
/// the string-keyed open world of the codec/stack registries, for refresh
/// scheduling. A policy that never refreshes must leave Shampoo acting as
/// its base optimizer.
#[test]
fn runtime_registered_policy_reaches_shampoo_by_key() {
    struct Never;
    impl RefreshScheduler for Never {
        fn key(&self) -> &'static str {
            "never"
        }
        fn plan(&mut self, _: u64, _: &[UnitInfo], _: &ShampooConfig, _: &mut RefreshPlan) {}
    }
    fn build_never(_: &ShampooConfig) -> Box<dyn RefreshScheduler> {
        Box::new(Never)
    }
    scheduler::register(SchedulerBuilder {
        key: "never",
        summary: "test-only: refresh nothing, ever",
        build: build_never,
    });

    let cfg = ShampooConfig {
        t1: 1,
        t2: 1,
        grafting: false,
        variant: ShampooVariant::Full32,
        refresh_policy: "never",
        ..Default::default()
    };
    let shapes = [(6usize, 6usize)];
    let mut sh = Shampoo::new(sgd_base(), cfg, &shapes);
    let mut rng = Rng::new(19);
    let w0 = Matrix::randn(6, 6, 1.0, &mut rng);
    let mut w_sh = w0.clone();
    let mut base = sgd_base();
    base.init(1);
    let mut w_base = w0.clone();
    for k in 1..=20u64 {
        let g = grads_at(&shapes, k, 23).remove(0);
        sh.step(std::slice::from_mut(&mut w_sh), std::slice::from_ref(&g), k, 1.0);
        base.step_param(0, &mut w_base, &g, 1.0);
    }
    assert_eq!(
        w_sh.max_abs_diff(&w_base),
        0.0,
        "a never-refresh policy must leave Shampoo == base optimizer"
    );
    let stats = sh.refresh_stats();
    assert_eq!(stats.root_units + stats.gram_units, 0);
    assert_eq!(sh.refresh_policy(), "never");
}

//! Coordinator integration: TOML specs → scheduled runs → aggregated
//! outcomes (requires artifacts; skips cleanly otherwise).

use quartz::coordinator::runner::run_all;
use quartz::coordinator::spec::{ExperimentSpec, OptimizerSpec, RunSpec, Workload};
use quartz::data::synthetic::ClusterSpec;
use quartz::optim::OptimizerKind;
use quartz::shampoo::{ShampooConfig, ShampooVariant};

fn artifacts_available() -> bool {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ok = dir.join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts not built");
    } else {
        std::env::set_var("QUARTZ_ARTIFACTS", dir);
    }
    ok
}

fn tiny_cluster(seed: u64) -> Workload {
    Workload::Cluster(ClusterSpec {
        classes: 32,
        dim: 64,
        train: 512,
        test: 128,
        seed,
        ..Default::default()
    })
}

#[test]
fn parallel_grid_executes_all_runs() {
    if !artifacts_available() {
        return;
    }
    let hyper = OptimizerSpec::paper_hyper(OptimizerKind::Sgdm);
    let mut specs = Vec::new();
    for i in 0..4 {
        let opt = if i % 2 == 0 {
            OptimizerSpec::base_only(OptimizerKind::Sgdm, hyper)
        } else {
            OptimizerSpec::with_shampoo(
                OptimizerKind::Sgdm,
                hyper,
                ShampooConfig {
                    variant: ShampooVariant::Cq4 { error_feedback: true },
                    t1: 5,
                    t2: 10,
                    max_order: 96,
                    ..Default::default()
                },
            )
        };
        specs.push(RunSpec::new("mlp_vgg_c32", tiny_cluster(i as u64), opt, 20));
    }
    let outcomes = run_all(&specs, 2);
    assert_eq!(outcomes.len(), 4);
    for o in &outcomes {
        assert!(o.error.is_none(), "run {} failed: {:?}", o.id, o.error);
        let m = o.metrics.as_ref().unwrap();
        assert!(m.loss_curve.last().unwrap().1.is_finite());
    }
}

#[test]
fn unknown_model_is_isolated_error() {
    if !artifacts_available() {
        return;
    }
    let hyper = OptimizerSpec::paper_hyper(OptimizerKind::Sgdm);
    let base = OptimizerSpec::base_only(OptimizerKind::Sgdm, hyper);
    let specs = vec![
        RunSpec::new("no_such_model", tiny_cluster(0), base.clone(), 5),
        RunSpec::new("mlp_vgg_c32", tiny_cluster(0), base, 5),
    ];
    let outcomes = run_all(&specs, 2);
    assert!(outcomes[0].error.as_deref().unwrap_or("").contains("unknown model"));
    assert!(outcomes[1].error.is_none(), "good run must survive bad sibling");
}

#[test]
fn toml_spec_end_to_end() {
    if !artifacts_available() {
        return;
    }
    let text = r#"
name = "it-spec"
steps = 15
workers = 2

[workload]
kind = "cluster"
classes = 32
dim = 64
train = 512
test = 128

[[runs]]
model = "mlp_vgg_c32"
base = "sgdm"
shampoo = "cq-ef"
t1 = 5
t2 = 10
max_order = 96

[[runs]]
model = "mlp_vgg_c32"
base = "adamw"
shampoo = "none"
"#;
    let spec = ExperimentSpec::from_toml(text).unwrap();
    assert_eq!(spec.runs.len(), 2);
    let outcomes = run_all(&spec.runs, spec.workers);
    for o in &outcomes {
        assert!(o.error.is_none(), "{:?}", o.error);
    }
    // Shampoo run carries preconditioner bytes; AdamW-only run carries 2×
    // param bytes.
    let m0 = outcomes[0].metrics.as_ref().unwrap();
    let m1 = outcomes[1].metrics.as_ref().unwrap();
    assert!(m0.state_bytes > m1.state_bytes / 2);
    assert!(outcomes[0].optimizer.contains("Shampoo"));
    assert!(!outcomes[1].optimizer.contains("Shampoo"));
}

#[test]
fn memory_budget_gates_before_execution() {
    if !artifacts_available() {
        return;
    }
    let hyper = OptimizerSpec::paper_hyper(OptimizerKind::AdamW);
    let mut spec = RunSpec::new(
        "lm_l",
        Workload::Tokens(quartz::data::tokens::CorpusSpec {
            length: 5_000,
            ..Default::default()
        }),
        OptimizerSpec::with_shampoo(
            OptimizerKind::AdamW,
            hyper,
            ShampooConfig { variant: ShampooVariant::Full32, max_order: 96, ..Default::default() },
        ),
        1000, // would take minutes if actually run — the gate must fire first
    );
    spec.memory_budget = Some(1024);
    let t0 = std::time::Instant::now();
    let outcomes = run_all(std::slice::from_ref(&spec), 1);
    assert!(outcomes[0].is_oom());
    assert!(t0.elapsed().as_secs() < 30, "gate must fire without training");
}

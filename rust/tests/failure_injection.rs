//! Failure injection: the optimizer stack and coordinator must degrade
//! gracefully, never poison state permanently, and isolate bad runs.

use quartz::linalg::Matrix;
use quartz::optim::BaseOptimizer;
use quartz::quant::{BlockQuantizer, QuantConfig};
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::util::pool::{JobResult, Pool};

fn cfg(variant: ShampooVariant) -> ShampooConfig {
    ShampooConfig {
        variant,
        t1: 1,
        t2: 2,
        max_order: 64,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn nan_gradient_does_not_poison_cq_state() {
    let mut sh = Shampoo::new(
        BaseOptimizer::sgd(0.01, 0.0),
        cfg(ShampooVariant::Cq4 { error_feedback: true }),
        &[(8, 8)],
    );
    let mut params = vec![Matrix::eye(8)];
    let mut bad = Matrix::eye(8);
    bad[(0, 0)] = f32::NAN;
    // NaN gradient step: parameters will take a NaN hit from the base
    // optimizer (as in any framework), but the *preconditioner state* must
    // self-heal so later steps are finite again.
    sh.step(&mut params, std::slice::from_ref(&bad), 1, 1.0);
    params[0] = Matrix::eye(8); // simulate checkpoint restore of params
    let good = Matrix::eye_scaled(8, 0.1);
    for k in 2..=6 {
        sh.step(&mut params, std::slice::from_ref(&good), k, 1.0);
    }
    assert!(
        !params[0].has_non_finite(),
        "preconditioner state must recover after NaN gradient"
    );
}

#[test]
fn inf_gradient_recovery_vq() {
    let mut sh = Shampoo::new(
        BaseOptimizer::sgd(0.01, 0.0),
        cfg(ShampooVariant::Vq4),
        &[(8, 8)],
    );
    let mut params = vec![Matrix::eye(8)];
    let mut bad = Matrix::zeros(8, 8);
    bad[(3, 3)] = f32::INFINITY;
    sh.step(&mut params, std::slice::from_ref(&bad), 1, 1.0);
    params[0] = Matrix::eye(8);
    let good = Matrix::eye_scaled(8, 0.1);
    for k in 2..=8 {
        sh.step(&mut params, std::slice::from_ref(&good), k, 1.0);
    }
    assert!(!params[0].has_non_finite());
}

#[test]
fn zero_gradients_are_stable() {
    // All-zero gradients: Gram stays εI-ish, roots stay finite, params fixed.
    for variant in [
        ShampooVariant::Full32,
        ShampooVariant::Vq4,
        ShampooVariant::Cq4 { error_feedback: true },
    ] {
        let mut sh = Shampoo::new(BaseOptimizer::sgd(0.1, 0.0), cfg(variant), &[(6, 6)]);
        let mut params = vec![Matrix::eye(6)];
        let zero = Matrix::zeros(6, 6);
        for k in 1..=6 {
            sh.step(&mut params, std::slice::from_ref(&zero), k, 1.0);
        }
        assert!(params[0].max_abs_diff(&Matrix::eye(6)) < 1e-5, "{variant:?}");
    }
}

#[test]
fn constant_rank_one_gradients_stay_finite() {
    // Rank-1 Gram matrices are maximally singular — the εI ridge and the
    // jittered Cholesky must keep every variant finite.
    for variant in [
        ShampooVariant::Full32,
        ShampooVariant::Vq4,
        ShampooVariant::Cq4 { error_feedback: false },
        ShampooVariant::Cq4 { error_feedback: true },
        ShampooVariant::Bw8,
    ] {
        let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg(variant), &[(10, 4)]);
        let mut params = vec![Matrix::zeros(10, 4)];
        let g = Matrix::from_fn(10, 4, |i, j| ((i + 1) as f32) * 0.1 * ((j + 1) as f32));
        for k in 1..=10 {
            sh.step(&mut params, std::slice::from_ref(&g), k, 1.0);
            assert!(!params[0].has_non_finite(), "{variant:?} step {k}");
        }
    }
}

#[test]
fn huge_dynamic_range_gradients() {
    // Mixed 1e-30 … 1e+20 magnitudes stress block scales; state must stay
    // finite (the f32 math saturates gracefully rather than NaN-ing).
    let mut sh = Shampoo::new(
        BaseOptimizer::sgd(1e-3, 0.0),
        cfg(ShampooVariant::Cq4 { error_feedback: true }),
        &[(8, 8)],
    );
    let mut params = vec![Matrix::zeros(8, 8)];
    let g = Matrix::from_fn(8, 8, |i, j| {
        if (i + j) % 2 == 0 {
            1e-30
        } else {
            1e20
        }
    });
    for k in 1..=4 {
        sh.step(&mut params, std::slice::from_ref(&g), k, 1.0);
    }
    assert!(!params[0].has_non_finite());
}

#[test]
fn exotic_codecs_recover_from_nan_under_every_refresh_policy() {
    // The guard engine's screening + fallback-ladder guarantees must hold
    // across the open-world codec registry too — entropy-coded ec4, f16,
    // and the rank-1 CQ side codec — under each refresh scheduler.
    for (side_codec, root_codec) in [("ec4", "ec4"), ("f16", "f16"), ("cq-r1", "vq4")] {
        for policy in ["every-n", "staggered", "staleness"] {
            let mut c = cfg(ShampooVariant::Full32);
            c.side_codec = Some(side_codec);
            c.root_codec = Some(root_codec);
            c.refresh_policy = policy;
            let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), c, &[(8, 8)]);
            let mut params = vec![Matrix::eye(8)];
            let mut bad = Matrix::eye(8);
            bad[(0, 0)] = f32::NAN;
            sh.step(&mut params, std::slice::from_ref(&bad), 1, 1.0);
            params[0] = Matrix::eye(8); // simulate checkpoint restore of params
            let good = Matrix::eye_scaled(8, 0.1);
            for k in 2..=8 {
                sh.step(&mut params, std::slice::from_ref(&good), k, 1.0);
            }
            assert!(
                !params[0].has_non_finite(),
                "{side_codec}/{root_codec} under '{policy}' must recover from NaN"
            );
            // The poisoned step was screened, not absorbed.
            assert!(
                sh.health().grads_screened >= 1,
                "{side_codec}/{root_codec} under '{policy}': screening counter never fired"
            );
        }
    }
}

#[test]
fn inf_gradient_is_screened_for_exotic_codecs() {
    // Same sweep with an Inf spike and a non-identity recovery gradient:
    // the screened step must not leak into gram/EF state, and subsequent
    // refreshes must keep producing finite preconditioned updates.
    for (side_codec, root_codec) in [("ec4", "ec4"), ("f16", "f16"), ("cq-r1", "vq4")] {
        for policy in ["every-n", "staggered", "staleness"] {
            let mut c = cfg(ShampooVariant::Full32);
            c.side_codec = Some(side_codec);
            c.root_codec = Some(root_codec);
            c.refresh_policy = policy;
            let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), c, &[(10, 4)]);
            let mut params = vec![Matrix::zeros(10, 4)];
            let mut bad = Matrix::zeros(10, 4);
            bad[(3, 1)] = f32::INFINITY;
            sh.step(&mut params, std::slice::from_ref(&bad), 1, 1.0);
            params[0] = Matrix::zeros(10, 4);
            let g = Matrix::from_fn(10, 4, |i, j| ((i + 1) as f32) * 0.1 * ((j + 1) as f32));
            for k in 2..=10 {
                sh.step(&mut params, std::slice::from_ref(&g), k, 1.0);
                assert!(
                    !params[0].has_non_finite(),
                    "{side_codec}/{root_codec} under '{policy}' step {k}"
                );
            }
        }
    }
}

#[test]
fn pool_isolates_panicking_jobs_among_good_ones() {
    let pool = Pool::new(4);
    let jobs: Vec<Box<dyn FnOnce() -> u32 + Send + std::panic::UnwindSafe>> = (0..16)
        .map(|i| {
            let f: Box<dyn FnOnce() -> u32 + Send + std::panic::UnwindSafe> = if i % 5 == 0 {
                Box::new(move || panic!("injected failure {i}"))
            } else {
                Box::new(move || i * 2)
            };
            f
        })
        .collect();
    let results = pool.run(jobs);
    for (i, r) in results.iter().enumerate() {
        match r {
            JobResult::Ok(v) => {
                assert_ne!(i % 5, 0);
                assert_eq!(*v, (i as u32) * 2);
            }
            JobResult::Panicked(msg) => {
                assert_eq!(i % 5, 0);
                assert!(msg.contains("injected failure"));
            }
        }
    }
}

#[test]
fn quantizer_handles_degenerate_blocks() {
    let q = BlockQuantizer::new(QuantConfig { block: 4, min_quant_elems: 0, ..Default::default() });
    // All-zero, single-value, and constant-negative blocks.
    for mat in [
        Matrix::zeros(8, 8),
        Matrix::from_fn(8, 8, |_, _| -3.0),
        Matrix::from_fn(8, 8, |i, j| if i == 0 && j == 0 { 7.0 } else { 0.0 }),
    ] {
        let back = q.roundtrip(&mat);
        assert!(!back.has_non_finite());
        assert!(back.max_abs_diff(&mat) <= quartz::linalg::max_abs(&mat) * 0.13 + 1e-6);
    }
}

#[test]
fn manifest_errors_are_reported_not_panicked() {
    use quartz::runtime::Manifest;
    assert!(Manifest::parse("{ not json").is_err());
    assert!(Manifest::parse("{}").is_err());
    let no_file = Manifest::load(std::path::Path::new("/nonexistent/dir"));
    assert!(no_file.is_err());
}

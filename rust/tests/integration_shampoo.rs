//! Shampoo-level integration: variant behavior over multi-step optimization
//! on deterministic objectives (no PJRT needed).

use quartz::linalg::{fro_norm, matmul, Matrix};
use quartz::optim::BaseOptimizer;
use quartz::quant::QuantConfig;
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::util::rng::Rng;

/// Quadratic objective f(W) = 0.5·tr(Wᵀ A W B); grad = A·W·B.
struct Quadratic {
    a: Matrix,
    b: Matrix,
}

impl Quadratic {
    fn new(m: usize, n: usize, cond: f32, seed: u64) -> Quadratic {
        let mut rng = Rng::new(seed);
        let mk = |dim: usize, rng: &mut Rng| {
            let g = Matrix::randn(dim, dim, 1.0, rng);
            let (_, v) = quartz::linalg::eig_sym(&quartz::linalg::syrk(&g), 1e-10, 100);
            let mut a = Matrix::zeros(dim, dim);
            for k in 0..dim {
                let lam = cond.powf(k as f32 / (dim - 1) as f32);
                for i in 0..dim {
                    for j in 0..dim {
                        a[(i, j)] += lam * v[(i, k)] * v[(j, k)];
                    }
                }
            }
            a
        };
        Quadratic { a: mk(m, &mut rng), b: mk(n, &mut rng) }
    }

    fn grad(&self, w: &Matrix) -> Matrix {
        matmul(&matmul(&self.a, w), &self.b)
    }

    fn loss(&self, w: &Matrix) -> f64 {
        0.5 * quartz::linalg::inner(w, &self.grad(w))
    }
}

fn train(variant: Option<ShampooVariant>, quad: &Quadratic, w0: &Matrix, steps: u64) -> f64 {
    let shapes = [(w0.rows(), w0.cols())];
    let lr = 5e-4;
    let mut w = w0.clone();
    match variant {
        None => {
            let mut opt = BaseOptimizer::sgd(lr, 0.0);
            opt.init(1);
            for _ in 0..steps {
                let g = quad.grad(&w);
                opt.step_param(0, &mut w, &g, 1.0);
            }
        }
        Some(v) => {
            let cfg = ShampooConfig {
                variant: v,
                t1: 2,
                t2: 10,
                max_order: 96,
                quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
                ..Default::default()
            };
            let mut sh = Shampoo::new(BaseOptimizer::sgd(lr, 0.0), cfg, &shapes);
            for k in 1..=steps {
                let g = quad.grad(&w);
                sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(&g), k, 1.0);
            }
        }
    }
    quad.loss(&w)
}

/// The paper's qualitative ordering on an ill-conditioned quadratic:
/// every Shampoo variant beats SGD, and CQ(+EF) stays close to 32-bit.
#[test]
fn variant_ordering_on_ill_conditioned_quadratic() {
    let quad = Quadratic::new(12, 8, 50.0, 7);
    let mut rng = Rng::new(8);
    let w0 = Matrix::randn(12, 8, 1.0, &mut rng);
    let steps = 500;

    let sgd = train(None, &quad, &w0, steps);
    let full = train(Some(ShampooVariant::Full32), &quad, &w0, steps);
    let cq = train(Some(ShampooVariant::Cq4 { error_feedback: false }), &quad, &w0, steps);
    let cqef = train(Some(ShampooVariant::Cq4 { error_feedback: true }), &quad, &w0, steps);
    let bw8 = train(Some(ShampooVariant::Bw8), &quad, &w0, steps);

    assert!(full < sgd * 0.8, "32-bit {full:.4} vs sgd {sgd:.4}");
    assert!(cq < sgd, "cq {cq:.4} vs sgd {sgd:.4}");
    assert!(cqef < sgd, "cqef {cqef:.4} vs sgd {sgd:.4}");
    assert!(bw8 < sgd, "bw8 {bw8:.4} vs sgd {sgd:.4}");
    // Quantized variants stay within a small constant factor of 32-bit on
    // this convex problem (quantization noise costs some progress).
    assert!(cqef < full * 5.0 + 1e-3, "cqef {cqef:.4} vs full {full:.4}");
    // 8-bit perturbs far less than 4-bit; it must track 32-bit closely.
    assert!(bw8 < full * 5.0 + 1e-3, "bw8 {bw8:.4} vs full {full:.4}");
}

/// Acceptance: every registered stack key constructs a working optimizer by
/// string, descends on the quadratic, and reports exact state bytes that
/// match the analytic memory model.
#[test]
fn registry_constructs_every_stack_by_key() {
    use quartz::metrics::MemoryModel;
    use quartz::train::registry;

    let quad = Quadratic::new(12, 8, 20.0, 15);
    let mut rng = Rng::new(16);
    let w0 = Matrix::randn(12, 8, 1.0, &mut rng);
    let shapes = [(12usize, 8usize)];
    for key in registry::stack_keys() {
        let cfg = ShampooConfig {
            t1: 2,
            t2: 10,
            max_order: 96,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut stack = registry::build(key, BaseOptimizer::sgd(5e-4, 0.0), &cfg, &shapes)
            .unwrap_or_else(|| panic!("stack key '{key}' must build"));
        stack.init(shapes.len());
        let mut w = w0.clone();
        for k in 1..=100 {
            let g = quad.grad(&w);
            stack.step(std::slice::from_mut(&mut w), std::slice::from_ref(&g), k, 1.0);
        }
        assert!(!w.has_non_finite(), "{key}: non-finite params");
        assert!(quad.loss(&w) < quad.loss(&w0), "{key}: must descend");

        // Memory-accounting parity: the analytic model predicts the live
        // stack's preconditioner bytes exactly (the paper's headline claim
        // survives the trait refactor byte-for-byte).
        if key != "none" {
            let model_cfg = match ShampooVariant::parse(key) {
                Some(variant) => ShampooConfig { variant, ..cfg },
                // The ec4/f16/cq-r1 family has no variant arm: its builders
                // declare their (side, root) overrides as registry metadata
                // — the same single source spec resolution reads — and the
                // key-based model prices those overrides directly.
                None => {
                    let (side, root) = registry::lookup(key)
                        .and_then(|b| b.codecs)
                        .expect("variant-less stack key must declare codec metadata");
                    ShampooConfig { side_codec: Some(side), root_codec: Some(root), ..cfg }
                }
            };
            let predicted = MemoryModel::new(&shapes).shampoo_bytes(&model_cfg);
            let measured = stack.state_bytes(); // sgd base holds no state
            assert_eq!(predicted, measured, "{key}: modeled vs measured bytes");
        }
    }
}

#[test]
fn t1_t2_intervals_are_respected() {
    // With T1 = T2 = very large, Shampoo must behave exactly like its base
    // (plus grafting disabled ⇒ identical trajectories).
    let quad = Quadratic::new(6, 6, 10.0, 9);
    let mut rng = Rng::new(10);
    let w0 = Matrix::randn(6, 6, 1.0, &mut rng);
    let cfg = ShampooConfig {
        variant: ShampooVariant::Full32,
        t1: 1_000_000,
        t2: 1_000_000,
        grafting: false,
        ..Default::default()
    };
    let mut sh = Shampoo::new(BaseOptimizer::sgd(1e-3, 0.0), cfg, &[(6, 6)]);
    let mut w_sh = w0.clone();
    let mut base = BaseOptimizer::sgd(1e-3, 0.0);
    base.init(1);
    let mut w_base = w0.clone();
    for k in 1..=50 {
        let g = quad.grad(&w_sh);
        sh.step(std::slice::from_mut(&mut w_sh), std::slice::from_ref(&g), k, 1.0);
        let g2 = quad.grad(&w_base);
        base.step_param(0, &mut w_base, &g2, 1.0);
    }
    assert!(w_sh.max_abs_diff(&w_base) < 1e-6);
}

#[test]
fn blocked_large_layer_trains() {
    // A layer above max_order must be blocked and still descend.
    let quad = Quadratic::new(48, 40, 20.0, 11);
    let mut rng = Rng::new(12);
    let w0 = Matrix::randn(48, 40, 1.0, &mut rng);
    let cfg = ShampooConfig {
        variant: ShampooVariant::Cq4 { error_feedback: true },
        t1: 2,
        t2: 10,
        max_order: 16, // force 3×3 block grid
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        ..Default::default()
    };
    let mut sh = Shampoo::new(BaseOptimizer::sgd(5e-4, 0.0), cfg, &[(48, 40)]);
    assert_eq!(sh.layers[0].blocks.len(), 9);
    let start = quad.loss(&w0);
    let mut w = w0;
    for k in 1..=300 {
        let g = quad.grad(&w);
        sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(&g), k, 1.0);
    }
    let end = quad.loss(&w);
    assert!(end < start * 0.5, "blocked training must descend: {start:.3} → {end:.3}");
    assert!(fro_norm(&w).is_finite());
}

#[test]
fn beta_sweep_remains_stable() {
    // Tab. 7's robustness claim at integration scope: every β in the
    // paper's sweep trains without blow-up.
    let quad = Quadratic::new(10, 10, 30.0, 13);
    let mut rng = Rng::new(14);
    let w0 = Matrix::randn(10, 10, 1.0, &mut rng);
    for beta in [0.6f32, 0.8, 0.95, 0.98] {
        let cfg = ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            beta,
            beta_e: beta,
            t1: 2,
            t2: 10,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgd(5e-4, 0.0), cfg, &[(10, 10)]);
        let mut w = w0.clone();
        for k in 1..=200 {
            let g = quad.grad(&w);
            sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(&g), k, 1.0);
        }
        assert!(!w.has_non_finite(), "β={beta}");
        assert!(quad.loss(&w) < quad.loss(&w0), "β={beta} must descend");
    }
}

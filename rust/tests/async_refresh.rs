//! The async-refresh engine's pinned contracts:
//!
//! 1. **Shard invariance** — with `async_refresh = true` the trajectory is
//!    bit-identical across `async_shards` ∈ {1, 2, 4}: publishes happen at
//!    deterministic due steps in unit-index order, worker timing never
//!    leaks into the math.
//! 2. **Staleness envelope** — over a 200-step soak no publish ever lands
//!    more than `max_async_staleness` steps after its submission.
//! 3. **Mid-flight checkpointing** — `write_state` drains (never publishes)
//!    in-flight refreshes, and a restored optimizer replays the
//!    uninterrupted trajectory bit-for-bit, including publishes at the
//!    original due steps.
//! 4. **Fault determinism** — forced root failures drive the fallback
//!    ladder through the async publish path with the same determinism.
//! 5. **Kill + resume, full stack** — the persistence oracle holds with
//!    refreshes in flight at every checkpoint.

use quartz::linalg::Matrix;
use quartz::optim::BaseOptimizer;
use quartz::persist::{list_checkpoints, spec_hash};
use quartz::quant::QuantConfig;
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::train::registry;
use quartz::train::synthetic::final_params_synthetic;
use quartz::train::{OptimizerStack, SyntheticSpec, TrainConfig};
use quartz::util::bytes::{ByteReader, ByteWriter};
use quartz::util::fault::FaultPlan;
use quartz::util::rng::Rng;
use std::path::PathBuf;

const SHAPES: [(usize, usize); 3] = [(12, 8), (8, 8), (16, 4)];

fn async_cfg(shards: usize, staleness: u64) -> ShampooConfig {
    ShampooConfig {
        t1: 1,
        t2: 2,
        variant: ShampooVariant::Cq4 { error_feedback: true },
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        async_refresh: true,
        async_shards: shards,
        max_async_staleness: staleness,
        ..Default::default()
    }
}

fn seeded_grads(steps: u64, seed: u64) -> Vec<Vec<Matrix>> {
    let mut rng = Rng::new(seed);
    (0..steps)
        .map(|_| SHAPES.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect())
        .collect()
}

fn run(
    cfg: ShampooConfig,
    grads: &[Vec<Matrix>],
    fault: Option<&FaultPlan>,
) -> (Vec<Matrix>, Shampoo) {
    let mut rng = Rng::new(29);
    let mut params: Vec<Matrix> =
        SHAPES.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
    let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &SHAPES);
    quartz::optim::Optimizer::set_fault_plan(&mut sh, fault);
    for (i, g) in grads.iter().enumerate() {
        sh.step(&mut params, g, i as u64 + 1, 1.0);
    }
    (params, sh)
}

#[test]
fn trajectory_is_invariant_across_shard_counts() {
    let grads = seeded_grads(30, 31);
    let (base, sh1) = run(async_cfg(1, 2), &grads, None);
    let s = &sh1.refresh_stats().async_refresh;
    assert!(s.submitted > 0, "30 steps at t2=2 must submit refreshes");
    assert!(s.published > 0);
    assert!(s.max_publish_lag <= 2, "lag {} exceeds the staleness envelope", s.max_publish_lag);
    for shards in [2usize, 4] {
        let (p, _) = run(async_cfg(shards, 2), &grads, None);
        for (i, (a, b)) in base.iter().zip(p.iter()).enumerate() {
            assert_eq!(
                a.max_abs_diff(b),
                0.0,
                "param {i}: async_shards={shards} diverged from async_shards=1"
            );
        }
    }
    for p in &base {
        assert!(!p.has_non_finite());
    }
}

#[test]
fn soak_respects_staleness_envelope_and_coalesces() {
    // d = 3 with roots planned every 2 steps: a unit is regularly re-planned
    // while still in flight, so the coalescing gate must fire — and no
    // publish may ever exceed the envelope across 200 steps.
    let grads = seeded_grads(200, 37);
    let (params, sh) = run(async_cfg(2, 3), &grads, None);
    let s = &sh.refresh_stats().async_refresh;
    assert!(s.max_publish_lag <= 3, "lag {} exceeds max_async_staleness=3", s.max_publish_lag);
    assert!(s.coalesced > 0, "t2=2 under d=3 must coalesce in-flight re-plans");
    assert!(s.steps_overlapped > 0, "refreshes must overlap optimizer steps");
    assert!(s.submitted >= s.published);
    assert!(s.max_in_flight >= 1);
    for (id, meta) in sh.unit_metas() {
        assert!(meta.refreshes > 0, "{id:?} starved across the soak");
    }
    for p in &params {
        assert!(!p.has_non_finite());
    }
}

#[test]
fn mid_flight_checkpoint_resumes_bit_identically() {
    // every-n at t2 = 4 with d = 3: roots submitted at step 4 publish at
    // step 7, so a checkpoint taken after step 5 has every unit in flight.
    let cfg = ShampooConfig {
        t1: 2,
        t2: 4,
        variant: ShampooVariant::Cq4 { error_feedback: true },
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        async_refresh: true,
        async_shards: 2,
        max_async_staleness: 3,
        ..Default::default()
    };
    let grads = seeded_grads(12, 43);
    let mut rng = Rng::new(29);
    let mut params: Vec<Matrix> =
        SHAPES.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
    let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &SHAPES);
    for k in 1..=5u64 {
        sh.step(&mut params, &grads[k as usize - 1], k, 1.0);
    }
    let s = &sh.refresh_stats().async_refresh;
    assert!(
        s.submitted > s.published,
        "checkpoint must catch refreshes in flight (submitted {} published {})",
        s.submitted,
        s.published
    );
    let mut w = ByteWriter::new();
    sh.write_state(&mut w);
    let bytes = w.into_bytes();
    let params_ck = params.clone();

    let mut resumed = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &SHAPES);
    resumed.read_state(&mut ByteReader::new(&bytes)).unwrap();
    let mut params_r = params_ck;
    for k in 6..=12u64 {
        sh.step(&mut params, &grads[k as usize - 1], k, 1.0);
        resumed.step(&mut params_r, &grads[k as usize - 1], k, 1.0);
    }
    for (i, (a, b)) in params.iter().zip(params_r.iter()).enumerate() {
        assert_eq!(a.max_abs_diff(b), 0.0, "param {i}: resumed mid-flight trajectory diverged");
    }
    // Truncating the pending table must error, not panic or truncate-accept.
    let mut fresh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 0.0), cfg, &SHAPES);
    assert!(fresh.read_state(&mut ByteReader::new(&bytes[..bytes.len() - 5])).is_err());
}

#[test]
fn forced_failures_stay_deterministic_under_async() {
    // Forced root failures skip the worker's compute rungs; the fallback
    // ladder then runs at publish time on the step thread. Trajectories
    // must stay bit-identical across shard counts, and the ladder outcomes
    // must land in the health counters.
    let fault = FaultPlan { seed: 5, force_fail_every: 4, fail_one_in: 1, ..Default::default() };
    let grads = seeded_grads(24, 47);
    let (base, sh) = run(async_cfg(1, 2), &grads, Some(&fault));
    let h = sh.health();
    assert!(
        h.stale_root_serves + h.floor_serves > 0,
        "forced failures must reach the stale/floor rungs through the publish path"
    );
    let (p2, sh2) = run(async_cfg(4, 2), &grads, Some(&fault));
    for (i, (a, b)) in base.iter().zip(p2.iter()).enumerate() {
        assert_eq!(a.max_abs_diff(b), 0.0, "param {i}: faulted async run diverged across shards");
    }
    assert_eq!(sh.health().quarantines, sh2.health().quarantines);
}

// ---------------------------------------------------------------------------
// Full-stack kill + resume with refreshes in flight at every checkpoint
// ---------------------------------------------------------------------------

fn spec() -> SyntheticSpec {
    SyntheticSpec { shapes: vec![(12, 8), (8, 8), (6, 4)], noise: 0.05, pace_ms: 0 }
}

/// cq-ef stack with the async engine on: every-n at t2 = 4 with d = 3, so
/// the checkpoints at steps 5 and 10 each catch the step-4 / step-8
/// submissions still in flight (due at 7 and 11). `graft` layers a
/// (possibly stateful) graft on top — "sgd" is the classic default.
fn async_stack_grafted(graft: &'static str) -> OptimizerStack {
    let cfg = ShampooConfig {
        t1: 2,
        t2: 4,
        max_order: 8,
        graft,
        quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
        async_refresh: true,
        async_shards: 2,
        max_async_staleness: 3,
        ..Default::default()
    };
    registry::build("cq-ef", BaseOptimizer::sgdm(0.05, 0.9, 0.0), &cfg, &spec().shapes)
        .expect("cq-ef stack must be registered")
}

fn async_stack() -> OptimizerStack {
    async_stack_grafted("sgd")
}

fn train_cfg(steps: u64, dir: Option<PathBuf>, hash: u64) -> TrainConfig {
    TrainConfig {
        steps,
        seed: 7,
        log_every: 5,
        checkpoint_every: 5,
        checkpoint_dir: dir,
        spec_hash: hash,
        ..Default::default()
    }
}

#[test]
fn kill_resume_with_in_flight_refreshes_is_bit_identical() {
    let dir = std::env::temp_dir().join(format!("quartz-async-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let hash = spec_hash("oracle|async-cq-ef");
    let spec = spec();

    // Uninterrupted control: 20 steps straight through.
    let (pa, oa) =
        final_params_synthetic(&spec, async_stack(), &train_cfg(20, None, hash)).unwrap();

    // Killed after step 12; checkpoints at 5 and 10 both hold in-flight
    // refreshes (submitted at 4 and 8, due at 7 and 11).
    final_params_synthetic(&spec, async_stack(), &train_cfg(12, Some(dir.clone()), hash)).unwrap();
    let steps: Vec<u64> = list_checkpoints(&dir).iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![5, 10], "unexpected checkpoints");

    // Resume restores step 10 (pending publish due at 11) and trains on.
    let (pb, ob) =
        final_params_synthetic(&spec, async_stack(), &train_cfg(20, Some(dir.clone()), hash))
            .unwrap();

    for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(a.max_abs_diff(b), 0.0, "param {i} diverged after mid-flight resume");
    }
    let state = |o: &OptimizerStack| {
        let mut w = ByteWriter::new();
        o.save_state(&mut w).unwrap();
        w.into_bytes()
    };
    assert_eq!(state(&oa), state(&ob), "optimizer state diverged after mid-flight resume");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same mid-flight kill/resume oracle with a stateful `adagrad` graft: the
/// accumulators advance once per step on the apply path, ride in the
/// checkpoint next to the pending-refresh table, and must restore to a
/// bit-identical trajectory and byte-equal serialized state.
#[test]
fn kill_resume_with_adagrad_graft_and_in_flight_refreshes() {
    let dir =
        std::env::temp_dir().join(format!("quartz-async-graft-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let hash = spec_hash("oracle|async-cq-ef-adagrad");
    let spec = spec();
    let mk = || async_stack_grafted("adagrad");

    let (pa, oa) = final_params_synthetic(&spec, mk(), &train_cfg(20, None, hash)).unwrap();
    final_params_synthetic(&spec, mk(), &train_cfg(12, Some(dir.clone()), hash)).unwrap();
    let steps: Vec<u64> = list_checkpoints(&dir).iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![5, 10], "unexpected checkpoints");
    let (pb, ob) =
        final_params_synthetic(&spec, mk(), &train_cfg(20, Some(dir.clone()), hash)).unwrap();

    for (i, (a, b)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(a.max_abs_diff(b), 0.0, "param {i} diverged after grafted resume");
    }
    let state = |o: &OptimizerStack| {
        let mut w = ByteWriter::new();
        o.save_state(&mut w).unwrap();
        w.into_bytes()
    };
    assert_eq!(state(&oa), state(&ob), "graft accumulators diverged after resume");
    let _ = std::fs::remove_dir_all(&dir);
}

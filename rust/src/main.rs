//! `quartz` — the L3 coordinator CLI.
//!
//! Subcommands map onto the paper's experiments (DESIGN.md §3):
//!
//! ```text
//! quartz table  --id tab3 [--quick] [--out runs]     # reproduce a table
//! quartz figure --id fig3 [--quick] [--out runs]     # reproduce a figure
//! quartz train  --model res_mlp_c32 --base sgdm --shampoo cq-ef --steps 400
//! quartz run    --config examples/experiment.toml    # user-defined grid
//! quartz queue  specs.toml --out DIR                 # resumable job queue
//! quartz resume DIR                                  # continue a queue dir
//! quartz quant-demo                                  # Fig. 2 joint store demo
//! quartz list                                        # artifacts + models
//! ```

use quartz::analysis::{figures, tables};
use quartz::bail;
use quartz::coordinator::queue::{resume_queue, run_queue, MetricsLog};
use quartz::coordinator::runner::{run_all, run_all_logged, RunOutcome};
use quartz::coordinator::spec::{ExperimentSpec, OptimizerSpec, RunSpec, Workload};
use quartz::data::synthetic::ClusterSpec;
use quartz::data::tokens::CorpusSpec;
use quartz::linalg::Matrix;
use quartz::metrics::HealthStats;
use quartz::quant::{BlockQuantizer, QuantConfig, TriJointStore};
use quartz::report::table::Table;
use quartz::runtime::Runtime;
use quartz::util::error::{Context, Result};
use quartz::util::fmt_bytes;
use quartz::util::json::Json;
use quartz::util::rng::Rng;
use std::collections::HashMap;
use std::path::PathBuf;

/// Minimal `--flag value` argument parser (offline build set has no clap).
struct Args {
    flags: HashMap<String, String>,
    bools: Vec<String>,
    /// Bare operands in order (`quartz resume <dir>`, `quartz queue <file>`).
    positionals: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    bools.push(name.to_string());
                    i += 1;
                }
            } else {
                positionals.push(a.clone());
                i += 1;
            }
        }
        Args { flags, bools, positionals }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    fn out_dir(&self) -> PathBuf {
        PathBuf::from(self.get("out").unwrap_or("runs"))
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let result = match cmd {
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "train" => cmd_train(&args),
        "run" => cmd_run(&args),
        "queue" => cmd_queue(&args),
        "resume" => cmd_resume(&args),
        "health" => cmd_health(&args),
        "quant-demo" => cmd_quant_demo(),
        "codecs" => cmd_codecs(),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(quartz::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "quartz — memory-efficient 4-bit preconditioned stochastic optimization\n\n\
         commands:\n\
         \x20 table  --id <tab1..tab10|mem-breakdown|all> [--quick] [--out DIR]\n\
         \x20 figure --id <fig1|fig3|fig4|all> [--quick] [--out DIR]\n\
         \x20 train  --model NAME [--base sgdm] [--shampoo KEY]\n\
         \x20        [--refresh-policy every-n|staggered|staleness]\n\
         \x20        [--refresh-budget N] [--steps N] [--lm] [--seed N]\n\
         \x20        [--async-refresh] [--async-shards N] [--max-async-staleness N]\n\
         \x20        [--graft none|sgd|adagrad|rmsprop|sqrt-n]\n\
         \x20        [--start-preconditioning-step N] [--no-precond-dim-gt N]\n\
         \x20 run    --config FILE.toml [--out DIR]\n\
         \x20 queue  FILE.toml [--out DIR] [--checkpoint-every N]\n\
         \x20        # resumable job queue: checkpoints + metrics.jsonl in DIR\n\
         \x20 resume DIR [--checkpoint-every N]\n\
         \x20        # continue a killed/crashed queue from its checkpoints\n\
         \x20 health DIR\n\
         \x20        # numerical-health counters + retry history from metrics.jsonl\n\
         \x20 quant-demo\n\
         \x20 codecs                               # registered optimizer/codec keys\n\
         \x20 list"
    );
    println!("\noptimizer keys (--shampoo / TOML `shampoo =`):");
    for key in quartz::train::registry::stack_keys() {
        let b = quartz::train::registry::lookup(key).unwrap();
        println!("  {key:<8} {}", b.summary);
    }
    println!("\nrefresh policies (--refresh-policy / TOML `refresh_policy =`):");
    for key in quartz::shampoo::scheduler::scheduler_keys() {
        let b = quartz::shampoo::scheduler::lookup(key).unwrap();
        println!("  {key:<10} {}", b.summary);
    }
    println!("\ngrafts (--graft / TOML `graft =`):");
    for key in quartz::optim::grafting::graft_keys() {
        let b = quartz::optim::grafting::lookup(key).unwrap();
        println!("  {key:<8} {}", b.summary);
    }
}

/// List the four registries — optimizer stacks, preconditioner codecs
/// (with bytes-per-element at a reference order), refresh policies, grafts
/// — under grouped headers. Rendering lives in `report::codecs` so the
/// output is snapshot-tested.
fn cmd_codecs() -> Result<()> {
    println!("{}", quartz::report::codecs::codec_listing());
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args.get("id").context("--id required")?;
    std::fs::create_dir_all(args.out_dir())?;
    tables::run_table(id, args.has("quick"), &args.out_dir())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args.get("id").context("--id required")?;
    std::fs::create_dir_all(args.out_dir())?;
    figures::run_figure(id, args.has("quick"), &args.out_dir())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let steps: u64 = args.get("steps").unwrap_or("300").parse()?;
    let seed: u64 = args.get("seed").unwrap_or("0").parse()?;
    let base_name = args.get("base").unwrap_or("sgdm");
    // Any `train::registry` key works here — built-in variants, aliases,
    // or stacks registered at runtime (`quartz codecs` lists them).
    let mut opt = OptimizerSpec::from_names(base_name, args.get("shampoo").unwrap_or("cq-ef"))?;
    if let Some(cfg) = &mut opt.shampoo {
        // Analog-scale intervals (paper ratios over a few hundred steps).
        let scaled = tables::scaled_shampoo(cfg.variant);
        cfg.t1 = scaled.t1;
        cfg.t2 = scaled.t2;
        cfg.max_order = scaled.max_order;
        // Refresh-scheduler selection (`quartz codecs` lists the keys).
        if let Some(rp) = args.get("refresh-policy") {
            let b = quartz::shampoo::scheduler::lookup(rp)
                .with_context(|| format!("unknown refresh policy '{rp}'"))?;
            cfg.refresh_policy = b.key;
        }
        if let Some(rb) = args.get("refresh-budget") {
            cfg.refresh_budget = rb.parse()?;
        }
        // Async-refresh engine (off by default; bit-identical when off).
        if args.has("async-refresh") {
            cfg.async_refresh = true;
        }
        if let Some(sh) = args.get("async-shards") {
            cfg.async_shards = sh.parse()?;
        }
        if let Some(st) = args.get("max-async-staleness") {
            cfg.max_async_staleness = st.parse()?;
            quartz::ensure!(
                cfg.max_async_staleness >= 1,
                "--max-async-staleness must be >= 1"
            );
        }
        // Workload knobs (`quartz codecs` lists the graft keys).
        if let Some(gk) = args.get("graft") {
            let b = quartz::optim::grafting::lookup(gk)
                .with_context(|| format!("unknown graft '{gk}'"))?;
            cfg.graft = b.key;
            cfg.grafting = b.key != "none";
        }
        if let Some(sp) = args.get("start-preconditioning-step") {
            cfg.start_preconditioning_step = sp.parse()?;
        }
        if let Some(dg) = args.get("no-precond-dim-gt") {
            cfg.no_preconditioning_for_layers_with_dim_gt = dg.parse()?;
        }
    }
    let workload = if args.has("lm") || model.starts_with("lm_") {
        Workload::Tokens(CorpusSpec { seed, ..Default::default() })
    } else {
        let classes = if model.ends_with("c64") { 64 } else { 32 };
        if model.starts_with("vit") || model.starts_with("swin") {
            Workload::Image(quartz::data::images::ImageSpec {
                side: 8,
                classes,
                seed,
                noise: 0.5,
                ..Default::default()
            })
        } else {
            Workload::Cluster(ClusterSpec { classes, dim: 64, seed, ..Default::default() })
        }
    };
    let mut spec = RunSpec::new(model, workload, opt, steps);
    spec.seed = seed;
    spec.eval_every = (steps / 5).max(1);

    println!("training {model} with {} for {steps} steps…", spec.optimizer.label());
    let outcomes = run_all(std::slice::from_ref(&spec), 1);
    let o = &outcomes[0];
    if let Some(e) = &o.error {
        bail!("run failed: {e}");
    }
    let m = o.metrics.as_ref().unwrap();
    let mut t = Table::new("run summary", &["metric", "value"]);
    t.row(vec!["model".into(), o.model.clone()]);
    t.row(vec!["optimizer".into(), o.optimizer.clone()]);
    t.row(vec!["final metric".into(), format!("{:.4}", m.final_metric)]);
    t.row(vec!["opt-state bytes".into(), fmt_bytes(m.state_bytes as u64)]);
    t.row(vec!["wall time (s)".into(), format!("{:.1}", m.wall_secs)]);
    t.row(vec!["optimizer time (s)".into(), format!("{:.2}", m.opt_secs)]);
    t.print();
    println!("loss curve: {:?}", m.loss_curve);
    Ok(())
}

fn outcome_table(title: &str, outcomes: &[RunOutcome]) -> Table {
    let mut t = Table::new(title, &["Run", "Metric", "Opt-State", "Wall (s)"]);
    for o in outcomes {
        let (metric, bytes, wall) = match (&o.metrics, &o.error) {
            (Some(m), _) => (
                format!("{:.4}", m.final_metric),
                fmt_bytes(m.state_bytes as u64),
                format!("{:.1}", m.wall_secs),
            ),
            (None, Some(e)) => {
                let first = e.lines().next().unwrap_or("");
                (format!("ERR {first}"), "-".to_string(), "-".to_string())
            }
            (None, None) => ("OOM".to_string(), fmt_bytes(o.modeled_bytes as u64), "-".to_string()),
        };
        t.row(vec![o.id.clone(), metric, bytes, wall]);
    }
    t
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args.get("config").context("--config required")?;
    let text = std::fs::read_to_string(path)?;
    let spec = ExperimentSpec::from_toml(&text)?;
    println!("experiment '{}': {} runs on {} workers", spec.name, spec.runs.len(), spec.workers);
    std::fs::create_dir_all(args.out_dir())?;
    // Stream per-run wall-clock + outcome events alongside the final table.
    let log = MetricsLog::open(&args.out_dir().join(format!("{}.jsonl", spec.name)))?;
    let outcomes = run_all_logged(&spec.runs, spec.workers, Some(&log));
    let t = outcome_table(&format!("experiment '{}'", spec.name), &outcomes);
    t.print();
    t.save_csv(&args.out_dir().join(format!("{}.csv", spec.name)))?;
    Ok(())
}

fn cmd_queue(args: &Args) -> Result<()> {
    let path = args
        .positional(0)
        .or_else(|| args.get("config"))
        .context("usage: quartz queue FILE.toml [--out DIR] [--checkpoint-every N]")?
        .to_string();
    let text = std::fs::read_to_string(&path)?;
    let dir = PathBuf::from(args.get("out").unwrap_or("runs/queue"));
    let every: u64 = args.get("checkpoint-every").unwrap_or("0").parse()?;
    println!("queue '{path}' -> {} (metrics.jsonl, runs/<id>/*.ckpt)", dir.display());
    let outcomes = run_queue(&text, &dir, every)?;
    outcome_table(&format!("queue {}", dir.display()), &outcomes).print();
    Ok(())
}

fn cmd_resume(args: &Args) -> Result<()> {
    let dir = args
        .positional(0)
        .or_else(|| args.get("dir"))
        .map(PathBuf::from)
        .context("usage: quartz resume DIR [--checkpoint-every N]")?;
    let every: u64 = args.get("checkpoint-every").unwrap_or("0").parse()?;
    println!("resuming queue {}…", dir.display());
    let outcomes = resume_queue(&dir, every)?;
    outcome_table(&format!("queue {}", dir.display()), &outcomes).print();
    Ok(())
}

/// Summarize the numerical-health guard counters a queue streamed into its
/// `metrics.jsonl`: last outcome per run, retry attempts, per-run guard
/// counters, and a totals line. Reads the same stream `quartz queue` /
/// `quartz resume` append to, so it works on live and finished queues alike.
fn cmd_health(args: &Args) -> Result<()> {
    let dir = args
        .positional(0)
        .or_else(|| args.get("dir"))
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("runs/queue"));
    let path = dir.join("metrics.jsonl");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no metrics stream at {}", path.display()))?;

    // Last run_end wins per id — a retried run logs one per attempt and the
    // terminal line carries the outcome the queue cached.
    let mut ends: std::collections::BTreeMap<String, (String, HealthStats)> = Default::default();
    let mut retries: HashMap<String, u64> = HashMap::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let j = Json::parse(line).with_context(|| format!("bad line in {}", path.display()))?;
        let event = j.get("event").and_then(|v| v.as_str()).unwrap_or("");
        let id = j.get("id").and_then(|v| v.as_str()).unwrap_or("").to_string();
        match event {
            "run_retry" => *retries.entry(id).or_insert(0) += 1,
            "run_end" => {
                let outcome = j.get("outcome").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                let mut h = HealthStats::default();
                if let Some(hj) = j.get("health") {
                    let g = |k: &str| hj.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                    h = HealthStats {
                        grads_screened: g("grads_screened"),
                        jitter_rescues: g("jitter_rescues"),
                        psd_projections: g("psd_projections"),
                        stale_root_serves: g("stale_root_serves"),
                        floor_serves: g("floor_serves"),
                        quarantines: g("quarantines"),
                        releases: g("releases"),
                    };
                }
                ends.insert(id, (outcome, h));
            }
            _ => {}
        }
    }
    if ends.is_empty() {
        bail!("no run_end events in {} yet", path.display());
    }

    let mut t = Table::new(
        &format!("health {}", dir.display()),
        &["Run", "Outcome", "Retries", "Screened", "Jitter", "PSD", "Stale", "Floor", "Quar", "Rel"],
    );
    let mut total = HealthStats::default();
    for (id, (outcome, h)) in &ends {
        total.absorb(h);
        t.row(vec![
            id.clone(),
            outcome.clone(),
            format!("{}", retries.get(id).copied().unwrap_or(0)),
            format!("{}", h.grads_screened),
            format!("{}", h.jitter_rescues),
            format!("{}", h.psd_projections),
            format!("{}", h.stale_root_serves),
            format!("{}", h.floor_serves),
            format!("{}", h.quarantines),
            format!("{}", h.releases),
        ]);
    }
    t.print();
    println!("totals: {}", total.summary());
    Ok(())
}

/// Fig. 2 demonstration: pack a Cholesky factor and its error state into one
/// buffer and show the byte accounting.
fn cmd_quant_demo() -> Result<()> {
    let n = 8;
    let mut rng = Rng::new(42);
    let q = BlockQuantizer::new(QuantConfig { block: 4, min_quant_elems: 0, ..Default::default() });
    let c = Matrix::from_fn(n, n, |i, j| {
        if i > j {
            rng.normal_f32(1.0)
        } else if i == j {
            2.0
        } else {
            0.0
        }
    });
    let e = Matrix::from_fn(n, n, |i, j| if i > j { rng.normal_f32(0.05) } else { 0.0 });
    let store = TriJointStore::store(&c, &e, &q);
    let (c2, e2) = store.load(&q);
    println!("Fig. 2 joint triangular storage demo (n = {n})");
    println!("  Cholesky factor C (lower, f32 diag):\n{c:?}");
    println!("  error state E (strictly lower):\n{e:?}");
    println!("  joint store bytes: {}", store.size_bytes());
    println!("  = one n²/2-byte nibble grid ({}) + f32 diag ({}) + scales", n * n / 2, n * 4);
    println!("  recovered C matches: {}", c2.max_abs_diff(&c) < 0.5);
    println!("  recovered E matches: {}", e2.max_abs_diff(&e) < 0.05);
    let full32 = 2 * n * n * 4;
    println!("  vs two f32 matrices: {} bytes → {:.1}% of f32", full32,
        100.0 * store.size_bytes() as f64 / full32 as f64);
    Ok(())
}

fn cmd_list() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    let mut t = Table::new("models", &["name", "kind", "batch", "params", "weights"]);
    for (name, m) in &rt.manifest.models {
        t.row(vec![
            name.clone(),
            m.kind.clone(),
            format!("{}", m.batch),
            format!("{}", m.params.len()),
            format!("{}", m.n_weights()),
        ]);
    }
    t.print();
    let mut t = Table::new("artifacts", &["name", "file", "inputs", "outputs"]);
    for (name, a) in &rt.manifest.artifacts {
        t.row(vec![
            name.clone(),
            a.file.clone(),
            format!("{}", a.inputs.len()),
            format!("{}", a.outputs),
        ]);
    }
    t.print();
    Ok(())
}

//! The training engine: drives AOT-compiled fwd/bwd graphs through the PJRT
//! runtime and applies the (possibly Shampoo-wrapped) optimizer in rust.

pub mod trainer;
pub mod stack;

pub use stack::OptimizerStack;
pub use trainer::{train_classifier, train_lm, ClassifierData, RunMetrics, TrainConfig};

//! The training engine: drives AOT-compiled fwd/bwd graphs through the PJRT
//! runtime and applies a boxed [`crate::optim::Optimizer`] in rust.
//!
//! * [`stack`] — [`OptimizerStack`], the trait-object carrier every loop
//!   programs against.
//! * [`registry`] — string-keyed stack construction (`"cq-ef"`, `"bw8"`, …)
//!   used by coordinator specs, the CLI, and the examples.
//! * [`trainer`] — the classifier/LM training loops and evaluation.
//! * [`synthetic`] — the artifact-free noisy-quadratic workload used by the
//!   job queue, the crash-resume smoke, and the resume oracle tests.

pub mod trainer;
pub mod stack;
pub mod registry;
pub mod synthetic;

pub use stack::OptimizerStack;
pub use synthetic::{train_synthetic, SyntheticSpec};
pub use trainer::{train_classifier, train_lm, ClassifierData, RunMetrics, TrainConfig};

//! Optimizer stack: a base optimizer alone, or Shampoo wrapping it
//! (paper's "base" vs "base + Shampoo" table rows).

use crate::linalg::Matrix;
use crate::optim::BaseOptimizer;
use crate::shampoo::Shampoo;

/// Either a first-order optimizer or Shampoo-wrapped.
pub enum OptimizerStack {
    Base(BaseOptimizer),
    Shampoo(Box<Shampoo>),
}

impl OptimizerStack {
    /// Initialize for the parameter set (no-op for Shampoo, which is built
    /// with shapes up-front).
    pub fn init(&mut self, n_params: usize) {
        if let OptimizerStack::Base(b) = self {
            b.init(n_params);
        }
    }

    /// Apply one step across all parameters.
    pub fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], k: u64, lr_scale: f32) {
        match self {
            OptimizerStack::Base(b) => {
                for (i, (w, g)) in params.iter_mut().zip(grads.iter()).enumerate() {
                    b.step_param(i, w, g, lr_scale);
                }
            }
            OptimizerStack::Shampoo(s) => s.step(params, grads, k, lr_scale),
        }
    }

    /// Persistent optimizer-state bytes.
    pub fn state_bytes(&self) -> usize {
        match self {
            OptimizerStack::Base(b) => b.state_bytes(),
            OptimizerStack::Shampoo(s) => s.state_bytes(),
        }
    }

    /// Human label for table rows ("SGDM + 4-bit Shampoo (CQ+EF)" style).
    pub fn label(&self) -> String {
        match self {
            OptimizerStack::Base(b) => b.kind.name().to_uppercase(),
            OptimizerStack::Shampoo(s) => {
                format!("{} + {} Shampoo", s.base.kind.name().to_uppercase(), s.cfg.variant.name())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shampoo::{ShampooConfig, ShampooVariant};

    #[test]
    fn labels() {
        let b = OptimizerStack::Base(BaseOptimizer::sgdm(0.1, 0.9, 0.0));
        assert_eq!(b.label(), "SGDM");
        let s = OptimizerStack::Shampoo(Box::new(Shampoo::new(
            BaseOptimizer::adamw(1e-3, 0.9, 0.999, 1e-8, 0.05),
            ShampooConfig { variant: ShampooVariant::Cq4 { error_feedback: true }, ..Default::default() },
            &[(8, 8)],
        )));
        assert_eq!(s.label(), "ADAMW + 4-bit (CQ+EF) Shampoo");
    }

    #[test]
    fn base_step_applies_to_all_params() {
        let mut stack = OptimizerStack::Base(BaseOptimizer::sgd(0.5, 0.0));
        stack.init(2);
        let mut params = vec![Matrix::zeros(1, 1), Matrix::zeros(1, 1)];
        let grads = vec![
            Matrix::from_rows(&[&[1.0]]),
            Matrix::from_rows(&[&[2.0]]),
        ];
        stack.step(&mut params, &grads, 1, 1.0);
        assert_eq!(params[0][(0, 0)], -0.5);
        assert_eq!(params[1][(0, 0)], -1.0);
    }
}

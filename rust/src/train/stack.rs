//! Optimizer stack: a thin newtype over a boxed [`Optimizer`] trait object.
//!
//! Everything downstream (trainer, coordinator, examples, benches) holds an
//! `OptimizerStack` and sees only the trait — any optimizer registered in
//! [`crate::train::registry`] (or constructed directly and boxed) slots in
//! without a code change here.

use crate::linalg::Matrix;
use crate::optim::{BaseOptimizer, Optimizer};
use crate::shampoo::Shampoo;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;

/// A boxed optimizer driving one training run.
pub struct OptimizerStack(Box<dyn Optimizer>);

impl OptimizerStack {
    /// Wrap any optimizer.
    pub fn new(opt: Box<dyn Optimizer>) -> OptimizerStack {
        OptimizerStack(opt)
    }

    /// A first-order base optimizer alone (the paper's baseline rows).
    pub fn base(b: BaseOptimizer) -> OptimizerStack {
        OptimizerStack(Box::new(b))
    }

    /// Shampoo wrapping its base (the "… + Shampoo" rows).
    pub fn shampoo(s: Shampoo) -> OptimizerStack {
        OptimizerStack(Box::new(s))
    }

    /// Initialize for the parameter set (no-op for optimizers built with
    /// shapes up-front, e.g. Shampoo).
    pub fn init(&mut self, n_params: usize) {
        self.0.init(n_params);
    }

    /// Apply one step across all parameters.
    pub fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], k: u64, lr_scale: f32) {
        self.0.step(params, grads, k, lr_scale);
    }

    /// Persistent optimizer-state bytes.
    pub fn state_bytes(&self) -> usize {
        self.0.state_bytes()
    }

    /// Human label for table rows ("SGDM + 4-bit Shampoo (CQ+EF)" style) —
    /// delegated to [`Optimizer::name`], the single naming source.
    pub fn label(&self) -> String {
        self.0.name()
    }

    /// Borrow the underlying trait object.
    pub fn inner(&self) -> &dyn Optimizer {
        self.0.as_ref()
    }

    /// Serialize the optimizer's mutable state — see
    /// [`Optimizer::save_state`] for the contract (errors if the boxed
    /// optimizer doesn't support checkpointing).
    pub fn save_state(&self, out: &mut ByteWriter) -> Result<()> {
        self.0.save_state(out)
    }

    /// Restore state into this freshly built stack.
    pub fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.0.restore_state(r)
    }

    /// Install (or clear) a deterministic fault-injection plan on the boxed
    /// optimizer — a no-op for optimizers without a refresh pipeline.
    pub fn set_fault_plan(&mut self, plan: Option<&crate::util::fault::FaultPlan>) {
        self.0.set_fault_plan(plan);
    }

    /// Cumulative numerical-health counters from the boxed optimizer.
    pub fn health_stats(&self) -> crate::metrics::HealthStats {
        self.0.health_stats()
    }
}

impl From<Box<dyn Optimizer>> for OptimizerStack {
    fn from(opt: Box<dyn Optimizer>) -> OptimizerStack {
        OptimizerStack(opt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shampoo::{ShampooConfig, ShampooVariant};

    #[test]
    fn labels() {
        let b = OptimizerStack::base(BaseOptimizer::sgdm(0.1, 0.9, 0.0));
        assert_eq!(b.label(), "SGDM");
        let s = OptimizerStack::shampoo(Shampoo::new(
            BaseOptimizer::adamw(1e-3, 0.9, 0.999, 1e-8, 0.05),
            ShampooConfig {
                variant: ShampooVariant::Cq4 { error_feedback: true },
                ..Default::default()
            },
            &[(8, 8)],
        ));
        assert_eq!(s.label(), "ADAMW + 4-bit (CQ+EF) Shampoo");
    }

    #[test]
    fn base_step_applies_to_all_params() {
        let mut stack = OptimizerStack::base(BaseOptimizer::sgd(0.5, 0.0));
        stack.init(2);
        let mut params = vec![Matrix::zeros(1, 1), Matrix::zeros(1, 1)];
        let grads = vec![
            Matrix::from_rows(&[&[1.0]]),
            Matrix::from_rows(&[&[2.0]]),
        ];
        stack.step(&mut params, &grads, 1, 1.0);
        assert_eq!(params[0][(0, 0)], -0.5);
        assert_eq!(params[1][(0, 0)], -1.0);
    }

    #[test]
    fn custom_optimizer_slots_in_through_the_trait() {
        // A user-defined optimizer the core has never heard of drives the
        // stack — the open-world property the newtype exists for.
        #[derive(Debug)]
        struct HalvingOptimizer;
        impl crate::optim::Optimizer for HalvingOptimizer {
            fn init(&mut self, _n: usize) {}
            fn step(&mut self, params: &mut [Matrix], _g: &[Matrix], _k: u64, _lr: f32) {
                for p in params.iter_mut() {
                    p.scale(0.5);
                }
            }
            fn state_bytes(&self) -> usize {
                0
            }
            fn name(&self) -> String {
                "HALVING".to_string()
            }
        }
        let mut stack = OptimizerStack::new(Box::new(HalvingOptimizer));
        assert_eq!(stack.label(), "HALVING");
        let mut params = vec![Matrix::eye(2)];
        let grads = vec![Matrix::zeros(2, 2)];
        stack.step(&mut params, &grads, 1, 1.0);
        assert_eq!(params[0][(0, 0)], 0.5);
    }
}

//! An artifact-free training workload: a deterministic noisy quadratic.
//!
//! The PJRT loops in [`super::trainer`] need AOT-compiled HLO artifacts on
//! disk; the queue service, the crash-resume CI smoke, and the
//! bit-identical-resume oracle tests need a workload that runs anywhere.
//! This one optimizes `mean ½‖W_l − T_l‖²` per layer with gradients
//! `(W_l − T_l) + noise·ε`, ε drawn from the seeded trainer RNG stream —
//! fully deterministic, exercises the whole optimizer stack (Shampoo
//! blocks, codecs, EF, refresh scheduler), and supports the same
//! checkpoint/resume hooks as the real loops.

use crate::linalg::Matrix;
use crate::metrics::Stopwatch;
use crate::train::trainer::{
    checkpoint_now, resume_or_start, should_checkpoint, RunMetrics, TrainConfig,
};
use crate::train::OptimizerStack;
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::time::Instant;

/// Shape and pacing of a synthetic run.
#[derive(Clone, Debug, PartialEq)]
pub struct SyntheticSpec {
    /// Per-layer parameter shapes.
    pub shapes: Vec<(usize, usize)>,
    /// Gradient noise scale (0 = exact quadratic).
    pub noise: f32,
    /// Sleep this long per step — paces runs so a crash-resume smoke can
    /// kill the process mid-run reliably (0 = full speed).
    pub pace_ms: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec { shapes: vec![(16, 8), (8, 8), (4, 1)], noise: 0.05, pace_ms: 0 }
    }
}

impl SyntheticSpec {
    /// Deterministic per-layer targets (a function of the seed only).
    fn targets(&self, seed: u64) -> Vec<Matrix> {
        let mut rng = Rng::new(seed ^ 0x7A46);
        self.shapes.iter().map(|&(r, c)| Matrix::randn(r, c, 1.0, &mut rng)).collect()
    }

    /// Deterministic initial parameters (a different stream).
    fn init_params(&self, seed: u64) -> Vec<Matrix> {
        let mut rng = Rng::new(seed ^ 0x1217);
        self.shapes.iter().map(|&(r, c)| Matrix::randn(r, c, 0.5, &mut rng)).collect()
    }
}

/// Mean ½‖W − T‖² across every element of every layer.
fn quadratic_loss(params: &[Matrix], targets: &[Matrix]) -> f32 {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for (w, t) in params.iter().zip(targets.iter()) {
        for (a, b) in w.data().iter().zip(t.data().iter()) {
            let d = (*a - *b) as f64;
            sum += 0.5 * d * d;
        }
        n += w.data().len();
    }
    (sum / n.max(1) as f64) as f32
}

/// Train `opt` on the noisy quadratic, mirroring the real loops' contract:
/// same RNG stream discipline (`seed ^ 0xBA7C`, all of a step's draws
/// before its optimizer update), same curve cadence, same
/// checkpoint/resume hooks, same [`RunMetrics`] shape. The eval metric is
/// the exact (noise-free) loss, so lower is better.
pub fn train_synthetic(
    spec: &SyntheticSpec,
    mut opt: OptimizerStack,
    cfg: &TrainConfig,
) -> Result<RunMetrics> {
    crate::ensure!(!spec.shapes.is_empty(), "synthetic workload needs at least one shape");
    let targets = spec.targets(cfg.seed);
    let mut params = spec.init_params(cfg.seed);
    opt.init(params.len());
    opt.set_fault_plan(cfg.faults.as_ref());

    let mut opt_time = Stopwatch::new();
    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();

    let mut rng = Rng::new(cfg.seed ^ 0xBA7C);
    let base =
        resume_or_start(cfg, &mut params, &mut opt, &mut rng, &mut loss_curve, &mut eval_curve)?;
    let run_start = Instant::now();
    for k in base.start_step + 1..=cfg.steps {
        let loss = quadratic_loss(&params, &targets);
        let mut grads: Vec<Matrix> = params
            .iter()
            .zip(targets.iter())
            .map(|(w, t)| {
                let mut g = w.clone();
                for (gv, tv) in g.data_mut().iter_mut().zip(t.data().iter()) {
                    *gv = (*gv - *tv) + rng.normal_f32(spec.noise);
                }
                g
            })
            .collect();
        // Fault injection is a pure function of (plan, step) — it consumes
        // nothing from the RNG stream, so a resumed run replays the exact
        // same corruption schedule.
        if let Some(fp) = &cfg.faults {
            fp.corrupt_grads(k, &mut grads);
        }

        let lr_scale = cfg.schedule.scale(k - 1);
        opt_time.time(|| opt.step(&mut params, &grads, k, lr_scale));

        if k % cfg.log_every.max(1) == 0 || k == 1 {
            loss_curve.push((k, loss));
        }
        if cfg.eval_every > 0 && k % cfg.eval_every == 0 {
            eval_curve.push((k, quadratic_loss(&params, &targets) as f64));
        }
        if should_checkpoint(cfg, k) {
            checkpoint_now(
                cfg,
                k,
                &params,
                &opt,
                &rng,
                &loss_curve,
                &eval_curve,
                base.wall_secs + run_start.elapsed().as_secs_f64(),
                base.opt_secs + opt_time.total_secs(),
            )?;
        }
        if spec.pace_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(spec.pace_ms));
        }
    }
    let final_loss = quadratic_loss(&params, &targets) as f64;
    eval_curve.push((cfg.steps, final_loss));

    Ok(RunMetrics {
        model: "synthetic".to_string(),
        optimizer: opt.label(),
        loss_curve,
        eval_curve,
        final_metric: final_loss,
        state_bytes: opt.state_bytes(),
        wall_secs: base.wall_secs + run_start.elapsed().as_secs_f64(),
        opt_secs: base.opt_secs + opt_time.total_secs(),
        health: opt.health_stats(),
    })
}

/// Final parameters of a synthetic run — the resume oracle tests compare
/// these byte-for-byte against an uninterrupted run.
pub fn final_params_synthetic(
    spec: &SyntheticSpec,
    mut opt: OptimizerStack,
    cfg: &TrainConfig,
) -> Result<(Vec<Matrix>, OptimizerStack)> {
    let targets = spec.targets(cfg.seed);
    let mut params = spec.init_params(cfg.seed);
    opt.init(params.len());
    opt.set_fault_plan(cfg.faults.as_ref());
    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();
    let mut rng = Rng::new(cfg.seed ^ 0xBA7C);
    let base =
        resume_or_start(cfg, &mut params, &mut opt, &mut rng, &mut loss_curve, &mut eval_curve)?;
    let run_start = Instant::now();
    for k in base.start_step + 1..=cfg.steps {
        let mut grads: Vec<Matrix> = params
            .iter()
            .zip(targets.iter())
            .map(|(w, t)| {
                let mut g = w.clone();
                for (gv, tv) in g.data_mut().iter_mut().zip(t.data().iter()) {
                    *gv = (*gv - *tv) + rng.normal_f32(spec.noise);
                }
                g
            })
            .collect();
        if let Some(fp) = &cfg.faults {
            fp.corrupt_grads(k, &mut grads);
        }
        let lr_scale = cfg.schedule.scale(k - 1);
        opt.step(&mut params, &grads, k, lr_scale);
        if should_checkpoint(cfg, k) {
            checkpoint_now(
                cfg,
                k,
                &params,
                &opt,
                &rng,
                &loss_curve,
                &eval_curve,
                base.wall_secs + run_start.elapsed().as_secs_f64(),
                base.opt_secs,
            )?;
        }
    }
    Ok((params, opt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::BaseOptimizer;

    fn sgdm_stack() -> OptimizerStack {
        OptimizerStack::base(BaseOptimizer::sgdm(0.05, 0.9, 0.0))
    }

    #[test]
    fn synthetic_loss_decreases_and_is_deterministic() {
        let spec = SyntheticSpec::default();
        let cfg = TrainConfig { steps: 60, log_every: 10, seed: 11, ..Default::default() };
        let m1 = train_synthetic(&spec, sgdm_stack(), &cfg).unwrap();
        let m2 = train_synthetic(&spec, sgdm_stack(), &cfg).unwrap();
        assert_eq!(m1.final_metric, m2.final_metric);
        assert_eq!(m1.loss_curve, m2.loss_curve);
        let first = m1.loss_curve.first().unwrap().1;
        assert!(
            m1.final_metric < first as f64 / 2.0,
            "loss did not decrease: {first} -> {}",
            m1.final_metric
        );
        assert_eq!(m1.model, "synthetic");
        assert_eq!(m1.eval_curve.last().unwrap().0, 60);
    }

    #[test]
    fn checkpointed_resume_matches_uninterrupted_run() {
        let dir = std::env::temp_dir().join(format!("quartz-syn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = SyntheticSpec::default();
        let straight = TrainConfig { steps: 40, seed: 3, ..Default::default() };
        let (pa, _) = final_params_synthetic(&spec, sgdm_stack(), &straight).unwrap();

        // Same run, but checkpoint every 15 steps and stop after 30…
        let ck = TrainConfig {
            steps: 30,
            seed: 3,
            checkpoint_every: 15,
            checkpoint_dir: Some(dir.clone()),
            ..Default::default()
        };
        train_synthetic(&spec, sgdm_stack(), &ck).unwrap();
        // …then resume from step 15's checkpoint (30 was suppressed as the
        // final step) and finish to 40.
        let resumed = TrainConfig { steps: 40, ..ck };
        let (pb, _) = final_params_synthetic(&spec, sgdm_stack(), &resumed).unwrap();
        for (a, b) in pa.iter().zip(pb.iter()) {
            assert_eq!(a.max_abs_diff(b), 0.0);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

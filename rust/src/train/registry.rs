//! String-keyed optimizer-stack registry.
//!
//! Maps a variant key (`"none"`, `"32bit"`, `"vq"`, `"cq"`, `"cq-ef"`,
//! `"bw8"`, or anything added via [`register`]) to a builder producing an
//! [`OptimizerStack`] for a model's parameter shapes. Coordinator specs,
//! the CLI, and the examples all construct optimizers through [`build`], so
//! a variant registered at startup is immediately reachable from TOML specs
//! and `--shampoo` flags without touching any construction site.
//!
//! Aliases (`"cqef"`, `"ours"`, `"full32"`, …) are resolved through
//! [`ShampooVariant::parse`] — the registry itself stores only canonical
//! keys.

use crate::optim::BaseOptimizer;
use crate::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use crate::train::OptimizerStack;
use std::sync::{Mutex, OnceLock};

/// One registry entry.
#[derive(Clone, Copy)]
pub struct StackBuilder {
    /// Canonical key (what [`ShampooVariant::key`] returns, or a new name).
    pub key: &'static str,
    /// One-line description for CLI/docs listings.
    pub summary: &'static str,
    /// Build the stack. `cfg` carries intervals/quantizer settings; builders
    /// for a fixed variant override `cfg.variant` with their own.
    pub build: fn(BaseOptimizer, &ShampooConfig, &[(usize, usize)]) -> OptimizerStack,
}

fn build_none(
    base: BaseOptimizer,
    _cfg: &ShampooConfig,
    _shapes: &[(usize, usize)],
) -> OptimizerStack {
    OptimizerStack::base(base)
}

fn with_variant(
    variant: ShampooVariant,
    base: BaseOptimizer,
    cfg: &ShampooConfig,
    shapes: &[(usize, usize)],
) -> OptimizerStack {
    let cfg = ShampooConfig { variant, ..*cfg };
    OptimizerStack::shampoo(Shampoo::new(base, cfg, shapes))
}

fn build_full32(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Full32, b, c, s)
}

fn build_vq(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Vq4, b, c, s)
}

fn build_cq(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Cq4 { error_feedback: false }, b, c, s)
}

fn build_cq_ef(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Cq4 { error_feedback: true }, b, c, s)
}

fn build_bw8(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Bw8, b, c, s)
}

fn builtin_stacks() -> Vec<StackBuilder> {
    vec![
        StackBuilder {
            key: "none",
            summary: "base optimizer alone (no preconditioning)",
            build: build_none,
        },
        StackBuilder {
            key: "32bit",
            summary: "f32 Shampoo (Algorithm 2)",
            build: build_full32,
        },
        StackBuilder {
            key: "vq",
            summary: "4-bit Shampoo, vanilla quantization (Sec. 4.1)",
            build: build_vq,
        },
        StackBuilder {
            key: "cq",
            summary: "4-bit Shampoo, Cholesky quantization (Sec. 4.2)",
            build: build_cq,
        },
        StackBuilder {
            key: "cq-ef",
            summary: "4-bit Shampoo, CQ + error feedback (Alg. 1, ours)",
            build: build_cq_ef,
        },
        StackBuilder {
            key: "bw8",
            summary: "8-bit Shampoo, block-wise quantization",
            build: build_bw8,
        },
    ]
}

fn registry() -> &'static Mutex<Vec<StackBuilder>> {
    static REGISTRY: OnceLock<Mutex<Vec<StackBuilder>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(builtin_stacks()))
}

/// Register a stack builder under a new key. Returns `false` (unchanged
/// registry) if the key is taken.
pub fn register(builder: StackBuilder) -> bool {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    if reg.iter().any(|b| b.key == builder.key) {
        return false;
    }
    reg.push(builder);
    true
}

/// Look up a builder by canonical key, then by variant alias.
pub fn lookup(key: &str) -> Option<StackBuilder> {
    let found = {
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.iter().find(|b| b.key == key).copied()
    };
    found.or_else(|| {
        let canonical = ShampooVariant::parse(key)?.key();
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.iter().find(|b| b.key == canonical).copied()
    })
}

/// Build a stack by key (canonical or alias). `cfg.variant` is overridden
/// by keyed builders; other config fields (intervals, quantizer, codec
/// overrides) pass through.
pub fn build(
    key: &str,
    base: BaseOptimizer,
    cfg: &ShampooConfig,
    shapes: &[(usize, usize)],
) -> Option<OptimizerStack> {
    lookup(key).map(|b| (b.build)(base, cfg, shapes))
}

/// All registered canonical keys, built-ins first.
pub fn stack_keys() -> Vec<&'static str> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().map(|b| b.key).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_key_builds() {
        let cfg = ShampooConfig { t1: 1, t2: 1, max_order: 16, ..Default::default() };
        for key in stack_keys() {
            let stack = build(key, BaseOptimizer::sgd(0.1, 0.0), &cfg, &[(8, 8)])
                .unwrap_or_else(|| panic!("key '{key}' must build"));
            if key == "none" {
                assert_eq!(stack.label(), "SGD");
            } else {
                assert!(stack.label().contains("Shampoo"), "{key}: {}", stack.label());
            }
        }
    }

    #[test]
    fn aliases_resolve_via_variant_parse() {
        let cfg = ShampooConfig::default();
        for (alias, canonical) in [("ours", "cq-ef"), ("full32", "32bit"), ("8bit", "bw8")] {
            let a = lookup(alias).unwrap_or_else(|| panic!("alias '{alias}'"));
            assert_eq!(a.key, canonical);
        }
        assert!(build("no-such-stack", BaseOptimizer::sgd(0.1, 0.0), &cfg, &[(4, 4)]).is_none());
    }

    #[test]
    fn builtin_stack_keys_cannot_be_shadowed() {
        let b = lookup("cq-ef").unwrap();
        assert!(!register(b));
    }

    #[test]
    fn refresh_policy_flows_through_keyed_builders() {
        // `cfg.refresh_policy` rides the same pass-through as intervals and
        // codec overrides: every keyed Shampoo builder honors it, and the
        // stack label surfaces the non-default schedule.
        let cfg = ShampooConfig {
            t1: 1,
            t2: 2,
            max_order: 16,
            refresh_policy: "staggered",
            ..Default::default()
        };
        for key in ["32bit", "vq", "cq", "cq-ef", "bw8"] {
            let stack = build(key, BaseOptimizer::sgd(0.1, 0.0), &cfg, &[(8, 8)]).unwrap();
            assert!(
                stack.label().contains("[refresh staggered]"),
                "key '{key}': {}",
                stack.label()
            );
        }
    }
}

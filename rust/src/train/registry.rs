//! String-keyed optimizer-stack registry.
//!
//! Maps a variant key (`"none"`, `"32bit"`, `"vq"`, `"cq"`, `"cq-ef"`,
//! `"bw8"`, `"ec4"`, `"f16"`, `"cq-r1"`, or anything added via [`register`])
//! to a builder producing an [`OptimizerStack`] for a model's parameter
//! shapes. Coordinator specs, the CLI, and the examples all construct
//! optimizers through [`build`], so a variant registered at startup is
//! immediately reachable from TOML specs and `--shampoo` flags without
//! touching any construction site.
//!
//! Aliases (`"cqef"`, `"ours"`, `"full32"`, …) are resolved through
//! [`ShampooVariant::parse`] — the registry itself stores only canonical
//! keys. The `ec4` / `f16` / `cq-r1` entries have **no** `ShampooVariant`
//! arm at all: their builders route sides and roots through
//! `quant::codec` registry keys, the open-world path any runtime-registered
//! codec can take.
//!
//! ```
//! use quartz::optim::BaseOptimizer;
//! use quartz::shampoo::ShampooConfig;
//!
//! // Any registered key (built-in, alias, or runtime-registered) builds:
//! let cfg = ShampooConfig { t1: 1, t2: 1, max_order: 16, ..Default::default() };
//! for key in ["cq-ef", "ours", "ec4", "f16", "cq-r1"] {
//!     let stack = quartz::train::registry::build(
//!         key,
//!         BaseOptimizer::sgd(0.1, 0.0),
//!         &cfg,
//!         &[(8, 8)],
//!     )
//!     .expect("registered key");
//!     assert!(stack.label().contains("Shampoo"));
//! }
//! assert!(quartz::train::registry::lookup("no-such-key").is_none());
//! ```

use crate::optim::BaseOptimizer;
use crate::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use crate::train::OptimizerStack;
use std::sync::{Mutex, OnceLock};

/// One registry entry.
#[derive(Clone, Copy)]
pub struct StackBuilder {
    /// Canonical key (what [`ShampooVariant::key`] returns, or a new name).
    pub key: &'static str,
    /// One-line description for CLI/docs listings.
    pub summary: &'static str,
    /// Build the stack. `cfg` carries intervals/quantizer settings; builders
    /// for a fixed variant override `cfg.variant` with their own.
    pub build: fn(BaseOptimizer, &ShampooConfig, &[(usize, usize)]) -> OptimizerStack,
    /// Declarative `(side_codec, root_codec)` overrides this builder applies
    /// (`None` = codecs derive from `cfg.variant`). This is the ONE source
    /// of the codec-family mapping: spec resolution copies it onto the run's
    /// `ShampooConfig` so the memory model prices — and labels name —
    /// exactly what will run.
    pub codecs: Option<(&'static str, &'static str)>,
}

/// The codec-family `(side, root)` pairings — shared by the build fns and
/// the registry metadata so they cannot drift.
const EC4_CODECS: (&str, &str) = ("ec4", "ec4");
const F16_CODECS: (&str, &str) = ("f16", "f16");
/// Factored sides + off-diagonal 4-bit roots, mirroring `cq`/`cq-ef`.
const CQ_R1_CODECS: (&str, &str) = ("cq-r1", "vq4");

fn build_none(
    base: BaseOptimizer,
    _cfg: &ShampooConfig,
    _shapes: &[(usize, usize)],
) -> OptimizerStack {
    OptimizerStack::base(base)
}

fn with_variant(
    variant: ShampooVariant,
    base: BaseOptimizer,
    cfg: &ShampooConfig,
    shapes: &[(usize, usize)],
) -> OptimizerStack {
    let cfg = ShampooConfig { variant, ..*cfg };
    OptimizerStack::shampoo(Shampoo::new(base, cfg, shapes))
}

fn build_full32(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Full32, b, c, s)
}

fn build_vq(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Vq4, b, c, s)
}

fn build_cq(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Cq4 { error_feedback: false }, b, c, s)
}

fn build_cq_ef(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Cq4 { error_feedback: true }, b, c, s)
}

fn build_bw8(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_variant(ShampooVariant::Bw8, b, c, s)
}

/// Build a Shampoo stack that routes sides/roots through explicit codec
/// registry keys (the open-world path — no `ShampooVariant` arm exists for
/// these representations; `Optimizer::name` names the codecs instead of the
/// dead variant). Spec resolution applies the same pair up-front, so this
/// is a no-op overwrite on spec-built runs and the safety net for direct
/// `registry::build` callers.
fn with_codecs(
    (side, root): (&'static str, &'static str),
    base: BaseOptimizer,
    cfg: &ShampooConfig,
    shapes: &[(usize, usize)],
) -> OptimizerStack {
    let cfg = ShampooConfig { side_codec: Some(side), root_codec: Some(root), ..*cfg };
    OptimizerStack::shampoo(Shampoo::new(base, cfg, shapes))
}

fn build_ec4(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_codecs(EC4_CODECS, b, c, s)
}

fn build_f16(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_codecs(F16_CODECS, b, c, s)
}

fn build_cq_r1(b: BaseOptimizer, c: &ShampooConfig, s: &[(usize, usize)]) -> OptimizerStack {
    with_codecs(CQ_R1_CODECS, b, c, s)
}

fn builtin_stacks() -> Vec<StackBuilder> {
    vec![
        StackBuilder {
            key: "none",
            summary: "base optimizer alone (no preconditioning)",
            build: build_none,
            codecs: None,
        },
        StackBuilder {
            key: "32bit",
            summary: "f32 Shampoo (Algorithm 2)",
            build: build_full32,
            codecs: None,
        },
        StackBuilder {
            key: "vq",
            summary: "4-bit Shampoo, vanilla quantization (Sec. 4.1)",
            build: build_vq,
            codecs: None,
        },
        StackBuilder {
            key: "cq",
            summary: "4-bit Shampoo, Cholesky quantization (Sec. 4.2)",
            build: build_cq,
            codecs: None,
        },
        StackBuilder {
            key: "cq-ef",
            summary: "4-bit Shampoo, CQ + error feedback (Alg. 1, ours)",
            build: build_cq_ef,
            codecs: None,
        },
        StackBuilder {
            key: "bw8",
            summary: "8-bit Shampoo, block-wise quantization",
            build: build_bw8,
            codecs: None,
        },
        StackBuilder {
            key: "ec4",
            summary: "4-bit Shampoo, eigenvalue-corrected (arXiv 2405.18144)",
            build: build_ec4,
            codecs: Some(EC4_CODECS),
        },
        StackBuilder {
            key: "f16",
            summary: "half-precision Shampoo (memory/accuracy midpoint)",
            build: build_f16,
            codecs: Some(F16_CODECS),
        },
        StackBuilder {
            key: "cq-r1",
            summary: "4-bit Cholesky Shampoo + per-row scale correction",
            build: build_cq_r1,
            codecs: Some(CQ_R1_CODECS),
        },
    ]
}

fn registry() -> &'static Mutex<Vec<StackBuilder>> {
    static REGISTRY: OnceLock<Mutex<Vec<StackBuilder>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(builtin_stacks()))
}

/// Register a stack builder under a new key. Returns `false` (unchanged
/// registry) if the key is taken.
pub fn register(builder: StackBuilder) -> bool {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    if reg.iter().any(|b| b.key == builder.key) {
        return false;
    }
    reg.push(builder);
    true
}

/// Look up a builder by canonical key, then by variant alias.
pub fn lookup(key: &str) -> Option<StackBuilder> {
    let found = {
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.iter().find(|b| b.key == key).copied()
    };
    found.or_else(|| {
        let canonical = ShampooVariant::parse(key)?.key();
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.iter().find(|b| b.key == canonical).copied()
    })
}

/// Build a stack by key (canonical or alias). `cfg.variant` is overridden
/// by keyed builders; other config fields (intervals, quantizer, codec
/// overrides) pass through.
pub fn build(
    key: &str,
    base: BaseOptimizer,
    cfg: &ShampooConfig,
    shapes: &[(usize, usize)],
) -> Option<OptimizerStack> {
    lookup(key).map(|b| (b.build)(base, cfg, shapes))
}

/// All registered canonical keys, built-ins first.
pub fn stack_keys() -> Vec<&'static str> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().map(|b| b.key).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_key_builds() {
        let cfg = ShampooConfig { t1: 1, t2: 1, max_order: 16, ..Default::default() };
        for key in stack_keys() {
            let stack = build(key, BaseOptimizer::sgd(0.1, 0.0), &cfg, &[(8, 8)])
                .unwrap_or_else(|| panic!("key '{key}' must build"));
            if key == "none" {
                assert_eq!(stack.label(), "SGD");
            } else {
                assert!(stack.label().contains("Shampoo"), "{key}: {}", stack.label());
            }
        }
    }

    #[test]
    fn aliases_resolve_via_variant_parse() {
        let cfg = ShampooConfig::default();
        for (alias, canonical) in [("ours", "cq-ef"), ("full32", "32bit"), ("8bit", "bw8")] {
            let a = lookup(alias).unwrap_or_else(|| panic!("alias '{alias}'"));
            assert_eq!(a.key, canonical);
        }
        assert!(build("no-such-stack", BaseOptimizer::sgd(0.1, 0.0), &cfg, &[(4, 4)]).is_none());
    }

    #[test]
    fn builtin_stack_keys_cannot_be_shadowed() {
        let b = lookup("cq-ef").unwrap();
        assert!(!register(b));
    }

    #[test]
    fn codec_family_keys_build_and_name_their_codecs() {
        // `ec4`/`f16`/`cq-r1` have no ShampooVariant arm: the builders set
        // both codec overrides, so the stack name is the codecs themselves —
        // never the placeholder variant's representation.
        let cfg = ShampooConfig { t1: 1, t2: 1, max_order: 16, ..Default::default() };
        for (key, want) in [
            ("ec4", "SGD + ec4 Shampoo"),
            ("f16", "SGD + f16 Shampoo"),
            ("cq-r1", "SGD + cq-r1/vq4 Shampoo"),
        ] {
            let stack = build(key, BaseOptimizer::sgd(0.1, 0.0), &cfg, &[(8, 8)]).unwrap();
            assert_eq!(stack.label(), want, "key '{key}'");
            // The mapping is declarative registry metadata (the one source
            // spec resolution and the parity tests read).
            assert!(lookup(key).unwrap().codecs.is_some(), "key '{key}' must declare codecs");
        }
    }

    #[test]
    fn refresh_policy_flows_through_keyed_builders() {
        // `cfg.refresh_policy` rides the same pass-through as intervals and
        // codec overrides: every keyed Shampoo builder honors it, and the
        // stack label surfaces the non-default schedule.
        let cfg = ShampooConfig {
            t1: 1,
            t2: 2,
            max_order: 16,
            refresh_policy: "staggered",
            ..Default::default()
        };
        for key in ["32bit", "vq", "cq", "cq-ef", "bw8"] {
            let stack = build(key, BaseOptimizer::sgd(0.1, 0.0), &cfg, &[(8, 8)]).unwrap();
            assert!(
                stack.label().contains("[refresh staggered]"),
                "key '{key}': {}",
                stack.label()
            );
        }
    }
}

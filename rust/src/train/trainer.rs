//! Training loops over AOT artifacts (the request path: rust-only).
//!
//! One step = pack params + minibatch into PJRT literals → execute the
//! model's `fwd_bwd` HLO → unpack loss/gradients → optimizer step in rust.

use crate::data::images::ImageDataset;
use crate::data::synthetic::ClusterDataset;
use crate::data::tokens::TokenCorpus;
use crate::linalg::Matrix;
use crate::metrics::scoring::{accuracy, perplexity_from_nll};
use crate::metrics::Stopwatch;
use crate::models::init_params;
use crate::optim::LrSchedule;
use crate::persist::TrainState;
use crate::runtime::literal::{
    literal_to_matrix, literal_to_scalar_f32, literal_to_vec_f32, matrix_to_literal,
    vec_f32_to_literal, vec_i32_to_literal,
};
use crate::runtime::{ModelInfo, Runtime};
use crate::train::OptimizerStack;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;
use std::path::PathBuf;
use std::time::Instant;

/// Unified classifier data view (built from either synthetic dataset).
#[derive(Clone, Debug)]
pub struct ClassifierData {
    pub dim: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<u32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<u32>,
}

impl From<(&ClusterDataset, &ClusterDataset)> for ClassifierData {
    fn from((tr, te): (&ClusterDataset, &ClusterDataset)) -> Self {
        ClassifierData {
            dim: tr.dim,
            classes: tr.classes,
            train_x: tr.features.clone(),
            train_y: tr.labels.clone(),
            test_x: te.features.clone(),
            test_y: te.labels.clone(),
        }
    }
}

impl From<(&ImageDataset, &ImageDataset)> for ClassifierData {
    fn from((tr, te): (&ImageDataset, &ImageDataset)) -> Self {
        ClassifierData {
            dim: tr.dim(),
            classes: tr.classes,
            train_x: tr.pixels.clone(),
            train_y: tr.labels.clone(),
            test_x: te.pixels.clone(),
            test_y: te.labels.clone(),
        }
    }
}

impl ClassifierData {
    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }
}

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub schedule: LrSchedule,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: u64,
    /// Record the loss every `log_every` steps.
    pub log_every: u64,
    pub seed: u64,
    /// Write a checkpoint every `checkpoint_every` steps (0 = never).
    /// Requires `checkpoint_dir`; the final step is never checkpointed.
    pub checkpoint_every: u64,
    /// Where checkpoints live. When set, training first tries to resume
    /// from the newest valid snapshot in this directory.
    pub checkpoint_dir: Option<PathBuf>,
    /// Spec identity hash pinned into every checkpoint
    /// ([`crate::persist::spec_hash`]) — guards against resuming a
    /// different run's state.
    pub spec_hash: u64,
    /// Deterministic fault-injection plan (chaos testing): corrupts chosen
    /// gradients, forces factorization failures inside the optimizer, and
    /// bit-flips chosen checkpoints. `None` (the default) is the guaranteed
    /// bit-identical production path.
    pub faults: Option<crate::util::fault::FaultPlan>,
    /// Retention: after each checkpoint write, delete all but the newest
    /// `keep_checkpoints` snapshots (0 = keep everything).
    pub keep_checkpoints: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            schedule: LrSchedule::Constant,
            eval_every: 0,
            log_every: 10,
            seed: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            spec_hash: 0,
            faults: None,
            keep_checkpoints: 0,
        }
    }
}

/// Everything a table/figure needs from one run.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub model: String,
    pub optimizer: String,
    /// (step, train loss)
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, eval metric) — accuracy (classifier) or PPL (lm)
    pub eval_curve: Vec<(u64, f64)>,
    /// Final eval metric.
    pub final_metric: f64,
    /// Persistent optimizer-state bytes at end of training.
    pub state_bytes: usize,
    /// End-to-end wall-clock seconds.
    pub wall_secs: f64,
    /// Seconds inside the optimizer (the paper's "update time" column).
    pub opt_secs: f64,
    /// Numerical-health counters accumulated by the optimizer's guard
    /// engine (all-zero for optimizers without one, and on healthy runs).
    pub health: crate::metrics::HealthStats,
}

/// What a resumed run inherits: completed steps and time already spent.
pub(crate) struct ResumeBase {
    pub start_step: u64,
    pub wall_secs: f64,
    pub opt_secs: f64,
}

/// Restore the newest valid checkpoint into the freshly built training
/// state, if `cfg` points at a checkpoint directory with one. Everything
/// the step path touches comes back byte-exact: params, the full optimizer
/// payload, the RNG stream position, and the metric curves.
pub(crate) fn resume_or_start(
    cfg: &TrainConfig,
    params: &mut [Matrix],
    opt: &mut OptimizerStack,
    rng: &mut Rng,
    loss_curve: &mut Vec<(u64, f32)>,
    eval_curve: &mut Vec<(u64, f64)>,
) -> Result<ResumeBase> {
    let fresh = ResumeBase { start_step: 0, wall_secs: 0.0, opt_secs: 0.0 };
    let Some(dir) = &cfg.checkpoint_dir else {
        return Ok(fresh);
    };
    let Some(st) = TrainState::load_latest(dir, cfg.spec_hash)? else {
        return Ok(fresh);
    };
    crate::ensure!(
        st.params.len() == params.len(),
        "checkpoint has {} params, model has {}",
        st.params.len(),
        params.len()
    );
    for (p, s) in params.iter_mut().zip(st.params.iter()) {
        crate::ensure!(
            p.rows() == s.rows() && p.cols() == s.cols(),
            "checkpoint param is {}x{}, model wants {}x{}",
            s.rows(),
            s.cols(),
            p.rows(),
            p.cols()
        );
        *p = s.clone();
    }
    let mut r = ByteReader::new(&st.opt);
    opt.restore_state(&mut r).context("restoring optimizer state")?;
    r.finish()?;
    *rng = Rng::from_state(st.rng);
    *loss_curve = st.loss_curve;
    *eval_curve = st.eval_curve;
    Ok(ResumeBase { start_step: st.step, wall_secs: st.wall_secs, opt_secs: st.opt_secs })
}

/// Whether step `k` is a checkpoint step under `cfg` (never the final
/// step — the run's outcome record supersedes a checkpoint there).
pub(crate) fn should_checkpoint(cfg: &TrainConfig, k: u64) -> bool {
    cfg.checkpoint_dir.is_some()
        && cfg.checkpoint_every > 0
        && k % cfg.checkpoint_every == 0
        && k < cfg.steps
}

/// Snapshot the run after step `k` completed (all of step `k`'s RNG draws
/// and the optimizer update have happened, step `k + 1`'s have not).
pub(crate) fn checkpoint_now(
    cfg: &TrainConfig,
    k: u64,
    params: &[Matrix],
    opt: &OptimizerStack,
    rng: &Rng,
    loss_curve: &[(u64, f32)],
    eval_curve: &[(u64, f64)],
    wall_secs: f64,
    opt_secs: f64,
) -> Result<()> {
    let Some(dir) = &cfg.checkpoint_dir else {
        return Ok(());
    };
    let mut w = ByteWriter::new();
    opt.save_state(&mut w)?;
    let st = TrainState {
        step: k,
        params: params.to_vec(),
        opt: w.into_bytes(),
        rng: rng.state(),
        loss_curve: loss_curve.to_vec(),
        eval_curve: eval_curve.to_vec(),
        wall_secs,
        opt_secs,
    };
    let path = st.save(dir, cfg.spec_hash)?;
    // Chaos hook: flip one deterministic bit in the freshly written file —
    // the CRC then rejects it on resume and the newest-valid scan must fall
    // back to the previous snapshot.
    if let Some(fp) = &cfg.faults {
        if fp.flips_checkpoint(k) {
            let mut bytes = std::fs::read(&path)
                .with_context(|| format!("chaos-reading {}", path.display()))?;
            if !bytes.is_empty() {
                let (pos, mask) = fp.flip_position(k, bytes.len());
                bytes[pos] ^= mask;
                std::fs::write(&path, &bytes)
                    .with_context(|| format!("chaos-writing {}", path.display()))?;
            }
        }
    }
    crate::persist::prune_checkpoints(dir, cfg.keep_checkpoints);
    Ok(())
}

/// Train a classifier model on `data`, returning metrics.
///
/// `opt` must have been initialized (or be a Shampoo built with the model's
/// shapes). Parameters are initialized deterministically from `cfg.seed`.
pub fn train_classifier(
    rt: &Runtime,
    model: &ModelInfo,
    data: &ClassifierData,
    mut opt: OptimizerStack,
    cfg: &TrainConfig,
) -> Result<RunMetrics> {
    crate::ensure!(model.kind == "classifier", "{} is not a classifier", model.name);
    crate::ensure!(
        data.dim == model.meta_usize("dim").unwrap_or(0),
        "data dim {} != model dim {:?}",
        data.dim,
        model.meta_usize("dim")
    );
    let fwd_bwd = format!("{}.fwd_bwd", model.name);
    let batch = model.batch;
    let mut params = init_params(model, cfg.seed);
    opt.init(params.len());
    opt.set_fault_plan(cfg.faults.as_ref());

    let mut opt_time = Stopwatch::new();
    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();

    let mut rng = Rng::new(cfg.seed ^ 0xBA7C);
    let base =
        resume_or_start(cfg, &mut params, &mut opt, &mut rng, &mut loss_curve, &mut eval_curve)?;
    let run_start = Instant::now();
    let n = data.n_train();
    for k in base.start_step + 1..=cfg.steps {
        // Sample a batch (with replacement — stream-style).
        let idx: Vec<usize> = (0..batch).map(|_| rng.below(n)).collect();
        let mut x = Vec::with_capacity(batch * data.dim);
        let mut y = Vec::with_capacity(batch);
        for &i in &idx {
            x.extend_from_slice(&data.train_x[i * data.dim..(i + 1) * data.dim]);
            y.push(data.train_y[i] as i32);
        }

        let mut inputs = Vec::with_capacity(params.len() + 2);
        for p in &params {
            inputs.push(matrix_to_literal(p)?);
        }
        inputs.push(vec_f32_to_literal(&x, &[batch, data.dim])?);
        inputs.push(vec_i32_to_literal(&y, &[batch])?);

        let outputs = rt.execute(&fwd_bwd, &inputs).context("fwd_bwd execution")?;
        let loss = literal_to_scalar_f32(&outputs[0])?;
        let mut grads: Vec<Matrix> = outputs[1..]
            .iter()
            .zip(params.iter())
            .map(|(l, p)| literal_to_matrix(l, p.rows(), p.cols()))
            .collect::<Result<_>>()?;
        if let Some(fp) = &cfg.faults {
            fp.corrupt_grads(k, &mut grads);
        }

        let lr_scale = cfg.schedule.scale(k - 1);
        opt_time.time(|| opt.step(&mut params, &grads, k, lr_scale));

        if k % cfg.log_every.max(1) == 0 || k == 1 {
            loss_curve.push((k, loss));
        }
        if cfg.eval_every > 0 && k % cfg.eval_every == 0 {
            let acc = eval_classifier(rt, model, data, &params)?;
            eval_curve.push((k, acc));
        }
        if should_checkpoint(cfg, k) {
            checkpoint_now(
                cfg,
                k,
                &params,
                &opt,
                &rng,
                &loss_curve,
                &eval_curve,
                base.wall_secs + run_start.elapsed().as_secs_f64(),
                base.opt_secs + opt_time.total_secs(),
            )?;
        }
    }
    let final_acc = eval_classifier(rt, model, data, &params)?;
    eval_curve.push((cfg.steps, final_acc));

    Ok(RunMetrics {
        model: model.name.clone(),
        optimizer: opt.label(),
        loss_curve,
        eval_curve,
        final_metric: final_acc,
        state_bytes: opt.state_bytes(),
        wall_secs: base.wall_secs + run_start.elapsed().as_secs_f64(),
        opt_secs: base.opt_secs + opt_time.total_secs(),
        health: opt.health_stats(),
    })
}

/// Test-set accuracy through the model's `eval` artifact.
pub fn eval_classifier(
    rt: &Runtime,
    model: &ModelInfo,
    data: &ClassifierData,
    params: &[Matrix],
) -> Result<f64> {
    let eval_name = format!("{}.eval", model.name);
    let batch = model.batch;
    let n_test = data.test_y.len();
    let mut correct_weighted = 0.0f64;
    let mut counted = 0usize;
    let mut start = 0usize;
    while start + batch <= n_test {
        let x = &data.test_x[start * data.dim..(start + batch) * data.dim];
        let mut inputs = Vec::with_capacity(params.len() + 1);
        for p in params {
            inputs.push(matrix_to_literal(p)?);
        }
        inputs.push(vec_f32_to_literal(x, &[batch, data.dim])?);
        let out = rt.execute(&eval_name, &inputs)?;
        let logits = literal_to_vec_f32(&out[0])?;
        let labels = &data.test_y[start..start + batch];
        correct_weighted += accuracy(&logits, data.classes, labels) * batch as f64;
        counted += batch;
        start += batch;
    }
    crate::ensure!(counted > 0, "test set smaller than one batch");
    Ok(correct_weighted / counted as f64)
}

/// Train an LM on a token corpus; final metric is held-out perplexity.
pub fn train_lm(
    rt: &Runtime,
    model: &ModelInfo,
    corpus: &TokenCorpus,
    mut opt: OptimizerStack,
    cfg: &TrainConfig,
) -> Result<RunMetrics> {
    crate::ensure!(model.kind == "lm", "{} is not an lm", model.name);
    let seq = model.meta_usize("seq").context("lm needs seq")?;
    let batch = model.batch;
    let fwd_bwd = format!("{}.fwd_bwd", model.name);
    let mut params = init_params(model, cfg.seed);
    opt.init(params.len());
    opt.set_fault_plan(cfg.faults.as_ref());

    // Hold out the corpus tail for eval.
    let split = corpus.tokens.len() * 9 / 10;
    let train = TokenCorpus { vocab: corpus.vocab, tokens: corpus.tokens[..split].to_vec() };
    let heldout = TokenCorpus { vocab: corpus.vocab, tokens: corpus.tokens[split..].to_vec() };

    let mut opt_time = Stopwatch::new();
    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();

    let mut rng = Rng::new(cfg.seed ^ 0x7E57);
    let base =
        resume_or_start(cfg, &mut params, &mut opt, &mut rng, &mut loss_curve, &mut eval_curve)?;
    let run_start = Instant::now();
    for k in base.start_step + 1..=cfg.steps {
        let (x, y) = train.sample_batch(batch, seq, &mut rng);
        let xi: Vec<i32> = x.iter().map(|&t| t as i32).collect();
        let yi: Vec<i32> = y.iter().map(|&t| t as i32).collect();

        let mut inputs = Vec::with_capacity(params.len() + 2);
        for p in &params {
            inputs.push(matrix_to_literal(p)?);
        }
        inputs.push(vec_i32_to_literal(&xi, &[batch, seq])?);
        inputs.push(vec_i32_to_literal(&yi, &[batch, seq])?);

        let outputs = rt.execute(&fwd_bwd, &inputs)?;
        let loss = literal_to_scalar_f32(&outputs[0])?;
        let mut grads: Vec<Matrix> = outputs[1..]
            .iter()
            .zip(params.iter())
            .map(|(l, p)| literal_to_matrix(l, p.rows(), p.cols()))
            .collect::<Result<_>>()?;
        if let Some(fp) = &cfg.faults {
            fp.corrupt_grads(k, &mut grads);
        }

        let lr_scale = cfg.schedule.scale(k - 1);
        opt_time.time(|| opt.step(&mut params, &grads, k, lr_scale));

        if k % cfg.log_every.max(1) == 0 || k == 1 {
            loss_curve.push((k, loss));
        }
        if cfg.eval_every > 0 && k % cfg.eval_every == 0 {
            eval_curve.push((k, eval_lm(rt, model, &heldout, &params, cfg.seed)?));
        }
        if should_checkpoint(cfg, k) {
            checkpoint_now(
                cfg,
                k,
                &params,
                &opt,
                &rng,
                &loss_curve,
                &eval_curve,
                base.wall_secs + run_start.elapsed().as_secs_f64(),
                base.opt_secs + opt_time.total_secs(),
            )?;
        }
    }
    let ppl = eval_lm(rt, model, &heldout, &params, cfg.seed)?;
    eval_curve.push((cfg.steps, ppl));

    Ok(RunMetrics {
        model: model.name.clone(),
        optimizer: opt.label(),
        loss_curve,
        eval_curve,
        final_metric: ppl,
        state_bytes: opt.state_bytes(),
        wall_secs: base.wall_secs + run_start.elapsed().as_secs_f64(),
        opt_secs: base.opt_secs + opt_time.total_secs(),
        health: opt.health_stats(),
    })
}

/// Held-out perplexity via the `eval` artifact (mean NLL over fixed batches).
pub fn eval_lm(
    rt: &Runtime,
    model: &ModelInfo,
    heldout: &TokenCorpus,
    params: &[Matrix],
    seed: u64,
) -> Result<f64> {
    let seq = model.meta_usize("seq").context("lm needs seq")?;
    let batch = model.batch;
    let eval_name = format!("{}.eval", model.name);
    let mut rng = crate::util::rng::Rng::new(seed ^ 0xEAE1);
    let mut nll_sum = 0.0f64;
    let eval_batches = 8;
    for _ in 0..eval_batches {
        let (x, y) = heldout.sample_batch(batch, seq, &mut rng);
        let xi: Vec<i32> = x.iter().map(|&t| t as i32).collect();
        let yi: Vec<i32> = y.iter().map(|&t| t as i32).collect();
        let mut inputs = Vec::with_capacity(params.len() + 2);
        for p in params {
            inputs.push(matrix_to_literal(p)?);
        }
        inputs.push(vec_i32_to_literal(&xi, &[batch, seq])?);
        inputs.push(vec_i32_to_literal(&yi, &[batch, seq])?);
        let out = rt.execute(&eval_name, &inputs)?;
        nll_sum += literal_to_scalar_f32(&out[0])? as f64;
    }
    Ok(perplexity_from_nll(nll_sum / eval_batches as f64))
}

//! Spectral-error analysis (paper Sec. 4.2, Eq. (9); Tabs. 1, 9, 10).
//!
//! NRE / AE measure how much quantization perturbs the inverse-4th-root of
//! a preconditioner. Cholesky quantization wins because `D(C̄)·D(C̄)ᵀ` is
//! symmetric PSD by construction while direct quantization can break
//! positive-definiteness (Tab. 9's negative eigenvalue).

use crate::linalg::{
    angle_between, cholesky_jittered, eig_sym, inverse_pth_root_eig_planned, matmul_nt,
    relative_error, Matrix, MatmulPlan,
};
use crate::quant::{BlockQuantizer, TriJointStore};
use crate::util::rng::Rng;

/// Random synthetic PD matrix (App. C.2): `A = U·Λ·Uᵀ` with `U` orthogonal
/// (eigenvectors of a random symmetric matrix) and `Λ` geometric from
/// `lo` to `hi` — a deliberately ill-conditioned spectrum.
pub fn synthetic_pd(n: usize, lo: f32, hi: f32, rng: &mut Rng) -> Matrix {
    let g = Matrix::randn(n, n, 1.0, rng);
    let (_, u) = eig_sym(&crate::linalg::syrk(&g), 1e-10, 100);
    let mut a = Matrix::zeros(n, n);
    for k in 0..n {
        let t = if n > 1 { k as f32 / (n - 1) as f32 } else { 0.0 };
        let lam = lo * (hi / lo).powf(t);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] += lam * u[(i, k)] * u[(j, k)];
            }
        }
    }
    a.symmetrize();
    a
}

/// Vanilla quantization round-trip `g(A) = D(Q(A))` (full matrix, as in the
/// paper's Tab. 1/9 analysis).
pub fn vq_roundtrip(a: &Matrix, q: &BlockQuantizer) -> Matrix {
    q.roundtrip(a)
}

/// Cholesky quantization round-trip: factor, quantize the factor
/// (off-diagonal 4-bit, f32 diagonal), reconstruct `D(C̄)·D(C̄)ᵀ`.
pub fn cq_roundtrip(a: &Matrix, eps: f32, q: &BlockQuantizer) -> Matrix {
    let (c, _) = cholesky_jittered(a, eps, 12).expect("PD input");
    let store = TriJointStore::store(&c, &Matrix::zeros(a.rows(), a.cols()), q);
    let (c_back, _) = store.load(q);
    matmul_nt(&c_back, &c_back)
}

/// The paper's Eq. (9) metrics on inverse-4th-roots:
/// `NRE = ‖A^{-1/4} − g(A)^{-1/4}‖_F / ‖A^{-1/4}‖_F`, `AE` in degrees.
/// Near-singular (or quantization-broken) eigenvalues are clamped at
/// `1e-12` so a PD violation shows up as a *large* error, as in the paper.
pub fn nre_ae(a: &Matrix, ga: &Matrix) -> (f64, f64) {
    nre_ae_planned(a, ga, &mut MatmulPlan::new())
}

/// [`nre_ae`] with a caller-owned matmul plan (the sweep loops reuse one
/// packed-B buffer across every root instead of allocating per call).
pub fn nre_ae_planned(a: &Matrix, ga: &Matrix, plan: &mut MatmulPlan) -> (f64, f64) {
    let ra = inverse_pth_root_eig_planned(a, 4.0, 1e-12, plan);
    let rg = inverse_pth_root_eig_planned(ga, 4.0, 1e-12, plan);
    (relative_error(&ra, &rg), angle_between(&ra, &rg))
}

/// Cumulative NRE/AE over a set of matrices (the paper reports cumulative
/// errors over all preconditioners, App. C.2).
pub fn cumulative_nre_ae(mats: &[Matrix], g: impl Fn(&Matrix) -> Matrix) -> (f64, f64) {
    let mut nre = 0.0;
    let mut ae = 0.0;
    let mut plan = MatmulPlan::new();
    for a in mats {
        let (n, e) = nre_ae_planned(a, &g(a), &mut plan);
        nre += n;
        ae += e;
    }
    (nre, ae)
}

/// Smallest eigenvalue (for PD checks / Fig. 3).
pub fn min_eigenvalue(a: &Matrix) -> f32 {
    let (vals, _) = eig_sym(a, 1e-11, 100);
    vals[0]
}

/// All eigenvalues (Fig. 3 histograms).
pub fn eigenvalues(a: &Matrix) -> Vec<f32> {
    eig_sym(a, 1e-11, 100).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantConfig;

    fn quantizer() -> BlockQuantizer {
        BlockQuantizer::new(QuantConfig { block: 64, min_quant_elems: 0, ..Default::default() })
    }

    #[test]
    fn synthetic_pd_spectrum() {
        let mut rng = Rng::new(1);
        let a = synthetic_pd(16, 1e-3, 1e3, &mut rng);
        let (vals, _) = eig_sym(&a, 1e-10, 100);
        assert!(vals[0] > 0.0, "PD");
        assert!((vals[0] - 1e-3).abs() / 1e-3 < 0.1, "λmin={}", vals[0]);
        assert!((vals[15] - 1e3).abs() / 1e3 < 0.1, "λmax={}", vals[15]);
    }

    /// The paper's core claim (Tab. 1): CQ NRE/AE ≪ VQ NRE/AE on
    /// ill-conditioned matrices.
    #[test]
    fn cq_beats_vq_on_ill_conditioned() {
        let mut rng = Rng::new(2);
        let q = quantizer();
        let mats: Vec<Matrix> = (0..5).map(|_| synthetic_pd(24, 1e-3, 1e3, &mut rng)).collect();
        let (nre_vq, ae_vq) = cumulative_nre_ae(&mats, |a| vq_roundtrip(a, &q));
        let (nre_cq, ae_cq) = cumulative_nre_ae(&mats, |a| cq_roundtrip(a, 1e-6, &q));
        assert!(
            nre_cq < nre_vq * 0.6,
            "CQ must preserve spectra better: vq={nre_vq:.2} cq={nre_cq:.2}"
        );
        assert!(ae_cq < ae_vq, "ae: vq={ae_vq:.2} cq={ae_cq:.2}");
    }

    /// Tab. 9 toy example: the paper's exact 2×2 matrix.
    #[test]
    fn toy_matrix_vq_breaks_pd_cq_does_not() {
        let q =
            BlockQuantizer::new(QuantConfig { block: 2, min_quant_elems: 0, ..Default::default() });
        let l = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0]]);
        let (orig_vals, _) = eig_sym(&l, 1e-12, 100);
        assert!((orig_vals[1] - 10.908).abs() < 1e-2);

        let vq = vq_roundtrip(&l, &q);
        let (vq_vals, _) = eig_sym(&vq, 1e-12, 100);
        let cq = cq_roundtrip(&l, 1e-6, &q);
        let (cq_vals, _) = eig_sym(&cq, 1e-12, 100);

        // CQ reconstruction is PSD by construction; the paper's VQ toy
        // example produces λmin < 0 while CQ stays close to (10.908, 0.092).
        assert!(cq_vals[0] >= 0.0, "cq λmin={}", cq_vals[0]);
        assert!(vq_vals[0] < cq_vals[0], "vq λmin {} vs cq {}", vq_vals[0], cq_vals[0]);
        assert!((cq_vals[1] - 10.908).abs() < 1.0, "cq λmax={}", cq_vals[1]);
    }

    #[test]
    fn nre_zero_for_identity_transform() {
        let mut rng = Rng::new(3);
        let a = synthetic_pd(8, 0.1, 10.0, &mut rng);
        let (nre, ae) = nre_ae(&a, &a);
        assert!(nre < 1e-5 && ae < 1e-3);
    }
}

//! Figure harnesses: CSV series + summary tables for the paper's plots.

use super::harvest::train_with_snapshots;
use super::spectral::eigenvalues;
use super::tables::scaled_shampoo;
use crate::coordinator::runner::run_all;
use crate::coordinator::spec::{OptimizerSpec, RunSpec, Workload};
use crate::data::images::ImageSpec;
use crate::data::synthetic::ClusterSpec;
use crate::optim::{BaseOptimizer, OptimizerKind};
use crate::report::table::{mb, pct, Table};
use crate::runtime::Runtime;
use crate::shampoo::{ShampooConfig, ShampooVariant};
use crate::bail;
use crate::train::ClassifierData;
use crate::util::csv::CsvWriter;
use crate::util::error::Result;
use crate::util::stats::Histogram;
use std::path::Path;

fn steps(full: u64, quick: bool) -> u64 {
    if quick {
        (full / 5).max(20)
    } else {
        full
    }
}

fn cluster(classes: usize, seed: u64) -> Workload {
    Workload::Cluster(ClusterSpec { classes, dim: 64, seed, ..Default::default() })
}

fn workload_for(model: &str, classes: usize, seed: u64) -> Workload {
    if model.starts_with("vit") || model.starts_with("swin") {
        Workload::Image(ImageSpec { side: 8, classes, seed, noise: 0.5, ..Default::default() })
    } else {
        cluster(classes, seed)
    }
}

/// Fig. 1 — accuracy vs optimizer-state memory scatter (ResNet analog).
pub fn fig1(quick: bool, out_dir: &Path) -> Result<Table> {
    let (_, outcomes) = super::tables::tab3(quick)?;
    let mut w = CsvWriter::create(&out_dir.join("fig1.csv"), &["optimizer", "accuracy", "mem_mb"])?;
    let mut t = Table::new(
        "Fig 1 — accuracy vs optimizer-state memory (ResNet analog)",
        &["Optimizer", "Accuracy (%)", "Opt-State (MB)"],
    );
    for o in outcomes.iter().filter(|o| o.model == "res_mlp_c32") {
        if let Some(m) = &o.metrics {
            w.row(&[
                o.optimizer.clone(),
                format!("{:.4}", m.final_metric),
                mb(m.state_bytes),
            ])?;
            t.row(vec![o.optimizer.clone(), pct(m.final_metric), mb(m.state_bytes)]);
        }
    }
    w.flush()?;
    Ok(t)
}

/// Fig. 3 — eigenvalue histograms of dequantized `D(L̂)`, `D(R̂)` across
/// training checkpoints; asserts positivity (Assumption 5.1c evidence).
pub fn fig3(rt: &Runtime, quick: bool, out_dir: &Path) -> Result<Table> {
    let total = steps(200, quick);
    let spec = ClusterSpec { classes: 32, dim: 64, seed: 31, ..Default::default() };
    let (tr, te) = crate::data::synthetic::ClusterDataset::generate(&spec);
    let data = ClassifierData::from((&tr, &te));
    let snaps = train_with_snapshots(
        rt,
        "mlp_vgg_c32",
        &data,
        BaseOptimizer::sgdm(0.05, 0.9, 5e-4),
        ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            t1: 5,
            t2: 20,
            max_order: 96,
            ..Default::default()
        },
        total,
        4,
        31,
    )?;

    let mut w = CsvWriter::create(
        &out_dir.join("fig3.csv"),
        &["checkpoint_step", "bin_center", "count"],
    )?;
    let mut t = Table::new(
        "Fig 3 — eigenvalues of dequantized preconditioner roots D(L̂), D(R̂)",
        &["Checkpoint", "# eigenvalues", "min λ", "max λ", "all > 0"],
    );
    for snap in &snaps {
        let mut all = Vec::new();
        for (l, r) in &snap.inv_roots {
            all.extend(eigenvalues(l));
            all.extend(eigenvalues(r));
        }
        let min = all.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = all.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut hist = Histogram::new(0.0, max as f64 * 1.01, 40);
        for &v in &all {
            hist.add(v as f64);
        }
        for (center, count) in hist.rows() {
            w.row(&[format!("{}", snap.step), format!("{center:.5}"), format!("{count}")])?;
        }
        t.row(vec![
            format!("step {}", snap.step),
            format!("{}", all.len()),
            format!("{min:.5}"),
            format!("{max:.4}"),
            format!("{}", min > 0.0),
        ]);
    }
    w.flush()?;
    Ok(t)
}

/// Fig. 4 — training-loss and eval-accuracy curves across optimizers for
/// two workloads (ResNet analog + ViT analog).
pub fn fig4(quick: bool, out_dir: &Path) -> Result<Table> {
    let total = steps(400, quick);
    let jobs = [
        ("res_mlp_c32", OptimizerKind::Sgdm, 32usize),
        ("vit_lite_c64", OptimizerKind::AdamW, 64usize),
    ];
    let mut specs = Vec::new();
    for (model, base, classes) in jobs {
        let hyper = OptimizerSpec::paper_hyper(base);
        specs.push(RunSpec::new(
            model,
            workload_for(model, classes, 41),
            OptimizerSpec::base_only(base, hyper),
            total,
        ));
        for variant in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: true },
        ] {
            specs.push(RunSpec::new(
                model,
                workload_for(model, classes, 41),
                OptimizerSpec::with_shampoo(base, hyper, scaled_shampoo(variant)),
                total,
            ));
        }
    }
    for s in specs.iter_mut() {
        s.eval_every = (total / 8).max(1);
        s.log_every = (total / 40).max(1);
    }
    let outcomes = run_all(&specs, crate::util::pool::default_threads().min(8));

    let mut w = CsvWriter::create(
        &out_dir.join("fig4.csv"),
        &["model", "optimizer", "series", "step", "value"],
    )?;
    let mut t = Table::new(
        "Fig 4 — loss / accuracy curves (series dumped to fig4.csv)",
        &["Model", "Optimizer", "final loss", "final acc (%)"],
    );
    for o in &outcomes {
        let Some(m) = &o.metrics else { continue };
        for (step, loss) in &m.loss_curve {
            let (model, opt) = (o.model.clone(), o.optimizer.clone());
            w.row(&[model, opt, "loss".into(), format!("{step}"), format!("{loss}")])?;
        }
        for (step, acc) in &m.eval_curve {
            let (model, opt) = (o.model.clone(), o.optimizer.clone());
            w.row(&[model, opt, "acc".into(), format!("{step}"), format!("{acc}")])?;
        }
        t.row(vec![
            o.model.clone(),
            o.optimizer.clone(),
            format!("{:.3}", m.loss_curve.last().map(|x| x.1).unwrap_or(f32::NAN)),
            pct(m.final_metric),
        ]);
    }
    w.flush()?;
    Ok(t)
}

/// Dispatch by figure id.
pub fn run_figure(id: &str, quick: bool, out_dir: &Path) -> Result<()> {
    let table = match id {
        "fig1" => fig1(quick, out_dir)?,
        "fig3" => {
            let rt = Runtime::open_default()?;
            fig3(&rt, quick, out_dir)?
        }
        "fig4" => fig4(quick, out_dir)?,
        "all" => {
            for id in ["fig1", "fig3", "fig4"] {
                run_figure(id, quick, out_dir)?;
            }
            return Ok(());
        }
        _ => bail!(
            "unknown figure id '{id}' (fig1, fig3, fig4, all; fig2 is demonstrated by \
             `quartz quant-demo` and the tri_store tests)"
        ),
    };
    table.print();
    Ok(())
}

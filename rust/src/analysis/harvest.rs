//! Checkpointed Shampoo training: trains a classifier with full access to
//! optimizer internals, snapshotting reconstructed preconditioners and
//! dequantized inverse roots at fixed fractions of training — the data
//! source behind Tab. 1/10 ("Epoch N" rows) and Fig. 3's histograms.

use crate::linalg::Matrix;
use crate::models::init_params;
use crate::optim::BaseOptimizer;
use crate::runtime::literal::{
    literal_to_matrix, literal_to_scalar_f32, matrix_to_literal, vec_f32_to_literal,
    vec_i32_to_literal,
};
use crate::runtime::Runtime;
use crate::shampoo::{Shampoo, ShampooConfig};
use crate::train::ClassifierData;
use crate::util::error::{Context, Result};

/// One training checkpoint's optimizer internals.
pub struct Snapshot {
    pub step: u64,
    /// Reconstructed `(L, R)` per layer-block (quantization round-tripped).
    pub preconds: Vec<(Matrix, Matrix)>,
    /// Dequantized `(D(L̂), D(R̂))` per layer-block.
    pub inv_roots: Vec<(Matrix, Matrix)>,
    pub loss: f32,
}

/// Train `model` with Shampoo and snapshot at `n_snapshots` evenly spaced
/// steps (the paper's "Epoch 50/100/150/200" checkpoints).
pub fn train_with_snapshots(
    rt: &Runtime,
    model_name: &str,
    data: &ClassifierData,
    base: BaseOptimizer,
    cfg: ShampooConfig,
    steps: u64,
    n_snapshots: usize,
    seed: u64,
) -> Result<Vec<Snapshot>> {
    let model = rt
        .manifest
        .models
        .get(model_name)
        .with_context(|| format!("unknown model {model_name}"))?
        .clone();
    let fwd_bwd = format!("{}.fwd_bwd", model.name);
    let batch = model.batch;
    let mut params = init_params(&model, seed);
    let mut sh = Shampoo::new(base, cfg, &model.shapes());

    let snap_steps: Vec<u64> = (1..=n_snapshots)
        .map(|i| (steps * i as u64) / n_snapshots as u64)
        .collect();
    let mut snapshots = Vec::new();

    let mut rng = crate::util::rng::Rng::new(seed ^ 0x5A4);
    let n = data.n_train();
    for k in 1..=steps {
        let idx: Vec<usize> = (0..batch).map(|_| rng.below(n)).collect();
        let mut x = Vec::with_capacity(batch * data.dim);
        let mut y = Vec::with_capacity(batch);
        for &i in &idx {
            x.extend_from_slice(&data.train_x[i * data.dim..(i + 1) * data.dim]);
            y.push(data.train_y[i] as i32);
        }
        let mut inputs = Vec::with_capacity(params.len() + 2);
        for p in &params {
            inputs.push(matrix_to_literal(p)?);
        }
        inputs.push(vec_f32_to_literal(&x, &[batch, data.dim])?);
        inputs.push(vec_i32_to_literal(&y, &[batch])?);
        let outputs = rt.execute(&fwd_bwd, &inputs)?;
        let loss = literal_to_scalar_f32(&outputs[0])?;
        let grads: Vec<Matrix> = outputs[1..]
            .iter()
            .zip(params.iter())
            .map(|(l, p)| literal_to_matrix(l, p.rows(), p.cols()))
            .collect::<Result<_>>()?;
        sh.step(&mut params, &grads, k, 1.0);

        if snap_steps.contains(&k) {
            let mut preconds = Vec::new();
            let mut inv_roots = Vec::new();
            for li in 0..sh.layers.len() {
                preconds.extend(sh.reconstructed_preconditioners(li));
                inv_roots.extend(sh.dequant_inv_roots(li));
            }
            snapshots.push(Snapshot { step: k, preconds, inv_roots, loss });
        }
    }
    Ok(snapshots)
}

//! Paper-experiment harnesses: one entry point per table and figure
//! (DESIGN.md §3 maps each to the paper).

pub mod harvest;
pub mod spectral;
pub mod tables;
pub mod figures;

pub use spectral::{cq_roundtrip, nre_ae, synthetic_pd, vq_roundtrip};

//! One harness per paper table (DESIGN.md §3). Each prints paper-style
//! rows and writes a CSV under the output directory.
//!
//! Scale note: the analogs train for a few hundred steps on synthetic data
//! (substitution table, DESIGN.md §4); the tables therefore reproduce the
//! paper's *orderings and ratios* — who wins, how memory ranks — not its
//! absolute ImageNet numbers.

use super::harvest::train_with_snapshots;
use super::spectral::{cq_roundtrip, cumulative_nre_ae, synthetic_pd, vq_roundtrip};
use crate::coordinator::runner::{run_all, RunOutcome};
use crate::coordinator::spec::{OptimizerSpec, RunSpec, Workload};
use crate::data::images::ImageSpec;
use crate::data::synthetic::{ClusterDataset, ClusterSpec};
use crate::data::tokens::CorpusSpec;
use crate::linalg::{eig_sym, Matrix};
use crate::metrics::MemoryModel;
use crate::optim::{BaseOptimizer, OptimizerKind};
use crate::quant::{BlockQuantizer, QuantConfig};
use crate::report::table::{mb, pct, secs, Table};
use crate::runtime::Runtime;
use crate::shampoo::{ShampooConfig, ShampooVariant};
use crate::bail;
use crate::train::ClassifierData;
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::path::Path;

/// Shampoo intervals scaled from the paper's T1=100/T2=500-over-78k-steps
/// to our few-hundred-step analogs.
pub fn scaled_shampoo(variant: ShampooVariant) -> ShampooConfig {
    ShampooConfig {
        variant,
        t1: 10,
        t2: 50,
        max_order: 96,
        ..Default::default()
    }
}

fn steps(full: u64, quick: bool) -> u64 {
    if quick {
        (full / 5).max(20)
    } else {
        full
    }
}

fn workers() -> usize {
    crate::util::pool::default_threads().min(8)
}

/// Default classifier workload (dim 64 matches every classifier analog).
fn cluster(classes: usize, seed: u64) -> Workload {
    Workload::Cluster(ClusterSpec { classes, dim: 64, seed, ..Default::default() })
}

/// Attention models (ViT/Swin analogs) train on patterned 8×8 images —
/// cluster vectors have no patch structure for attention to exploit.
fn workload_for(model: &str, classes: usize, seed: u64) -> Workload {
    if model.starts_with("vit") || model.starts_with("swin") {
        Workload::Image(ImageSpec { side: 8, classes, seed, noise: 0.5, ..Default::default() })
    } else {
        cluster(classes, seed)
    }
}

fn mem_cell(o: &RunOutcome) -> String {
    match &o.metrics {
        Some(m) => mb(m.state_bytes),
        None => mb(o.modeled_bytes),
    }
}

fn acc_cell(o: &RunOutcome) -> String {
    match (&o.metrics, &o.error) {
        (Some(m), _) => pct(m.final_metric),
        (None, Some(e)) => format!("ERR: {}", e.lines().next().unwrap_or("?")),
        (None, None) => "OOM".to_string(),
    }
}

/// The 5-row optimizer column of Tabs. 3: base, 32-bit, VQ, CQ, CQ+EF.
fn five_variants(base: OptimizerKind) -> Vec<OptimizerSpec> {
    let hyper = OptimizerSpec::paper_hyper(base);
    let mut v = vec![OptimizerSpec::base_only(base, hyper)];
    for variant in [
        ShampooVariant::Full32,
        ShampooVariant::Vq4,
        ShampooVariant::Cq4 { error_feedback: false },
        ShampooVariant::Cq4 { error_feedback: true },
    ] {
        v.push(OptimizerSpec::with_shampoo(base, hyper, scaled_shampoo(variant)));
    }
    v
}

/// Tab. 1 / Tab. 10 — NRE and AE of VQ vs CQ on synthetic + harvested
/// preconditioners.
pub fn tab_nre_ae(rt: &Runtime, model_name: &str, quick: bool, title: &str) -> Result<Table> {
    let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
    let mut t = Table::new(title, &["Source", "VQ NRE", "VQ AE", "CQ NRE", "CQ AE"]);

    // Synthetic row (App. C.2: 100 matrices, spectrum 1e-3…1e3).
    let n_mats = if quick { 10 } else { 100 };
    let dim = 64;
    let mut rng = Rng::new(0xAB);
    let mats: Vec<Matrix> = (0..n_mats).map(|_| synthetic_pd(dim, 1e-3, 1e3, &mut rng)).collect();
    let (vq_nre, vq_ae) = cumulative_nre_ae(&mats, |a| vq_roundtrip(a, &q));
    let (cq_nre, cq_ae) = cumulative_nre_ae(&mats, |a| cq_roundtrip(a, 1e-6, &q));
    t.row(vec![
        "Synthetic".into(),
        format!("{vq_nre:.3}"),
        format!("{vq_ae:.3}"),
        format!("{cq_nre:.3}"),
        format!("{cq_ae:.3}"),
    ]);

    // Harvested rows: 32-bit Shampoo training checkpoints (the paper's
    // "Epoch 50/100/150/200").
    let total = steps(200, quick);
    let spec = ClusterSpec { classes: 32, dim: 64, seed: 17, ..Default::default() };
    let (tr, te) = ClusterDataset::generate(&spec);
    let data = ClassifierData::from((&tr, &te));
    let snaps = train_with_snapshots(
        rt,
        model_name,
        &data,
        BaseOptimizer::sgdm(0.05, 0.9, 5e-4),
        ShampooConfig {
            variant: ShampooVariant::Full32,
            t1: 5,
            t2: 20,
            max_order: 96,
            ..Default::default()
        },
        total,
        4,
        17,
    )?;
    for snap in &snaps {
        let mut mats = Vec::new();
        for (l, r) in &snap.preconds {
            mats.push(l.clone());
            mats.push(r.clone());
        }
        let (vq_nre, vq_ae) = cumulative_nre_ae(&mats, |a| vq_roundtrip(a, &q));
        let (cq_nre, cq_ae) = cumulative_nre_ae(&mats, |a| cq_roundtrip(a, 1e-6, &q));
        t.row(vec![
            format!("Step {}", snap.step),
            format!("{vq_nre:.3}"),
            format!("{vq_ae:.3}"),
            format!("{cq_nre:.3}"),
            format!("{cq_ae:.3}"),
        ]);
    }
    Ok(t)
}

/// Tab. 2 — off-diagonal vs original block-wise quantization.
pub fn tab2(quick: bool) -> Result<Table> {
    let total = steps(400, quick);
    let mut specs = Vec::new();
    for (model, base, classes) in
        [("mlp_vgg_c32", OptimizerKind::Sgdm, 32), ("swin_lite_c32", OptimizerKind::AdamW, 32)]
    {
        for quantize_diag in [true, false] {
            let mut cfg = scaled_shampoo(ShampooVariant::Vq4);
            cfg.vq_quantize_diag = quantize_diag;
            let opt =
                OptimizerSpec::with_shampoo(base, OptimizerSpec::paper_hyper(base), cfg);
            let mut run = RunSpec::new(model, workload_for(model, classes, 2), opt, total);
            run.id = format!(
                "{model}/{}",
                if quantize_diag { "Original" } else { "Off-Diagonal" }
            );
            specs.push(run);
        }
    }
    let outcomes = run_all(&specs, workers());
    let mut t = Table::new(
        "Tab 2 — off-diagonal vs original block-wise quantization (vanilla 4-bit Shampoo)",
        &["Model", "Quantization", "Accuracy (%)", "Opt-State (MB)"],
    );
    for (spec, o) in specs.iter().zip(outcomes.iter()) {
        let (model, kind) = spec.id.split_once('/').unwrap();
        t.row(vec![model.into(), kind.into(), acc_cell(o), mem_cell(o)]);
    }
    Ok(t)
}

/// Tab. 3 — CIFAR-100 analog grid (4 models × 5 optimizers).
pub fn tab3(quick: bool) -> Result<(Table, Vec<RunOutcome>)> {
    let total = steps(400, quick);
    let models = [
        ("mlp_vgg_c32", OptimizerKind::Sgdm),
        ("res_mlp_c32", OptimizerKind::Sgdm),
        ("swin_lite_c32", OptimizerKind::AdamW),
        ("vit_lite_c32", OptimizerKind::AdamW),
    ];
    let mut specs = Vec::new();
    for (model, base) in models {
        for opt in five_variants(base) {
            specs.push(RunSpec::new(model, workload_for(model, 32, 3), opt, total));
        }
    }
    let outcomes = run_all(&specs, workers());
    let mut t = Table::new(
        "Tab 3 — CIFAR-100 analog: accuracy & optimizer-state memory",
        &["Model", "Optimizer", "Accuracy (%)", "Opt-State (MB)"],
    );
    for (spec, o) in specs.iter().zip(outcomes.iter()) {
        t.row(vec![spec.model.clone(), o.optimizer.clone(), acc_cell(o), mem_cell(o)]);
    }
    Ok((t, outcomes))
}

/// Tab. 4 — Tiny-ImageNet analog grid (64 classes; base/32-bit/VQ/CQ+EF).
pub fn tab4(quick: bool) -> Result<Table> {
    let total = steps(400, quick);
    let models = [
        ("mlp_vgg_c64", OptimizerKind::Sgdm),
        ("res_mlp_c64", OptimizerKind::Sgdm),
        ("swin_lite_c64", OptimizerKind::AdamW),
        ("vit_lite_c64", OptimizerKind::AdamW),
    ];
    let mut specs = Vec::new();
    for (model, base) in models {
        let hyper = OptimizerSpec::paper_hyper(base);
        specs.push(RunSpec::new(
            model,
            workload_for(model, 64, 4),
            OptimizerSpec::base_only(base, hyper),
            total,
        ));
        for variant in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: true },
        ] {
            specs.push(RunSpec::new(
                model,
                workload_for(model, 64, 4),
                OptimizerSpec::with_shampoo(base, hyper, scaled_shampoo(variant)),
                total,
            ));
        }
    }
    let outcomes = run_all(&specs, workers());
    let mut t = Table::new(
        "Tab 4 — Tiny-ImageNet analog: accuracy & optimizer-state memory",
        &["Model", "Optimizer", "Accuracy (%)", "Opt-State (MB)"],
    );
    for (spec, o) in specs.iter().zip(outcomes.iter()) {
        t.row(vec![spec.model.clone(), o.optimizer.clone(), acc_cell(o), mem_cell(o)]);
    }
    Ok(t)
}

/// Tab. 5 — ImageNet analog: bigger bodies, wall-clock column.
pub fn tab5(quick: bool) -> Result<Table> {
    let total = steps(500, quick);
    let models = [
        ("res_big_c64", OptimizerKind::Sgdm),
        ("vit_big_c64", OptimizerKind::AdamW),
    ];
    let mut specs = Vec::new();
    for (model, base) in models {
        let hyper = OptimizerSpec::paper_hyper(base);
        specs.push(RunSpec::new(
            model,
            workload_for(model, 64, 5),
            OptimizerSpec::base_only(base, hyper),
            total,
        ));
        for variant in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: true },
        ] {
            specs.push(RunSpec::new(
                model,
                workload_for(model, 64, 5),
                OptimizerSpec::with_shampoo(base, hyper, scaled_shampoo(variant)),
                total,
            ));
        }
    }
    let outcomes = run_all(&specs, workers());
    let mut t = Table::new(
        "Tab 5 — ImageNet analog: accuracy, wall-clock, optimizer-state memory",
        &["Model", "Optimizer", "Accuracy (%)", "Time (s)", "Opt-State (MB)"],
    );
    for (spec, o) in specs.iter().zip(outcomes.iter()) {
        let time = o.metrics.as_ref().map(|m| secs(m.wall_secs)).unwrap_or_else(|| "-".into());
        t.row(vec![spec.model.clone(), o.optimizer.clone(), acc_cell(o), time, mem_cell(o)]);
    }
    Ok(t)
}

/// Tab. 6 — LLaMA/C4 analog: PPL, update time, memory, with the OOM row.
pub fn tab6(rt: &Runtime, quick: bool) -> Result<Table> {
    let total = steps(250, quick);
    let base = OptimizerKind::AdamW;
    let mut hyper = OptimizerSpec::paper_hyper(base);
    hyper.lr = 3e-3;
    hyper.weight_decay = 0.0; // paper: wd 0 for LLM pre-training

    // The "80 GB A100" analog: a budget that admits every 4-bit run and the
    // mid-size 32-bit run but rejects 32-bit on the largest model (DESIGN §4).
    let budget = {
        let shapes_m = rt.manifest.models["lm_m"].shapes();
        let shapes_l = rt.manifest.models["lm_l"].shapes();
        let full = scaled_shampoo(ShampooVariant::Full32);
        let vq = scaled_shampoo(ShampooVariant::Vq4);
        let fits_m = MemoryModel::new(&shapes_m).total_bytes(base, Some(&full));
        let fits_l4 = MemoryModel::new(&shapes_l).total_bytes(base, Some(&vq));
        let breaks = MemoryModel::new(&shapes_l).total_bytes(base, Some(&full));
        let b = fits_m.max(fits_l4) + (breaks - fits_m.max(fits_l4)) / 4;
        assert!(b < breaks, "budget must reject lm_l 32-bit");
        b
    };

    let corpus = |seed| {
        let length = if quick { 30_000 } else { 120_000 };
        Workload::Tokens(CorpusSpec { length, seed, ..Default::default() })
    };
    let mut specs = Vec::new();
    for model in ["lm_s", "lm_m", "lm_l"] {
        specs.push(RunSpec::new(model, corpus(6), OptimizerSpec::base_only(base, hyper), total));
        for variant in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: true },
        ] {
            let mut run = RunSpec::new(
                model,
                corpus(6),
                OptimizerSpec::with_shampoo(base, hyper, scaled_shampoo(variant)),
                total,
            );
            run.memory_budget = Some(budget);
            specs.push(run);
        }
    }
    let outcomes = run_all(&specs, workers());
    let mut t = Table::new(
        "Tab 6 — LLaMA/C4 analog: perplexity, optimizer update time, memory",
        &["Model", "Optimizer", "PPL", "Update time (s)", "Opt-State (MB)"],
    );
    for (spec, o) in specs.iter().zip(outcomes.iter()) {
        let ppl = match (&o.metrics, &o.error) {
            (Some(m), _) => format!("{:.2}", m.final_metric),
            (None, Some(e)) => format!("ERR: {}", e.lines().next().unwrap_or("?")),
            (None, None) => "Out of Memory".into(),
        };
        let time = o.metrics.as_ref().map(|m| secs(m.opt_secs)).unwrap_or_else(|| "-".into());
        t.row(vec![spec.model.clone(), o.optimizer.clone(), ppl, time, mem_cell(o)]);
    }
    Ok(t)
}

/// Tab. 7 — β, βₑ robustness sweep (CQ+EF).
pub fn tab7(quick: bool) -> Result<Table> {
    let total = steps(300, quick);
    let base = OptimizerKind::Sgdm;
    let hyper = OptimizerSpec::paper_hyper(base);
    let mut specs = Vec::new();
    let betas = [0.6f32, 0.7, 0.8, 0.9, 0.95, 0.98];
    for &b in &betas {
        let mut cfg = scaled_shampoo(ShampooVariant::Cq4 { error_feedback: true });
        cfg.beta = b;
        cfg.beta_e = b;
        let opt = OptimizerSpec::with_shampoo(base, hyper, cfg);
        specs.push(RunSpec::new("res_mlp_c32", cluster(32, 7), opt, total));
    }
    let outcomes = run_all(&specs, workers());
    let mut t = Table::new(
        "Tab 7 — momentum (β = βₑ) robustness, ResNet analog, CQ+EF",
        &["β, βₑ", "Accuracy (%)"],
    );
    for (b, o) in betas.iter().zip(outcomes.iter()) {
        t.row(vec![format!("{b}"), acc_cell(o)]);
    }
    Ok(t)
}

/// Tab. 8 — RMSProp base optimizer.
pub fn tab8(quick: bool) -> Result<Table> {
    let total = steps(400, quick);
    let base = OptimizerKind::RmsProp;
    let hyper = OptimizerSpec::paper_hyper(base);
    let mut specs = vec![RunSpec::new(
        "swin_lite_c32",
        workload_for("swin_lite_c32", 32, 8),
        OptimizerSpec::base_only(base, hyper),
        total,
    )];
    for variant in
        [ShampooVariant::Full32, ShampooVariant::Vq4, ShampooVariant::Cq4 { error_feedback: true }]
    {
        specs.push(RunSpec::new(
            "swin_lite_c32",
            workload_for("swin_lite_c32", 32, 8),
            OptimizerSpec::with_shampoo(base, hyper, scaled_shampoo(variant)),
            total,
        ));
    }
    let outcomes = run_all(&specs, workers());
    let mut t = Table::new(
        "Tab 8 — RMSProp base, Swin analog",
        &["Optimizer", "Accuracy (%)", "Opt-State (MB)"],
    );
    for o in &outcomes {
        t.row(vec![o.optimizer.clone(), acc_cell(o), mem_cell(o)]);
    }
    Ok(t)
}

/// Tab. 9 — the toy 2×2 example (paper App. C.1), exact matrix.
pub fn tab9() -> Result<Table> {
    let q = BlockQuantizer::new(QuantConfig { block: 2, min_quant_elems: 0, ..Default::default() });
    let l = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0]]);
    let (orig, _) = eig_sym(&l, 1e-12, 100);
    let vq = vq_roundtrip(&l, &q);
    let (vq_vals, _) = eig_sym(&vq, 1e-12, 100);
    let cq = cq_roundtrip(&l, 1e-6, &q);
    let (cq_vals, _) = eig_sym(&cq, 1e-12, 100);

    let mut t = Table::new(
        "Tab 9 — toy 2×2 matrix L = [[10,3],[3,1]]: eigenvalues after 4-bit round-trip",
        &["Method", "Matrix (row-major)", "Eigenvalues (λmax, λmin)"],
    );
    let fmt_m = |m: &Matrix| {
        format!("[{:.2}, {:.2}; {:.2}, {:.2}]", m[(0, 0)], m[(0, 1)], m[(1, 0)], m[(1, 1)])
    };
    t.row(vec![
        "Original".into(),
        fmt_m(&l),
        format!("({:.3}, {:.3})", orig[1], orig[0]),
    ]);
    t.row(vec![
        "VQ".into(),
        fmt_m(&vq),
        format!("({:.3}, {:.3})", vq_vals[1], vq_vals[0]),
    ]);
    t.row(vec![
        "CQ".into(),
        fmt_m(&cq),
        format!("({:.3}, {:.3})", cq_vals[1], cq_vals[0]),
    ]);
    Ok(t)
}

/// App. C.4 — memory breakdown: 32-bit vs VQ vs CQ vs CQ+EF state deltas.
pub fn mem_breakdown(rt: &Runtime) -> Result<Table> {
    let model = &rt.manifest.models["res_mlp_c32"];
    let shapes = model.shapes();
    let mm = MemoryModel::new(&shapes);
    let mut t = Table::new(
        "App C.4 analog — optimizer-state memory breakdown (ResNet analog)",
        &["Configuration", "Precond bytes", "vs 32-bit", "vs VQ"],
    );
    let full = mm.shampoo_bytes(&scaled_shampoo(ShampooVariant::Full32));
    let q = |v| {
        let mut c = scaled_shampoo(v);
        c.quant.min_quant_elems = 0;
        mm.shampoo_bytes(&c)
    };
    let vq = q(ShampooVariant::Vq4);
    let cq = q(ShampooVariant::Cq4 { error_feedback: false });
    let cqef = q(ShampooVariant::Cq4 { error_feedback: true });
    let rows = [
        ("32-bit Shampoo (L, R, L^-1/4, R^-1/4)", full),
        ("4-bit VQ", vq),
        ("4-bit CQ", cq),
        ("4-bit CQ+EF (joint triangular store)", cqef),
    ];
    for (label, bytes) in rows {
        t.row(vec![
            label.into(),
            format!("{bytes}"),
            format!("{:.1}%", 100.0 * bytes as f64 / full as f64),
            format!("{:.1}%", 100.0 * bytes as f64 / vq as f64),
        ]);
    }
    Ok(t)
}

/// Dispatch by table id, printing and saving CSVs.
pub fn run_table(id: &str, quick: bool, out_dir: &Path) -> Result<()> {
    let need_rt = matches!(id, "tab1" | "tab10" | "tab6" | "mem-breakdown");
    let rt = if need_rt { Some(Runtime::open_default()?) } else { None };
    let tables: Vec<Table> = match id {
        "tab1" => vec![tab_nre_ae(
            rt.as_ref().unwrap(),
            "mlp_vgg_c32",
            quick,
            "Tab 1 — NRE/AE, VQ vs CQ (synthetic + VGG-analog preconditioners)",
        )?],
        "tab2" => vec![tab2(quick)?],
        "tab3" => vec![tab3(quick)?.0],
        "tab4" => vec![tab4(quick)?],
        "tab5" => vec![tab5(quick)?],
        "tab6" => vec![tab6(rt.as_ref().unwrap(), quick)?],
        "tab7" => vec![tab7(quick)?],
        "tab8" => vec![tab8(quick)?],
        "tab9" => vec![tab9()?],
        "tab10" => vec![tab_nre_ae(
            rt.as_ref().unwrap(),
            "swin_lite_c32",
            quick,
            "Tab 10 — NRE/AE, VQ vs CQ (Swin-analog preconditioners)",
        )?],
        "mem-breakdown" => vec![mem_breakdown(rt.as_ref().unwrap())?],
        "all" => {
            for id in [
                "tab1", "tab2", "tab3", "tab4", "tab5", "tab6", "tab7", "tab8", "tab9", "tab10",
                "mem-breakdown",
            ] {
                run_table(id, quick, out_dir)?;
            }
            return Ok(());
        }
        _ => bail!("unknown table id '{id}' (tab1..tab10, mem-breakdown, all)"),
    };
    for t in &tables {
        t.print();
        let path = out_dir.join(format!("{id}.csv"));
        t.save_csv(&path)?;
        println!("(csv saved to {})\n", path.display());
    }
    Ok(())
}

//! Wall-clock timing helpers for the Tab. 5/6 time columns.

use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop around code regions, read the total.
#[derive(Clone, Debug, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        Stopwatch::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Time one closure and accumulate.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn total_secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.total = Duration::ZERO;
        self.started = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_regions() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.total() >= Duration::from_millis(9));
    }

    #[test]
    fn reset_clears() {
        let mut sw = Stopwatch::new();
        sw.time(|| ());
        sw.reset();
        assert_eq!(sw.total(), Duration::ZERO);
    }
}

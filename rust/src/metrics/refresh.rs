//! Refresh-scheduler telemetry: per-step refresh-unit counts and refresh
//! busy time, so the latency-spike flattening of `shampoo::scheduler`
//! policies is *measurable*, not asserted.
//!
//! `Shampoo` records one sample per step; the end-to-end step benches and
//! the scheduler test suite read the aggregate. `max_root_units` is the
//! spike metric: `every-n` concentrates all units in one step, `staggered`
//! bounds it by ⌈units/T₂⌉.

/// Overlap accounting for the sharded async-refresh engine
/// (`shampoo::async_engine`): how much refresh work ran concurrently with
/// optimizer steps, and how often the bounded-staleness contract had to
/// stall a step waiting for an overdue worker. All counters are cumulative
/// over the optimizer's lifetime; every one stays zero with
/// `async_refresh = false`.
#[derive(Clone, Debug, Default)]
pub struct AsyncRefreshStats {
    /// Root-refresh jobs shipped to worker shards.
    pub submitted: u64,
    /// Results published into the live root slots (every submission is
    /// eventually published or drained at shutdown).
    pub published: u64,
    /// Planned root refreshes skipped because the unit was already in
    /// flight — the scheduler re-planned faster than the staleness window.
    pub coalesced: u64,
    /// Publishes that had to block on an unfinished worker (the barrier at
    /// `max_async_staleness`), and the wall-clock spent blocked.
    pub barrier_stalls: u64,
    pub barrier_stall_secs: f64,
    /// Most units simultaneously in flight.
    pub max_in_flight: usize,
    /// Largest publish lag in steps (publish step − submit step). The
    /// bounded-staleness contract pins this ≤ `max_async_staleness`; the
    /// async soak test asserts it.
    pub max_publish_lag: u64,
    /// Steps that ended with at least one refresh in flight — the overlap
    /// the engine exists to create.
    pub steps_overlapped: u64,
    /// Wall-clock from worker completion to publish, total and worst —
    /// how long finished roots waited for their deterministic due step.
    pub publish_latency_secs: f64,
    pub max_publish_latency_secs: f64,
}

impl AsyncRefreshStats {
    /// One-line human summary (appended to [`RefreshStats::summary`] when
    /// the async engine ran).
    pub fn summary(&self) -> String {
        format!(
            "async sub {} pub {} coal {} | in-flight max {} | lag max {} steps | \
             stalls {} ({:.3} ms) | overlapped {} steps",
            self.submitted,
            self.published,
            self.coalesced,
            self.max_in_flight,
            self.max_publish_lag,
            self.barrier_stalls,
            self.barrier_stall_secs * 1e3,
            self.steps_overlapped,
        )
    }
}

/// Aggregate refresh telemetry over an optimizer's lifetime.
#[derive(Clone, Debug, Default)]
pub struct RefreshStats {
    /// Steps recorded.
    pub steps: u64,
    /// Total Gram-EMA units executed.
    pub gram_units: u64,
    /// Total inverse-root units executed.
    pub root_units: u64,
    /// Largest per-step Gram unit count (spike height, cheap half).
    pub max_gram_units: usize,
    /// Largest per-step root unit count (spike height, expensive half).
    pub max_root_units: usize,
    /// Last step's counts (budget assertions).
    pub last_gram_units: usize,
    pub last_root_units: usize,
    /// Refresh-task **busy time** (summed across workers), total and worst
    /// step. Equals wall-clock when one worker runs; with concurrent
    /// workers it is an upper bound on the spike's latency contribution —
    /// still the right comparator between policies, since total refresh
    /// work is schedule-invariant.
    pub refresh_secs: f64,
    pub max_refresh_secs: f64,
    /// Wall-clock of whole steps (refresh + precondition + apply).
    pub step_secs: f64,
    /// Cumulative numerical-health counters (guard screens, fallback-ladder
    /// rungs, quarantine transitions) drained from the refresh executor's
    /// [`super::HealthLedger`] once per step.
    pub health: super::HealthStats,
    /// Async-refresh overlap counters (all zero when `async_refresh` is
    /// off); copied from the engine once per step.
    pub async_refresh: AsyncRefreshStats,
}

impl RefreshStats {
    pub fn new() -> RefreshStats {
        RefreshStats::default()
    }

    /// Record one step's plan execution.
    pub fn record(&mut self, gram_units: usize, root_units: usize, refresh_ns: u64, step_ns: u64) {
        self.steps += 1;
        self.gram_units += gram_units as u64;
        self.root_units += root_units as u64;
        self.max_gram_units = self.max_gram_units.max(gram_units);
        self.max_root_units = self.max_root_units.max(root_units);
        self.last_gram_units = gram_units;
        self.last_root_units = root_units;
        let rs = refresh_ns as f64 / 1e9;
        self.refresh_secs += rs;
        self.max_refresh_secs = self.max_refresh_secs.max(rs);
        self.step_secs += step_ns as f64 / 1e9;
    }

    /// Mean root units per step — spread policies keep this equal to the
    /// every-n mean while shrinking [`Self::max_root_units`].
    pub fn mean_root_units(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.root_units as f64 / self.steps as f64
    }

    /// Refresh busy time over step wall-clock. Clamped to 1.0 — summed
    /// busy time can exceed wall-clock when refresh tasks run concurrently.
    pub fn refresh_fraction(&self) -> f64 {
        if self.step_secs <= 0.0 {
            return 0.0;
        }
        (self.refresh_secs / self.step_secs).min(1.0)
    }

    /// One-line human summary (bench output).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "steps {} | units/step mean {:.2} max {} (gram max {}) | \
             refresh busy {:.1}% of step, worst {:.3} ms",
            self.steps,
            self.mean_root_units(),
            self.max_root_units,
            self.max_gram_units,
            100.0 * self.refresh_fraction(),
            self.max_refresh_secs * 1e3,
        );
        if self.async_refresh.submitted > 0 {
            s.push_str(" | ");
            s.push_str(&self.async_refresh.summary());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_totals_and_spikes() {
        let mut s = RefreshStats::new();
        s.record(4, 0, 0, 1_000);
        s.record(0, 6, 500, 1_000);
        s.record(2, 2, 250, 1_000);
        assert_eq!(s.steps, 3);
        assert_eq!(s.gram_units, 6);
        assert_eq!(s.root_units, 8);
        assert_eq!(s.max_gram_units, 4);
        assert_eq!(s.max_root_units, 6);
        assert_eq!(s.last_root_units, 2);
        assert!((s.mean_root_units() - 8.0 / 3.0).abs() < 1e-12);
        assert!(s.refresh_fraction() > 0.0 && s.refresh_fraction() < 1.0);
    }

    #[test]
    fn empty_stats_are_well_defined() {
        let s = RefreshStats::new();
        assert_eq!(s.mean_root_units(), 0.0);
        assert_eq!(s.refresh_fraction(), 0.0);
        assert!(s.summary().contains("steps 0"));
    }

    #[test]
    fn async_counters_surface_in_summary_only_when_used() {
        let mut s = RefreshStats::new();
        s.record(0, 0, 0, 1_000);
        assert!(!s.summary().contains("async"), "sync runs keep the classic summary");
        s.async_refresh.submitted = 3;
        s.async_refresh.published = 3;
        s.async_refresh.max_publish_lag = 2;
        s.async_refresh.steps_overlapped = 5;
        let line = s.summary();
        assert!(line.contains("async sub 3 pub 3"), "{line}");
        assert!(line.contains("lag max 2"), "{line}");
        assert!(line.contains("overlapped 5 steps"), "{line}");
    }
}

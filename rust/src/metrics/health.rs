//! Numerical-health telemetry: what the guard engine screened, rescued,
//! served stale, floored, and quarantined.
//!
//! Two pieces:
//! * [`HealthStats`] — a plain counter snapshot, folded into
//!   [`super::RefreshStats`], surfaced in `RunMetrics`, streamed into the
//!   queue's `metrics.jsonl`, and printed by `quartz health`.
//! * [`HealthLedger`] — the lock-free accumulator the parallel refresh
//!   executor increments from worker threads; drained once per step into
//!   the owning optimizer's `HealthStats` via [`HealthLedger::take`].
//!
//! This module deliberately knows nothing about Shampoo: the ledger exposes
//! one increment method per counter and the refresh layer maps its typed
//! `FallbackOutcome` onto them.

use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative health counters for one optimizer (or one run).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Gradient / gram updates skipped because the input was non-finite.
    pub grads_screened: u64,
    /// Exceptional root refreshes rescued by the ridged eigendecomposition
    /// (the ladder's jitter rung).
    pub jitter_rescues: u64,
    /// Root refreshes that needed the sanitized eigenvalue-clamped PSD
    /// projection rung.
    pub psd_projections: u64,
    /// Refreshes that kept serving the last good root (stale-root rung).
    pub stale_root_serves: u64,
    /// Refreshes served from the diagonal floor (quarantine or last rung).
    pub floor_serves: u64,
    /// Units newly quarantined after repeated consecutive failures.
    pub quarantines: u64,
    /// Units released from quarantine by a successful probation refresh.
    pub releases: u64,
}

impl HealthStats {
    /// Whether any counter is non-zero.
    pub fn any(&self) -> bool {
        self.grads_screened
            + self.jitter_rescues
            + self.psd_projections
            + self.stale_root_serves
            + self.floor_serves
            + self.quarantines
            + self.releases
            > 0
    }

    /// Add another snapshot's counters into this one.
    pub fn absorb(&mut self, other: &HealthStats) {
        self.grads_screened += other.grads_screened;
        self.jitter_rescues += other.jitter_rescues;
        self.psd_projections += other.psd_projections;
        self.stale_root_serves += other.stale_root_serves;
        self.floor_serves += other.floor_serves;
        self.quarantines += other.quarantines;
        self.releases += other.releases;
    }

    /// One-line human summary (`quartz health` totals row).
    pub fn summary(&self) -> String {
        format!(
            "screened {} · jitter {} · psd {} · stale {} · floor {} · quarantined {} · released {}",
            self.grads_screened,
            self.jitter_rescues,
            self.psd_projections,
            self.stale_root_serves,
            self.floor_serves,
            self.quarantines,
            self.releases
        )
    }
}

/// Thread-safe health accumulator for the parallel refresh executor.
#[derive(Debug, Default)]
pub struct HealthLedger {
    grads_screened: AtomicU64,
    jitter_rescues: AtomicU64,
    psd_projections: AtomicU64,
    stale_root_serves: AtomicU64,
    floor_serves: AtomicU64,
    quarantines: AtomicU64,
    releases: AtomicU64,
}

impl HealthLedger {
    pub fn new() -> HealthLedger {
        HealthLedger::default()
    }

    pub fn grad_screened(&self) {
        self.grads_screened.fetch_add(1, Ordering::Relaxed);
    }

    pub fn jitter_rescue(&self) {
        self.jitter_rescues.fetch_add(1, Ordering::Relaxed);
    }

    pub fn psd_projection(&self) {
        self.psd_projections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stale_root_serve(&self) {
        self.stale_root_serves.fetch_add(1, Ordering::Relaxed);
    }

    pub fn floor_serve(&self) {
        self.floor_serves.fetch_add(1, Ordering::Relaxed);
    }

    pub fn quarantine(&self) {
        self.quarantines.fetch_add(1, Ordering::Relaxed);
    }

    pub fn release(&self) {
        self.releases.fetch_add(1, Ordering::Relaxed);
    }

    /// Drain the ledger: return everything counted since the last `take`
    /// and reset every counter to zero.
    pub fn take(&self) -> HealthStats {
        HealthStats {
            grads_screened: self.grads_screened.swap(0, Ordering::Relaxed),
            jitter_rescues: self.jitter_rescues.swap(0, Ordering::Relaxed),
            psd_projections: self.psd_projections.swap(0, Ordering::Relaxed),
            stale_root_serves: self.stale_root_serves.swap(0, Ordering::Relaxed),
            floor_serves: self.floor_serves.swap(0, Ordering::Relaxed),
            quarantines: self.quarantines.swap(0, Ordering::Relaxed),
            releases: self.releases.swap(0, Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_drains_to_zero() {
        let l = HealthLedger::new();
        l.grad_screened();
        l.grad_screened();
        l.jitter_rescue();
        l.quarantine();
        l.release();
        let s = l.take();
        assert_eq!(s.grads_screened, 2);
        assert_eq!(s.jitter_rescues, 1);
        assert_eq!(s.quarantines, 1);
        assert_eq!(s.releases, 1);
        assert!(s.any());
        assert!(!l.take().any(), "take resets every counter");
    }

    #[test]
    fn absorb_accumulates() {
        let mut a = HealthStats::default();
        assert!(!a.any());
        let b = HealthStats { psd_projections: 3, floor_serves: 2, ..Default::default() };
        a.absorb(&b);
        a.absorb(&b);
        assert_eq!(a.psd_projections, 6);
        assert_eq!(a.floor_serves, 4);
        assert!(a.summary().contains("psd 6"));
    }
}

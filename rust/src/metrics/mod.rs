//! Measurement: the optimizer-state memory accountant behind the paper's
//! peak-memory columns, plus wall-clock timers and task metrics.

pub mod memory;
pub mod timer;
pub mod scoring;

pub use memory::MemoryModel;
pub use scoring::{accuracy, cross_entropy, perplexity_from_nll};
pub use timer::Stopwatch;

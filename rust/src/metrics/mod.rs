//! Measurement: the optimizer-state memory accountant behind the paper's
//! peak-memory columns, wall-clock timers, task metrics, and the
//! refresh-scheduler telemetry.

pub mod health;
pub mod memory;
pub mod refresh;
pub mod scoring;
pub mod timer;

pub use health::{HealthLedger, HealthStats};
pub use memory::MemoryModel;
pub use refresh::{AsyncRefreshStats, RefreshStats};
pub use scoring::{accuracy, cross_entropy, perplexity_from_nll};
pub use timer::Stopwatch;

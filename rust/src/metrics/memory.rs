//! Analytic optimizer-state memory model (paper App. C.4).
//!
//! The paper's memory columns isolate the *optimizer-state* delta on top of
//! the base optimizer: e.g. for ResNet-34/CIFAR-100, 32-bit Shampoo adds
//! 627.9 MB, vanilla 4-bit adds 86.3 MB, and CQ brings that to ≈75% of VQ
//! (64.8 MB). This module predicts those bytes exactly from parameter
//! shapes + configuration, and unit tests pin the model to the *measured*
//! `size_bytes()` of live optimizer states (no drift allowed).

use crate::optim::{grafting, GraftParams, OptimizerKind};
use crate::shampoo::{Blocking, LayerState, ShampooConfig, UnitMeta};

/// Byte accountant for a model (list of parameter shapes).
#[derive(Clone, Debug)]
pub struct MemoryModel {
    pub shapes: Vec<(usize, usize)>,
}

impl MemoryModel {
    pub fn new(shapes: &[(usize, usize)]) -> MemoryModel {
        MemoryModel { shapes: shapes.to_vec() }
    }

    /// f32 parameter bytes.
    pub fn param_bytes(&self) -> usize {
        self.shapes.iter().map(|&(m, n)| m * n * 4).sum()
    }

    /// Base-optimizer state bytes (momentum/second-moment buffers).
    pub fn base_state_bytes(&self, kind: OptimizerKind) -> usize {
        self.param_bytes() * kind.state_slots()
    }

    /// Shampoo preconditioner + graft-accumulator bytes for a variant
    /// (excluding base state), at the steady-state (post-warmup) footprint.
    pub fn shampoo_bytes(&self, cfg: &ShampooConfig) -> usize {
        self.bytes_inner(cfg, true)
    }

    /// Like [`MemoryModel::shampoo_bytes`] but at a point in training:
    /// while `step < cfg.start_preconditioning_step` the inverse-root slots
    /// are still deferred — never computed, not counted, exactly like the
    /// live state — and from the threshold step on the steady-state
    /// footprint applies (exact under the default `every-n` cadence with
    /// `t2 = 1`; with a sparser root schedule the slots go live at the
    /// first post-warmup root refresh instead).
    pub fn shampoo_bytes_at(&self, cfg: &ShampooConfig, step: u64) -> usize {
        self.bytes_inner(cfg, step >= cfg.start_preconditioning_step)
    }

    fn bytes_inner(&self, cfg: &ShampooConfig, roots_live: bool) -> usize {
        self.shapes
            .iter()
            .map(|&(m, n)| {
                let graft = graft_state_bytes(m, n, cfg);
                if m.min(n) <= 1 || LayerState::dim_opted_out(m, n, cfg) {
                    // Vectors and dim-gt opt-outs bypass preconditioning:
                    // zero codec state, but the grafted base path still
                    // carries its accumulator.
                    return graft;
                }
                graft
                    + Blocking::new(m, n, cfg.max_order)
                        .blocks
                        .iter()
                        .map(|b| {
                            // Four codec stores plus the refresh scheduler's
                            // per-unit bookkeeping (two units per block) —
                            // policy-invariant, so this model holds under
                            // every registered refresh policy.
                            let roots = if roots_live {
                                root_bytes(b.rows, cfg) + root_bytes(b.cols, cfg)
                            } else {
                                0
                            };
                            side_bytes(b.rows, cfg) + side_bytes(b.cols, cfg)
                                + roots
                                + 2 * UnitMeta::BYTES
                        })
                        .sum::<usize>()
            })
            .sum()
    }

    /// Full optimizer footprint: base state + Shampoo preconditioners.
    pub fn total_bytes(&self, base: OptimizerKind, shampoo: Option<&ShampooConfig>) -> usize {
        self.base_state_bytes(base) + shampoo.map(|c| self.shampoo_bytes(c)).unwrap_or(0)
    }
}

/// Accumulator bytes of the configured graft for one `m×n` layer, priced
/// through the registry itself (build one and ask) so runtime-registered
/// grafts are exact rather than approximated. Stateless keys cost zero.
fn graft_state_bytes(m: usize, n: usize, cfg: &ShampooConfig) -> usize {
    let gp = GraftParams { eps: cfg.eps, beta: cfg.beta };
    grafting::build_for(cfg.graft_key(), m, n, &gp).size_bytes()
}

/// Scale count for one `dim×dim` block-quantized matrix.
fn n_scales(dim: usize, block: usize) -> usize {
    let b = dim.div_ceil(block);
    b * b
}

/// Closed-form bytes of one `dim×dim` slot stored under a **side**
/// constructor of codec `key`. This mirrors `quant::codec` exactly, keyed
/// on the registry string rather than on `ShampooVariant` — so the model
/// prices `side_codec`/`root_codec` overrides and the `ec4`/`f16`/`cq-r1`
/// family through the same formulas as the variant-derived keys, and the
/// parity tests below pin each one against a *live* optimizer's measured
/// `size_bytes()`. Unknown (runtime-registered) keys are approximated with
/// the `cq4-ef` footprint — the same convention
/// `ShampooVariant::default_for_custom` uses.
fn codec_side_bytes(key: &str, dim: usize, cfg: &ShampooConfig) -> usize {
    let scales = n_scales(dim, cfg.quant.block) * 4;
    match key {
        "f32" => dim * dim * 4,
        // dense IEEE half: two bytes per element, no side-bands
        "f16" => dim * dim * 2,
        // off-diag 4-bit codes (full grid) + scales + f32 diagonal
        "vq4" => (dim * dim).div_ceil(2) + scales + dim * 4,
        // Tab. 2 "Original": codes + scales, no f32 diagonal
        "vq4-full" => (dim * dim).div_ceil(2) + scales,
        // lower-triangle nibbles only + diag + 1 scale set
        "cq4" => ((dim * (dim + 1)) / 2).div_ceil(2) + dim * 4 + scales,
        // Fig. 2 joint store: one full nibble grid + diag + 2 scale sets
        "cq4-ef" => (dim * dim).div_ceil(2) + dim * 4 + 2 * scales,
        // cq4 payload + the per-row f32 scale vector
        "cq-r1" => codec_side_bytes("cq4", dim, cfg) + dim * 4,
        // one byte per off-diag code + scales + f32 diagonal
        "bw8" => dim * dim + scales + dim * 4,
        // 4-bit eigenvector grid + scales + f32 eigenvalue vector
        "ec4" => (dim * dim).div_ceil(2) + scales + dim * 4,
        _ => codec_side_bytes("cq4-ef", dim, cfg),
    }
}

/// Like [`codec_side_bytes`] for a **root** constructor: the Cholesky-family
/// builders keep off-diagonally quantized roots (Sec. 4.2: roots are applied
/// every step and never factored), so their root slots price as `vq4`.
fn codec_root_bytes(key: &str, dim: usize, cfg: &ShampooConfig) -> usize {
    match key {
        "cq4" | "cq4-ef" | "cq-r1" => codec_side_bytes("vq4", dim, cfg),
        _ => codec_side_bytes(key, dim, cfg),
    }
}

/// Bytes of one Gram-side store (`L` or `R`) of order `dim`, honoring the
/// small-tensor exemption exactly like `shampoo::state`.
fn side_bytes(dim: usize, cfg: &ShampooConfig) -> usize {
    if dim * dim < cfg.quant.min_quant_elems {
        return dim * dim * 4;
    }
    codec_side_bytes(cfg.side_codec_key(), dim, cfg)
}

/// Bytes of one inverse-root store (`L̂` or `R̂`) of order `dim`.
fn root_bytes(dim: usize, cfg: &ShampooConfig) -> usize {
    let key = cfg.root_codec_key();
    if key == "f32" || dim * dim < cfg.quant.min_quant_elems {
        return dim * dim * 4;
    }
    codec_root_bytes(key, dim, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::BaseOptimizer;
    use crate::quant::QuantConfig;
    use crate::shampoo::{Shampoo, ShampooVariant};
    use crate::util::rng::Rng;

    fn run_one_step(variant: ShampooVariant, shapes: &[(usize, usize)]) -> (usize, ShampooConfig) {
        let cfg = ShampooConfig {
            variant,
            t1: 1,
            t2: 1,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            max_order: 96,
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, shapes);
        let mut rng = Rng::new(9);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
        sh.step(&mut params, &grads, 1, 1.0);
        (sh.shampoo_state_bytes(), cfg)
    }

    /// The accountant must match the measured bytes of live states exactly,
    /// for every variant, including blocked layers and vector passthrough.
    #[test]
    fn model_matches_measured_bytes() {
        let shapes = [(64, 48), (128, 64), (33, 1), (120, 100)];
        for variant in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: false },
            ShampooVariant::Cq4 { error_feedback: true },
            ShampooVariant::Bw8,
        ] {
            let (measured, cfg) = run_one_step(variant, &shapes);
            let predicted = MemoryModel::new(&shapes).shampoo_bytes(&cfg);
            assert_eq!(predicted, measured, "variant {variant:?}");
        }
    }

    /// The `ec4`/`f16`/`cq-r1` family has no `ShampooVariant` arm — it runs
    /// through `side_codec`/`root_codec` overrides — and the key-based model
    /// must stay byte-exact against the live optimizer there too. The
    /// pairings come from the registry's codec metadata, so a future family
    /// key joins this parity gate automatically.
    #[test]
    fn model_matches_measured_bytes_for_codec_override_families() {
        let shapes = [(64, 48), (33, 1), (120, 100)];
        let family: Vec<(&str, &str)> = crate::train::registry::stack_keys()
            .into_iter()
            .filter_map(|key| crate::train::registry::lookup(key)?.codecs)
            .collect();
        assert!(family.len() >= 3, "ec4/f16/cq-r1 must declare codec metadata");
        for (side, root) in family {
            let cfg = ShampooConfig {
                t1: 1,
                t2: 1,
                side_codec: Some(side),
                root_codec: Some(root),
                quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
                max_order: 96,
                ..Default::default()
            };
            let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, &shapes);
            let mut rng = Rng::new(17);
            let mut params: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
            sh.step(&mut params, &grads, 1, 1.0);
            let predicted = MemoryModel::new(&shapes).shampoo_bytes(&cfg);
            assert_eq!(predicted, sh.shampoo_state_bytes(), "codecs {side}/{root}");
        }
    }

    /// App. C.4's headline ratio: CQ preconditioner storage ≈ 75% of VQ
    /// (two of four matrices halve).
    #[test]
    fn cq_is_about_three_quarters_of_vq() {
        let shapes = [(512, 512)];
        let mk = |variant| ShampooConfig {
            variant,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mm = MemoryModel::new(&shapes);
        let vq = mm.shampoo_bytes(&mk(ShampooVariant::Vq4)) as f64;
        let cq = mm.shampoo_bytes(&mk(ShampooVariant::Cq4 { error_feedback: false })) as f64;
        let ratio = cq / vq;
        assert!((0.70..0.82).contains(&ratio), "CQ/VQ ratio {ratio:.3} (paper ≈ 0.75)");
    }

    /// 4-bit total is far below 32-bit (paper: < 1/7 of the 32-bit delta).
    #[test]
    fn four_bit_is_fraction_of_full() {
        let shapes = [(512, 512), (256, 512)];
        let mm = MemoryModel::new(&shapes);
        let full = mm.shampoo_bytes(&ShampooConfig {
            variant: ShampooVariant::Full32,
            ..Default::default()
        }) as f64;
        let vq = mm.shampoo_bytes(&ShampooConfig {
            variant: ShampooVariant::Vq4,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        }) as f64;
        assert!(vq < full / 7.0, "vq={vq} full={full}");
    }

    /// EF costs (almost) nothing over CQ thanks to the Fig. 2 joint store —
    /// and never exceeds the VQ footprint.
    #[test]
    fn ef_rides_free_in_the_upper_triangle() {
        let shapes = [(256, 256)];
        let mk = |variant| ShampooConfig {
            variant,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mm = MemoryModel::new(&shapes);
        let vq = mm.shampoo_bytes(&mk(ShampooVariant::Vq4));
        let cqef = mm.shampoo_bytes(&mk(ShampooVariant::Cq4 { error_feedback: true }));
        assert!(cqef <= vq + 2 * 16 * 4, "cqef={cqef} vq={vq}");
    }

    /// 8-bit lands strictly between 4-bit VQ and f32 (≈ 2× VQ's codes).
    #[test]
    fn bw8_is_between_vq_and_full() {
        let shapes = [(512, 512)];
        let mk = |variant| ShampooConfig {
            variant,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mm = MemoryModel::new(&shapes);
        let vq = mm.shampoo_bytes(&mk(ShampooVariant::Vq4));
        let bw8 = mm.shampoo_bytes(&mk(ShampooVariant::Bw8));
        let full = mm.shampoo_bytes(&mk(ShampooVariant::Full32));
        assert!(vq < bw8 && bw8 < full / 3, "vq={vq} bw8={bw8} full={full}");
    }

    #[test]
    fn base_state_bytes_by_kind() {
        let mm = MemoryModel::new(&[(10, 10)]);
        assert_eq!(mm.base_state_bytes(OptimizerKind::Sgd), 0);
        assert_eq!(mm.base_state_bytes(OptimizerKind::Sgdm), 400);
        assert_eq!(mm.base_state_bytes(OptimizerKind::AdamW), 800);
    }

    #[test]
    fn small_tensor_exemption_in_model() {
        let shapes = [(16, 16)]; // 256-elem preconditioners < 4096 → f32
        let cfg = ShampooConfig { variant: ShampooVariant::Vq4, ..Default::default() };
        let mm = MemoryModel::new(&shapes);
        // L, R, L̂, R̂ all f32, plus the scheduler's two per-block units.
        assert_eq!(mm.shampoo_bytes(&cfg), 4 * 16 * 16 * 4 + 2 * UnitMeta::BYTES);
    }

    /// The scheduler's per-block metadata is persistent state: the model
    /// must count it and stay byte-exact against the live optimizer under
    /// EVERY refresh policy (metadata is policy-invariant by design), on a
    /// layer set that includes a multi-block layer.
    #[test]
    fn scheduler_metadata_is_counted_under_each_policy() {
        let shapes = [(120, 100), (64, 48), (33, 1)]; // multi-block + vector
        for policy in ["every-n", "staggered", "staleness"] {
            let cfg = ShampooConfig {
                variant: ShampooVariant::Cq4 { error_feedback: true },
                t1: 1,
                t2: 2,
                refresh_policy: policy,
                quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
                max_order: 96,
                ..Default::default()
            };
            let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, &shapes);
            let mut rng = Rng::new(31);
            let mut params: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
            for k in 1..=4u64 {
                sh.step(&mut params, &grads, k, 1.0);
            }
            let predicted = MemoryModel::new(&shapes).shampoo_bytes(&cfg);
            assert_eq!(
                predicted,
                sh.shampoo_state_bytes(),
                "policy '{policy}': modeled vs measured bytes"
            );
        }
    }

    /// Graft accumulators are persistent optimizer state: every registered
    /// graft key priced byte-exactly against the live optimizer under every
    /// registered codec (accumulators ride on top of the codec stores
    /// independently), on a layer set with a multi-block layer and a
    /// vector.
    #[test]
    fn model_matches_measured_bytes_for_every_graft_and_codec() {
        let shapes = [(64, 48), (33, 1), (120, 100)];
        let codecs = crate::quant::codec::codec_keys();
        assert!(codecs.len() >= 9, "expected the full codec registry");
        for graft in crate::optim::grafting::graft_keys() {
            for &codec in &codecs {
                let cfg = ShampooConfig {
                    t1: 1,
                    t2: 1,
                    graft,
                    side_codec: Some(codec),
                    root_codec: Some(codec),
                    quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
                    max_order: 96,
                    ..Default::default()
                };
                let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, &shapes);
                let mut rng = Rng::new(23);
                let mut params: Vec<Matrix> =
                    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
                let grads: Vec<Matrix> =
                    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
                sh.step(&mut params, &grads, 1, 1.0);
                let predicted = MemoryModel::new(&shapes).shampoo_bytes(&cfg);
                assert_eq!(predicted, sh.shampoo_state_bytes(), "graft {graft} codec {codec}");
            }
        }
    }

    /// `no_preconditioning_for_layers_with_dim_gt` routes a layer to the
    /// passthrough path: zero codec state in the model AND the live
    /// optimizer, while the grafted base path keeps its accumulator.
    #[test]
    fn dim_opt_out_layers_price_zero_codec_state() {
        let shapes = [(200, 64), (64, 48)];
        let mk = |bound: usize| ShampooConfig {
            t1: 1,
            t2: 1,
            graft: "adagrad",
            no_preconditioning_for_layers_with_dim_gt: bound,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            max_order: 96,
            ..Default::default()
        };
        let cfg = mk(100);
        let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, &shapes);
        let mut rng = Rng::new(29);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
        sh.step(&mut params, &grads, 1, 1.0);
        let mm = MemoryModel::new(&shapes);
        assert_eq!(mm.shampoo_bytes(&cfg), sh.shampoo_state_bytes());
        // The opted-out (200, 64) layer contributes only its accumulator:
        // the delta against the unbounded config is that layer's codec
        // state, i.e. the single-layer model without the knob.
        let only_big = MemoryModel::new(&shapes[..1]);
        let codec_state = only_big.shampoo_bytes(&mk(0)) - only_big.shampoo_bytes(&mk(100));
        assert!(codec_state > 0);
        assert_eq!(mm.shampoo_bytes(&mk(0)), mm.shampoo_bytes(&cfg) + codec_state);
    }

    /// During `start_preconditioning_step` warmup the root slots are
    /// deferred in the live state, and `shampoo_bytes_at` tracks the
    /// transition exactly (t2 = 1: roots go live at the threshold step).
    #[test]
    fn warmup_defers_root_bytes_in_model_and_live_state() {
        let shapes = [(64, 48), (33, 1)];
        let cfg = ShampooConfig {
            t1: 1,
            t2: 1,
            start_preconditioning_step: 3,
            quant: QuantConfig { min_quant_elems: 0, ..Default::default() },
            max_order: 96,
            ..Default::default()
        };
        let mm = MemoryModel::new(&shapes);
        let mut sh = Shampoo::new(BaseOptimizer::sgd(0.01, 0.0), cfg, &shapes);
        let mut rng = Rng::new(37);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
        for k in 1..=3u64 {
            sh.step(&mut params, &grads, k, 1.0);
            assert_eq!(mm.shampoo_bytes_at(&cfg, k), sh.shampoo_state_bytes(), "step {k}");
        }
        assert!(mm.shampoo_bytes_at(&cfg, 2) < mm.shampoo_bytes(&cfg));
        assert_eq!(mm.shampoo_bytes_at(&cfg, 3), mm.shampoo_bytes(&cfg));
    }
}

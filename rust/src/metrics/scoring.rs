//! Task metrics: classification accuracy, cross-entropy, perplexity.

/// Top-1 accuracy from per-example logits and integer labels.
/// `logits` is row-major `[batch, classes]`.
pub fn accuracy(logits: &[f32], classes: usize, labels: &[u32]) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut correct = 0usize;
    for (b, &y) in labels.iter().enumerate() {
        let row = &logits[b * classes..(b + 1) * classes];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best as u32 == y {
            correct += 1;
        }
    }
    correct as f64 / labels.len().max(1) as f64
}

/// Mean cross-entropy (nats) from logits and labels, numerically stable.
pub fn cross_entropy(logits: &[f32], classes: usize, labels: &[u32]) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut total = 0.0f64;
    for (b, &y) in labels.iter().enumerate() {
        let row = &logits[b * classes..(b + 1) * classes];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse: f64 = row.iter().map(|&v| ((v as f64) - maxv).exp()).sum::<f64>().ln() + maxv;
        total += lse - row[y as usize] as f64;
    }
    total / labels.len().max(1) as f64
}

/// Perplexity from a mean negative log-likelihood in nats (Tab. 6's PPL).
pub fn perplexity_from_nll(nll_nats: f64) -> f64 {
    nll_nats.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let logits = [1.0, 2.0, 0.0, /* row2 */ 3.0, 0.0, 0.0];
        assert_eq!(accuracy(&logits, 3, &[1, 0]), 1.0);
        assert_eq!(accuracy(&logits, 3, &[0, 0]), 0.5);
    }

    #[test]
    fn cross_entropy_uniform() {
        // Uniform logits over 4 classes → CE = ln 4.
        let logits = [0.0f32; 4];
        let ce = cross_entropy(&logits, 4, &[2]);
        assert!((ce - 4f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn cross_entropy_confident() {
        let logits = [100.0, 0.0];
        assert!(cross_entropy(&logits, 2, &[0]) < 1e-6);
        assert!(cross_entropy(&logits, 2, &[1]) > 50.0);
    }

    #[test]
    fn ppl_of_ln2_is_2() {
        assert!((perplexity_from_nll(2f64.ln()) - 2.0).abs() < 1e-12);
    }
}

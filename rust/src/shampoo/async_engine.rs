//! Sharded async-refresh engine: overlap inverse-root recomputation with
//! subsequent optimizer steps under a *deterministic* bounded-staleness
//! contract.
//!
//! ## The contract
//!
//! A root refresh planned at step `s` is **submitted** after step `s`
//! executes: the unit's gram is dequantized into an owned snapshot (so it
//! includes step-`s` Gram updates, matching the sync gram-before-root
//! ordering) and shipped to a worker shard. The worker runs the *pure*
//! compute rungs of the fallback ladder
//! ([`compute_root_from_gram`](super::state)) against the snapshot. The
//! result is **published** into the live root slot by the step thread at
//! the start of step `s + d` (`d = max_async_staleness`), in unit-index
//! order — blocking on the completion channel if the worker is not done
//! (a *barrier stall*, counted and timed). Early completions are buffered,
//! never published early.
//!
//! Publishing at the due step rather than on completion is what makes the
//! engine deterministic: trajectories are a function of the schedule alone,
//! bit-identical across worker timings and shard counts (the GEMM tier
//! underneath is bit-identical across thread counts, so worker-side math
//! equals step-thread math). That determinism is load-bearing — it is what
//! lets a killed-and-resumed run with refreshes in flight replay the exact
//! trajectory of an uninterrupted one.
//!
//! ## Sharding
//!
//! Workers are long-lived threads, each owning a private `ScratchArena`;
//! units are assigned to shards by a stable FNV-1a hash of their `UnitId`,
//! so one unit's refreshes are always computed by the same shard (warm
//! arena, no cross-shard reordering of a unit's own jobs).
//!
//! ## Health accounting
//!
//! Workers never touch the `HealthLedger` or unit metadata: they return the
//! ladder outcome, and ALL ledger increments plus the quarantine state
//! machine run at publish time on the step thread
//! ([`BlockState::publish_root_unit`](super::state::BlockState)) — race-free
//! by construction.
//!
//! ## Checkpointing
//!
//! `Shampoo::save_state` *drains* the engine: it waits for every in-flight
//! completion **without publishing** (publishing early would change the
//! trajectory) and serializes the pending publication records — submit/due
//! steps, pending-norm watermark, and the computed root matrix. On restore
//! the records repopulate the ready buffer and publish at their original
//! due steps, so a resumed run is bit-identical to the uninterrupted one.

use super::config::ShampooConfig;
use super::scheduler::UnitId;
use super::state::{compute_root_from_gram, FallbackOutcome};
use crate::linalg::{Matrix, ScratchArena};
use crate::metrics::AsyncRefreshStats;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Stable shard assignment: FNV-1a over the unit's address fields. Hash
/// stability (not distribution quality) is the requirement — the same unit
/// must land on the same shard across runs and resumes.
pub(crate) fn shard_of(id: UnitId, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in [id.layer as u64, id.block as u64, id.side.index() as u64] {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    (h % shards.max(1) as u64) as usize
}

/// One refresh job shipped to a worker shard.
struct AsyncJob {
    unit: usize,
    /// Deterministic fault injection: skip the compute rungs entirely.
    forced: bool,
    /// Owned gram snapshot, dequantized at submission.
    gram: Matrix,
}

/// One completed job, sent back on the shared completion channel.
struct AsyncDone {
    unit: usize,
    /// `None` = every compute rung failed (or the job was forced); the
    /// publish path falls to the stale-root / floor serving rungs.
    result: Option<(Matrix, FallbackOutcome)>,
    finished_at: Instant,
}

/// Step-thread record of one in-flight (or computed-but-unpublished) unit.
struct Pending {
    submit_step: u64,
    due_step: u64,
    /// `pending_norm` watermark at submission — energy absorbed while in
    /// flight stays pending after the publish.
    pending_at_submit: f32,
    /// Filled when the completion is reaped from the channel.
    done: Option<AsyncDone>,
}

/// A publication the step thread must apply to the unit's root slot now.
pub(crate) struct DuePublish {
    pub unit: usize,
    pub submit_step: u64,
    pub pending_at_submit: f32,
    pub result: Option<(Matrix, FallbackOutcome)>,
}

fn worker_loop(rx: mpsc::Receiver<AsyncJob>, tx: mpsc::Sender<AsyncDone>, cfg: ShampooConfig) {
    let mut scratch = ScratchArena::new();
    while let Ok(job) = rx.recv() {
        let result = if job.forced {
            None
        } else {
            // The result matrix comes out of this shard's arena and is
            // moved across the channel (never recycled back) — one
            // allocation per refresh, the documented async overhead.
            compute_root_from_gram(&job.gram, &cfg, &mut scratch)
        };
        scratch.recycle(job.gram);
        let done = AsyncDone { unit: job.unit, result, finished_at: Instant::now() };
        // A send error means the engine (receiver) is gone — shutdown.
        if tx.send(done).is_err() {
            return;
        }
    }
}

/// The engine: shard senders + worker handles on one side, the pending
/// table and overlap counters on the other. Owned by `Shampoo` behind an
/// `Option<Mutex<…>>` (interior mutability for the `&self` checkpoint
/// path); all methods run on the step thread.
pub(crate) struct AsyncRefresh {
    shard_of_unit: Vec<usize>,
    shards: Vec<mpsc::Sender<AsyncJob>>,
    done_rx: mpsc::Receiver<AsyncDone>,
    handles: Vec<JoinHandle<()>>,
    pending: Vec<Option<Pending>>,
    staleness: u64,
    pub stats: AsyncRefreshStats,
}

impl AsyncRefresh {
    /// Spawn `shards` workers (0 = auto) and build the per-unit shard map.
    pub fn new(units: &[UnitId], cfg: &ShampooConfig) -> AsyncRefresh {
        let shards = if cfg.async_shards == 0 {
            crate::util::pool::default_threads().clamp(1, 4)
        } else {
            cfg.async_shards
        };
        let (done_tx, done_rx) = mpsc::channel();
        let mut senders = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = mpsc::channel();
            let dtx = done_tx.clone();
            let wcfg = *cfg;
            handles.push(std::thread::spawn(move || worker_loop(rx, dtx, wcfg)));
            senders.push(tx);
        }
        AsyncRefresh {
            shard_of_unit: units.iter().map(|&id| shard_of(id, shards)).collect(),
            shards: senders,
            done_rx,
            handles,
            pending: units.iter().map(|_| None).collect(),
            staleness: cfg.max_async_staleness.max(1),
            stats: AsyncRefreshStats::default(),
        }
    }

    /// Whether a unit has a submission that has not been published yet
    /// (in flight on a worker, or computed and buffered for its due step).
    pub fn in_flight(&self, unit: usize) -> bool {
        self.pending[unit].is_some()
    }

    /// Count a planned refresh skipped because the unit was already in
    /// flight.
    pub fn note_coalesced(&mut self) {
        self.stats.coalesced += 1;
    }

    /// Called once at the end of every step: overlap bookkeeping.
    pub fn note_step_end(&mut self) {
        let in_flight = self.pending.iter().filter(|p| p.is_some()).count();
        self.stats.max_in_flight = self.stats.max_in_flight.max(in_flight);
        if in_flight > 0 {
            self.stats.steps_overlapped += 1;
        }
    }

    /// Ship one refresh job to the unit's shard. The caller has already run
    /// the coalescing and quarantine gates.
    pub fn submit(
        &mut self,
        unit: usize,
        submit_step: u64,
        forced: bool,
        gram: Matrix,
        pending_at_submit: f32,
    ) {
        debug_assert!(self.pending[unit].is_none(), "submit over an in-flight unit");
        self.pending[unit] = Some(Pending {
            submit_step,
            due_step: submit_step + self.staleness,
            pending_at_submit,
            done: None,
        });
        self.stats.submitted += 1;
        // A send error means the worker died (panicked); surface the job as
        // a compute failure at the due step instead of wedging the barrier.
        let sent = self.shards[self.shard_of_unit[unit]].send(AsyncJob { unit, forced, gram });
        if sent.is_err() {
            if let Some(p) = self.pending[unit].as_mut() {
                p.done = Some(AsyncDone { unit, result: None, finished_at: Instant::now() });
            }
        }
    }

    /// Drain the completion channel without blocking (early completions are
    /// buffered against their due step).
    fn reap_ready(&mut self) {
        while let Ok(d) = self.done_rx.try_recv() {
            let unit = d.unit;
            if let Some(p) = self.pending[unit].as_mut() {
                p.done = Some(d);
            }
        }
    }

    /// Block until `unit`'s completion arrives, buffering completions of
    /// other units reaped along the way. Returns the stall wall-clock.
    fn wait_for(&mut self, unit: usize) -> f64 {
        let t0 = Instant::now();
        loop {
            if self.pending[unit].as_ref().is_some_and(|p| p.done.is_some()) {
                return t0.elapsed().as_secs_f64();
            }
            match self.done_rx.recv() {
                Ok(d) => {
                    let u = d.unit;
                    if let Some(p) = self.pending[u].as_mut() {
                        p.done = Some(d);
                    }
                }
                Err(_) => {
                    // All workers gone (panicked): mark the unit failed so
                    // the publish path degrades to stale/floor service.
                    if let Some(p) = self.pending[unit].as_mut() {
                        p.done =
                            Some(AsyncDone { unit, result: None, finished_at: Instant::now() });
                    }
                    return t0.elapsed().as_secs_f64();
                }
            }
        }
    }

    /// Collect every unit whose due step has arrived, in unit-index order,
    /// blocking at the staleness barrier where a worker is not done. Called
    /// at the START of each step, before planning — the publishes are part
    /// of step `step`'s pre-state.
    pub fn collect_due(&mut self, step: u64) -> Vec<DuePublish> {
        self.reap_ready();
        let due_units: Vec<usize> = self
            .pending
            .iter()
            .enumerate()
            .filter_map(|(u, p)| p.as_ref().filter(|p| p.due_step <= step).map(|_| u))
            .collect();
        let mut out = Vec::with_capacity(due_units.len());
        for unit in due_units {
            if !self.pending[unit].as_ref().is_some_and(|p| p.done.is_some()) {
                let stalled = self.wait_for(unit);
                self.stats.barrier_stalls += 1;
                self.stats.barrier_stall_secs += stalled;
            }
            let p = self.pending[unit].take().expect("due unit must be pending");
            let d = p.done.expect("waited-for unit must be done");
            let latency = d.finished_at.elapsed().as_secs_f64();
            self.stats.publish_latency_secs += latency;
            self.stats.max_publish_latency_secs = self.stats.max_publish_latency_secs.max(latency);
            self.stats.max_publish_lag =
                self.stats.max_publish_lag.max(step.saturating_sub(p.submit_step));
            self.stats.published += 1;
            out.push(DuePublish {
                unit,
                submit_step: p.submit_step,
                pending_at_submit: p.pending_at_submit,
                result: d.result,
            });
        }
        out
    }

    /// Wait for every in-flight completion WITHOUT publishing — the
    /// checkpoint barrier. After this, every `Pending` holds its result and
    /// [`AsyncRefresh::write_pending`] serializes a complete picture; the
    /// trajectory is untouched (draining only waits, it never publishes).
    pub fn drain(&mut self) {
        for unit in 0..self.pending.len() {
            if self.pending[unit].is_some() {
                self.wait_for(unit);
            }
        }
    }

    /// Serialize the drained pending table (call [`AsyncRefresh::drain`]
    /// first). Format: count, then per record — unit, submit step, due
    /// step, pending-norm watermark, outcome tag (0 = compute failed,
    /// else [`FallbackOutcome::code`]), and the root matrix when present.
    pub fn write_pending(&self, out: &mut ByteWriter) {
        let live: Vec<(usize, &Pending)> = self
            .pending
            .iter()
            .enumerate()
            .filter_map(|(u, p)| p.as_ref().map(|p| (u, p)))
            .collect();
        out.put_u64(live.len() as u64);
        for (unit, p) in live {
            out.put_u64(unit as u64);
            out.put_u64(p.submit_step);
            out.put_u64(p.due_step);
            out.put_f32(p.pending_at_submit);
            let done = p.done.as_ref().expect("write_pending requires a drained engine");
            match &done.result {
                Some((x, outcome)) => {
                    out.put_u8(outcome.code());
                    out.put_u64(x.rows() as u64);
                    out.put_u64(x.cols() as u64);
                    out.put_f32s(x.data());
                }
                None => out.put_u8(0),
            }
        }
    }

    /// Inverse of [`AsyncRefresh::write_pending`]: repopulate the pending
    /// table with already-computed results. Publishes then happen at the
    /// original due steps, replaying the uninterrupted trajectory.
    pub fn read_pending(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        for p in &mut self.pending {
            *p = None;
        }
        let n = r.get_len()?;
        for _ in 0..n {
            let unit = r.get_len()?;
            crate::ensure!(unit < self.pending.len(), "pending unit {unit} out of range");
            let submit_step = r.get_u64()?;
            let due_step = r.get_u64()?;
            let pending_at_submit = r.get_f32()?;
            let tag = r.get_u8()?;
            let result = if tag == 0 {
                None
            } else {
                let outcome = FallbackOutcome::from_code(tag)
                    .ok_or_else(|| crate::anyhow!("unknown fallback outcome tag {tag}"))?;
                let rows = r.get_len()?;
                let cols = r.get_len()?;
                let data = r.get_f32s()?;
                crate::ensure!(
                    data.len() == rows * cols,
                    "pending root shape mismatch: {rows}x{cols} vs {} elems",
                    data.len()
                );
                Some((Matrix::from_vec(rows, cols, data), outcome))
            };
            self.pending[unit] = Some(Pending {
                submit_step,
                due_step,
                pending_at_submit,
                done: Some(AsyncDone { unit, result, finished_at: Instant::now() }),
            });
        }
        Ok(())
    }
}

impl Drop for AsyncRefresh {
    fn drop(&mut self) {
        // Closing the job channels ends the worker loops; join so no
        // worker outlives the optimizer.
        self.shards.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shampoo::state::Side;

    fn uid(layer: u32, block: u32, side: Side) -> UnitId {
        UnitId { layer, block, side }
    }

    #[test]
    fn shard_assignment_is_stable_and_in_range() {
        for shards in [1usize, 2, 3, 7] {
            for layer in 0..4u32 {
                for block in 0..3u32 {
                    for side in Side::BOTH {
                        let id = uid(layer, block, side);
                        let s = shard_of(id, shards);
                        assert!(s < shards);
                        assert_eq!(s, shard_of(id, shards), "hash must be deterministic");
                    }
                }
            }
        }
    }

    #[test]
    fn shard_hash_separates_sides() {
        // Not a distribution test — just that the hash actually consumes
        // all three address fields (L and R of one block may collide for
        // some shard counts, but not for all of these).
        let mut seen = std::collections::HashSet::new();
        for layer in 0..8u32 {
            for side in Side::BOTH {
                seen.insert(shard_of(uid(layer, 0, side), 1024));
            }
        }
        assert!(seen.len() > 8, "hash should spread units, got {} buckets", seen.len());
    }

    #[test]
    fn submit_compute_collect_roundtrip() {
        // One real job through a real worker: a well-conditioned gram must
        // come back Healthy, publish exactly at submit + staleness, and the
        // stats must record the lifecycle.
        let units = [uid(0, 0, Side::L), uid(0, 0, Side::R)];
        let cfg = ShampooConfig { async_shards: 2, max_async_staleness: 3, ..Default::default() };
        let mut eng = AsyncRefresh::new(&units, &cfg);
        let mut gram = Matrix::eye(6);
        gram.add_diag(1.5);
        eng.submit(0, 10, false, gram, 0.25);
        assert!(eng.in_flight(0));
        assert!(!eng.in_flight(1));
        // Not due before submit + staleness.
        assert!(eng.collect_due(11).is_empty());
        assert!(eng.collect_due(12).is_empty());
        let due = eng.collect_due(13);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].unit, 0);
        assert_eq!(due[0].submit_step, 10);
        assert_eq!(due[0].pending_at_submit, 0.25);
        let (x, outcome) = due[0].result.as_ref().expect("identity-like gram must compute");
        assert_eq!(outcome, &FallbackOutcome::Healthy);
        assert!(!x.has_non_finite());
        assert!(!eng.in_flight(0));
        assert_eq!(eng.stats.submitted, 1);
        assert_eq!(eng.stats.published, 1);
        assert!(eng.stats.max_publish_lag <= 3);
    }

    #[test]
    fn forced_jobs_return_no_result() {
        let units = [uid(0, 0, Side::L)];
        let cfg = ShampooConfig { async_shards: 1, max_async_staleness: 1, ..Default::default() };
        let mut eng = AsyncRefresh::new(&units, &cfg);
        eng.submit(0, 5, true, Matrix::eye(4), 0.0);
        let due = eng.collect_due(6);
        assert_eq!(due.len(), 1);
        assert!(due[0].result.is_none(), "forced failure must surface as compute failure");
    }

    #[test]
    fn drained_pending_table_roundtrips_through_bytes() {
        let units = [uid(0, 0, Side::L), uid(0, 0, Side::R), uid(1, 0, Side::L)];
        let cfg = ShampooConfig { async_shards: 2, max_async_staleness: 4, ..Default::default() };
        let mut eng = AsyncRefresh::new(&units, &cfg);
        let mut gram = Matrix::eye(5);
        gram.add_diag(0.5);
        eng.submit(1, 20, false, gram, 1.5);
        eng.submit(2, 21, true, Matrix::eye(3), 0.0);
        eng.drain();
        let mut w = ByteWriter::new();
        eng.write_pending(&mut w);
        let bytes = w.into_bytes();

        let mut eng2 = AsyncRefresh::new(&units, &cfg);
        let mut r = ByteReader::new(&bytes);
        eng2.read_pending(&mut r).expect("roundtrip");
        assert!(!eng2.in_flight(0));
        assert!(eng2.in_flight(1));
        assert!(eng2.in_flight(2));
        // Publishes land at the original due steps with identical payloads.
        assert!(eng2.collect_due(23).is_empty());
        let due = eng2.collect_due(25);
        assert_eq!(due.len(), 2);
        assert_eq!((due[0].unit, due[0].submit_step), (1, 20));
        assert_eq!(due[0].pending_at_submit, 1.5);
        assert!(due[0].result.is_some());
        assert_eq!((due[1].unit, due[1].submit_step), (2, 21));
        assert!(due[1].result.is_none());

        // The restored payload is bit-identical to the original's.
        let orig = eng.collect_due(25);
        let (a, _) = orig[0].result.as_ref().unwrap();
        let restored = due[0].result.as_ref().map(|(m, _)| m).unwrap();
        assert_eq!(a.data(), restored.data());
    }
}

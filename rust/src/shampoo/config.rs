//! Shampoo configuration (paper App. C.3 defaults).

use crate::linalg::schur_newton::SchurNewtonConfig;
use crate::quant::QuantConfig;

/// Which preconditioner representation the optimizer keeps.
///
/// Each variant is sugar for a pair of [`crate::quant::codec`] registry
/// keys (one for the Gram sides, one for the inverse roots); representations
/// outside this list are reached through [`ShampooConfig::side_codec`] /
/// [`ShampooConfig::root_codec`] overrides, which accept ANY registered key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShampooVariant {
    /// Algorithm 2: f32 `(L, R, L^{-1/4}, R^{-1/4})`.
    Full32,
    /// Sec. 4.1: 4-bit off-diagonal block-wise quantization of all four
    /// matrices ("vanilla 4-bit Shampoo", the paper's VQ baseline).
    Vq4,
    /// Sec. 4.2/4.3: 4-bit Cholesky quantization — store quantized Cholesky
    /// factors of `L, R` (+ 4-bit inverse roots). With `error_feedback` the
    /// EF state rides in the upper triangle (Alg. 1, Fig. 2).
    Cq4 { error_feedback: bool },
    /// 8-bit block-wise quantization of all four matrices, f32 diagonals —
    /// the half-memory middle ground of "Memory Efficient Optimizers with
    /// 4-bit States" (arXiv 2309.01507)-style 8-bit baselines.
    Bw8,
}

impl ShampooVariant {
    pub fn name(&self) -> &'static str {
        match self {
            ShampooVariant::Full32 => "32-bit",
            ShampooVariant::Vq4 => "4-bit (VQ)",
            ShampooVariant::Cq4 { error_feedback: false } => "4-bit (CQ)",
            ShampooVariant::Cq4 { error_feedback: true } => "4-bit (CQ+EF)",
            ShampooVariant::Bw8 => "8-bit (BW)",
        }
    }

    /// Canonical registry key (the spelling `train::registry` and the
    /// optimizer builders resolve; `parse` accepts the aliases).
    pub fn key(&self) -> &'static str {
        match self {
            ShampooVariant::Full32 => "32bit",
            ShampooVariant::Vq4 => "vq",
            ShampooVariant::Cq4 { error_feedback: false } => "cq",
            ShampooVariant::Cq4 { error_feedback: true } => "cq-ef",
            ShampooVariant::Bw8 => "bw8",
        }
    }

    /// Parse from the config-file spelling.
    pub fn parse(s: &str) -> Option<ShampooVariant> {
        match s {
            "32bit" | "full32" | "32-bit" => Some(ShampooVariant::Full32),
            "vq" | "vq4" | "4bit-vq" => Some(ShampooVariant::Vq4),
            "cq" | "cq4" | "4bit-cq" => Some(ShampooVariant::Cq4 { error_feedback: false }),
            "cq-ef" | "cqef" | "4bit-cq-ef" | "ours" => {
                Some(ShampooVariant::Cq4 { error_feedback: true })
            }
            "bw8" | "8bit" | "8bit-bw" => Some(ShampooVariant::Bw8),
            _ => None,
        }
    }

    /// Paper-style row label for a full optimizer stack — the ONE place the
    /// "`BASE` + `variant` Shampoo" composition lives (`Optimizer::name`
    /// impls and `OptimizerSpec::label` both call this).
    pub fn stack_label(&self, base: crate::optim::OptimizerKind) -> String {
        format!("{} + {} Shampoo", base.name().to_uppercase(), self.name())
    }

    /// Placeholder variant carried by specs built from a runtime-registered
    /// stack key (the keyed builder overrides it; the memory model uses it
    /// as its footprint approximation).
    pub fn default_for_custom() -> ShampooVariant {
        ShampooVariant::Cq4 { error_feedback: true }
    }
}

/// Full Shampoo configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShampooConfig {
    pub variant: ShampooVariant,
    /// Preconditioner EMA momentum β (paper: 0.95).
    pub beta: f32,
    /// Error-state EMA momentum βₑ (paper: 0.95).
    pub beta_e: f32,
    /// Numerical-stability constant ε (paper: 1e-6).
    pub eps: f32,
    /// Gram/Cholesky update interval T₁ (paper: 100 for CIFAR-scale).
    pub t1: u64,
    /// Inverse-root update interval T₂ (paper: 500 for CIFAR-scale).
    pub t2: u64,
    /// Max preconditioner order: larger dims are blocked (paper: 1200).
    pub max_order: usize,
    /// Block-wise quantizer settings (b=4, B=64, linear-2).
    pub quant: QuantConfig,
    /// Learning-rate grafting (Eq. 13). `false` disables grafting entirely
    /// (equivalent to `graft = "none"`); `true` applies the [`Self::graft`]
    /// variant.
    pub grafting: bool,
    /// Grafting variant, resolved in `optim::grafting`'s string-keyed
    /// registry: `"sgd"` (the default — today's Eq. 13 `‖G‖_F` norm graft),
    /// `"adagrad"` / `"rmsprop"` (per-layer second-moment accumulators),
    /// `"sqrt-n"` (dimension-normalized constant), or any
    /// runtime-registered key. Ignored when [`Self::grafting`] is `false`.
    pub graft: &'static str,
    /// Scalable-Shampoo warmup: steps `< start_preconditioning_step` take
    /// base-optimizer-only updates — the scheduler plans zero refresh
    /// units, inverse-root slots stay unallocated (uncounted in
    /// `state_bytes` and the memory model), and the trajectory is
    /// bit-identical to the bare base optimizer (under the default `sgd`
    /// graft, whose scale is exactly 1 on unpreconditioned updates).
    /// 0 (the default) preconditions from the first step.
    pub start_preconditioning_step: u64,
    /// Scalable-Shampoo opt-out for embedding-table-shaped layers: a layer
    /// with `max(rows, cols)` beyond this bound is routed to the grafted
    /// base update with ZERO codec state (no blocks, no gram/root slots).
    /// 0 (the default) disables the bound.
    pub no_preconditioning_for_layers_with_dim_gt: usize,
    /// Scalable-Shampoo shape interpretation: collapse a ≥3-D tensor into
    /// the list of its trailing-two-dim matrices before blocking (e.g.
    /// `[4, 3, 1024, 512]` → 12 × `[1024, 512]` L/R statistics stacked in
    /// one layer) instead of flattening all leading dims into the rows.
    /// Only observable through `Shampoo::new_nd`; 2-D layers are
    /// unaffected. Default `false` = flatten.
    pub shape_interpretation: bool,
    /// Tab. 2 ablation: quantize the diagonal too ("Original" block-wise
    /// quantization). Default false = off-diagonal quantization.
    pub vq_quantize_diag: bool,
    /// Schur–Newton settings for the inverse 4th root.
    pub schur: SchurNewtonConfig,
    /// Override the Gram-side codec with ANY registered key — built-ins
    /// outside the variant set (`"ec4"`, `"f16"`, `"cq-r1"`) or one added
    /// via `quant::codec::register`. `None` = derive from `variant`. The
    /// `train::registry` keys of the same names are sugar for these
    /// overrides.
    pub side_codec: Option<&'static str>,
    /// Override the inverse-root codec likewise.
    pub root_codec: Option<&'static str>,
    /// Refresh-scheduler policy key, resolved in `shampoo::scheduler`
    /// (`"every-n"` reproduces the classic `k % T1`/`k % T2` behavior
    /// bit-for-bit; `"staggered"`/`"staleness"` spread the work; any
    /// runtime-registered key works — same open-world contract as the
    /// codec registry).
    pub refresh_policy: &'static str,
    /// Per-step root-refresh unit budget for budgeted policies
    /// (`"staleness"`). 0 = automatic: ⌈units/T₂⌉, the staggered rate.
    pub refresh_budget: usize,
    /// Numerical-health guard: a unit whose root refresh falls through to
    /// the stale/floor rungs this many *consecutive* times is quarantined
    /// to the diagonal floor. Inert on healthy runs (the counter only
    /// advances on ladder failures).
    pub quarantine_after: u32,
    /// Steps between probation retries of a quarantined unit: the unit is
    /// served from the floor until this many steps have passed since
    /// quarantine, then gets one full refresh attempt (release on success,
    /// timer reset on failure).
    pub probation_interval: u64,
    /// Run inverse-root refreshes on the sharded async engine
    /// (`shampoo::async_engine`): planned roots are submitted to persistent
    /// worker shards and published `max_async_staleness` steps later, so
    /// refresh overlaps subsequent steps. `false` (the default) keeps the
    /// synchronous executor and reproduces its trajectories bit-identically.
    pub async_refresh: bool,
    /// Worker shards for the async engine. 0 = automatic
    /// (`min(default_threads(), 4)`). Shard count never affects the
    /// trajectory — only throughput.
    pub async_shards: usize,
    /// The bounded-staleness contract: an async root submitted at step `s`
    /// is published at the start of step `s + max_async_staleness`,
    /// blocking there if the worker has not finished (the synchronous
    /// barrier). Minimum 1; larger values buy more overlap at the cost of
    /// staler roots.
    pub max_async_staleness: u64,
}

impl ShampooConfig {
    /// Codec registry key for the Gram sides `L`/`R` (before the
    /// small-tensor exemption, which the state layer applies per block).
    pub fn side_codec_key(&self) -> &'static str {
        if let Some(key) = self.side_codec {
            return key;
        }
        match self.variant {
            ShampooVariant::Full32 => "f32",
            ShampooVariant::Vq4 if self.vq_quantize_diag => "vq4-full",
            ShampooVariant::Vq4 => "vq4",
            ShampooVariant::Cq4 { error_feedback: false } => "cq4",
            ShampooVariant::Cq4 { error_feedback: true } => "cq4-ef",
            ShampooVariant::Bw8 => "bw8",
        }
    }

    /// Codec registry key for the inverse roots `L̂`/`R̂`. Roots are applied
    /// every step and therefore never Cholesky-factored (Sec. 4.2): the CQ
    /// variants keep 4-bit off-diagonal roots.
    pub fn root_codec_key(&self) -> &'static str {
        if let Some(key) = self.root_codec {
            return key;
        }
        match self.variant {
            ShampooVariant::Full32 => "f32",
            ShampooVariant::Bw8 => "bw8",
            _ if self.vq_quantize_diag => "vq4-full",
            _ => "vq4",
        }
    }

    /// Grafting registry key actually in effect: `"none"` when
    /// [`Self::grafting`] is off, otherwise [`Self::graft`].
    pub fn graft_key(&self) -> &'static str {
        if self.grafting {
            self.graft
        } else {
            "none"
        }
    }
}

impl Default for ShampooConfig {
    fn default() -> Self {
        ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            beta: 0.95,
            beta_e: 0.95,
            eps: 1e-6,
            t1: 100,
            t2: 500,
            max_order: 1200,
            quant: QuantConfig::default(),
            grafting: true,
            graft: "sgd",
            start_preconditioning_step: 0,
            no_preconditioning_for_layers_with_dim_gt: 0,
            shape_interpretation: false,
            vq_quantize_diag: false,
            schur: SchurNewtonConfig::default(),
            side_codec: None,
            root_codec: None,
            refresh_policy: "every-n",
            refresh_budget: 0,
            quarantine_after: 3,
            probation_interval: 50,
            async_refresh: false,
            async_shards: 0,
            max_async_staleness: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_c3() {
        let c = ShampooConfig::default();
        assert_eq!(c.beta, 0.95);
        assert_eq!(c.beta_e, 0.95);
        assert_eq!(c.eps, 1e-6);
        assert_eq!(c.quant.bits, 4);
        assert_eq!(c.quant.block, 64);
        assert_eq!(c.max_order, 1200);
        assert!(c.grafting);
    }

    #[test]
    fn workload_knobs_default_off_and_graft_keys_resolve() {
        let c = ShampooConfig::default();
        // Defaults must reproduce pre-workload-engine trajectories
        // bit-identically: Eq. 13 sgd graft, no warmup, no dim bound, flat
        // shape interpretation.
        assert_eq!(c.graft, "sgd");
        assert_eq!(c.graft_key(), "sgd");
        assert_eq!(c.start_preconditioning_step, 0);
        assert_eq!(c.no_preconditioning_for_layers_with_dim_gt, 0);
        assert!(!c.shape_interpretation);
        let off = ShampooConfig { grafting: false, ..Default::default() };
        assert_eq!(off.graft_key(), "none", "grafting=false routes to the none graft");
        for key in ["none", "sgd", "adagrad", "rmsprop", "sqrt-n"] {
            assert!(crate::optim::grafting::lookup(key).is_some(), "graft '{key}' not registered");
        }
    }

    #[test]
    fn variant_parsing() {
        assert_eq!(ShampooVariant::parse("32bit"), Some(ShampooVariant::Full32));
        assert_eq!(ShampooVariant::parse("vq"), Some(ShampooVariant::Vq4));
        assert_eq!(
            ShampooVariant::parse("cq-ef"),
            Some(ShampooVariant::Cq4 { error_feedback: true })
        );
        assert_eq!(ShampooVariant::parse("nope"), None);
    }

    #[test]
    fn variant_names_match_tables() {
        assert_eq!(ShampooVariant::Vq4.name(), "4-bit (VQ)");
        assert_eq!(ShampooVariant::Cq4 { error_feedback: true }.name(), "4-bit (CQ+EF)");
        assert_eq!(ShampooVariant::Bw8.name(), "8-bit (BW)");
    }

    #[test]
    fn canonical_keys_parse_back() {
        for v in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: false },
            ShampooVariant::Cq4 { error_feedback: true },
            ShampooVariant::Bw8,
        ] {
            assert_eq!(ShampooVariant::parse(v.key()), Some(v), "key '{}'", v.key());
        }
    }

    #[test]
    fn codec_keys_resolve_in_registry() {
        for v in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: false },
            ShampooVariant::Cq4 { error_feedback: true },
            ShampooVariant::Bw8,
        ] {
            let cfg = ShampooConfig { variant: v, ..Default::default() };
            for key in [cfg.side_codec_key(), cfg.root_codec_key()] {
                assert!(
                    crate::quant::codec::lookup(key).is_some(),
                    "{v:?}: codec '{key}' not registered"
                );
            }
        }
    }

    #[test]
    fn default_health_knobs_are_sane() {
        let c = ShampooConfig::default();
        assert!(c.quarantine_after >= 1, "0 would quarantine on the first failure");
        assert!(c.probation_interval >= 1, "0 would retry every step");
    }

    #[test]
    fn async_refresh_defaults_off_with_sane_envelope() {
        let c = ShampooConfig::default();
        assert!(!c.async_refresh, "async must be opt-in: off reproduces sync bit-identically");
        assert_eq!(c.async_shards, 0, "0 = auto shard count");
        assert!(c.max_async_staleness >= 1, "a 0 staleness window could never overlap");
    }

    #[test]
    fn default_refresh_policy_is_classic_and_registered() {
        let c = ShampooConfig::default();
        assert_eq!(c.refresh_policy, "every-n");
        assert_eq!(c.refresh_budget, 0);
        for key in ["every-n", "staggered", "staleness"] {
            assert!(
                crate::shampoo::scheduler::lookup(key).is_some(),
                "refresh policy '{key}' not registered"
            );
        }
    }

    #[test]
    fn codec_family_override_keys_are_registered() {
        // The keys the ec4/f16/cq-r1 stack builders route through must
        // resolve in the codec registry (side AND root spellings).
        for key in ["ec4", "f16", "cq-r1", "vq4"] {
            assert!(crate::quant::codec::lookup(key).is_some(), "codec '{key}' not registered");
        }
    }

    #[test]
    fn codec_overrides_win() {
        let cfg = ShampooConfig {
            side_codec: Some("bw8"),
            root_codec: Some("f32"),
            ..Default::default()
        };
        assert_eq!(cfg.side_codec_key(), "bw8");
        assert_eq!(cfg.root_codec_key(), "f32");
    }

    #[test]
    fn stack_label_composes_once() {
        use crate::optim::OptimizerKind;
        let v = ShampooVariant::Cq4 { error_feedback: true };
        assert_eq!(v.stack_label(OptimizerKind::Sgdm), "SGDM + 4-bit (CQ+EF) Shampoo");
    }
}

//! Shampoo configuration (paper App. C.3 defaults).

use crate::linalg::schur_newton::SchurNewtonConfig;
use crate::quant::QuantConfig;

/// Which preconditioner representation the optimizer keeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShampooVariant {
    /// Algorithm 2: f32 `(L, R, L^{-1/4}, R^{-1/4})`.
    Full32,
    /// Sec. 4.1: 4-bit off-diagonal block-wise quantization of all four
    /// matrices ("vanilla 4-bit Shampoo", the paper's VQ baseline).
    Vq4,
    /// Sec. 4.2/4.3: 4-bit Cholesky quantization — store quantized Cholesky
    /// factors of `L, R` (+ 4-bit inverse roots). With `error_feedback` the
    /// EF state rides in the upper triangle (Alg. 1, Fig. 2).
    Cq4 { error_feedback: bool },
}

impl ShampooVariant {
    pub fn name(&self) -> &'static str {
        match self {
            ShampooVariant::Full32 => "32-bit",
            ShampooVariant::Vq4 => "4-bit (VQ)",
            ShampooVariant::Cq4 { error_feedback: false } => "4-bit (CQ)",
            ShampooVariant::Cq4 { error_feedback: true } => "4-bit (CQ+EF)",
        }
    }

    /// Parse from the config-file spelling.
    pub fn parse(s: &str) -> Option<ShampooVariant> {
        match s {
            "32bit" | "full32" | "32-bit" => Some(ShampooVariant::Full32),
            "vq" | "vq4" | "4bit-vq" => Some(ShampooVariant::Vq4),
            "cq" | "cq4" | "4bit-cq" => Some(ShampooVariant::Cq4 { error_feedback: false }),
            "cq-ef" | "cqef" | "4bit-cq-ef" | "ours" => {
                Some(ShampooVariant::Cq4 { error_feedback: true })
            }
            _ => None,
        }
    }
}

/// Full Shampoo configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShampooConfig {
    pub variant: ShampooVariant,
    /// Preconditioner EMA momentum β (paper: 0.95).
    pub beta: f32,
    /// Error-state EMA momentum βₑ (paper: 0.95).
    pub beta_e: f32,
    /// Numerical-stability constant ε (paper: 1e-6).
    pub eps: f32,
    /// Gram/Cholesky update interval T₁ (paper: 100 for CIFAR-scale).
    pub t1: u64,
    /// Inverse-root update interval T₂ (paper: 500 for CIFAR-scale).
    pub t2: u64,
    /// Max preconditioner order: larger dims are blocked (paper: 1200).
    pub max_order: usize,
    /// Block-wise quantizer settings (b=4, B=64, linear-2).
    pub quant: QuantConfig,
    /// Learning-rate grafting (Eq. 13).
    pub grafting: bool,
    /// Tab. 2 ablation: quantize the diagonal too ("Original" block-wise
    /// quantization). Default false = off-diagonal quantization.
    pub vq_quantize_diag: bool,
    /// Schur–Newton settings for the inverse 4th root.
    pub schur: SchurNewtonConfig,
}

impl Default for ShampooConfig {
    fn default() -> Self {
        ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            beta: 0.95,
            beta_e: 0.95,
            eps: 1e-6,
            t1: 100,
            t2: 500,
            max_order: 1200,
            quant: QuantConfig::default(),
            grafting: true,
            vq_quantize_diag: false,
            schur: SchurNewtonConfig::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_c3() {
        let c = ShampooConfig::default();
        assert_eq!(c.beta, 0.95);
        assert_eq!(c.beta_e, 0.95);
        assert_eq!(c.eps, 1e-6);
        assert_eq!(c.quant.bits, 4);
        assert_eq!(c.quant.block, 64);
        assert_eq!(c.max_order, 1200);
        assert!(c.grafting);
    }

    #[test]
    fn variant_parsing() {
        assert_eq!(ShampooVariant::parse("32bit"), Some(ShampooVariant::Full32));
        assert_eq!(ShampooVariant::parse("vq"), Some(ShampooVariant::Vq4));
        assert_eq!(
            ShampooVariant::parse("cq-ef"),
            Some(ShampooVariant::Cq4 { error_feedback: true })
        );
        assert_eq!(ShampooVariant::parse("nope"), None);
    }

    #[test]
    fn variant_names_match_tables() {
        assert_eq!(ShampooVariant::Vq4.name(), "4-bit (VQ)");
        assert_eq!(ShampooVariant::Cq4 { error_feedback: true }.name(), "4-bit (CQ+EF)");
    }
}

//! The Shampoo optimizer family (paper Algorithms 1 & 2).
//!
//! * [`config`] — variants as sugar over preconditioner-codec keys: 32-bit
//!   (Alg. 2), 4-bit vanilla quantization (Sec. 4.1), 4-bit Cholesky
//!   quantization (Sec. 4.2), 4-bit CQ with error feedback (Sec. 4.3,
//!   Alg. 1), and 8-bit block-wise — plus `side_codec`/`root_codec`
//!   overrides that accept ANY key registered in `quant::codec`.
//! * [`blocking`] — layer-wise max-order blocking (App. C.3: large dims are
//!   split so each preconditioner stays below a cap), with balanced strips
//!   so refresh units do comparable work.
//! * [`state`] — per-block storage behind `PrecondCodec` trait objects,
//!   with exact byte accounting and per-unit refresh metadata.
//! * [`scheduler`] — the refresh-scheduler engine: a [`RefreshScheduler`]
//!   policy decides per step which `(layer, block, side)` units recompute
//!   their Gram EMA / inverse root (`every-n` | `staggered` | `staleness` |
//!   registered keys), and a work-queue executor runs them on the
//!   `util::pool` workers while untouched layers precondition-and-apply.
//! * [`async_engine`] — the sharded async-refresh engine: planned roots are
//!   stripped from the synchronous plan, computed on persistent worker
//!   shards from gram snapshots, and published `max_async_staleness` steps
//!   later under a deterministic bounded-staleness contract
//!   (`cfg.async_refresh`, default off).
//! * [`Shampoo`] — the driver: plan → execute-refresh → apply each step,
//!   with the classic behavior (Gram EMA every `T1` steps, inverse roots
//!   every `T2`) reproduced bit-for-bit by the default `every-n` policy.
//!   Scalable-Shampoo workload knobs ride on the config: string-keyed
//!   grafting (the `optim::grafting` registry), the
//!   `start_preconditioning_step` warmup, ≥3-D `shape_interpretation`
//!   chunking (via [`Shampoo::new_nd`]), and
//!   `no_preconditioning_for_layers_with_dim_gt` opt-outs.

pub(crate) mod async_engine;
pub mod blocking;
pub mod config;
pub mod scheduler;
pub mod state;

pub use blocking::Blocking;
pub use config::{ShampooConfig, ShampooVariant};
pub use scheduler::{RefreshPlan, RefreshScheduler, UnitId, UnitInfo};
pub use state::{FallbackOutcome, LayerState, Side, UnitHealth, UnitMeta};

use crate::linalg::{Matrix, ScratchArena};
use crate::metrics::{HealthLedger, HealthStats, RefreshStats};
use crate::optim::{grafting, BaseOptimizer, Graft, GraftParams, Optimizer};
use crate::quant::codec::CodecCtx;
use crate::quant::BlockQuantizer;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;
use crate::util::fault::FaultPlan;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Named view over [`Shampoo::scratch_stats`]: the aggregate of every
/// parked arena's [`crate::linalg::ScratchStats`] counters plus the pool
/// size itself.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShampooScratchStats {
    /// Arenas currently parked in the pool (peak concurrent workers).
    pub arenas: usize,
    /// Σ matrix takes served from an arena's free list.
    pub hits: usize,
    /// Σ matrix takes that had to allocate.
    pub misses: usize,
    /// Σ GEMM-plan packing-buffer growths.
    pub plan_grows: usize,
}

/// Shampoo wrapping a first-order base optimizer `F` (Algorithm 1).
pub struct Shampoo {
    pub base: BaseOptimizer,
    pub cfg: ShampooConfig,
    pub layers: Vec<LayerState>,
    /// Per-layer grafting state (`cfg.graft`), applied to the preconditioned
    /// update before the base rule. Stateless keys (`none`/`sgd`/`sqrt-n`)
    /// hold zero bytes; `adagrad`/`rmsprop` carry a full-rank second-moment
    /// accumulator counted in `state_bytes` and checkpointed alongside the
    /// layer codecs.
    grafts: Vec<Box<dyn Graft>>,
    ctx: CodecCtx,
    /// The refresh policy (chosen by `cfg.refresh_policy`).
    sched: Box<dyn RefreshScheduler>,
    /// Unit table: flat `(layer, block, side)` addressing, `[L, R]` per
    /// block — the executor relies on this pairing.
    units: Vec<UnitId>,
    /// Reused per-step buffers (scheduler input snapshot, decision, and
    /// the executor's grouped task list).
    infos: Vec<UnitInfo>,
    plan: RefreshPlan,
    tasks: Vec<scheduler::Task>,
    /// Per-step refresh telemetry (unit counts, wall-clock spikes).
    stats: RefreshStats,
    /// Deterministic fault schedule (test/chaos hook; `None` in production
    /// runs). Set through [`Optimizer::set_fault_plan`].
    fault: Option<FaultPlan>,
    /// Lock-free health accumulator the executor's workers count on,
    /// drained into `stats.health` once per step.
    ledger: HealthLedger,
    /// Worker-checked-out scratch arenas: each step worker pops one, runs
    /// its tasks' store/load/root pipeline out of it, and returns it. The
    /// pool grows to the peak concurrent worker count and then every
    /// steady-state step is allocation-free (see `scratch_stats`).
    scratch_pool: Mutex<Vec<ScratchArena>>,
    /// Sharded async-refresh engine (`cfg.async_refresh`): planned root
    /// units are stripped from the synchronous plan, computed on persistent
    /// worker shards from gram snapshots taken after this step's gram
    /// update, and published at the start of step `submit +
    /// max_async_staleness` in unit-index order. The `Mutex` only provides
    /// interior mutability for `write_state(&self)` draining; it is never
    /// contended (all access is from the step/checkpoint thread).
    async_eng: Option<Mutex<async_engine::AsyncRefresh>>,
}

impl Shampoo {
    /// Build for a fixed set of parameter shapes `(rows, cols)`.
    pub fn new(mut base: BaseOptimizer, cfg: ShampooConfig, shapes: &[(usize, usize)]) -> Shampoo {
        base.init(shapes.len());
        let ctx = Self::make_ctx(&cfg);
        let layers: Vec<LayerState> =
            shapes.iter().map(|&(m, n)| LayerState::new(m, n, &cfg, &ctx)).collect();
        Self::from_layers(base, cfg, ctx, layers)
    }

    /// Build for N-dimensional parameter shapes, applying the
    /// `shape_interpretation` knob: with it set, a tensor of rank ≥ 3 is
    /// read as a stack of matrices over its leading axes — `[4, 3, 1024,
    /// 512]` becomes 12 independent `[1024, 512]` chunks, each blocked and
    /// preconditioned on its own Gram pair — instead of one flattened
    /// `[12288, 512]` matrix whose row Gram would mix unrelated slices.
    /// The parameter the caller steps with is still the single collapsed
    /// `(∏ leading · rows, cols)` matrix; chunking only changes the block
    /// table. With the knob off (the default) every shape is flattened the
    /// classic way, bit-identical to [`Shampoo::new`] on collapsed shapes.
    /// Rank-0/1 shapes become column vectors (passthrough layers).
    pub fn new_nd(mut base: BaseOptimizer, cfg: ShampooConfig, shapes: &[Vec<usize>]) -> Shampoo {
        base.init(shapes.len());
        let ctx = Self::make_ctx(&cfg);
        let layers: Vec<LayerState> =
            shapes.iter().map(|s| Self::layer_for_nd(s, &cfg, &ctx)).collect();
        Self::from_layers(base, cfg, ctx, layers)
    }

    /// The collapsed `(rows, cols)` an ND shape steps with — what callers
    /// must size their parameter/gradient matrices to under [`new_nd`].
    pub fn collapsed_shape(shape: &[usize]) -> (usize, usize) {
        match shape {
            [] => (1, 1),
            &[n] => (n, 1),
            &[.., m, n] => (shape[..shape.len() - 2].iter().product::<usize>() * m, n),
        }
    }

    fn make_ctx(cfg: &ShampooConfig) -> CodecCtx {
        CodecCtx::new(cfg.eps, cfg.beta_e, Arc::new(BlockQuantizer::new(cfg.quant)))
    }

    /// Collapse one ND shape into a [`LayerState`] (see [`new_nd`]).
    fn layer_for_nd(shape: &[usize], cfg: &ShampooConfig, ctx: &CodecCtx) -> LayerState {
        match shape {
            [] => LayerState::new(1, 1, cfg, ctx),
            &[n] => LayerState::new(n, 1, cfg, ctx),
            &[m, n] => LayerState::new(m, n, cfg, ctx),
            &[.., m, n] => {
                let c: usize = shape[..shape.len() - 2].iter().product();
                if !cfg.shape_interpretation || c <= 1 || m <= 1 || n <= 1 {
                    return LayerState::new(c * m, n, cfg, ctx);
                }
                // One blocking table per chunk, offset down the row axis of
                // the collapsed (c·m, n) matrix the caller steps with.
                // Passthrough/opt-out is judged on chunk dims — the shapes
                // preconditioning would actually see.
                let mut blocks = Vec::new();
                for i in 0..c {
                    for mut b in Blocking::new(m, n, cfg.max_order).blocks {
                        b.r0 += i * m;
                        blocks.push(b);
                    }
                }
                let blocking = Blocking { m: c * m, n, max_order: cfg.max_order.max(1), blocks };
                let passthrough = m.min(n) <= 1 || LayerState::dim_opted_out(m, n, cfg);
                LayerState::from_blocking(c * m, n, blocking, passthrough, cfg, ctx)
            }
        }
    }

    fn from_layers(
        base: BaseOptimizer,
        cfg: ShampooConfig,
        ctx: CodecCtx,
        layers: Vec<LayerState>,
    ) -> Shampoo {
        let gp = GraftParams { eps: cfg.eps, beta: cfg.beta };
        let grafts: Vec<Box<dyn Graft>> = layers
            .iter()
            .map(|l| grafting::build_for(cfg.graft_key(), l.rows, l.cols, &gp))
            .collect();
        let mut units = Vec::new();
        for (li, layer) in layers.iter().enumerate() {
            for bi in 0..layer.blocks.len() {
                for side in Side::BOTH {
                    units.push(UnitId { layer: li as u32, block: bi as u32, side });
                }
            }
        }
        let sched = scheduler::build_for(&cfg);
        let async_eng = if cfg.async_refresh {
            Some(Mutex::new(async_engine::AsyncRefresh::new(&units, &cfg)))
        } else {
            None
        };
        Shampoo {
            base,
            cfg,
            layers,
            grafts,
            ctx,
            sched,
            units,
            infos: Vec::new(),
            plan: RefreshPlan::default(),
            tasks: Vec::new(),
            stats: RefreshStats::new(),
            fault: None,
            ledger: HealthLedger::new(),
            scratch_pool: Mutex::new(Vec::new()),
            async_eng,
        }
    }

    /// One optimization step (Algorithm 1 lines 2–16), in three phases:
    ///
    /// 1. **Plan** — the configured [`RefreshScheduler`] picks this step's
    ///    refresh units from their metadata (`step` is 1-based, the paper's
    ///    `k`; the default `every-n` policy refreshes all units at
    ///    `k % T1 == 0` / `k % T2 == 0`, exactly the classic behavior).
    /// 2. **Execute refresh** — scheduled units fan out over the scoped
    ///    thread pool with per-worker scratch arenas.
    /// 3. **Apply** — every layer's precondition + graft + base update;
    ///    layers without scheduled units proceed immediately, refreshed
    ///    layers apply the moment their last unit lands.
    ///
    /// Units and layers are mutually independent (disjoint state, disjoint
    /// parameter/momentum buffers) and per unit the math is identical to
    /// the sequential loop, so trajectories are bit-for-bit deterministic
    /// regardless of thread count.
    pub fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], step: u64, lr_scale: f32) {
        assert_eq!(params.len(), self.layers.len());
        assert_eq!(grads.len(), self.layers.len());
        assert_eq!(self.base.states.len(), self.layers.len(), "optimizer not initialized");

        let t0 = Instant::now();
        // Phase 0 (async only): publish roots whose staleness deadline is
        // this step, in unit-index order. `collect_due` blocks on not-yet-
        // finished units (a counted barrier stall) and never releases early
        // completions before their due step, so the published sequence is
        // deterministic regardless of worker timing or shard count.
        if let Some(eng) = &self.async_eng {
            let due = eng.lock().unwrap_or_else(|e| e.into_inner()).collect_due(step);
            if !due.is_empty() {
                let mut scratch = {
                    let mut pool = self.scratch_pool.lock().unwrap_or_else(|e| e.into_inner());
                    pool.pop().unwrap_or_else(ScratchArena::new)
                };
                for d in &due {
                    let id = self.units[d.unit];
                    self.layers[id.layer as usize].blocks[id.block as usize].publish_root_unit(
                        id.side,
                        d.result.as_ref().map(|(x, o)| (x, *o)),
                        d.submit_step,
                        d.pending_at_submit,
                        &self.cfg,
                        &self.ctx,
                        &mut scratch,
                        &self.ledger,
                    );
                }
                self.scratch_pool.lock().unwrap_or_else(|e| e.into_inner()).push(scratch);
            }
        }

        // Phase 1: snapshot unit metadata and let the policy decide. During
        // warmup (`step < cfg.start_preconditioning_step`) the policy is not
        // consulted at all: the plan stays empty (zero planned units in the
        // telemetry), the executor takes its sequential fast path, and every
        // layer applies the grafted base rule on the raw gradient.
        let warmup = step < self.cfg.start_preconditioning_step;
        self.infos.clear();
        for &id in &self.units {
            let meta = self.layers[id.layer as usize].unit_meta(id.block as usize, id.side);
            self.infos.push(UnitInfo { id, meta });
        }
        self.plan.reset(self.units.len());
        if !warmup {
            self.sched.plan(step, &self.infos, &self.cfg, &mut self.plan);
        }

        // Async mode computes roots off the step thread: record what the
        // policy planned (for telemetry parity with sync mode), then strip
        // the ROOT flags so the executor only runs gram updates and applies.
        let planned_roots = self.plan.root_units();
        let mut async_roots: Vec<usize> = Vec::new();
        if self.async_eng.is_some() && planned_roots > 0 {
            for u in 0..self.plan.len() {
                if self.plan.flags(u) & RefreshPlan::ROOT != 0 {
                    async_roots.push(u);
                    self.plan.clear_root(u);
                }
            }
        }

        // Phases 2+3: the work-queue executor.
        let sc = scheduler::StepCtx {
            cfg: &self.cfg,
            ctx: &self.ctx,
            hyper: self.base.hyper,
            kind: self.base.kind,
            lr_scale,
            step,
            fault: self.fault.as_ref(),
            ledger: &self.ledger,
            warmup,
        };
        let refresh_ns = scheduler::execute_step(
            &mut self.layers,
            params,
            grads,
            &mut self.base.states,
            &mut self.grafts,
            &self.plan,
            &self.units,
            &mut self.tasks,
            &self.scratch_pool,
            &sc,
        );
        // Phase 4 (async only): submit the stripped root units AFTER the
        // executor, so each gram snapshot includes this step's gram update —
        // the same gram a synchronous refresh would have rooted. An in-
        // flight unit is coalesced rather than resubmitted; quarantined
        // units inside their probation window are floor-served inline
        // (exactly the synchronous gate) and never reach the workers.
        if let Some(eng) = &self.async_eng {
            let mut eng = eng.lock().unwrap_or_else(|e| e.into_inner());
            let mut scratch = {
                let mut pool = self.scratch_pool.lock().unwrap_or_else(|e| e.into_inner());
                pool.pop().unwrap_or_else(ScratchArena::new)
            };
            for &u in &async_roots {
                let id = self.units[u];
                let (li, bi) = (id.layer as usize, id.block as usize);
                // The executor already screened and counted this gradient.
                if grads[li].has_non_finite() {
                    continue;
                }
                if eng.in_flight(u) {
                    eng.note_coalesced();
                    continue;
                }
                let block = &mut self.layers[li].blocks[bi];
                if block.async_quarantine_gate(id.side, step, &self.cfg, &self.ledger) {
                    continue;
                }
                let forced = self.fault.as_ref().is_some_and(|f| {
                    f.forces_root_failure(step, id.layer, id.block, id.side.index())
                });
                let gram = block.snapshot_gram(id.side, &mut scratch);
                let pending = block.side(id.side).meta.pending_norm;
                eng.submit(u, step, forced, gram, pending);
            }
            eng.note_step_end();
            self.stats.async_refresh = eng.stats.clone();
            self.scratch_pool.lock().unwrap_or_else(|e| e.into_inner()).push(scratch);
        }

        self.stats.health.absorb(&self.ledger.take());
        self.stats.record(
            self.plan.gram_units(),
            planned_roots,
            refresh_ns,
            t0.elapsed().as_nanos() as u64,
        );
    }

    /// Refresh telemetry accumulated over all steps so far.
    pub fn refresh_stats(&self) -> &RefreshStats {
        &self.stats
    }

    /// Cumulative numerical-health counters (guard screens, fallback-ladder
    /// rungs, quarantine transitions) over all steps so far.
    pub fn health(&self) -> &HealthStats {
        &self.stats.health
    }

    /// The active refresh policy's registry key.
    pub fn refresh_policy(&self) -> &'static str {
        self.sched.key()
    }

    /// Total refresh units (2 per non-passthrough block).
    pub fn unit_count(&self) -> usize {
        self.units.len()
    }

    /// Snapshot of every unit's address + refresh bookkeeping (coverage and
    /// starvation tests; telemetry).
    pub fn unit_metas(&self) -> Vec<(UnitId, UnitMeta)> {
        self.units
            .iter()
            .map(|&id| {
                (id, self.layers[id.layer as usize].unit_meta(id.block as usize, id.side))
            })
            .collect()
    }

    /// Scratch-reuse telemetry summed across all parked arenas (named
    /// fields — call sites no longer pattern-match on positional tuple
    /// order). In steady state both `misses` and `plan_grows` are constant
    /// step-over-step — matrix takes *and* the GEMM tier's packing buffers
    /// are allocation-free. This is the assertion behind the scratch-reuse
    /// test in `tests/kernel_equivalence.rs`. The async engine's per-shard
    /// arenas are worker-owned and intentionally not included.
    pub fn scratch_stats(&self) -> ShampooScratchStats {
        let pool = self.scratch_pool.lock().unwrap_or_else(|e| e.into_inner());
        ShampooScratchStats {
            arenas: pool.len(),
            hits: pool.iter().map(|a| a.hits()).sum(),
            misses: pool.iter().map(|a| a.misses()).sum(),
            plan_grows: pool.iter().map(|a| a.stats().plan_grows).sum(),
        }
    }

    /// Persistent optimizer-state bytes: Shampoo preconditioner storage
    /// plus the base optimizer's buffers (the quantity behind the paper's
    /// peak-memory deltas, App. C.4).
    pub fn state_bytes(&self) -> usize {
        self.shampoo_state_bytes() + self.base.state_bytes()
    }

    /// Preconditioner storage plus graft accumulators (zero for the
    /// stateless `none`/`sgd`/`sqrt-n` keys).
    pub fn shampoo_state_bytes(&self) -> usize {
        let layers: usize = self.layers.iter().map(|l| l.size_bytes()).sum();
        let grafts: usize = self.grafts.iter().map(|g| g.size_bytes()).sum();
        layers + grafts
    }

    /// Dequantized inverse-root pairs `(D(L̂), D(R̂))` of every block of
    /// layer `idx` — used by the Fig. 3 eigenvalue-histogram harness.
    pub fn dequant_inv_roots(&self, idx: usize) -> Vec<(Matrix, Matrix)> {
        self.layers[idx].dequant_inv_roots()
    }

    /// Reconstructed preconditioner pairs `(L, R)` of every block of layer
    /// `idx` (for the Tab. 1/10 NRE/AE harvest).
    pub fn reconstructed_preconditioners(&self, idx: usize) -> Vec<(Matrix, Matrix)> {
        self.layers[idx].reconstructed_preconditioners()
    }

    pub fn quantizer(&self) -> &BlockQuantizer {
        &self.ctx.quantizer
    }

    /// The codec context (for building compatible codecs outside the state).
    pub fn codec_ctx(&self) -> &CodecCtx {
        &self.ctx
    }

    /// Serialize all mutable state a resumed run needs: every layer's codec
    /// payloads + refresh metadata, then the base optimizer's buffers.
    /// Config, shapes, and blocking are spec-derived and not written (the
    /// restoring side rebuilds the optimizer from its spec first); the
    /// refresh schedulers are stateless functions of [`UnitMeta`], so the
    /// per-unit metadata is the complete scheduler state.
    pub fn write_state(&self, out: &mut ByteWriter) {
        out.put_u64(self.layers.len() as u64);
        for l in &self.layers {
            l.write_state(out);
        }
        self.base.write_state(out);
        // Graft section: the active key (a format self-check — restoring
        // under a different graft is a spec mismatch, not a recoverable
        // state) followed by each layer's accumulator. Stateless grafts
        // write nothing, so classic checkpoints cost only the key string.
        out.put_str(self.cfg.graft_key());
        for g in &self.grafts {
            g.write_state(out);
        }
        // Async mode appends the in-flight refresh table: every pending unit
        // is drained to completion (results are NOT published — that would
        // perturb the trajectory) and serialized with its submit/due steps,
        // so a resumed run publishes at the original due steps and matches
        // an uninterrupted control bit-for-bit. The section exists exactly
        // when `cfg.async_refresh` is set — spec-pinned on both sides, so
        // async-off checkpoints keep their historical format.
        if let Some(eng) = &self.async_eng {
            let mut eng = eng.lock().unwrap_or_else(|e| e.into_inner());
            eng.drain();
            eng.write_pending(out);
        }
    }

    /// Inverse of [`Shampoo::write_state`] on a freshly built optimizer.
    pub fn read_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        let n = r.get_len()?;
        crate::ensure!(
            n == self.layers.len(),
            "checkpoint holds {n} layers, optimizer built with {}",
            self.layers.len()
        );
        let mut scratch = ScratchArena::new();
        for l in &mut self.layers {
            l.read_state(r, &self.ctx, &mut scratch)?;
        }
        self.base.read_state(r)?;
        let key = r.get_str()?;
        crate::ensure!(
            key == self.cfg.graft_key(),
            "checkpoint graft '{key}' does not match configured '{}'",
            self.cfg.graft_key()
        );
        for g in &mut self.grafts {
            g.read_state(r)?;
        }
        if let Some(eng) = &self.async_eng {
            eng.lock().unwrap_or_else(|e| e.into_inner()).read_pending(r)?;
        }
        Ok(())
    }
}

impl Optimizer for Shampoo {
    /// Shampoo is built with shapes up-front; `init` is a no-op.
    fn init(&mut self, _n_params: usize) {}

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], k: u64, lr_scale: f32) {
        Shampoo::step(self, params, grads, k, lr_scale);
    }

    fn state_bytes(&self) -> usize {
        Shampoo::state_bytes(self)
    }

    fn name(&self) -> String {
        let base = self.base.kind.name().to_uppercase();
        // Codec overrides change what actually runs — rows must never
        // attribute an override's results to the base variant. With BOTH
        // slots overridden (the ec4/f16/cq-r1 stack keys) the variant
        // contributes nothing, so the codecs ARE the name; with a partial
        // override the variant still picks the other slot and the override
        // rides as a suffix.
        let mut label = match (self.cfg.side_codec, self.cfg.root_codec) {
            (Some(side), Some(root)) if side == root => format!("{base} + {side} Shampoo"),
            (Some(side), Some(root)) => format!("{base} + {side}/{root} Shampoo"),
            (None, None) => self.cfg.variant.stack_label(self.base.kind),
            _ => {
                let side = self.cfg.side_codec_key();
                let root = self.cfg.root_codec_key();
                let mut l = self.cfg.variant.stack_label(self.base.kind);
                l.push_str(&format!(" [codecs {side}/{root}]"));
                l
            }
        };
        // Likewise a non-classic refresh schedule changes trajectories.
        if self.cfg.refresh_policy != "every-n" {
            label.push_str(&format!(" [refresh {}]", self.cfg.refresh_policy));
        }
        // Workload knobs: only non-default settings are surfaced, so classic
        // configs keep their historical labels.
        if self.cfg.grafting && self.cfg.graft != "sgd" {
            label.push_str(&format!(" [graft {}]", self.cfg.graft));
        }
        if self.cfg.start_preconditioning_step > 0 {
            label.push_str(&format!(" [warmup {}]", self.cfg.start_preconditioning_step));
        }
        if self.cfg.no_preconditioning_for_layers_with_dim_gt > 0 {
            let d = self.cfg.no_preconditioning_for_layers_with_dim_gt;
            label.push_str(&format!(" [dim-gt {d}]"));
        }
        if self.cfg.shape_interpretation {
            label.push_str(" [shape-nd]");
        }
        label
    }

    fn save_state(&self, out: &mut ByteWriter) -> Result<()> {
        self.write_state(out);
        Ok(())
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.read_state(r)
    }

    fn set_fault_plan(&mut self, plan: Option<&FaultPlan>) {
        self.fault = plan.cloned();
    }

    fn health_stats(&self) -> HealthStats {
        self.stats.health.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kron::vec_cols;
    use crate::linalg::{eig_sym, fro_norm, kron, matmul, matmul_nt, matmul_tn};
    use crate::optim::{graft, OptimizerKind};
    use crate::util::rng::Rng;

    fn sgd_base() -> BaseOptimizer {
        BaseOptimizer::sgd(0.05, 0.0)
    }

    #[test]
    fn identity_preconditioner_before_first_update() {
        // Before step T1, L̂ = R̂ = I, so (without grafting) Ĝ = G and
        // Shampoo+SGD equals SGD.
        let cfg = ShampooConfig {
            t1: 10,
            t2: 10,
            grafting: false,
            variant: ShampooVariant::Full32,
            ..Default::default()
        };
        let mut sh = Shampoo::new(sgd_base(), cfg, &[(4, 3)]);
        let mut rng = Rng::new(1);
        let mut w1 = Matrix::randn(4, 3, 1.0, &mut rng);
        let mut w2 = w1.clone();
        let g = Matrix::randn(4, 3, 1.0, &mut rng);

        sh.step(std::slice::from_mut(&mut w1), std::slice::from_ref(&g), 1, 1.0);

        let mut plain = sgd_base();
        plain.init(1);
        plain.step_param(0, &mut w2, &g, 1.0);
        assert!(w1.max_abs_diff(&w2) < 1e-6);
    }

    /// Validate the full-precision update against the vectorized oracle of
    /// Eq. (15): x ← x − η (R̂ ⊗ L̂) g with exact Kronecker algebra.
    #[test]
    fn full32_matches_kronecker_oracle() {
        let cfg = ShampooConfig {
            t1: 1,
            t2: 1,
            grafting: false,
            variant: ShampooVariant::Full32,
            ..Default::default()
        };
        let mut rng = Rng::new(2);
        let (m, n) = (3, 4);
        let mut sh = Shampoo::new(sgd_base(), cfg, &[(m, n)]);
        let mut w = Matrix::randn(m, n, 1.0, &mut rng);
        let w0 = w.clone();
        let g = Matrix::randn(m, n, 1.0, &mut rng);

        sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(&g), 1, 1.0);

        // Pull L̂/R̂ from the state and check the parameter delta equals
        // η·unvec((R̂ᵀ ⊗ L̂)·vec(G)).
        let roots = sh.dequant_inv_roots(0);
        let (lhat, rhat) = &roots[0];
        let h = kron(&rhat.transpose(), lhat);
        let vg = vec_cols(&g);
        let mut hv = vec![0.0f32; vg.len()];
        for i in 0..h.rows() {
            hv[i] = crate::linalg::matmul::dot(h.row(i), &vg);
        }
        // un-vec (column stacking)
        let mut want = w0.clone();
        for j in 0..n {
            for i in 0..m {
                want[(i, j)] -= 0.05 * hv[j * m + i];
            }
        }
        assert!(w.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gram_ema_matches_eq2() {
        // After one update at k=T1=1: L = β·εI + (1−β)GGᵀ.
        let cfg = ShampooConfig {
            t1: 1,
            t2: 1,
            variant: ShampooVariant::Full32,
            beta: 0.9,
            eps: 1e-6,
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let g = Matrix::randn(4, 5, 1.0, &mut rng);
        let mut sh = Shampoo::new(sgd_base(), cfg, &[(4, 5)]);
        let mut w = Matrix::zeros(4, 5);
        sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(&g), 1, 1.0);

        let recon = sh.reconstructed_preconditioners(0);
        let (l, r) = &recon[0];
        let mut want_l = matmul_nt(&g, &g);
        want_l.scale(0.1);
        want_l.add_diag(0.9 * 1e-6);
        assert!(l.max_abs_diff(&want_l) < 1e-5);
        let mut want_r = matmul_tn(&g, &g);
        want_r.scale(0.1);
        want_r.add_diag(0.9 * 1e-6);
        assert!(r.max_abs_diff(&want_r) < 1e-5);
    }

    #[test]
    fn all_variants_run_and_stay_finite() {
        let mut rng = Rng::new(4);
        for variant in [
            ShampooVariant::Full32,
            ShampooVariant::Vq4,
            ShampooVariant::Cq4 { error_feedback: false },
            ShampooVariant::Cq4 { error_feedback: true },
            ShampooVariant::Bw8,
        ] {
            let cfg = ShampooConfig { t1: 2, t2: 4, variant, ..Default::default() };
            let mut sh = Shampoo::new(sgd_base(), cfg, &[(16, 8), (8, 8)]);
            let mut params = vec![
                Matrix::randn(16, 8, 0.5, &mut rng),
                Matrix::randn(8, 8, 0.5, &mut rng),
            ];
            for k in 1..=12 {
                let grads: Vec<Matrix> = params
                    .iter()
                    .map(|p| {
                        let mut g = p.clone();
                        g.scale(0.1);
                        g.axpy(0.01, &Matrix::randn(p.rows(), p.cols(), 1.0, &mut rng));
                        g
                    })
                    .collect();
                sh.step(&mut params, &grads, k, 1.0);
            }
            for p in &params {
                assert!(!p.has_non_finite(), "{variant:?} produced non-finite params");
            }
        }
    }

    #[test]
    fn parallel_step_matches_sequential_oracle() {
        // The fanned-out step must reproduce a hand-written sequential
        // per-layer loop bit-for-bit: same state pairing, same operation
        // order within each layer, no cross-layer interaction.
        let cfg = ShampooConfig {
            t1: 1,
            t2: 2,
            variant: ShampooVariant::Cq4 { error_feedback: true },
            quant: crate::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let shapes = [(12usize, 8usize), (8, 8), (16, 4), (6, 10)];
        let mut rng = Rng::new(11);
        let params0: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();

        // Parallel path: the real optimizer.
        let mut sh = Shampoo::new(sgd_base(), cfg, &shapes);
        let mut pa = params0.clone();
        for k in 1..=6u64 {
            sh.step(&mut pa, &grads, k, 1.0);
        }

        // Sequential oracle over the same public per-layer operations.
        let ctx = CodecCtx::new(
            cfg.eps,
            cfg.beta_e,
            Arc::new(BlockQuantizer::new(cfg.quant)),
        );
        let mut layers: Vec<LayerState> =
            shapes.iter().map(|&(m, n)| LayerState::new(m, n, &cfg, &ctx)).collect();
        let mut base = sgd_base();
        base.init(shapes.len());
        let mut pb = params0.clone();
        let mut scratch = ScratchArena::new();
        for k in 1..=6u64 {
            for i in 0..shapes.len() {
                if k % cfg.t1 == 0 {
                    layers[i].update_gram(&grads[i], &cfg, &mut scratch);
                }
                if k % cfg.t2 == 0 {
                    layers[i].update_inv_roots(&cfg, &ctx, &mut scratch);
                }
                let mut ghat = layers[i].precondition(&grads[i]);
                if cfg.grafting {
                    graft(&grads[i], &mut ghat);
                }
                base.step_param(i, &mut pb[i], &ghat, 1.0);
            }
        }

        for (a, b) in pa.iter().zip(pb.iter()) {
            assert_eq!(a.max_abs_diff(b), 0.0, "parallel step must match sequential oracle");
        }
    }

    #[test]
    fn quantized_variants_use_less_memory() {
        let shapes = [(64usize, 64usize), (128, 64)];
        let mk = |variant| {
            let cfg = ShampooConfig {
                t1: 1,
                t2: 1,
                variant,
                // allow quantization of these (small) test tensors
                quant: crate::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
                ..Default::default()
            };
            let mut sh = Shampoo::new(sgd_base(), cfg, &shapes);
            let mut rng = Rng::new(5);
            let mut params: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
            sh.step(&mut params, &grads, 1, 1.0);
            sh.shampoo_state_bytes()
        };
        let full = mk(ShampooVariant::Full32);
        let vq = mk(ShampooVariant::Vq4);
        let cq = mk(ShampooVariant::Cq4 { error_feedback: false });
        let cqef = mk(ShampooVariant::Cq4 { error_feedback: true });
        let bw8 = mk(ShampooVariant::Bw8);
        assert!(vq < full / 4, "vq={vq} full={full}");
        assert!(cq < vq, "cq={cq} vq={vq}");
        assert!(cqef >= cq && cqef <= vq + 64, "cq={cq} cqef={cqef} vq={vq}");
        // 8-bit sits strictly between 4-bit and f32.
        assert!(bw8 > vq && bw8 < full / 2, "vq={vq} bw8={bw8} full={full}");
    }

    #[test]
    fn vector_params_bypass_preconditioning() {
        let cfg = ShampooConfig { t1: 1, t2: 1, grafting: false, ..Default::default() };
        let mut sh = Shampoo::new(sgd_base(), cfg, &[(5, 1)]);
        let mut w = Matrix::zeros(5, 1);
        let g = Matrix::from_fn(5, 1, |i, _| i as f32);
        sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(&g), 1, 1.0);
        // Pure SGD on the bias: w = −lr·g.
        for i in 0..5 {
            assert!((w[(i, 0)] + 0.05 * i as f32).abs() < 1e-7);
        }
        assert_eq!(sh.shampoo_state_bytes(), 0);
    }

    #[test]
    fn optimizer_trait_object_drives_shampoo() {
        let cfg = ShampooConfig { t1: 1, t2: 1, ..Default::default() };
        let mut opt: Box<dyn Optimizer> =
            Box::new(Shampoo::new(sgd_base(), cfg, &[(8, 8)]));
        assert_eq!(opt.name(), "SGD + 4-bit (CQ+EF) Shampoo");
        let mut rng = Rng::new(9);
        let mut params = vec![Matrix::randn(8, 8, 1.0, &mut rng)];
        let grads = vec![Matrix::randn(8, 8, 1.0, &mut rng)];
        opt.init(1); // no-op for Shampoo
        opt.step(&mut params, &grads, 1, 1.0);
        assert!(!params[0].has_non_finite());
        assert!(opt.state_bytes() > 0);
    }

    #[test]
    fn refresh_stats_track_every_n_spikes() {
        let cfg = ShampooConfig {
            t1: 2,
            t2: 4,
            variant: ShampooVariant::Full32,
            ..Default::default()
        };
        let mut sh = Shampoo::new(sgd_base(), cfg, &[(8, 8), (8, 8)]);
        assert_eq!(sh.unit_count(), 4);
        assert_eq!(sh.refresh_policy(), "every-n");
        let mut rng = Rng::new(17);
        let mut params = vec![
            Matrix::randn(8, 8, 0.5, &mut rng),
            Matrix::randn(8, 8, 0.5, &mut rng),
        ];
        let grads = vec![Matrix::randn(8, 8, 0.5, &mut rng), Matrix::randn(8, 8, 0.5, &mut rng)];
        for k in 1..=8u64 {
            sh.step(&mut params, &grads, k, 1.0);
        }
        let s = sh.refresh_stats();
        assert_eq!(s.steps, 8);
        // Gram at k ∈ {2,4,6,8}, roots at k ∈ {4,8} — all 4 units each time.
        assert_eq!(s.gram_units, 16);
        assert_eq!(s.root_units, 8);
        assert_eq!(s.max_root_units, 4, "every-n concentrates all units in one step");
        assert_eq!(s.last_root_units, 4);
        // Every unit's bookkeeping reflects the classic cadence.
        for (id, meta) in sh.unit_metas() {
            assert_eq!(meta.last_gram, 8, "{id:?}");
            assert_eq!(meta.last_root, 8, "{id:?}");
            assert_eq!(meta.refreshes, 2, "{id:?}");
        }
    }

    #[test]
    fn state_restore_resumes_bit_identically() {
        // Train 6 steps and checkpoint, then: (a) continue 4 more steps,
        // (b) rebuild from the spec, restore, and run the same 4 steps.
        // Both trajectories must agree bit-for-bit — the contract the
        // persist layer's resume oracle builds on.
        let cfg = ShampooConfig {
            t1: 1,
            t2: 2,
            variant: ShampooVariant::Cq4 { error_feedback: true },
            quant: crate::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
            refresh_policy: "staleness",
            ..Default::default()
        };
        let shapes = [(12usize, 8usize), (8, 8), (5, 1)];
        let mut rng = Rng::new(41);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
        let grads: Vec<Vec<Matrix>> = (0..10)
            .map(|_| shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect())
            .collect();
        let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 1e-4), cfg, &shapes);
        for k in 1..=6u64 {
            sh.step(&mut params, &grads[k as usize - 1], k, 1.0);
        }
        let mut w = ByteWriter::new();
        sh.write_state(&mut w);
        let bytes = w.into_bytes();
        let params_ck = params.clone();

        let mut resumed = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 1e-4), cfg, &shapes);
        resumed.read_state(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(resumed.state_bytes(), sh.state_bytes());
        let mut params_r = params_ck;
        for k in 7..=10u64 {
            sh.step(&mut params, &grads[k as usize - 1], k, 1.0);
            resumed.step(&mut params_r, &grads[k as usize - 1], k, 1.0);
        }
        for (a, b) in params.iter().zip(params_r.iter()) {
            assert_eq!(a.max_abs_diff(b), 0.0, "resumed trajectory must be bit-identical");
        }
        // Truncated state errors instead of panicking.
        let mut fresh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 1e-4), cfg, &shapes);
        assert!(fresh.read_state(&mut ByteReader::new(&bytes[..bytes.len() - 5])).is_err());
    }

    #[test]
    fn non_default_policy_is_surfaced_in_name() {
        let cfg = ShampooConfig { refresh_policy: "staggered", ..Default::default() };
        let sh = Shampoo::new(sgd_base(), cfg, &[(8, 8)]);
        assert!(Optimizer::name(&sh).contains("[refresh staggered]"));
        let sh2 = Shampoo::new(sgd_base(), ShampooConfig::default(), &[(8, 8)]);
        assert!(!Optimizer::name(&sh2).contains("refresh"));
    }

    #[test]
    fn workload_knobs_are_surfaced_in_name_only_when_set() {
        let sh = Shampoo::new(sgd_base(), ShampooConfig::default(), &[(8, 8)]);
        let name = Optimizer::name(&sh);
        for marker in ["graft", "warmup", "dim-gt", "shape-nd"] {
            assert!(!name.contains(marker), "default name must not carry '{marker}': {name}");
        }
        let cfg = ShampooConfig {
            graft: "rmsprop",
            start_preconditioning_step: 10,
            no_preconditioning_for_layers_with_dim_gt: 4096,
            shape_interpretation: true,
            ..Default::default()
        };
        let sh = Shampoo::new(sgd_base(), cfg, &[(8, 8)]);
        let name = Optimizer::name(&sh);
        for marker in ["[graft rmsprop]", "[warmup 10]", "[dim-gt 4096]", "[shape-nd]"] {
            assert!(name.contains(marker), "expected '{marker}' in: {name}");
        }
    }

    #[test]
    fn warmup_steps_run_grafted_base_only() {
        // Steps below `start_preconditioning_step` must equal the bare base
        // optimizer bit-for-bit (the default sgd graft rescales by exactly
        // ‖G‖/‖G‖ = 1.0) and plan zero refresh units; preconditioning then
        // kicks in at the threshold step.
        let cfg = ShampooConfig {
            t1: 1,
            t2: 1,
            variant: ShampooVariant::Full32,
            start_preconditioning_step: 4,
            ..Default::default()
        };
        let mut rng = Rng::new(21);
        let mut w = Matrix::randn(6, 5, 0.5, &mut rng);
        let mut w_ref = w.clone();
        let grads: Vec<Matrix> = (0..4).map(|_| Matrix::randn(6, 5, 0.5, &mut rng)).collect();
        let mut sh = Shampoo::new(sgd_base(), cfg, &[(6, 5)]);
        let bytes_warm = sh.shampoo_state_bytes();
        let mut plain = sgd_base();
        plain.init(1);
        for k in 1..=3u64 {
            let g = &grads[k as usize - 1];
            sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(g), k, 1.0);
            plain.step_param(0, &mut w_ref, g, 1.0);
            assert_eq!(w.max_abs_diff(&w_ref), 0.0, "warmup step {k} must be bare SGD");
        }
        let s = sh.refresh_stats();
        assert_eq!((s.gram_units, s.root_units), (0, 0), "warmup must plan nothing");
        // Step 4 preconditions: the trajectory departs and the deferred
        // root bytes are now counted.
        sh.step(std::slice::from_mut(&mut w), std::slice::from_ref(&grads[3]), 4, 1.0);
        plain.step_param(0, &mut w_ref, &grads[3], 1.0);
        assert!(w.max_abs_diff(&w_ref) > 0.0, "preconditioning must engage at the threshold");
        assert!(sh.refresh_stats().root_units > 0);
        assert!(sh.shampoo_state_bytes() > bytes_warm, "root bytes counted after warmup");
    }

    #[test]
    fn nd_shapes_chunk_blocks_under_shape_interpretation() {
        assert_eq!(Shampoo::collapsed_shape(&[]), (1, 1));
        assert_eq!(Shampoo::collapsed_shape(&[7]), (7, 1));
        assert_eq!(Shampoo::collapsed_shape(&[2, 3, 4]), (6, 4));
        let nd = vec![vec![2usize, 3, 4]];
        let off = Shampoo::new_nd(sgd_base(), ShampooConfig::default(), &nd);
        assert_eq!((off.layers[0].rows, off.layers[0].cols), (6, 4));
        assert_eq!(off.layers[0].blocks.len(), 1, "knob off flattens to one block");
        let cfg = ShampooConfig { shape_interpretation: true, ..Default::default() };
        let on = Shampoo::new_nd(sgd_base(), cfg, &nd);
        assert_eq!((on.layers[0].rows, on.layers[0].cols), (6, 4));
        assert_eq!(on.layers[0].blocks.len(), 2, "two independent 3x4 chunks");
        assert_eq!(on.unit_count(), 4);
        assert_eq!(on.layers[0].blocking.blocks[0].r0, 0);
        assert_eq!(on.layers[0].blocking.blocks[1].r0, 3, "second chunk offset down the rows");
    }

    #[test]
    fn stateful_graft_bytes_counted_and_key_checked_on_restore() {
        let shapes = [(8usize, 6usize), (4, 4)];
        let mk = |graft: &'static str| {
            let cfg = ShampooConfig { t1: 1, t2: 1, graft, ..Default::default() };
            Shampoo::new(sgd_base(), cfg, &shapes)
        };
        let sgd = mk("sgd");
        let mut ada = mk("adagrad");
        let acc: usize = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n).size_bytes()).sum();
        assert_eq!(ada.shampoo_state_bytes(), sgd.shampoo_state_bytes() + acc);
        // A checkpoint written under one graft refuses to restore under
        // another — accumulator state is not transferable across keys.
        let mut rng = Rng::new(33);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.5, &mut rng)).collect();
        ada.step(&mut params, &grads, 1, 1.0);
        let mut w = ByteWriter::new();
        ada.write_state(&mut w);
        let bytes = w.into_bytes();
        let mut wrong = mk("sgd");
        assert!(wrong.read_state(&mut ByteReader::new(&bytes)).is_err());
        let mut right = mk("adagrad");
        right.read_state(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(right.state_bytes(), ada.state_bytes());
    }

    #[test]
    fn preconditioning_beats_sgd_on_ill_conditioned_quadratic() {
        // f(W) = 0.5·tr(Wᵀ A W B) with A, B badly conditioned: Shampoo's
        // preconditioner whitens the curvature, SGD crawls.
        let mut rng = Rng::new(6);
        let (m, n) = (8, 6);
        let mut mk_spd = |dim: usize, cond: f32, rng: &mut Rng| {
            let g = Matrix::randn(dim, dim, 1.0, rng);
            let (_, v) = eig_sym(&crate::linalg::syrk(&g), 1e-10, 100);
            let mut a = Matrix::zeros(dim, dim);
            for k in 0..dim {
                let lam = cond.powf(k as f32 / (dim - 1) as f32);
                for i in 0..dim {
                    for j in 0..dim {
                        a[(i, j)] += lam * v[(i, k)] * v[(j, k)];
                    }
                }
            }
            a
        };
        let a = mk_spd(m, 50.0, &mut rng);
        let b = mk_spd(n, 50.0, &mut rng);
        let grad = |w: &Matrix| matmul(&matmul(&a, w), &b);
        let loss = |w: &Matrix| {
            let awb = grad(w);
            0.5 * crate::linalg::inner(w, &awb)
        };

        let w0 = Matrix::randn(m, n, 1.0, &mut rng);

        // SGD baseline.
        let mut w_sgd = w0.clone();
        let mut opt = BaseOptimizer::new(
            OptimizerKind::Sgd,
            crate::optim::optimizer::Hyper { lr: 5e-4, ..Default::default() },
        );
        opt.init(1);
        for _ in 0..600 {
            let g = grad(&w_sgd);
            opt.step_param(0, &mut w_sgd, &g, 1.0);
        }

        // Shampoo (full precision, grafted).
        let cfg = ShampooConfig {
            t1: 1,
            t2: 5,
            variant: ShampooVariant::Full32,
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgd(5e-4, 0.0), cfg, &[(m, n)]);
        let mut w_sh = w0.clone();
        for k in 1..=600 {
            let g = grad(&w_sh);
            sh.step(std::slice::from_mut(&mut w_sh), std::slice::from_ref(&g), k, 1.0);
        }

        let (l_sgd, l_sh) = (loss(&w_sgd), loss(&w_sh));
        assert!(
            l_sh < l_sgd * 0.7,
            "shampoo should win on ill-conditioned quadratic: sgd={l_sgd:.4} shampoo={l_sh:.4}"
        );
        let _ = fro_norm(&w_sh);
    }
}

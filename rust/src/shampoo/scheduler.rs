//! Refresh-scheduler engine: *which* preconditioner refresh work runs at
//! *which* step is a policy, decoupled from the step path.
//!
//! The paper amortizes its expensive operations — Gram-root recomputation,
//! Cholesky factorization, 4-bit re-quantization with error feedback — by
//! refreshing preconditioners only every `T1`/`T2` steps (App. C.3; delayed
//! preconditioner computation is already the wall-clock key in Gupta et al.,
//! arXiv 1802.09568, and 4-bit Shampoo, arXiv 2405.18144). Refreshing
//! **all** blocks of **all** layers in the same step produces latency
//! spikes; this module makes the decision per **refresh unit** —
//! a `(layer, block, side)` triple — so policies can spread the work.
//!
//! * [`RefreshScheduler`] — the policy trait: fill a [`RefreshPlan`] per
//!   step from per-unit [`UnitMeta`] bookkeeping.
//! * Built-ins: [`EveryN`] (bit-identical reproduction of the classic
//!   `k % T` behavior), [`Staggered`] (round-robin spreading, per-step
//!   unit count ≤ ⌈units/T⌉), [`Staleness`] (staleness × pending-update
//!   priority under a hard per-step budget).
//! * A string-keyed registry mirroring `quant::codec` — `register` /
//!   [`lookup`] / [`scheduler_keys`]; `ShampooConfig::refresh_policy`
//!   selects by key from the CLI / TOML specs.
//! * [`execute_step`] — the work-queue executor: scheduled units run on the
//!   `util::pool` scoped workers with per-worker `ScratchArena`s while the
//!   cheap precondition-and-apply path proceeds over the remaining layers
//!   (a layer applies the moment its last pending unit lands).

use super::blocking::BlockSpec;
use super::config::ShampooConfig;
use super::state::{BlockState, LayerState, Side, UnitMeta};
use crate::linalg::{Matrix, ScratchArena};
use crate::metrics::HealthLedger;
use crate::optim::optimizer::{Hyper, ParamState};
use crate::optim::{apply_graft, BaseOptimizer, Graft, OptimizerKind};
use crate::quant::codec::CodecCtx;
use crate::util::fault::FaultPlan;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Address of one refresh unit: one Kronecker factor of one block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UnitId {
    /// Index into the optimizer's layer list.
    pub layer: u32,
    /// Block index within the layer's [`super::Blocking`] tiling.
    pub block: u32,
    /// Which Kronecker factor (`L` or `R`) of that block.
    pub side: Side,
}

/// Scheduler-visible snapshot of one unit (address + bookkeeping).
#[derive(Clone, Copy, Debug)]
pub struct UnitInfo {
    /// The unit's `(layer, block, side)` address.
    pub id: UnitId,
    /// Persistent refresh bookkeeping (last-refresh steps, pending norm).
    pub meta: UnitMeta,
}

/// The per-step decision: which units run a Gram EMA update and which
/// recompute their inverse root. Buffers are reused across steps.
#[derive(Clone, Debug, Default)]
pub struct RefreshPlan {
    flags: Vec<u8>,
}

impl RefreshPlan {
    /// Flag bit: the unit absorbs a fresh Gram EMA update this step.
    pub const GRAM: u8 = 1;
    /// Flag bit: the unit recomputes its inverse root this step.
    pub const ROOT: u8 = 2;

    /// Clear and size for `units` (all units unscheduled).
    pub fn reset(&mut self, units: usize) {
        self.flags.clear();
        self.flags.resize(units, 0);
    }

    /// Schedule unit `unit` for a Gram EMA update.
    pub fn mark_gram(&mut self, unit: usize) {
        self.flags[unit] |= Self::GRAM;
    }

    /// Schedule unit `unit` for an inverse-root recomputation.
    pub fn mark_root(&mut self, unit: usize) {
        self.flags[unit] |= Self::ROOT;
    }

    /// Unschedule unit `unit`'s root recomputation (its Gram flag is kept).
    /// The async engine strips planned roots from the synchronous plan this
    /// way and submits them to worker shards instead.
    pub fn clear_root(&mut self, unit: usize) {
        self.flags[unit] &= !Self::ROOT;
    }

    /// The [`Self::GRAM`]`/`[`Self::ROOT`] flag bits of unit `unit`.
    pub fn flags(&self, unit: usize) -> u8 {
        self.flags[unit]
    }

    /// Number of addressable units (the size passed to [`Self::reset`]).
    pub fn len(&self) -> usize {
        self.flags.len()
    }

    /// Units scheduled for a Gram update this step.
    pub fn gram_units(&self) -> usize {
        self.flags.iter().filter(|&&f| f & Self::GRAM != 0).count()
    }

    /// Units scheduled for a root recomputation this step.
    pub fn root_units(&self) -> usize {
        self.flags.iter().filter(|&&f| f & Self::ROOT != 0).count()
    }

    /// `true` when no unit is scheduled this step (the executor then takes
    /// the mutex-free sequential fast path).
    pub fn is_empty(&self) -> bool {
        self.flags.iter().all(|&f| f == 0)
    }
}

/// A refresh policy: decides, per step, which units refresh.
///
/// `plan` arrives reset to `units.len()`; implementations mark units. The
/// same scheduler instance lives for the whole optimizer lifetime, so
/// policies may keep internal buffers — but all *decision-relevant* state
/// must come from `UnitMeta` (it is the persistent, byte-accounted record).
pub trait RefreshScheduler: Send {
    /// Registry key (also the config-file spelling).
    fn key(&self) -> &'static str;

    /// Fill `plan` for 1-based `step`.
    fn plan(&mut self, step: u64, units: &[UnitInfo], cfg: &ShampooConfig, plan: &mut RefreshPlan);
}

/// The `Staleness` per-step root budget: explicit `cfg.refresh_budget`, or
/// ⌈units/T₂⌉ (the `Staggered` rate — the smallest budget that keeps every
/// unit refreshable once per interval).
pub fn effective_budget(cfg: &ShampooConfig, units: usize) -> usize {
    if cfg.refresh_budget > 0 {
        return cfg.refresh_budget;
    }
    units.div_ceil(cfg.t2.max(1) as usize).max(1)
}

/// Classic interval refresh: every unit's Gram updates at `k % T1 == 0`,
/// every unit's root at `k % T2 == 0` — bit-identical to the pre-scheduler
/// `Shampoo::step` (the determinism fixtures pin this).
pub struct EveryN;

impl RefreshScheduler for EveryN {
    fn key(&self) -> &'static str {
        "every-n"
    }

    fn plan(&mut self, step: u64, units: &[UnitInfo], cfg: &ShampooConfig, plan: &mut RefreshPlan) {
        if step % cfg.t1 == 0 {
            for u in 0..units.len() {
                plan.mark_gram(u);
            }
        }
        if step % cfg.t2 == 0 {
            for u in 0..units.len() {
                plan.mark_root(u);
            }
        }
    }
}

/// Warm-start guard for spreading policies: a root refresh before a unit's
/// first Gram update would factor the `ε·I` init into a `~ε^{-1/4}·I`
/// preconditioner — a ~1000× update blow-up with grafting off. Schedule a
/// just-in-time Gram update for such units (the executor always runs gram
/// before root within a block), so the first root sees real curvature.
/// `every-n` deliberately does NOT use this: it must stay bit-identical to
/// the classic schedule. Custom policies are encouraged to call it.
pub fn guard_first_root(units: &[UnitInfo], plan: &mut RefreshPlan) {
    for (u, info) in units.iter().enumerate() {
        if plan.flags(u) & RefreshPlan::ROOT != 0 && info.meta.last_gram == 0 {
            plan.mark_gram(u);
        }
    }
}

/// Round-robin staggering: unit `i` of `n` refreshes at interval offset
/// `⌊i·T/n⌋`, so every unit refreshes exactly once per interval and no step
/// runs more than ⌈n/T⌉ units — the latency-spike flattener.
pub struct Staggered;

impl RefreshScheduler for Staggered {
    fn key(&self) -> &'static str {
        "staggered"
    }

    fn plan(&mut self, step: u64, units: &[UnitInfo], cfg: &ShampooConfig, plan: &mut RefreshPlan) {
        let n = units.len() as u64;
        for i in 0..units.len() {
            let iu = i as u64;
            if step % cfg.t1 == iu * cfg.t1 / n {
                plan.mark_gram(i);
            }
            if step % cfg.t2 == iu * cfg.t2 / n {
                plan.mark_root(i);
            }
        }
        guard_first_root(units, plan);
    }
}

/// Priority refresh: roots are recomputed for the units where they are most
/// stale, weighted by the Gram-update magnitude absorbed since the last
/// refresh, under a hard per-step budget ([`effective_budget`]). Units
/// overdue a full `T2` interval jump to a forced tier (ordered by staleness)
/// so nothing starves: with the default budget the worst case is bounded by
/// `2·T2`. Gram updates keep the classic global `T1` cadence — they are the
/// cheap half, and a synchronized EMA keeps `pending_norm` comparable
/// across units.
pub struct Staleness {
    /// Reused sort buffer: `(forced, staleness, score, unit)`.
    order: Vec<(bool, u64, f64, usize)>,
}

impl Staleness {
    pub fn new() -> Staleness {
        Staleness { order: Vec::new() }
    }
}

impl Default for Staleness {
    fn default() -> Self {
        Self::new()
    }
}

impl RefreshScheduler for Staleness {
    fn key(&self) -> &'static str {
        "staleness"
    }

    fn plan(&mut self, step: u64, units: &[UnitInfo], cfg: &ShampooConfig, plan: &mut RefreshPlan) {
        if step % cfg.t1 == 0 {
            for u in 0..units.len() {
                plan.mark_gram(u);
            }
        }
        if units.is_empty() {
            return;
        }
        let budget = effective_budget(cfg, units.len());
        self.order.clear();
        for (i, u) in units.iter().enumerate() {
            let stale = step.saturating_sub(u.meta.last_root);
            // A NaN gradient leaves pending_norm non-finite until this
            // unit's next root refresh; map it to +∞ so the poisoned unit
            // refreshes first (the refresh resets pending_norm and the
            // codec's reset path self-heals) and the sort comparator never
            // sees a NaN.
            let pending = u.meta.pending_norm as f64;
            let score = if pending.is_finite() {
                stale as f64 * (1.0 + pending.max(0.0))
            } else {
                f64::INFINITY
            };
            self.order.push((stale >= cfg.t2, stale, score, i));
        }
        // Forced tier first (most stale leading), then by score; unit index
        // breaks ties so the plan is deterministic. `total_cmp` (not
        // partial_cmp-with-fallback) keeps this a genuine total order —
        // sort_unstable_by panics on inconsistent comparators since 1.81.
        self.order.sort_unstable_by(|a, b| {
            b.0.cmp(&a.0)
                .then(if a.0 && b.0 { b.1.cmp(&a.1) } else { b.2.total_cmp(&a.2) })
                .then(a.3.cmp(&b.3))
        });
        for &(_, _, _, unit) in self.order.iter().take(budget) {
            plan.mark_root(unit);
        }
        guard_first_root(units, plan);
    }
}

/// One registry entry (mirrors `quant::codec::CodecBuilder`).
#[derive(Clone, Copy)]
pub struct SchedulerBuilder {
    /// Canonical key (the `refresh_policy` config spelling).
    pub key: &'static str,
    /// One-line description for CLI/docs listings.
    pub summary: &'static str,
    /// Build a fresh scheduler for one optimizer instance.
    pub build: fn(&ShampooConfig) -> Box<dyn RefreshScheduler>,
}

fn build_every_n(_cfg: &ShampooConfig) -> Box<dyn RefreshScheduler> {
    Box::new(EveryN)
}

fn build_staggered(_cfg: &ShampooConfig) -> Box<dyn RefreshScheduler> {
    Box::new(Staggered)
}

fn build_staleness(_cfg: &ShampooConfig) -> Box<dyn RefreshScheduler> {
    Box::new(Staleness::new())
}

fn builtin_schedulers() -> Vec<SchedulerBuilder> {
    vec![
        SchedulerBuilder {
            key: "every-n",
            summary: "all units at k % T1 / k % T2 (classic, bit-identical)",
            build: build_every_n,
        },
        SchedulerBuilder {
            key: "staggered",
            summary: "round-robin spread, ≤ ⌈units/T⌉ per step (flat latency)",
            build: build_staggered,
        },
        SchedulerBuilder {
            key: "staleness",
            summary: "staleness × pending-norm priority under a per-step budget",
            build: build_staleness,
        },
    ]
}

fn registry() -> &'static Mutex<Vec<SchedulerBuilder>> {
    static REGISTRY: OnceLock<Mutex<Vec<SchedulerBuilder>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(builtin_schedulers()))
}

/// Register a policy under a new key. Returns `false` (unchanged registry)
/// if the key is taken.
pub fn register(builder: SchedulerBuilder) -> bool {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    if reg.iter().any(|b| b.key == builder.key) {
        return false;
    }
    reg.push(builder);
    true
}

/// Look up a policy builder by key.
///
/// ```
/// use quartz::shampoo::scheduler::{lookup, scheduler_keys};
///
/// let b = lookup("staggered").expect("built-in policy");
/// assert_eq!(b.key, "staggered");
/// assert!(lookup("no-such-policy").is_none());
/// // Built-ins come first in the key listing.
/// assert_eq!(scheduler_keys()[..3].to_vec(), vec!["every-n", "staggered", "staleness"]);
/// ```
pub fn lookup(key: &str) -> Option<SchedulerBuilder> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().find(|b| b.key == key).copied()
}

/// All registered keys, built-ins first.
pub fn scheduler_keys() -> Vec<&'static str> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().map(|b| b.key).collect()
}

/// Build the configured policy, panicking with the key on an unknown one —
/// configs can reference runtime-registered policies, so this is a runtime
/// binding by design (same contract as the codec registry).
pub(crate) fn build_for(cfg: &ShampooConfig) -> Box<dyn RefreshScheduler> {
    let b = lookup(cfg.refresh_policy)
        .unwrap_or_else(|| panic!("refresh policy '{}' is not registered", cfg.refresh_policy));
    (b.build)(cfg)
}

// ---------------------------------------------------------------------------
// Work-queue executor
// ---------------------------------------------------------------------------

/// Per-step context threaded to every worker.
pub(crate) struct StepCtx<'a> {
    pub cfg: &'a ShampooConfig,
    pub ctx: &'a CodecCtx,
    pub hyper: Hyper,
    pub kind: OptimizerKind,
    pub lr_scale: f32,
    pub step: u64,
    /// Deterministic fault schedule (test/chaos hook) — `None` in
    /// production runs, in which case no root failure is ever forced.
    pub fault: Option<&'a FaultPlan>,
    /// Health accumulator the guard screens and ladder outcomes count on.
    pub ledger: &'a HealthLedger,
    /// `start_preconditioning_step` warmup: the step takes grafted
    /// base-optimizer updates without touching the (identity) root caches.
    /// Only ever `true` with an empty plan — the driver skips planning
    /// during warmup — so it is a fast-path concern.
    pub warmup: bool,
}

/// One layer's shared-state view for the step: blocks behind per-block
/// mutexes (refresh units lock exactly one), the apply-side mutable state,
/// and the count of refresh tasks gating the apply.
struct LayerRun<'a> {
    rows: usize,
    cols: usize,
    passthrough: bool,
    trivial: bool,
    specs: &'a [BlockSpec],
    grad: &'a Matrix,
    blocks: Vec<Mutex<&'a mut BlockState>>,
    /// Param + base-optimizer state + the layer's graft: the apply phase
    /// runs exactly once per layer per step, so stateful graft
    /// accumulators advance deterministically under it.
    apply: Mutex<(&'a mut Matrix, &'a mut ParamState, &'a mut Box<dyn Graft>)>,
    pending: AtomicUsize,
}

/// One work-queue item.
#[derive(Clone, Copy)]
pub(crate) enum Task {
    /// Run the scheduled sides of one block (`fl`/`fr` are
    /// [`RefreshPlan`] flag bytes for the L/R units).
    Refresh { layer: usize, block: usize, fl: u8, fr: u8 },
    /// Precondition-and-apply a layer with no scheduled refresh work.
    Apply { layer: usize },
}

/// Execute one planned step: scheduled refresh units fan out over the
/// scoped-thread pool (per-worker arenas from `scratch_pool`), and each
/// layer's precondition-and-apply runs as soon as its refresh work is done
/// — immediately for untouched layers, inline after the last unit
/// otherwise. Per unit and per layer the math is identical to the
/// sequential loop, so trajectories are bit-for-bit deterministic
/// regardless of thread count. Returns the nanoseconds of refresh-task
/// busy time, summed across workers (the spike metric; equals wall-clock
/// at one worker, an upper bound on spike latency otherwise).
///
/// `tasks` is a caller-owned reused buffer (cleared here). The per-layer
/// views (`runs` and their block mutexes) hold per-call borrows and are
/// rebuilt each step — O(layers + blocks) small allocations, the same
/// order as the pre-scheduler per-layer work list; all *matrix* buffers
/// come from the arenas.
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute_step(
    layers: &mut [LayerState],
    params: &mut [Matrix],
    grads: &[Matrix],
    states: &mut [ParamState],
    grafts: &mut [Box<dyn Graft>],
    plan: &RefreshPlan,
    units: &[UnitId],
    tasks: &mut Vec<Task>,
    scratch_pool: &Mutex<Vec<ScratchArena>>,
    sc: &StepCtx<'_>,
) -> u64 {
    debug_assert_eq!(plan.len(), units.len());

    // Fast path: no refresh work this step. Precondition-and-apply
    // sequentially through the public per-layer path — no mutex views, no
    // task list, no thread spawns (the pre-scheduler threads == 1 path).
    // The common in-between step is two small matmuls per layer; the
    // blocked matmul already parallelizes internally for large layers.
    if plan.is_empty() {
        let mut scratch = scratch_pool
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        let it = layers
            .iter_mut()
            .zip(params.iter_mut())
            .zip(grads.iter())
            .zip(states.iter_mut())
            .zip(grafts.iter_mut());
        for ((((layer, w), g), st), gr) in it {
            // Guard screen: a poisoned gradient skips the layer's update
            // entirely — params and momentum never absorb the non-finite
            // values. Finite gradients pass through untouched.
            if g.has_non_finite() {
                sc.ledger.grad_screened();
                continue;
            }
            let mut ghat = scratch.take(g.rows(), g.cols());
            if sc.warmup {
                // Warmup: base-optimizer-only updates — the (identity)
                // root caches are not even multiplied through.
                ghat.copy_from(g);
            } else {
                layer.precondition_into(g, &mut ghat, &mut scratch);
            }
            // Graft screen: a non-finite magnitude or ‖Ĝ‖ (the
            // preconditioned product can overflow on finite-but-huge
            // gradients) skips the base update like the raw-grad screen.
            if apply_graft(gr.as_mut(), g, &mut ghat, sc.ledger) {
                BaseOptimizer::step_one(&sc.hyper, sc.kind, st, w, &ghat, sc.lr_scale);
            }
            scratch.recycle(ghat);
        }
        scratch_pool.lock().unwrap_or_else(|e| e.into_inner()).push(scratch);
        return 0;
    }

    // Guard screen (refresh steps): a layer whose gradient is non-finite
    // is skipped wholesale — neither its refresh units nor its parameter
    // update may absorb the poison. Counted once per poisoned layer per
    // step (mirroring the fast path above).
    let poisoned: Vec<bool> = grads.iter().map(|g| g.has_non_finite()).collect();
    for &p in &poisoned {
        if p {
            sc.ledger.grad_screened();
        }
    }

    let runs: Vec<LayerRun> = layers
        .iter_mut()
        .zip(params.iter_mut())
        .zip(grads.iter())
        .zip(states.iter_mut())
        .zip(grafts.iter_mut())
        .map(|((((layer, w), g), st), gr)| {
            // Disjoint field borrows: specs are read-only, blocks are the
            // per-unit mutable state behind the mutexes.
            let LayerState { rows, cols, blocking, blocks, passthrough } = layer;
            let blocking: &super::blocking::Blocking = blocking;
            LayerRun {
                rows: *rows,
                cols: *cols,
                passthrough: *passthrough,
                trivial: blocking.is_trivial(),
                specs: &blocking.blocks,
                grad: g,
                blocks: blocks.iter_mut().map(Mutex::new).collect(),
                apply: Mutex::new((w, st, gr)),
                pending: AtomicUsize::new(0),
            }
        })
        .collect();

    // Group the plan's units into per-block refresh tasks (units are laid
    // out [L, R] per block, so unit 2b/2b+1 address block-table entry b).
    tasks.clear();
    for b in 0..units.len() / 2 {
        let (fl, fr) = (plan.flags(2 * b), plan.flags(2 * b + 1));
        if (fl | fr) != 0 {
            let id = units[2 * b];
            debug_assert_eq!(id.side, Side::L);
            let (layer, block) = (id.layer as usize, id.block as usize);
            if poisoned[layer] {
                continue;
            }
            tasks.push(Task::Refresh { layer, block, fl, fr });
            runs[layer].pending.fetch_add(1, Ordering::Relaxed);
        }
    }
    for (i, run) in runs.iter().enumerate() {
        if !poisoned[i] && run.pending.load(Ordering::Relaxed) == 0 {
            tasks.push(Task::Apply { layer: i });
        }
    }
    // Every scheduled layer screened and nothing else to apply: the step
    // is a no-op (the plan was non-empty, but the poison vetoed it all).
    if tasks.is_empty() {
        return 0;
    }

    // This step does refresh work (the fast path handled the empty plan),
    // so fan out: Gram EMA / Cholesky / Schur–Newton dominate and the
    // per-block tasks are chunky enough to amortize the scoped spawns.
    let threads = crate::util::pool::default_threads().min(tasks.len().max(1));

    let refresh_ns = AtomicU64::new(0);
    let tasks = &*tasks;
    let runs = &runs;
    let refresh_ns_ref = &refresh_ns;
    crate::util::pool::parallel_for(tasks.len(), threads, |ti| {
        // Check an arena out of the pool (or start a fresh one on the very
        // first steps); every matrix temporary of the refresh + apply
        // pipeline is served from it, so a warmed-up step allocates no
        // matrix buffers. Arena contents never influence results — every
        // taken buffer is fully overwritten before use.
        let mut scratch = scratch_pool
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        match tasks[ti] {
            Task::Refresh { layer, block, fl, fr } => {
                let run = &runs[layer];
                let t0 = Instant::now();
                {
                    let mut bs = run.blocks[block].lock().unwrap();
                    let spec = &run.specs[block];
                    if (fl | fr) & RefreshPlan::GRAM != 0 {
                        let mut gb = scratch.take(spec.rows, spec.cols);
                        run.grad.block_into(spec.r0, spec.c0, &mut gb);
                        if fl & RefreshPlan::GRAM != 0 {
                            bs.gram_unit(Side::L, &gb, sc.step, sc.cfg, &mut scratch, sc.ledger);
                        }
                        if fr & RefreshPlan::GRAM != 0 {
                            bs.gram_unit(Side::R, &gb, sc.step, sc.cfg, &mut scratch, sc.ledger);
                        }
                        scratch.recycle(gb);
                    }
                    let forced = |side: Side| {
                        sc.fault.is_some_and(|f| {
                            f.forces_root_failure(
                                sc.step,
                                layer as u32,
                                block as u32,
                                side.index(),
                            )
                        })
                    };
                    if fl & RefreshPlan::ROOT != 0 {
                        let fo = forced(Side::L);
                        bs.root_unit(Side::L, sc.step, sc.cfg, sc.ctx, &mut scratch, fo, sc.ledger);
                    }
                    if fr & RefreshPlan::ROOT != 0 {
                        let fo = forced(Side::R);
                        bs.root_unit(Side::R, sc.step, sc.cfg, sc.ctx, &mut scratch, fo, sc.ledger);
                    }
                }
                refresh_ns_ref.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                // Last pending unit of the layer → this worker applies it.
                if run.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                    apply_layer(run, sc, &mut scratch);
                }
            }
            Task::Apply { layer } => apply_layer(&runs[layer], sc, &mut scratch),
        }
        scratch_pool.lock().unwrap_or_else(|e| e.into_inner()).push(scratch);
    });
    refresh_ns.into_inner()
}

/// `Ĝ = D(L̂)·G·D(R̂)` (line 15), grafting (Eq. 13), base-optimizer update —
/// the apply phase of a refresh step, reading the (possibly just-refreshed)
/// root caches. Runs exactly once per layer per step.
///
/// This mirrors `LayerState::precondition_into` (the reference
/// implementation, used by the no-refresh fast path and the oracle tests)
/// with per-block mutex access instead of a plain borrow — the mutexes are
/// uncontended here because a layer only applies after its refresh units
/// completed. The every-n bit-identity suite exercises all three branches
/// (passthrough / trivial / blocked) against the reference; keep the two
/// in lockstep.
fn apply_layer(run: &LayerRun<'_>, sc: &StepCtx<'_>, scratch: &mut ScratchArena) {
    let mut guard = run.apply.lock().unwrap();
    let (w, st, gr) = &mut *guard;
    let g = run.grad;
    let mut ghat = scratch.take(run.rows, run.cols);
    if run.passthrough {
        ghat.copy_from(g);
    } else if run.trivial {
        let bs = run.blocks[0].lock().unwrap();
        bs.precondition_into(g, &mut ghat, scratch);
    } else {
        for (spec, blk) in run.specs.iter().zip(run.blocks.iter()) {
            let mut gb = scratch.take(spec.rows, spec.cols);
            g.block_into(spec.r0, spec.c0, &mut gb);
            let mut ob = scratch.take(spec.rows, spec.cols);
            let bs = blk.lock().unwrap();
            bs.precondition_into(&gb, &mut ob, scratch);
            drop(bs);
            ghat.set_block(spec.r0, spec.c0, &ob);
            scratch.recycle(ob);
            scratch.recycle(gb);
        }
    }
    // Same graft screen as the fast path: a screened layer skips the base
    // update entirely (its accumulator, if any, already advanced — exactly
    // like the sequential reference).
    if apply_graft(gr.as_mut(), g, &mut ghat, sc.ledger) {
        BaseOptimizer::step_one(&sc.hyper, sc.kind, st, w, &ghat, sc.lr_scale);
    }
    scratch.recycle(ghat);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn infos(n: usize) -> Vec<UnitInfo> {
        (0..n)
            .map(|i| UnitInfo {
                id: UnitId {
                    layer: 0,
                    block: (i / 2) as u32,
                    side: if i % 2 == 0 { Side::L } else { Side::R },
                },
                meta: UnitMeta::default(),
            })
            .collect()
    }

    fn cfg(t1: u64, t2: u64) -> ShampooConfig {
        ShampooConfig { t1, t2, ..Default::default() }
    }

    #[test]
    fn every_n_marks_all_on_boundaries_only() {
        let units = infos(6);
        let c = cfg(2, 4);
        let mut s = EveryN;
        let mut plan = RefreshPlan::default();
        for step in 1..=8u64 {
            plan.reset(units.len());
            s.plan(step, &units, &c, &mut plan);
            let want_gram = if step % 2 == 0 { 6 } else { 0 };
            let want_root = if step % 4 == 0 { 6 } else { 0 };
            assert_eq!(plan.gram_units(), want_gram, "step {step}");
            assert_eq!(plan.root_units(), want_root, "step {step}");
        }
    }

    #[test]
    fn staggered_bounds_per_step_and_covers_interval() {
        for (n, t2) in [(6usize, 4u64), (32, 8), (3, 9), (16, 16), (5, 1)] {
            let units = infos(n);
            let c = cfg(1, t2);
            let mut s = Staggered;
            let mut plan = RefreshPlan::default();
            let mut per_unit = vec![0usize; n];
            let mut max_step = 0usize;
            for step in 1..=t2 {
                plan.reset(n);
                s.plan(step, &units, &c, &mut plan);
                let mut this = 0;
                for u in 0..n {
                    if plan.flags(u) & RefreshPlan::ROOT != 0 {
                        per_unit[u] += 1;
                        this += 1;
                    }
                }
                max_step = max_step.max(this);
            }
            assert!(
                per_unit.iter().all(|&c| c == 1),
                "n={n} t2={t2}: coverage {per_unit:?}"
            );
            assert!(
                max_step <= n.div_ceil(t2 as usize),
                "n={n} t2={t2}: max/step {max_step}"
            );
        }
    }

    #[test]
    fn staleness_respects_budget_and_prefers_stale_units() {
        let mut units = infos(8);
        let c = cfg(1, 4); // auto budget = ⌈8/4⌉ = 2
        // Unit 5 is much more stale than the rest.
        for (i, u) in units.iter_mut().enumerate() {
            u.meta.last_root = if i == 5 { 1 } else { 90 };
            u.meta.pending_norm = 1.0;
        }
        let mut s = Staleness::new();
        let mut plan = RefreshPlan::default();
        plan.reset(units.len());
        s.plan(100, &units, &c, &mut plan);
        assert_eq!(plan.root_units(), 2);
        assert!(plan.flags(5) & RefreshPlan::ROOT != 0, "most-stale unit must be chosen");
    }

    #[test]
    fn staleness_pending_norm_breaks_ties() {
        let mut units = infos(4);
        let c = ShampooConfig { t1: 1, t2: 4, refresh_budget: 1, ..Default::default() };
        for (i, u) in units.iter_mut().enumerate() {
            u.meta.last_root = 10; // equal staleness, below the forced tier
            u.meta.pending_norm = i as f32;
        }
        let mut s = Staleness::new();
        let mut plan = RefreshPlan::default();
        plan.reset(units.len());
        s.plan(12, &units, &c, &mut plan);
        assert_eq!(plan.root_units(), 1);
        assert!(plan.flags(3) & RefreshPlan::ROOT != 0, "largest pending norm wins ties");
    }

    #[test]
    fn staleness_survives_nan_pending_norm_and_heals_it_first() {
        // A NaN gradient poisons pending_norm until the unit's next root
        // refresh; the comparator must stay a total order (no sort panic)
        // and the poisoned unit must be refreshed first so it self-heals.
        let mut units = infos(6);
        let c = ShampooConfig { t1: 1, t2: 4, refresh_budget: 2, ..Default::default() };
        for (i, u) in units.iter_mut().enumerate() {
            u.meta.last_root = 10;
            u.meta.pending_norm = if i == 4 { f32::NAN } else { i as f32 };
        }
        let mut s = Staleness::new();
        let mut plan = RefreshPlan::default();
        plan.reset(units.len());
        s.plan(12, &units, &c, &mut plan);
        assert_eq!(plan.root_units(), 2);
        assert!(plan.flags(4) & RefreshPlan::ROOT != 0, "NaN unit must refresh first");
    }

    #[test]
    fn spreading_policies_never_root_refresh_without_gram_data() {
        // Before a unit's first Gram update, its side codec holds the ε·I
        // init; factoring that into a root would give ~ε^{-1/4}·I. The
        // spreading policies must pair such roots with a just-in-time Gram
        // update (the executor runs gram before root within a block).
        let units = infos(4); // all last_gram == 0
        let c = cfg(100, 2); // roots fire long before the first T1 boundary
        let mut plan = RefreshPlan::default();
        for mut s in [
            Box::new(Staggered) as Box<dyn RefreshScheduler>,
            Box::new(Staleness::new()),
        ] {
            plan.reset(units.len());
            s.plan(1, &units, &c, &mut plan);
            assert!(plan.root_units() > 0, "{}: fixture must schedule roots", s.key());
            for u in 0..units.len() {
                if plan.flags(u) & RefreshPlan::ROOT != 0 {
                    assert!(
                        plan.flags(u) & RefreshPlan::GRAM != 0,
                        "{}: unit {u} would root-refresh the ε·I init",
                        s.key()
                    );
                }
            }
        }
    }

    #[test]
    fn clear_root_strips_only_the_root_flag() {
        let mut plan = RefreshPlan::default();
        plan.reset(3);
        plan.mark_gram(1);
        plan.mark_root(1);
        plan.mark_root(2);
        plan.clear_root(1);
        assert_eq!(plan.flags(1), RefreshPlan::GRAM, "gram flag must survive the strip");
        assert_eq!(plan.root_units(), 1);
        assert_eq!(plan.gram_units(), 1);
    }

    #[test]
    fn effective_budget_defaults_to_staggered_rate() {
        assert_eq!(effective_budget(&cfg(1, 4), 8), 2);
        assert_eq!(effective_budget(&cfg(1, 100), 8), 1);
        assert_eq!(
            effective_budget(&ShampooConfig { refresh_budget: 5, ..cfg(1, 4) }, 8),
            5
        );
    }

    #[test]
    fn registry_has_builtins_and_accepts_custom_keys() {
        for key in ["every-n", "staggered", "staleness"] {
            let b = lookup(key).unwrap_or_else(|| panic!("builtin '{key}' missing"));
            assert_eq!(b.key, key);
        }
        assert!(lookup("no-such-policy").is_none());
        // Built-in keys cannot be shadowed.
        let b = lookup("every-n").unwrap();
        assert!(!register(b));
        assert!(scheduler_keys().starts_with(&["every-n", "staggered", "staleness"]));
    }
}

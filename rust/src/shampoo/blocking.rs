//! Max-order blocking (paper App. C.3: "Shampoo applies layer-wise
//! preconditioning to blocks derived from large matrices, with the maximum
//! order of the preconditioner set to 1200").
//!
//! A parameter of shape `m×n` is tiled into sub-blocks of at most
//! `max_order` per side; each sub-block keeps its own `(L, R)` pair. This
//! caps the O(d³) root cost and bounds preconditioner memory.

/// One sub-block of a parameter matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpec {
    pub r0: usize,
    pub c0: usize,
    pub rows: usize,
    pub cols: usize,
}

/// The blocking of an `m×n` parameter with side cap `max_order`.
#[derive(Clone, Debug)]
pub struct Blocking {
    pub m: usize,
    pub n: usize,
    pub max_order: usize,
    pub blocks: Vec<BlockSpec>,
}

impl Blocking {
    pub fn new(m: usize, n: usize, max_order: usize) -> Blocking {
        let cap = max_order.max(1);
        let mut blocks = Vec::new();
        let mut r0 = 0;
        while r0 < m {
            let rows = cap.min(m - r0);
            let mut c0 = 0;
            while c0 < n {
                let cols = cap.min(n - c0);
                blocks.push(BlockSpec { r0, c0, rows, cols });
                c0 += cols;
            }
            r0 += rows;
        }
        Blocking { m, n, max_order: cap, blocks }
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// True when the parameter fits in a single preconditioner pair.
    pub fn is_trivial(&self) -> bool {
        self.blocks.len() == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_param_is_one_block() {
        let b = Blocking::new(64, 32, 1200);
        assert!(b.is_trivial());
        assert_eq!(b.blocks[0], BlockSpec { r0: 0, c0: 0, rows: 64, cols: 32 });
    }

    #[test]
    fn partition_is_exact_and_disjoint() {
        for (m, n, cap) in [(100, 70, 32), (64, 64, 64), (65, 64, 64), (1, 500, 96)] {
            let b = Blocking::new(m, n, cap);
            // Coverage check: every cell in exactly one block.
            let mut seen = vec![0u8; m * n];
            for blk in &b.blocks {
                assert!(blk.rows <= cap && blk.cols <= cap);
                for i in blk.r0..blk.r0 + blk.rows {
                    for j in blk.c0..blk.c0 + blk.cols {
                        seen[i * n + j] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s == 1), "({m},{n},{cap}) not a partition");
        }
    }

    #[test]
    fn block_count() {
        let b = Blocking::new(130, 70, 64);
        // rows: 64+64+2 → 3 strips; cols: 64+6 → 2 strips
        assert_eq!(b.num_blocks(), 6);
    }
}

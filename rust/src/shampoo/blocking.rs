//! Max-order blocking (paper App. C.3: "Shampoo applies layer-wise
//! preconditioning to blocks derived from large matrices, with the maximum
//! order of the preconditioner set to 1200").
//!
//! A parameter of shape `m×n` is tiled into sub-blocks of at most
//! `max_order` per side; each sub-block keeps its own `(L, R)` pair. This
//! caps the O(d³) root cost and bounds preconditioner memory. Each dimension
//! is ceil-divided into equal-width strips (±1), so blocks are balanced —
//! important now that blocks are the refresh scheduler's work units.

/// One sub-block of a parameter matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpec {
    pub r0: usize,
    pub c0: usize,
    pub rows: usize,
    pub cols: usize,
}

/// The blocking of an `m×n` parameter with side cap `max_order`.
#[derive(Clone, Debug)]
pub struct Blocking {
    pub m: usize,
    pub n: usize,
    pub max_order: usize,
    pub blocks: Vec<BlockSpec>,
}

/// Ceil-divide `dim` into `⌈dim/cap⌉` strips of near-equal width (the first
/// `dim % k` strips are one wider). Returns `(offset, width)` per strip.
///
/// Balanced strips avoid the degenerate remainder of greedy `cap`-sized
/// tiling — 130 at cap 64 yields 44/43/43, not 64/64/2 — so every block's
/// preconditioner does comparable work and no refresh unit is a sliver.
fn strips(dim: usize, cap: usize) -> Vec<(usize, usize)> {
    if dim == 0 {
        return Vec::new();
    }
    let k = dim.div_ceil(cap);
    let base = dim / k;
    let extra = dim % k;
    let mut out = Vec::with_capacity(k);
    let mut at = 0;
    for i in 0..k {
        let w = base + usize::from(i < extra);
        out.push((at, w));
        at += w;
    }
    out
}

impl Blocking {
    /// Tile an `m×n` parameter with side cap `max_order` (clamped to ≥ 1).
    ///
    /// Each dimension is ceil-divided into `⌈dim/cap⌉` near-equal strips
    /// (the first `dim mod k` strips one wider): 130 at cap 64 blocks as
    /// 44/43/43, never the greedy 64/64/2. The row × column strip cross
    /// product becomes row-major [`BlockSpec`]s, so every block's
    /// preconditioner — and therefore every refresh-scheduler unit — does
    /// comparable work.
    pub fn new(m: usize, n: usize, max_order: usize) -> Blocking {
        let cap = max_order.max(1);
        let row_strips = strips(m, cap);
        let col_strips = strips(n, cap);
        let mut blocks = Vec::with_capacity(row_strips.len() * col_strips.len());
        for &(r0, rows) in &row_strips {
            for &(c0, cols) in &col_strips {
                blocks.push(BlockSpec { r0, c0, rows, cols });
            }
        }
        Blocking { m, n, max_order: cap, blocks }
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// True when the parameter fits in a single preconditioner pair.
    pub fn is_trivial(&self) -> bool {
        self.blocks.len() == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_param_is_one_block() {
        let b = Blocking::new(64, 32, 1200);
        assert!(b.is_trivial());
        assert_eq!(b.blocks[0], BlockSpec { r0: 0, c0: 0, rows: 64, cols: 32 });
    }

    #[test]
    fn partition_is_exact_and_disjoint() {
        for (m, n, cap) in [(100, 70, 32), (64, 64, 64), (65, 64, 64), (1, 500, 96)] {
            let b = Blocking::new(m, n, cap);
            // Coverage check: every cell in exactly one block.
            let mut seen = vec![0u8; m * n];
            for blk in &b.blocks {
                assert!(blk.rows <= cap && blk.cols <= cap);
                for i in blk.r0..blk.r0 + blk.rows {
                    for j in blk.c0..blk.c0 + blk.cols {
                        seen[i * n + j] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s == 1), "({m},{n},{cap}) not a partition");
        }
    }

    #[test]
    fn block_count() {
        let b = Blocking::new(130, 70, 64);
        // rows: 44+43+43 → 3 strips; cols: 35+35 → 2 strips
        assert_eq!(b.num_blocks(), 6);
        assert_eq!(b.blocks[0], BlockSpec { r0: 0, c0: 0, rows: 44, cols: 35 });
        assert_eq!(b.blocks[5], BlockSpec { r0: 87, c0: 35, rows: 43, cols: 35 });
    }

    #[test]
    fn strips_are_balanced() {
        // No strip differs from another by more than one element, and no
        // degenerate remainder strip survives (the old greedy tiling gave
        // 130 @ 64 → 64+64+2).
        for (dim, cap) in [(130, 64), (70, 64), (1200, 1200), (1201, 1200), (300, 7)] {
            let s = strips(dim, cap);
            let min = s.iter().map(|&(_, w)| w).min().unwrap();
            let max = s.iter().map(|&(_, w)| w).max().unwrap();
            assert!(max <= cap, "({dim},{cap}) strip {max} over cap");
            assert!(max - min <= 1, "({dim},{cap}) unbalanced: {min}..{max}");
            assert_eq!(s.iter().map(|&(_, w)| w).sum::<usize>(), dim);
            assert_eq!(s.len(), dim.div_ceil(cap));
        }
    }
}

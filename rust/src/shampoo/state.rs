//! Per-layer preconditioner state, stored behind [`PrecondCodec`] trait
//! objects.
//!
//! Each parameter is tiled by [`Blocking`]; each block keeps two
//! [`SideState`]s (the `L` and `R` Kronecker factors), each holding a Gram
//! codec, an inverse-root codec, a dequantized root cache, and the
//! [`UnitMeta`] refresh bookkeeping. A `(layer, block, side)` triple is one
//! **refresh unit** — the granularity at which `shampoo::scheduler` policies
//! decide what to recompute each step. Dequantized roots are cached between
//! refreshes — the codec is the persistent store, the cache is transient
//! scratch that never diverges from `D(L̂)` because `L̂` only changes at
//! refresh time.
//!
//! The refresh *schedule* lives in `shampoo::scheduler`; the unit-level
//! *mechanics* (Gram EMA re-store, root recomputation) live here; everything
//! representation-specific (Cholesky factorization, error feedback, bit
//! packing) lives inside the codecs.

use super::blocking::Blocking;
use super::config::ShampooConfig;
use crate::linalg::schur_newton::inverse_pth_root_scratch;
use crate::linalg::{
    inner, inverse_pth_root_eig_planned, matmul_into_planned, matmul_tn_into_planned,
    psd_clamped_root_planned, syrk_into_planned, Matrix, ScratchArena,
};
use crate::metrics::HealthLedger;
use crate::quant::codec::{lookup, CodecBuilder, CodecCtx};
use crate::quant::PrecondCodec;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;

/// Which Kronecker factor of a block a refresh unit addresses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Side {
    /// The row-space factor `L` (`G·Gᵀ` statistics, `L̂ = L^{-1/4}`).
    L,
    /// The column-space factor `R` (`Gᵀ·G` statistics, `R̂ = R^{-1/4}`).
    R,
}

impl Side {
    pub const BOTH: [Side; 2] = [Side::L, Side::R];

    pub fn index(self) -> usize {
        match self {
            Side::L => 0,
            Side::R => 1,
        }
    }
}

/// Which rung of the numerical-health fallback ladder served one root
/// refresh. Returned by every `update_root`, mapped onto
/// [`HealthLedger`] counters by `root_unit`.
///
/// The ladder, top to bottom:
/// 1. [`Healthy`](FallbackOutcome::Healthy) — the Schur–Newton iteration
///    converged; nothing exceptional happened.
/// 2. [`JitterRescue`](FallbackOutcome::JitterRescue) — Schur–Newton
///    diverged, but the trace-scaled-ridge eigendecomposition route
///    (`+λmax·ε·I`, eigenvalue-clamped) produced a finite root.
/// 3. [`PsdProjection`](FallbackOutcome::PsdProjection) — the ridged route
///    was itself non-finite (NaN/Inf in the gram); the sanitized
///    PSD-clamped projection ([`psd_clamped_root_planned`]) recovered a
///    finite root.
/// 4. [`StaleRoot`](FallbackOutcome::StaleRoot) — no fresh root could be
///    computed (or a fault forced the failure); the last good cached root
///    keeps serving.
/// 5. [`DiagonalFloor`](FallbackOutcome::DiagonalFloor) — not even a stale
///    root was available; the unit was floored to diagonal
///    preconditioning from the gram's sanitized diagonal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FallbackOutcome {
    Healthy,
    JitterRescue,
    PsdProjection,
    StaleRoot,
    DiagonalFloor,
}

impl FallbackOutcome {
    /// Rungs 1–3 install a freshly computed root; rungs 4–5 only serve
    /// previously known state — the distinction quarantine accounting
    /// (consecutive-failure counting, probation release) keys on.
    pub fn is_serving_fresh(self) -> bool {
        matches!(
            self,
            FallbackOutcome::Healthy
                | FallbackOutcome::JitterRescue
                | FallbackOutcome::PsdProjection
        )
    }

    /// Stable serialization tag (checkpoint persistence of in-flight async
    /// refresh results). 0 is reserved for "no outcome".
    pub fn code(self) -> u8 {
        match self {
            FallbackOutcome::Healthy => 1,
            FallbackOutcome::JitterRescue => 2,
            FallbackOutcome::PsdProjection => 3,
            FallbackOutcome::StaleRoot => 4,
            FallbackOutcome::DiagonalFloor => 5,
        }
    }

    /// Inverse of [`FallbackOutcome::code`].
    pub fn from_code(code: u8) -> Option<FallbackOutcome> {
        match code {
            1 => Some(FallbackOutcome::Healthy),
            2 => Some(FallbackOutcome::JitterRescue),
            3 => Some(FallbackOutcome::PsdProjection),
            4 => Some(FallbackOutcome::StaleRoot),
            5 => Some(FallbackOutcome::DiagonalFloor),
            _ => None,
        }
    }
}

/// Per-unit numerical-health state: consecutive-failure counting and the
/// quarantine/probation machine. Persistent optimizer state (rides inside
/// [`UnitMeta`], serialized with it) so a resumed run continues probation
/// timing deterministically.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct UnitHealth {
    /// Root refreshes in a row that fell to the stale/floor rungs. Reset
    /// by any fresh-root outcome.
    pub consecutive_failures: u32,
    /// `step + 1` of the most recent quarantine entry (or probation
    /// failure); 0 = not quarantined. Offset by one so step 0 state is
    /// unambiguous.
    pub quarantined_since: u64,
    /// Total quarantine entries over the unit's lifetime.
    pub quarantines: u32,
    /// Total probation releases over the unit's lifetime.
    pub releases: u32,
}

impl UnitHealth {
    /// Exact byte footprint: failure counter + since-step + two counters.
    pub const BYTES: usize = 4 + 8 + 4 + 4;

    pub fn is_quarantined(&self) -> bool {
        self.quarantined_since != 0
    }
}

/// Per-unit refresh bookkeeping the scheduler decides from.
///
/// These bytes are persistent optimizer state and are counted in
/// `size_bytes()` / `MemoryModel::shampoo_bytes` ([`UnitMeta::BYTES`] per
/// unit, two units per block) — the memory-model parity tests pin this.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct UnitMeta {
    /// Step of the last Gram EMA update for this unit (0 = never).
    pub last_gram: u64,
    /// Step of the last inverse-root recomputation (0 = never).
    pub last_root: u64,
    /// Accumulated `‖G_block‖²_F` absorbed into the Gram side since the last
    /// root refresh — the `Staleness` policy's update-magnitude weight.
    pub pending_norm: f32,
    /// Total root refreshes of this unit (coverage-counter tests).
    pub refreshes: u32,
    /// Quarantine / consecutive-failure state (guard engine).
    pub health: UnitHealth,
}

impl UnitMeta {
    /// Exact byte footprint: two `u64` steps + `f32` norm + `u32` counter
    /// + the health block.
    pub const BYTES: usize = 8 + 8 + 4 + 4 + UnitHealth::BYTES;
}

/// Resolve a codec builder, falling back to a panic that names the key —
/// a config can reference registered-at-runtime codecs, so this is a
/// runtime (not compile-time) binding by design.
fn builder(key: &str) -> CodecBuilder {
    lookup(key).unwrap_or_else(|| panic!("preconditioner codec '{key}' is not registered"))
}

/// Fresh f32 codec holding `x` (initial roots, small-tensor exemption).
fn f32_with(x: &Matrix, ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    let mut c = (builder("f32").side)(ctx);
    c.store(x);
    c
}

/// Side codec for a `dim×dim` Gram slot, honoring the small-tensor
/// exemption (App. C.3: tiny preconditioners stay f32).
fn side_codec(dim: usize, cfg: &ShampooConfig, ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    let quantize = dim * dim >= cfg.quant.min_quant_elems;
    let key = if quantize { cfg.side_codec_key() } else { "f32" };
    let mut codec = (builder(key).side)(ctx);
    codec.init(dim, cfg.eps);
    codec
}

/// Absorb a fresh Gram statistic into a side codec:
/// `L ← β·L_prev + (1−β)·gram`, then re-store in its representation
/// (Eq. (5) for VQ; the codec runs Eq. (7)–(11) for CQ). All temporaries
/// come from the caller's arena — a warmed-up refresh allocates nothing.
fn update_side(
    side: &mut dyn PrecondCodec,
    gram: &Matrix,
    cfg: &ShampooConfig,
    scratch: &mut ScratchArena,
) {
    let mut l_new = scratch.take(gram.rows(), gram.cols());
    side.load_into(&mut l_new, scratch);
    l_new.ema(cfg.beta, gram);
    l_new.symmetrize();
    side.store_into(&l_new, scratch);
    scratch.recycle(l_new);
}

/// Rungs 0–2 of the fallback ladder as a *pure* function of the dequantized
/// gram: Schur–Newton, ridged-eigendecomposition rescue, sanitized PSD
/// projection. Returns the computed root and the rung that produced it, or
/// `None` when every compute rung failed (the caller falls to the
/// stale-root / diagonal-floor serving rungs, which need codec state).
///
/// Deliberately free of any codec, ledger, or metadata access: the async
/// refresh engine runs this on worker shards against a gram snapshot taken
/// at submission, and determinism of the result depends only on
/// `(precond, cfg)` — the GEMM tier underneath is bit-identical across
/// thread counts, so worker-side results equal step-thread results.
pub(crate) fn compute_root_from_gram(
    precond: &Matrix,
    cfg: &ShampooConfig,
    scratch: &mut ScratchArena,
) -> Option<(Matrix, FallbackOutcome)> {
    let dim = precond.rows();
    // Eq. (6)/(12): ridge λ_max·ε·I handled inside the iteration.
    let (x, stats) = inverse_pth_root_scratch(precond, &cfg.schur, scratch);
    // Direct (VQ) quantization can break positive-definiteness
    // (Tab. 9); Schur–Newton then diverges. Fall back to the exact
    // eigendecomposition route with eigenvalue clamping — defined
    // for indefinite inputs, so VQ stays *functional but degraded*,
    // matching the paper's observed behavior.
    // The true root satisfies ‖X‖_max ≤ (λmin + ridge)^{-1/4}; a
    // quantization-created negative eigendirection can pass through
    // zero during the iteration, leaving M ≈ I (small residual)
    // while X accumulated an enormous finite factor — bound the
    // magnitude.
    let lam0 = stats.lambda_max.max(0.0);
    let root_bound = 10.0 * ((lam0 * cfg.schur.eps).max(1e-10) as f64).powf(-0.25) as f32;
    if x.has_non_finite()
        || !stats.residual.is_finite()
        || stats.residual > 0.1
        || crate::linalg::max_abs(&x) > root_bound
    {
        // Exceptional path — allocation here is acceptable, but the
        // ridged copy and the matmul plan still come from the arena.
        scratch.recycle(x);
        let lam = stats.lambda_max.max(0.0);
        // Clamp at λmax·1e-4 (not the ε ridge): quantization-created
        // negative directions would otherwise get ~(1e-6)^{-1/4} ≈
        // 30× amplification and swamp the true curvature signal.
        let clamp = (lam * 1e-4).max(1e-10);
        // The ridge rung feeds the gram to the eigensolver as-is, so
        // it is only defined for finite grams (the Jacobi sweep's
        // eigenvalue sort is not total over NaN); non-finite grams
        // skip straight to the sanitized projection rung.
        let rescued = if precond.has_non_finite() {
            None
        } else {
            let mut ridged = scratch.take(dim, dim);
            ridged.copy_from(precond);
            ridged.add_diag(lam * cfg.schur.eps);
            let eig =
                inverse_pth_root_eig_planned(&ridged, cfg.schur.p as f64, clamp, scratch.plan());
            scratch.recycle(ridged);
            if eig.has_non_finite() {
                scratch.recycle(eig);
                None
            } else {
                Some(eig)
            }
        };
        if let Some(eig) = rescued {
            Some((eig, FallbackOutcome::JitterRescue))
        } else {
            let psd = psd_clamped_root_planned(precond, cfg.schur.p as f64, clamp, scratch.plan());
            if !psd.has_non_finite() {
                Some((psd, FallbackOutcome::PsdProjection))
            } else {
                scratch.recycle(psd);
                None
            }
        }
    } else {
        Some((x, FallbackOutcome::Healthy))
    }
}

/// One Kronecker factor of one block: Gram codec + root codec + root cache
/// + refresh metadata. This is the state behind ONE refresh unit.
#[derive(Clone, Debug)]
pub struct SideState {
    dim: usize,
    gram: Box<dyn PrecondCodec>,
    root: Box<dyn PrecondCodec>,
    /// Builder key the root slot was created from ("f32" until the first
    /// refresh) — compared against the configured key so the SAME codec
    /// instance is reused across refreshes once it matches.
    root_key: &'static str,
    /// Dequantized root cache (refreshed whenever `root` changes).
    cache: Matrix,
    /// Whether the root slot holds computed state. `false` only during the
    /// `start_preconditioning_step` warmup, where the root is still the
    /// spec-derived identity: uncounted in [`SideState::size_bytes`] (the
    /// memory model must not charge roots before preconditioning starts)
    /// and unserialized (a mid-warmup checkpoint rebuilds the identity
    /// cache from the spec). Any [`SideState::rebind_and_store`] makes the
    /// slot live for good.
    root_live: bool,
    /// Refresh bookkeeping (scheduler input; counted in `size_bytes`).
    pub meta: UnitMeta,
}

impl SideState {
    fn new(dim: usize, cfg: &ShampooConfig, ctx: &CodecCtx) -> SideState {
        SideState {
            dim,
            gram: side_codec(dim, cfg, ctx),
            // Algorithm 1: L̂₀ = I, R̂₀ = I (f32 until the first refresh
            // replaces the slot with the variant's root codec).
            root: f32_with(&Matrix::eye(dim), ctx),
            root_key: "f32",
            cache: Matrix::eye(dim),
            root_live: cfg.start_preconditioning_step == 0,
            meta: UnitMeta::default(),
        }
    }

    fn update_gram(&mut self, gram: &Matrix, cfg: &ShampooConfig, scratch: &mut ScratchArena) {
        update_side(&mut *self.gram, gram, cfg, scratch);
    }

    /// Recompute this unit's inverse root, descending the fallback ladder
    /// as far as needed (see [`FallbackOutcome`] for the rungs). `forced`
    /// simulates a hard factorization failure (deterministic fault
    /// injection): the computation is skipped entirely and the unit drops
    /// straight to the stale-root / floor rungs.
    fn update_root(
        &mut self,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
        forced: bool,
    ) -> FallbackOutcome {
        if forced {
            return self.serve_stale_or_floor(cfg, ctx, scratch);
        }
        let dim = self.dim;
        let mut precond = scratch.take(dim, dim);
        self.gram.load_into(&mut precond, scratch);
        let result = compute_root_from_gram(&precond, cfg, scratch);
        scratch.recycle(precond);
        match result {
            Some((x, outcome)) => {
                self.rebind_and_store(&x, cfg, ctx, scratch);
                scratch.recycle(x);
                outcome
            }
            None => self.serve_stale_or_floor(cfg, ctx, scratch),
        }
    }

    /// Rungs 4–5 of the ladder: keep the last good cached root if it is
    /// finite, otherwise install the diagonal floor.
    fn serve_stale_or_floor(
        &mut self,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) -> FallbackOutcome {
        if self.cache.has_non_finite() {
            self.install_floor(cfg, ctx, scratch);
            FallbackOutcome::DiagonalFloor
        } else {
            FallbackOutcome::StaleRoot
        }
    }

    /// Install the diagonal floor `L̂ ← diag((d_i + ε)^{-1/p})` from the
    /// gram's sanitized diagonal — the ladder's last rung and the
    /// quarantine serving state. Stored through the root codec so a
    /// checkpoint round-trips the floored unit like any other.
    fn install_floor(&mut self, cfg: &ShampooConfig, ctx: &CodecCtx, scratch: &mut ScratchArena) {
        let dim = self.dim;
        let mut gram = scratch.take(dim, dim);
        self.gram.load_into(&mut gram, scratch);
        let floor = Matrix::from_fn(dim, dim, |i, j| {
            if i != j {
                return 0.0;
            }
            let d = gram[(i, i)];
            let d = if d.is_finite() && d > 0.0 { d } else { 0.0 };
            ((d + cfg.eps) as f64).powf(-1.0 / cfg.schur.p as f64) as f32
        });
        scratch.recycle(gram);
        self.rebind_and_store(&floor, cfg, ctx, scratch);
        scratch.recycle(floor);
    }

    /// Bind the root slot to the configured codec (first refresh switches
    /// it off its f32 init; afterwards the SAME codec instance is reused so
    /// stateful root codecs keep their state across refreshes), store `x`,
    /// and rebuild the dequantized cache.
    fn rebind_and_store(
        &mut self,
        x: &Matrix,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) {
        let configured = cfg.root_codec_key();
        let quantize = configured != "f32" && self.dim * self.dim >= cfg.quant.min_quant_elems;
        let key = if quantize { configured } else { "f32" };
        if self.root_key != key {
            self.root = (builder(key).root)(ctx);
            self.root_key = key;
        }
        self.root.store_into(x, scratch);
        self.root.load_into(&mut self.cache, scratch);
        self.root_live = true;
    }

    pub(crate) fn cache(&self) -> &Matrix {
        &self.cache
    }

    fn size_bytes(&self) -> usize {
        let root = if self.root_live { self.root.size_bytes() } else { 0 };
        self.gram.size_bytes() + root + UnitMeta::BYTES
    }

    /// Serialize this refresh unit's persistent state: Gram codec payload,
    /// root codec key + payload, and the [`UnitMeta`] bookkeeping. The
    /// dequantized root cache is transient (it never diverges from the
    /// stored root) and is recomputed on restore, not written.
    fn write_state(&self, out: &mut ByteWriter) {
        self.gram.save_state(out);
        // Warmup deferral: a root slot that never left its spec-derived
        // identity writes only the liveness flag — restore rebuilds the
        // identity cache instead of reading a payload.
        out.put_u8(self.root_live as u8);
        if self.root_live {
            out.put_str(self.root_key);
            self.root.save_state(out);
        }
        out.put_u64(self.meta.last_gram);
        out.put_u64(self.meta.last_root);
        out.put_f32(self.meta.pending_norm);
        out.put_u32(self.meta.refreshes);
        out.put_u32(self.meta.health.consecutive_failures);
        out.put_u64(self.meta.health.quarantined_since);
        out.put_u32(self.meta.health.quarantines);
        out.put_u32(self.meta.health.releases);
    }

    /// Inverse of [`SideState::write_state`] on a freshly built unit: the
    /// root slot is switched to the saved codec key (same re-bind idiom as
    /// `update_root`), payloads restored byte-exactly, and the root cache
    /// rebuilt by dequantizing the restored root.
    fn read_state(
        &mut self,
        r: &mut ByteReader<'_>,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) -> Result<()> {
        self.gram.restore_state(r)?;
        self.root_live = r.get_u8()? != 0;
        if self.root_live {
            let key = r.get_str()?;
            if self.root_key != key {
                let b = lookup(&key)
                    .ok_or_else(|| crate::anyhow!("root codec '{key}' is not registered"))?;
                self.root = (b.root)(ctx);
                self.root_key = b.key;
            }
            self.root.restore_state(r)?;
        }
        self.meta.last_gram = r.get_u64()?;
        self.meta.last_root = r.get_u64()?;
        self.meta.pending_norm = r.get_f32()?;
        self.meta.refreshes = r.get_u32()?;
        self.meta.health.consecutive_failures = r.get_u32()?;
        self.meta.health.quarantined_since = r.get_u64()?;
        self.meta.health.quarantines = r.get_u32()?;
        self.meta.health.releases = r.get_u32()?;
        if self.root_live {
            self.root.load_into(&mut self.cache, scratch);
        } else {
            self.cache = Matrix::eye(self.dim);
        }
        Ok(())
    }
}

/// State of one sub-block of one parameter: `L` and `R` [`SideState`]s.
#[derive(Clone, Debug)]
pub struct BlockState {
    pub rows: usize,
    pub cols: usize,
    sides: [SideState; 2],
}

impl BlockState {
    fn new(rows: usize, cols: usize, cfg: &ShampooConfig, ctx: &CodecCtx) -> BlockState {
        BlockState {
            rows,
            cols,
            sides: [SideState::new(rows, cfg, ctx), SideState::new(cols, cfg, ctx)],
        }
    }

    pub(crate) fn side(&self, s: Side) -> &SideState {
        &self.sides[s.index()]
    }

    /// One refresh unit's Gram EMA update: extract nothing — `gb` is the
    /// already-extracted gradient block. Records `last_gram` and accumulates
    /// the pending-update norm the `Staleness` policy weighs.
    ///
    /// Guard screens run at two points: a non-finite gradient block and a
    /// non-finite gram product (finite-but-huge gradients can overflow
    /// `G·Gᵀ` to Inf) each skip the update — counted on `ledger`, no codec
    /// or EF state is touched and no metadata advances, so the poisoned
    /// step simply never happened for this unit.
    pub(crate) fn gram_unit(
        &mut self,
        side: Side,
        gb: &Matrix,
        step: u64,
        cfg: &ShampooConfig,
        scratch: &mut ScratchArena,
        ledger: &HealthLedger,
    ) {
        if gb.has_non_finite() {
            ledger.grad_screened();
            return;
        }
        let dim = match side {
            Side::L => gb.rows(),
            Side::R => gb.cols(),
        };
        let mut gram = scratch.take(dim, dim);
        match side {
            Side::L => syrk_into_planned(gb, &mut gram, scratch.plan()), // G·Gᵀ
            Side::R => matmul_tn_into_planned(gb, gb, &mut gram, scratch.plan()), // Gᵀ·G
        }
        if gram.has_non_finite() {
            ledger.grad_screened();
            scratch.recycle(gram);
            return;
        }
        let s = &mut self.sides[side.index()];
        s.update_gram(&gram, cfg, scratch);
        s.meta.last_gram = step;
        s.meta.pending_norm += inner(gb, gb) as f32;
        scratch.recycle(gram);
    }

    /// One refresh unit's inverse-root recomputation; resets the pending
    /// norm and bumps the coverage counter.
    ///
    /// The quarantine machine wraps the fallback ladder:
    /// * A quarantined unit inside its probation window is served from the
    ///   installed floor without attempting a refresh.
    /// * Once the window elapses the unit gets one full refresh attempt —
    ///   a fresh-root outcome releases it, a stale/floor outcome resets
    ///   the probation timer.
    /// * A healthy unit that fails [`ShampooConfig::quarantine_after`]
    ///   consecutive times is quarantined and floored.
    ///
    /// `forced` simulates a hard factorization failure for this attempt
    /// (deterministic fault injection).
    pub(crate) fn root_unit(
        &mut self,
        side: Side,
        step: u64,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
        forced: bool,
        ledger: &HealthLedger,
    ) {
        let s = &mut self.sides[side.index()];
        let health = s.meta.health;
        if health.is_quarantined()
            && step.saturating_sub(health.quarantined_since - 1) < cfg.probation_interval
        {
            // Floor-serving window: no refresh attempt, no refresh count —
            // the schedule slot is consumed so the scheduler moves on.
            ledger.floor_serve();
            s.meta.last_root = step;
            s.meta.pending_norm = 0.0;
            return;
        }
        let outcome = s.update_root(cfg, ctx, scratch, forced);
        match outcome {
            FallbackOutcome::Healthy => {}
            FallbackOutcome::JitterRescue => ledger.jitter_rescue(),
            FallbackOutcome::PsdProjection => ledger.psd_projection(),
            FallbackOutcome::StaleRoot => ledger.stale_root_serve(),
            FallbackOutcome::DiagonalFloor => ledger.floor_serve(),
        }
        let h = &mut s.meta.health;
        if outcome.is_serving_fresh() {
            if h.is_quarantined() {
                h.quarantined_since = 0;
                h.releases += 1;
                ledger.release();
            }
            h.consecutive_failures = 0;
        } else {
            h.consecutive_failures += 1;
            if h.is_quarantined() {
                // Probation failed: restart the window, not a new entry.
                h.quarantined_since = step + 1;
            } else if h.consecutive_failures >= cfg.quarantine_after {
                h.quarantined_since = step + 1;
                h.quarantines += 1;
                ledger.quarantine();
                s.install_floor(cfg, ctx, scratch);
            }
        }
        s.meta.last_root = step;
        s.meta.pending_norm = 0.0;
        s.meta.refreshes += 1;
    }

    /// Dequantize one side's gram into a fresh owned matrix — the snapshot
    /// an async refresh submission ships to its worker shard. Owned (not
    /// arena-backed) because it crosses the thread boundary and outlives
    /// this step; the async path therefore allocates one `dim×dim` buffer
    /// per submission (documented in `docs/PERFORMANCE.md`).
    pub(crate) fn snapshot_gram(&self, side: Side, scratch: &mut ScratchArena) -> Matrix {
        let s = &self.sides[side.index()];
        let mut g = Matrix::zeros(s.dim, s.dim);
        s.gram.load_into(&mut g, scratch);
        g
    }

    /// The quarantine probation gate, replicated for async submission: a
    /// quarantined unit inside its probation window is served from the
    /// installed floor *now* (no job is dispatched) and the schedule slot
    /// is consumed — byte-identical metadata effects to the sync path in
    /// [`BlockState::root_unit`]. Returns `true` when the gate consumed the
    /// slot (caller must not submit), `false` when a refresh (probation or
    /// regular) should be submitted.
    pub(crate) fn async_quarantine_gate(
        &mut self,
        side: Side,
        step: u64,
        cfg: &ShampooConfig,
        ledger: &HealthLedger,
    ) -> bool {
        let s = &mut self.sides[side.index()];
        let health = s.meta.health;
        if health.is_quarantined()
            && step.saturating_sub(health.quarantined_since - 1) < cfg.probation_interval
        {
            ledger.floor_serve();
            s.meta.last_root = step;
            s.meta.pending_norm = 0.0;
            return true;
        }
        false
    }

    /// Publish one completed async refresh into the unit's root slot. Runs
    /// on the *step thread* at the unit's deterministic due step, so all
    /// ledger accounting and the quarantine state machine execute here,
    /// race-free — worker shards only ever run the pure compute rungs.
    ///
    /// `computed` is the worker's [`compute_root_from_gram`] result
    /// (`None` = every compute rung failed, or the refresh was a forced
    /// fault); `submit_step` is the step the gram snapshot was taken at, and
    /// the metadata records it — not the publish step — so the scheduler's
    /// staleness view matches what the root actually reflects.
    /// `pending_at_submit` is the unit's `pending_norm` at submission:
    /// gradient energy absorbed *while the refresh was in flight* is not in
    /// the published root and stays pending.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn publish_root_unit(
        &mut self,
        side: Side,
        computed: Option<(&Matrix, FallbackOutcome)>,
        submit_step: u64,
        pending_at_submit: f32,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
        ledger: &HealthLedger,
    ) {
        let s = &mut self.sides[side.index()];
        let outcome = match computed {
            Some((x, outcome)) => {
                s.rebind_and_store(x, cfg, ctx, scratch);
                outcome
            }
            None => s.serve_stale_or_floor(cfg, ctx, scratch),
        };
        match outcome {
            FallbackOutcome::Healthy => {}
            FallbackOutcome::JitterRescue => ledger.jitter_rescue(),
            FallbackOutcome::PsdProjection => ledger.psd_projection(),
            FallbackOutcome::StaleRoot => ledger.stale_root_serve(),
            FallbackOutcome::DiagonalFloor => ledger.floor_serve(),
        }
        let h = &mut s.meta.health;
        if outcome.is_serving_fresh() {
            if h.is_quarantined() {
                h.quarantined_since = 0;
                h.releases += 1;
                ledger.release();
            }
            h.consecutive_failures = 0;
        } else {
            h.consecutive_failures += 1;
            if h.is_quarantined() {
                // Probation failed: restart the window, not a new entry.
                h.quarantined_since = submit_step + 1;
            } else if h.consecutive_failures >= cfg.quarantine_after {
                h.quarantined_since = submit_step + 1;
                h.quarantines += 1;
                ledger.quarantine();
                s.install_floor(cfg, ctx, scratch);
            }
        }
        s.meta.last_root = submit_step;
        s.meta.pending_norm = (s.meta.pending_norm - pending_at_submit).max(0.0);
        s.meta.refreshes += 1;
    }

    /// Whole-block Gram update (both sides, `L` then `R`) — the legacy
    /// sequential entry the `EveryN` oracle tests drive.
    fn update_gram(&mut self, g: &Matrix, cfg: &ShampooConfig, scratch: &mut ScratchArena) {
        let mut gram_l = scratch.take(g.rows(), g.rows());
        syrk_into_planned(g, &mut gram_l, scratch.plan()); // G·Gᵀ
        self.sides[0].update_gram(&gram_l, cfg, scratch);
        scratch.recycle(gram_l);
        let mut gram_r = scratch.take(g.cols(), g.cols());
        matmul_tn_into_planned(g, g, &mut gram_r, scratch.plan()); // Gᵀ·G
        self.sides[1].update_gram(&gram_r, cfg, scratch);
        scratch.recycle(gram_r);
    }

    fn update_inv_roots(
        &mut self,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) {
        for side in &mut self.sides {
            // Legacy oracle path: ladder outcomes are not health-tracked
            // here (metadata stays untouched, matching `update_gram`).
            side.update_root(cfg, ctx, scratch, false);
        }
    }

    /// `Ĝ = D(L̂)·G·D(R̂)` (Algorithm 1 line 15), arena-backed.
    pub(crate) fn precondition_into(
        &self,
        g: &Matrix,
        out: &mut Matrix,
        scratch: &mut ScratchArena,
    ) {
        let mut tmp = scratch.take(self.rows, g.cols());
        matmul_into_planned(self.sides[0].cache(), g, &mut tmp, scratch.plan());
        matmul_into_planned(&tmp, self.sides[1].cache(), out, scratch.plan());
        scratch.recycle(tmp);
    }

    fn size_bytes(&self) -> usize {
        self.sides[0].size_bytes() + self.sides[1].size_bytes()
    }

    fn write_state(&self, out: &mut ByteWriter) {
        for s in &self.sides {
            s.write_state(out);
        }
    }

    fn read_state(
        &mut self,
        r: &mut ByteReader<'_>,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) -> Result<()> {
        for s in &mut self.sides {
            s.read_state(r, ctx, scratch)?;
        }
        Ok(())
    }
}

/// State of one parameter (all its blocks, or passthrough for vectors).
pub struct LayerState {
    pub rows: usize,
    pub cols: usize,
    pub blocking: Blocking,
    pub blocks: Vec<BlockState>,
    /// Vectors/scalars skip preconditioning entirely.
    pub passthrough: bool,
}

impl LayerState {
    pub fn new(rows: usize, cols: usize, cfg: &ShampooConfig, ctx: &CodecCtx) -> LayerState {
        let passthrough = rows.min(cols) <= 1 || Self::dim_opted_out(rows, cols, cfg);
        let blocking = Blocking::new(rows, cols, cfg.max_order);
        Self::from_blocking(rows, cols, blocking, passthrough, cfg, ctx)
    }

    /// The scalable-Shampoo large-dim opt-out: a layer whose longest side
    /// exceeds `no_preconditioning_for_layers_with_dim_gt` (embedding
    /// tables) takes the grafted base update with zero codec state.
    pub fn dim_opted_out(rows: usize, cols: usize, cfg: &ShampooConfig) -> bool {
        cfg.no_preconditioning_for_layers_with_dim_gt > 0
            && rows.max(cols) > cfg.no_preconditioning_for_layers_with_dim_gt
    }

    /// Build from an explicit blocking — the shape-interpretation path
    /// (`Shampoo::new_nd`) composes per-chunk blockings with row offsets so
    /// a collapsed ≥3-D tensor gets one `BlockState` per trailing-two-dim
    /// matrix chunk instead of blocking the flattened rows. `passthrough`
    /// is caller-decided (the ND path judges degeneracy and the dim bound
    /// on the *chunk* dims, not the stacked rows).
    pub fn from_blocking(
        rows: usize,
        cols: usize,
        blocking: Blocking,
        passthrough: bool,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
    ) -> LayerState {
        let blocks = if passthrough {
            Vec::new()
        } else {
            blocking
                .blocks
                .iter()
                .map(|b| BlockState::new(b.rows, b.cols, cfg, ctx))
                .collect()
        };
        LayerState { rows, cols, blocking, blocks, passthrough }
    }

    /// Refresh units in this layer (two per block; passthrough layers have
    /// none) — the scheduler's unit-addressing contract.
    pub fn unit_count(&self) -> usize {
        self.blocks.len() * 2
    }

    /// Refresh bookkeeping of one unit (test/telemetry surface).
    pub fn unit_meta(&self, block: usize, side: Side) -> UnitMeta {
        self.blocks[block].side(side).meta
    }

    pub fn update_gram(&mut self, g: &Matrix, cfg: &ShampooConfig, scratch: &mut ScratchArena) {
        if self.passthrough {
            return;
        }
        for (spec, state) in self.blocking.blocks.iter().zip(self.blocks.iter_mut()) {
            let mut gb = scratch.take(spec.rows, spec.cols);
            g.block_into(spec.r0, spec.c0, &mut gb);
            state.update_gram(&gb, cfg, scratch);
            scratch.recycle(gb);
        }
    }

    pub fn update_inv_roots(
        &mut self,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) {
        if self.passthrough {
            return;
        }
        for state in self.blocks.iter_mut() {
            state.update_inv_roots(cfg, ctx, scratch);
        }
    }

    /// Allocating convenience wrapper over [`Self::precondition_into`].
    pub fn precondition(&self, g: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.precondition_into(g, &mut out, &mut ScratchArena::new());
        out
    }

    /// Precondition into a caller-owned buffer; every per-block temporary
    /// comes from the arena (the per-step hot path of `Shampoo::step`).
    /// `out` is fully overwritten (the block specs tile the layer).
    pub fn precondition_into(&self, g: &Matrix, out: &mut Matrix, scratch: &mut ScratchArena) {
        if self.passthrough {
            out.copy_from(g);
            return;
        }
        if self.blocking.is_trivial() {
            self.blocks[0].precondition_into(g, out, scratch);
            return;
        }
        for (spec, state) in self.blocking.blocks.iter().zip(self.blocks.iter()) {
            let mut gb = scratch.take(spec.rows, spec.cols);
            g.block_into(spec.r0, spec.c0, &mut gb);
            let mut ob = scratch.take(spec.rows, spec.cols);
            state.precondition_into(&gb, &mut ob, scratch);
            out.set_block(spec.r0, spec.c0, &ob);
            scratch.recycle(ob);
            scratch.recycle(gb);
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.size_bytes()).sum()
    }

    /// Serialize every block's unit states (passthrough layers write an
    /// empty block list). Shapes and blocking are spec-derived and not
    /// written — restore targets a layer rebuilt from the same spec.
    pub fn write_state(&self, out: &mut ByteWriter) {
        out.put_u64(self.blocks.len() as u64);
        for b in &self.blocks {
            b.write_state(out);
        }
    }

    /// Inverse of [`LayerState::write_state`] on a freshly built layer.
    pub fn read_state(
        &mut self,
        r: &mut ByteReader<'_>,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) -> Result<()> {
        let n = r.get_len()?;
        crate::ensure!(
            n == self.blocks.len(),
            "checkpoint holds {n} blocks, layer built with {}",
            self.blocks.len()
        );
        for b in &mut self.blocks {
            b.read_state(r, ctx, scratch)?;
        }
        Ok(())
    }

    pub fn dequant_inv_roots(&self) -> Vec<(Matrix, Matrix)> {
        self.blocks
            .iter()
            .map(|b| (b.sides[0].cache.clone(), b.sides[1].cache.clone()))
            .collect()
    }

    pub fn reconstructed_preconditioners(&self) -> Vec<(Matrix, Matrix)> {
        self.blocks
            .iter()
            .map(|b| (b.sides[0].gram.load(), b.sides[1].gram.load()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_nt, syrk};
    use crate::quant::{BlockQuantizer, QuantConfig};
    use crate::shampoo::ShampooVariant;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn cfg(variant: ShampooVariant) -> ShampooConfig {
        ShampooConfig {
            variant,
            t1: 1,
            t2: 1,
            quant: QuantConfig { min_quant_elems: 0, block: 8, ..Default::default() },
            ..Default::default()
        }
    }

    fn ctx(c: &ShampooConfig) -> CodecCtx {
        CodecCtx::new(c.eps, c.beta_e, Arc::new(BlockQuantizer::new(c.quant)))
    }

    #[test]
    fn cq_reconstruction_is_psd() {
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let ctx = ctx(&c);
        let mut rng = Rng::new(1);
        let mut side = side_codec(12, &c, &ctx);
        let mut scratch = ScratchArena::new();
        assert_eq!(side.key(), "cq4-ef");
        for _ in 0..5 {
            let g = Matrix::randn(12, 16, 1.0, &mut rng);
            update_side(&mut *side, &syrk(&g), &c, &mut scratch);
            let l = side.load();
            // PSD check via eigensolver.
            let (vals, _) = crate::linalg::eig_sym(&l, 1e-10, 100);
            assert!(vals[0] >= -1e-4, "λmin={} — CQ must preserve PSD", vals[0]);
            // Symmetry by construction.
            assert!(l.max_abs_diff(&l.transpose()) < 1e-6);
        }
    }

    #[test]
    fn vq_reconstruction_can_lose_psd_cq_does_not() {
        // The paper's Tab. 9 phenomenon on the toy ill-conditioned matrix:
        // direct quantization can produce a negative eigenvalue while CQ's
        // C·Cᵀ reconstruction cannot.
        let q = BlockQuantizer::new(QuantConfig {
            min_quant_elems: 0,
            block: 2,
            ..Default::default()
        });
        // quantize the paper's [[10,3],[3,1]] directly (full quantization,
        // i.e. including diagonal, mirroring C.1's "VQ perturbs elements")
        let l = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0]]);
        let vq_back = q.roundtrip(&l);
        let (vals_vq, _) = crate::linalg::eig_sym(&vq_back, 1e-12, 100);
        // CQ path on the same matrix, through the codec.
        let c_cfg = cfg(ShampooVariant::Cq4 { error_feedback: false });
        let mut cc = ShampooConfig { quant: QuantConfig { block: 2, ..c_cfg.quant }, ..c_cfg };
        cc.eps = 1e-6;
        let cctx = ctx(&cc);
        let mut codec = side_codec(2, &cc, &cctx);
        codec.store(&l);
        let cq_back = codec.load();
        let (vals_cq, _) = crate::linalg::eig_sym(&cq_back, 1e-12, 100);
        assert!(
            vals_cq[0] >= 0.0,
            "CQ reconstruction must stay PSD, got λmin={}",
            vals_cq[0]
        );
        // (VQ on this matrix may or may not go negative depending on block
        // size; the Tab. 9 harness reproduces the paper's exact setting.)
        let _ = vals_vq;
    }

    #[test]
    fn cq_codec_matches_direct_tri_store() {
        // The codec's C·Cᵀ reconstruction equals hand-driving the joint
        // store (no behavior change vs. the pre-trait implementation).
        let c = cfg(ShampooVariant::Cq4 { error_feedback: false });
        let cctx = ctx(&c);
        let mut rng = Rng::new(7);
        let g = Matrix::randn(12, 12, 1.0, &mut rng);
        let mut spd = syrk(&g);
        spd.add_diag(0.5);
        let mut codec = side_codec(12, &c, &cctx);
        codec.store(&spd);
        let via_codec = codec.load();

        let (chol, _) = crate::linalg::cholesky_jittered(&spd, c.eps, 12).unwrap();
        let store = crate::quant::TriJointStore::store(
            &chol,
            &Matrix::zeros(12, 12),
            &cctx.quantizer,
        );
        let (c_back, _) = store.load(&cctx.quantizer);
        let direct = matmul_nt(&c_back, &c_back);
        assert!(via_codec.max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn blocked_layer_partitions_work() {
        let mut c = cfg(ShampooVariant::Full32);
        c.max_order = 8;
        let cctx = ctx(&c);
        let mut rng = Rng::new(2);
        let mut layer = LayerState::new(20, 12, &c, &cctx);
        let mut scratch = ScratchArena::new();
        assert_eq!(layer.blocks.len(), 3 * 2);
        assert_eq!(layer.unit_count(), 12);
        let g = Matrix::randn(20, 12, 1.0, &mut rng);
        layer.update_gram(&g, &c, &mut scratch);
        layer.update_inv_roots(&c, &cctx, &mut scratch);
        let ghat = layer.precondition(&g);
        assert_eq!((ghat.rows(), ghat.cols()), (20, 12));
        assert!(!ghat.has_non_finite());
    }

    #[test]
    fn small_tensor_exemption_keeps_f32() {
        let mut c = cfg(ShampooVariant::Vq4);
        c.quant.min_quant_elems = 4096; // paper default
        let cctx = ctx(&c);
        // 32×32 preconditioners are 1024 < 4096 elems → stay f32.
        let layer = LayerState::new(32, 32, &c, &cctx);
        assert_eq!(layer.blocks[0].side(Side::L).gram.key(), "f32");
        // 128×128 → 16384 ≥ 4096 → quantized.
        let layer2 = LayerState::new(128, 128, &c, &cctx);
        assert_eq!(layer2.blocks[0].side(Side::L).gram.key(), "vq4");
    }

    #[test]
    fn root_cache_matches_store() {
        let c = cfg(ShampooVariant::Vq4);
        let cctx = ctx(&c);
        let mut rng = Rng::new(3);
        let mut block = BlockState::new(10, 10, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let g = Matrix::randn(10, 10, 1.0, &mut rng);
        block.update_gram(&g, &c, &mut scratch);
        block.update_inv_roots(&c, &cctx, &mut scratch);
        assert_eq!(block.side(Side::L).root.key(), "vq4");
        for s in Side::BOTH {
            let side = block.side(s);
            assert!(side.cache.max_abs_diff(&side.root.load()) < 1e-7);
        }
    }

    #[test]
    fn unit_level_refresh_matches_whole_block_path() {
        // Driving the two sides through the scheduler's unit API produces
        // bit-identical state to the legacy whole-block calls.
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let cctx = ctx(&c);
        let mut rng = Rng::new(21);
        let mut a = BlockState::new(12, 8, &c, &cctx);
        let mut b = BlockState::new(12, 8, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let ledger = HealthLedger::new();
        for step in 1..=4u64 {
            let g = Matrix::randn(12, 8, 0.5, &mut rng);
            a.update_gram(&g, &c, &mut scratch);
            a.update_inv_roots(&c, &cctx, &mut scratch);
            b.gram_unit(Side::L, &g, step, &c, &mut scratch, &ledger);
            b.gram_unit(Side::R, &g, step, &c, &mut scratch, &ledger);
            b.root_unit(Side::L, step, &c, &cctx, &mut scratch, false, &ledger);
            b.root_unit(Side::R, step, &c, &cctx, &mut scratch, false, &ledger);
            for s in Side::BOTH {
                assert_eq!(a.side(s).cache.max_abs_diff(&b.side(s).cache), 0.0);
            }
        }
        // Unit path also recorded its bookkeeping.
        let meta = b.side(Side::L).meta;
        assert_eq!(meta.last_gram, 4);
        assert_eq!(meta.last_root, 4);
        assert_eq!(meta.refreshes, 4);
        assert_eq!(meta.pending_norm, 0.0);
        // The legacy path leaves metadata untouched (oracle usage).
        assert_eq!(a.side(Side::L).meta, UnitMeta::default());
    }

    #[test]
    fn pending_norm_accumulates_between_root_refreshes() {
        let c = cfg(ShampooVariant::Full32);
        let cctx = ctx(&c);
        let mut rng = Rng::new(22);
        let mut block = BlockState::new(6, 6, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let g = Matrix::randn(6, 6, 1.0, &mut rng);
        let g2 = inner(&g, &g) as f32;
        let ledger = HealthLedger::new();
        block.gram_unit(Side::L, &g, 1, &c, &mut scratch, &ledger);
        block.gram_unit(Side::L, &g, 2, &c, &mut scratch, &ledger);
        let meta = block.side(Side::L).meta;
        assert!((meta.pending_norm - 2.0 * g2).abs() < 1e-3 * g2.abs());
        block.root_unit(Side::L, 3, &c, &cctx, &mut scratch, false, &ledger);
        assert_eq!(block.side(Side::L).meta.pending_norm, 0.0);
        assert_eq!(block.side(Side::L).meta.last_root, 3);
    }

    #[test]
    fn cholesky_failure_resets_state() {
        // Inject a Gram update that is wildly non-PSD after quantization
        // noise: NaN gram — state must reset, not crash.
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let cctx = ctx(&c);
        let mut side = side_codec(6, &c, &cctx);
        let mut bad = Matrix::zeros(6, 6);
        bad[(0, 0)] = f32::NAN;
        update_side(&mut *side, &bad, &c, &mut ScratchArena::new());
        let l = side.load();
        assert!(!l.has_non_finite(), "reset must clear NaNs");
    }

    #[test]
    fn bw8_layer_runs_and_is_half_of_f32_codes() {
        let c = cfg(ShampooVariant::Bw8);
        let cctx = ctx(&c);
        let mut rng = Rng::new(4);
        let mut layer = LayerState::new(32, 32, &c, &cctx);
        let mut scratch = ScratchArena::new();
        assert_eq!(layer.blocks[0].side(Side::L).gram.key(), "bw8");
        let g = Matrix::randn(32, 32, 1.0, &mut rng);
        layer.update_gram(&g, &c, &mut scratch);
        layer.update_inv_roots(&c, &cctx, &mut scratch);
        assert!(!layer.precondition(&g).has_non_finite());
        // 8-bit codes: each side/root ≈ n² bytes + scales + diag, far below
        // the 4·n² f32 payload and roughly twice the 4-bit payload.
        let bytes = layer.size_bytes();
        assert!(bytes < 4 * 4 * 32 * 32, "bw8 must undercut f32: {bytes}");
    }

    #[test]
    fn codec_family_overrides_drive_a_blocked_layer() {
        // The ec4/f16/cq-r1 family reaches the state layer purely through
        // codec overrides (no variant arm): a blocked layer must construct,
        // refresh, and precondition finitely under each pairing, with the
        // root slot switching from its f32 init to the configured codec at
        // the first refresh.
        for (side, root) in [("ec4", "ec4"), ("f16", "f16"), ("cq-r1", "vq4")] {
            let mut c = cfg(ShampooVariant::Full32);
            c.side_codec = Some(side);
            c.root_codec = Some(root);
            c.max_order = 8;
            let cctx = ctx(&c);
            let mut layer = LayerState::new(20, 12, &c, &cctx);
            let mut scratch = ScratchArena::new();
            assert_eq!(layer.blocks[0].side(Side::L).gram.key(), side);
            assert_eq!(layer.blocks[0].side(Side::L).root.key(), "f32", "pre-refresh init");
            let mut rng = Rng::new(33);
            let g = Matrix::randn(20, 12, 1.0, &mut rng);
            layer.update_gram(&g, &c, &mut scratch);
            layer.update_inv_roots(&c, &cctx, &mut scratch);
            assert_eq!(layer.blocks[0].side(Side::L).root.key(), root, "post-refresh");
            let ghat = layer.precondition(&g);
            assert!(!ghat.has_non_finite(), "codecs {side}/{root}");
        }
    }

    #[test]
    fn codec_override_reaches_unregistered_variants() {
        // A config can route sides through any registered codec without a
        // matching ShampooVariant arm — the open-world path.
        let mut c = cfg(ShampooVariant::Full32);
        c.side_codec = Some("bw8");
        let cctx = ctx(&c);
        let layer = LayerState::new(16, 16, &c, &cctx);
        assert_eq!(layer.blocks[0].side(Side::L).gram.key(), "bw8");
    }

    // ---- fallback-ladder rungs ---------------------------------------

    #[test]
    fn ladder_rung_jitter_rescue_on_indefinite_gram() {
        // Eigenvalues {3, −1}: Schur–Newton provably diverges on the
        // negative direction, the ridged eigendecomposition rescues.
        let c = cfg(ShampooVariant::Full32);
        let cctx = ctx(&c);
        let mut side = SideState::new(2, &c, &cctx);
        let mut scratch = ScratchArena::new();
        side.gram.store(&Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]));
        let outcome = side.update_root(&c, &cctx, &mut scratch, false);
        assert_eq!(outcome, FallbackOutcome::JitterRescue);
        assert!(!side.cache.has_non_finite());
    }

    #[test]
    fn ladder_rung_psd_projection_on_non_finite_gram() {
        // NaN off-diagonals poison Schur–Newton AND make the ridge rung
        // undefined (the eigensolver can't order NaN); the sanitized
        // projection sees diag(2) and serves 2^{-1/4}·I.
        let c = cfg(ShampooVariant::Full32);
        let cctx = ctx(&c);
        let mut side = SideState::new(2, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let mut bad = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, 2.0]]);
        bad[(0, 1)] = f32::NAN;
        bad[(1, 0)] = f32::NAN;
        side.gram.store(&bad);
        let outcome = side.update_root(&c, &cctx, &mut scratch, false);
        assert_eq!(outcome, FallbackOutcome::PsdProjection);
        assert!(!side.cache.has_non_finite());
        let want = 2.0f32.powf(-0.25);
        assert!((side.cache[(0, 0)] - want).abs() < 1e-4);
        assert!(side.cache[(0, 1)].abs() < 1e-4);
    }

    #[test]
    fn ladder_rung_stale_root_keeps_last_good_cache() {
        let c = cfg(ShampooVariant::Full32);
        let cctx = ctx(&c);
        let mut side = SideState::new(2, &c, &cctx);
        let mut scratch = ScratchArena::new();
        side.gram.store(&Matrix::eye_scaled(2, 2.0));
        assert_eq!(
            side.update_root(&c, &cctx, &mut scratch, false),
            FallbackOutcome::Healthy
        );
        let snapshot = side.cache.clone();
        // Forced factorization failure: the finite cached root is served.
        let outcome = side.update_root(&c, &cctx, &mut scratch, true);
        assert_eq!(outcome, FallbackOutcome::StaleRoot);
        assert_eq!(side.cache.max_abs_diff(&snapshot), 0.0);
    }

    #[test]
    fn ladder_rung_diagonal_floor_when_cache_is_poisoned() {
        let c = cfg(ShampooVariant::Full32);
        let cctx = ctx(&c);
        let mut side = SideState::new(2, &c, &cctx);
        let mut scratch = ScratchArena::new();
        side.gram.store(&Matrix::eye_scaled(2, 2.0));
        side.update_root(&c, &cctx, &mut scratch, false);
        // Poisoned cache + failed refresh: nothing left to serve but the
        // diagonal floor built from the gram's sanitized diagonal.
        side.cache[(0, 0)] = f32::NAN;
        let outcome = side.update_root(&c, &cctx, &mut scratch, true);
        assert_eq!(outcome, FallbackOutcome::DiagonalFloor);
        assert!(!side.cache.has_non_finite());
        let want = ((2.0f64 + c.eps as f64).powf(-0.25)) as f32;
        assert!((side.cache[(0, 0)] - want).abs() < 1e-6);
        assert_eq!(side.cache[(0, 1)], 0.0);
    }

    // ---- screening + quarantine machine ------------------------------

    #[test]
    fn gram_unit_screens_overflowing_product() {
        // Finite but huge gradients overflow G·Gᵀ to Inf — the unit's
        // codec/EF state and metadata must stay untouched.
        let c = cfg(ShampooVariant::Full32);
        let cctx = ctx(&c);
        let mut block = BlockState::new(2, 2, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let ledger = HealthLedger::new();
        let before = block.side(Side::L).gram.load();
        let huge = Matrix::from_fn(2, 2, |_, _| 1e20);
        block.gram_unit(Side::L, &huge, 1, &c, &mut scratch, &ledger);
        assert_eq!(block.side(Side::L).gram.load().max_abs_diff(&before), 0.0);
        assert_eq!(block.side(Side::L).meta.last_gram, 0);
        assert_eq!(block.side(Side::L).meta.pending_norm, 0.0);
        let stats = ledger.take();
        assert_eq!(stats.grads_screened, 1);
        // Direct NaN gradients are screened by the same guard.
        let mut nan_g = Matrix::zeros(2, 2);
        nan_g[(1, 1)] = f32::NAN;
        block.gram_unit(Side::L, &nan_g, 2, &c, &mut scratch, &ledger);
        assert_eq!(ledger.take().grads_screened, 1);
        assert_eq!(block.side(Side::L).meta.last_gram, 0);
    }

    #[test]
    fn quarantine_locks_after_k_failures_and_releases_on_probation() {
        let mut c = cfg(ShampooVariant::Full32);
        c.quarantine_after = 2;
        c.probation_interval = 3;
        let cctx = ctx(&c);
        let mut block = BlockState::new(2, 2, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let ledger = HealthLedger::new();
        block.gram_unit(Side::L, &Matrix::eye(2), 1, &c, &mut scratch, &ledger);
        // Steps 1–2: forced failures → stale roots → quarantine at K=2.
        block.root_unit(Side::L, 1, &c, &cctx, &mut scratch, true, &ledger);
        assert!(!block.side(Side::L).meta.health.is_quarantined());
        block.root_unit(Side::L, 2, &c, &cctx, &mut scratch, true, &ledger);
        let h = block.side(Side::L).meta.health;
        assert!(h.is_quarantined());
        assert_eq!(h.quarantines, 1);
        assert_eq!(h.consecutive_failures, 2);
        // Steps 3–4: inside the probation window — floor-served, no refresh
        // attempt, refresh counter does not advance.
        let refreshes_before = block.side(Side::L).meta.refreshes;
        block.root_unit(Side::L, 3, &c, &cctx, &mut scratch, false, &ledger);
        block.root_unit(Side::L, 4, &c, &cctx, &mut scratch, false, &ledger);
        assert_eq!(block.side(Side::L).meta.refreshes, refreshes_before);
        assert!(block.side(Side::L).meta.health.is_quarantined());
        // Step 5: window elapsed → probation attempt on the healthy gram
        // succeeds → released.
        block.root_unit(Side::L, 5, &c, &cctx, &mut scratch, false, &ledger);
        let h = block.side(Side::L).meta.health;
        assert!(!h.is_quarantined());
        assert_eq!(h.releases, 1);
        assert_eq!(h.consecutive_failures, 0);
        assert!(!block.side(Side::L).cache.has_non_finite());
        let stats = ledger.take();
        assert_eq!(stats.stale_root_serves, 2);
        assert_eq!(stats.floor_serves, 2);
        assert_eq!(stats.quarantines, 1);
        assert_eq!(stats.releases, 1);
        assert_eq!(stats.grads_screened, 0);
    }

    #[test]
    fn failed_probation_restarts_window_without_new_quarantine() {
        let mut c = cfg(ShampooVariant::Full32);
        c.quarantine_after = 1;
        c.probation_interval = 2;
        let cctx = ctx(&c);
        let mut block = BlockState::new(2, 2, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let ledger = HealthLedger::new();
        block.gram_unit(Side::L, &Matrix::eye(2), 1, &c, &mut scratch, &ledger);
        block.root_unit(Side::L, 1, &c, &cctx, &mut scratch, true, &ledger);
        assert_eq!(block.side(Side::L).meta.health.quarantined_since, 2);
        // Step 3: probation attempt also forced to fail — the window
        // restarts but `quarantines` does not double-count.
        block.root_unit(Side::L, 3, &c, &cctx, &mut scratch, true, &ledger);
        let h = block.side(Side::L).meta.health;
        assert!(h.is_quarantined());
        assert_eq!(h.quarantined_since, 4);
        assert_eq!(h.quarantines, 1);
        assert_eq!(h.releases, 0);
    }

    #[test]
    fn unit_health_round_trips_through_state_serialization() {
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let cctx = ctx(&c);
        let mut rng = Rng::new(9);
        let mut side = SideState::new(6, &c, &cctx);
        let mut scratch = ScratchArena::new();
        side.update_gram(&syrk(&Matrix::randn(6, 6, 1.0, &mut rng)), &c, &mut scratch);
        side.update_root(&c, &cctx, &mut scratch, false);
        side.meta.health = UnitHealth {
            consecutive_failures: 2,
            quarantined_since: 41,
            quarantines: 3,
            releases: 1,
        };
        let mut w = ByteWriter::new();
        side.write_state(&mut w);
        let bytes = w.into_bytes();
        let mut fresh = SideState::new(6, &c, &cctx);
        fresh
            .read_state(&mut ByteReader::new(&bytes), &cctx, &mut scratch)
            .unwrap();
        assert_eq!(fresh.meta, side.meta);
        assert_eq!(fresh.cache.max_abs_diff(&side.cache), 0.0);
        // Truncated input errors instead of panicking.
        let mut fresh2 = SideState::new(6, &c, &cctx);
        assert!(fresh2
            .read_state(&mut ByteReader::new(&bytes[..bytes.len() - 2]), &cctx, &mut scratch)
            .is_err());
    }

    #[test]
    fn warmup_defers_root_bytes_until_first_refresh() {
        let mut c = cfg(ShampooVariant::Full32);
        c.start_preconditioning_step = 5;
        let cctx = ctx(&c);
        let mut side = SideState::new(6, &c, &cctx);
        let mut scratch = ScratchArena::new();
        // The identity root is spec-derived, not state: uncounted …
        assert!(!side.root_live);
        assert_eq!(side.size_bytes(), side.gram.size_bytes() + UnitMeta::BYTES);
        // … and unserialized — a mid-warmup round trip rebuilds the
        // identity cache instead of reading a root payload.
        let mut w = ByteWriter::new();
        side.write_state(&mut w);
        let bytes = w.into_bytes();
        let mut fresh = SideState::new(6, &c, &cctx);
        fresh.read_state(&mut ByteReader::new(&bytes), &cctx, &mut scratch).unwrap();
        assert!(!fresh.root_live);
        assert_eq!(fresh.cache.max_abs_diff(&Matrix::eye(6)), 0.0);
        // First refresh makes the slot live for good: counted + serialized.
        side.gram.store(&Matrix::eye_scaled(6, 2.0));
        assert_eq!(side.update_root(&c, &cctx, &mut scratch, false), FallbackOutcome::Healthy);
        assert!(side.root_live);
        assert_eq!(
            side.size_bytes(),
            side.gram.size_bytes() + side.root.size_bytes() + UnitMeta::BYTES
        );
        let mut w2 = ByteWriter::new();
        side.write_state(&mut w2);
        let bytes2 = w2.into_bytes();
        let mut fresh2 = SideState::new(6, &c, &cctx);
        fresh2.read_state(&mut ByteReader::new(&bytes2), &cctx, &mut scratch).unwrap();
        assert!(fresh2.root_live);
        assert_eq!(fresh2.cache.max_abs_diff(&side.cache), 0.0);
    }

    #[test]
    fn dim_gt_opt_out_routes_layer_to_zero_state_passthrough() {
        let mut c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        c.no_preconditioning_for_layers_with_dim_gt = 100;
        let cctx = ctx(&c);
        // Embedding-shaped layer: longest side over the bound → grafted
        // base update with exactly zero codec state.
        let big = LayerState::new(200, 64, &c, &cctx);
        assert!(big.passthrough);
        assert_eq!(big.unit_count(), 0);
        assert_eq!(big.size_bytes(), 0);
        let g = Matrix::from_fn(200, 64, |i, j| (i + j) as f32);
        assert_eq!(big.precondition(&g).max_abs_diff(&g), 0.0);
        // Inside the bound: preconditioned as usual.
        let small = LayerState::new(64, 64, &c, &cctx);
        assert!(!small.passthrough);
        assert!(small.size_bytes() > 0);
        // Bound 0 = disabled.
        let off = cfg(ShampooVariant::Cq4 { error_feedback: true });
        assert!(!LayerState::dim_opted_out(200, 64, &off));
    }
}

//! Per-layer preconditioner state for every Shampoo variant.
//!
//! Each parameter is tiled by [`Blocking`]; each block keeps an `(L, R)`
//! pair in the representation the variant dictates, plus the (possibly
//! quantized) inverse-4th-roots. Dequantized roots are cached between `T2`
//! refreshes — the quantized state is the persistent store, the cache is
//! transient scratch that never diverges from `D(L̂)` because `L̂` only
//! changes at refresh time.

use super::blocking::Blocking;
use super::config::{ShampooConfig, ShampooVariant};
use crate::linalg::cholesky::cholesky_jittered;
use crate::linalg::schur_newton::inverse_pth_root;
use crate::linalg::{matmul, matmul_nt, matmul_tn, syrk, Matrix};
use crate::quant::error_feedback::ErrorFeedback;
use crate::quant::{
    dequantize_offdiag, quantize_offdiag, BlockQuantizer, OffDiagQuantized, QuantizedMatrix,
    TriJointStore,
};

/// Storage of one Gram-side preconditioner (`L` or `R`).
#[derive(Clone, Debug)]
pub enum SideStore {
    /// f32 `L` (Algorithm 2, or small tensors exempt from quantization).
    Full(Matrix),
    /// 4-bit off-diagonal quantized `L` (Sec. 4.1).
    Vq(OffDiagQuantized),
    /// Tab. 2 "Original": full block-wise quantization including diagonal.
    VqFull(QuantizedMatrix),
    /// 4-bit quantized Cholesky factor (+ EF error state) of `L` (Sec. 4.2/4.3).
    Cq { store: TriJointStore, ef: bool },
}

/// Storage of one inverse-root matrix (`L̂` or `R̂`).
#[derive(Clone, Debug)]
pub enum RootStore {
    Full(Matrix),
    Quant(OffDiagQuantized),
    QuantFull(QuantizedMatrix),
}

impl SideStore {
    fn init(dim: usize, cfg: &ShampooConfig, q: &BlockQuantizer) -> SideStore {
        let quantize = dim * dim >= cfg.quant.min_quant_elems;
        match cfg.variant {
            ShampooVariant::Full32 => SideStore::Full(Matrix::eye_scaled(dim, cfg.eps)),
            ShampooVariant::Vq4 if quantize && cfg.vq_quantize_diag => {
                SideStore::VqFull(q.quantize(&Matrix::eye_scaled(dim, cfg.eps)))
            }
            ShampooVariant::Vq4 if quantize => {
                SideStore::Vq(quantize_offdiag(&Matrix::eye_scaled(dim, cfg.eps), q))
            }
            ShampooVariant::Cq4 { error_feedback } if quantize => SideStore::Cq {
                store: TriJointStore::init(dim, cfg.eps, q),
                ef: error_feedback,
            },
            _ => SideStore::Full(Matrix::eye_scaled(dim, cfg.eps)),
        }
    }

    /// Reconstruct the f32 preconditioner (Eq. (5) `D(L̄)` or Eq. (7)
    /// `D(C̄)·D(C̄)ᵀ`).
    fn reconstruct(&self, q: &BlockQuantizer) -> Matrix {
        match self {
            SideStore::Full(l) => l.clone(),
            SideStore::Vq(s) => dequantize_offdiag(s, q),
            SideStore::VqFull(s) => q.dequantize(s),
            SideStore::Cq { store, .. } => {
                let (c, _) = store.load(q);
                matmul_nt(&c, &c)
            }
        }
    }

    /// Absorb the fresh Gram statistic: `L ← β·L_prev + (1−β)·gram`, then
    /// re-store in this representation (Eq. (5) for VQ, Eq. (7)–(11) for CQ).
    fn update(&mut self, gram: &Matrix, cfg: &ShampooConfig, q: &BlockQuantizer) {
        let mut l_new = self.reconstruct(q);
        l_new.ema(cfg.beta, gram);
        l_new.symmetrize();
        match self {
            SideStore::Full(l) => *l = l_new,
            SideStore::Vq(s) => *s = quantize_offdiag(&l_new, q),
            SideStore::VqFull(s) => *s = q.quantize(&l_new),
            SideStore::Cq { store, ef } => {
                // Eq. (7): C = Cholesky(L + εI); escalating jitter guards
                // quantization-induced PSD violations.
                let (c, _) = match cholesky_jittered(&l_new, cfg.eps, 12) {
                    Ok(v) => v,
                    Err(_) => {
                        // Pathological input (e.g. non-finite gradient blew up
                        // the Gram). Reset to the initial factor — the EMA
                        // will rebuild state over the next T1 windows.
                        (Matrix::eye_scaled(l_new.rows(), cfg.eps.sqrt()), cfg.eps)
                    }
                };
                let (_, e_prev) = store.load(q);
                if *ef {
                    let efb = ErrorFeedback::new(cfg.beta_e);
                    // Eq. (10): quantize the compensated factor.
                    let comp = efb.compensate(&c, &e_prev);
                    // D(C̄): round-trip the strictly-lower part (diagonal is
                    // stored exactly, so it carries no quantization error).
                    let n = comp.rows();
                    let comp_off =
                        Matrix::from_fn(n, n, |i, j| if i > j { comp[(i, j)] } else { 0.0 });
                    let mut c_deq = q.roundtrip(&comp_off);
                    for i in 0..n {
                        c_deq[(i, i)] = comp[(i, i)];
                    }
                    // Eq. (11): EMA of the residual.
                    let e_new = efb.update(&c, &e_prev, &c_deq);
                    *store = TriJointStore::store(&comp, &e_new, q);
                } else {
                    *store = TriJointStore::store(&c, &Matrix::zeros(c.rows(), c.cols()), q);
                }
            }
        }
    }

    fn size_bytes(&self) -> usize {
        match self {
            SideStore::Full(l) => l.size_bytes(),
            SideStore::Vq(s) => s.size_bytes(),
            SideStore::VqFull(s) => s.size_bytes(),
            SideStore::Cq { store, ef } => {
                if *ef {
                    store.size_bytes()
                } else {
                    store.size_bytes_cq_only()
                }
            }
        }
    }
}

impl RootStore {
    fn dequant(&self, q: &BlockQuantizer) -> Matrix {
        match self {
            RootStore::Full(x) => x.clone(),
            RootStore::Quant(s) => dequantize_offdiag(s, q),
            RootStore::QuantFull(s) => q.dequantize(s),
        }
    }

    fn size_bytes(&self) -> usize {
        match self {
            RootStore::Full(x) => x.size_bytes(),
            RootStore::Quant(s) => s.size_bytes(),
            RootStore::QuantFull(s) => s.size_bytes(),
        }
    }
}

/// State of one sub-block of one parameter.
#[derive(Clone, Debug)]
pub struct BlockState {
    pub rows: usize,
    pub cols: usize,
    l: SideStore,
    r: SideStore,
    lhat: RootStore,
    rhat: RootStore,
    /// Dequantized root caches (refreshed whenever `lhat`/`rhat` change).
    cache_lhat: Matrix,
    cache_rhat: Matrix,
}

impl BlockState {
    fn new(rows: usize, cols: usize, cfg: &ShampooConfig, q: &BlockQuantizer) -> BlockState {
        BlockState {
            rows,
            cols,
            l: SideStore::init(rows, cfg, q),
            r: SideStore::init(cols, cfg, q),
            // Algorithm 1: L̂₀ = I, R̂₀ = I.
            lhat: RootStore::Full(Matrix::eye(rows)),
            rhat: RootStore::Full(Matrix::eye(cols)),
            cache_lhat: Matrix::eye(rows),
            cache_rhat: Matrix::eye(cols),
        }
    }

    fn update_gram(&mut self, g: &Matrix, cfg: &ShampooConfig, q: &BlockQuantizer) {
        let gram_l = syrk(g); // G·Gᵀ
        let gram_r = matmul_tn(g, g); // Gᵀ·G
        self.l.update(&gram_l, cfg, q);
        self.r.update(&gram_r, cfg, q);
    }

    fn update_inv_roots(&mut self, cfg: &ShampooConfig, q: &BlockQuantizer) {
        for (side, root, cache) in [
            (&self.l, &mut self.lhat, &mut self.cache_lhat),
            (&self.r, &mut self.rhat, &mut self.cache_rhat),
        ] {
            let precond = side.reconstruct(q);
            // Eq. (6)/(12): ridge λ_max·ε·I handled inside the iteration.
            let (x, stats) = inverse_pth_root(&precond, &cfg.schur);
            // Direct (VQ) quantization can break positive-definiteness
            // (Tab. 9); Schur–Newton then diverges. Fall back to the exact
            // eigendecomposition route with eigenvalue clamping — defined
            // for indefinite inputs, so VQ stays *functional but degraded*,
            // matching the paper's observed behavior.
            // The true root satisfies ‖X‖_max ≤ (λmin + ridge)^{-1/4}; a
            // quantization-created negative eigendirection can pass through
            // zero during the iteration, leaving M ≈ I (small residual) while
            // X accumulated an enormous finite factor — bound the magnitude.
            let lam0 = stats.lambda_max.max(0.0);
            let root_bound = 10.0 * ((lam0 * cfg.schur.eps).max(1e-10) as f64).powf(-0.25) as f32;
            let x = if x.has_non_finite()
                || !stats.residual.is_finite()
                || stats.residual > 0.1
                || crate::linalg::max_abs(&x) > root_bound
            {
                let mut ridged = precond.clone();
                let lam = stats.lambda_max.max(0.0);
                ridged.add_diag(lam * cfg.schur.eps);
                // Clamp at λmax·1e-4 (not the ε ridge): quantization-created
                // negative directions would otherwise get ~(1e-6)^{-1/4} ≈ 30×
                // amplification and swamp the true curvature signal.
                crate::linalg::inverse_pth_root_eig(
                    &ridged,
                    cfg.schur.p as f64,
                    (lam * 1e-4).max(1e-10),
                )
            } else {
                x
            };
            let dim = x.rows();
            let quantize = !matches!(cfg.variant, ShampooVariant::Full32)
                && dim * dim >= cfg.quant.min_quant_elems;
            *root = if quantize && cfg.vq_quantize_diag {
                RootStore::QuantFull(q.quantize(&x))
            } else if quantize {
                RootStore::Quant(quantize_offdiag(&x, q))
            } else {
                RootStore::Full(x)
            };
            *cache = root.dequant(q);
        }
    }

    /// `Ĝ = D(L̂)·G·D(R̂)` (Algorithm 1 line 15).
    fn precondition(&self, g: &Matrix) -> Matrix {
        matmul(&matmul(&self.cache_lhat, g), &self.cache_rhat)
    }

    fn size_bytes(&self) -> usize {
        self.l.size_bytes() + self.r.size_bytes() + self.lhat.size_bytes() + self.rhat.size_bytes()
    }
}

/// State of one parameter (all its blocks, or passthrough for vectors).
pub struct LayerState {
    pub rows: usize,
    pub cols: usize,
    pub blocking: Blocking,
    pub blocks: Vec<BlockState>,
    /// Vectors/scalars skip preconditioning entirely.
    pub passthrough: bool,
}

impl LayerState {
    pub fn new(rows: usize, cols: usize, cfg: &ShampooConfig, q: &BlockQuantizer) -> LayerState {
        let passthrough = rows.min(cols) <= 1;
        let blocking = Blocking::new(rows, cols, cfg.max_order);
        let blocks = if passthrough {
            Vec::new()
        } else {
            blocking
                .blocks
                .iter()
                .map(|b| BlockState::new(b.rows, b.cols, cfg, q))
                .collect()
        };
        LayerState { rows, cols, blocking, blocks, passthrough }
    }

    pub fn update_gram(&mut self, g: &Matrix, cfg: &ShampooConfig, q: &BlockQuantizer) {
        if self.passthrough {
            return;
        }
        for (spec, state) in self.blocking.blocks.iter().zip(self.blocks.iter_mut()) {
            let gb = g.block(spec.r0, spec.c0, spec.rows, spec.cols);
            state.update_gram(&gb, cfg, q);
        }
    }

    pub fn update_inv_roots(&mut self, cfg: &ShampooConfig, q: &BlockQuantizer) {
        if self.passthrough {
            return;
        }
        for state in self.blocks.iter_mut() {
            state.update_inv_roots(cfg, q);
        }
    }

    pub fn precondition(&self, g: &Matrix, _q: &BlockQuantizer) -> Matrix {
        if self.passthrough {
            return g.clone();
        }
        if self.blocking.is_trivial() {
            return self.blocks[0].precondition(g);
        }
        let mut out = Matrix::zeros(self.rows, self.cols);
        for (spec, state) in self.blocking.blocks.iter().zip(self.blocks.iter()) {
            let gb = g.block(spec.r0, spec.c0, spec.rows, spec.cols);
            out.set_block(spec.r0, spec.c0, &state.precondition(&gb));
        }
        out
    }

    pub fn size_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.size_bytes()).sum()
    }

    pub fn dequant_inv_roots(&self, _q: &BlockQuantizer) -> Vec<(Matrix, Matrix)> {
        self.blocks
            .iter()
            .map(|b| (b.cache_lhat.clone(), b.cache_rhat.clone()))
            .collect()
    }

    pub fn reconstructed_preconditioners(&self, q: &BlockQuantizer) -> Vec<(Matrix, Matrix)> {
        self.blocks
            .iter()
            .map(|b| (b.l.reconstruct(q), b.r.reconstruct(q)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantConfig;
    use crate::util::rng::Rng;

    fn cfg(variant: ShampooVariant) -> ShampooConfig {
        ShampooConfig {
            variant,
            t1: 1,
            t2: 1,
            quant: QuantConfig { min_quant_elems: 0, block: 8, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn cq_reconstruction_is_psd() {
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let q = BlockQuantizer::new(c.quant);
        let mut rng = Rng::new(1);
        let mut side = SideStore::init(12, &c, &q);
        for _ in 0..5 {
            let g = Matrix::randn(12, 16, 1.0, &mut rng);
            side.update(&syrk(&g), &c, &q);
            let l = side.reconstruct(&q);
            // PSD check via eigensolver.
            let (vals, _) = crate::linalg::eig_sym(&l, 1e-10, 100);
            assert!(vals[0] >= -1e-4, "λmin={} — CQ must preserve PSD", vals[0]);
            // Symmetry by construction.
            assert!(l.max_abs_diff(&l.transpose()) < 1e-6);
        }
    }

    #[test]
    fn vq_reconstruction_can_lose_psd_cq_does_not() {
        // The paper's Tab. 9 phenomenon on the toy ill-conditioned matrix:
        // direct quantization can produce a negative eigenvalue while CQ's
        // C·Cᵀ reconstruction cannot.
        let c_vq = cfg(ShampooVariant::Vq4);
        let q = BlockQuantizer::new(QuantConfig {
            min_quant_elems: 0,
            block: 2,
            ..Default::default()
        });
        // quantize the paper's [[10,3],[3,1]] directly (full quantization,
        // i.e. including diagonal, mirroring C.1's "VQ perturbs elements")
        let l = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0]]);
        let vq_back = q.roundtrip(&l);
        let (vals_vq, _) = crate::linalg::eig_sym(&vq_back, 1e-12, 100);
        // CQ path on the same matrix.
        let c_cfg = cfg(ShampooVariant::Cq4 { error_feedback: false });
        let (chol, _) = cholesky_jittered(&l, 1e-6, 8).unwrap();
        let store = TriJointStore::store(&chol, &Matrix::zeros(2, 2), &q);
        let (c_back, _) = store.load(&q);
        let cq_back = matmul_nt(&c_back, &c_back);
        let (vals_cq, _) = crate::linalg::eig_sym(&cq_back, 1e-12, 100);
        assert!(
            vals_cq[0] >= 0.0,
            "CQ reconstruction must stay PSD, got λmin={}",
            vals_cq[0]
        );
        // (VQ on this matrix may or may not go negative depending on block
        // size; the Tab. 9 harness reproduces the paper's exact setting.)
        let _ = (vals_vq, c_vq, c_cfg);
    }

    #[test]
    fn blocked_layer_partitions_work() {
        let mut c = cfg(ShampooVariant::Full32);
        c.max_order = 8;
        let q = BlockQuantizer::new(c.quant);
        let mut rng = Rng::new(2);
        let mut layer = LayerState::new(20, 12, &c, &q);
        assert_eq!(layer.blocks.len(), 3 * 2);
        let g = Matrix::randn(20, 12, 1.0, &mut rng);
        layer.update_gram(&g, &c, &q);
        layer.update_inv_roots(&c, &q);
        let ghat = layer.precondition(&g, &q);
        assert_eq!((ghat.rows(), ghat.cols()), (20, 12));
        assert!(!ghat.has_non_finite());
    }

    #[test]
    fn small_tensor_exemption_keeps_f32() {
        let mut c = cfg(ShampooVariant::Vq4);
        c.quant.min_quant_elems = 4096; // paper default
        let q = BlockQuantizer::new(c.quant);
        // 32×32 preconditioners are 1024 < 4096 elems → stay f32.
        let layer = LayerState::new(32, 32, &c, &q);
        assert!(matches!(layer.blocks[0].l, SideStore::Full(_)));
        // 128×128 → 16384 ≥ 4096 → quantized.
        let layer2 = LayerState::new(128, 128, &c, &q);
        assert!(matches!(layer2.blocks[0].l, SideStore::Vq(_)));
    }

    #[test]
    fn root_cache_matches_store() {
        let c = cfg(ShampooVariant::Vq4);
        let q = BlockQuantizer::new(c.quant);
        let mut rng = Rng::new(3);
        let mut block = BlockState::new(10, 10, &c, &q);
        let g = Matrix::randn(10, 10, 1.0, &mut rng);
        block.update_gram(&g, &c, &q);
        block.update_inv_roots(&c, &q);
        assert!(block.cache_lhat.max_abs_diff(&block.lhat.dequant(&q)) < 1e-7);
        assert!(block.cache_rhat.max_abs_diff(&block.rhat.dequant(&q)) < 1e-7);
    }

    #[test]
    fn cholesky_failure_resets_state() {
        // Inject a Gram update that is wildly non-PSD after quantization
        // noise: NaN gram — state must reset, not crash.
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let q = BlockQuantizer::new(c.quant);
        let mut side = SideStore::init(6, &c, &q);
        let mut bad = Matrix::zeros(6, 6);
        bad[(0, 0)] = f32::NAN;
        side.update(&bad, &c, &q);
        let l = side.reconstruct(&q);
        assert!(!l.has_non_finite(), "reset must clear NaNs");
    }
}

//! Per-layer preconditioner state, stored behind [`PrecondCodec`] trait
//! objects.
//!
//! Each parameter is tiled by [`Blocking`]; each block keeps an `(L, R)`
//! pair plus the inverse-4th-roots `(L̂, R̂)`, each slot a boxed codec chosen
//! by the config's codec keys (f32 / vq4 / cq4 / cq4-ef / bw8 / any
//! registered key — see `quant::codec`). Dequantized roots are cached
//! between `T2` refreshes — the codec is the persistent store, the cache is
//! transient scratch that never diverges from `D(L̂)` because `L̂` only
//! changes at refresh time.
//!
//! The EMA/refresh *schedule* lives here; everything representation-specific
//! (Cholesky factorization, error feedback, bit packing) lives inside the
//! codecs.

use super::blocking::Blocking;
use super::config::ShampooConfig;
use crate::linalg::schur_newton::inverse_pth_root_scratch;
use crate::linalg::{
    inverse_pth_root_eig_planned, matmul_into_planned, matmul_tn_into, syrk_into, Matrix,
    ScratchArena,
};
use crate::quant::codec::{lookup, CodecBuilder, CodecCtx};
use crate::quant::PrecondCodec;

/// Resolve a codec builder, falling back to a panic that names the key —
/// a config can reference registered-at-runtime codecs, so this is a
/// runtime (not compile-time) binding by design.
fn builder(key: &str) -> CodecBuilder {
    lookup(key).unwrap_or_else(|| panic!("preconditioner codec '{key}' is not registered"))
}

/// Fresh f32 codec holding `x` (initial roots, small-tensor exemption).
fn f32_with(x: &Matrix, ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    let mut c = (builder("f32").side)(ctx);
    c.store(x);
    c
}

/// Side codec for a `dim×dim` Gram slot, honoring the small-tensor
/// exemption (App. C.3: tiny preconditioners stay f32).
fn side_codec(dim: usize, cfg: &ShampooConfig, ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    let quantize = dim * dim >= cfg.quant.min_quant_elems;
    let key = if quantize { cfg.side_codec_key() } else { "f32" };
    let mut codec = (builder(key).side)(ctx);
    codec.init(dim, cfg.eps);
    codec
}

/// State of one sub-block of one parameter.
#[derive(Clone, Debug)]
pub struct BlockState {
    pub rows: usize,
    pub cols: usize,
    l: Box<dyn PrecondCodec>,
    r: Box<dyn PrecondCodec>,
    lhat: Box<dyn PrecondCodec>,
    rhat: Box<dyn PrecondCodec>,
    /// Builder keys the root slots were created from ("f32" until the
    /// first refresh) — compared against the configured key so the SAME
    /// codec instance is reused across refreshes once it matches.
    lhat_key: &'static str,
    rhat_key: &'static str,
    /// Dequantized root caches (refreshed whenever `lhat`/`rhat` change).
    cache_lhat: Matrix,
    cache_rhat: Matrix,
}

impl BlockState {
    fn new(rows: usize, cols: usize, cfg: &ShampooConfig, ctx: &CodecCtx) -> BlockState {
        BlockState {
            rows,
            cols,
            l: side_codec(rows, cfg, ctx),
            r: side_codec(cols, cfg, ctx),
            // Algorithm 1: L̂₀ = I, R̂₀ = I (f32 until the first refresh
            // replaces the slot with the variant's root codec).
            lhat: f32_with(&Matrix::eye(rows), ctx),
            rhat: f32_with(&Matrix::eye(cols), ctx),
            lhat_key: "f32",
            rhat_key: "f32",
            cache_lhat: Matrix::eye(rows),
            cache_rhat: Matrix::eye(cols),
        }
    }

    /// Absorb the fresh Gram statistic into a side codec:
    /// `L ← β·L_prev + (1−β)·gram`, then re-store in its representation
    /// (Eq. (5) for VQ; the codec runs Eq. (7)–(11) for CQ). All
    /// temporaries come from the caller's arena — a warmed-up refresh
    /// allocates nothing.
    fn update_side(
        side: &mut dyn PrecondCodec,
        gram: &Matrix,
        cfg: &ShampooConfig,
        scratch: &mut ScratchArena,
    ) {
        let mut l_new = scratch.take(gram.rows(), gram.cols());
        side.load_into(&mut l_new, scratch);
        l_new.ema(cfg.beta, gram);
        l_new.symmetrize();
        side.store_into(&l_new, scratch);
        scratch.recycle(l_new);
    }

    fn update_gram(&mut self, g: &Matrix, cfg: &ShampooConfig, scratch: &mut ScratchArena) {
        let mut gram_l = scratch.take(g.rows(), g.rows());
        syrk_into(g, &mut gram_l); // G·Gᵀ
        Self::update_side(&mut *self.l, &gram_l, cfg, scratch);
        scratch.recycle(gram_l);
        let mut gram_r = scratch.take(g.cols(), g.cols());
        matmul_tn_into(g, g, &mut gram_r); // Gᵀ·G
        Self::update_side(&mut *self.r, &gram_r, cfg, scratch);
        scratch.recycle(gram_r);
    }

    fn update_inv_roots(
        &mut self,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) {
        for (side, root, root_key, cache) in [
            (&self.l, &mut self.lhat, &mut self.lhat_key, &mut self.cache_lhat),
            (&self.r, &mut self.rhat, &mut self.rhat_key, &mut self.cache_rhat),
        ] {
            let dim = cache.rows();
            let mut precond = scratch.take(dim, dim);
            side.load_into(&mut precond, scratch);
            // Eq. (6)/(12): ridge λ_max·ε·I handled inside the iteration.
            let (x, stats) = inverse_pth_root_scratch(&precond, &cfg.schur, scratch);
            // Direct (VQ) quantization can break positive-definiteness
            // (Tab. 9); Schur–Newton then diverges. Fall back to the exact
            // eigendecomposition route with eigenvalue clamping — defined
            // for indefinite inputs, so VQ stays *functional but degraded*,
            // matching the paper's observed behavior.
            // The true root satisfies ‖X‖_max ≤ (λmin + ridge)^{-1/4}; a
            // quantization-created negative eigendirection can pass through
            // zero during the iteration, leaving M ≈ I (small residual)
            // while X accumulated an enormous finite factor — bound the
            // magnitude.
            let lam0 = stats.lambda_max.max(0.0);
            let root_bound = 10.0 * ((lam0 * cfg.schur.eps).max(1e-10) as f64).powf(-0.25) as f32;
            let x = if x.has_non_finite()
                || !stats.residual.is_finite()
                || stats.residual > 0.1
                || crate::linalg::max_abs(&x) > root_bound
            {
                // Exceptional path — allocation here is acceptable, but the
                // ridged copy and the matmul plan still come from the arena.
                scratch.recycle(x);
                let mut ridged = scratch.take(dim, dim);
                ridged.copy_from(&precond);
                let lam = stats.lambda_max.max(0.0);
                ridged.add_diag(lam * cfg.schur.eps);
                // Clamp at λmax·1e-4 (not the ε ridge): quantization-created
                // negative directions would otherwise get ~(1e-6)^{-1/4} ≈
                // 30× amplification and swamp the true curvature signal.
                let eig = inverse_pth_root_eig_planned(
                    &ridged,
                    cfg.schur.p as f64,
                    (lam * 1e-4).max(1e-10),
                    scratch.plan(),
                );
                scratch.recycle(ridged);
                eig
            } else {
                x
            };
            let configured = cfg.root_codec_key();
            let quantize = configured != "f32" && dim * dim >= cfg.quant.min_quant_elems;
            let key = if quantize { configured } else { "f32" };
            // Slots start f32 (L̂₀ = I exactly) and switch representation at
            // the first refresh; after that the SAME codec instance is
            // reused so stateful root codecs (e.g. EF-based ones reached
            // via `root_codec` overrides) keep their state across refreshes.
            if *root_key != key {
                *root = (builder(key).root)(ctx);
                *root_key = key;
            }
            root.store_into(&x, scratch);
            root.load_into(cache, scratch);
            scratch.recycle(x);
            scratch.recycle(precond);
        }
    }

    /// `Ĝ = D(L̂)·G·D(R̂)` (Algorithm 1 line 15), arena-backed.
    fn precondition_into(&self, g: &Matrix, out: &mut Matrix, scratch: &mut ScratchArena) {
        let mut tmp = scratch.take(self.rows, g.cols());
        matmul_into_planned(&self.cache_lhat, g, &mut tmp, scratch.plan());
        matmul_into_planned(&tmp, &self.cache_rhat, out, scratch.plan());
        scratch.recycle(tmp);
    }

    fn size_bytes(&self) -> usize {
        self.l.size_bytes() + self.r.size_bytes() + self.lhat.size_bytes() + self.rhat.size_bytes()
    }
}

/// State of one parameter (all its blocks, or passthrough for vectors).
pub struct LayerState {
    pub rows: usize,
    pub cols: usize,
    pub blocking: Blocking,
    pub blocks: Vec<BlockState>,
    /// Vectors/scalars skip preconditioning entirely.
    pub passthrough: bool,
}

impl LayerState {
    pub fn new(rows: usize, cols: usize, cfg: &ShampooConfig, ctx: &CodecCtx) -> LayerState {
        let passthrough = rows.min(cols) <= 1;
        let blocking = Blocking::new(rows, cols, cfg.max_order);
        let blocks = if passthrough {
            Vec::new()
        } else {
            blocking
                .blocks
                .iter()
                .map(|b| BlockState::new(b.rows, b.cols, cfg, ctx))
                .collect()
        };
        LayerState { rows, cols, blocking, blocks, passthrough }
    }

    pub fn update_gram(&mut self, g: &Matrix, cfg: &ShampooConfig, scratch: &mut ScratchArena) {
        if self.passthrough {
            return;
        }
        for (spec, state) in self.blocking.blocks.iter().zip(self.blocks.iter_mut()) {
            let mut gb = scratch.take(spec.rows, spec.cols);
            g.block_into(spec.r0, spec.c0, &mut gb);
            state.update_gram(&gb, cfg, scratch);
            scratch.recycle(gb);
        }
    }

    pub fn update_inv_roots(
        &mut self,
        cfg: &ShampooConfig,
        ctx: &CodecCtx,
        scratch: &mut ScratchArena,
    ) {
        if self.passthrough {
            return;
        }
        for state in self.blocks.iter_mut() {
            state.update_inv_roots(cfg, ctx, scratch);
        }
    }

    /// Allocating convenience wrapper over [`Self::precondition_into`].
    pub fn precondition(&self, g: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.precondition_into(g, &mut out, &mut ScratchArena::new());
        out
    }

    /// Precondition into a caller-owned buffer; every per-block temporary
    /// comes from the arena (the per-step hot path of `Shampoo::step`).
    /// `out` is fully overwritten (the block specs tile the layer).
    pub fn precondition_into(&self, g: &Matrix, out: &mut Matrix, scratch: &mut ScratchArena) {
        if self.passthrough {
            out.copy_from(g);
            return;
        }
        if self.blocking.is_trivial() {
            self.blocks[0].precondition_into(g, out, scratch);
            return;
        }
        for (spec, state) in self.blocking.blocks.iter().zip(self.blocks.iter()) {
            let mut gb = scratch.take(spec.rows, spec.cols);
            g.block_into(spec.r0, spec.c0, &mut gb);
            let mut ob = scratch.take(spec.rows, spec.cols);
            state.precondition_into(&gb, &mut ob, scratch);
            out.set_block(spec.r0, spec.c0, &ob);
            scratch.recycle(ob);
            scratch.recycle(gb);
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.size_bytes()).sum()
    }

    pub fn dequant_inv_roots(&self) -> Vec<(Matrix, Matrix)> {
        self.blocks
            .iter()
            .map(|b| (b.cache_lhat.clone(), b.cache_rhat.clone()))
            .collect()
    }

    pub fn reconstructed_preconditioners(&self) -> Vec<(Matrix, Matrix)> {
        self.blocks.iter().map(|b| (b.l.load(), b.r.load())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_nt, syrk};
    use crate::quant::{BlockQuantizer, QuantConfig};
    use crate::shampoo::ShampooVariant;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn cfg(variant: ShampooVariant) -> ShampooConfig {
        ShampooConfig {
            variant,
            t1: 1,
            t2: 1,
            quant: QuantConfig { min_quant_elems: 0, block: 8, ..Default::default() },
            ..Default::default()
        }
    }

    fn ctx(c: &ShampooConfig) -> CodecCtx {
        CodecCtx::new(c.eps, c.beta_e, Arc::new(BlockQuantizer::new(c.quant)))
    }

    #[test]
    fn cq_reconstruction_is_psd() {
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let ctx = ctx(&c);
        let mut rng = Rng::new(1);
        let mut side = side_codec(12, &c, &ctx);
        let mut scratch = ScratchArena::new();
        assert_eq!(side.key(), "cq4-ef");
        for _ in 0..5 {
            let g = Matrix::randn(12, 16, 1.0, &mut rng);
            BlockState::update_side(&mut *side, &syrk(&g), &c, &mut scratch);
            let l = side.load();
            // PSD check via eigensolver.
            let (vals, _) = crate::linalg::eig_sym(&l, 1e-10, 100);
            assert!(vals[0] >= -1e-4, "λmin={} — CQ must preserve PSD", vals[0]);
            // Symmetry by construction.
            assert!(l.max_abs_diff(&l.transpose()) < 1e-6);
        }
    }

    #[test]
    fn vq_reconstruction_can_lose_psd_cq_does_not() {
        // The paper's Tab. 9 phenomenon on the toy ill-conditioned matrix:
        // direct quantization can produce a negative eigenvalue while CQ's
        // C·Cᵀ reconstruction cannot.
        let q = BlockQuantizer::new(QuantConfig {
            min_quant_elems: 0,
            block: 2,
            ..Default::default()
        });
        // quantize the paper's [[10,3],[3,1]] directly (full quantization,
        // i.e. including diagonal, mirroring C.1's "VQ perturbs elements")
        let l = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0]]);
        let vq_back = q.roundtrip(&l);
        let (vals_vq, _) = crate::linalg::eig_sym(&vq_back, 1e-12, 100);
        // CQ path on the same matrix, through the codec.
        let c_cfg = cfg(ShampooVariant::Cq4 { error_feedback: false });
        let mut cc = ShampooConfig { quant: QuantConfig { block: 2, ..c_cfg.quant }, ..c_cfg };
        cc.eps = 1e-6;
        let cctx = ctx(&cc);
        let mut codec = side_codec(2, &cc, &cctx);
        codec.store(&l);
        let cq_back = codec.load();
        let (vals_cq, _) = crate::linalg::eig_sym(&cq_back, 1e-12, 100);
        assert!(
            vals_cq[0] >= 0.0,
            "CQ reconstruction must stay PSD, got λmin={}",
            vals_cq[0]
        );
        // (VQ on this matrix may or may not go negative depending on block
        // size; the Tab. 9 harness reproduces the paper's exact setting.)
        let _ = vals_vq;
    }

    #[test]
    fn cq_codec_matches_direct_tri_store() {
        // The codec's C·Cᵀ reconstruction equals hand-driving the joint
        // store (no behavior change vs. the pre-trait implementation).
        let c = cfg(ShampooVariant::Cq4 { error_feedback: false });
        let cctx = ctx(&c);
        let mut rng = Rng::new(7);
        let g = Matrix::randn(12, 12, 1.0, &mut rng);
        let mut spd = syrk(&g);
        spd.add_diag(0.5);
        let mut codec = side_codec(12, &c, &cctx);
        codec.store(&spd);
        let via_codec = codec.load();

        let (chol, _) = crate::linalg::cholesky_jittered(&spd, c.eps, 12).unwrap();
        let store = crate::quant::TriJointStore::store(
            &chol,
            &Matrix::zeros(12, 12),
            &cctx.quantizer,
        );
        let (c_back, _) = store.load(&cctx.quantizer);
        let direct = matmul_nt(&c_back, &c_back);
        assert!(via_codec.max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn blocked_layer_partitions_work() {
        let mut c = cfg(ShampooVariant::Full32);
        c.max_order = 8;
        let cctx = ctx(&c);
        let mut rng = Rng::new(2);
        let mut layer = LayerState::new(20, 12, &c, &cctx);
        let mut scratch = ScratchArena::new();
        assert_eq!(layer.blocks.len(), 3 * 2);
        let g = Matrix::randn(20, 12, 1.0, &mut rng);
        layer.update_gram(&g, &c, &mut scratch);
        layer.update_inv_roots(&c, &cctx, &mut scratch);
        let ghat = layer.precondition(&g);
        assert_eq!((ghat.rows(), ghat.cols()), (20, 12));
        assert!(!ghat.has_non_finite());
    }

    #[test]
    fn small_tensor_exemption_keeps_f32() {
        let mut c = cfg(ShampooVariant::Vq4);
        c.quant.min_quant_elems = 4096; // paper default
        let cctx = ctx(&c);
        // 32×32 preconditioners are 1024 < 4096 elems → stay f32.
        let layer = LayerState::new(32, 32, &c, &cctx);
        assert_eq!(layer.blocks[0].l.key(), "f32");
        // 128×128 → 16384 ≥ 4096 → quantized.
        let layer2 = LayerState::new(128, 128, &c, &cctx);
        assert_eq!(layer2.blocks[0].l.key(), "vq4");
    }

    #[test]
    fn root_cache_matches_store() {
        let c = cfg(ShampooVariant::Vq4);
        let cctx = ctx(&c);
        let mut rng = Rng::new(3);
        let mut block = BlockState::new(10, 10, &c, &cctx);
        let mut scratch = ScratchArena::new();
        let g = Matrix::randn(10, 10, 1.0, &mut rng);
        block.update_gram(&g, &c, &mut scratch);
        block.update_inv_roots(&c, &cctx, &mut scratch);
        assert_eq!(block.lhat.key(), "vq4");
        assert!(block.cache_lhat.max_abs_diff(&block.lhat.load()) < 1e-7);
        assert!(block.cache_rhat.max_abs_diff(&block.rhat.load()) < 1e-7);
    }

    #[test]
    fn cholesky_failure_resets_state() {
        // Inject a Gram update that is wildly non-PSD after quantization
        // noise: NaN gram — state must reset, not crash.
        let c = cfg(ShampooVariant::Cq4 { error_feedback: true });
        let cctx = ctx(&c);
        let mut side = side_codec(6, &c, &cctx);
        let mut bad = Matrix::zeros(6, 6);
        bad[(0, 0)] = f32::NAN;
        BlockState::update_side(&mut *side, &bad, &c, &mut ScratchArena::new());
        let l = side.load();
        assert!(!l.has_non_finite(), "reset must clear NaNs");
    }

    #[test]
    fn bw8_layer_runs_and_is_half_of_f32_codes() {
        let c = cfg(ShampooVariant::Bw8);
        let cctx = ctx(&c);
        let mut rng = Rng::new(4);
        let mut layer = LayerState::new(32, 32, &c, &cctx);
        let mut scratch = ScratchArena::new();
        assert_eq!(layer.blocks[0].l.key(), "bw8");
        let g = Matrix::randn(32, 32, 1.0, &mut rng);
        layer.update_gram(&g, &c, &mut scratch);
        layer.update_inv_roots(&c, &cctx, &mut scratch);
        assert!(!layer.precondition(&g).has_non_finite());
        // 8-bit codes: each side/root ≈ n² bytes + scales + diag, far below
        // the 4·n² f32 payload and roughly twice the 4-bit payload.
        let bytes = layer.size_bytes();
        assert!(bytes < 4 * 4 * 32 * 32, "bw8 must undercut f32: {bytes}");
    }

    #[test]
    fn codec_override_reaches_unregistered_variants() {
        // A config can route sides through any registered codec without a
        // matching ShampooVariant arm — the open-world path.
        let mut c = cfg(ShampooVariant::Full32);
        c.side_codec = Some("bw8");
        let cctx = ctx(&c);
        let layer = LayerState::new(16, 16, &c, &cctx);
        assert_eq!(layer.blocks[0].l.key(), "bw8");
    }
}

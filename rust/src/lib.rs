//! # quartz — Memory-Efficient 4-bit Preconditioned Stochastic Optimization
//!
//! A production-grade reproduction of *"Memory-Efficient 4-bit Preconditioned
//! Stochastic Optimization"* (Li, Ding, Toh, Zhou; 2024): **4-bit Shampoo via
//! compensated Cholesky quantization (CQ + EF)**, built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (block-wise 4-bit quantization, preconditioner
//!   apply, Gram EMA) authored in `python/compile/kernels/`, validated
//!   against pure-jnp oracles, lowered with the rest of the model.
//! * **L2** — JAX model graphs (MLP / CNN / ViT-analog / decoder LM
//!   forward+backward) AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **L3** — this crate: the coordinator, trainer, PJRT runtime, and the
//!   complete native optimizer substrate (linear algebra, quantization,
//!   Shampoo family, base optimizers).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once; the `quartz` binary is self-contained after.
//!
//! ## Crate map
//!
//! | module | contents |
//! |---|---|
//! | [`util`] | RNG, stats, JSON/TOML parsers, thread pool, bench + property-test harnesses |
//! | [`linalg`] | dense f32 matrices, the packed-panel microkernel GEMM tier ([`linalg::gemm`] — AVX2/scalar, all matmul/syrk entry points route through it), blocked + naive Cholesky, Schur–Newton inverse p-th root, Jacobi eigensolver, power iteration, the [`linalg::ScratchArena`] buffer pool behind the allocation-free refresh path |
//! | [`quant`] | codebook mappings, block-wise quantizers (4/8-bit), off-diagonal quantization, the Fig. 2 joint triangular store, error feedback, and the open [`quant::codec`] registry |
//! | [`optim`] | the [`optim::Optimizer`] trait; SGD(M), Adam(W), RMSProp, grafting, LR schedules |
//! | [`shampoo`] | 32-bit Shampoo (Alg. 2) and quantized Shampoo VQ / CQ / CQ+EF (Alg. 1) / 8-bit, all storing state through `PrecondCodec` trait objects; balanced max-order blocking; the [`shampoo::scheduler`] refresh engine (string-keyed `every-n` / `staggered` / `staleness` policies over `(layer, block, side)` units + work-queue executor) |
//! | [`data`] | seeded synthetic datasets: gaussian-cluster classification, patch images, Markov token corpus |
//! | [`models`] | model/artifact specs and deterministic parameter initialization mirroring `model.py` |
//! | [`runtime`] | PJRT CPU client, HLO-text loading, executable cache, literal helpers |
//! | [`train`] | training loop over AOT artifacts, [`train::OptimizerStack`] + string-keyed [`train::registry`], eval, curve logging |
//! | [`metrics`] | exact optimizer-state memory accountant, timers, refresh-scheduler telemetry |
//! | [`persist`] | versioned CRC-checked checkpoint container, full-run snapshots, bit-identical resume |
//! | [`coordinator`] | experiment specs, multi-worker job queue (checkpointing, JSONL metrics, crash resume), result registry |
//! | [`report`] | paper-style table renderer, figure series dumps |
//!
//! ## Quickstart
//!
//! ```no_run
//! use quartz::prelude::*;
//! // Construct any registered variant by string key…
//! let stack = quartz::train::registry::build(
//!     "cq-ef",
//!     BaseOptimizer::sgdm(0.1, 0.9, 5e-4),
//!     &ShampooConfig::default(),
//!     &[(64, 32)],
//! )
//! .unwrap();
//! // …or build the concrete type directly:
//! let cfg = ShampooConfig { variant: ShampooVariant::Bw8, ..Default::default() };
//! let mut opt = Shampoo::new(BaseOptimizer::sgdm(0.1, 0.9, 5e-4), cfg, &[(64, 32)]);
//! // feed per-layer gradients each step:
//! // opt.step(&mut params, &grads, step_idx, lr_scale);
//! # let _ = stack;
//! ```

// The numerical kernels are written in explicit-index style on purpose (the
// perf notes depend on the autovectorizable fixed-loop shape), and a few
// internal signatures are wide by design; silence the style lints that fight
// that idiom so `clippy -D warnings` can gate everything else.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::new_without_default,
    clippy::many_single_char_names
)]

pub mod util;
pub mod linalg;
pub mod quant;
pub mod optim;
pub mod shampoo;
pub mod data;
pub mod models;
pub mod runtime;
pub mod train;
pub mod metrics;
pub mod persist;
pub mod coordinator;
pub mod report;
pub mod analysis;

/// Convenience re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::linalg::{Matrix, MatmulPlan, ScratchArena};
    pub use crate::metrics::memory::MemoryModel;
    pub use crate::optim::{BaseOptimizer, LrSchedule, Optimizer};
    pub use crate::quant::{BlockQuantizer, CodecCtx, Mapping, PrecondCodec, QuantConfig};
    pub use crate::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
    pub use crate::train::OptimizerStack;
    pub use crate::util::rng::Rng;
}

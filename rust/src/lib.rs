//! # quartz — Memory-Efficient 4-bit Preconditioned Stochastic Optimization
//!
//! A production-grade reproduction of *"Memory-Efficient 4-bit Preconditioned
//! Stochastic Optimization"* (Li, Ding, Toh, Zhou; 2024): **4-bit Shampoo via
//! compensated Cholesky quantization (CQ + EF)**, built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (block-wise 4-bit quantization, preconditioner
//!   apply, Gram EMA) authored in `python/compile/kernels/`, validated
//!   against pure-jnp oracles, lowered with the rest of the model.
//! * **L2** — JAX model graphs (MLP / CNN / ViT-analog / decoder LM
//!   forward+backward) AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **L3** — this crate: the coordinator, trainer, PJRT runtime, and the
//!   complete native optimizer substrate (linear algebra, quantization,
//!   Shampoo family, base optimizers).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once; the `quartz` binary is self-contained after.
//!
//! ## Crate map
//!
//! | module | contents |
//! |---|---|
//! | [`util`] | RNG, stats, JSON/TOML parsers, thread pool, bench + property-test harnesses |
//! | [`linalg`] | dense f32 matrices, blocked matmul, Cholesky, Schur–Newton inverse p-th root, Jacobi eigensolver, power iteration |
//! | [`quant`] | linear-2 / linear / dynamic mappings, block-wise 4-bit quantizers, off-diagonal quantization, packed triangular joint storage (paper Fig. 2), error feedback |
//! | [`optim`] | SGD(M), Adam(W), RMSProp, grafting, LR schedules |
//! | [`shampoo`] | practical 32-bit Shampoo (Alg. 2) and 4-bit Shampoo VQ / CQ / CQ+EF (Alg. 1), max-order blocking |
//! | [`data`] | seeded synthetic datasets: gaussian-cluster classification, patch images, Markov token corpus |
//! | [`models`] | model/artifact specs and deterministic parameter initialization mirroring `model.py` |
//! | [`runtime`] | PJRT CPU client, HLO-text loading, executable cache, literal helpers |
//! | [`train`] | training loop over AOT artifacts, eval (accuracy / perplexity), curve logging |
//! | [`metrics`] | exact optimizer-state memory accountant, timers |
//! | [`coordinator`] | experiment specs, multi-worker scheduler, result registry |
//! | [`report`] | paper-style table renderer, figure series dumps |
//!
//! ## Quickstart
//!
//! ```no_run
//! use quartz::prelude::*;
//! let cfg = ShampooConfig { variant: ShampooVariant::Cq4 { error_feedback: true }, ..Default::default() };
//! let mut opt = Shampoo::new(BaseOptimizer::sgdm(0.1, 0.9, 5e-4), cfg, &[(64, 32)]);
//! // feed per-layer gradients each step:
//! // opt.step(&mut params, &grads, step_idx);
//! ```

pub mod util;
pub mod linalg;
pub mod quant;
pub mod optim;
pub mod shampoo;
pub mod data;
pub mod models;
pub mod runtime;
pub mod train;
pub mod metrics;
pub mod coordinator;
pub mod report;
pub mod analysis;

/// Convenience re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::linalg::{Matrix, MatmulPlan};
    pub use crate::metrics::memory::MemoryModel;
    pub use crate::optim::{BaseOptimizer, LrSchedule};
    pub use crate::quant::{BlockQuantizer, Mapping, QuantConfig};
    pub use crate::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
    pub use crate::util::rng::Rng;
}

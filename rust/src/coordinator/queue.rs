//! The multi-run job service: many [`RunSpec`]s over the shared worker
//! pool with periodic checkpointing, a streaming JSONL metrics log, and
//! crash/kill resume.
//!
//! A queue is a directory:
//!
//! ```text
//! <dir>/queue.toml      the spec, pinned on first run (resume re-reads it)
//! <dir>/metrics.jsonl   append-only event stream (queue_start / run_start /
//!                       run_end), one JSON object per line
//! <dir>/runs/<id>/      per-run checkpoints (step-NNNNNNNN.ckpt)
//! ```
//!
//! Re-entering the same directory is idempotent: runs whose `run_end`
//! event is already on the stream are returned from the log without
//! re-executing; interrupted runs resume from their newest valid
//! checkpoint (a truncated or corrupt tail checkpoint fails its CRC and
//! the scan falls back to the previous one); a torn final line on the
//! metrics stream — the other crash artifact — fails to parse and is
//! ignored. So `quartz resume <dir>` after a SIGKILL finishes exactly the
//! work that was left.

use super::runner::{run_all_logged, RunOutcome};
use super::spec::{ExperimentSpec, RunSpec};
use crate::train::RunMetrics;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// An append-only, line-buffered JSONL event stream shared by the worker
/// pool (interior `Mutex` keeps concurrent lines whole).
pub struct MetricsLog {
    file: Mutex<fs::File>,
}

impl MetricsLog {
    /// Open the stream at `path` for appending, creating parent
    /// directories as needed.
    pub fn open(path: &Path) -> Result<MetricsLog> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening metrics log {}", path.display()))?;
        Ok(MetricsLog { file: Mutex::new(file) })
    }

    /// Append one event line. IO failures go to stderr and are swallowed —
    /// a full disk must not take down the runs themselves.
    pub fn event(&self, obj: Json) {
        let line = obj.to_string();
        let mut f = self.file.lock().unwrap();
        if let Err(e) = writeln!(f, "{line}") {
            eprintln!("metrics log write failed: {e}");
        }
    }

    pub(crate) fn run_start(&self, spec: &RunSpec) {
        self.event(obj(vec![
            ("event", s("run_start")),
            ("id", s(&spec.id)),
            ("model", s(&spec.model)),
            ("optimizer", s(&spec.optimizer.label())),
            ("steps", num(spec.steps as f64)),
            ("seed", num(spec.seed as f64)),
            ("ts", num(now_secs())),
        ]));
    }

    pub(crate) fn run_end(&self, o: &RunOutcome) {
        let outcome = if o.poisoned {
            "poisoned"
        } else if o.error.is_some() {
            "error"
        } else if o.metrics.is_some() {
            "ok"
        } else {
            "oom"
        };
        let mut fields = vec![
            ("event", s("run_end")),
            ("id", s(&o.id)),
            ("model", s(&o.model)),
            ("optimizer", s(&o.optimizer)),
            ("outcome", s(outcome)),
            ("wall_secs", num(o.wall_secs)),
            ("modeled_bytes", num(o.modeled_bytes as f64)),
            ("ts", num(now_secs())),
        ];
        if let Some(m) = &o.metrics {
            fields.push(("final_metric", num(m.final_metric)));
            fields.push(("state_bytes", num(m.state_bytes as f64)));
            fields.push(("opt_secs", num(m.opt_secs)));
            fields.push(("train_wall_secs", num(m.wall_secs)));
            let h = &m.health;
            fields.push((
                "health",
                obj(vec![
                    ("grads_screened", num(h.grads_screened as f64)),
                    ("jitter_rescues", num(h.jitter_rescues as f64)),
                    ("psd_projections", num(h.psd_projections as f64)),
                    ("stale_root_serves", num(h.stale_root_serves as f64)),
                    ("floor_serves", num(h.floor_serves as f64)),
                    ("quarantines", num(h.quarantines as f64)),
                    ("releases", num(h.releases as f64)),
                ]),
            ));
        }
        if let Some(e) = &o.error {
            fields.push(("error", s(e)));
        }
        self.event(obj(fields));
    }

    /// One retry-attempt announcement (bounded-retry ladder bookkeeping).
    pub(crate) fn run_retry(&self, id: &str, attempt: u32, backoff_ms: u64) {
        self.event(obj(vec![
            ("event", s("run_retry")),
            ("id", s(id)),
            ("attempt", num(attempt as f64)),
            ("backoff_ms", num(backoff_ms as f64)),
            ("ts", num(now_secs())),
        ]));
    }
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// JSON has no non-finite numbers; map them to null rather than emitting
/// a line the parser (and every resume pass) would reject.
fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn now_secs() -> f64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs_f64()).unwrap_or(0.0)
}

/// Filesystem-safe per-run directory name: the sanitized id plus a short
/// hash of the exact id, so ids that sanitize identically cannot share a
/// checkpoint directory.
fn run_dir_name(id: &str) -> String {
    let safe: String = id
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') { c } else { '-' })
        .collect();
    format!("{safe}-{:08x}", crate::persist::spec_hash(id) as u32)
}

/// Outcomes a previous pass over this queue already recorded as terminal
/// (`ok`, `oom`, or `poisoned` — a run that exhausted its retry budget),
/// keyed by run id. Plain `error` runs are retried, not cached. Curves are
/// not replayed from the log — only the summary fields a table needs.
fn completed_runs(path: &Path) -> BTreeMap<String, RunOutcome> {
    let Ok(text) = fs::read_to_string(path) else {
        return BTreeMap::new();
    };
    let mut done = BTreeMap::new();
    for line in text.lines() {
        // A torn tail line (crash mid-append) fails to parse and is
        // skipped; every complete line before it still counts.
        let Ok(j) = Json::parse(line) else { continue };
        if j.get("event").and_then(|v| v.as_str()) != Some("run_end") {
            continue;
        }
        let Some(id) = j.get("id").and_then(|v| v.as_str()) else { continue };
        let outcome = j.get("outcome").and_then(|v| v.as_str()).unwrap_or("");
        if outcome != "ok" && outcome != "oom" && outcome != "poisoned" {
            continue;
        }
        let optimizer = j.get("optimizer").and_then(|v| v.as_str()).unwrap_or("").to_string();
        let model = j.get("model").and_then(|v| v.as_str()).unwrap_or("").to_string();
        let metrics = (outcome == "ok").then(|| {
            let mut health = crate::metrics::HealthStats::default();
            if let Some(hj) = j.get("health") {
                let g = |k: &str| hj.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                health.grads_screened = g("grads_screened");
                health.jitter_rescues = g("jitter_rescues");
                health.psd_projections = g("psd_projections");
                health.stale_root_serves = g("stale_root_serves");
                health.floor_serves = g("floor_serves");
                health.quarantines = g("quarantines");
                health.releases = g("releases");
            }
            RunMetrics {
                model: model.clone(),
                optimizer: optimizer.clone(),
                loss_curve: Vec::new(),
                eval_curve: Vec::new(),
                final_metric: j.get("final_metric").and_then(|v| v.as_f64()).unwrap_or(0.0),
                state_bytes: j.get("state_bytes").and_then(|v| v.as_usize()).unwrap_or(0),
                wall_secs: j.get("train_wall_secs").and_then(|v| v.as_f64()).unwrap_or(0.0),
                opt_secs: j.get("opt_secs").and_then(|v| v.as_f64()).unwrap_or(0.0),
                health,
            }
        });
        let poisoned = outcome == "poisoned";
        let error = poisoned.then(|| {
            j.get("error")
                .and_then(|v| v.as_str())
                .unwrap_or("poisoned (retries exhausted)")
                .to_string()
        });
        done.insert(
            id.to_string(),
            RunOutcome {
                id: id.to_string(),
                model,
                optimizer,
                modeled_bytes: j.get("modeled_bytes").and_then(|v| v.as_usize()).unwrap_or(0),
                metrics,
                error,
                poisoned,
                wall_secs: 0.0,
            },
        );
    }
    done
}

/// Run (or re-enter) an experiment spec as a resumable job queue rooted
/// at `dir`. `checkpoint_every > 0` overrides the spec's own interval.
/// Run ids must be unique within the spec (they are `model/label`, so two
/// literally identical `[[runs]]` entries would alias).
pub fn run_queue(spec_text: &str, dir: &Path, checkpoint_every: u64) -> Result<Vec<RunOutcome>> {
    let exp = ExperimentSpec::from_toml(spec_text).context("parsing queue spec")?;
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let pinned = dir.join("queue.toml");
    if !pinned.exists() {
        fs::write(&pinned, spec_text)
            .with_context(|| format!("writing {}", pinned.display()))?;
    }
    let done = completed_runs(&dir.join("metrics.jsonl"));
    let log = MetricsLog::open(&dir.join("metrics.jsonl"))?;

    let mut slots: Vec<Option<RunOutcome>> = vec![None; exp.runs.len()];
    let mut pending: Vec<(usize, RunSpec)> = Vec::new();
    for (i, run) in exp.runs.iter().enumerate() {
        if let Some(prev) = done.get(&run.id) {
            slots[i] = Some(prev.clone());
            continue;
        }
        let mut run = run.clone();
        if checkpoint_every > 0 {
            run.checkpoint_every = checkpoint_every;
        }
        run.out_dir = Some(dir.join("runs").join(run_dir_name(&run.id)));
        pending.push((i, run));
    }
    log.event(obj(vec![
        ("event", s("queue_start")),
        ("name", s(&exp.name)),
        ("total", num(exp.runs.len() as f64)),
        ("cached", num((exp.runs.len() - pending.len()) as f64)),
        ("ts", num(now_secs())),
    ]));

    let specs: Vec<RunSpec> = pending.iter().map(|(_, r)| r.clone()).collect();
    let mut fresh = run_all_logged(&specs, exp.workers, Some(&log));

    // Bounded retry ladder: re-attempt errored runs up to `exp.retries`
    // times with step-doubling backoff; each attempt is announced on the
    // stream as a `run_retry` event. Checkpoints written by the failed
    // attempt are still in the run's out_dir, so a retry resumes rather
    // than restarting.
    let mut backoff_ms = exp.retry_backoff_ms;
    for attempt in 1..=exp.retries {
        let retry_idx: Vec<usize> = fresh
            .iter()
            .enumerate()
            .filter(|(_, o)| o.error.is_some())
            .map(|(j, _)| j)
            .collect();
        if retry_idx.is_empty() {
            break;
        }
        for &j in &retry_idx {
            log.run_retry(&specs[j].id, attempt, backoff_ms);
        }
        if backoff_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(backoff_ms));
        }
        let retry_specs: Vec<RunSpec> = retry_idx.iter().map(|&j| specs[j].clone()).collect();
        let retried = run_all_logged(&retry_specs, exp.workers, Some(&log));
        for (&j, o) in retry_idx.iter().zip(retried) {
            fresh[j] = o;
        }
        backoff_ms = backoff_ms.saturating_mul(2);
    }
    // Retries exhausted: mark survivors poisoned — a terminal outcome the
    // next resume pass caches instead of re-attempting.
    for o in fresh.iter_mut() {
        if o.error.is_some() {
            o.poisoned = true;
            log.run_end(o);
        }
    }

    for ((i, _), outcome) in pending.into_iter().zip(fresh) {
        slots[i] = Some(outcome);
    }
    Ok(slots.into_iter().map(|o| o.expect("every queue slot filled")).collect())
}

/// Resume a queue directory created by [`run_queue`]: re-reads the pinned
/// `dir/queue.toml` and re-enters the queue — finished runs come back
/// from the metrics stream, interrupted ones restart from their newest
/// valid checkpoint and train only the remaining steps.
pub fn resume_queue(dir: &Path, checkpoint_every: u64) -> Result<Vec<RunOutcome>> {
    let pinned = dir.join("queue.toml");
    let text = fs::read_to_string(&pinned).with_context(|| {
        format!("no queue to resume at {} (missing queue.toml)", dir.display())
    })?;
    run_queue(&text, dir, checkpoint_every)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = "\nname = \"q\"\nsteps = 30\nworkers = 2\ncheckpoint_every = 10\n\n\
                        [workload]\nkind = \"synthetic\"\nshapes = [12, 6, 6, 6]\n\n\
                        [[runs]]\nmodel = \"syn\"\nbase = \"sgdm\"\n\n\
                        [[runs]]\nmodel = \"syn\"\nbase = \"sgdm\"\nshampoo = \"cq-ef\"\n";

    #[test]
    fn queue_streams_metrics_and_skips_completed_runs_on_resume() {
        let dir = std::env::temp_dir().join(format!("quartz-queue-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);

        let out = run_queue(SPEC, &dir, 0).unwrap();
        assert_eq!(out.len(), 2);
        for o in &out {
            assert!(o.metrics.is_some(), "{}: {:?}", o.id, o.error);
            assert!(o.wall_secs > 0.0);
        }
        // Checkpoints landed under per-run directories.
        assert!(dir.join("runs").read_dir().unwrap().count() == 2);
        // The stream is valid JSONL with one run_end per run.
        let text = fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        for line in text.lines() {
            Json::parse(line).unwrap();
        }
        let ends = text.lines().filter(|l| l.contains("\"run_end\"")).count();
        assert_eq!(ends, 2);
        assert!(text.contains("\"queue_start\""));
        assert!(text.contains("\"run_start\""));
        assert!(text.contains("\"wall_secs\""));

        // Re-entering the queue executes nothing: outcomes come back from
        // the stream and no new run_end events are appended.
        let out2 = resume_queue(&dir, 0).unwrap();
        assert_eq!(out2.len(), 2);
        for (a, b) in out.iter().zip(out2.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.metrics.as_ref().unwrap().final_metric,
                b.metrics.as_ref().unwrap().final_metric
            );
        }
        let text2 = fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        let ends2 = text2.lines().filter(|l| l.contains("\"run_end\"")).count();
        assert_eq!(ends2, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn errored_runs_retry_then_poison_and_cache() {
        let dir = std::env::temp_dir().join(format!("quartz-queue-poison-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        // A nonexistent model fails deterministically on every machine,
        // whether or not compiled artifacts are present.
        let spec = "\nname = \"p\"\nsteps = 5\nworkers = 1\nretries = 2\nretry_backoff_ms = 1\n\n\
                    [[runs]]\nmodel = \"no-such-model\"\nbase = \"sgdm\"\n";

        let out = run_queue(spec, &dir, 0).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].poisoned, "expected terminal poisoned outcome");
        assert!(out[0].error.is_some());
        assert!(out[0].metrics.is_none());

        let text = fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        let retries = text.lines().filter(|l| l.contains("\"run_retry\"")).count();
        assert_eq!(retries, 2, "one run_retry event per retry attempt:\n{text}");
        assert!(text.contains("\"outcome\":\"poisoned\""), "{text}");

        // Resuming serves the poisoned outcome from the stream: no new
        // attempts, no new retry or run_end events.
        let out2 = resume_queue(&dir, 0).unwrap();
        assert_eq!(out2.len(), 1);
        assert!(out2[0].poisoned);
        assert!(out2[0].error.is_some());
        let text2 = fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        let retries2 = text2.lines().filter(|l| l.contains("\"run_retry\"")).count();
        assert_eq!(retries2, 2);
        let ends2 = text2.lines().filter(|l| l.contains("\"run_end\"")).count();
        assert_eq!(ends2, text.lines().filter(|l| l.contains("\"run_end\"")).count());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_without_queue_dir_errors() {
        let dir = std::env::temp_dir().join("quartz-queue-absent");
        let err = format!("{:#}", resume_queue(&dir, 0).unwrap_err());
        assert!(err.contains("queue.toml"), "{err}");
    }
}

//! Declarative experiment specifications (+ TOML loading for user-defined
//! grids; the built-in paper tables construct these programmatically).

use crate::bail;
use crate::data::images::ImageSpec;
use crate::data::synthetic::ClusterSpec;
use crate::data::tokens::CorpusSpec;
use crate::optim::optimizer::Hyper;
use crate::optim::{grafting, BaseOptimizer, LrSchedule, OptimizerKind};
use crate::shampoo::{scheduler, ShampooConfig, ShampooVariant};
use crate::train::{registry, OptimizerStack, SyntheticSpec};
use crate::util::error::{Context, Result};
use crate::util::toml::{TomlDoc, TomlTable};
use std::path::PathBuf;

/// What data the run trains on.
#[derive(Clone, Debug)]
pub enum Workload {
    Cluster(ClusterSpec),
    Image(ImageSpec),
    Tokens(CorpusSpec),
    /// The artifact-free noisy quadratic ([`crate::train::synthetic`]) —
    /// runs without a PJRT runtime; the model name is ignored.
    Synthetic(SyntheticSpec),
}

/// Base optimizer + optional Shampoo wrapper.
#[derive(Clone, Debug)]
pub struct OptimizerSpec {
    pub base: OptimizerKind,
    pub hyper: Hyper,
    pub shampoo: Option<ShampooConfig>,
    /// Registry key overriding the variant-derived one — set when the spec
    /// was parsed from a name `ShampooVariant` does not cover (a stack
    /// registered at runtime). The memory model then approximates the
    /// footprint with `shampoo`'s variant.
    pub stack: Option<String>,
}

impl OptimizerSpec {
    pub fn base_only(base: OptimizerKind, hyper: Hyper) -> OptimizerSpec {
        OptimizerSpec { base, hyper, shampoo: None, stack: None }
    }

    pub fn with_shampoo(
        base: OptimizerKind,
        hyper: Hyper,
        shampoo: ShampooConfig,
    ) -> OptimizerSpec {
        OptimizerSpec { base, hyper, shampoo: Some(shampoo), stack: None }
    }

    /// The paper's default base hypers (App. C.3), scaled for the analogs.
    pub fn paper_hyper(base: OptimizerKind) -> Hyper {
        match base {
            OptimizerKind::Sgd | OptimizerKind::Sgdm => Hyper {
                lr: 0.05,
                momentum: 0.9,
                weight_decay: 5e-4,
                ..Default::default()
            },
            OptimizerKind::Adam | OptimizerKind::AdamW => Hyper {
                lr: 1e-3,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                weight_decay: 5e-2,
                ..Default::default()
            },
            OptimizerKind::RmsProp => Hyper {
                lr: 5e-4,
                beta2: 0.99,
                eps: 1e-8,
                weight_decay: 0.0,
                ..Default::default()
            },
        }
    }

    /// Spec from config-file spellings: any base the optim layer knows and
    /// any stack key in `train::registry` — built-in variants, their
    /// aliases, AND keys registered at runtime — with the paper's default
    /// hypers for that base.
    pub fn from_names(base: &str, shampoo: &str) -> Result<OptimizerSpec> {
        let base = OptimizerKind::parse(base)
            .with_context(|| format!("unknown base optimizer '{base}'"))?;
        let hyper = OptimizerSpec::paper_hyper(base);
        match shampoo {
            "none" => Ok(OptimizerSpec::base_only(base, hyper)),
            s => {
                if let Some(variant) = ShampooVariant::parse(s) {
                    let cfg = ShampooConfig { variant, ..Default::default() };
                    return Ok(OptimizerSpec::with_shampoo(base, hyper, cfg));
                }
                let Some(builder) = registry::lookup(s) else {
                    bail!("unknown shampoo variant or stack key '{s}'");
                };
                let mut cfg = ShampooConfig::default();
                // Keys with declarative codec metadata (ec4/f16/cq-r1) get
                // their overrides on the SPEC's config, so the memory model
                // prices — and labels name — what will actually run, not the
                // placeholder variant.
                if let Some((side, root)) = builder.codecs {
                    cfg.side_codec = Some(side);
                    cfg.root_codec = Some(root);
                }
                let mut spec = OptimizerSpec::with_shampoo(base, hyper, cfg);
                spec.stack = Some(s.to_string());
                Ok(spec)
            }
        }
    }

    /// The `train::registry` key this spec resolves to.
    pub fn stack_key(&self) -> &str {
        if let Some(key) = &self.stack {
            return key;
        }
        match &self.shampoo {
            None => "none",
            Some(cfg) => cfg.variant.key(),
        }
    }

    /// Materialize the optimizer stack for a model's shapes via the
    /// string-keyed registry (so registered stacks and codec overrides flow
    /// through the same path as the built-ins).
    pub fn build(&self, shapes: &[(usize, usize)]) -> OptimizerStack {
        let base = BaseOptimizer::new(self.base, self.hyper);
        let cfg = self.shampoo.unwrap_or_default();
        registry::build(self.stack_key(), base, &cfg, shapes)
            .expect("stack key was validated when the spec was constructed")
    }

    /// Row label matching the paper's tables (same composition as
    /// `Optimizer::name`, usable before the stack is materialized — OOM
    /// rows are labeled without ever building the optimizer). Stack keys
    /// carrying codec metadata label by their codecs, exactly like the
    /// built stack's `Optimizer::name`, so spec rows and runtime rows
    /// always join; metadata-less runtime-registered keys label by key.
    pub fn label(&self) -> String {
        let base = self.base.name().to_uppercase();
        if let Some(key) = &self.stack {
            if let Some(cfg) = &self.shampoo {
                match (cfg.side_codec, cfg.root_codec) {
                    (Some(side), Some(root)) if side == root => {
                        return format!("{base} + {side} Shampoo");
                    }
                    (Some(side), Some(root)) => {
                        return format!("{base} + {side}/{root} Shampoo");
                    }
                    _ => {}
                }
            }
            return format!("{base} + {key} Shampoo");
        }
        match &self.shampoo {
            None => base,
            Some(cfg) => cfg.variant.stack_label(self.base),
        }
    }
}

/// One training run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub id: String,
    pub model: String,
    pub workload: Workload,
    pub optimizer: OptimizerSpec,
    pub steps: u64,
    pub seed: u64,
    pub schedule: LrSchedule,
    pub eval_every: u64,
    pub log_every: u64,
    /// Optional memory ceiling in bytes: if the *modeled* optimizer state
    /// exceeds it the run is reported as OOM without executing (Tab. 6).
    pub memory_budget: Option<usize>,
    /// Checkpoint every N steps (0 = never). Needs `out_dir`.
    pub checkpoint_every: u64,
    /// Per-run output directory: checkpoints land here, and training
    /// resumes from the newest valid one found here.
    pub out_dir: Option<PathBuf>,
    /// Deterministic fault-injection plan for chaos runs (`None` = healthy).
    pub faults: Option<crate::util::fault::FaultPlan>,
    /// Keep only the newest N checkpoints after each write (0 = all).
    pub keep_checkpoints: usize,
}

impl RunSpec {
    pub fn new(model: &str, workload: Workload, optimizer: OptimizerSpec, steps: u64) -> RunSpec {
        RunSpec {
            id: format!("{}/{}", model, optimizer.label()),
            model: model.to_string(),
            workload,
            optimizer,
            steps,
            seed: 0,
            schedule: LrSchedule::CosineWarmup { warmup: 20, total: steps, min_frac: 0.05 },
            eval_every: 0,
            log_every: 10,
            memory_budget: None,
            checkpoint_every: 0,
            out_dir: None,
            faults: None,
            keep_checkpoints: 0,
        }
    }

    /// The spec-identity string hashed into every checkpoint header
    /// ([`crate::persist::spec_hash`]): anything that changes the training
    /// trajectory — model, optimizer stack, step count, seed — changes the
    /// hash, so a resume against a drifted spec restarts instead of
    /// restoring incompatible state.
    pub fn identity(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}",
            self.id,
            self.model,
            self.optimizer.label(),
            self.steps,
            self.seed
        )
    }
}

/// A named collection of runs.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    pub name: String,
    pub runs: Vec<RunSpec>,
    pub workers: usize,
    /// How many times the queue re-attempts a run that errored before
    /// declaring it poisoned (0 = fail on first error).
    pub retries: u32,
    /// Base backoff between retry attempts; doubles per attempt.
    pub retry_backoff_ms: u64,
}

impl ExperimentSpec {
    /// Parse a user-authored TOML spec, e.g.:
    ///
    /// ```toml
    /// name = "my-sweep"
    /// steps = 300
    /// workers = 4
    ///
    /// [workload]
    /// kind = "cluster"       # or "image" | "tokens" | "synthetic"
    /// classes = 32
    /// dim = 64
    /// # synthetic runs take a flat even-length dims list instead:
    /// #   shapes = [16, 8, 8, 8, 4, 1]   # layers (16x8), (8x8), (4x1)
    /// #   noise = 0.05
    /// #   pace_ms = 0
    ///
    /// [[runs]]
    /// model = "res_mlp_c32"
    /// base = "sgdm"
    /// shampoo = "cq-ef"      # any train::registry key: 32bit | vq | cq |
    ///                        # cq-ef | bw8 | ec4 | f16 | cq-r1 | none |
    ///                        # registered additions
    /// refresh_policy = "staggered"  # any shampoo::scheduler key:
    ///                               # every-n | staggered | staleness | …
    /// refresh_budget = 4            # staleness per-step unit budget (0 = auto)
    /// async_refresh = true          # overlap root refreshes with later steps
    /// async_shards = 2              # async worker shards (0 = auto)
    /// max_async_staleness = 2       # async publish deadline in steps (>= 1)
    /// graft = "adagrad"             # any optim::grafting key: none | sgd |
    ///                               # adagrad | rmsprop | sqrt-n | …
    /// start_preconditioning_step = 100   # grafted-base-only warmup steps
    /// no_preconditioning_for_layers_with_dim_gt = 4096  # 0 = disabled
    /// shape_interpretation = true   # chunk >=3-D tensors into matrices
    /// ```
    pub fn from_toml(text: &str) -> Result<ExperimentSpec> {
        let doc = TomlDoc::parse(text)?;
        let name = doc
            .root
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("experiment")
            .to_string();
        let steps = doc.root.get("steps").and_then(|v| v.as_i64()).unwrap_or(300) as u64;
        let seed = doc.root.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let workers = doc.root.get("workers").and_then(|v| v.as_i64()).unwrap_or(4) as usize;
        let checkpoint_every =
            doc.root.get("checkpoint_every").and_then(|v| v.as_i64()).unwrap_or(0).max(0) as u64;
        let keep_checkpoints =
            doc.root.get("keep_checkpoints").and_then(|v| v.as_i64()).unwrap_or(0).max(0) as usize;
        let retries = doc.root.get("retries").and_then(|v| v.as_i64()).unwrap_or(2).max(0) as u32;
        let retry_backoff_ms =
            doc.root.get("retry_backoff_ms").and_then(|v| v.as_i64()).unwrap_or(250).max(0) as u64;
        let faults = parse_faults(doc.tables.get("faults"))?;

        let wl_table = doc.tables.get("workload");
        let workload = parse_workload(wl_table, seed)?;

        let run_tables = doc
            .table_arrays
            .get("runs")
            .context("spec needs at least one [[runs]] entry")?;
        let mut runs = Vec::new();
        for (i, t) in run_tables.iter().enumerate() {
            let model = t
                .get("model")
                .and_then(|v| v.as_str())
                .with_context(|| format!("runs[{i}]: missing model"))?
                .to_string();
            let base_name = t.get("base").and_then(|v| v.as_str()).unwrap_or("sgdm");
            let base = parse_base(base_name)?;
            let mut hyper = OptimizerSpec::paper_hyper(base);
            if let Some(lr) = t.get("lr").and_then(|v| v.as_f64()) {
                hyper.lr = lr as f32;
            }
            let mut stack = None;
            let mut stack_codecs = None;
            let shampoo = match t.get("shampoo").and_then(|v| v.as_str()) {
                None | Some("none") => None,
                Some(s) => {
                    // Built-in variant spellings first; otherwise any stack
                    // key registered in `train::registry` is accepted.
                    let variant = match ShampooVariant::parse(s) {
                        Some(v) => v,
                        None => {
                            let Some(builder) = registry::lookup(s) else {
                                bail!("runs[{i}]: unknown shampoo variant or stack key '{s}'");
                            };
                            stack = Some(s.to_string());
                            // Declarative codec metadata (ec4/f16/cq-r1):
                            // carried onto the run config below so modeled
                            // bytes and labels match what runs.
                            stack_codecs = builder.codecs;
                            ShampooVariant::default_for_custom()
                        }
                    };
                    let mut cfg = ShampooConfig { variant, ..Default::default() };
                    if let Some((side, root)) = stack_codecs {
                        cfg.side_codec = Some(side);
                        cfg.root_codec = Some(root);
                    }
                    if let Some(t1) = t.get("t1").and_then(|v| v.as_i64()) {
                        cfg.t1 = t1 as u64;
                    }
                    if let Some(t2) = t.get("t2").and_then(|v| v.as_i64()) {
                        cfg.t2 = t2 as u64;
                    }
                    if let Some(b) = t.get("beta").and_then(|v| v.as_f64()) {
                        cfg.beta = b as f32;
                    }
                    if let Some(mo) = t.get("max_order").and_then(|v| v.as_i64()) {
                        cfg.max_order = mo as usize;
                    }
                    if let Some(qa) = t.get("quarantine_after").and_then(|v| v.as_i64()) {
                        crate::ensure!(
                            qa >= 1,
                            "runs[{i}]: quarantine_after must be >= 1, got {qa}"
                        );
                        cfg.quarantine_after = qa as u32;
                    }
                    if let Some(pi) = t.get("probation_interval").and_then(|v| v.as_i64()) {
                        crate::ensure!(
                            pi >= 1,
                            "runs[{i}]: probation_interval must be >= 1, got {pi}"
                        );
                        cfg.probation_interval = pi as u64;
                    }
                    // Refresh-scheduler selection mirrors the codec
                    // registry: any key in `shampoo::scheduler` (built-in
                    // or registered at runtime) is accepted; the stored
                    // key is the registry's canonical &'static str.
                    if let Some(rp) = t.get("refresh_policy").and_then(|v| v.as_str()) {
                        let b = scheduler::lookup(rp).with_context(|| {
                            format!("runs[{i}]: unknown refresh policy '{rp}'")
                        })?;
                        cfg.refresh_policy = b.key;
                    }
                    if let Some(rb) = t.get("refresh_budget").and_then(|v| v.as_i64()) {
                        crate::ensure!(
                            rb >= 0,
                            "runs[{i}]: refresh_budget must be >= 0, got {rb}"
                        );
                        cfg.refresh_budget = rb as usize;
                    }
                    if let Some(ar) = t.get("async_refresh").and_then(|v| v.as_bool()) {
                        cfg.async_refresh = ar;
                    }
                    if let Some(sh) = t.get("async_shards").and_then(|v| v.as_i64()) {
                        crate::ensure!(
                            sh >= 0,
                            "runs[{i}]: async_shards must be >= 0 (0 = auto), got {sh}"
                        );
                        cfg.async_shards = sh as usize;
                    }
                    if let Some(st) = t.get("max_async_staleness").and_then(|v| v.as_i64()) {
                        crate::ensure!(
                            st >= 1,
                            "runs[{i}]: max_async_staleness must be >= 1, got {st}"
                        );
                        cfg.max_async_staleness = st as u64;
                    }
                    // Workload knobs (scalable-Shampoo style). Graft
                    // selection mirrors the scheduler registry: any key in
                    // `optim::grafting` is accepted, and `none` disables
                    // grafting outright.
                    if let Some(gk) = t.get("graft").and_then(|v| v.as_str()) {
                        let b = grafting::lookup(gk)
                            .with_context(|| format!("runs[{i}]: unknown graft '{gk}'"))?;
                        cfg.graft = b.key;
                        cfg.grafting = b.key != "none";
                    }
                    if let Some(sp) = t.get("start_preconditioning_step").and_then(|v| v.as_i64())
                    {
                        crate::ensure!(
                            sp >= 0,
                            "runs[{i}]: start_preconditioning_step must be >= 0, got {sp}"
                        );
                        cfg.start_preconditioning_step = sp as u64;
                    }
                    if let Some(dg) = t
                        .get("no_preconditioning_for_layers_with_dim_gt")
                        .and_then(|v| v.as_i64())
                    {
                        crate::ensure!(
                            dg >= 0,
                            "runs[{i}]: no_preconditioning_for_layers_with_dim_gt must be >= 0 \
                             (0 = disabled), got {dg}"
                        );
                        cfg.no_preconditioning_for_layers_with_dim_gt = dg as usize;
                    }
                    if let Some(si) = t.get("shape_interpretation").and_then(|v| v.as_bool()) {
                        cfg.shape_interpretation = si;
                    }
                    Some(cfg)
                }
            };
            let opt = OptimizerSpec { base, hyper, shampoo, stack };
            let mut run = RunSpec::new(&model, workload.clone(), opt, steps);
            run.seed = seed;
            run.checkpoint_every = checkpoint_every;
            run.keep_checkpoints = keep_checkpoints;
            run.faults = faults.clone();
            runs.push(run);
        }
        Ok(ExperimentSpec { name, runs, workers, retries, retry_backoff_ms })
    }
}

/// Parse an optional `[faults]` chaos table:
///
/// ```toml
/// [faults]
/// seed = 7
/// nan_grad_every = 5      # NaN-poison one gradient every 5th step
/// inf_grad_every = 0      # (0 disables a channel)
/// force_fail_every = 10   # force factorization failure on every 10th step
/// fail_one_in = 1         # …for 1-in-N of that step's refresh units
/// ckpt_flip_every = 0     # bit-flip every Nth checkpoint file
/// until_step = 100        # stop injecting after this step (0 = never stop)
/// ```
fn parse_faults(t: Option<&TomlTable>) -> Result<Option<crate::util::fault::FaultPlan>> {
    let Some(t) = t else { return Ok(None) };
    let mut fp = crate::util::fault::FaultPlan::default();
    let get = |k: &str| t.get(k).and_then(|v| v.as_i64());
    if let Some(v) = get("seed") {
        fp.seed = v as u64;
    }
    if let Some(v) = get("nan_grad_every") {
        fp.nan_grad_every = v.max(0) as u64;
    }
    if let Some(v) = get("inf_grad_every") {
        fp.inf_grad_every = v.max(0) as u64;
    }
    if let Some(v) = get("force_fail_every") {
        fp.force_fail_every = v.max(0) as u64;
    }
    if let Some(v) = get("fail_one_in") {
        crate::ensure!(v >= 1, "faults.fail_one_in must be >= 1, got {v}");
        fp.fail_one_in = v as u64;
    }
    if let Some(v) = get("ckpt_flip_every") {
        fp.ckpt_flip_every = v.max(0) as u64;
    }
    if let Some(v) = get("until_step") {
        fp.until_step = v.max(0) as u64;
    }
    Ok(Some(fp))
}

fn parse_base(s: &str) -> Result<OptimizerKind> {
    match OptimizerKind::parse(s) {
        Some(kind) => Ok(kind),
        None => bail!("unknown base optimizer '{s}'"),
    }
}

fn parse_workload(t: Option<&TomlTable>, seed: u64) -> Result<Workload> {
    let Some(t) = t else {
        return Ok(Workload::Cluster(ClusterSpec { seed, ..Default::default() }));
    };
    match t.get("kind").and_then(|v| v.as_str()).unwrap_or("cluster") {
        "cluster" => {
            let mut spec = ClusterSpec { seed, ..Default::default() };
            if let Some(v) = t.get("classes").and_then(|v| v.as_i64()) {
                spec.classes = v as usize;
            }
            if let Some(v) = t.get("dim").and_then(|v| v.as_i64()) {
                spec.dim = v as usize;
            }
            if let Some(v) = t.get("train").and_then(|v| v.as_i64()) {
                spec.train = v as usize;
            }
            if let Some(v) = t.get("test").and_then(|v| v.as_i64()) {
                spec.test = v as usize;
            }
            Ok(Workload::Cluster(spec))
        }
        "image" => {
            let mut spec = ImageSpec { seed, ..Default::default() };
            if let Some(v) = t.get("classes").and_then(|v| v.as_i64()) {
                spec.classes = v as usize;
            }
            if let Some(v) = t.get("side").and_then(|v| v.as_i64()) {
                spec.side = v as usize;
            }
            Ok(Workload::Image(spec))
        }
        "tokens" => {
            let mut spec = CorpusSpec { seed, ..Default::default() };
            if let Some(v) = t.get("vocab").and_then(|v| v.as_i64()) {
                spec.vocab = v as usize;
            }
            if let Some(v) = t.get("length").and_then(|v| v.as_i64()) {
                spec.length = v as usize;
            }
            Ok(Workload::Tokens(spec))
        }
        "synthetic" => {
            let mut spec = SyntheticSpec::default();
            if let Some(arr) = t.get("shapes").and_then(|v| v.as_arr()) {
                let dims: Vec<usize> =
                    arr.iter().filter_map(|v| v.as_i64()).map(|d| d.max(1) as usize).collect();
                crate::ensure!(
                    dims.len() == arr.len() && !dims.is_empty() && dims.len() % 2 == 0,
                    "synthetic shapes must be a flat, even-length list of integer dims"
                );
                spec.shapes = dims.chunks_exact(2).map(|p| (p[0], p[1])).collect();
            }
            if let Some(v) = t.get("noise").and_then(|v| v.as_f64()) {
                spec.noise = v as f32;
            }
            if let Some(v) = t.get("pace_ms").and_then(|v| v.as_i64()) {
                spec.pace_ms = v.max(0) as u64;
            }
            Ok(Workload::Synthetic(spec))
        }
        other => bail!("unknown workload kind '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let text = r#"
name = "sweep"
steps = 100
workers = 2

[workload]
kind = "cluster"
classes = 16
dim = 64

[[runs]]
model = "res_mlp_c32"
base = "sgdm"
shampoo = "cq-ef"
t1 = 5

[[runs]]
model = "res_mlp_c32"
base = "adamw"
"#;
        let spec = ExperimentSpec::from_toml(text).unwrap();
        assert_eq!(spec.name, "sweep");
        assert_eq!(spec.runs.len(), 2);
        let r0 = &spec.runs[0];
        assert_eq!(r0.steps, 100);
        let sh = r0.optimizer.shampoo.as_ref().unwrap();
        assert_eq!(sh.t1, 5);
        assert_eq!(sh.variant, ShampooVariant::Cq4 { error_feedback: true });
        assert!(spec.runs[1].optimizer.shampoo.is_none());
        match &r0.workload {
            Workload::Cluster(c) => assert_eq!(c.classes, 16),
            _ => panic!("wrong workload"),
        }
    }

    #[test]
    fn parses_synthetic_workload() {
        let text = "\ncheckpoint_every = 25\n\n[workload]\nkind = \"synthetic\"\n\
                    shapes = [16, 8, 8, 8]\nnoise = 0.1\n\n[[runs]]\nmodel = \"synthetic\"\n\
                    shampoo = \"cq-ef\"\n";
        let spec = ExperimentSpec::from_toml(text).unwrap();
        match &spec.runs[0].workload {
            Workload::Synthetic(s) => {
                assert_eq!(s.shapes, vec![(16, 8), (8, 8)]);
                assert_eq!(s.noise, 0.1);
            }
            _ => panic!("wrong workload"),
        }
        assert_eq!(spec.runs[0].checkpoint_every, 25);
        // Identity strings key checkpoints: distinct runs must differ.
        assert!(spec.runs[0].identity().contains("synthetic"));
        // Odd-length shape lists are rejected.
        let bad =
            "\n[workload]\nkind = \"synthetic\"\nshapes = [16, 8, 8]\n\n[[runs]]\nmodel = \"m\"\n";
        assert!(ExperimentSpec::from_toml(bad).is_err());
    }

    #[test]
    fn parses_faults_retries_and_retention() {
        let text = "\nretries = 3\nretry_backoff_ms = 50\nkeep_checkpoints = 4\n\
                    \n[faults]\nseed = 7\nnan_grad_every = 5\nforce_fail_every = 10\n\
                    fail_one_in = 2\nuntil_step = 60\n\
                    \n[[runs]]\nmodel = \"m\"\nshampoo = \"cq-ef\"\n\
                    quarantine_after = 2\nprobation_interval = 9\n";
        let spec = ExperimentSpec::from_toml(text).unwrap();
        assert_eq!(spec.retries, 3);
        assert_eq!(spec.retry_backoff_ms, 50);
        let run = &spec.runs[0];
        assert_eq!(run.keep_checkpoints, 4);
        let fp = run.faults.as_ref().unwrap();
        assert_eq!(fp.seed, 7);
        assert_eq!(fp.nan_grad_every, 5);
        assert_eq!(fp.force_fail_every, 10);
        assert_eq!(fp.fail_one_in, 2);
        assert_eq!(fp.until_step, 60);
        let sh = run.optimizer.shampoo.as_ref().unwrap();
        assert_eq!(sh.quarantine_after, 2);
        assert_eq!(sh.probation_interval, 9);
        // Defaults: no faults, keep everything, 2 retries.
        let plain = ExperimentSpec::from_toml("\n[[runs]]\nmodel = \"m\"\n").unwrap();
        assert!(plain.runs[0].faults.is_none());
        assert_eq!(plain.runs[0].keep_checkpoints, 0);
        assert_eq!(plain.retries, 2);
        // fail_one_in = 0 would divide by zero downstream → parse error.
        let bad = "\n[faults]\nfail_one_in = 0\n\n[[runs]]\nmodel = \"m\"\n";
        assert!(ExperimentSpec::from_toml(bad).is_err());
    }

    #[test]
    fn rejects_bad_variant() {
        let text = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"5bit\"\n";
        assert!(ExperimentSpec::from_toml(text).is_err());
    }

    #[test]
    fn labels_match_paper_style() {
        let o = OptimizerSpec::with_shampoo(
            OptimizerKind::Sgdm,
            OptimizerSpec::paper_hyper(OptimizerKind::Sgdm),
            ShampooConfig { variant: ShampooVariant::Vq4, ..Default::default() },
        );
        assert_eq!(o.label(), "SGDM + 4-bit (VQ) Shampoo");
    }

    #[test]
    fn from_names_builds_any_registered_variant() {
        for key in ["none", "32bit", "vq", "cq", "cq-ef", "bw8", "ours"] {
            let spec = OptimizerSpec::from_names("sgdm", key).unwrap();
            let stack = spec.build(&[(8, 8)]);
            // Spec label (pre-build) and trait name (post-build) must agree.
            assert_eq!(spec.label(), stack.label(), "key '{key}'");
        }
        assert!(OptimizerSpec::from_names("lion", "cq-ef").is_err());
        assert!(OptimizerSpec::from_names("sgdm", "5bit").is_err());
    }

    #[test]
    fn toml_selects_refresh_policy() {
        let text = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"cq-ef\"\n\
                    refresh_policy = \"staggered\"\nrefresh_budget = 3\n";
        let spec = ExperimentSpec::from_toml(text).unwrap();
        let sh = spec.runs[0].optimizer.shampoo.as_ref().unwrap();
        assert_eq!(sh.refresh_policy, "staggered");
        assert_eq!(sh.refresh_budget, 3);
        // Default stays the classic bit-identical policy.
        let plain = ExperimentSpec::from_toml("\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\n")
            .unwrap();
        assert_eq!(plain.runs[0].optimizer.shampoo.as_ref().unwrap().refresh_policy, "every-n");
        // Unknown policies are rejected at parse time.
        let bad = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\nrefresh_policy = \"nope\"\n";
        assert!(ExperimentSpec::from_toml(bad).is_err());
        // A negative budget must error, not wrap into a huge usize.
        let neg = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\nrefresh_budget = -1\n";
        assert!(ExperimentSpec::from_toml(neg).is_err());
    }

    #[test]
    fn toml_selects_async_refresh() {
        let text = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"cq-ef\"\nasync_refresh = true\n\
                    async_shards = 2\nmax_async_staleness = 3\n";
        let spec = ExperimentSpec::from_toml(text).unwrap();
        let sh = spec.runs[0].optimizer.shampoo.as_ref().unwrap();
        assert!(sh.async_refresh);
        assert_eq!(sh.async_shards, 2);
        assert_eq!(sh.max_async_staleness, 3);
        // Default stays synchronous — the bit-identical classic path.
        let plain = ExperimentSpec::from_toml("\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\n")
            .unwrap();
        assert!(!plain.runs[0].optimizer.shampoo.as_ref().unwrap().async_refresh);
        // A zero staleness window would mean "publish before the next step
        // starts" — that is the sync path; reject it rather than alias it.
        let zero = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\nmax_async_staleness = 0\n";
        assert!(ExperimentSpec::from_toml(zero).is_err());
        let neg = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\nasync_shards = -1\n";
        assert!(ExperimentSpec::from_toml(neg).is_err());
    }

    #[test]
    fn toml_selects_workload_knobs() {
        let text = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"cq-ef\"\ngraft = \"adagrad\"\n\
                    start_preconditioning_step = 100\n\
                    no_preconditioning_for_layers_with_dim_gt = 4096\n\
                    shape_interpretation = true\n";
        let spec = ExperimentSpec::from_toml(text).unwrap();
        let sh = spec.runs[0].optimizer.shampoo.as_ref().unwrap();
        assert_eq!(sh.graft, "adagrad");
        assert!(sh.grafting);
        assert_eq!(sh.start_preconditioning_step, 100);
        assert_eq!(sh.no_preconditioning_for_layers_with_dim_gt, 4096);
        assert!(sh.shape_interpretation);
        // `graft = "none"` disables grafting outright (graft_key() → none).
        let off = ExperimentSpec::from_toml(
            "\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\ngraft = \"none\"\n",
        )
        .unwrap();
        let sh = off.runs[0].optimizer.shampoo.as_ref().unwrap();
        assert!(!sh.grafting);
        assert_eq!(sh.graft_key(), "none");
        // Defaults stay the classic Eq. 13 norm graft with no warmup.
        let plain = ExperimentSpec::from_toml("\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\n")
            .unwrap();
        let sh = plain.runs[0].optimizer.shampoo.as_ref().unwrap();
        assert_eq!(sh.graft_key(), "sgd");
        assert_eq!(sh.start_preconditioning_step, 0);
        assert_eq!(sh.no_preconditioning_for_layers_with_dim_gt, 0);
        assert!(!sh.shape_interpretation);
        // Unknown grafts and negative knobs are rejected at parse time.
        let bad = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\ngraft = \"nope\"\n";
        assert!(ExperimentSpec::from_toml(bad).is_err());
        let neg =
            "\n[[runs]]\nmodel = \"m\"\nshampoo = \"vq\"\nstart_preconditioning_step = -1\n";
        assert!(ExperimentSpec::from_toml(neg).is_err());
    }

    #[test]
    fn toml_accepts_bw8() {
        let text = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"bw8\"\n";
        let spec = ExperimentSpec::from_toml(text).unwrap();
        let sh = spec.runs[0].optimizer.shampoo.as_ref().unwrap();
        assert_eq!(sh.variant, ShampooVariant::Bw8);
    }

    #[test]
    fn toml_and_cli_names_reach_the_codec_family_keys() {
        // `ec4`/`f16`/`cq-r1` resolve as stack keys (no ShampooVariant arm)
        // from both entry points: TOML specs (with interval overrides
        // applied) and the `--shampoo` path through `from_names`. Spec
        // resolution must copy the keys' registry codec metadata onto the
        // run config, so the memory model prices the actual representation
        // (an `f16` run costs 2 B/elem, not the placeholder variant's
        // nibbles) and labels name what runs.
        for (key, side, root) in
            [("ec4", "ec4", "ec4"), ("f16", "f16", "f16"), ("cq-r1", "cq-r1", "vq4")]
        {
            let text = format!("\n[[runs]]\nmodel = \"m\"\nshampoo = \"{key}\"\nt1 = 9\n");
            let spec = ExperimentSpec::from_toml(&text).unwrap();
            let opt = &spec.runs[0].optimizer;
            assert_eq!(opt.stack_key(), key);
            let sh = opt.shampoo.as_ref().unwrap();
            assert_eq!(sh.t1, 9);
            assert_eq!(sh.side_codec, Some(side), "TOML spec must carry codec metadata");
            assert_eq!(sh.root_codec, Some(root));
            assert!(opt.label().contains(key), "{}", opt.label());

            let named = OptimizerSpec::from_names("sgdm", key).unwrap();
            assert_eq!(named.stack_key(), key);
            let cfg = named.shampoo.as_ref().unwrap();
            assert_eq!(cfg.side_codec, Some(side));
            assert_eq!(cfg.root_codec, Some(root));
            // Spec label (pre-build) and Optimizer::name (post-build) agree
            // exactly — PR 2's single-naming-source invariant: runner rows
            // and trainer rows for the same run always join.
            let stack = named.build(&[(8, 8)]);
            assert_eq!(named.label(), stack.label(), "key '{key}'");
        }
        let named = OptimizerSpec::from_names("sgdm", "f16").unwrap();
        assert_eq!(named.label(), "SGDM + f16 Shampoo");
        let named = OptimizerSpec::from_names("sgdm", "cq-r1").unwrap();
        assert_eq!(named.label(), "SGDM + cq-r1/vq4 Shampoo");
    }

    #[test]
    fn runtime_registered_stack_reaches_specs_and_toml() {
        use crate::optim::BaseOptimizer;
        use crate::shampoo::Shampoo;

        fn build_custom(
            base: BaseOptimizer,
            cfg: &ShampooConfig,
            shapes: &[(usize, usize)],
        ) -> OptimizerStack {
            let cfg = ShampooConfig { variant: ShampooVariant::Vq4, ..*cfg };
            OptimizerStack::shampoo(Shampoo::new(base, cfg, shapes))
        }
        registry::register(registry::StackBuilder {
            key: "custom-vq",
            summary: "test-only registered stack",
            build: build_custom,
            codecs: None,
        });

        // from_names resolves the registered key…
        let spec = OptimizerSpec::from_names("sgdm", "custom-vq").unwrap();
        assert_eq!(spec.stack_key(), "custom-vq");
        assert!(spec.label().contains("custom-vq"), "{}", spec.label());
        let stack = spec.build(&[(8, 8)]);
        assert!(stack.label().contains("Shampoo"));

        // …and so does a TOML spec, with interval overrides applied.
        let text = "\n[[runs]]\nmodel = \"m\"\nshampoo = \"custom-vq\"\nt1 = 7\n";
        let parsed = ExperimentSpec::from_toml(text).unwrap();
        let opt = &parsed.runs[0].optimizer;
        assert_eq!(opt.stack_key(), "custom-vq");
        assert_eq!(opt.shampoo.as_ref().unwrap().t1, 7);
    }
}

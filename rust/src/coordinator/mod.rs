//! L3 coordinator: experiment specification, scheduling, and execution.
//!
//! The paper's contribution lives in the optimizer (L2/L1-adjacent math),
//! so the coordinator is the framework glue a real training system needs:
//! a declarative run grid (every paper table is one), a panic-isolated
//! worker pool where each worker owns its own PJRT client, a memory-budget
//! gate (reproducing Tab. 6's "Out of GPU Memory" row), the resumable job
//! queue ([`queue`]: periodic checkpointing, streaming JSONL metrics,
//! crash/kill recovery), and result aggregation for the report layer.

pub mod spec;
pub mod runner;
pub mod queue;

pub use queue::{resume_queue, run_queue, MetricsLog};
pub use runner::{run_all, run_all_logged, RunOutcome};
pub use spec::{ExperimentSpec, OptimizerSpec, RunSpec, Workload};

//! Experiment execution: a panic-isolated worker pool where each worker
//! owns its own PJRT client (the client is `Rc`-backed and must not cross
//! threads).

use super::spec::{RunSpec, Workload};
use crate::data::images::ImageDataset;
use crate::data::synthetic::ClusterDataset;
use crate::data::tokens::TokenCorpus;
use crate::metrics::MemoryModel;
use crate::runtime::Runtime;
use crate::train::{train_classifier, train_lm, ClassifierData, RunMetrics, TrainConfig};
use crate::util::pool::{JobResult, Pool};
use std::path::PathBuf;

/// Result of one scheduled run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub id: String,
    pub model: String,
    pub optimizer: String,
    /// Modeled optimizer-state bytes (always available, even for OOM rows).
    pub modeled_bytes: usize,
    /// `None` when the memory gate rejected the run (Tab. 6 OOM row).
    pub metrics: Option<RunMetrics>,
    /// Populated when the run failed (panic or error).
    pub error: Option<String>,
}

impl RunOutcome {
    pub fn is_oom(&self) -> bool {
        self.metrics.is_none() && self.error.is_none()
    }
}

thread_local! {
    /// One Runtime (PJRT client + executable cache) per worker thread:
    /// the client is `Rc`-backed, and reusing it across runs on the same
    /// thread amortizes artifact compilation across a whole table grid.
    static TL_RUNTIME: std::cell::RefCell<Option<(PathBuf, std::rc::Rc<Runtime>)>> =
        const { std::cell::RefCell::new(None) };
}

fn thread_runtime(dir: &PathBuf) -> crate::util::error::Result<std::rc::Rc<Runtime>> {
    TL_RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some((cached_dir, rt)) = slot.as_ref() {
            if cached_dir == dir {
                return Ok(std::rc::Rc::clone(rt));
            }
        }
        let rt = std::rc::Rc::new(Runtime::open(dir)?);
        *slot = Some((dir.clone(), std::rc::Rc::clone(&rt)));
        Ok(rt)
    })
}

/// Execute one run in the current thread (reuses the thread's Runtime).
pub fn run_one(artifact_dir: &PathBuf, spec: &RunSpec) -> crate::util::error::Result<RunOutcome> {
    let rt = thread_runtime(artifact_dir)?;
    let model = rt
        .manifest
        .models
        .get(&spec.model)
        .ok_or_else(|| crate::anyhow!("unknown model '{}'", spec.model))?
        .clone();

    // Memory gate: the modeled footprint stands in for the paper's 80 GB
    // A100 ceiling (DESIGN.md §4).
    let mm = MemoryModel::new(&model.shapes());
    let modeled = mm.total_bytes(
        spec.optimizer.base,
        spec.optimizer.shampoo.as_ref(),
    );
    if let Some(budget) = spec.memory_budget {
        if modeled > budget {
            return Ok(RunOutcome {
                id: spec.id.clone(),
                model: spec.model.clone(),
                optimizer: spec.optimizer.label(),
                modeled_bytes: modeled,
                metrics: None,
                error: None,
            });
        }
    }

    let opt = spec.optimizer.build(&model.shapes());
    let cfg = TrainConfig {
        steps: spec.steps,
        schedule: spec.schedule,
        eval_every: spec.eval_every,
        log_every: spec.log_every,
        seed: spec.seed,
    };

    let metrics = match &spec.workload {
        Workload::Cluster(cs) => {
            let (tr, te) = ClusterDataset::generate(cs);
            let data = ClassifierData::from((&tr, &te));
            train_classifier(&rt, &model, &data, opt, &cfg)?
        }
        Workload::Image(is) => {
            let (tr, te) = ImageDataset::generate(is);
            let data = ClassifierData::from((&tr, &te));
            train_classifier(&rt, &model, &data, opt, &cfg)?
        }
        Workload::Tokens(ts) => {
            let corpus = TokenCorpus::generate(ts);
            train_lm(&rt, &model, &corpus, opt, &cfg)?
        }
    };

    Ok(RunOutcome {
        id: spec.id.clone(),
        model: spec.model.clone(),
        optimizer: spec.optimizer.label(),
        modeled_bytes: modeled,
        metrics: Some(metrics),
        error: None,
    })
}

/// Execute all runs over `workers` threads; failures are isolated per run.
pub fn run_all(specs: &[RunSpec], workers: usize) -> Vec<RunOutcome> {
    let dir = Runtime::artifact_dir();
    let pool = Pool::new(workers.max(1));
    let jobs: Vec<_> = specs
        .iter()
        .cloned()
        .map(|spec| {
            let dir = dir.clone();
            move || match run_one(&dir, &spec) {
                Ok(outcome) => outcome,
                Err(e) => RunOutcome {
                    id: spec.id.clone(),
                    model: spec.model.clone(),
                    optimizer: spec.optimizer.label(),
                    modeled_bytes: 0,
                    metrics: None,
                    error: Some(format!("{e:#}")),
                },
            }
        })
        .collect();
    pool.run(jobs)
        .into_iter()
        .zip(specs.iter())
        .map(|(res, spec)| match res {
            JobResult::Ok(outcome) => outcome,
            JobResult::Panicked(msg) => RunOutcome {
                id: spec.id.clone(),
                model: spec.model.clone(),
                optimizer: spec.optimizer.label(),
                modeled_bytes: 0,
                metrics: None,
                error: Some(format!("worker panicked: {msg}")),
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::OptimizerSpec;
    use crate::data::synthetic::ClusterSpec;
    use crate::optim::OptimizerKind;
    use crate::shampoo::{ShampooConfig, ShampooVariant};

    #[test]
    fn memory_gate_rejects_over_budget() {
        // Use a tiny budget; no artifacts needed because the gate fires
        // before Runtime would execute anything — but Runtime::open is
        // called first, so skip when artifacts are absent.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let opt = OptimizerSpec::with_shampoo(
            OptimizerKind::Sgdm,
            OptimizerSpec::paper_hyper(OptimizerKind::Sgdm),
            ShampooConfig { variant: ShampooVariant::Full32, ..Default::default() },
        );
        let mut spec = RunSpec::new(
            "res_mlp_c32",
            Workload::Cluster(ClusterSpec::default()),
            opt,
            10,
        );
        spec.memory_budget = Some(1); // 1 byte: everything OOMs
        let outcome = run_one(&dir, &spec).unwrap();
        assert!(outcome.is_oom());
        assert!(outcome.modeled_bytes > 0);
    }
}

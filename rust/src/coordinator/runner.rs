//! Experiment execution: a panic-isolated worker pool where each worker
//! owns its own PJRT client (the client is `Rc`-backed and must not cross
//! threads).

use super::queue::MetricsLog;
use super::spec::{RunSpec, Workload};
use crate::data::images::ImageDataset;
use crate::data::synthetic::ClusterDataset;
use crate::data::tokens::TokenCorpus;
use crate::metrics::MemoryModel;
use crate::persist;
use crate::runtime::Runtime;
use crate::train::{
    train_classifier, train_lm, train_synthetic, ClassifierData, RunMetrics, TrainConfig,
};
use crate::util::pool::{JobResult, Pool};
use std::path::PathBuf;
use std::time::Instant;

/// Result of one scheduled run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub id: String,
    pub model: String,
    pub optimizer: String,
    /// Modeled optimizer-state bytes (always available, even for OOM rows).
    pub modeled_bytes: usize,
    /// `None` when the memory gate rejected the run (Tab. 6 OOM row).
    pub metrics: Option<RunMetrics>,
    /// Populated when the run failed (panic or error).
    pub error: Option<String>,
    /// True when the run exhausted its retry budget — a terminal failure
    /// the queue caches (like `oom`) instead of re-attempting on resume.
    pub poisoned: bool,
    /// Wall-clock seconds this scheduling attempt took, as measured by the
    /// scheduler (includes resume-restore time; 0 when the worker panicked
    /// or the outcome was reloaded from a previous queue pass).
    pub wall_secs: f64,
}

impl RunOutcome {
    pub fn is_oom(&self) -> bool {
        self.metrics.is_none() && self.error.is_none()
    }
}

thread_local! {
    /// One Runtime (PJRT client + executable cache) per worker thread:
    /// the client is `Rc`-backed, and reusing it across runs on the same
    /// thread amortizes artifact compilation across a whole table grid.
    static TL_RUNTIME: std::cell::RefCell<Option<(PathBuf, std::rc::Rc<Runtime>)>> =
        const { std::cell::RefCell::new(None) };
}

fn thread_runtime(dir: &PathBuf) -> crate::util::error::Result<std::rc::Rc<Runtime>> {
    TL_RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some((cached_dir, rt)) = slot.as_ref() {
            if cached_dir == dir {
                return Ok(std::rc::Rc::clone(rt));
            }
        }
        let rt = std::rc::Rc::new(Runtime::open(dir)?);
        *slot = Some((dir.clone(), std::rc::Rc::clone(&rt)));
        Ok(rt)
    })
}

/// The [`TrainConfig`] a run spec resolves to, checkpointing included.
fn train_config(spec: &RunSpec) -> TrainConfig {
    TrainConfig {
        steps: spec.steps,
        schedule: spec.schedule,
        eval_every: spec.eval_every,
        log_every: spec.log_every,
        seed: spec.seed,
        checkpoint_every: spec.checkpoint_every,
        checkpoint_dir: spec.out_dir.clone(),
        spec_hash: persist::spec_hash(&spec.identity()),
        faults: spec.faults.clone(),
        keep_checkpoints: spec.keep_checkpoints,
    }
}

/// Execute one run in the current thread (reuses the thread's Runtime).
/// Synthetic workloads run entirely in rust — no PJRT client, no
/// artifacts — so the queue service and CI smoke work on any machine.
pub fn run_one(artifact_dir: &PathBuf, spec: &RunSpec) -> crate::util::error::Result<RunOutcome> {
    if let Workload::Synthetic(ss) = &spec.workload {
        let mm = MemoryModel::new(&ss.shapes);
        let modeled = mm.total_bytes(spec.optimizer.base, spec.optimizer.shampoo.as_ref());
        if let Some(budget) = spec.memory_budget {
            if modeled > budget {
                return Ok(RunOutcome {
                    id: spec.id.clone(),
                    model: spec.model.clone(),
                    optimizer: spec.optimizer.label(),
                    modeled_bytes: modeled,
                    metrics: None,
                    error: None,
                    poisoned: false,
                    wall_secs: 0.0,
                });
            }
        }
        let opt = spec.optimizer.build(&ss.shapes);
        let metrics = train_synthetic(ss, opt, &train_config(spec))?;
        return Ok(RunOutcome {
            id: spec.id.clone(),
            model: spec.model.clone(),
            optimizer: spec.optimizer.label(),
            modeled_bytes: modeled,
            metrics: Some(metrics),
            error: None,
            poisoned: false,
            wall_secs: 0.0,
        });
    }

    let rt = thread_runtime(artifact_dir)?;
    let model = rt
        .manifest
        .models
        .get(&spec.model)
        .ok_or_else(|| crate::anyhow!("unknown model '{}'", spec.model))?
        .clone();

    // Memory gate: the modeled footprint stands in for the paper's 80 GB
    // A100 ceiling (DESIGN.md §4).
    let mm = MemoryModel::new(&model.shapes());
    let modeled = mm.total_bytes(
        spec.optimizer.base,
        spec.optimizer.shampoo.as_ref(),
    );
    if let Some(budget) = spec.memory_budget {
        if modeled > budget {
            return Ok(RunOutcome {
                id: spec.id.clone(),
                model: spec.model.clone(),
                optimizer: spec.optimizer.label(),
                modeled_bytes: modeled,
                metrics: None,
                error: None,
                poisoned: false,
                wall_secs: 0.0,
            });
        }
    }

    let opt = spec.optimizer.build(&model.shapes());
    let cfg = train_config(spec);

    let metrics = match &spec.workload {
        Workload::Cluster(cs) => {
            let (tr, te) = ClusterDataset::generate(cs);
            let data = ClassifierData::from((&tr, &te));
            train_classifier(&rt, &model, &data, opt, &cfg)?
        }
        Workload::Image(is) => {
            let (tr, te) = ImageDataset::generate(is);
            let data = ClassifierData::from((&tr, &te));
            train_classifier(&rt, &model, &data, opt, &cfg)?
        }
        Workload::Tokens(ts) => {
            let corpus = TokenCorpus::generate(ts);
            train_lm(&rt, &model, &corpus, opt, &cfg)?
        }
        Workload::Synthetic(_) => unreachable!("handled before the runtime opens"),
    };

    Ok(RunOutcome {
        id: spec.id.clone(),
        model: spec.model.clone(),
        optimizer: spec.optimizer.label(),
        modeled_bytes: modeled,
        metrics: Some(metrics),
        error: None,
        poisoned: false,
        wall_secs: 0.0,
    })
}

fn failed_outcome(spec: &RunSpec, error: String) -> RunOutcome {
    RunOutcome {
        id: spec.id.clone(),
        model: spec.model.clone(),
        optimizer: spec.optimizer.label(),
        modeled_bytes: 0,
        metrics: None,
        error: Some(error),
        poisoned: false,
        wall_secs: 0.0,
    }
}

/// Execute all runs over `workers` threads; failures are isolated per run.
pub fn run_all(specs: &[RunSpec], workers: usize) -> Vec<RunOutcome> {
    run_all_logged(specs, workers, None)
}

/// [`run_all`] with a live JSONL metrics stream: every run emits a
/// `run_start` event when a worker picks it up and a `run_end` event —
/// wall-clock seconds, outcome, final metric — when it finishes, so an
/// external watcher (or a later `resume`) sees per-run progress without
/// waiting for the whole grid.
pub fn run_all_logged(
    specs: &[RunSpec],
    workers: usize,
    log: Option<&MetricsLog>,
) -> Vec<RunOutcome> {
    let dir = Runtime::artifact_dir();
    let pool = Pool::new(workers.max(1));
    let jobs: Vec<_> = specs
        .iter()
        .cloned()
        .map(|spec| {
            let dir = dir.clone();
            move || {
                if let Some(log) = log {
                    log.run_start(&spec);
                }
                let t0 = Instant::now();
                let mut outcome = match run_one(&dir, &spec) {
                    Ok(outcome) => outcome,
                    Err(e) => failed_outcome(&spec, format!("{e:#}")),
                };
                outcome.wall_secs = t0.elapsed().as_secs_f64();
                if let Some(log) = log {
                    log.run_end(&outcome);
                }
                outcome
            }
        })
        .collect();
    pool.run(jobs)
        .into_iter()
        .zip(specs.iter())
        .map(|(res, spec)| match res {
            JobResult::Ok(outcome) => outcome,
            JobResult::Panicked(msg) => {
                let outcome = failed_outcome(spec, format!("worker panicked: {msg}"));
                if let Some(log) = log {
                    log.run_end(&outcome);
                }
                outcome
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::OptimizerSpec;
    use crate::data::synthetic::ClusterSpec;
    use crate::optim::OptimizerKind;
    use crate::shampoo::{ShampooConfig, ShampooVariant};

    #[test]
    fn memory_gate_rejects_over_budget() {
        // Use a tiny budget; no artifacts needed because the gate fires
        // before Runtime would execute anything — but Runtime::open is
        // called first, so skip when artifacts are absent.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let opt = OptimizerSpec::with_shampoo(
            OptimizerKind::Sgdm,
            OptimizerSpec::paper_hyper(OptimizerKind::Sgdm),
            ShampooConfig { variant: ShampooVariant::Full32, ..Default::default() },
        );
        let mut spec = RunSpec::new(
            "res_mlp_c32",
            Workload::Cluster(ClusterSpec::default()),
            opt,
            10,
        );
        spec.memory_budget = Some(1); // 1 byte: everything OOMs
        let outcome = run_one(&dir, &spec).unwrap();
        assert!(outcome.is_oom());
        assert!(outcome.modeled_bytes > 0);
    }
}

//! Summary statistics used by the bench harness and report tables.

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a sample (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).floor() as usize;
    s[rank.min(s.len() - 1)]
}

/// Histogram with fixed linear bins over `[lo, hi]`; under/overflow clamp to
/// the edge bins. Used for the paper's Fig. 3 eigenvalue-frequency plots.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// (bin_center, count) rows for CSV export.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + w * (i as f64 + 0.5), c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_basics() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.add(x);
        }
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 2.5).abs() < 1e-12);
        assert!((a.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(-5.0);
        h.add(0.55);
        h.add(99.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.total(), 3);
    }
}

//! Tiny CSV writer for figure series and run logs.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    /// Write one row of already-formatted cells.
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(cells.len(), self.cols, "csv row width mismatch");
        writeln!(self.w, "{}", cells.join(","))
    }

    /// Write a row of f64 values.
    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        let cells: Vec<String> = cells.iter().map(|x| format!("{x}")).collect();
        self.row(&cells)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("quartz_csv_test");
        let path = dir.join("x.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row_f64(&[0.0, 2.5]).unwrap();
            w.row(&["1".into(), "2.25".into()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n0,2.5\n1,2.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}

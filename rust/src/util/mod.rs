//! Small self-contained infrastructure: RNG, statistics, JSON/TOML parsing,
//! a scoped thread pool, CSV writing, and in-tree bench / property-test
//! harnesses.
//!
//! This environment builds fully offline against a minimal crate set, so the
//! pieces a production repo would pull from `rand`, `serde_json`, `toml`,
//! `rayon`, `criterion`, and `proptest` are implemented here as first-class
//! substrates (per the reproduction ground rules: build, don't stub).

pub mod bytes;
pub mod error;
pub mod fault;
pub mod rng;
pub mod stats;
pub mod json;
pub mod toml;
pub mod pool;
pub mod csv;
pub mod bench;
pub mod prop;

/// Round `x` to `d` decimal places (for stable table output).
pub fn round_to(x: f64, d: u32) -> f64 {
    let p = 10f64.powi(d as i32);
    (x * p).round() / p
}

/// Human-readable byte count (`1.23 MB` style, decimal units to match the
/// paper's MB/GB figures).
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{} B", bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_to_works() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(-1.005, 1), -1.0);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2_500), "2.5 KB");
        assert_eq!(fmt_bytes(64_800_000), "64.8 MB");
        assert_eq!(fmt_bytes(5_100_000_000), "5.10 GB");
    }
}

//! Little-endian binary serialization primitives for the checkpoint paths.
//!
//! The offline build set has no `serde`/`bincode`, so the `persist`
//! subsystem encodes state through two tiny cursor types: [`ByteWriter`]
//! appends fixed-width little-endian values and length-prefixed slices to a
//! growable buffer; [`ByteReader`] consumes the same layout, failing with a
//! positioned error (never panicking) on truncated or oversized input so a
//! corrupt checkpoint tail surfaces as a recoverable [`Error`]. A
//! table-based CRC-32 ([`crc32`], the IEEE/zlib polynomial) guards whole
//! checkpoint files.
//!
//! Layout conventions used by every consumer:
//! * all integers and floats little-endian, no alignment padding;
//! * slices and strings as a `u64` element count followed by the payload;
//! * `f32` payloads as raw IEEE-754 bits, so quantized state and error
//!   triangles round-trip **bit-exactly** (NaN payloads included).

use super::error::{Error, Result};

/// Append-only little-endian encoder over a growable byte buffer.
#[derive(Debug, Default, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> ByteWriter {
        ByteWriter { buf: Vec::with_capacity(n) }
    }

    /// Finish and take the encoded buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// View of the encoded bytes (for CRC computation before finishing).
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes with a `u64` length prefix.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// f32 slice with a `u64` element-count prefix, raw IEEE bits.
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// u64 slice with a `u64` element-count prefix.
    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x);
        }
    }

    /// UTF-8 string with a `u64` byte-length prefix.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }
}

/// Consuming little-endian decoder over a byte slice.
///
/// Every getter advances the cursor and returns a positioned error instead
/// of panicking when the input is shorter than the requested read — the
/// contract that lets the checkpoint restore path treat a truncated file as
/// recoverable data corruption.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    /// Current cursor position (bytes consumed).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::msg(format!(
                "truncated input: need {n} bytes at offset {}, {} remain",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// `u64` read back as `usize`, rejecting values beyond the platform.
    pub fn get_len(&mut self) -> Result<usize> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| Error::msg(format!("length {v} exceeds usize")))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length-prefixed raw bytes (counterpart of [`ByteWriter::put_bytes`]).
    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_len()?;
        // Bound the declared length by what is actually present so a corrupt
        // prefix cannot trigger a huge allocation before `take` fails.
        if n > self.remaining() {
            return Err(Error::msg(format!(
                "truncated input: declared {n} bytes at offset {}, {} remain",
                self.pos,
                self.remaining()
            )));
        }
        self.take(n)
    }

    /// Length-prefixed f32 slice (counterpart of [`ByteWriter::put_f32s`]).
    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_len()?;
        if n.saturating_mul(4) > self.remaining() {
            return Err(Error::msg(format!(
                "truncated input: declared {n} f32s at offset {}, {} bytes remain",
                self.pos,
                self.remaining()
            )));
        }
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }

    /// Length-prefixed u64 slice (counterpart of [`ByteWriter::put_u64s`]).
    pub fn get_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.get_len()?;
        if n.saturating_mul(8) > self.remaining() {
            return Err(Error::msg(format!(
                "truncated input: declared {n} u64s at offset {}, {} bytes remain",
                self.pos,
                self.remaining()
            )));
        }
        let raw = self.take(n * 8)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(8) {
            out.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }

    /// Length-prefixed UTF-8 string (counterpart of [`ByteWriter::put_str`]).
    pub fn get_str(&mut self) -> Result<String> {
        let raw = self.get_bytes()?;
        String::from_utf8(raw.to_vec()).map_err(|e| Error::msg(format!("invalid utf-8: {e}")))
    }

    /// Error unless the whole buffer was consumed — catches trailing junk
    /// appended to an otherwise valid section.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::msg(format!(
                "{} trailing bytes after offset {}",
                self.remaining(),
                self.pos
            )));
        }
        Ok(())
    }
}

/// CRC-32 lookup table for the IEEE/zlib polynomial (reflected 0xEDB88320).
fn crc_table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3 / zlib) of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_widths() {
        let mut w = ByteWriter::new();
        w.put_u8(0xAB);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f32(-0.0);
        w.put_f64(std::f64::consts::PI);
        w.put_bytes(&[1, 2, 3]);
        w.put_f32s(&[1.5, f32::NAN, -3e7]);
        w.put_u64s(&[7, 8]);
        w.put_str("cq-ef");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 0xAB);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.get_f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        let fs = r.get_f32s().unwrap();
        assert_eq!(fs.len(), 3);
        assert_eq!(fs[0], 1.5);
        assert!(fs[1].is_nan());
        assert_eq!(fs[2], -3e7);
        assert_eq!(r.get_u64s().unwrap(), vec![7, 8]);
        assert_eq!(r.get_str().unwrap(), "cq-ef");
        r.finish().unwrap();
    }

    #[test]
    fn truncated_reads_error_not_panic() {
        let mut w = ByteWriter::new();
        w.put_u64(5);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..6]);
        let e = r.get_u64().unwrap_err();
        assert!(format!("{e}").contains("truncated"), "{e}");
        // Declared slice length past end of buffer.
        let mut w = ByteWriter::new();
        w.put_u64(1_000_000);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_bytes().is_err());
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_f32s().is_err());
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_u64s().is_err());
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u8(2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        r.get_u8().unwrap();
        assert!(r.finish().is_err());
        r.get_u8().unwrap();
        r.finish().unwrap();
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC-32 check values (zlib-compatible).
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn crc32_detects_single_bit_flip() {
        let mut data = vec![0u8; 64];
        let a = crc32(&data);
        data[40] ^= 0x10;
        assert_ne!(a, crc32(&data));
    }
}

//! Minimal JSON parser + writer.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used to read `artifacts/manifest.json` written
//! by `python/compile/aot.py` and to dump run records. Kept dependency-free
//! by design (no `serde` in the offline build set).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\n", "c": true, "d": null, "nested": {"x": 0}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\n");
        assert_eq!(v.get("c"), Some(&Json::Bool(true)));
        let text = v.to_string();
        let v2 = Json::parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_nan_and_inf_literals() {
        // JSON has no non-finite numbers; the metrics/checkpoint emitters
        // must never produce them, and the parser must refuse every common
        // spelling rather than silently accepting one.
        for src in ["NaN", "nan", "Infinity", "-Infinity", "inf", "-inf", "1e", "--1"] {
            assert!(Json::parse(src).is_err(), "accepted {src:?}");
        }
        // A writer handed a non-finite Num emits text that does NOT parse
        // back — the round-trip fails loudly instead of corrupting a value.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = Json::Num(bad).to_string();
            assert!(Json::parse(&text).is_err(), "non-finite {bad} round-tripped as {text:?}");
        }
    }

    #[test]
    fn u64_step_counters_round_trip_exactly() {
        // Step counters ride through Num(f64); every integer with |x| < 2^53
        // is exact in f64, and the writer's i64 fast path (|x| < 1e15) keeps
        // the text form integral. Check the range checkpoints actually use,
        // including the largest exactly-representable boundary cases.
        let steps: [u64; 7] =
            [0, 1, 1_000_000, 4_294_967_296, 999_999_999_999_999, (1 << 53) - 1, 1 << 53];
        for &k in &steps {
            let text = Json::Num(k as f64).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back as u64, k, "step {k} came back as {back}");
            assert_eq!(back.fract(), 0.0, "step {k} lost integrality: {text}");
        }
        // And the negative control: beyond 2^53 adjacent integers collide,
        // which is why checkpoint files store the step as a raw u64, not JSON.
        let k = (1u64 << 53) + 1;
        assert_ne!((k as f64) as u64, k);
    }

    #[test]
    fn truncated_inputs_error_with_position() {
        // Prefixes of a valid record — what a crash mid-append leaves in a
        // JSONL metrics file. Every prefix must fail cleanly, with the byte
        // offset pointing into the input (never past it).
        let full = r#"{"event":"run_end","step":1200,"wall_secs":3.25}"#;
        for cut in 1..full.len() {
            let frag = &full[..cut];
            match Json::parse(frag) {
                Ok(v) => panic!("truncated {frag:?} parsed as {v:?}"),
                Err(e) => assert!(e.pos <= frag.len(), "pos {} past input {}", e.pos, frag.len()),
            }
        }
        // Truncated escape and truncated \u escape inside strings.
        assert!(Json::parse(r#""abc\"#).is_err());
        assert!(Json::parse(r#""abc\u00"#).is_err());
    }
}

//! In-tree property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (seeded case generator); the
//! runner executes `cases` random cases and reports the seed of the first
//! failing case so it can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the xla rpath flags
//! use quartz::util::prop::{run_prop, Gen};
//! run_prop("abs is non-negative", 64, |g: &mut Gen| {
//!     let x = g.f32_in(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use super::rng::Rng;

/// Per-case generator: thin wrapper over [`Rng`] with test-oriented helpers
/// (sizes, shapes, well-conditioned matrices).
pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn f64(&mut self) -> f64 {
        self.rng.uniform()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Vector of N(0, std²) values.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// Vector with a mix of magnitudes (exercises block-wise normalization):
    /// each element is N(0,1) scaled by 10^U(-scale_range, scale_range).
    pub fn wide_range_vec(&mut self, n: usize, scale_range: f32) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let e = self.rng.uniform_in(-scale_range, scale_range);
                self.rng.normal_f32(1.0) * 10f32.powf(e)
            })
            .collect()
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing test)
/// with the case seed if any case panics.
pub fn run_prop<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    // Base seed is stable by default; override for fuzzing sessions.
    let base = std::env::var("QUARTZ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5EED_CAFE);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(seed) };
            prop(&mut g);
        });
        if let Err(p) = result {
            let msg = if let Some(s) = p.downcast_ref::<&str>() {
                s.to_string()
            } else if let Some(s) = p.downcast_ref::<String>() {
                s.clone()
            } else {
                "unknown panic".into()
            };
            panic!(
                "property '{name}' failed on case {case} (replay with QUARTZ_PROP_SEED={base}): {msg}"
            );
        }
    }
}

/// Replay a single seed (used in regression tests once a failure is found).
pub fn replay<F: Fn(&mut Gen)>(seed: u64, prop: F) {
    let mut g = Gen { rng: Rng::new(seed) };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run_prop("sum is commutative", 64, |g| {
            let a = g.f32_in(-100.0, 100.0);
            let b = g.f32_in(-100.0, 100.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        run_prop("always fails", 8, |_g| {
            panic!("nope");
        });
    }

    #[test]
    fn wide_range_vec_has_dynamic_range() {
        let mut g = Gen { rng: Rng::new(1) };
        let v = g.wide_range_vec(1000, 3.0);
        let max = v.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
        let min_nonzero = v
            .iter()
            .map(|x| x.abs())
            .filter(|&x| x > 0.0)
            .fold(f32::INFINITY, f32::min);
        assert!(max / min_nonzero > 1e2, "dynamic range too small");
    }
}

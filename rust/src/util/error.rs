//! Minimal `anyhow`-style error handling (the offline build set has no
//! `anyhow`/`thiserror`, per the repo's dependency-free ground rules).
//!
//! Provides:
//! * [`Error`] — an opaque, context-carrying application error.
//! * [`Result`] — `Result<T, Error>` alias with a default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, mirroring anyhow's ergonomics.
//! * [`crate::anyhow!`], [`crate::bail!`], [`crate::ensure!`] — the familiar
//!   formatting macros (exported at the crate root).
//!
//! Like anyhow's `Error`, this type deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent, so `?` works on
//! any std-error result inside functions returning [`Result`].

use std::fmt;

/// An application error: a root message plus a stack of context frames
/// (outermost first, like anyhow's `{:#}` rendering).
pub struct Error {
    /// Context frames, outermost last (pushed as the error bubbles up).
    frames: Vec<String>,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { frames: vec![m.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.frames.push(c.to_string());
        self
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.frames.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first, then the chain down to the root cause.
        for (i, frame) in self.frames.iter().rev().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{frame}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold the std source chain into the frame stack (innermost first).
        let mut frames = Vec::new();
        frames.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            frames.insert(0, s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, anyhow-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: Result<()> = Err(io_err()).context("loading manifest");
        let msg = format!("{}", r.unwrap_err());
        assert!(msg.starts_with("loading manifest:"), "{msg}");
        assert!(msg.contains("file missing"), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let bytes = [0xFFu8];
            let s = std::str::from_utf8(&bytes)?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_format() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(50).unwrap_err()), "x too big: 50");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = crate::anyhow!("code {}", 7);
        assert_eq!(e.root_cause(), "code 7");
    }

    #[test]
    fn alternate_format_matches_display() {
        let e = Error::msg("root").context("outer");
        assert_eq!(format!("{e:#}"), format!("{e}"));
        assert_eq!(format!("{e:?}"), "outer: root");
    }
}

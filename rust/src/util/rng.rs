//! Deterministic pseudo-random number generation.
//!
//! `Rng` is a SplitMix64-seeded xoshiro256++ generator: fast, high quality,
//! and reproducible across platforms — every dataset, initialization, and
//! property test in the repo is seeded through it so experiment tables are
//! exactly re-runnable.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Snapshot the full generator state (the four xoshiro256++ words).
    ///
    /// Together with [`Rng::from_state`] this lets a checkpoint continue
    /// the *exact* stream instead of reseeding: restoring the snapshot and
    /// drawing is indistinguishable from never having stopped.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Standard normal as f32, scaled.
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        self.normal() as f32 * std
    }

    /// Fill a slice with N(0, std²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(std);
        }
    }

    /// Sample an index from an unnormalized non-negative weight vector.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut r = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w as f64;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_sampling_respects_zero_weight() {
        let mut r = Rng::new(13);
        for _ in 0..200 {
            let i = r.sample_weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn state_snapshot_resumes_exact_stream() {
        let mut r = Rng::new(17);
        for _ in 0..257 {
            r.next_u64();
        }
        let snap = r.state();
        let tail: Vec<u64> = (0..64).map(|_| r.next_u64()).collect();
        let mut resumed = Rng::from_state(snap);
        let tail2: Vec<u64> = (0..64).map(|_| resumed.next_u64()).collect();
        assert_eq!(tail, tail2, "restored stream must continue bit-exactly");
    }

    #[test]
    fn state_round_trips_through_serialization_shape() {
        // The checkpoint stores the four words verbatim; any permutation or
        // truncation would diverge immediately.
        let mut r = Rng::new(23);
        r.normal();
        let snap = r.state();
        assert_eq!(Rng::from_state(snap).state(), snap);
        let mut a = Rng::from_state(snap);
        let mut b = r.clone();
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

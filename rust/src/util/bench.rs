//! In-tree micro-benchmark harness (criterion is unavailable in the offline
//! build set, so `cargo bench` targets use this with `harness = false`).
//!
//! Methodology mirrors criterion's core loop: warmup, then timed batches
//! sized so one batch is ≳1 ms, reporting mean / std / p50 / p99 per
//! iteration plus derived throughput. Output is stable, grep-friendly text.

use super::stats::{percentile, Accumulator};
use std::time::{Duration, Instant};

/// One benchmark's collected timings.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Optional work units per iteration for throughput (e.g. bytes, flops).
    pub units_per_iter: Option<(f64, &'static str)>,
}

impl BenchReport {
    pub fn print(&self) {
        let thr = match self.units_per_iter {
            Some((units, label)) => {
                let per_sec = units / (self.mean_ns / 1e9);
                format!("  {:>10}/s", fmt_si(per_sec, label))
            }
            None => String::new(),
        };
        println!(
            "bench {:<44} {:>12}  ±{:>9}  p50 {:>10}  p99 {:>10}  ({} iters){}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters,
            thr
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{:.0} ns", ns)
    }
}

fn fmt_si(x: f64, label: &str) -> String {
    if x >= 1e9 {
        format!("{:.2} G{label}", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M{label}", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} K{label}", x / 1e3)
    } else {
        format!("{x:.2} {label}")
    }
}

/// Benchmark runner; construct once per bench binary.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    max_batches: usize,
    /// Quick mode (QUARTZ_BENCH_QUICK=1) shrinks times for CI smoke runs.
    pub reports: Vec<BenchReport>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        let quick = std::env::var("QUARTZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        if quick {
            Bencher {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(80),
                max_batches: 20,
                reports: Vec::new(),
            }
        } else {
            Bencher {
                warmup: Duration::from_millis(300),
                measure: Duration::from_millis(1500),
                max_batches: 200,
                reports: Vec::new(),
            }
        }
    }

    /// Time `f`, which performs ONE iteration of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchReport {
        self.bench_with_units(name, None, f)
    }

    /// Time `f` and report throughput given `units` of work per iteration.
    pub fn bench_with_units<F: FnMut()>(
        &mut self,
        name: &str,
        units_per_iter: Option<(f64, &'static str)>,
        mut f: F,
    ) -> &BenchReport {
        // Warmup + batch size calibration: target ≳1ms per batch.
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.warmup || calib_iters == 0 {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters as f64;
        let batch = ((1e6 / per_iter).ceil() as u64).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let mut acc = Accumulator::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_batches {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            acc.add(ns);
            total_iters += batch;
        }

        let report = BenchReport {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: acc.mean(),
            std_ns: acc.std(),
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            units_per_iter,
        };
        report.print();
        append_json_record(&report);
        self.reports.push(report);
        self.reports.last().unwrap()
    }
}

/// When `QUARTZ_BENCH_JSON=<path>` is set, append one JSON object per
/// report as a line to that file (JSONL). `scripts/harvest_bench.sh`
/// assembles these into `BENCH_quartz.json` for the perf trajectory.
fn append_json_record(r: &BenchReport) {
    let Ok(path) = std::env::var("QUARTZ_BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    use std::io::Write as _;
    let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) else {
        return;
    };
    // Bench names are plain ASCII identifiers (letters, digits, /x_.-); a
    // replace guard keeps the output valid JSON regardless.
    let name = r.name.replace(['"', '\\'], "_");
    // One write(2) per record: O_APPEND appends are atomic per syscall, so
    // concurrent bench processes sharing the file cannot tear a line.
    let record = format!(
        "{{\"name\":\"{}\",\"mean_ns\":{:.1},\"std_ns\":{:.1},\"p50_ns\":{:.1},\"p99_ns\":{:.1},\"iters\":{}}}\n",
        name, r.mean_ns, r.std_ns, r.p50_ns, r.p99_ns, r.iters
    );
    let _ = f.write_all(record.as_bytes());
}

/// Prevent the optimizer from eliding a computed value (ptr read/write
/// barrier, same trick as criterion's `black_box` pre-std).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both tests below mutate process-wide env vars the harness reads;
    /// serialize them so parallel test threads never observe each other's
    /// transient state.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn bench_runs_quickly_in_quick_mode() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("QUARTZ_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        std::env::remove_var("QUARTZ_BENCH_QUICK");
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn bench_emits_json_records_when_asked() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("QUARTZ_BENCH_QUICK", "1");
        let path = std::env::temp_dir().join(format!("quartz_bench_{}.jsonl", std::process::id()));
        std::fs::remove_file(&path).ok();
        std::env::set_var("QUARTZ_BENCH_JSON", &path);
        let mut b = Bencher::new();
        let mut acc = 0u64;
        b.bench("json-hook-probe", || {
            acc = black_box(acc.wrapping_add(1));
        });
        std::env::remove_var("QUARTZ_BENCH_JSON");
        std::env::remove_var("QUARTZ_BENCH_QUICK");
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text
            .lines()
            .find(|l| l.contains("json-hook-probe"))
            .expect("record for this bench");
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"mean_ns\":"), "{line}");
        std::fs::remove_file(&path).ok();
    }
}

//! Minimal TOML-subset parser for experiment configuration files.
//!
//! Supports the subset the coordinator's `.toml` specs use: `[table]` and
//! `[[array-of-tables]]` headers, `key = value` with strings, integers,
//! floats, booleans, and flat arrays, plus `#` comments. Nested inline
//! tables are intentionally out of scope.

use std::collections::BTreeMap;

/// A TOML value (subset).
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// One table (section) of key/value pairs.
pub type TomlTable = BTreeMap<String, TomlValue>;

/// A parsed document: the root table, named tables, and arrays of tables.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub root: TomlTable,
    pub tables: BTreeMap<String, TomlTable>,
    pub table_arrays: BTreeMap<String, Vec<TomlTable>>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        // Which table keys are currently written into.
        enum Target {
            Root,
            Table(String),
            ArrayElem(String),
        }
        let mut target = Target::Root;

        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };

            if let Some(name) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
                let name = name.trim().to_string();
                doc.table_arrays.entry(name.clone()).or_default().push(TomlTable::new());
                target = Target::ArrayElem(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                let name = name.trim().to_string();
                doc.tables.entry(name.clone()).or_default();
                target = Target::Table(name);
            } else {
                let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
                let key = line[..eq].trim().trim_matches('"').to_string();
                let val = parse_value(line[eq + 1..].trim())
                    .map_err(|m| err(&m))?;
                let table = match &target {
                    Target::Root => &mut doc.root,
                    Target::Table(n) => doc.tables.get_mut(n).unwrap(),
                    Target::ArrayElem(n) => {
                        doc.table_arrays.get_mut(n).unwrap().last_mut().unwrap()
                    }
                };
                table.insert(key, val);
            }
        }
        Ok(doc)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(TomlValue::Arr(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Split on top-level commas (not inside nested brackets or strings).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_doc() {
        let src = r#"
# experiment spec
name = "tab3"
seed = 42
lr = 1.5e-3
verbose = true
dims = [64, 128, 256]

[model]
kind = "mlp"
width = 128

[[runs]]
optimizer = "sgdm"

[[runs]]
optimizer = "shampoo-cq-ef"  # ours
"#;
        let doc = TomlDoc::parse(src).unwrap();
        assert_eq!(doc.root["name"].as_str(), Some("tab3"));
        assert_eq!(doc.root["seed"].as_i64(), Some(42));
        assert!((doc.root["lr"].as_f64().unwrap() - 1.5e-3).abs() < 1e-12);
        assert_eq!(doc.root["verbose"].as_bool(), Some(true));
        assert_eq!(doc.root["dims"].as_arr().unwrap().len(), 3);
        assert_eq!(doc.tables["model"]["kind"].as_str(), Some("mlp"));
        let runs = &doc.table_arrays["runs"];
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[1]["optimizer"].as_str(), Some("shampoo-cq-ef"));
    }

    #[test]
    fn comments_and_strings() {
        let doc = TomlDoc::parse("s = \"a # not comment\" # real comment").unwrap();
        assert_eq!(doc.root["s"].as_str(), Some("a # not comment"));
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("m = [[1, 2], [3, 4]]").unwrap();
        let outer = doc.root["m"].as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_arr().unwrap()[1].as_i64(), Some(2));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
    }
}

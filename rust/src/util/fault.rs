//! Deterministic fault injection for the numerical-health guard engine.
//!
//! A [`FaultPlan`] is a *pure schedule*: every query is a function of the
//! plan's seed, the step number, and (for unit-level faults) the unit
//! coordinates — no global RNG stream is consumed, so injecting faults
//! never perturbs the trainer's own `seed ^ 0xBA7C` draw sequence and a
//! resumed run replays the exact same faults. Tests and the chaos smoke
//! compute the *expected* health counters by replaying these same pure
//! functions against the known refresh cadence.
//!
//! Fault kinds:
//! * NaN / Inf gradient injection ([`FaultPlan::corrupt_grads`]) — one
//!   element of one layer, both chosen by a seeded hash of the step.
//! * Forced factorization failure ([`FaultPlan::forces_root_failure`]) —
//!   the refresh executor treats the chosen units' root refresh as failed,
//!   driving the fallback ladder / quarantine machinery.
//! * Checkpoint bit-flips ([`FaultPlan::flips_checkpoint`]) — one bit of
//!   the just-written snapshot, exercising the CRC fallback scan.

use crate::linalg::Matrix;

/// Which non-finite value a gradient fault injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Nan,
    Inf,
}

/// A seeded, fully deterministic fault schedule. All `*_every` cadences are
/// step-periodic (0 disables that fault kind); `until_step` bounds the
/// whole plan so soak tests can stop injecting and verify recovery.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Hash seed for element / unit / bit-position choices.
    pub seed: u64,
    /// Inject a NaN gradient element every N steps (0 = never).
    pub nan_grad_every: u64,
    /// Inject an Inf gradient element every N steps (0 = never; NaN wins
    /// when both cadences hit the same step).
    pub inf_grad_every: u64,
    /// Force root-refresh failure every N steps (0 = never).
    pub force_fail_every: u64,
    /// On a forced-failure step, fail roughly one unit in N (1 = every
    /// unit; chosen by a seeded hash of the unit coordinates).
    pub fail_one_in: u64,
    /// Flip one bit of the checkpoint written at every N-th step (0 = never).
    pub ckpt_flip_every: u64,
    /// Last step (inclusive) at which any fault fires; 0 = no bound.
    pub until_step: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            nan_grad_every: 0,
            inf_grad_every: 0,
            force_fail_every: 0,
            fail_one_in: 1,
            ckpt_flip_every: 0,
            until_step: 0,
        }
    }
}

/// SplitMix64 finalizer — the hash behind every deterministic choice.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl FaultPlan {
    /// Whether any fault may fire at `step`.
    pub fn active(&self, step: u64) -> bool {
        self.until_step == 0 || step <= self.until_step
    }

    fn hash(&self, step: u64, salt: u64) -> u64 {
        mix(self.seed ^ mix(step) ^ mix(salt))
    }

    /// The gradient fault scheduled for `step`, if any.
    pub fn grad_fault(&self, step: u64) -> Option<FaultKind> {
        if !self.active(step) || step == 0 {
            return None;
        }
        if self.nan_grad_every > 0 && step % self.nan_grad_every == 0 {
            return Some(FaultKind::Nan);
        }
        if self.inf_grad_every > 0 && step % self.inf_grad_every == 0 {
            return Some(FaultKind::Inf);
        }
        None
    }

    /// The (layer, element) a step's gradient fault poisons — pure so the
    /// soak tests can predict exactly which layer absorbs each fault.
    pub fn grad_target(&self, step: u64, n_layers: usize) -> Option<usize> {
        if n_layers == 0 {
            return None;
        }
        self.grad_fault(step).map(|_| (self.hash(step, 0x6AD) as usize) % n_layers)
    }

    /// Overwrite one element of one gradient with the scheduled non-finite
    /// value (no-op when `step` has no gradient fault).
    pub fn corrupt_grads(&self, step: u64, grads: &mut [Matrix]) {
        let Some(kind) = self.grad_fault(step) else { return };
        let Some(layer) = self.grad_target(step, grads.len()) else { return };
        let g = &mut grads[layer];
        let n = g.rows() * g.cols();
        if n == 0 {
            return;
        }
        let idx = (self.hash(step, 0xE1E) as usize) % n;
        g.data_mut()[idx] = match kind {
            FaultKind::Nan => f32::NAN,
            FaultKind::Inf => f32::INFINITY,
        };
    }

    /// Whether the refresh of unit `(layer, block, side)` at `step` must be
    /// treated as a failed factorization.
    pub fn forces_root_failure(&self, step: u64, layer: u32, block: u32, side: usize) -> bool {
        if self.force_fail_every == 0 || !self.active(step) || step == 0 {
            return false;
        }
        if step % self.force_fail_every != 0 {
            return false;
        }
        let one_in = self.fail_one_in.max(1);
        let unit = ((layer as u64) << 40) | ((block as u64) << 8) | side as u64;
        self.hash(step, unit) % one_in == 0
    }

    /// Whether the checkpoint written after `step` gets one bit flipped.
    pub fn flips_checkpoint(&self, step: u64) -> bool {
        self.ckpt_flip_every > 0
            && self.active(step)
            && step > 0
            && step % self.ckpt_flip_every == 0
    }

    /// Which bit of a `len`-byte checkpoint file to flip (byte · 8 + bit).
    pub fn flip_position(&self, step: u64, len: usize) -> (usize, u8) {
        let h = self.hash(step, 0xF11);
        let byte = if len == 0 { 0 } else { (h as usize) % len };
        (byte, 1u8 << ((h >> 32) & 7))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan {
            seed: 7,
            nan_grad_every: 6,
            inf_grad_every: 4,
            force_fail_every: 5,
            ckpt_flip_every: 10,
            until_step: 20,
            ..Default::default()
        }
    }

    #[test]
    fn schedule_is_pure_and_bounded() {
        let p = plan();
        assert_eq!(p.grad_fault(6), Some(FaultKind::Nan));
        assert_eq!(p.grad_fault(4), Some(FaultKind::Inf));
        // NaN cadence wins when both hit the same step.
        assert_eq!(p.grad_fault(12), Some(FaultKind::Nan));
        assert_eq!(p.grad_fault(5), None);
        // Nothing fires past the bound, and step 0 never faults.
        assert_eq!(p.grad_fault(24), None);
        assert_eq!(p.grad_fault(0), None);
        assert!(!p.forces_root_failure(25, 0, 0, 0));
        assert!(!p.flips_checkpoint(30));
        assert!(p.flips_checkpoint(10));
        // Same inputs, same answers — replayable by tests.
        for step in 0..30 {
            assert_eq!(p.grad_fault(step), plan().grad_fault(step));
            assert_eq!(
                p.forces_root_failure(step, 1, 2, 1),
                plan().forces_root_failure(step, 1, 2, 1)
            );
        }
    }

    #[test]
    fn corrupt_grads_poisons_exactly_one_element() {
        let p = plan();
        let mut grads = vec![Matrix::zeros(4, 3), Matrix::zeros(2, 2)];
        p.corrupt_grads(6, &mut grads);
        let bad: usize = grads.iter().map(|g| g.data().iter().filter(|x| x.is_nan()).count()).sum();
        assert_eq!(bad, 1, "exactly one NaN injected");
        let target = p.grad_target(6, 2).unwrap();
        assert!(grads[target].has_non_finite());
        // A no-fault step leaves gradients untouched.
        let mut clean = vec![Matrix::zeros(4, 3)];
        p.corrupt_grads(5, &mut clean);
        assert!(!clean[0].has_non_finite());
    }

    #[test]
    fn unit_selection_respects_fail_one_in() {
        let every = FaultPlan { force_fail_every: 1, ..FaultPlan::default() };
        let every = FaultPlan { seed: 3, ..every };
        for step in 1..10 {
            assert!(every.forces_root_failure(step, 0, 0, 0), "fail_one_in=1 fails every unit");
        }
        let sparse = FaultPlan { fail_one_in: 4, ..every.clone() };
        let hits = (1..200u64)
            .filter(|&s| sparse.forces_root_failure(s, 0, 0, 0))
            .count();
        assert!(hits > 10 && hits < 120, "roughly 1-in-4 selection, got {hits}/199");
    }

    #[test]
    fn flip_position_is_in_range() {
        let p = plan();
        for len in [1usize, 7, 4096] {
            let (byte, bit) = p.flip_position(10, len);
            assert!(byte < len);
            assert_eq!(bit.count_ones(), 1);
        }
    }
}

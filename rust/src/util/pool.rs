//! A small scoped thread pool built on `std::thread::scope` (the offline
//! build set has no `rayon` or `crossbeam`; std scoped threads, stable since
//! 1.63, give the same borrow-friendly fork/join shape with zero deps).
//!
//! Two entry points:
//! * [`parallel_for`] — split an index range over worker threads (used by the
//!   blocked matmul and block-wise quantizers).
//! * [`Pool`] — a persistent FIFO job queue used by the coordinator to run
//!   experiment jobs concurrently with panic isolation.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Run `f(i)` for every `i in 0..n`, distributing chunks over up to
/// `threads` scoped workers. `f` must be `Sync`; iteration order within a
/// chunk is ascending. Falls back to inline execution for tiny ranges.
///
/// A panic in `f` propagates out of this call when the scope joins the
/// worker that hit it (other workers drain their remaining chunks first).
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    // Chunked dynamic scheduling: grab `chunk` indices at a time.
    let chunk = (n / (threads * 4)).max(1);
    let f = &f;
    let counter = &counter;
    thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(move || loop {
                    let start = counter.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    for i in start..(start + chunk).min(n) {
                        f(i);
                    }
                })
            })
            .collect();
        // Join explicitly so a worker's panic payload (not the scope's
        // generic "a scoped thread panicked") reaches the caller.
        for h in handles {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
    });
}

/// Outcome of a pool job.
#[derive(Debug)]
pub enum JobResult<T> {
    Ok(T),
    Panicked(String),
}

/// Persistent thread pool executing boxed jobs; results are collected in
/// completion order with their submission index. Worker panics are caught
/// and surfaced as [`JobResult::Panicked`] so one bad experiment cannot take
/// down a whole table run.
pub struct Pool {
    threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        Pool { threads: threads.max(1) }
    }

    /// Run all `jobs`, returning results ordered by submission index.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<JobResult<T>>
    where
        T: Send,
        F: FnOnce() -> T + Send + std::panic::UnwindSafe,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let queue = Arc::new(Mutex::new(
            jobs.into_iter().enumerate().collect::<Vec<(usize, F)>>(),
        ));
        let (tx, rx) = mpsc::channel::<(usize, JobResult<T>)>();

        thread::scope(|s| {
            for _ in 0..self.threads.min(n) {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                s.spawn(move || loop {
                    // The lock guard is dropped before the job runs, so a
                    // panicking job can never poison the queue mutex.
                    let job = queue.lock().unwrap().pop();
                    let Some((idx, f)) = job else { break };
                    let res = match std::panic::catch_unwind(f) {
                        Ok(v) => JobResult::Ok(v),
                        Err(p) => JobResult::Panicked(panic_msg(p.as_ref())),
                    };
                    if tx.send((idx, res)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<JobResult<T>>> = (0..n).map(|_| None).collect();
            for (idx, res) in rx {
                out[idx] = Some(res);
            }
            out.into_iter().map(|r| r.expect("job result missing")).collect()
        })
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread() {
        let sum = AtomicU64::new(0);
        parallel_for(100, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn parallel_for_zero_items_is_noop() {
        let calls = AtomicUsize::new(0);
        parallel_for(0, 8, |_| {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn parallel_for_single_item_runs_inline() {
        let calls = AtomicUsize::new(0);
        parallel_for(1, 8, |i| {
            assert_eq!(i, 0);
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_for_more_threads_than_items() {
        // threads is clamped to n; every index must still run exactly once.
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(5, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_zero_threads_is_clamped() {
        let sum = AtomicU64::new(0);
        parallel_for(10, 0, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    #[should_panic(expected = "boom at 3")]
    fn parallel_for_propagates_worker_panic() {
        parallel_for(8, 4, |i| {
            if i == 3 {
                panic!("boom at 3");
            }
        });
    }

    #[test]
    fn pool_preserves_order() {
        let pool = Pool::new(4);
        let jobs: Vec<_> = (0..32usize).map(|i| move || i * i).collect();
        let results = pool.run(jobs);
        for (i, r) in results.iter().enumerate() {
            match r {
                JobResult::Ok(v) => assert_eq!(*v, i * i),
                JobResult::Panicked(m) => panic!("unexpected panic: {m}"),
            }
        }
    }

    #[test]
    fn pool_zero_jobs() {
        let pool = Pool::new(4);
        let jobs: Vec<fn() -> usize> = Vec::new();
        assert!(pool.run(jobs).is_empty());
    }

    #[test]
    fn pool_more_threads_than_jobs() {
        let pool = Pool::new(16);
        let jobs: Vec<_> = (0..3usize).map(|i| move || i + 10).collect();
        let results = pool.run(jobs);
        assert_eq!(results.len(), 3);
        for (i, r) in results.iter().enumerate() {
            assert!(matches!(r, JobResult::Ok(v) if *v == i + 10));
        }
    }

    #[test]
    fn pool_isolates_panics() {
        let pool = Pool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send + std::panic::UnwindSafe>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let results = pool.run(jobs);
        assert!(matches!(results[0], JobResult::Ok(1)));
        assert!(matches!(results[1], JobResult::Panicked(ref m) if m.contains("boom")));
        assert!(matches!(results[2], JobResult::Ok(3)));
    }

    #[test]
    fn pool_survives_repeated_panicking_batches() {
        // The queue mutex must not be poisoned by panicking jobs; the same
        // Pool value must keep working across batches.
        let pool = Pool::new(3);
        for round in 0..3u32 {
            let jobs: Vec<Box<dyn FnOnce() -> u32 + Send + std::panic::UnwindSafe>> = (0..6)
                .map(|i| {
                    let f: Box<dyn FnOnce() -> u32 + Send + std::panic::UnwindSafe> =
                        if i % 2 == 0 {
                            Box::new(move || panic!("round {round} job {i}"))
                        } else {
                            Box::new(move || round * 100 + i)
                        };
                    f
                })
                .collect();
            let results = pool.run(jobs);
            for (i, r) in results.iter().enumerate() {
                if i % 2 == 0 {
                    assert!(matches!(r, JobResult::Panicked(_)));
                } else {
                    assert!(matches!(r, JobResult::Ok(v) if *v == round * 100 + i as u32));
                }
            }
        }
    }
}

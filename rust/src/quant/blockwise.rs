//! Block-wise b-bit quantization (paper Sec. 3.2).
//!
//! A matrix is tiled into `B×B` blocks; each block is normalized by its
//! absmax `N_p` and every element is mapped to the nearest codebook level
//! (Eq. 3). Dequantization is `N_p · M(q)`. Block-wise normalization
//! contains outliers to their own block, which is the reason the paper can
//! push preconditioners to 4 bits at all.

use super::mapping::{Codebook, Mapping};
use super::packed::PackedNibbles;
use crate::linalg::Matrix;

/// Quantizer configuration (paper defaults: b=4, B=64, linear-2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    pub bits: u32,
    pub block: usize,
    pub mapping: Mapping,
    /// Tensors with fewer elements than this stay in f32 (App. C.3 uses 4096).
    pub min_quant_elems: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { bits: 4, block: 64, mapping: Mapping::Linear2, min_quant_elems: 4096 }
    }
}

/// Physical code storage: nibble-packed for `b ≤ 4`, one byte per code
/// above (the 8-bit codecs store one code per byte; no packing needed).
#[derive(Clone, Debug, PartialEq)]
pub enum CodeStore {
    Nibbles(PackedNibbles),
    Bytes(Vec<u8>),
}

impl CodeStore {
    /// Zero-initialized storage for `len` codes of width `bits`.
    pub fn zeros(len: usize, bits: u32) -> CodeStore {
        if bits <= 4 {
            CodeStore::Nibbles(PackedNibbles::zeros(len))
        } else {
            CodeStore::Bytes(vec![0u8; len])
        }
    }

    /// Code at index `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        match self {
            CodeStore::Nibbles(p) => p.get(i),
            CodeStore::Bytes(v) => v[i],
        }
    }

    /// Store code `c` at index `i`.
    #[inline]
    pub fn set(&mut self, i: usize, c: u8) {
        match self {
            CodeStore::Nibbles(p) => p.set(i, c),
            CodeStore::Bytes(v) => v[i] = c,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            CodeStore::Nibbles(p) => p.len(),
            CodeStore::Bytes(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Physical storage bytes (what the memory accountant counts).
    pub fn size_bytes(&self) -> usize {
        match self {
            CodeStore::Nibbles(p) => p.size_bytes(),
            CodeStore::Bytes(v) => v.len(),
        }
    }
}

/// A block-quantized matrix: packed codes + per-block scales.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub bits: u32,
    pub mapping: Mapping,
    /// Row-major packed codes (same element order as the source matrix).
    pub codes: CodeStore,
    /// Per-block normalization factors `N_p`, blocks in row-major block order.
    pub scales: Vec<f32>,
}

/// Stateless quantize/dequantize engine with a precomputed codebook.
#[derive(Clone, Debug)]
pub struct BlockQuantizer {
    pub cfg: QuantConfig,
    codebook: Codebook,
}

impl BlockQuantizer {
    pub fn new(cfg: QuantConfig) -> BlockQuantizer {
        BlockQuantizer { cfg, codebook: Codebook::new(cfg.mapping, cfg.bits) }
    }

    pub fn codebook(&self) -> &Codebook {
        &self.codebook
    }

    /// Quantize `x` block-wise (Eq. 3). All-zero blocks get scale 0.
    pub fn quantize(&self, x: &Matrix) -> QuantizedMatrix {
        let (m, n) = (x.rows(), x.cols());
        let b = self.cfg.block.max(1);
        let bm = m.div_ceil(b);
        let bn = n.div_ceil(b);
        let mut scales = vec![0.0f32; bm * bn];
        let mut codes = CodeStore::zeros(m * n, self.cfg.bits);

        let zero_code = self.codebook.encode(0.0);
        for bi in 0..bm {
            for bj in 0..bn {
                let r0 = bi * b;
                let c0 = bj * b;
                let r1 = (r0 + b).min(m);
                let c1 = (c0 + b).min(n);
                // absmax of the block
                let mut amax = 0.0f32;
                for i in r0..r1 {
                    for &v in &x.row(i)[c0..c1] {
                        amax = amax.max(v.abs());
                    }
                }
                scales[bi * bn + bj] = amax;
                if amax == 0.0 {
                    for i in r0..r1 {
                        for j in c0..c1 {
                            codes.set(i * n + j, zero_code);
                        }
                    }
                    continue;
                }
                let inv = 1.0 / amax;
                for i in r0..r1 {
                    let row = x.row(i);
                    for j in c0..c1 {
                        codes.set(i * n + j, self.codebook.encode(row[j] * inv));
                    }
                }
            }
        }

        QuantizedMatrix {
            rows: m,
            cols: n,
            block: b,
            bits: self.cfg.bits,
            mapping: self.cfg.mapping,
            codes,
            scales,
        }
    }

    /// Dequantize back to f32 (`D` of Sec. 3.2).
    pub fn dequantize(&self, q: &QuantizedMatrix) -> Matrix {
        let mut out = Matrix::zeros(q.rows, q.cols);
        self.dequantize_into(q, &mut out);
        out
    }

    /// Dequantize into an existing buffer (hot-path variant, no allocation).
    pub fn dequantize_into(&self, q: &QuantizedMatrix, out: &mut Matrix) {
        assert_eq!((out.rows(), out.cols()), (q.rows, q.cols));
        debug_assert_eq!(q.mapping, self.cfg.mapping);
        debug_assert_eq!(q.bits, self.cfg.bits);
        let (m, n, b) = (q.rows, q.cols, q.block);
        let bn = n.div_ceil(b);
        for i in 0..m {
            let bi = i / b;
            let row = out.row_mut(i);
            for (j, slot) in row.iter_mut().enumerate() {
                let scale = q.scales[bi * bn + j / b];
                *slot = scale * self.codebook.decode(q.codes.get(i * n + j));
            }
        }
    }

    /// Round-trip `D(Q(x))` in one call.
    pub fn roundtrip(&self, x: &Matrix) -> Matrix {
        self.dequantize(&self.quantize(x))
    }
}

impl QuantizedMatrix {
    /// Physical bytes: packed codes + f32 scales (what the paper's memory
    /// tables count for VQ preconditioners).
    pub fn size_bytes(&self) -> usize {
        self.codes.size_bytes() + self.scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn quantizer(block: usize) -> BlockQuantizer {
        BlockQuantizer::new(QuantConfig { block, ..Default::default() })
    }

    #[test]
    fn roundtrip_error_bounded_by_block_absmax() {
        // Proposition B.1: ‖D(Q(x)) − x‖∞ ≤ ‖x‖∞ · max_gap/2 per block.
        let mut rng = Rng::new(1);
        let q = quantizer(8);
        let bound_factor = q.codebook().max_abs_error();
        for _ in 0..20 {
            let x = Matrix::randn(19, 23, 2.0, &mut rng);
            let qx = q.quantize(&x);
            let back = q.dequantize(&qx);
            // Check per-element error against the block scale.
            let bn = 23usize.div_ceil(8);
            for i in 0..19 {
                for j in 0..23 {
                    let scale = qx.scales[(i / 8) * bn + j / 8];
                    let err = (back[(i, j)] - x[(i, j)]).abs();
                    assert!(err <= scale * bound_factor + 1e-6, "err={err} scale={scale}");
                }
            }
        }
    }

    #[test]
    fn zero_matrix_roundtrips_exactly() {
        let q = quantizer(4);
        let x = Matrix::zeros(10, 10);
        assert_eq!(q.roundtrip(&x).max_abs_diff(&x), 0.0);
    }

    #[test]
    fn blockwise_isolates_outliers() {
        // One huge outlier in block (0,0) must not destroy accuracy in the
        // other blocks — the point of block-wise normalization.
        let mut rng = Rng::new(2);
        let mut x = Matrix::randn(16, 16, 1.0, &mut rng);
        x[(0, 0)] = 1e6;
        let q = quantizer(8);
        let back = q.roundtrip(&x);
        // Far block (8.., 8..) should be accurate relative to its own scale.
        for i in 8..16 {
            for j in 8..16 {
                let err = (back[(i, j)] - x[(i, j)]).abs();
                assert!(err < 0.5, "block leakage: err={err}");
            }
        }
    }

    #[test]
    fn exact_levels_roundtrip_exactly() {
        let q = quantizer(64);
        // A matrix whose entries are exact codebook levels times a scale.
        let levels = q.codebook().levels.clone();
        let x = Matrix::from_fn(4, 4, |i, j| 3.5 * levels[(i * 4 + j) % 16]);
        let back = q.roundtrip(&x);
        assert!(back.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn size_is_roughly_half_byte_per_elem() {
        let q = quantizer(64);
        let x = Matrix::zeros(128, 128);
        let qx = q.quantize(&x);
        let payload = 128 * 128 / 2;
        let scales = 4 * 4; // 2x2 blocks of 64 → 4 scales × 4 bytes
        assert_eq!(qx.size_bytes(), payload + scales);
    }

    #[test]
    fn eight_bit_codes_use_one_byte_each_and_beat_four_bit() {
        let mut rng = Rng::new(5);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let q4 = quantizer(16);
        let q8 = BlockQuantizer::new(QuantConfig { bits: 8, block: 16, ..Default::default() });
        let e4 = q4.roundtrip(&x).max_abs_diff(&x);
        let e8 = q8.roundtrip(&x).max_abs_diff(&x);
        assert!(e8 < e4 * 0.5, "8-bit must beat 4-bit: e8={e8} e4={e4}");
        let qx = q8.quantize(&x);
        assert!(matches!(qx.codes, CodeStore::Bytes(_)));
        // One byte per code + 2×2 blocks of f32 scales.
        assert_eq!(qx.size_bytes(), 32 * 32 + 4 * 4);
    }

    #[test]
    fn non_divisible_shapes() {
        let mut rng = Rng::new(3);
        let q = quantizer(16);
        let x = Matrix::randn(33, 17, 1.0, &mut rng);
        let back = q.roundtrip(&x);
        assert_eq!(back.rows(), 33);
        assert_eq!(back.cols(), 17);
        // sanity: correlation stays high
        let num = crate::linalg::inner(&x, &back);
        let den = crate::linalg::fro_norm(&x) * crate::linalg::fro_norm(&back);
        assert!(num / den > 0.95);
    }

    #[test]
    fn block_one_is_per_element_scale() {
        let mut rng = Rng::new(4);
        let q = quantizer(1);
        let x = Matrix::randn(5, 5, 1.0, &mut rng);
        // With B=1 every element is its own block: |x| is the scale so the
        // roundtrip recovers |x| exactly at the ±1 levels.
        let back = q.roundtrip(&x);
        assert!(back.max_abs_diff(&x) < 1e-6);
    }
}

//! Block-wise b-bit quantization (paper Sec. 3.2).
//!
//! A matrix is tiled into `B×B` blocks; each block is normalized by its
//! absmax `N_p` and every element is mapped to the nearest codebook level
//! (Eq. 3). Dequantization is `N_p · M(q)`. Block-wise normalization
//! contains outliers to their own block, which is the reason the paper can
//! push preconditioners to 4 bits at all.

use super::mapping::{Codebook, Mapping};
use super::packed::{NibbleReader, NibbleWriter, PackedNibbles};
use crate::linalg::matmul::SendPtr;
use crate::linalg::Matrix;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::pool::{default_threads, parallel_for};

/// Element count below which quantize/dequantize stay single-threaded
/// (fan-out overhead beats the scan for small preconditioner blocks).
const PAR_ELEMS_THRESHOLD: usize = 1 << 15;

/// Rows-per-chunk for row-parallel kernels over an `rows × cols` grid,
/// sized so each worker gets ~4 chunks AND every chunk's flat start index
/// (`row · cols`) is even. The latter is the bit-identical-parallelism
/// guard for nibble-packed codes: a byte holds two consecutive codes, so
/// chunks that start on an even flat index never share a byte — parallel
/// workers write disjoint byte ranges and the result is independent of the
/// thread count.
pub(crate) fn even_aligned_chunk(rows: usize, cols: usize, threads: usize) -> usize {
    let base = rows.div_ceil(threads.max(1) * 4).max(1);
    if cols % 2 == 1 {
        base.next_multiple_of(2)
    } else {
        base
    }
}

pub(crate) fn auto_threads(elems: usize) -> usize {
    if elems < PAR_ELEMS_THRESHOLD {
        1
    } else {
        default_threads()
    }
}

/// Quantizer configuration (paper defaults: b=4, B=64, linear-2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    pub bits: u32,
    pub block: usize,
    pub mapping: Mapping,
    /// Tensors with fewer elements than this stay in f32 (App. C.3 uses 4096).
    pub min_quant_elems: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { bits: 4, block: 64, mapping: Mapping::Linear2, min_quant_elems: 4096 }
    }
}

/// Physical code storage: nibble-packed for `b ≤ 4`, one byte per code
/// above (the 8-bit codecs store one code per byte; no packing needed).
#[derive(Clone, Debug, PartialEq)]
pub enum CodeStore {
    Nibbles(PackedNibbles),
    Bytes(Vec<u8>),
}

impl CodeStore {
    /// Zero-initialized storage for `len` codes of width `bits`.
    pub fn zeros(len: usize, bits: u32) -> CodeStore {
        if bits <= 4 {
            CodeStore::Nibbles(PackedNibbles::zeros(len))
        } else {
            CodeStore::Bytes(vec![0u8; len])
        }
    }

    /// Code at index `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        match self {
            CodeStore::Nibbles(p) => p.get(i),
            CodeStore::Bytes(v) => v[i],
        }
    }

    /// Store code `c` at index `i`.
    #[inline]
    pub fn set(&mut self, i: usize, c: u8) {
        match self {
            CodeStore::Nibbles(p) => p.set(i, c),
            CodeStore::Bytes(v) => v[i] = c,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            CodeStore::Nibbles(p) => p.len(),
            CodeStore::Bytes(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Physical storage bytes (what the memory accountant counts).
    pub fn size_bytes(&self) -> usize {
        match self {
            CodeStore::Nibbles(p) => p.size_bytes(),
            CodeStore::Bytes(v) => v.len(),
        }
    }

    /// Resize to `len` zeroed codes of width `bits`, reusing the existing
    /// allocation when the variant matches and capacity suffices (the
    /// `quantize_into` steady-state path).
    pub fn reset(&mut self, len: usize, bits: u32) {
        match (&mut *self, bits <= 4) {
            (CodeStore::Nibbles(p), true) => p.reset(len),
            (CodeStore::Bytes(v), false) => {
                v.clear();
                v.resize(len, 0);
            }
            (s, _) => *s = CodeStore::zeros(len, bits),
        }
    }
}

/// A block-quantized matrix: packed codes + per-block scales.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub bits: u32,
    pub mapping: Mapping,
    /// Row-major packed codes (same element order as the source matrix).
    pub codes: CodeStore,
    /// Per-block normalization factors `N_p`, blocks in row-major block order.
    pub scales: Vec<f32>,
}

/// Stateless quantize/dequantize engine with a precomputed codebook.
#[derive(Clone, Debug)]
pub struct BlockQuantizer {
    pub cfg: QuantConfig,
    codebook: Codebook,
}

impl BlockQuantizer {
    pub fn new(cfg: QuantConfig) -> BlockQuantizer {
        BlockQuantizer { cfg, codebook: Codebook::new(cfg.mapping, cfg.bits) }
    }

    pub fn codebook(&self) -> &Codebook {
        &self.codebook
    }

    /// Quantize `x` block-wise (Eq. 3). All-zero blocks get scale 0.
    /// Allocates a fresh [`QuantizedMatrix`]; loops should hold one and call
    /// [`Self::quantize_into`] instead.
    pub fn quantize(&self, x: &Matrix) -> QuantizedMatrix {
        let mut q = QuantizedMatrix {
            rows: 0,
            cols: 0,
            block: self.cfg.block.max(1),
            bits: self.cfg.bits,
            mapping: self.cfg.mapping,
            codes: CodeStore::zeros(0, self.cfg.bits),
            scales: Vec::new(),
        };
        self.quantize_into(x, &mut q);
        q
    }

    /// Quantize into a caller-owned [`QuantizedMatrix`], reusing its code
    /// and scale buffers (zero allocations once capacities have warmed up —
    /// the codec store hot path). `q` is fully overwritten, including its
    /// shape/config metadata.
    pub fn quantize_into(&self, x: &Matrix, q: &mut QuantizedMatrix) {
        self.quantize_into_threaded(x, q, auto_threads(x.rows() * x.cols()));
    }

    /// [`Self::quantize_into`] with an explicit worker count.
    ///
    /// The fused kernel runs two passes — block absmax scales (parallel
    /// over block rows), then encode+pack (parallel over row chunks,
    /// streaming whole bytes through `NibbleWriter` instead of per-code
    /// `CodeStore::set`). Every element's code depends only on its own
    /// value and its block scale, workers write disjoint byte ranges
    /// (even-aligned chunks), and per-block scale folds stay row-major —
    /// so the result is bit-identical for every `threads` value (pinned by
    /// the kernel-equivalence suite).
    pub fn quantize_into_threaded(&self, x: &Matrix, q: &mut QuantizedMatrix, threads: usize) {
        let (m, n) = (x.rows(), x.cols());
        let b = self.cfg.block.max(1);
        let bm = m.div_ceil(b);
        let bn = n.div_ceil(b);
        q.rows = m;
        q.cols = n;
        q.block = b;
        q.bits = self.cfg.bits;
        q.mapping = self.cfg.mapping;
        q.scales.clear();
        q.scales.resize(bm * bn, 0.0);
        q.codes.reset(m * n, self.cfg.bits);

        // Pass 1: per-block absmax → scales. Parallel over block rows;
        // each task owns a disjoint `bn`-slice of the scale vector, and the
        // fold within a block is row-major exactly like the scalar
        // reference, so scales are bit-identical to a sequential pass.
        {
            let scales_ptr = SendPtr(q.scales.as_mut_ptr());
            let threads1 = threads.min(bm.max(1));
            parallel_for(bm, threads1, |bi| {
                let r0 = bi * b;
                let r1 = (r0 + b).min(m);
                let srow = unsafe {
                    std::slice::from_raw_parts_mut(scales_ptr.get().add(bi * bn), bn)
                };
                for i in r0..r1 {
                    let row = x.row(i);
                    for (bj, s) in srow.iter_mut().enumerate() {
                        let c0 = bj * b;
                        let c1 = (c0 + b).min(n);
                        let mut amax = *s;
                        for &v in &row[c0..c1] {
                            amax = amax.max(v.abs());
                        }
                        *s = amax;
                    }
                }
            });
        }

        // Pass 2: encode + pack, parallel over even-aligned row chunks.
        let zero_code = self.codebook.encode(0.0);
        let chunk = even_aligned_chunk(m, n, threads);
        let n_chunks = m.div_ceil(chunk.max(1));
        let scales = &q.scales;
        match &mut q.codes {
            CodeStore::Nibbles(p) => {
                let bytes_ptr = SendPtr(p.bytes_mut().as_mut_ptr());
                parallel_for(n_chunks, threads, |c| {
                    let r0 = c * chunk;
                    let r1 = (r0 + chunk).min(m);
                    let flat0 = r0 * n; // even by construction
                    let flat1 = r1 * n;
                    let byte_lo = flat0 >> 1;
                    let byte_hi = flat1.div_ceil(2);
                    // Safety: chunks start on even flat indices, so byte
                    // ranges are disjoint across tasks.
                    let sub = unsafe {
                        std::slice::from_raw_parts_mut(
                            bytes_ptr.get().add(byte_lo),
                            byte_hi - byte_lo,
                        )
                    };
                    let mut w = NibbleWriter::new(sub, 0);
                    for i in r0..r1 {
                        let row = x.row(i);
                        let srow = &scales[(i / b) * bn..(i / b) * bn + bn];
                        for (bj, &amax) in srow.iter().enumerate() {
                            let c0 = bj * b;
                            let c1 = (c0 + b).min(n);
                            if amax == 0.0 {
                                for _ in c0..c1 {
                                    w.push(zero_code);
                                }
                            } else {
                                let inv = 1.0 / amax;
                                for &v in &row[c0..c1] {
                                    w.push(self.codebook.encode(v * inv));
                                }
                            }
                        }
                    }
                    w.finish();
                });
            }
            CodeStore::Bytes(v) => {
                let bytes_ptr = SendPtr(v.as_mut_ptr());
                parallel_for(n_chunks, threads, |c| {
                    let r0 = c * chunk;
                    let r1 = (r0 + chunk).min(m);
                    // Safety: one byte per code — row ranges are disjoint.
                    let sub = unsafe {
                        std::slice::from_raw_parts_mut(bytes_ptr.get().add(r0 * n), (r1 - r0) * n)
                    };
                    for i in r0..r1 {
                        let row = x.row(i);
                        let out = &mut sub[(i - r0) * n..(i - r0) * n + n];
                        let srow = &scales[(i / b) * bn..(i / b) * bn + bn];
                        for (bj, &amax) in srow.iter().enumerate() {
                            let c0 = bj * b;
                            let c1 = (c0 + b).min(n);
                            if amax == 0.0 {
                                out[c0..c1].fill(zero_code);
                            } else {
                                let inv = 1.0 / amax;
                                for (slot, &v) in out[c0..c1].iter_mut().zip(&row[c0..c1]) {
                                    *slot = self.codebook.encode(v * inv);
                                }
                            }
                        }
                    }
                });
            }
        }
    }

    /// Dequantize back to f32 (`D` of Sec. 3.2).
    pub fn dequantize(&self, q: &QuantizedMatrix) -> Matrix {
        let mut out = Matrix::zeros(q.rows, q.cols);
        self.dequantize_into(q, &mut out);
        out
    }

    /// Dequantize into an existing buffer (hot-path variant, no allocation).
    pub fn dequantize_into(&self, q: &QuantizedMatrix, out: &mut Matrix) {
        self.dequantize_into_threaded(q, out, auto_threads(q.rows * q.cols));
    }

    /// [`Self::dequantize_into`] with an explicit worker count.
    ///
    /// Fused kernel: per row chunk, codes stream through `NibbleReader`
    /// (one byte load per two codes) and each `B`-column segment is decoded
    /// through a stack-resident 16-entry `scale·level` table, replacing the
    /// per-element multiply of the scalar path with a load of the identical
    /// precomputed product — bit-identical to sequential for any `threads`.
    pub fn dequantize_into_threaded(&self, q: &QuantizedMatrix, out: &mut Matrix, threads: usize) {
        assert_eq!((out.rows(), out.cols()), (q.rows, q.cols));
        debug_assert_eq!(q.mapping, self.cfg.mapping);
        debug_assert_eq!(q.bits, self.cfg.bits);
        let (m, n, b) = (q.rows, q.cols, q.block);
        let bn = n.div_ceil(b);
        let chunk = even_aligned_chunk(m, n, threads).max(1);
        let n_chunks = m.div_ceil(chunk);
        let out_ptr = SendPtr(out.data_mut().as_mut_ptr());
        match &q.codes {
            CodeStore::Nibbles(p) => {
                let nlevels = self.codebook.levels.len();
                debug_assert!(nlevels <= 16);
                let bytes = p.bytes();
                parallel_for(n_chunks, threads, |c| {
                    let r0 = c * chunk;
                    let r1 = (r0 + chunk).min(m);
                    let mut tab = [0.0f32; 16];
                    for i in r0..r1 {
                        // Safety: output rows are disjoint across tasks.
                        let orow = unsafe {
                            std::slice::from_raw_parts_mut(out_ptr.get().add(i * n), n)
                        };
                        let mut rd = NibbleReader::new(bytes, i * n);
                        let srow = &q.scales[(i / b) * bn..(i / b) * bn + bn];
                        for (bj, &scale) in srow.iter().enumerate() {
                            let c0 = bj * b;
                            let c1 = (c0 + b).min(n);
                            // Rebuilt per (row, segment): 16/B extra
                            // multiplies per element (25% of a mul at B=64,
                            // vs. the 1 mul/elem the table replaces).
                            // Amortizing across a block row would need a
                            // bn×16 table heap buffer (breaking the
                            // zero-alloc contract) or block-column-outer
                            // iteration (re-traversing each row B times).
                            self.codebook.scaled_levels(scale, &mut tab[..nlevels]);
                            for slot in &mut orow[c0..c1] {
                                *slot = tab[rd.next_code() as usize];
                            }
                        }
                    }
                });
            }
            CodeStore::Bytes(v) => {
                let levels = &self.codebook.levels;
                parallel_for(n_chunks, threads, |c| {
                    let r0 = c * chunk;
                    let r1 = (r0 + chunk).min(m);
                    for i in r0..r1 {
                        // Safety: output rows are disjoint across tasks.
                        let orow = unsafe {
                            std::slice::from_raw_parts_mut(out_ptr.get().add(i * n), n)
                        };
                        let crow = &v[i * n..i * n + n];
                        let srow = &q.scales[(i / b) * bn..(i / b) * bn + bn];
                        for (bj, &scale) in srow.iter().enumerate() {
                            let c0 = bj * b;
                            let c1 = (c0 + b).min(n);
                            for (slot, &code) in orow[c0..c1].iter_mut().zip(&crow[c0..c1]) {
                                *slot = scale * levels[code as usize];
                            }
                        }
                    }
                });
            }
        }
    }

    /// Round-trip `D(Q(x))` in one call.
    pub fn roundtrip(&self, x: &Matrix) -> Matrix {
        self.dequantize(&self.quantize(x))
    }
}

impl QuantizedMatrix {
    /// Physical bytes: packed codes + f32 scales (what the paper's memory
    /// tables count for VQ preconditioners).
    pub fn size_bytes(&self) -> usize {
        self.codes.size_bytes() + self.scales.len() * 4
    }

    /// Serialize for checkpointing: shape/config header, then the packed
    /// code bytes verbatim, then the raw f32 scale bits. A restore followed
    /// by [`Self::write_bytes`] reproduces the identical byte string — no
    /// re-quantization is involved anywhere on the path.
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        w.put_u64(self.rows as u64);
        w.put_u64(self.cols as u64);
        w.put_u64(self.block as u64);
        w.put_u32(self.bits);
        w.put_u8(self.mapping.tag());
        match &self.codes {
            CodeStore::Nibbles(p) => {
                w.put_u8(0);
                w.put_u64(p.len() as u64);
                w.put_bytes(p.bytes());
            }
            CodeStore::Bytes(v) => {
                w.put_u8(1);
                w.put_bytes(v);
            }
        }
        w.put_f32s(&self.scales);
    }

    /// Inverse of [`Self::write_bytes`]; errors on truncation or on layout
    /// tags this build does not know.
    pub fn read_bytes(r: &mut ByteReader<'_>) -> crate::util::error::Result<QuantizedMatrix> {
        let rows = r.get_len()?;
        let cols = r.get_len()?;
        let block = r.get_len()?;
        let bits = r.get_u32()?;
        let tag = r.get_u8()?;
        let mapping =
            Mapping::from_tag(tag).ok_or_else(|| crate::anyhow!("unknown mapping tag {tag}"))?;
        let codes = match r.get_u8()? {
            0 => {
                let len = r.get_len()?;
                let raw = r.get_bytes()?;
                crate::ensure!(
                    raw.len() == len.div_ceil(2),
                    "nibble payload {} bytes, want {} for {len} codes",
                    raw.len(),
                    len.div_ceil(2)
                );
                let mut p = PackedNibbles::zeros(len);
                p.bytes_mut().copy_from_slice(raw);
                CodeStore::Nibbles(p)
            }
            1 => CodeStore::Bytes(r.get_bytes()?.to_vec()),
            t => crate::bail!("unknown code-store tag {t}"),
        };
        crate::ensure!(codes.len() == rows * cols, "code count mismatch");
        let scales = r.get_f32s()?;
        Ok(QuantizedMatrix { rows, cols, block, bits, mapping, codes, scales })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn quantizer(block: usize) -> BlockQuantizer {
        BlockQuantizer::new(QuantConfig { block, ..Default::default() })
    }

    #[test]
    fn roundtrip_error_bounded_by_block_absmax() {
        // Proposition B.1: ‖D(Q(x)) − x‖∞ ≤ ‖x‖∞ · max_gap/2 per block.
        let mut rng = Rng::new(1);
        let q = quantizer(8);
        let bound_factor = q.codebook().max_abs_error();
        for _ in 0..20 {
            let x = Matrix::randn(19, 23, 2.0, &mut rng);
            let qx = q.quantize(&x);
            let back = q.dequantize(&qx);
            // Check per-element error against the block scale.
            let bn = 23usize.div_ceil(8);
            for i in 0..19 {
                for j in 0..23 {
                    let scale = qx.scales[(i / 8) * bn + j / 8];
                    let err = (back[(i, j)] - x[(i, j)]).abs();
                    assert!(err <= scale * bound_factor + 1e-6, "err={err} scale={scale}");
                }
            }
        }
    }

    #[test]
    fn zero_matrix_roundtrips_exactly() {
        let q = quantizer(4);
        let x = Matrix::zeros(10, 10);
        assert_eq!(q.roundtrip(&x).max_abs_diff(&x), 0.0);
    }

    #[test]
    fn blockwise_isolates_outliers() {
        // One huge outlier in block (0,0) must not destroy accuracy in the
        // other blocks — the point of block-wise normalization.
        let mut rng = Rng::new(2);
        let mut x = Matrix::randn(16, 16, 1.0, &mut rng);
        x[(0, 0)] = 1e6;
        let q = quantizer(8);
        let back = q.roundtrip(&x);
        // Far block (8.., 8..) should be accurate relative to its own scale.
        for i in 8..16 {
            for j in 8..16 {
                let err = (back[(i, j)] - x[(i, j)]).abs();
                assert!(err < 0.5, "block leakage: err={err}");
            }
        }
    }

    #[test]
    fn exact_levels_roundtrip_exactly() {
        let q = quantizer(64);
        // A matrix whose entries are exact codebook levels times a scale.
        let levels = q.codebook().levels.clone();
        let x = Matrix::from_fn(4, 4, |i, j| 3.5 * levels[(i * 4 + j) % 16]);
        let back = q.roundtrip(&x);
        assert!(back.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn size_is_roughly_half_byte_per_elem() {
        let q = quantizer(64);
        let x = Matrix::zeros(128, 128);
        let qx = q.quantize(&x);
        let payload = 128 * 128 / 2;
        let scales = 4 * 4; // 2x2 blocks of 64 → 4 scales × 4 bytes
        assert_eq!(qx.size_bytes(), payload + scales);
    }

    #[test]
    fn eight_bit_codes_use_one_byte_each_and_beat_four_bit() {
        let mut rng = Rng::new(5);
        let x = Matrix::randn(32, 32, 1.0, &mut rng);
        let q4 = quantizer(16);
        let q8 = BlockQuantizer::new(QuantConfig { bits: 8, block: 16, ..Default::default() });
        let e4 = q4.roundtrip(&x).max_abs_diff(&x);
        let e8 = q8.roundtrip(&x).max_abs_diff(&x);
        assert!(e8 < e4 * 0.5, "8-bit must beat 4-bit: e8={e8} e4={e4}");
        let qx = q8.quantize(&x);
        assert!(matches!(qx.codes, CodeStore::Bytes(_)));
        // One byte per code + 2×2 blocks of f32 scales.
        assert_eq!(qx.size_bytes(), 32 * 32 + 4 * 4);
    }

    #[test]
    fn non_divisible_shapes() {
        let mut rng = Rng::new(3);
        let q = quantizer(16);
        let x = Matrix::randn(33, 17, 1.0, &mut rng);
        let back = q.roundtrip(&x);
        assert_eq!(back.rows(), 33);
        assert_eq!(back.cols(), 17);
        // sanity: correlation stays high
        let num = crate::linalg::inner(&x, &back);
        let den = crate::linalg::fro_norm(&x) * crate::linalg::fro_norm(&back);
        assert!(num / den > 0.95);
    }

    #[test]
    fn serialization_round_trips_byte_exactly() {
        let mut rng = Rng::new(6);
        for (bits, (m, n)) in [(4u32, (33, 17)), (8, (16, 16))] {
            let q = BlockQuantizer::new(QuantConfig { bits, block: 16, ..Default::default() });
            let x = Matrix::randn(m, n, 1.0, &mut rng);
            let qx = q.quantize(&x);
            let mut w = ByteWriter::new();
            qx.write_bytes(&mut w);
            let bytes = w.into_bytes();
            let mut r = ByteReader::new(&bytes);
            let back = QuantizedMatrix::read_bytes(&mut r).unwrap();
            r.finish().unwrap();
            // Idempotent re-serialization — the on-disk form is canonical.
            let mut w2 = ByteWriter::new();
            back.write_bytes(&mut w2);
            assert_eq!(bytes, w2.into_bytes(), "bits={bits}");
            assert_eq!(q.dequantize(&back).max_abs_diff(&q.dequantize(&qx)), 0.0);
            // A truncated tail is an error, never a partial value.
            let mut r = ByteReader::new(&bytes[..bytes.len() - 3]);
            assert!(QuantizedMatrix::read_bytes(&mut r).is_err());
        }
    }

    #[test]
    fn block_one_is_per_element_scale() {
        let mut rng = Rng::new(4);
        let q = quantizer(1);
        let x = Matrix::randn(5, 5, 1.0, &mut rng);
        // With B=1 every element is its own block: |x| is the scale so the
        // roundtrip recovers |x| exactly at the ±1 levels.
        let back = q.roundtrip(&x);
        assert!(back.max_abs_diff(&x) < 1e-6);
    }
}

//! Quantization mappings `M : [0, 2^b−1] → [−1, 1]` (paper Eq. (3)–(4)).
//!
//! The paper uses **linear-2** ("linear square") for b = 4: a signed-square
//! codebook that concentrates levels near zero where preconditioner entries
//! cluster. Plain linear and a geometric "dynamic" codebook are provided
//! for ablations.

/// Available codebooks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mapping {
    /// Uniform levels on [−1, 1].
    Linear,
    /// Signed square of uniform levels — Eq. (4), the paper's default.
    Linear2,
    /// Signed geometric (power-of-two) levels, à la dynamic quantization.
    Dynamic,
}

impl Mapping {
    /// The `2^b` codebook values, strictly increasing.
    pub fn levels(&self, bits: u32) -> Vec<f32> {
        let n = 1usize << bits;
        let half = (n / 2) as i64 - 1; // index of the zero level, Eq. (4)
        match self {
            Mapping::Linear => (0..n)
                .map(|j| -1.0 + 2.0 * j as f32 / (n as f32 - 1.0))
                .collect(),
            Mapping::Linear2 => (0..n)
                .map(|j| {
                    let j = j as i64;
                    let u = -1.0 + 2.0 * j as f32 / (n as f32 - 1.0);
                    if j < half {
                        -(u * u)
                    } else if j == half {
                        0.0
                    } else {
                        u * u
                    }
                })
                .collect(),
            Mapping::Dynamic => {
                // Negative side: −2^0 … −2^{−(half−1)}, then 0, then the
                // positive mirror; 2^b values total, increasing.
                let mut v = Vec::with_capacity(n);
                for k in 0..half {
                    v.push(-(2.0f32.powi(-(k as i32))));
                }
                v.push(0.0);
                for k in (0..(n as i64 - half - 1)).rev() {
                    v.push(2.0f32.powi(-(k as i32)));
                }
                v
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mapping::Linear => "linear",
            Mapping::Linear2 => "linear2",
            Mapping::Dynamic => "dynamic",
        }
    }

    /// Stable one-byte tag for checkpoint serialization. These values are
    /// part of the on-disk format — never renumber, only append.
    pub fn tag(&self) -> u8 {
        match self {
            Mapping::Linear => 0,
            Mapping::Linear2 => 1,
            Mapping::Dynamic => 2,
        }
    }

    /// Inverse of [`Mapping::tag`]; `None` for tags from a newer format.
    pub fn from_tag(tag: u8) -> Option<Mapping> {
        match tag {
            0 => Some(Mapping::Linear),
            1 => Some(Mapping::Linear2),
            2 => Some(Mapping::Dynamic),
            _ => None,
        }
    }
}

/// Precomputed nearest-level quantizer for one (mapping, bits) pair.
///
/// `encode` maps a normalized value in [−1, 1] to the argmin index of
/// Eq. (3) with a single branchless pass over a precomputed **boundary
/// table**: `bounds[k]` is the largest f32 that still encodes to level ≤ k
/// (found once at construction by bit-level binary search against the
/// scalar midpoint/tie-break reference), so `encode(x)` is just "count
/// boundaries below x" — no per-call tie-break branch, and bit-identical
/// to the reference by construction. `decode` is a table lookup.
#[derive(Clone, Debug)]
pub struct Codebook {
    pub bits: u32,
    pub levels: Vec<f32>,
    mids: Vec<f32>,
    /// `bounds[k]` = largest f32 with `encode_scalar(x) ≤ k` (len 2^b − 1).
    bounds: Vec<f32>,
}

impl Codebook {
    pub fn new(mapping: Mapping, bits: u32) -> Codebook {
        let levels = mapping.levels(bits);
        debug_assert!(levels.windows(2).all(|w| w[0] < w[1]), "levels must increase");
        let mids: Vec<f32> = levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
        let mut cb = Codebook { bits, levels, mids, bounds: Vec::new() };
        // Decision boundary k sits between levels k and k+1; the scalar
        // reference's exact f32 cut is found by binary search over the
        // total order of f32 bit patterns (the predicate is monotone in x).
        cb.bounds = (0..cb.levels.len() - 1)
            .map(|k| {
                let mut lo = f32_ord(cb.levels[k]);
                let mut hi = f32_ord(cb.levels[k + 1]);
                debug_assert!(cb.encode_scalar(cb.levels[k]) as usize <= k);
                debug_assert!(cb.encode_scalar(cb.levels[k + 1]) as usize > k);
                while hi - lo > 1 {
                    let mid = lo + (hi - lo) / 2;
                    if cb.encode_scalar(f32_unord(mid)) as usize <= k {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                f32_unord(lo)
            })
            .collect();
        cb
    }

    /// Nearest-level index for normalized `x` (clamped to [−1, 1]) — the
    /// argmin of Eq. (3).
    ///
    /// One branchless pass counts the boundary-table entries strictly
    /// below `x`; since `bounds[k]` is the largest f32 that still encodes
    /// to level ≤ k, that count IS the nearest level, and the result is
    /// bit-identical to [`Self::encode_scalar`]'s midpoint scan +
    /// tie-break by construction (the table is built by bit-level binary
    /// search against it).
    #[inline]
    pub fn encode(&self, x: f32) -> u8 {
        let x = x.clamp(-1.0, 1.0);
        let mut idx = 0usize;
        for &b in &self.bounds {
            idx += (b < x) as usize;
        }
        idx as u8
    }

    /// The scalar reference: midpoint count + tie-break toward the closer
    /// level. Used to build the boundary table and as the oracle in the
    /// kernel-equivalence tests.
    #[inline]
    pub fn encode_scalar(&self, x: f32) -> u8 {
        let x = x.clamp(-1.0, 1.0);
        let mut idx = 0usize;
        for &m in &self.mids {
            idx += (m < x) as usize;
        }
        // Tie-break toward the closer level (partition_point puts x==mid up).
        if idx > 0 {
            let lo = self.levels[idx - 1];
            let hi = self.levels[idx];
            if (x - lo).abs() <= (hi - x).abs() {
                return (idx - 1) as u8;
            }
        }
        idx as u8
    }

    #[inline]
    pub fn decode(&self, q: u8) -> f32 {
        self.levels[q as usize]
    }

    /// Fill `out` (length `2^b`) with `scale · level` — the per-block
    /// dequant table the fused kernels index by code, replacing a multiply
    /// per element with a load. Entry `c` equals `scale * decode(c)`
    /// bit-for-bit, so table-based dequantization matches the scalar path.
    #[inline]
    pub fn scaled_levels(&self, scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.levels.len());
        for (o, &l) in out.iter_mut().zip(self.levels.iter()) {
            *o = scale * l;
        }
    }

    /// The decision-boundary table (test/diagnostic access).
    pub fn bounds(&self) -> &[f32] {
        &self.bounds
    }

    /// Worst-case |decode(encode(x)) − x| over the codebook's domain:
    /// half the largest gap between adjacent levels (plus edge gaps).
    pub fn max_abs_error(&self) -> f32 {
        let mut worst = 0.0f32;
        for w in self.levels.windows(2) {
            worst = worst.max(0.5 * (w[1] - w[0]));
        }
        // Values clamp at ±1; levels end at ±1 for all our mappings.
        worst
    }
}

// ------------------------------------------------- IEEE-754 half (f16) ----

/// Convert an `f32` to IEEE-754 binary16 bits (software conversion — the
/// crate is dependency-free, so the `f16` codec cannot lean on a `half`
/// crate). Round-to-nearest-even, with gradual underflow into subnormals
/// (preconditioner ε values like `1e-6` sit below the smallest normal half,
/// `6.1e-5`, and must survive the trip), overflow to ±∞ above `65504`, and
/// NaN payloads preserved as quiet NaNs.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // ±∞ stays ±∞; any NaN becomes a quiet NaN.
        let payload: u16 = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | payload;
    }
    // Rebias: f32 bias 127 → f16 bias 15.
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±∞
    }
    if e <= 0 {
        // Subnormal half (unit 2⁻²⁴), or zero below half the smallest one.
        if e < -10 {
            return sign;
        }
        let m = man | 0x0080_0000; // restore the implicit leading 1
        let shift = (14 - e) as u32; // 14..=24
        let kept = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && (kept & 1) == 1);
        // A mantissa carry rolls into exponent 1 — still a valid half.
        return sign | (kept + round_up as u32) as u16;
    }
    // Normal: drop 13 mantissa bits, round to nearest even. A carry out of
    // the mantissa propagates into the exponent (and into ∞ at the top).
    let kept = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let round_up = rem > 0x1000 || (rem == 0x1000 && (kept & 1) == 1);
    sign | (kept + round_up as u32) as u16
}

/// Convert IEEE-754 binary16 bits back to `f32` (exact — every half value
/// is representable in single precision).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x03ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        // Subnormal: value = man · 2⁻²⁴ (exact in f32).
        let v = man as f32 * (1.0 / 16_777_216.0);
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp as u32 + 127 - 15) << 23) | (man << 13))
}

/// Map a finite f32 to a u32 preserving total order (sign-magnitude →
/// biased representation; the classic IEEE-754 radix trick).
#[inline]
fn f32_ord(x: f32) -> u32 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

/// Inverse of [`f32_ord`].
#[inline]
fn f32_unord(o: u32) -> f32 {
    let b = if o & 0x8000_0000 != 0 { o & 0x7fff_ffff } else { !o };
    f32::from_bits(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_order_trick_roundtrips_and_orders() {
        let xs = [-1.0f32, -0.5, -1e-20, 0.0, 1e-20, 0.25, 1.0];
        for &x in &xs {
            assert_eq!(f32_unord(f32_ord(x)), x);
        }
        for w in xs.windows(2) {
            assert!(f32_ord(w[0]) < f32_ord(w[1]), "{} !< {}", w[0], w[1]);
        }
    }

    #[test]
    fn boundary_encode_is_bit_identical_to_scalar() {
        for m in [Mapping::Linear, Mapping::Linear2, Mapping::Dynamic] {
            for bits in [2u32, 3, 4, 8] {
                let cb = Codebook::new(m, bits);
                // Dense sweep…
                for i in 0..20_000 {
                    let x = -1.002 + 2.004 * i as f32 / 19_999.0;
                    assert_eq!(cb.encode(x), cb.encode_scalar(x), "{} b={bits} x={x}", m.name());
                }
                // …plus the ulp-neighbourhood of every decision boundary,
                // where the two formulations could disagree if the table
                // were off by one bit.
                for &b in cb.bounds() {
                    let o = f32_ord(b);
                    for d in -2i64..=2 {
                        let x = f32_unord((o as i64 + d) as u32);
                        assert_eq!(
                            cb.encode(x),
                            cb.encode_scalar(x),
                            "{} b={bits} boundary {b} offset {d}",
                            m.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mapping_tags_round_trip_and_stay_stable() {
        for m in [Mapping::Linear, Mapping::Linear2, Mapping::Dynamic] {
            assert_eq!(Mapping::from_tag(m.tag()), Some(m));
        }
        // On-disk values — a renumbering would silently corrupt checkpoints.
        assert_eq!(Mapping::Linear.tag(), 0);
        assert_eq!(Mapping::Linear2.tag(), 1);
        assert_eq!(Mapping::Dynamic.tag(), 2);
        assert_eq!(Mapping::from_tag(3), None);
    }

    #[test]
    fn scaled_levels_table_matches_decode() {
        let cb = Codebook::new(Mapping::Linear2, 4);
        let mut tab = [0.0f32; 16];
        cb.scaled_levels(3.7, &mut tab);
        for c in 0u8..16 {
            assert_eq!(tab[c as usize], 3.7 * cb.decode(c));
        }
    }

    #[test]
    fn linear2_matches_eq4() {
        // b=4: j=0 → −1, j=7 → 0, j=15 → +1, j=11 → (−1+22/15)² = (7/15)².
        let l = Mapping::Linear2.levels(4);
        assert_eq!(l.len(), 16);
        assert!((l[0] + 1.0).abs() < 1e-6);
        assert_eq!(l[7], 0.0);
        assert!((l[15] - 1.0).abs() < 1e-6);
        let want = (7.0f32 / 15.0).powi(2);
        assert!((l[11] - want).abs() < 1e-6);
        // symmetric-ish: M(j) near −M(14−j) for the square parts
        assert!((l[1] + l[14]).abs() < 0.07);
    }

    #[test]
    fn all_mappings_strictly_increasing() {
        for m in [Mapping::Linear, Mapping::Linear2, Mapping::Dynamic] {
            for bits in [2, 3, 4, 8] {
                let l = m.levels(bits);
                assert_eq!(l.len(), 1 << bits);
                assert!(
                    l.windows(2).all(|w| w[0] < w[1]),
                    "{}/{} not increasing: {:?}",
                    m.name(),
                    bits,
                    l
                );
            }
        }
    }

    #[test]
    fn encode_decode_nearest() {
        let cb = Codebook::new(Mapping::Linear2, 4);
        // Exact levels round-trip.
        for (j, &lv) in cb.levels.iter().enumerate() {
            assert_eq!(cb.encode(lv), j as u8, "level {j}");
            assert_eq!(cb.decode(j as u8), lv);
        }
        // Arbitrary points map to the truly nearest level.
        for i in 0..2000 {
            let x = -1.0 + 2.0 * i as f32 / 1999.0;
            let q = cb.encode(x);
            let err = (cb.decode(q) - x).abs();
            for &lv in &cb.levels {
                assert!(err <= (lv - x).abs() + 1e-7, "x={x} q={q}");
            }
        }
    }

    #[test]
    fn encode_clamps() {
        let cb = Codebook::new(Mapping::Linear, 4);
        assert_eq!(cb.encode(-5.0), 0);
        assert_eq!(cb.encode(5.0), 15);
    }

    #[test]
    fn zero_encodes_to_zero_level() {
        for m in [Mapping::Linear2, Mapping::Dynamic] {
            let cb = Codebook::new(m, 4);
            assert_eq!(cb.decode(cb.encode(0.0)), 0.0, "{}", m.name());
        }
    }

    #[test]
    fn f16_exact_values_roundtrip_exactly() {
        // Powers of two, small integers, and k/65536 grids are exact halves.
        let near_tenth = 6553.0 / 65536.0; // 0.0999755859375, exact in f16
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1024.0, 65504.0, near_tenth] {
            assert_eq!(f16_to_f32(f32_to_f16(x)), x, "x={x}");
        }
        // Signed zero keeps its sign bit.
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(0.0), 0x0000);
    }

    #[test]
    fn f16_roundtrip_error_within_half_ulp() {
        // Normals: relative error ≤ 2⁻¹¹ (half an ulp of a 10-bit mantissa).
        for i in 0..4000 {
            let x = -8.0 + 16.0 * i as f32 / 3999.0;
            let back = f16_to_f32(f32_to_f16(x));
            assert!((back - x).abs() <= x.abs() * (1.0 / 2048.0) + 1e-24, "x={x} back={back}");
        }
    }

    #[test]
    fn f16_subnormals_cover_epsilon_range() {
        // ε = 1e-6 (the paper's stability constant) is far below the
        // smallest normal half (≈6.1e-5) — gradual underflow must keep it.
        let eps = 1e-6f32;
        let back = f16_to_f32(f32_to_f16(eps));
        assert!((back - eps).abs() <= 0.5 / 16_777_216.0, "eps survives as subnormal: {back}");
        // Smallest subnormal and the underflow-to-zero threshold.
        let tiny = 1.0 / 16_777_216.0; // 2⁻²⁴
        assert_eq!(f16_to_f32(f32_to_f16(tiny)), tiny);
        assert_eq!(f16_to_f32(f32_to_f16(tiny * 0.25)), 0.0);
    }

    #[test]
    fn f16_overflow_and_specials() {
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e6)), f32::NEG_INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // 65504 is the largest finite half; the next f32 above the midpoint
        // to 65536 must overflow.
        assert_eq!(f16_to_f32(f32_to_f16(65504.0)), 65504.0);
        assert_eq!(f16_to_f32(f32_to_f16(65521.0)), f32::INFINITY);
    }

    #[test]
    fn max_abs_error_bounds_roundtrip() {
        for m in [Mapping::Linear, Mapping::Linear2, Mapping::Dynamic] {
            let cb = Codebook::new(m, 4);
            let bound = cb.max_abs_error();
            for i in 0..500 {
                let x = -1.0 + 2.0 * i as f32 / 499.0;
                let err = (cb.decode(cb.encode(x)) - x).abs();
                assert!(err <= bound + 1e-6, "{} x={x} err={err} bound={bound}", m.name());
            }
        }
    }
}

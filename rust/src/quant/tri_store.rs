//! Joint triangular storage (paper Fig. 2).
//!
//! The Cholesky factor `C` is lower triangular with an f32 diagonal; the EF
//! error state `E` is *strictly* lower triangular (quantization skips the
//! diagonal, so its error is zero there). Their 4-bit codes therefore fit in
//! ONE `n×n` nibble grid: `C`'s code for `(i,j), i>j` at slot `(i,j)`, and
//! `E`'s code for `(i,j), i>j` at the mirrored slot `(j,i)` — so CQ+EF costs
//! no more code bytes than vanilla 4-bit quantization of one full matrix
//! (Sec. 4.3).

use super::blockwise::{BlockQuantizer, CodeStore, QuantizedMatrix};
use super::packed::PackedNibbles;
use crate::linalg::Matrix;

/// One packed buffer holding a quantized Cholesky factor (lower) and its
/// quantized error state (upper, mirrored).
#[derive(Clone, Debug)]
pub struct TriJointStore {
    pub n: usize,
    /// Shared n×n nibble grid (lower: C codes, upper: mirrored E codes).
    codes: PackedNibbles,
    /// f32 diagonal of C (never quantized, Sec. 4.2).
    pub diag: Vec<f32>,
    /// Block scales of the C quantization.
    c_scales: Vec<f32>,
    /// Block scales of the E quantization.
    e_scales: Vec<f32>,
    block: usize,
}

impl TriJointStore {
    /// Initial state `C = √ε·I`, `E = 0` (Algorithm 1 inputs).
    pub fn init(n: usize, eps: f32, quantizer: &BlockQuantizer) -> TriJointStore {
        let c = Matrix::eye_scaled(n, eps.sqrt());
        let e = Matrix::zeros(n, n);
        TriJointStore::store(&c, &e, quantizer)
    }

    /// Quantize and pack `c` (lower-tri incl. diagonal) and `e` (strictly
    /// lower-tri). Entries on/above the diagonal of `c` and on/above the
    /// diagonal of `e` are ignored.
    pub fn store(c: &Matrix, e: &Matrix, quantizer: &BlockQuantizer) -> TriJointStore {
        assert!(c.is_square() && e.is_square() && c.rows() == e.rows());
        // The joint nibble grid is a 4-bit layout by construction (Fig. 2);
        // wider codes would not fit two triangles in one n×n grid.
        debug_assert!(quantizer.cfg.bits <= 4, "TriJointStore requires b ≤ 4");
        let n = c.rows();

        // Strictly-lower copies for quantization (diag of C kept f32).
        let c_off = Matrix::from_fn(n, n, |i, j| if i > j { c[(i, j)] } else { 0.0 });
        let e_off = Matrix::from_fn(n, n, |i, j| if i > j { e[(i, j)] } else { 0.0 });
        let qc = quantizer.quantize(&c_off);
        let qe = quantizer.quantize(&e_off);

        let mut codes = PackedNibbles::zeros(n * n);
        for i in 0..n {
            for j in 0..i {
                codes.set(i * n + j, qc.codes.get(i * n + j)); // lower: C
                codes.set(j * n + i, qe.codes.get(i * n + j)); // upper: E mirrored
            }
        }

        TriJointStore {
            n,
            codes,
            diag: c.diag(),
            c_scales: qc.scales,
            e_scales: qe.scales,
            block: qc.block,
        }
    }

    /// Unpack and dequantize: returns `(C, E)` with `C` lower triangular
    /// (f32 diagonal restored) and `E` strictly lower triangular.
    pub fn load(&self, quantizer: &BlockQuantizer) -> (Matrix, Matrix) {
        let n = self.n;
        // Rebuild the two QuantizedMatrix views and reuse the block dequantizer.
        let mut c_codes = PackedNibbles::zeros(n * n);
        let mut e_codes = PackedNibbles::zeros(n * n);
        let zero = quantizer.codebook().encode(0.0);
        for i in 0..n {
            for j in 0..n {
                if i > j {
                    c_codes.set(i * n + j, self.codes.get(i * n + j));
                    e_codes.set(i * n + j, self.codes.get(j * n + i));
                } else {
                    c_codes.set(i * n + j, zero);
                    e_codes.set(i * n + j, zero);
                }
            }
        }
        let qc = QuantizedMatrix {
            rows: n,
            cols: n,
            block: self.block,
            bits: quantizer.cfg.bits,
            mapping: quantizer.cfg.mapping,
            codes: CodeStore::Nibbles(c_codes),
            scales: self.c_scales.clone(),
        };
        let qe = QuantizedMatrix {
            rows: n,
            cols: n,
            block: self.block,
            bits: quantizer.cfg.bits,
            mapping: quantizer.cfg.mapping,
            codes: CodeStore::Nibbles(e_codes),
            scales: self.e_scales.clone(),
        };
        let mut c = quantizer.dequantize(&qc);
        let mut e = quantizer.dequantize(&qe);
        // Mask the structural zeros explicitly: codebooks without an exact
        // zero level (e.g. plain linear) would otherwise leak ±scale/15
        // into the upper triangles.
        for i in 0..n {
            for j in i..n {
                c[(i, j)] = 0.0;
                e[(i, j)] = 0.0;
            }
            e[(i, i)] = 0.0;
        }
        for (i, &d) in self.diag.iter().enumerate() {
            c[(i, i)] = d;
        }
        (c, e)
    }

    /// Physical bytes: ONE n×n nibble grid + f32 diagonal + both scale sets.
    /// Compare: vanilla 4-bit VQ of one preconditioner = one n×n nibble grid
    /// + diagonal + one scale set — EF adds only the second scale set.
    pub fn size_bytes(&self) -> usize {
        self.codes.size_bytes()
            + self.diag.len() * 4
            + (self.c_scales.len() + self.e_scales.len()) * 4
    }

    /// Bytes without the error-state scales (pure CQ, no EF).
    pub fn size_bytes_cq_only(&self) -> usize {
        // CQ stores only the lower triangle: ⌈n(n+1)/2 codes / 2⌉ bytes.
        let tri_codes = (self.n * (self.n + 1)) / 2;
        tri_codes.div_ceil(2) + self.diag.len() * 4 + self.c_scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise::QuantConfig;
    use crate::util::rng::Rng;

    fn lower_tri(n: usize, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(n, n, |i, j| {
            if i > j {
                rng.normal_f32(1.0)
            } else if i == j {
                2.0 + rng.uniform() as f32
            } else {
                0.0
            }
        })
    }

    fn strictly_lower(n: usize, rng: &mut Rng, std: f32) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i > j { rng.normal_f32(std) } else { 0.0 })
    }

    #[test]
    fn roundtrip_recovers_structure() {
        let mut rng = Rng::new(1);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let c = lower_tri(17, &mut rng);
        let e = strictly_lower(17, &mut rng, 0.1);
        let store = TriJointStore::store(&c, &e, &quantizer);
        let (c2, e2) = store.load(&quantizer);

        // Structure: C lower-tri with exact diagonal, E strictly lower.
        for i in 0..17 {
            assert_eq!(c2[(i, i)], c[(i, i)], "diag exact");
            for j in (i + 1)..17 {
                assert_eq!(c2[(i, j)], 0.0);
                assert_eq!(e2[(i, j)], 0.0);
            }
            assert_eq!(e2[(i, i)], 0.0);
        }
        // Values: within block-quantization error.
        for i in 0..17 {
            for j in 0..i {
                assert!((c2[(i, j)] - c[(i, j)]).abs() < 0.5, "c[{i}][{j}]");
                assert!((e2[(i, j)] - e[(i, j)]).abs() < 0.05, "e[{i}][{j}]");
            }
        }
    }

    #[test]
    fn c_and_e_do_not_interfere() {
        let mut rng = Rng::new(2);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 4, ..Default::default() });
        let c = lower_tri(9, &mut rng);
        let zero = Matrix::zeros(9, 9);
        // Same C with and without an error state must load the same C.
        let s1 = TriJointStore::store(&c, &zero, &quantizer);
        let e = strictly_lower(9, &mut rng, 5.0);
        let s2 = TriJointStore::store(&c, &e, &quantizer);
        let (c1, _) = s1.load(&quantizer);
        let (c2, e2) = s2.load(&quantizer);
        assert_eq!(c1, c2, "E must not perturb C");
        assert!(e2.max_abs_diff(&e) < 1.0);
    }

    #[test]
    fn init_state_matches_algorithm1() {
        let quantizer = BlockQuantizer::new(QuantConfig::default());
        let s = TriJointStore::init(12, 1e-6, &quantizer);
        let (c, e) = s.load(&quantizer);
        let want = Matrix::eye_scaled(12, (1e-6f32).sqrt());
        assert!(c.max_abs_diff(&want) < 1e-9);
        assert_eq!(e, Matrix::zeros(12, 12));
    }

    #[test]
    fn joint_codes_cost_one_grid() {
        let quantizer = BlockQuantizer::new(QuantConfig { block: 64, ..Default::default() });
        let n = 64;
        let mut rng = Rng::new(3);
        let c = lower_tri(n, &mut rng);
        let e = strictly_lower(n, &mut rng, 0.1);
        let s = TriJointStore::store(&c, &e, &quantizer);
        // One n×n nibble grid = n²/2 bytes.
        let code_bytes = n * n / 2;
        assert_eq!(s.size_bytes(), code_bytes + n * 4 + 2 * 4);
    }
}

//! Joint triangular storage (paper Fig. 2).
//!
//! The Cholesky factor `C` is lower triangular with an f32 diagonal; the EF
//! error state `E` is *strictly* lower triangular (quantization skips the
//! diagonal, so its error is zero there). Their 4-bit codes therefore fit in
//! ONE `n×n` nibble grid: `C`'s code for `(i,j), i>j` at slot `(i,j)`, and
//! `E`'s code for `(i,j), i>j` at the mirrored slot `(j,i)` — so CQ+EF costs
//! no more code bytes than vanilla 4-bit quantization of one full matrix
//! (Sec. 4.3).
//!
//! ## Fused kernels
//!
//! The store/load paths quantize the triangles **directly into the joint
//! grid** — no staging matrices, no second quantization pass, no per-code
//! `get`/`set`: block scales are folded over the strictly-lower entries
//! only, codes stream through `NibbleWriter`/`NibbleReader` (whole-byte
//! traffic), and rows fan out over the thread pool. The staged API
//! ([`store_c_into`](TriJointStore::store_c_into) →
//! [`load_c_into`](TriJointStore::load_c_into) →
//! [`store_e_into`](TriJointStore::store_e_into)) exists because the EF
//! update needs `D(C̄)` *between* writing `C` and writing `E`; the staged
//! flow reads the freshly packed codes back instead of quantizing the
//! factor twice as the unfused path did. `store_c_into` must run first in a
//! refresh (it owns shape changes); `store_e_into`/`store_e_zero` complete
//! the grid. All `*_into` methods reuse the existing buffers — zero
//! allocations in steady state.

use super::blockwise::{auto_threads, even_aligned_chunk, BlockQuantizer};
use super::packed::{NibbleReader, NibbleWriter, PackedNibbles};
use crate::linalg::matmul::SendPtr;
use crate::linalg::Matrix;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;
use crate::util::pool::parallel_for;

/// One packed buffer holding a quantized Cholesky factor (lower) and its
/// quantized error state (upper, mirrored).
#[derive(Clone, Debug)]
pub struct TriJointStore {
    pub n: usize,
    /// Shared n×n nibble grid (lower: C codes, upper: mirrored E codes).
    codes: PackedNibbles,
    /// f32 diagonal of C (never quantized, Sec. 4.2).
    pub diag: Vec<f32>,
    /// Block scales of the C quantization.
    c_scales: Vec<f32>,
    /// Block scales of the E quantization.
    e_scales: Vec<f32>,
    block: usize,
}

impl TriJointStore {
    /// An unshaped store; the first `store_c_into` sizes it.
    pub fn empty() -> TriJointStore {
        TriJointStore {
            n: 0,
            codes: PackedNibbles::zeros(0),
            diag: Vec::new(),
            c_scales: Vec::new(),
            e_scales: Vec::new(),
            block: 1,
        }
    }

    /// Initial state `C = √ε·I`, `E = 0` (Algorithm 1 inputs).
    pub fn init(n: usize, eps: f32, quantizer: &BlockQuantizer) -> TriJointStore {
        let c = Matrix::eye_scaled(n, eps.sqrt());
        let e = Matrix::zeros(n, n);
        TriJointStore::store(&c, &e, quantizer)
    }

    /// Quantize and pack `c` (lower-tri incl. diagonal) and `e` (strictly
    /// lower-tri). Entries on/above the diagonal of `c` and on/above the
    /// diagonal of `e` are ignored.
    pub fn store(c: &Matrix, e: &Matrix, quantizer: &BlockQuantizer) -> TriJointStore {
        let mut s = TriJointStore::empty();
        s.store_into(c, e, quantizer);
        s
    }

    /// [`Self::store`] into this store's existing buffers.
    pub fn store_into(&mut self, c: &Matrix, e: &Matrix, quantizer: &BlockQuantizer) {
        assert!(c.is_square() && e.is_square() && c.rows() == e.rows());
        self.store_c_into(c, quantizer);
        self.store_e_into(e, quantizer);
    }

    /// Stage 1 of a refresh: quantize `c`'s strict lower triangle into the
    /// grid's lower half, record the exact f32 diagonal, and zero the
    /// diagonal nibble slots. Owns reshaping; call before any `store_e_*`.
    pub fn store_c_into(&mut self, c: &Matrix, quantizer: &BlockQuantizer) {
        assert!(c.is_square());
        // The joint nibble grid is a 4-bit layout by construction (Fig. 2);
        // wider codes would not fit two triangles in one n×n grid.
        debug_assert!(quantizer.cfg.bits <= 4, "TriJointStore requires b ≤ 4");
        let n = c.rows();
        let b = quantizer.cfg.block.max(1);
        if self.n != n || self.block != b {
            self.n = n;
            self.block = b;
            // Every nibble is rewritten by the C+E passes, so a plain
            // reshape (no zero fill) is enough.
            self.codes = PackedNibbles::zeros(n * n);
        }
        let bn = n.div_ceil(b);

        self.diag.clear();
        for i in 0..n {
            self.diag.push(c[(i, i)]);
        }
        strict_lower_scales(c, b, &mut self.c_scales);

        let cb = quantizer.codebook();
        let zero_code = cb.encode(0.0);
        let threads = auto_threads(n * n);
        let chunk = even_aligned_chunk(n, n, threads).max(1);
        let scales = &self.c_scales;
        let bytes_ptr = SendPtr(self.codes.bytes_mut().as_mut_ptr());
        parallel_for(n.div_ceil(chunk), threads, |ch| {
            let r0 = ch * chunk;
            let r1 = (r0 + chunk).min(n);
            for r in r0..r1 {
                // Row r writes codes for flat [r·n, r·n + r] — its C run
                // plus the zeroed diagonal slot.
                // Safety: row r's last slot is flat r·n + r and row r+1's
                // first is (r+1)·n — distance n − r ≥ 2 for every row with
                // a successor, which forces distinct bytes — see
                // `row_writer`.
                let mut w = unsafe { row_writer(bytes_ptr.get(), r * n, r + 1) };
                let bi = r / b;
                let crow = c.row(r);
                let mut j = 0usize;
                while j < r {
                    let bj = j / b;
                    let c1 = ((bj + 1) * b).min(r);
                    let amax = scales[bi * bn + bj];
                    if amax == 0.0 {
                        for _ in j..c1 {
                            w.push(zero_code);
                        }
                    } else {
                        let inv = 1.0 / amax;
                        for &v in &crow[j..c1] {
                            w.push(cb.encode(v * inv));
                        }
                    }
                    j = c1;
                }
                // Diagonal slot stays raw-nibble 0 (legacy grid layout;
                // the diagonal is carried exactly in `diag`).
                w.push(0);
                w.finish();
            }
        });
    }

    /// Stage 3 of a refresh: quantize `e`'s strict lower triangle into the
    /// grid's upper half (mirrored). Shape must match the last
    /// `store_c_into`.
    pub fn store_e_into(&mut self, e: &Matrix, quantizer: &BlockQuantizer) {
        assert!(e.is_square() && e.rows() == self.n, "store_c_into must run first");
        let (n, b) = (self.n, self.block);
        let bn = n.div_ceil(b);
        strict_lower_scales(e, b, &mut self.e_scales);

        let cb = quantizer.codebook();
        let zero_code = cb.encode(0.0);
        let threads = auto_threads(n * n);
        let chunk = even_aligned_chunk(n, n, threads).max(1);
        let scales = &self.e_scales;
        let bytes_ptr = SendPtr(self.codes.bytes_mut().as_mut_ptr());
        parallel_for(n.div_ceil(chunk), threads, |ch| {
            let r0 = ch * chunk;
            let r1 = (r0 + chunk).min(n);
            for r in r0..r1 {
                // Grid row r's upper slots (r, cc), cc > r hold E[cc][r] —
                // E's column r. Flat run [r·n + r + 1, (r+1)·n); row spans
                // are pairwise disjoint as in the C pass.
                let count = n - r - 1;
                if count == 0 {
                    continue;
                }
                // Safety: E runs of consecutive rows are ≥ 3 flat indices
                // apart, hence byte-disjoint — see `row_writer`.
                let mut w = unsafe { row_writer(bytes_ptr.get(), r * n + r + 1, count) };
                let bjr = r / b; // logical column block of E's column r
                let mut cc = r + 1;
                while cc < n {
                    let bi = cc / b;
                    let c1 = ((bi + 1) * b).min(n);
                    let amax = scales[bi * bn + bjr];
                    if amax == 0.0 {
                        for _ in cc..c1 {
                            w.push(zero_code);
                        }
                    } else {
                        let inv = 1.0 / amax;
                        for i in cc..c1 {
                            w.push(cb.encode(e[(i, r)] * inv));
                        }
                    }
                    cc = c1;
                }
                w.finish();
            }
        });
    }

    /// [`Self::store_e_into`] for `E = 0` without materializing a zero
    /// matrix (the non-EF CQ path): zero scales, zero-level codes.
    pub fn store_e_zero(&mut self, quantizer: &BlockQuantizer) {
        let (n, b) = (self.n, self.block);
        let bn = n.div_ceil(b);
        self.e_scales.clear();
        self.e_scales.resize(bn * bn, 0.0);
        let zero_code = quantizer.codebook().encode(0.0);
        let threads = auto_threads(n * n);
        let chunk = even_aligned_chunk(n, n, threads).max(1);
        let bytes_ptr = SendPtr(self.codes.bytes_mut().as_mut_ptr());
        parallel_for(n.div_ceil(chunk), threads, |ch| {
            let r0 = ch * chunk;
            let r1 = (r0 + chunk).min(n);
            for r in r0..r1 {
                let count = n - r - 1;
                if count == 0 {
                    continue;
                }
                // Safety: same byte-disjoint row spans as `store_e_into`.
                let mut w = unsafe { row_writer(bytes_ptr.get(), r * n + r + 1, count) };
                for _ in 0..count {
                    w.push(zero_code);
                }
                w.finish();
            }
        });
    }

    /// Unpack and dequantize: returns `(C, E)` with `C` lower triangular
    /// (f32 diagonal restored) and `E` strictly lower triangular.
    pub fn load(&self, quantizer: &BlockQuantizer) -> (Matrix, Matrix) {
        let mut c = Matrix::zeros(self.n, self.n);
        let mut e = Matrix::zeros(self.n, self.n);
        self.load_into(quantizer, &mut c, &mut e);
        (c, e)
    }

    /// [`Self::load`] into caller-owned buffers (zero allocation).
    pub fn load_into(&self, quantizer: &BlockQuantizer, c: &mut Matrix, e: &mut Matrix) {
        self.load_c_into(quantizer, c);
        self.load_e_into(quantizer, e);
    }

    /// Reconstruct `D(C̄)`: strictly-lower dequantized codes, exact f32
    /// diagonal, zero above. `out` is fully overwritten.
    pub fn load_c_into(&self, quantizer: &BlockQuantizer, out: &mut Matrix) {
        let (n, b) = (self.n, self.block);
        assert_eq!((out.rows(), out.cols()), (n, n));
        let bn = n.div_ceil(b);
        let cb = quantizer.codebook();
        let nlevels = cb.levels.len();
        debug_assert!(nlevels <= 16);
        let threads = auto_threads(n * n);
        let chunk = even_aligned_chunk(n, n, threads).max(1);
        let bytes = self.codes.bytes();
        let out_ptr = SendPtr(out.data_mut().as_mut_ptr());
        let (diag, scales) = (&self.diag, &self.c_scales);
        parallel_for(n.div_ceil(chunk), threads, |ch| {
            let r0 = ch * chunk;
            let r1 = (r0 + chunk).min(n);
            let mut tab = [0.0f32; 16];
            for r in r0..r1 {
                // Safety: output rows are disjoint across tasks.
                let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r * n), n) };
                let mut rd = NibbleReader::new(bytes, r * n);
                let bi = r / b;
                let mut j = 0usize;
                while j < r {
                    let bj = j / b;
                    let c1 = ((bj + 1) * b).min(r);
                    cb.scaled_levels(scales[bi * bn + bj], &mut tab[..nlevels]);
                    for slot in &mut orow[j..c1] {
                        *slot = tab[rd.next_code() as usize];
                    }
                    j = c1;
                }
                orow[r] = diag[r];
                orow[r + 1..].fill(0.0);
            }
        });
    }

    /// Reconstruct `D(Ē)`: strictly-lower dequantized error state, zero on
    /// and above the diagonal. `out` is fully overwritten.
    pub fn load_e_into(&self, quantizer: &BlockQuantizer, out: &mut Matrix) {
        let (n, b) = (self.n, self.block);
        assert_eq!((out.rows(), out.cols()), (n, n));
        let bn = n.div_ceil(b);
        let cb = quantizer.codebook();
        let nlevels = cb.levels.len();
        debug_assert!(nlevels <= 16);
        let threads = auto_threads(n * n);
        let chunk = even_aligned_chunk(n, n, threads).max(1);
        let bytes = self.codes.bytes();
        let out_ptr = SendPtr(out.data_mut().as_mut_ptr());
        let scales = &self.e_scales;
        // Pass A: zero fill (parallel over output rows).
        parallel_for(n.div_ceil(chunk), threads, |ch| {
            let r0 = ch * chunk;
            let r1 = (r0 + chunk).min(n);
            for r in r0..r1 {
                // Safety: output rows are disjoint across tasks.
                let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r * n), n) };
                orow.fill(0.0);
            }
        });
        // Pass B: stream grid row r's upper codes into E's column r —
        // distinct r ⇒ distinct output columns, so tasks stay disjoint.
        parallel_for(n.div_ceil(chunk), threads, |ch| {
            let r0 = ch * chunk;
            let r1 = (r0 + chunk).min(n);
            let mut tab = [0.0f32; 16];
            for r in r0..r1 {
                if r + 1 >= n {
                    continue;
                }
                let mut rd = NibbleReader::new(bytes, r * n + r + 1);
                let bjr = r / b;
                let base = out_ptr.get();
                let mut cc = r + 1;
                while cc < n {
                    let bi = cc / b;
                    let c1 = ((bi + 1) * b).min(n);
                    cb.scaled_levels(scales[bi * bn + bjr], &mut tab[..nlevels]);
                    for i in cc..c1 {
                        // Safety: element (i, r) is written only by the
                        // task owning grid row r.
                        unsafe { *base.add(i * n + r) = tab[rd.next_code() as usize] };
                    }
                    cc = c1;
                }
            }
        });
    }

    /// Physical bytes: ONE n×n nibble grid + f32 diagonal + both scale sets.
    /// Compare: vanilla 4-bit VQ of one preconditioner = one n×n nibble grid
    /// + diagonal + one scale set — EF adds only the second scale set.
    pub fn size_bytes(&self) -> usize {
        self.codes.size_bytes()
            + self.diag.len() * 4
            + (self.c_scales.len() + self.e_scales.len()) * 4
    }

    /// Bytes without the error-state scales (pure CQ, no EF).
    pub fn size_bytes_cq_only(&self) -> usize {
        // CQ stores only the lower triangle: ⌈n(n+1)/2 codes / 2⌉ bytes.
        let tri_codes = (self.n * (self.n + 1)) / 2;
        tri_codes.div_ceil(2) + self.diag.len() * 4 + self.c_scales.len() * 4
    }

    /// Serialize for checkpointing: the packed nibble grid verbatim plus the
    /// f32 diagonal and both scale sets as raw bits. Restoring and
    /// re-serializing reproduces the identical byte string — factor codes
    /// and EF triangles survive without any re-factorization or
    /// re-quantization.
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        w.put_u64(self.n as u64);
        w.put_u64(self.block as u64);
        w.put_u64(self.codes.len() as u64);
        w.put_bytes(self.codes.bytes());
        w.put_f32s(&self.diag);
        w.put_f32s(&self.c_scales);
        w.put_f32s(&self.e_scales);
    }

    /// Inverse of [`Self::write_bytes`]; errors on truncated or
    /// inconsistent input.
    pub fn read_bytes(r: &mut ByteReader<'_>) -> Result<TriJointStore> {
        let n = r.get_len()?;
        let block = r.get_len()?;
        let code_len = r.get_len()?;
        crate::ensure!(code_len == n * n, "joint grid holds {code_len} codes, want {}", n * n);
        let raw = r.get_bytes()?;
        crate::ensure!(
            raw.len() == code_len.div_ceil(2),
            "nibble payload {} bytes, want {}",
            raw.len(),
            code_len.div_ceil(2)
        );
        let mut codes = PackedNibbles::zeros(code_len);
        codes.bytes_mut().copy_from_slice(raw);
        let diag = r.get_f32s()?;
        crate::ensure!(diag.len() == n, "diagonal length {} ≠ n {n}", diag.len());
        let c_scales = r.get_f32s()?;
        let e_scales = r.get_f32s()?;
        Ok(TriJointStore { n, codes, diag, c_scales, e_scales, block: block.max(1) })
    }
}

/// A [`NibbleWriter`] positioned over grid slots `[flat0, flat0 + count)`:
/// computes the run's byte span (`count ≥ 1`), materializes the sub-slice,
/// and sets the start-nibble parity. The single audited site for the
/// nibble→byte span arithmetic all three store passes share.
///
/// # Safety
///
/// Within one parallel pass, every two runs handed to `row_writer` must be
/// **byte-disjoint**. A one-nibble gap between runs is NOT enough (two
/// nibbles share a byte); the store passes guarantee a flat-index distance
/// of ≥ 2 between one run's last slot and the next run's first slot, which
/// is what forces distinct bytes. `ptr` must cover the whole grid.
unsafe fn row_writer<'a>(ptr: *mut u8, flat0: usize, count: usize) -> NibbleWriter<'a> {
    debug_assert!(count >= 1);
    let byte_lo = flat0 >> 1;
    let byte_hi = (flat0 + count - 1) / 2 + 1;
    let sub = std::slice::from_raw_parts_mut(ptr.add(byte_lo), byte_hi - byte_lo);
    NibbleWriter::new(sub, flat0 & 1)
}

/// Per-block absmax over the strictly-lower entries of square `x` (blocks
/// with no lower entries get scale 0 — identical to quantizing the masked
/// matrix, since zeros never raise an absmax). Parallel over block rows;
/// the fold within a block stays row-major like the scalar reference.
fn strict_lower_scales(x: &Matrix, b: usize, scales: &mut Vec<f32>) {
    let n = x.rows();
    let bn = n.div_ceil(b);
    scales.clear();
    scales.resize(bn * bn, 0.0);
    let threads = auto_threads(n * n / 2);
    let scales_ptr = SendPtr(scales.as_mut_ptr());
    parallel_for(bn, threads, |bi| {
        let r0 = bi * b;
        let r1 = (r0 + b).min(n);
        // Safety: each task owns scale row bi.
        let srow = unsafe { std::slice::from_raw_parts_mut(scales_ptr.get().add(bi * bn), bn) };
        for i in r0..r1 {
            let row = x.row(i);
            for (bj, s) in srow.iter_mut().enumerate().take(i / b + 1) {
                let c0 = bj * b;
                let c1 = ((bj + 1) * b).min(i); // strictly below the diagonal
                if c0 >= c1 {
                    continue;
                }
                let mut amax = *s;
                for &v in &row[c0..c1] {
                    amax = amax.max(v.abs());
                }
                *s = amax;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise::QuantConfig;
    use crate::util::rng::Rng;

    fn lower_tri(n: usize, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(n, n, |i, j| {
            if i > j {
                rng.normal_f32(1.0)
            } else if i == j {
                2.0 + rng.uniform() as f32
            } else {
                0.0
            }
        })
    }

    fn strictly_lower(n: usize, rng: &mut Rng, std: f32) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i > j { rng.normal_f32(std) } else { 0.0 })
    }

    #[test]
    fn roundtrip_recovers_structure() {
        let mut rng = Rng::new(1);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let c = lower_tri(17, &mut rng);
        let e = strictly_lower(17, &mut rng, 0.1);
        let store = TriJointStore::store(&c, &e, &quantizer);
        let (c2, e2) = store.load(&quantizer);

        // Structure: C lower-tri with exact diagonal, E strictly lower.
        for i in 0..17 {
            assert_eq!(c2[(i, i)], c[(i, i)], "diag exact");
            for j in (i + 1)..17 {
                assert_eq!(c2[(i, j)], 0.0);
                assert_eq!(e2[(i, j)], 0.0);
            }
            assert_eq!(e2[(i, i)], 0.0);
        }
        // Values: within block-quantization error.
        for i in 0..17 {
            for j in 0..i {
                assert!((c2[(i, j)] - c[(i, j)]).abs() < 0.5, "c[{i}][{j}]");
                assert!((e2[(i, j)] - e[(i, j)]).abs() < 0.05, "e[{i}][{j}]");
            }
        }
    }

    #[test]
    fn c_and_e_do_not_interfere() {
        let mut rng = Rng::new(2);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 4, ..Default::default() });
        let c = lower_tri(9, &mut rng);
        let zero = Matrix::zeros(9, 9);
        // Same C with and without an error state must load the same C.
        let s1 = TriJointStore::store(&c, &zero, &quantizer);
        let e = strictly_lower(9, &mut rng, 5.0);
        let s2 = TriJointStore::store(&c, &e, &quantizer);
        let (c1, _) = s1.load(&quantizer);
        let (c2, e2) = s2.load(&quantizer);
        assert_eq!(c1, c2, "E must not perturb C");
        assert!(e2.max_abs_diff(&e) < 1.0);
    }

    #[test]
    fn init_state_matches_algorithm1() {
        let quantizer = BlockQuantizer::new(QuantConfig::default());
        let s = TriJointStore::init(12, 1e-6, &quantizer);
        let (c, e) = s.load(&quantizer);
        let want = Matrix::eye_scaled(12, (1e-6f32).sqrt());
        assert!(c.max_abs_diff(&want) < 1e-9);
        assert_eq!(e, Matrix::zeros(12, 12));
    }

    #[test]
    fn joint_codes_cost_one_grid() {
        let quantizer = BlockQuantizer::new(QuantConfig { block: 64, ..Default::default() });
        let n = 64;
        let mut rng = Rng::new(3);
        let c = lower_tri(n, &mut rng);
        let e = strictly_lower(n, &mut rng, 0.1);
        let s = TriJointStore::store(&c, &e, &quantizer);
        // One n×n nibble grid = n²/2 bytes.
        let code_bytes = n * n / 2;
        assert_eq!(s.size_bytes(), code_bytes + n * 4 + 2 * 4);
    }

    #[test]
    fn store_into_reuse_matches_fresh_store() {
        // A store refreshed in place (different values, same shape) must be
        // indistinguishable from a freshly built one — stale codes, scales
        // or diagonals must never leak through the buffer reuse.
        let mut rng = Rng::new(4);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let mut s = TriJointStore::store(
            &lower_tri(19, &mut rng),
            &strictly_lower(19, &mut rng, 2.0),
            &quantizer,
        );
        let c = lower_tri(19, &mut rng);
        let e = strictly_lower(19, &mut rng, 0.1);
        s.store_into(&c, &e, &quantizer);
        let fresh = TriJointStore::store(&c, &e, &quantizer);
        let (sc, se) = s.load(&quantizer);
        let (fc, fe) = fresh.load(&quantizer);
        assert_eq!(sc, fc);
        assert_eq!(se, fe);
        assert_eq!(s.size_bytes(), fresh.size_bytes());
    }

    #[test]
    fn staged_store_matches_joint_store() {
        let mut rng = Rng::new(5);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 4, ..Default::default() });
        for n in [6usize, 13] {
            let c = lower_tri(n, &mut rng);
            let e = strictly_lower(n, &mut rng, 0.2);
            let joint = TriJointStore::store(&c, &e, &quantizer);
            let mut staged = TriJointStore::empty();
            staged.store_c_into(&c, &quantizer);
            staged.store_e_into(&e, &quantizer);
            let (jc, je) = joint.load(&quantizer);
            let (sc, se) = staged.load(&quantizer);
            assert_eq!(jc, sc, "n={n}");
            assert_eq!(je, se, "n={n}");

            // store_e_zero ≡ storing an explicit zero matrix.
            let mut ez = TriJointStore::empty();
            ez.store_c_into(&c, &quantizer);
            ez.store_e_zero(&quantizer);
            let explicit = TriJointStore::store(&c, &Matrix::zeros(n, n), &quantizer);
            let (zc, ze) = ez.load(&quantizer);
            let (xc, xe) = explicit.load(&quantizer);
            assert_eq!(zc, xc, "n={n}");
            assert_eq!(ze, xe, "n={n}");
        }
    }

    #[test]
    fn serialization_round_trips_byte_exactly() {
        let mut rng = Rng::new(7);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let c = lower_tri(19, &mut rng);
        let e = strictly_lower(19, &mut rng, 0.1);
        let s = TriJointStore::store(&c, &e, &quantizer);
        let mut w = ByteWriter::new();
        s.write_bytes(&mut w);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let back = TriJointStore::read_bytes(&mut r).unwrap();
        r.finish().unwrap();
        // Canonical form: re-serialization is byte-identical…
        let mut w2 = ByteWriter::new();
        back.write_bytes(&mut w2);
        assert_eq!(bytes, w2.into_bytes());
        // …and both triangles dequantize identically (no re-quantization).
        let (c1, e1) = s.load(&quantizer);
        let (c2, e2) = back.load(&quantizer);
        assert_eq!(c1, c2);
        assert_eq!(e1, e2);
        // Truncated and corrupted inputs fail instead of mis-restoring.
        let mut r = ByteReader::new(&bytes[..bytes.len() / 2]);
        assert!(TriJointStore::read_bytes(&mut r).is_err());
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF; // n is now inconsistent with the grid length
        let mut r = ByteReader::new(&bad);
        assert!(TriJointStore::read_bytes(&mut r).is_err());
    }

    #[test]
    fn load_c_reads_back_packed_codes() {
        // The staged EF flow relies on load_c_into returning exactly the
        // D(C̄) the grid holds, into a dirty buffer.
        let mut rng = Rng::new(6);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let c = lower_tri(11, &mut rng);
        let mut s = TriJointStore::empty();
        s.store_c_into(&c, &quantizer);
        s.store_e_zero(&quantizer);
        let (want, _) = s.load(&quantizer);
        let mut got = Matrix::from_fn(11, 11, |_, _| f32::NAN);
        s.load_c_into(&quantizer, &mut got);
        assert_eq!(got, want);
    }
}

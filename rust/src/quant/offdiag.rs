//! Off-diagonal quantization (paper Sec. 4.1–4.2, Tab. 2).
//!
//! Only the off-diagonal entries are pushed to 4 bits; the diagonal stays
//! f32. Diagonal entries dominate stability of both the preconditioners and
//! the Cholesky factors (Proposition 5.1 quantifies this: the quantization
//! error bound then scales with ‖·‖_off,max rather than ‖·‖_max).

use super::blockwise::{BlockQuantizer, QuantizedMatrix};
use crate::linalg::Matrix;

/// A square matrix with 4-bit off-diagonal codes and an f32 diagonal.
#[derive(Clone, Debug)]
pub struct OffDiagQuantized {
    pub q: QuantizedMatrix,
    pub diag: Vec<f32>,
}

/// Quantize `x` (square) keeping the diagonal exact.
pub fn quantize_offdiag(x: &Matrix, quantizer: &BlockQuantizer) -> OffDiagQuantized {
    assert!(x.is_square(), "off-diagonal quantization needs a square matrix");
    let n = x.rows();
    let mut off = x.clone();
    for i in 0..n {
        off[(i, i)] = 0.0;
    }
    OffDiagQuantized { q: quantizer.quantize(&off), diag: x.diag() }
}

/// Dequantize: `D(codes) + Diag(diag)` (Eq. (18) in Appendix B).
pub fn dequantize_offdiag(s: &OffDiagQuantized, quantizer: &BlockQuantizer) -> Matrix {
    let mut out = quantizer.dequantize(&s.q);
    for (i, &d) in s.diag.iter().enumerate() {
        out[(i, i)] = d;
    }
    out
}

impl OffDiagQuantized {
    /// Physical bytes: packed codes + scales + f32 diagonal.
    pub fn size_bytes(&self) -> usize {
        self.q.size_bytes() + self.diag.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise::QuantConfig;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_is_exact() {
        let mut rng = Rng::new(1);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let mut x = Matrix::randn(20, 20, 1.0, &mut rng);
        // Huge diagonal, as preconditioners have after εI regularization.
        for i in 0..20 {
            x[(i, i)] = 100.0 + i as f32;
        }
        let s = quantize_offdiag(&x, &quantizer);
        let back = dequantize_offdiag(&s, &quantizer);
        for i in 0..20 {
            assert_eq!(back[(i, i)], x[(i, i)], "diag must be bit-exact");
        }
    }

    #[test]
    fn off_diag_error_bounded_by_offdiag_scale() {
        // Appendix B remark: quantizing only off-diagonals bounds error by
        // 2^{-b}·‖S‖_off,∞-ish per block, independent of the diagonal size.
        let mut rng = Rng::new(2);
        let quantizer = BlockQuantizer::new(QuantConfig { block: 64, ..Default::default() });
        let mut x = Matrix::randn(16, 16, 0.01, &mut rng);
        for i in 0..16 {
            x[(i, i)] = 1e6; // dominant diagonal
        }
        let back = dequantize_offdiag(&quantize_offdiag(&x, &quantizer), &quantizer);
        let mut worst = 0.0f32;
        for i in 0..16 {
            for j in 0..16 {
                if i != j {
                    worst = worst.max((back[(i, j)] - x[(i, j)]).abs());
                }
            }
        }
        // Full-matrix quantization would have error ~1e6·2^-4; off-diag keeps
        // it at the off-diagonal magnitude scale.
        assert!(worst < 0.01, "worst={worst}");
    }

    #[test]
    fn size_accounts_diag() {
        let quantizer = BlockQuantizer::new(QuantConfig::default());
        let x = Matrix::eye(64);
        let s = quantize_offdiag(&x, &quantizer);
        assert_eq!(s.size_bytes(), s.q.size_bytes() + 64 * 4);
    }
}

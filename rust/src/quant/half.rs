//! Dense IEEE-754 half-precision preconditioner storage — the `f16` codec.
//!
//! The memory/accuracy midpoint between dense f32 (Algorithm 2) and the
//! 4-bit families: exactly 2 bytes per element, no block scales, no
//! diagonal side-band, and a ~`2⁻¹¹` relative round-trip error that is two
//! orders of magnitude below 4-bit quantization noise. Conversion is the
//! software routine in [`crate::quant::mapping`] (the crate is
//! dependency-free), including gradual underflow so `ε·I` initial states
//! survive the trip.

use super::codec::PrecondCodec;
use super::mapping::{f16_to_f32, f32_to_f16};
use crate::linalg::{Matrix, ScratchArena};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;

/// Half-precision storage of one preconditioner matrix (`f16` registry key).
#[derive(Clone, Debug, Default)]
pub struct F16Codec {
    rows: usize,
    cols: usize,
    data: Vec<u16>,
}

impl PrecondCodec for F16Codec {
    fn key(&self) -> &'static str {
        "f16"
    }

    fn store(&mut self, x: &Matrix) {
        self.store_into(x, &mut ScratchArena::new());
    }

    fn load(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.load_into(&mut out, &mut ScratchArena::new());
        out
    }

    fn store_into(&mut self, x: &Matrix, _scratch: &mut ScratchArena) {
        self.rows = x.rows();
        self.cols = x.cols();
        self.data.clear();
        self.data.extend(x.data().iter().map(|&v| f32_to_f16(v)));
    }

    fn load_into(&self, out: &mut Matrix, _scratch: &mut ScratchArena) {
        assert!(!self.data.is_empty(), "F16Codec::load before store");
        assert_eq!((out.rows(), out.cols()), (self.rows, self.cols));
        for (slot, &h) in out.data_mut().iter_mut().zip(self.data.iter()) {
            *slot = f16_to_f32(h);
        }
    }

    /// Exactly 2 bytes per element — no scales, no f32 side-band.
    fn size_bytes(&self) -> usize {
        self.data.len() * 2
    }

    /// Raw little-endian u16 payload after the shape header — restoring
    /// skips the f32→f16 conversion entirely, so the state is bit-exact.
    fn save_state(&self, out: &mut ByteWriter) {
        out.put_u64(self.rows as u64);
        out.put_u64(self.cols as u64);
        let mut raw = Vec::with_capacity(self.data.len() * 2);
        for &h in &self.data {
            raw.extend_from_slice(&h.to_le_bytes());
        }
        out.put_bytes(&raw);
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        let rows = r.get_len()?;
        let cols = r.get_len()?;
        let raw = r.get_bytes()?;
        crate::ensure!(
            raw.len() == rows * cols * 2,
            "f16 payload {} bytes, want {rows}x{cols} halves",
            raw.len()
        );
        self.rows = rows;
        self.cols = cols;
        self.data = raw.chunks_exact(2).map(|b| u16::from_le_bytes([b[0], b[1]])).collect();
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_is_half_precision_accurate() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(12, 12, 3.0, &mut rng);
        let mut c = F16Codec::default();
        c.store(&x);
        let back = c.load();
        for i in 0..12 {
            for j in 0..12 {
                let (a, b) = (x[(i, j)], back[(i, j)]);
                assert!((a - b).abs() <= a.abs() / 2048.0 + 1e-24, "({i},{j}): {a} vs {b}");
            }
        }
    }

    #[test]
    fn size_is_two_bytes_per_element() {
        let mut c = F16Codec::default();
        assert_eq!(c.size_bytes(), 0);
        c.store(&Matrix::zeros(17, 17));
        assert_eq!(c.size_bytes(), 17 * 17 * 2);
    }

    #[test]
    fn init_survives_subnormal_epsilon() {
        let mut c = F16Codec::default();
        c.init(8, 1e-6);
        let back = c.load();
        assert!(back.max_abs_diff(&Matrix::eye_scaled(8, 1e-6)) < 1e-7);
    }
}

//! Eigenvalue-corrected 4-bit preconditioner storage — the `ec4` codec.
//!
//! The scheme of *4-bit Shampoo for Memory-Efficient Network Training*
//! (arXiv 2405.18144), expressed through [`PrecondCodec`]: factor the
//! incoming SPD matrix as `A = V·diag(λ)·Vᵀ` ([`eig_sym_with`]), quantize
//! the **orthogonal eigenvector matrix** block-wise to 4 bits, and keep the
//! eigenvalue vector in f32 (`n` floats — the same order of side-band cost
//! as the f32 diagonal the VQ codecs keep). Quantizing `V` instead of `A`
//! moves the 4-bit noise into the eigenbasis, where a cheap correction can
//! undo its first-order effect on the spectrum.
//!
//! **Eigenvalue correction at `load`:** the dequantized `Ṽ` is no longer
//! orthonormal, so `Ṽ·diag(λ)·Ṽᵀ` would scale mode `j` by `‖ṽ_j‖²`. Each
//! column is therefore renormalized — the reconstruction is
//! `Σ_j λ_j·(ṽ_j/‖ṽ_j‖)(ṽ_j/‖ṽ_j‖)ᵀ`, which removes the per-mode scale
//! error exactly; what remains is the second-order cross-orthogonality
//! residual `Σ_{k≠j} λ_k·⟨ũ_j, ũ_k⟩²`. The spectral test in
//! `tests/integration_quant.rs` pins the reconstructed eigenvalues of an
//! inverse 4-th root against `inverse_pth_root_eig`. With `λ ≥ 0` the
//! reconstruction is PSD by construction, like the Cholesky codecs.

use super::blockwise::{BlockQuantizer, QuantizedMatrix};
use super::codec::{CodecCtx, PrecondCodec};
use crate::linalg::{eig_sym_with, matmul_nt_into_planned, EigWork, Matrix, ScratchArena};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;
use std::cell::RefCell;
use std::sync::Arc;

/// Jacobi settings for the refresh-path decomposition: 4-bit quantization
/// noise (~1e-2 relative) dominates long before the eigensolver's last
/// digits, so the codec stops far earlier than the `1e-12` oracle runs.
const EIG_TOL: f64 = 1e-7;
const EIG_MAX_SWEEPS: usize = 16;

thread_local! {
    /// Shared Jacobi workspace (`2·n²` f64s + the sort permutation). One
    /// per WORKER THREAD, not per codec slot: a model's hundreds of ec4
    /// slots would otherwise each retain ~16 B/elem of f64 scratch —
    /// dwarfing the ~0.5 B/elem of quantized state `size_bytes` reports.
    /// Refreshes run on the scoped `util::pool` workers, whose
    /// thread-locals die with the step's scope, so this is as transient as
    /// the `ScratchArena`s it rides next to.
    static EIG_WORK: RefCell<EigWork> = RefCell::new(EigWork::default());
}

/// Eigenvalue-corrected 4-bit storage of one preconditioner matrix
/// (`ec4` registry key).
#[derive(Clone, Debug)]
pub struct Ec4Codec {
    eps: f32,
    q: Arc<BlockQuantizer>,
    /// f32 eigenvalues, ascending (persistent state: `4n` bytes).
    vals: Vec<f32>,
    /// 4-bit block-quantized eigenvector matrix (persistent state).
    vecs: Option<QuantizedMatrix>,
}

impl Ec4Codec {
    pub fn new(ctx: &CodecCtx) -> Ec4Codec {
        Ec4Codec {
            eps: ctx.eps,
            q: Arc::clone(&ctx.quantizer),
            vals: Vec::new(),
            vecs: None,
        }
    }
}

impl PrecondCodec for Ec4Codec {
    fn key(&self) -> &'static str {
        "ec4"
    }

    fn init(&mut self, dim: usize, eps: f32) {
        self.eps = eps;
        // ε·I decomposes exactly (V = I quantizes bit-exactly: ±1 and 0 are
        // codebook levels), so the initial reconstruction is exactly ε·I.
        self.store(&Matrix::eye_scaled(dim, eps));
    }

    fn store(&mut self, x: &Matrix) {
        self.store_into(x, &mut ScratchArena::new());
    }

    fn load(&self) -> Matrix {
        let n = self.vecs.as_ref().expect("Ec4Codec::load before store").rows;
        let mut out = Matrix::zeros(n, n);
        self.load_into(&mut out, &mut ScratchArena::new());
        out
    }

    /// Factor → quantize eigenvectors → keep eigenvalues. The eigenvector
    /// buffer comes from the caller's arena and the Jacobi workspace /
    /// packed-code buffers are reused, so a warmed-up refresh allocates
    /// nothing.
    fn store_into(&mut self, x: &Matrix, scratch: &mut ScratchArena) {
        assert!(x.is_square(), "ec4 stores square (preconditioner-shaped) matrices");
        let n = x.rows();
        let mut v = scratch.take(n, n);
        if x.has_non_finite() {
            // Pathological input (same contract as the Cholesky codec's
            // jitter fallback): reset to ε·I and let the EMA rebuild.
            v.set_eye_scaled(1.0);
            self.vals.clear();
            self.vals.resize(n, self.eps);
        } else {
            EIG_WORK.with(|w| {
                let work = &mut w.borrow_mut();
                eig_sym_with(x, EIG_TOL, EIG_MAX_SWEEPS, work, &mut self.vals, &mut v);
            });
        }
        match &mut self.vecs {
            Some(s) => self.q.quantize_into(&v, s),
            slot => *slot = Some(self.q.quantize(&v)),
        }
        scratch.recycle(v);
    }

    /// `Σ_j λ_j·(ṽ_j/‖ṽ_j‖)(ṽ_j/‖ṽ_j‖)ᵀ` into `out` — dequantize, fold the
    /// per-column eigenvalue correction into one copy, and close with a
    /// single `A·Bᵀ` product. All temporaries are arena-backed.
    fn load_into(&self, out: &mut Matrix, scratch: &mut ScratchArena) {
        let s = self.vecs.as_ref().expect("Ec4Codec::load before store");
        let n = s.rows;
        let mut v = scratch.take(n, n);
        self.q.dequantize_into(s, &mut v);
        // Column norms, accumulated row-major.
        let mut w = scratch.take(1, n);
        for i in 0..n {
            let row = v.row(i);
            let wr = w.row_mut(0);
            for j in 0..n {
                wr[j] += row[j] * row[j];
            }
        }
        // In-place: w_j ← λ_j / ‖ṽ_j‖² (a dropped column reconstructs as 0).
        {
            let wr = w.row_mut(0);
            for j in 0..n {
                // ‖ṽ_j‖² = 0 or a non-finite λ divides to non-finite → 0.
                let c = self.vals[j] / wr[j];
                wr[j] = if c.is_finite() { c } else { 0.0 };
            }
        }
        let mut scaled = scratch.take(n, n);
        for i in 0..n {
            let (src, wr) = (v.row(i), w.row(0));
            let dst = scaled.row_mut(i);
            for j in 0..n {
                dst[j] = src[j] * wr[j];
            }
        }
        matmul_nt_into_planned(&scaled, &v, out, scratch.plan());
        scratch.recycle(scaled);
        scratch.recycle(w);
        scratch.recycle(v);
    }

    /// Quantized eigenvector grid (codes + block scales) plus the f32
    /// eigenvalue vector.
    fn size_bytes(&self) -> usize {
        self.vecs.as_ref().map(|s| s.size_bytes()).unwrap_or(0) + self.vals.len() * 4
    }

    /// Packed eigenvector codes + raw f32 eigenvalues — no
    /// re-decomposition on restore, so resume continues from the exact
    /// stored eigenbasis.
    fn save_state(&self, out: &mut ByteWriter) {
        out.put_f32s(&self.vals);
        match &self.vecs {
            Some(s) => {
                out.put_u8(1);
                s.write_bytes(out);
            }
            None => out.put_u8(0),
        }
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.vals = r.get_f32s()?;
        self.vecs = match r.get_u8()? {
            0 => None,
            _ => Some(QuantizedMatrix::read_bytes(r)?),
        };
        if let Some(s) = &self.vecs {
            crate::ensure!(
                self.vals.len() == s.rows,
                "eigenvalue count {} vs eigenvector rows {}",
                self.vals.len(),
                s.rows
            );
        }
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig_sym;
    use crate::quant::{BlockQuantizer, QuantConfig};
    use crate::util::rng::Rng;

    fn ctx() -> CodecCtx {
        let q = BlockQuantizer::new(QuantConfig {
            min_quant_elems: 0,
            block: 16,
            ..Default::default()
        });
        CodecCtx::new(1e-6, 0.95, Arc::new(q))
    }

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let g = Matrix::randn(n, n + 4, 1.0, &mut rng);
        let mut a = crate::linalg::syrk(&g);
        a.scale(1.0 / n as f32);
        a.add_diag(0.5);
        a
    }

    #[test]
    fn corrected_spectrum_tracks_stored_eigenvalues() {
        // The correction's point: the reconstruction's eigenvalues track
        // the stored f32 spectrum (what's left is the second-order
        // cross-orthogonality residual), and strictly beat the uncorrected
        // `Ṽ·diag(λ)·Ṽᵀ` per-mode scale error in aggregate.
        let ctx = ctx();
        let a = spd(20, 1);
        let mut c = Ec4Codec::new(&ctx);
        c.store(&a);
        let back = c.load();
        let (got, _) = eig_sym(&back, 1e-10, 100);
        let lam_max = *c.vals.last().unwrap();
        // Ostrowski: back = Λ^½·(ŨᵀŨ)·Λ^½-congruent, so every mode is off
        // by at most the MULTIPLICATIVE factor ‖ŨᵀŨ − I‖ — small modes are
        // tracked relatively, which additive 4-bit noise would not give.
        for (j, (&g, &want)) in got.iter().zip(c.vals.iter()).enumerate() {
            assert!(
                (g - want).abs() <= 0.35 * want.abs() + 0.02 * lam_max,
                "mode {j}: reconstructed λ {g} vs stored {want} (λmax {lam_max})"
            );
        }
    }

    #[test]
    fn reconstruction_is_psd_and_close() {
        let ctx = ctx();
        let a = spd(24, 2);
        let mut c = Ec4Codec::new(&ctx);
        c.store(&a);
        let back = c.load();
        assert!(back.max_abs_diff(&back.transpose()) < 1e-5, "symmetric by construction");
        let (vals, _) = eig_sym(&back, 1e-10, 100);
        assert!(vals[0] >= -1e-5, "λ ≥ 0 stored ⇒ PSD reconstruction, got {}", vals[0]);
        let rel = crate::linalg::relative_error(&a, &back);
        assert!(rel < 0.3, "relative reconstruction error {rel}");
    }

    #[test]
    fn non_finite_input_resets_to_eps_identity() {
        let ctx = ctx();
        let mut c = Ec4Codec::new(&ctx);
        let mut bad = Matrix::zeros(8, 8);
        bad[(3, 4)] = f32::NAN;
        c.store(&bad);
        let back = c.load();
        assert!(!back.has_non_finite());
        assert!(back.max_abs_diff(&Matrix::eye_scaled(8, 1e-6)) < 1e-7);
    }

    #[test]
    fn size_counts_codes_scales_and_eigenvalues() {
        let ctx = ctx();
        let mut c = Ec4Codec::new(&ctx);
        c.store(&spd(32, 3));
        let scales = 32usize.div_ceil(16).pow(2) * 4;
        assert_eq!(c.size_bytes(), (32 * 32usize).div_ceil(2) + scales + 32 * 4);
    }
}

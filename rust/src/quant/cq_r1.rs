//! Cholesky quantization with a rank-1 row-scale correction — the `cq-r1`
//! codec.
//!
//! Layered on the plain 4-bit Cholesky scheme (Sec. 4.2): `store` factors
//! the incoming PSD matrix, packs the factor into the Fig. 2 triangular
//! buffer exactly like `cq4`, and additionally keeps a **per-row f32 scale
//! vector** `s` — the least-squares fit `s_i = ⟨C_i, D(C̄)_i⟩ / ‖D(C̄)_i‖²`
//! over each stored row. `load` folds the scales back in and reconstructs
//! `(S·D(C̄))·(S·D(C̄))ᵀ` with `S = diag(s)` — a diagonal congruence, so the
//! PSD-by-construction guarantee of the Cholesky family is untouched. Per
//! row the fitted scale can only tighten the factor error (it minimizes it
//! over a scalar; `s ≡ 1` recovers `cq4` exactly), at a cost of `4n` bytes —
//! the same side-band order as the f32 diagonal already stored.
//!
//! This is the blockwise analogue of the rank-1 corrections in *Memory
//! Efficient Optimizers with 4-bit States* (arXiv 2309.01507), applied to
//! the factor rather than to raw optimizer moments.

use super::blockwise::BlockQuantizer;
use super::codec::{CodecCtx, PrecondCodec};
use super::tri_store::TriJointStore;
use crate::linalg::{cholesky_jittered_into_planned, matmul_nt_into_planned, Matrix, ScratchArena};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;
use std::sync::Arc;

/// 4-bit Cholesky factor + per-row f32 scale correction (`cq-r1` key).
#[derive(Clone, Debug)]
pub struct CholeskyR1Codec {
    eps: f32,
    q: Arc<BlockQuantizer>,
    s: Option<TriJointStore>,
    /// Per-row least-squares scales, refreshed at every `store`.
    row_scale: Vec<f32>,
}

impl CholeskyR1Codec {
    pub fn new(ctx: &CodecCtx) -> CholeskyR1Codec {
        CholeskyR1Codec {
            eps: ctx.eps,
            q: Arc::clone(&ctx.quantizer),
            s: None,
            row_scale: Vec::new(),
        }
    }
}

impl PrecondCodec for CholeskyR1Codec {
    fn key(&self) -> &'static str {
        "cq-r1"
    }

    /// `C₀ = √ε·I` with unit scales — bit-identical to the `cq4` initial
    /// state plus a neutral correction.
    fn init(&mut self, dim: usize, eps: f32) {
        self.eps = eps;
        self.s = Some(TriJointStore::init(dim, eps, &self.q));
        self.row_scale.clear();
        self.row_scale.resize(dim, 1.0);
    }

    fn store(&mut self, x: &Matrix) {
        self.store_into(x, &mut ScratchArena::new());
    }

    fn load(&self) -> Matrix {
        let n = self.s.as_ref().expect("CholeskyR1Codec::load before store").n;
        let mut out = Matrix::zeros(n, n);
        self.load_into(&mut out, &mut ScratchArena::new());
        out
    }

    /// Factor → pack (factor quantized once, like the fused `cq4` path) →
    /// read `D(C̄)` back from the packed codes → fit the row scales.
    fn store_into(&mut self, x: &Matrix, scratch: &mut ScratchArena) {
        let n = x.rows();
        let mut c = scratch.take(n, n);
        if cholesky_jittered_into_planned(x, self.eps, 12, &mut c, scratch.plan()).is_err() {
            // Same reset contract as CholeskyCodec: a pathological Gram
            // falls back to the initial factor.
            c.set_eye_scaled(self.eps.sqrt());
        }
        let store = self.s.get_or_insert_with(TriJointStore::empty);
        store.store_c_into(&c, &self.q);
        store.store_e_zero(&self.q);
        let mut d = scratch.take(n, n);
        store.load_c_into(&self.q, &mut d);
        self.row_scale.clear();
        for i in 0..n {
            let (crow, drow) = (c.row(i), d.row(i));
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            // Lower triangle incl. diagonal (the diagonal is stored exactly,
            // pulling the fit toward 1 as the off-diag error vanishes).
            for j in 0..=i {
                num += crow[j] as f64 * drow[j] as f64;
                den += drow[j] as f64 * drow[j] as f64;
            }
            let s = if den > 0.0 { (num / den) as f32 } else { 1.0 };
            self.row_scale.push(if s.is_finite() { s } else { 1.0 });
        }
        scratch.recycle(d);
        scratch.recycle(c);
    }

    /// `(S·D(C̄))·(S·D(C̄))ᵀ` into `out`, factor staged in the arena.
    fn load_into(&self, out: &mut Matrix, scratch: &mut ScratchArena) {
        let store = self.s.as_ref().expect("CholeskyR1Codec::load before store");
        let mut c = scratch.take(store.n, store.n);
        store.load_c_into(&self.q, &mut c);
        for i in 0..store.n {
            let s = self.row_scale[i];
            for v in c.row_mut(i).iter_mut() {
                *v *= s;
            }
        }
        matmul_nt_into_planned(&c, &c, out, scratch.plan());
        scratch.recycle(c);
    }

    /// The `cq4` triangular payload (lower-tri nibbles + f32 diagonal + one
    /// scale set) plus the `4n`-byte row-scale vector.
    fn size_bytes(&self) -> usize {
        self.s.as_ref().map(|s| s.size_bytes_cq_only()).unwrap_or(0) + self.row_scale.len() * 4
    }

    /// Triangular store bytes plus the row-scale side-band — no
    /// re-factorization or scale refit on restore.
    fn save_state(&self, out: &mut ByteWriter) {
        match &self.s {
            Some(s) => {
                out.put_u8(1);
                s.write_bytes(out);
            }
            None => out.put_u8(0),
        }
        out.put_f32s(&self.row_scale);
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.s = match r.get_u8()? {
            0 => None,
            _ => Some(TriJointStore::read_bytes(r)?),
        };
        self.row_scale = r.get_f32s()?;
        if let Some(s) = &self.s {
            crate::ensure!(
                self.row_scale.len() == s.n,
                "row-scale len {} vs factor dim {}",
                self.row_scale.len(),
                s.n
            );
        }
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig_sym;
    use crate::quant::QuantConfig;
    use crate::util::rng::Rng;

    fn ctx() -> CodecCtx {
        let q = BlockQuantizer::new(QuantConfig {
            min_quant_elems: 0,
            block: 16,
            ..Default::default()
        });
        CodecCtx::new(1e-6, 0.95, Arc::new(q))
    }

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let g = Matrix::randn(n, n + 4, 1.0, &mut rng);
        let mut a = crate::linalg::syrk(&g);
        a.add_diag(0.5);
        a
    }

    #[test]
    fn row_scales_never_hurt_the_factor_fit() {
        // Per row the LS scale minimizes ‖s·D_i − C_i‖ over s, so the scaled
        // factor is at least as close as the raw cq4 factor row-by-row.
        let ctx = ctx();
        let a = spd(24, 1);
        let mut r1 = CholeskyR1Codec::new(&ctx);
        r1.store(&a);
        let mut plain = crate::quant::codec::CholeskyCodec::new(false, &ctx);
        plain.store(&a);
        let e_r1 = crate::linalg::relative_error(&a, &r1.load());
        let e_cq = crate::linalg::relative_error(&a, &plain.load());
        assert!(e_r1 <= e_cq * 1.05 + 1e-6, "cq-r1 {e_r1} must track ≤ cq4 {e_cq}");
    }

    #[test]
    fn reconstruction_stays_psd() {
        let ctx = ctx();
        let mut c = CholeskyR1Codec::new(&ctx);
        c.store(&spd(16, 2));
        let (vals, _) = eig_sym(&c.load(), 1e-10, 100);
        assert!(vals[0] >= -1e-6, "diagonal congruence keeps PSD, λmin={}", vals[0]);
    }

    #[test]
    fn size_adds_one_f32_per_row_over_cq4() {
        let ctx = ctx();
        let a = spd(32, 3);
        let mut r1 = CholeskyR1Codec::new(&ctx);
        r1.store(&a);
        let mut plain = crate::quant::codec::CholeskyCodec::new(false, &ctx);
        plain.store(&a);
        assert_eq!(r1.size_bytes(), plain.size_bytes() + 32 * 4);
    }

    #[test]
    fn pathological_input_resets() {
        let ctx = ctx();
        let mut c = CholeskyR1Codec::new(&ctx);
        let mut bad = Matrix::zeros(6, 6);
        bad[(0, 0)] = f32::NAN;
        c.store(&bad);
        assert!(!c.load().has_non_finite());
    }
}

//! Error feedback for Cholesky quantization (paper Sec. 4.3, Eq. (10)–(11)).
//!
//! Before quantizing the fresh Cholesky factor we *compensate* it with the
//! dequantized error state (Eq. 10); afterwards the error state is updated
//! by an exponential moving average of the new quantization residual
//! (Eq. 11). Both states are strictly lower triangular.

use crate::linalg::Matrix;

/// The EF update rule with momentum `βₑ`.
#[derive(Clone, Copy, Debug)]
pub struct ErrorFeedback {
    pub beta_e: f32,
}

impl ErrorFeedback {
    pub fn new(beta_e: f32) -> ErrorFeedback {
        assert!((0.0..1.0).contains(&beta_e), "βₑ must be in [0,1)");
        ErrorFeedback { beta_e }
    }

    /// Eq. (10): the matrix that actually gets quantized, `C_k + E_{k−1}`.
    /// Only the strictly-lower triangle is compensated (the diagonal stays
    /// the exact `C_k` diagonal — it is never quantized).
    pub fn compensate(&self, c: &Matrix, e_prev: &Matrix) -> Matrix {
        assert_eq!((c.rows(), c.cols()), (e_prev.rows(), e_prev.cols()));
        let n = c.rows();
        Matrix::from_fn(n, n, |i, j| {
            if i > j {
                c[(i, j)] + e_prev[(i, j)]
            } else {
                c[(i, j)]
            }
        })
    }

    /// Eq. (11): `E_k = βₑ·E_{k−1} + (1−βₑ)·(C_k + E_{k−1} − D(C̄_k))`,
    /// restricted to the strictly-lower triangle (diagonal error is zero by
    /// construction).
    pub fn update(
        &self,
        c: &Matrix,
        e_prev: &Matrix,
        c_dequantized: &Matrix,
    ) -> Matrix {
        let n = c.rows();
        Matrix::from_fn(n, n, |i, j| {
            if i > j {
                let residual = c[(i, j)] + e_prev[(i, j)] - c_dequantized[(i, j)];
                self.beta_e * e_prev[(i, j)] + (1.0 - self.beta_e) * residual
            } else {
                0.0
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise::{BlockQuantizer, QuantConfig};
    use crate::util::rng::Rng;

    fn lower_tri(n: usize, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(n, n, |i, j| {
            if i > j {
                rng.normal_f32(1.0)
            } else if i == j {
                3.0
            } else {
                0.0
            }
        })
    }

    #[test]
    fn error_state_stays_strictly_lower() {
        let mut rng = Rng::new(1);
        let ef = ErrorFeedback::new(0.95);
        let q = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let c = lower_tri(12, &mut rng);
        let mut e = Matrix::zeros(12, 12);
        for _ in 0..5 {
            let comp = ef.compensate(&c, &e);
            let back = q.roundtrip(&comp);
            e = ef.update(&c, &e, &back);
            for i in 0..12 {
                for j in i..12 {
                    assert_eq!(e[(i, j)], 0.0, "upper/diag must stay zero");
                }
            }
        }
    }

    #[test]
    fn perfect_quantizer_drives_error_to_zero() {
        // If D(Q(·)) is exact, residual = E_{k−1}, so
        // E_k = βₑE + (1−βₑ)E = E … wait: residual = C + E − C − E = 0 only
        // when dequantization returns the compensated matrix exactly; then
        // E_k = βₑ·E_{k−1}, decaying geometrically.
        let ef = ErrorFeedback::new(0.5);
        let mut rng = Rng::new(2);
        let c = lower_tri(6, &mut rng);
        let mut e = Matrix::from_fn(6, 6, |i, j| if i > j { 1.0 } else { 0.0 });
        for _ in 0..20 {
            let comp = ef.compensate(&c, &e);
            e = ef.update(&c, &e, &comp); // exact dequantization
        }
        assert!(crate::linalg::max_abs(&e) < 1e-5);
    }

    #[test]
    fn compensation_reduces_accumulated_bias() {
        // Repeatedly quantizing the SAME factor: with EF the time-average of
        // dequantized factors converges toward the true factor; without EF it
        // stays at the one-shot quantization error.
        let mut rng = Rng::new(3);
        let n = 16;
        let c = lower_tri(n, &mut rng);
        let q = BlockQuantizer::new(QuantConfig { block: 8, ..Default::default() });
        let ef = ErrorFeedback::new(0.9);

        let steps = 200;
        let mut e = Matrix::zeros(n, n);
        let mut avg_ef = Matrix::zeros(n, n);
        for _ in 0..steps {
            let comp = ef.compensate(&c, &e);
            let back = q.roundtrip(&comp);
            e = ef.update(&c, &e, &back);
            avg_ef.axpy(1.0 / steps as f32, &back);
        }
        let one_shot = q.roundtrip(&c);

        // Compare strictly-lower error only (diagonals identical).
        let mut err_ef = 0.0f64;
        let mut err_vq = 0.0f64;
        for i in 0..n {
            for j in 0..i {
                err_ef += ((avg_ef[(i, j)] - c[(i, j)]) as f64).powi(2);
                err_vq += ((one_shot[(i, j)] - c[(i, j)]) as f64).powi(2);
            }
        }
        assert!(
            err_ef < err_vq * 0.5,
            "EF time-average should beat one-shot: ef={err_ef:.3e} vq={err_vq:.3e}"
        );
    }

    #[test]
    #[should_panic(expected = "βₑ must be in [0,1)")]
    fn rejects_bad_beta() {
        ErrorFeedback::new(1.0);
    }
}

//! The open preconditioner-codec API.
//!
//! A [`PrecondCodec`] is the persistent storage of ONE preconditioner-shaped
//! matrix slot (a Gram side `L`/`R` or an inverse root `L̂`/`R̂`): it owns the
//! representation (f32, 4-bit off-diagonal, quantized Cholesky factor, …),
//! knows how to absorb a fresh f32 value (`store`), reconstruct it (`load`),
//! and account for its exact physical bytes (`size_bytes`).
//!
//! Every variant the paper studies ships as a codec:
//!
//! | key        | representation                                   | paper  |
//! |------------|--------------------------------------------------|--------|
//! | `f32`      | dense f32                                        | Alg. 2 |
//! | `vq4`      | 4-bit block-wise, f32 diagonal                   | §4.1   |
//! | `vq4-full` | 4-bit block-wise incl. diagonal (Tab. 2 ablation) | §3.2   |
//! | `cq4`      | 4-bit quantized Cholesky factor                  | §4.2   |
//! | `cq4-ef`   | `cq4` + error feedback in the upper triangle     | §4.3   |
//! | `bw8`      | 8-bit block-wise, f32 diagonal                   | —      |
//! | `ec4`      | eigenvalue-corrected 4-bit eigenfactors          | [^ec]  |
//! | `f16`      | dense IEEE half precision                        | —      |
//! | `cq-r1`    | `cq4` + per-row f32 scale correction             | [^r1]  |
//!
//! [^ec]: *4-bit Shampoo* (arXiv 2405.18144), see [`crate::quant::ec4`].
//!
//! [^r1]: rank-1 correction in the spirit of arXiv 2309.01507, see
//! [`crate::quant::cq_r1`].
//!
//! The set is *open*: [`register`] adds a codec at runtime, and everything
//! above the quant layer (Shampoo state, TOML specs, the memory accountant's
//! callers, the codec benches and the codec-generic test suite) resolves
//! codecs through [`lookup`] by string key. Adding a representation is one
//! `impl PrecondCodec` plus one `register` call — no enum arms to edit
//! (`docs/ARCHITECTURE.md` walks through the full recipe):
//!
//! ```
//! use quartz::quant::codec::lookup;
//! use quartz::quant::{BlockQuantizer, CodecCtx, QuantConfig};
//! use quartz::linalg::Matrix;
//! use std::sync::Arc;
//!
//! let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
//! let ctx = CodecCtx::new(1e-6, 0.95, Arc::new(q));
//! // Every registered key resolves to side/root constructors…
//! let builder = lookup("cq4-ef").expect("built-in");
//! let mut side = (builder.side)(&ctx);
//! // …and round-trips a preconditioner within its representation error.
//! side.init(8, 1e-6);
//! assert!(side.load().max_abs_diff(&Matrix::eye_scaled(8, 1e-6)) < 1e-6);
//! ```

use super::blockwise::{BlockQuantizer, QuantConfig, QuantizedMatrix};
use super::cq_r1::CholeskyR1Codec;
use super::ec4::Ec4Codec;
use super::half::F16Codec;
use super::offdiag::{dequantize_offdiag, quantize_offdiag, OffDiagQuantized};
use super::tri_store::TriJointStore;
use crate::linalg::{cholesky_jittered_into_planned, matmul_nt_into_planned, Matrix, ScratchArena};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;
use std::sync::{Arc, Mutex, OnceLock};

/// Shared context handed to codec constructors: the numerical-stability
/// constant, the EF momentum, and the experiment's block quantizer.
#[derive(Clone, Debug)]
pub struct CodecCtx {
    /// Stability constant ε (initial state is `ε·I` for sides).
    pub eps: f32,
    /// Error-feedback EMA momentum βₑ (Eq. 11); ignored by non-EF codecs.
    pub beta_e: f32,
    /// The experiment's 4-bit block quantizer (block size, mapping).
    pub quantizer: Arc<BlockQuantizer>,
}

impl CodecCtx {
    pub fn new(eps: f32, beta_e: f32, quantizer: Arc<BlockQuantizer>) -> CodecCtx {
        CodecCtx { eps, beta_e, quantizer }
    }
}

/// Persistent storage of one preconditioner matrix, behind a uniform
/// store/load/account interface. Implementations own their representation.
pub trait PrecondCodec: std::fmt::Debug + Send {
    /// Registry key of this codec (`"f32"`, `"cq4-ef"`, …).
    fn key(&self) -> &'static str;

    /// Reset to the canonical initial state for a `dim×dim` slot: the
    /// stored value reconstructs to `eps·I` (Algorithm 1/2 inputs).
    fn init(&mut self, dim: usize, eps: f32) {
        self.store(&Matrix::eye_scaled(dim, eps));
    }

    /// Absorb a fresh f32 value into this representation. For side codecs
    /// `x` is the EMA'd Gram statistic (symmetric PSD up to quantization
    /// noise); EF-aware codecs compensate with their error state here.
    fn store(&mut self, x: &Matrix);

    /// Reconstruct the stored matrix to f32 (Eq. (5) `D(L̄)`, or Eq. (7)
    /// `D(C̄)·D(C̄)ᵀ` for Cholesky codecs).
    fn load(&self) -> Matrix;

    /// Scratch-aware [`Self::store`]: temporaries come from the caller's
    /// arena and internal buffers are reused, so a steady-state refresh
    /// performs no heap allocation. The default falls back to `store`
    /// (correct for any external codec; override to join the
    /// allocation-free pipeline). Semantically identical to `store`.
    fn store_into(&mut self, x: &Matrix, _scratch: &mut ScratchArena) {
        self.store(x);
    }

    /// Scratch-aware [`Self::load`]: reconstruct into a caller-owned
    /// `dim×dim` buffer (fully overwritten). The default falls back to
    /// `load` plus a copy. Semantically identical to `load`.
    fn load_into(&self, out: &mut Matrix, _scratch: &mut ScratchArena) {
        out.copy_from(&self.load());
    }

    /// Exact physical bytes of the persistent state (the quantity behind
    /// the paper's memory tables; no caches, no transient scratch).
    fn size_bytes(&self) -> usize;

    /// The strictly-lower error-feedback state, if this codec keeps one.
    fn error_state(&self) -> Option<Matrix> {
        None
    }

    /// Serialize this codec's persistent state for checkpointing.
    ///
    /// The default reconstructs through [`Self::load`] and writes a dense
    /// f32 matrix — correct for any external codec, but only
    /// reconstruction-accurate. Every built-in overrides the pair to dump
    /// its *physical* representation (packed codes, block scales, EF
    /// triangles, exact diagonals) so that restore → save reproduces the
    /// identical byte string with no re-quantization or re-factorization —
    /// the property the bit-identical-resume oracle pins.
    ///
    /// Configuration (ε, βₑ, the shared quantizer) is NOT serialized: a
    /// restored codec keeps the config it was constructed with, and the
    /// checkpoint's spec hash guards against restoring under a different
    /// experiment configuration.
    fn save_state(&self, out: &mut ByteWriter) {
        let m = self.load();
        out.put_u8(1);
        m.write_bytes(out);
    }

    /// Inverse of [`Self::save_state`]. The default reads the dense f32
    /// fallback and re-absorbs it through [`Self::store`].
    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        crate::ensure!(r.get_u8()? == 1, "{}: empty saved state", self.key());
        let m = Matrix::read_bytes(r)?;
        self.store(&m);
        Ok(())
    }

    /// Clone through the trait object (enables `Clone` for boxed codecs).
    fn clone_box(&self) -> Box<dyn PrecondCodec>;
}

impl Clone for Box<dyn PrecondCodec> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

// ---------------------------------------------------------------- f32 ----

/// Dense f32 storage (Algorithm 2, and the small-tensor exemption).
#[derive(Clone, Debug, Default)]
pub struct F32Codec {
    m: Option<Matrix>,
}

impl PrecondCodec for F32Codec {
    fn key(&self) -> &'static str {
        "f32"
    }

    fn store(&mut self, x: &Matrix) {
        self.store_into(x, &mut ScratchArena::new());
    }

    fn load(&self) -> Matrix {
        self.m.clone().expect("F32Codec::load before store")
    }

    fn store_into(&mut self, x: &Matrix, _scratch: &mut ScratchArena) {
        match &mut self.m {
            Some(m) if (m.rows(), m.cols()) == (x.rows(), x.cols()) => m.copy_from(x),
            slot => *slot = Some(x.clone()),
        }
    }

    fn load_into(&self, out: &mut Matrix, _scratch: &mut ScratchArena) {
        out.copy_from(self.m.as_ref().expect("F32Codec::load before store"));
    }

    fn size_bytes(&self) -> usize {
        self.m.as_ref().map(|m| m.size_bytes()).unwrap_or(0)
    }

    fn save_state(&self, out: &mut ByteWriter) {
        match &self.m {
            Some(m) => {
                out.put_u8(1);
                m.write_bytes(out);
            }
            None => out.put_u8(0),
        }
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.m = match r.get_u8()? {
            0 => None,
            _ => Some(Matrix::read_bytes(r)?),
        };
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

// ------------------------------------------------------ block-wise VQ ----

/// Block-wise quantization with an exact f32 diagonal (Sec. 4.1's VQ at
/// b = 4; the same struct at b = 8 is the `bw8` codec).
#[derive(Clone, Debug)]
pub struct OffDiagCodec {
    key: &'static str,
    q: Arc<BlockQuantizer>,
    s: Option<OffDiagQuantized>,
}

impl OffDiagCodec {
    pub fn new(key: &'static str, q: Arc<BlockQuantizer>) -> OffDiagCodec {
        OffDiagCodec { key, q, s: None }
    }
}

impl PrecondCodec for OffDiagCodec {
    fn key(&self) -> &'static str {
        self.key
    }

    fn store(&mut self, x: &Matrix) {
        self.s = Some(quantize_offdiag(x, &self.q));
    }

    fn load(&self) -> Matrix {
        dequantize_offdiag(self.s.as_ref().expect("OffDiagCodec::load before store"), &self.q)
    }

    fn store_into(&mut self, x: &Matrix, scratch: &mut ScratchArena) {
        assert!(x.is_square(), "off-diagonal quantization needs a square matrix");
        let n = x.rows();
        let mut off = scratch.take(n, n);
        off.copy_from(x);
        for i in 0..n {
            off[(i, i)] = 0.0;
        }
        match &mut self.s {
            Some(s) => {
                self.q.quantize_into(&off, &mut s.q);
                s.diag.clear();
                for i in 0..n {
                    s.diag.push(x[(i, i)]);
                }
            }
            slot => *slot = Some(OffDiagQuantized { q: self.q.quantize(&off), diag: x.diag() }),
        }
        scratch.recycle(off);
    }

    fn load_into(&self, out: &mut Matrix, _scratch: &mut ScratchArena) {
        let s = self.s.as_ref().expect("OffDiagCodec::load before store");
        self.q.dequantize_into(&s.q, out);
        for (i, &d) in s.diag.iter().enumerate() {
            out[(i, i)] = d;
        }
    }

    fn size_bytes(&self) -> usize {
        self.s.as_ref().map(|s| s.size_bytes()).unwrap_or(0)
    }

    fn save_state(&self, out: &mut ByteWriter) {
        match &self.s {
            Some(s) => {
                out.put_u8(1);
                s.q.write_bytes(out);
                out.put_f32s(&s.diag);
            }
            None => out.put_u8(0),
        }
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.s = match r.get_u8()? {
            0 => None,
            _ => {
                let q = QuantizedMatrix::read_bytes(r)?;
                let diag = r.get_f32s()?;
                crate::ensure!(diag.len() == q.rows, "diagonal length mismatch");
                Some(OffDiagQuantized { q, diag })
            }
        };
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

/// Full-grid block-wise quantization including the diagonal (Tab. 2's
/// "Original" ablation).
#[derive(Clone, Debug)]
pub struct FullGridCodec {
    key: &'static str,
    q: Arc<BlockQuantizer>,
    s: Option<QuantizedMatrix>,
}

impl FullGridCodec {
    pub fn new(key: &'static str, q: Arc<BlockQuantizer>) -> FullGridCodec {
        FullGridCodec { key, q, s: None }
    }
}

impl PrecondCodec for FullGridCodec {
    fn key(&self) -> &'static str {
        self.key
    }

    fn store(&mut self, x: &Matrix) {
        self.s = Some(self.q.quantize(x));
    }

    fn load(&self) -> Matrix {
        self.q.dequantize(self.s.as_ref().expect("FullGridCodec::load before store"))
    }

    fn store_into(&mut self, x: &Matrix, _scratch: &mut ScratchArena) {
        match &mut self.s {
            Some(s) => self.q.quantize_into(x, s),
            slot => *slot = Some(self.q.quantize(x)),
        }
    }

    fn load_into(&self, out: &mut Matrix, _scratch: &mut ScratchArena) {
        self.q.dequantize_into(self.s.as_ref().expect("FullGridCodec::load before store"), out);
    }

    fn size_bytes(&self) -> usize {
        self.s.as_ref().map(|s| s.size_bytes()).unwrap_or(0)
    }

    fn save_state(&self, out: &mut ByteWriter) {
        match &self.s {
            Some(s) => {
                out.put_u8(1);
                s.write_bytes(out);
            }
            None => out.put_u8(0),
        }
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.s = match r.get_u8()? {
            0 => None,
            _ => Some(QuantizedMatrix::read_bytes(r)?),
        };
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

// -------------------------------------------------- Cholesky quantized ----

/// 4-bit Cholesky quantization (Sec. 4.2), optionally with error feedback
/// (Sec. 4.3): `store` factorizes the incoming PSD matrix, compensates with
/// the EF state, and packs factor + error into the Fig. 2 joint triangular
/// buffer; `load` reconstructs `D(C̄)·D(C̄)ᵀ` (PSD by construction).
#[derive(Clone, Debug)]
pub struct CholeskyCodec {
    ef: bool,
    eps: f32,
    beta_e: f32,
    q: Arc<BlockQuantizer>,
    s: Option<TriJointStore>,
}

impl CholeskyCodec {
    pub fn new(ef: bool, ctx: &CodecCtx) -> CholeskyCodec {
        // Same contract `ErrorFeedback::new` enforces; the EF update loops
        // are inlined in `store_into` (Eq. (10)–(11)), so validate here.
        if ef {
            assert!((0.0..1.0).contains(&ctx.beta_e), "βₑ must be in [0,1)");
        }
        CholeskyCodec {
            ef,
            eps: ctx.eps,
            beta_e: ctx.beta_e,
            q: Arc::clone(&ctx.quantizer),
            s: None,
        }
    }
}

impl PrecondCodec for CholeskyCodec {
    fn key(&self) -> &'static str {
        if self.ef {
            "cq4-ef"
        } else {
            "cq4"
        }
    }

    /// Algorithm 1 inputs: `C₀ = √ε·I`, `E₀ = 0` (stored directly — no
    /// factorization round-trip, so the initial bits match the paper).
    fn init(&mut self, dim: usize, eps: f32) {
        self.eps = eps;
        self.s = Some(TriJointStore::init(dim, eps, &self.q));
    }

    fn store(&mut self, x: &Matrix) {
        self.store_into(x, &mut ScratchArena::new());
    }

    fn load(&self) -> Matrix {
        let n = self.s.as_ref().expect("CholeskyCodec::load before store").n;
        let mut out = Matrix::zeros(n, n);
        self.load_into(&mut out, &mut ScratchArena::new());
        out
    }

    /// Fused refresh: factor → (EF: compensate → pack C → read back `D(C̄)`
    /// from the freshly packed codes → EMA residual) → pack E. The staged
    /// `TriJointStore` API means the compensated factor is quantized ONCE
    /// (the unfused path quantized it twice — once for the round-trip, once
    /// for the store), with every temporary arena-backed.
    fn store_into(&mut self, x: &Matrix, scratch: &mut ScratchArena) {
        let n = x.rows();
        let mut c = scratch.take(n, n);
        // Eq. (7): C = Cholesky(L + εI); escalating jitter guards
        // quantization-induced PSD violations.
        if cholesky_jittered_into_planned(x, self.eps, 12, &mut c, scratch.plan()).is_err() {
            // Pathological input (e.g. non-finite gradient blew up the
            // Gram). Reset to the initial factor — the EMA will rebuild
            // state over the next T1 windows.
            c.set_eye_scaled(self.eps.sqrt());
        }
        let store = self.s.get_or_insert_with(TriJointStore::empty);
        if self.ef {
            let mut e_prev = scratch.take(n, n);
            if store.n == n {
                store.load_e_into(&self.q, &mut e_prev);
            }
            // Eq. (10): compensate the factor in place (strict lower only;
            // the diagonal stays the exact C diagonal — never quantized).
            for i in 0..n {
                let (erow, crow) = (e_prev.row(i), c.row_mut(i));
                for j in 0..i {
                    crow[j] += erow[j];
                }
            }
            store.store_c_into(&c, &self.q);
            // D(C̄): read the freshly packed strictly-lower codes back.
            let mut c_deq = scratch.take(n, n);
            store.load_c_into(&self.q, &mut c_deq);
            // Eq. (11): EMA of the residual, in place on the old state.
            let beta_e = self.beta_e;
            for i in 0..n {
                let (crow, drow) = (c.row(i), c_deq.row(i));
                let erow = e_prev.row_mut(i);
                for j in 0..i {
                    let residual = crow[j] - drow[j];
                    erow[j] = beta_e * erow[j] + (1.0 - beta_e) * residual;
                }
            }
            store.store_e_into(&e_prev, &self.q);
            scratch.recycle(c_deq);
            scratch.recycle(e_prev);
        } else {
            store.store_c_into(&c, &self.q);
            store.store_e_zero(&self.q);
        }
        scratch.recycle(c);
    }

    /// `D(C̄)·D(C̄)ᵀ` into `out` (Eq. (7) reconstruction, PSD by
    /// construction), with the factor staged in the arena.
    fn load_into(&self, out: &mut Matrix, scratch: &mut ScratchArena) {
        let store = self.s.as_ref().expect("CholeskyCodec::load before store");
        let mut c = scratch.take(store.n, store.n);
        store.load_c_into(&self.q, &mut c);
        matmul_nt_into_planned(&c, &c, out, scratch.plan());
        scratch.recycle(c);
    }

    fn size_bytes(&self) -> usize {
        match &self.s {
            Some(s) if self.ef => s.size_bytes(),
            Some(s) => s.size_bytes_cq_only(),
            None => 0,
        }
    }

    fn error_state(&self) -> Option<Matrix> {
        if self.ef {
            self.s.as_ref().map(|s| s.load(&self.q).1)
        } else {
            None
        }
    }

    /// The joint triangular buffer verbatim — factor codes, exact f32
    /// diagonal, EF codes, and both scale sets. Nothing is re-factorized on
    /// restore, so resume continues from the *same* quantized factor and
    /// error state, not a re-quantization of their reconstruction.
    fn save_state(&self, out: &mut ByteWriter) {
        match &self.s {
            Some(s) => {
                out.put_u8(1);
                s.write_bytes(out);
            }
            None => out.put_u8(0),
        }
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.s = match r.get_u8()? {
            0 => None,
            _ => Some(TriJointStore::read_bytes(r)?),
        };
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn PrecondCodec> {
        Box::new(self.clone())
    }
}

// ----------------------------------------------------------- registry ----

/// One registry entry: constructors for the side (`L`/`R`) and root
/// (`L̂`/`R̂`) storage of this scheme. They may differ — CQ factorizes the
/// sides but keeps roots off-diagonal-quantized, because roots are applied
/// every step (Sec. 4.2).
#[derive(Clone, Copy)]
pub struct CodecBuilder {
    /// Registry key (the `side_codec`/`root_codec` config spelling).
    pub key: &'static str,
    /// One-line description for docs/CLI listings.
    pub summary: &'static str,
    /// Constructor for a Gram-side slot (`L`/`R`).
    pub side: fn(&CodecCtx) -> Box<dyn PrecondCodec>,
    /// Constructor for an inverse-root slot (`L̂`/`R̂`).
    pub root: fn(&CodecCtx) -> Box<dyn PrecondCodec>,
}

fn f32_ctor(_ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(F32Codec::default())
}

fn vq4_ctor(ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(OffDiagCodec::new("vq4", Arc::clone(&ctx.quantizer)))
}

fn vq4_full_ctor(ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(FullGridCodec::new("vq4-full", Arc::clone(&ctx.quantizer)))
}

fn cq4_ctor(ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(CholeskyCodec::new(false, ctx))
}

fn cq4_ef_ctor(ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(CholeskyCodec::new(true, ctx))
}

/// An 8-bit quantizer mirroring the context's block/mapping settings,
/// cached per distinct config so the hundreds of codec instances of a large
/// model share one 256-level codebook (like the 4-bit one in the ctx).
fn eight_bit(ctx: &CodecCtx) -> Arc<BlockQuantizer> {
    static CACHE: OnceLock<Mutex<Vec<Arc<BlockQuantizer>>>> = OnceLock::new();
    let cfg = QuantConfig { bits: 8, ..ctx.quantizer.cfg };
    let mut cache = CACHE
        .get_or_init(|| Mutex::new(Vec::new()))
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    if let Some(q) = cache.iter().find(|q| q.cfg == cfg) {
        return Arc::clone(q);
    }
    let q = Arc::new(BlockQuantizer::new(cfg));
    cache.push(Arc::clone(&q));
    q
}

fn bw8_ctor(ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(OffDiagCodec::new("bw8", eight_bit(ctx)))
}

fn ec4_ctor(ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(Ec4Codec::new(ctx))
}

fn f16_ctor(_ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::<F16Codec>::default()
}

fn cq_r1_ctor(ctx: &CodecCtx) -> Box<dyn PrecondCodec> {
    Box::new(CholeskyR1Codec::new(ctx))
}

fn builtin_codecs() -> Vec<CodecBuilder> {
    vec![
        CodecBuilder {
            key: "f32",
            summary: "dense f32 (Algorithm 2)",
            side: f32_ctor,
            root: f32_ctor,
        },
        CodecBuilder {
            key: "vq4",
            summary: "4-bit block-wise, f32 diagonal (Sec. 4.1)",
            side: vq4_ctor,
            root: vq4_ctor,
        },
        CodecBuilder {
            key: "vq4-full",
            summary: "4-bit block-wise incl. diagonal (Tab. 2 ablation)",
            side: vq4_full_ctor,
            root: vq4_full_ctor,
        },
        CodecBuilder {
            key: "cq4",
            summary: "4-bit quantized Cholesky factor (Sec. 4.2)",
            side: cq4_ctor,
            root: vq4_ctor,
        },
        CodecBuilder {
            key: "cq4-ef",
            summary: "4-bit Cholesky + error feedback (Sec. 4.3, Alg. 1)",
            side: cq4_ef_ctor,
            root: vq4_ctor,
        },
        CodecBuilder {
            key: "bw8",
            summary: "8-bit block-wise, f32 diagonal",
            side: bw8_ctor,
            root: bw8_ctor,
        },
        CodecBuilder {
            key: "ec4",
            summary: "eigenvalue-corrected 4-bit eigenfactors (arXiv 2405.18144)",
            side: ec4_ctor,
            root: ec4_ctor,
        },
        CodecBuilder {
            key: "f16",
            summary: "dense IEEE half precision (software conversion)",
            side: f16_ctor,
            root: f16_ctor,
        },
        CodecBuilder {
            // Like `cq4`, the factored representation is for the sides;
            // roots stay off-diagonal-quantized (they are applied every
            // step — Sec. 4.2's argument is unchanged by the row scales).
            key: "cq-r1",
            summary: "4-bit Cholesky + per-row f32 scale correction",
            side: cq_r1_ctor,
            root: vq4_ctor,
        },
    ]
}

fn registry() -> &'static Mutex<Vec<CodecBuilder>> {
    static REGISTRY: OnceLock<Mutex<Vec<CodecBuilder>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(builtin_codecs()))
}

/// Register a codec. Returns `false` (and changes nothing) if the key is
/// already taken — built-ins cannot be shadowed.
pub fn register(builder: CodecBuilder) -> bool {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    if reg.iter().any(|b| b.key == builder.key) {
        return false;
    }
    reg.push(builder);
    true
}

/// Look up a codec builder by key.
pub fn lookup(key: &str) -> Option<CodecBuilder> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().find(|b| b.key == key).copied()
}

/// All registered keys, built-ins first, registration order after.
pub fn codec_keys() -> Vec<&'static str> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().map(|b| b.key).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ctx() -> CodecCtx {
        let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
        CodecCtx::new(1e-6, 0.95, Arc::new(q))
    }

    #[test]
    fn builtins_are_registered() {
        for key in ["f32", "vq4", "vq4-full", "cq4", "cq4-ef", "bw8", "ec4", "f16", "cq-r1"] {
            let b = lookup(key).unwrap_or_else(|| panic!("missing builtin '{key}'"));
            assert_eq!(b.key, key);
        }
        assert!(lookup("no-such-codec").is_none());
    }

    #[test]
    fn builtin_keys_cannot_be_shadowed() {
        let b = lookup("f32").unwrap();
        assert!(!register(b), "re-registering an existing key must fail");
    }

    #[test]
    fn init_reconstructs_eps_identity() {
        let ctx = ctx();
        for key in codec_keys() {
            let b = lookup(key).unwrap();
            let mut side = (b.side)(&ctx);
            side.init(12, 1e-6);
            let back = side.load();
            let want = Matrix::eye_scaled(12, 1e-6);
            assert!(back.max_abs_diff(&want) < 1e-6, "{key}: init must be ≈ ε·I");
        }
    }

    #[test]
    fn store_load_roundtrips_within_codec_error() {
        let ctx = ctx();
        let mut rng = Rng::new(1);
        let g = Matrix::randn(16, 20, 1.0, &mut rng);
        let mut spd = crate::linalg::syrk(&g);
        spd.add_diag(0.5);
        for key in codec_keys() {
            let b = lookup(key).unwrap();
            let mut side = (b.side)(&ctx);
            side.store(&spd);
            let back = side.load();
            let rel = crate::linalg::relative_error(&spd, &back);
            assert!(rel < 0.35, "{key}: relative store/load error {rel}");
        }
    }

    #[test]
    fn boxed_codecs_clone_deeply() {
        let ctx = ctx();
        let mut rng = Rng::new(2);
        let g = Matrix::randn(8, 8, 1.0, &mut rng);
        let mut spd = crate::linalg::syrk(&g);
        spd.add_diag(1.0);
        let mut a: Box<dyn PrecondCodec> = (lookup("vq4").unwrap().side)(&ctx);
        a.store(&spd);
        let b = a.clone();
        a.store(&Matrix::eye(8));
        // The clone must keep the original value.
        assert!(b.load().max_abs_diff(&spd) < 0.35 * crate::linalg::max_abs(&spd));
    }

    #[test]
    fn scratch_paths_match_allocating_paths() {
        // store_into/load_into are the same transforms as store/load, just
        // without the allocations — pin them element-for-element.
        let ctx = ctx();
        let mut rng = Rng::new(3);
        let g = Matrix::randn(16, 20, 1.0, &mut rng);
        let mut spd = crate::linalg::syrk(&g);
        spd.add_diag(0.5);
        for key in codec_keys() {
            let b = lookup(key).unwrap();
            let mut plain = (b.side)(&ctx);
            let mut scratched = (b.side)(&ctx);
            let mut arena = ScratchArena::new();
            plain.store(&spd);
            scratched.store_into(&spd, &mut arena);
            let want = plain.load();
            let mut got = Matrix::zeros(16, 16);
            scratched.load_into(&mut got, &mut arena);
            assert_eq!(want.max_abs_diff(&got), 0.0, "{key}: scratch path diverged");
            assert_eq!(plain.size_bytes(), scratched.size_bytes(), "{key}");
        }
    }

    #[test]
    fn steady_state_refresh_is_allocation_free() {
        // After one warm-up refresh, repeated store_into/load_into must be
        // served entirely from the arena pool and the codecs' own buffers.
        let ctx = ctx();
        let mut rng = Rng::new(4);
        let mut fresh_spd = |rng: &mut Rng| {
            let g = Matrix::randn(24, 28, 1.0, rng);
            let mut s = crate::linalg::syrk(&g);
            s.add_diag(0.5);
            s
        };
        for key in ["f32", "vq4", "vq4-full", "cq4", "cq4-ef", "bw8", "ec4", "f16", "cq-r1"] {
            let b = lookup(key).unwrap();
            let mut codec = (b.side)(&ctx);
            let mut arena = ScratchArena::new();
            let mut out = Matrix::zeros(24, 24);
            codec.store_into(&fresh_spd(&mut rng), &mut arena);
            codec.load_into(&mut out, &mut arena);
            let baseline = arena.misses();
            for _ in 0..3 {
                codec.store_into(&fresh_spd(&mut rng), &mut arena);
                codec.load_into(&mut out, &mut arena);
            }
            assert_eq!(arena.misses(), baseline, "{key}: steady-state refresh allocated");
        }
    }

    #[test]
    fn save_restore_is_byte_exact_for_every_builtin() {
        // The checkpoint contract: save → restore into a FRESH instance →
        // save again must reproduce the identical byte string, and the
        // restored codec must reconstruct the identical matrix. This is the
        // per-codec half of the bit-identical-resume oracle.
        let ctx = ctx();
        let mut rng = Rng::new(7);
        let g = Matrix::randn(20, 24, 1.0, &mut rng);
        let mut spd = crate::linalg::syrk(&g);
        spd.add_diag(0.5);
        for key in codec_keys() {
            let b = lookup(key).unwrap();
            let mut orig = (b.side)(&ctx);
            orig.init(20, 1e-6);
            orig.store(&spd);
            let mut w = ByteWriter::new();
            orig.save_state(&mut w);
            let bytes = w.into_bytes();

            let mut fresh = (b.side)(&ctx);
            let mut r = ByteReader::new(&bytes);
            fresh.restore_state(&mut r).unwrap_or_else(|e| panic!("{key}: restore failed: {e}"));
            r.finish().unwrap_or_else(|e| panic!("{key}: trailing bytes: {e}"));

            let mut w2 = ByteWriter::new();
            fresh.save_state(&mut w2);
            assert_eq!(bytes, w2.into_bytes(), "{key}: save→restore→save not byte-exact");
            assert_eq!(orig.load().max_abs_diff(&fresh.load()), 0.0, "{key}: load diverged");
            assert_eq!(orig.size_bytes(), fresh.size_bytes(), "{key}: byte accounting diverged");

            // EF state (where present) must survive the trip too.
            match (orig.error_state(), fresh.error_state()) {
                (Some(a), Some(b)) => assert_eq!(a, b, "{key}: EF state diverged"),
                (None, None) => {}
                _ => panic!("{key}: EF presence diverged"),
            }

            // Truncated input must error, never mis-restore.
            if bytes.len() > 4 {
                let mut fresh = (b.side)(&ctx);
                let mut r = ByteReader::new(&bytes[..bytes.len() - 3]);
                assert!(fresh.restore_state(&mut r).is_err(), "{key}: accepted truncated state");
            }
        }
    }

    #[test]
    fn default_save_restore_falls_back_to_dense() {
        // A codec that does not override the pair still round-trips through
        // the dense fallback (reconstruction-exact for lossless codecs).
        #[derive(Debug, Clone)]
        struct Plain(Option<Matrix>);
        impl PrecondCodec for Plain {
            fn key(&self) -> &'static str {
                "plain-test"
            }
            fn store(&mut self, x: &Matrix) {
                self.0 = Some(x.clone());
            }
            fn load(&self) -> Matrix {
                self.0.clone().unwrap()
            }
            fn size_bytes(&self) -> usize {
                self.0.as_ref().map(|m| m.size_bytes()).unwrap_or(0)
            }
            fn clone_box(&self) -> Box<dyn PrecondCodec> {
                Box::new(self.clone())
            }
        }
        let mut rng = Rng::new(8);
        let x = Matrix::randn(9, 9, 1.0, &mut rng);
        let mut a = Plain(None);
        a.store(&x);
        let mut w = ByteWriter::new();
        a.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut b = Plain(None);
        b.restore_state(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(b.load(), x);
    }

    #[test]
    fn only_ef_codec_exposes_error_state() {
        let ctx = ctx();
        for key in ["f32", "vq4", "vq4-full", "cq4", "bw8", "ec4", "f16", "cq-r1"] {
            let mut c = (lookup(key).unwrap().side)(&ctx);
            c.init(8, 1e-6);
            assert!(c.error_state().is_none(), "{key} must not carry EF state");
        }
        let mut c = (lookup("cq4-ef").unwrap().side)(&ctx);
        c.init(8, 1e-6);
        assert!(c.error_state().is_some());
    }
}

//! Packed 4-bit (nibble) storage.
//!
//! Two 4-bit codes per byte — the physical representation behind every
//! "4-bit" number in the paper's memory tables. Element count may be odd;
//! the trailing nibble of the last byte is zero-padded.

/// A dense vector of 4-bit codes.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedNibbles {
    len: usize,
    bytes: Vec<u8>,
}

impl PackedNibbles {
    /// Zero-initialized packed buffer for `len` codes.
    pub fn zeros(len: usize) -> PackedNibbles {
        PackedNibbles { len, bytes: vec![0u8; len.div_ceil(2)] }
    }

    /// Pack a slice of codes (each must fit in 4 bits).
    pub fn from_codes(codes: &[u8]) -> PackedNibbles {
        let mut p = PackedNibbles::zeros(codes.len());
        for (i, &c) in codes.iter().enumerate() {
            p.set(i, c);
        }
        p
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Code at index `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        let b = self.bytes[i >> 1];
        if i & 1 == 0 {
            b & 0x0F
        } else {
            b >> 4
        }
    }

    /// Store code `c` (≤ 15) at index `i`.
    #[inline]
    pub fn set(&mut self, i: usize, c: u8) {
        debug_assert!(i < self.len);
        debug_assert!(c <= 0x0F, "code {c} exceeds 4 bits");
        let b = &mut self.bytes[i >> 1];
        if i & 1 == 0 {
            *b = (*b & 0xF0) | c;
        } else {
            *b = (*b & 0x0F) | (c << 4);
        }
    }

    /// Unpack to one code per byte.
    pub fn to_codes(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Physical storage bytes (the quantity the memory accountant counts).
    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Raw packed bytes (two codes per byte, low nibble first).
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Mutable raw bytes — the escape hatch the fused kernels use to write
    /// whole bytes instead of per-code read-modify-write. Callers must keep
    /// the two-codes-per-byte layout (see [`NibbleWriter`]).
    #[inline]
    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.bytes
    }

    /// Resize to `len` zeroed codes, reusing the existing allocation when
    /// its capacity suffices (the `quantize_into` steady-state path).
    pub fn reset(&mut self, len: usize) {
        self.len = len;
        self.bytes.clear();
        self.bytes.resize(len.div_ceil(2), 0);
    }

    /// Bulk-write `codes` starting at code index `start`: whole-byte stores
    /// in the interior, read-modify-write only at unaligned ends. Exactly
    /// equivalent to `for (i, c) in codes { self.set(start + i, c) }`.
    pub fn set_run(&mut self, start: usize, codes: &[u8]) {
        debug_assert!(start + codes.len() <= self.len);
        let mut w = NibbleWriter::new(&mut self.bytes, start);
        for &c in codes {
            w.push(c);
        }
        w.finish();
    }

    /// Bulk-read `out.len()` codes starting at code index `start`. Exactly
    /// equivalent to `for (i, o) in out { *o = self.get(start + i) }`.
    pub fn get_run(&self, start: usize, out: &mut [u8]) {
        debug_assert!(start + out.len() <= self.len);
        let mut r = NibbleReader::new(&self.bytes, start);
        for o in out.iter_mut() {
            *o = r.next_code();
        }
    }
}

/// Streaming writer of 4-bit codes into a packed byte buffer.
///
/// `bytes` is the (sub)buffer and `start` the code index *relative to it*;
/// interior bytes are written whole (two codes per store), and only a
/// half-covered first or last byte does a read-modify-write that preserves
/// the neighbouring nibble. This is what lets the fused quantize kernels
/// bypass `CodeStore::get`/`set` in their inner loops while remaining
/// bit-exact with them, and what makes row-parallel packing sound: writers
/// on byte-disjoint ranges never touch each other's bytes.
pub struct NibbleWriter<'a> {
    bytes: &'a mut [u8],
    idx: usize,
    carry: u8,
}

impl<'a> NibbleWriter<'a> {
    #[inline]
    pub fn new(bytes: &'a mut [u8], start: usize) -> NibbleWriter<'a> {
        let carry = if start & 1 == 1 {
            // Preserve the existing low nibble of the half-open first byte.
            bytes[start >> 1] & 0x0F
        } else {
            0
        };
        NibbleWriter { bytes, idx: start, carry }
    }

    /// Append one code (must fit in 4 bits).
    #[inline]
    pub fn push(&mut self, c: u8) {
        debug_assert!(c <= 0x0F, "code {c} exceeds 4 bits");
        if self.idx & 1 == 0 {
            self.carry = c;
        } else {
            self.bytes[self.idx >> 1] = self.carry | (c << 4);
        }
        self.idx += 1;
    }

    /// Flush a trailing half-byte, preserving the neighbouring high nibble.
    #[inline]
    pub fn finish(self) {
        if self.idx & 1 == 1 {
            let b = &mut self.bytes[self.idx >> 1];
            *b = (*b & 0xF0) | self.carry;
        }
    }
}

/// Streaming reader of 4-bit codes from a packed byte buffer (byte cached
/// across the two nibbles it holds).
pub struct NibbleReader<'a> {
    bytes: &'a [u8],
    idx: usize,
    cur: u8,
}

impl<'a> NibbleReader<'a> {
    #[inline]
    pub fn new(bytes: &'a [u8], start: usize) -> NibbleReader<'a> {
        let cur = if start & 1 == 1 { bytes[start >> 1] } else { 0 };
        NibbleReader { bytes, idx: start, cur }
    }

    /// Read the next code.
    #[inline]
    pub fn next_code(&mut self) -> u8 {
        let c = if self.idx & 1 == 0 {
            self.cur = self.bytes[self.idx >> 1];
            self.cur & 0x0F
        } else {
            self.cur >> 4
        };
        self.idx += 1;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_even_and_odd() {
        for n in [0usize, 1, 2, 7, 64, 1001] {
            let mut rng = Rng::new(n as u64 + 1);
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xF) as u8).collect();
            let p = PackedNibbles::from_codes(&codes);
            assert_eq!(p.to_codes(), codes, "n={n}");
            assert_eq!(p.size_bytes(), n.div_ceil(2));
        }
    }

    #[test]
    fn set_overwrites_cleanly() {
        let mut p = PackedNibbles::zeros(4);
        p.set(0, 0xF);
        p.set(1, 0x3);
        p.set(0, 0x1);
        assert_eq!(p.get(0), 0x1);
        assert_eq!(p.get(1), 0x3);
    }

    #[test]
    fn half_the_bytes_of_u8_codes() {
        let p = PackedNibbles::zeros(1000);
        assert_eq!(p.size_bytes(), 500);
    }

    #[test]
    fn set_run_matches_scalar_set_at_any_alignment() {
        let mut rng = Rng::new(42);
        for total in [9usize, 16, 33, 128] {
            for start in [0usize, 1, 2, 3, 5] {
                for len in [0usize, 1, 2, 3, 7, 8] {
                    if start + len > total {
                        continue;
                    }
                    let codes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xF) as u8).collect();
                    // Background pattern so preserved nibbles are visible.
                    let bg: Vec<u8> = (0..total).map(|i| ((i * 7 + 3) & 0xF) as u8).collect();
                    let mut bulk = PackedNibbles::from_codes(&bg);
                    let mut scalar = PackedNibbles::from_codes(&bg);
                    bulk.set_run(start, &codes);
                    for (i, &c) in codes.iter().enumerate() {
                        scalar.set(start + i, c);
                    }
                    assert_eq!(bulk, scalar, "total={total} start={start} len={len}");
                }
            }
        }
    }

    #[test]
    fn get_run_matches_scalar_get_at_any_alignment() {
        let mut rng = Rng::new(43);
        let codes: Vec<u8> = (0..77).map(|_| (rng.next_u64() & 0xF) as u8).collect();
        let p = PackedNibbles::from_codes(&codes);
        for start in [0usize, 1, 4, 7] {
            for len in [0usize, 1, 2, 9, 70 - start] {
                let mut bulk = vec![0u8; len];
                p.get_run(start, &mut bulk);
                let scalar: Vec<u8> = (0..len).map(|i| p.get(start + i)).collect();
                assert_eq!(bulk, scalar, "start={start} len={len}");
            }
        }
    }

    #[test]
    fn reset_reuses_capacity_and_zeroes() {
        let mut p = PackedNibbles::from_codes(&[0xF; 100]);
        p.reset(40);
        assert_eq!(p.len(), 40);
        assert!(p.to_codes().iter().all(|&c| c == 0), "reset must zero");
        assert_eq!(p.size_bytes(), 20);
    }
}

//! Packed 4-bit (nibble) storage.
//!
//! Two 4-bit codes per byte — the physical representation behind every
//! "4-bit" number in the paper's memory tables. Element count may be odd;
//! the trailing nibble of the last byte is zero-padded.

/// A dense vector of 4-bit codes.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedNibbles {
    len: usize,
    bytes: Vec<u8>,
}

impl PackedNibbles {
    /// Zero-initialized packed buffer for `len` codes.
    pub fn zeros(len: usize) -> PackedNibbles {
        PackedNibbles { len, bytes: vec![0u8; len.div_ceil(2)] }
    }

    /// Pack a slice of codes (each must fit in 4 bits).
    pub fn from_codes(codes: &[u8]) -> PackedNibbles {
        let mut p = PackedNibbles::zeros(codes.len());
        for (i, &c) in codes.iter().enumerate() {
            p.set(i, c);
        }
        p
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Code at index `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        let b = self.bytes[i >> 1];
        if i & 1 == 0 {
            b & 0x0F
        } else {
            b >> 4
        }
    }

    /// Store code `c` (≤ 15) at index `i`.
    #[inline]
    pub fn set(&mut self, i: usize, c: u8) {
        debug_assert!(i < self.len);
        debug_assert!(c <= 0x0F, "code {c} exceeds 4 bits");
        let b = &mut self.bytes[i >> 1];
        if i & 1 == 0 {
            *b = (*b & 0xF0) | c;
        } else {
            *b = (*b & 0x0F) | (c << 4);
        }
    }

    /// Unpack to one code per byte.
    pub fn to_codes(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Physical storage bytes (the quantity the memory accountant counts).
    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_even_and_odd() {
        for n in [0usize, 1, 2, 7, 64, 1001] {
            let mut rng = Rng::new(n as u64 + 1);
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xF) as u8).collect();
            let p = PackedNibbles::from_codes(&codes);
            assert_eq!(p.to_codes(), codes, "n={n}");
            assert_eq!(p.size_bytes(), n.div_ceil(2));
        }
    }

    #[test]
    fn set_overwrites_cleanly() {
        let mut p = PackedNibbles::zeros(4);
        p.set(0, 0xF);
        p.set(1, 0x3);
        p.set(0, 0x1);
        assert_eq!(p.get(0), 0x1);
        assert_eq!(p.get(1), 0x3);
    }

    #[test]
    fn half_the_bytes_of_u8_codes() {
        let p = PackedNibbles::zeros(1000);
        assert_eq!(p.size_bytes(), 500);
    }
}

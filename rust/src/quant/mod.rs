//! Quantization library (paper Sec. 3.2, 4.1–4.3) and the open
//! preconditioner-codec API.
//!
//! * [`mapping`] — the codebooks: **linear-2** (Eq. 4, the paper's choice),
//!   plain linear, and dynamic-exponent mappings, at any bit width; plus the
//!   software IEEE-754 half conversions behind the `f16` codec.
//! * [`blockwise`] — B×B block-wise absmax quantization (Sec. 3.2) with
//!   packed 4-bit (or byte-per-code 8-bit) storage.
//! * [`offdiag`] — off-diagonal quantization keeping the diagonal in f32
//!   (Sec. 4.1 / Tab. 2, and the CQ diagonal rule of Sec. 4.2).
//! * [`tri_store`] — the Fig. 2 joint container: quantized Cholesky factor
//!   in the lower triangle, quantized EF error state in the upper triangle
//!   of the same packed buffer.
//! * [`error_feedback`] — the EMA error-state update of Eq. (11).
//! * [`ec4`] — eigenvalue-corrected 4-bit eigenfactor storage
//!   (arXiv 2405.18144).
//! * [`half`] — dense half-precision storage (`f16` key), the
//!   memory/accuracy midpoint.
//! * [`cq_r1`] — Cholesky quantization with a per-row rank-1 scale
//!   correction.
//! * [`codec`] — the [`PrecondCodec`] trait + string-keyed registry that
//!   every preconditioner representation (f32 / vq4 / vq4-full / cq4 /
//!   cq4-ef / bw8 / ec4 / f16 / cq-r1 / user-registered) plugs into. The
//!   Shampoo state layer stores all of `L`, `R`, `L̂`, `R̂` behind this
//!   trait; see `docs/ARCHITECTURE.md` for the add-your-own-codec
//!   walkthrough.

pub mod mapping;
pub mod blockwise;
pub mod packed;
pub mod offdiag;
pub mod tri_store;
pub mod error_feedback;
pub mod codec;
pub mod ec4;
pub mod half;
pub mod cq_r1;

pub use blockwise::{BlockQuantizer, CodeStore, QuantConfig, QuantizedMatrix};
pub use codec::{CodecBuilder, CodecCtx, PrecondCodec};
pub use cq_r1::CholeskyR1Codec;
pub use ec4::Ec4Codec;
pub use error_feedback::ErrorFeedback;
pub use half::F16Codec;
pub use mapping::{f16_to_f32, f32_to_f16, Mapping};
pub use offdiag::{dequantize_offdiag, quantize_offdiag, OffDiagQuantized};
pub use packed::{NibbleReader, NibbleWriter, PackedNibbles};
pub use tri_store::TriJointStore;

/// The scratch arena threaded through every `store_into`/`load_into`
/// (defined in `linalg`, re-exported here next to the codec API it serves).
pub use crate::linalg::ScratchArena;

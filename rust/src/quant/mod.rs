//! Quantization library (paper Sec. 3.2, 4.1–4.3) and the open
//! preconditioner-codec API.
//!
//! * [`mapping`] — the codebooks: **linear-2** (Eq. 4, the paper's choice),
//!   plain linear, and dynamic-exponent mappings, at any bit width.
//! * [`blockwise`] — B×B block-wise absmax quantization (Sec. 3.2) with
//!   packed 4-bit (or byte-per-code 8-bit) storage.
//! * [`offdiag`] — off-diagonal quantization keeping the diagonal in f32
//!   (Sec. 4.1 / Tab. 2, and the CQ diagonal rule of Sec. 4.2).
//! * [`tri_store`] — the Fig. 2 joint container: quantized Cholesky factor
//!   in the lower triangle, quantized EF error state in the upper triangle
//!   of the same packed buffer.
//! * [`error_feedback`] — the EMA error-state update of Eq. (11).
//! * [`codec`] — the [`PrecondCodec`] trait + string-keyed registry that
//!   every preconditioner representation (f32 / vq4 / vq4-full / cq4 /
//!   cq4-ef / bw8 / user-registered) plugs into. The Shampoo state layer
//!   stores all of `L`, `R`, `L̂`, `R̂` behind this trait; see the README's
//!   "add your own codec" walkthrough.

pub mod mapping;
pub mod blockwise;
pub mod packed;
pub mod offdiag;
pub mod tri_store;
pub mod error_feedback;
pub mod codec;

pub use blockwise::{BlockQuantizer, CodeStore, QuantConfig, QuantizedMatrix};
pub use codec::{CodecBuilder, CodecCtx, PrecondCodec};
pub use error_feedback::ErrorFeedback;
pub use mapping::Mapping;
pub use offdiag::{dequantize_offdiag, quantize_offdiag, OffDiagQuantized};
pub use packed::{NibbleReader, NibbleWriter, PackedNibbles};
pub use tri_store::TriJointStore;

/// The scratch arena threaded through every `store_into`/`load_into`
/// (defined in `linalg`, re-exported here next to the codec API it serves).
pub use crate::linalg::ScratchArena;

//! Synthetic patch-image data (the ViT/CNN-analog input): `side×side`
//! single-channel images composed of class-specific frequency patterns
//! plus structured noise. Flattened for MLP heads or consumed patch-wise
//! by the ViT-analog graph.

use crate::util::rng::Rng;

/// Labelled image dataset (row-major `[n, side*side]`).
#[derive(Clone, Debug)]
pub struct ImageDataset {
    pub side: usize,
    pub classes: usize,
    pub pixels: Vec<f32>,
    pub labels: Vec<u32>,
}

/// Generation settings.
#[derive(Clone, Copy, Debug)]
pub struct ImageSpec {
    pub side: usize,
    pub classes: usize,
    pub train: usize,
    pub test: usize,
    pub noise: f32,
    pub seed: u64,
}

impl Default for ImageSpec {
    fn default() -> Self {
        ImageSpec { side: 8, classes: 16, train: 4096, test: 1024, noise: 0.6, seed: 0 }
    }
}

impl ImageDataset {
    pub fn generate(spec: &ImageSpec) -> (ImageDataset, ImageDataset) {
        let mut rng = Rng::new(spec.seed ^ 0x1111_AAAA);
        let s = spec.side;
        // Each class: a 2-D sinusoidal template with random frequency/phase.
        let templates: Vec<Vec<f32>> = (0..spec.classes)
            .map(|_| {
                let fx = 1.0 + rng.uniform() as f32 * 3.0;
                let fy = 1.0 + rng.uniform() as f32 * 3.0;
                let px = rng.uniform() as f32 * std::f32::consts::TAU;
                let py = rng.uniform() as f32 * std::f32::consts::TAU;
                (0..s * s)
                    .map(|i| {
                        let (x, y) = ((i % s) as f32 / s as f32, (i / s) as f32 / s as f32);
                        ((fx * std::f32::consts::TAU * x + px).sin()
                            + (fy * std::f32::consts::TAU * y + py).sin())
                            * 0.5
                    })
                    .collect()
            })
            .collect();

        let make = |n: usize, rng: &mut Rng| {
            let mut pixels = Vec::with_capacity(n * s * s);
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                let y = rng.below(spec.classes);
                let amp = 0.7 + 0.6 * rng.uniform() as f32;
                for &t in &templates[y] {
                    pixels.push(amp * t + rng.normal_f32(spec.noise));
                }
                labels.push(y as u32);
            }
            ImageDataset { side: s, classes: spec.classes, pixels, labels }
        };
        let mut tr_rng = rng.fork(1);
        let mut te_rng = rng.fork(2);
        (make(spec.train, &mut tr_rng), make(spec.test, &mut te_rng))
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.side * self.side
    }

    pub fn gather(&self, indices: &[usize]) -> (Vec<f32>, Vec<u32>) {
        let d = self.dim();
        let mut x = Vec::with_capacity(indices.len() * d);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(&self.pixels[i * d..(i + 1) * d]);
            y.push(self.labels[i]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let (tr, te) = ImageDataset::generate(&ImageSpec {
            train: 32,
            test: 16,
            ..Default::default()
        });
        assert_eq!(tr.pixels.len(), 32 * 64);
        assert_eq!(te.len(), 16);
        assert_eq!(tr.dim(), 64);
    }

    #[test]
    fn deterministic() {
        let spec = ImageSpec { train: 10, test: 5, ..Default::default() };
        let (a, _) = ImageDataset::generate(&spec);
        let (b, _) = ImageDataset::generate(&spec);
        assert_eq!(a.pixels, b.pixels);
    }

    #[test]
    fn gather_extracts_rows() {
        let (tr, _) =
            ImageDataset::generate(&ImageSpec { train: 10, test: 1, ..Default::default() });
        let (x, y) = tr.gather(&[3, 7]);
        assert_eq!(x.len(), 2 * 64);
        assert_eq!(x[..64], tr.pixels[3 * 64..4 * 64]);
        assert_eq!(y[1], tr.labels[7]);
    }
}

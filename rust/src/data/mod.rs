//! Seeded synthetic datasets — the scaled analogs of the paper's workloads
//! (DESIGN.md §4). All generation is deterministic in the seed so every
//! table row is exactly reproducible.

pub mod synthetic;
pub mod tokens;
pub mod images;

pub use images::ImageDataset;
pub use synthetic::ClusterDataset;
pub use tokens::TokenCorpus;

//! Synthetic token corpus (the C4 analog for LLM pre-training, Tab. 6):
//! a seeded order-2 Markov chain over `vocab` symbols with a skewed
//! (Zipf-ish) stationary distribution. Next-token prediction on it has
//! learnable structure (bigram/trigram statistics) and a nontrivial
//! entropy floor, so perplexity curves behave qualitatively like language.

use crate::util::rng::Rng;

/// A generated corpus plus sampling utilities.
#[derive(Clone, Debug)]
pub struct TokenCorpus {
    pub vocab: usize,
    pub tokens: Vec<u32>,
}

/// Corpus generation settings.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub length: usize,
    /// Number of preferred successors per (prev, cur) context.
    pub branching: usize,
    /// Probability mass on preferred successors (higher = lower entropy).
    pub peak: f32,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec { vocab: 64, length: 200_000, branching: 4, peak: 0.85, seed: 0 }
    }
}

impl TokenCorpus {
    pub fn generate(spec: &CorpusSpec) -> TokenCorpus {
        let mut rng = Rng::new(spec.seed ^ 0x70C0_1215);
        let v = spec.vocab;
        // For each context hash, a preferred successor set.
        // Kept implicit via hashing to avoid a v² table at larger vocabs.
        let ctx_salt = rng.next_u64();
        let mut tokens = Vec::with_capacity(spec.length);
        let (mut prev, mut cur) = (0u32, 1u32 % v as u32);
        for _ in 0..spec.length {
            let next = if rng.uniform() < spec.peak as f64 {
                // Deterministic preferred successor from the context.
                let k = rng.below(spec.branching) as u64;
                let h = (prev as u64)
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(cur as u64)
                    .wrapping_mul(0xA24B_AED4_963E_E407)
                    .wrapping_add(ctx_salt)
                    .wrapping_add(k.wrapping_mul(0x165_667B1));
                ((h >> 17) % v as u64) as u32
            } else {
                // Zipf-ish background: prefer low token ids.
                let u = rng.uniform();
                ((u * u * v as f64) as usize % v) as u32
            };
            tokens.push(next);
            prev = cur;
            cur = next;
        }
        TokenCorpus { vocab: v, tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Sample a batch of `(input, target)` windows of length `seq`:
    /// inputs `t[i..i+seq]`, targets `t[i+1..i+seq+1]`.
    pub fn sample_batch(
        &self,
        batch: usize,
        seq: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<u32>) {
        assert!(self.tokens.len() > seq + 1, "corpus shorter than sequence");
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - seq - 1);
            x.extend_from_slice(&self.tokens[start..start + seq]);
            y.extend_from_slice(&self.tokens[start + 1..start + seq + 1]);
        }
        (x, y)
    }

    /// Empirical unigram entropy (nats) — a perplexity sanity anchor.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = CorpusSpec { length: 1000, ..Default::default() };
        assert_eq!(TokenCorpus::generate(&spec).tokens, TokenCorpus::generate(&spec).tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let spec = CorpusSpec { vocab: 17, length: 5000, ..Default::default() };
        let c = TokenCorpus::generate(&spec);
        assert!(c.tokens.iter().all(|&t| t < 17));
    }

    #[test]
    fn batch_shapes_and_shift() {
        let c = TokenCorpus::generate(&CorpusSpec { length: 1000, ..Default::default() });
        let mut rng = Rng::new(1);
        let (x, y) = c.sample_batch(4, 16, &mut rng);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        // y is x shifted by one within each window — check via re-lookup.
        // (Windows overlap the corpus so verify first window only.)
        let first_x = &x[0..16];
        let first_y = &y[0..16];
        assert_eq!(&first_x[1..], &first_y[..15]);
    }

    #[test]
    fn structure_is_learnable() {
        // The Markov structure must make bigram prediction beat unigram:
        // estimated conditional entropy < unigram entropy.
        let c = TokenCorpus::generate(&CorpusSpec {
            vocab: 32,
            length: 100_000,
            ..Default::default()
        });
        let h1 = c.unigram_entropy();
        // The chain is order-2: estimate H(next | prev, cur) over trigrams.
        let v = 32usize;
        let mut joint = vec![0f64; v * v * v];
        for w in c.tokens.windows(3) {
            joint[(w[0] as usize * v + w[1] as usize) * v + w[2] as usize] += 1.0;
        }
        let total: f64 = joint.iter().sum();
        let mut h3 = 0.0;
        for ctx in 0..v * v {
            let row = &joint[ctx * v..(ctx + 1) * v];
            let rn: f64 = row.iter().sum();
            if rn == 0.0 {
                continue;
            }
            for &cnt in row {
                if cnt > 0.0 {
                    let p_joint = cnt / total;
                    let p_cond = cnt / rn;
                    h3 -= p_joint * p_cond.ln();
                }
            }
        }
        assert!(
            h3 < h1 * 0.8,
            "order-2 conditional entropy {h3:.3} should be well below unigram {h1:.3}"
        );
    }
}

//! Gaussian-cluster classification data (the CIFAR-100 / Tiny-ImageNet
//! analog): `classes` anisotropic gaussian clusters in `dim` dimensions with
//! class-dependent covariance structure, plus label noise — hard enough
//! that optimizer ranking (Shampoo > first-order) emerges, small enough for
//! CPU training.

use crate::util::rng::Rng;

/// An in-memory labelled dataset of f32 feature vectors.
#[derive(Clone, Debug)]
pub struct ClusterDataset {
    pub dim: usize,
    pub classes: usize,
    pub features: Vec<f32>, // row-major [n, dim]
    pub labels: Vec<u32>,
}

/// Generation settings.
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    pub dim: usize,
    pub classes: usize,
    pub train: usize,
    pub test: usize,
    /// Cluster center scale (separation); smaller = harder.
    pub separation: f32,
    /// Within-class noise scale.
    pub noise: f32,
    /// Fraction of labels randomly flipped.
    pub label_noise: f32,
    pub seed: u64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            dim: 64,
            classes: 32,
            train: 4096,
            test: 1024,
            separation: 1.0,
            noise: 0.9,
            label_noise: 0.02,
            seed: 0,
        }
    }
}

impl ClusterDataset {
    /// Generate a (train, test) pair sharing cluster geometry.
    pub fn generate(spec: &ClusterSpec) -> (ClusterDataset, ClusterDataset) {
        let mut rng = Rng::new(spec.seed ^ 0xC1A5_55E5);
        // Class centers with a shared low-rank "style" component that makes
        // input covariance ill-conditioned (where preconditioning helps).
        let centers: Vec<Vec<f32>> = (0..spec.classes)
            .map(|_| (0..spec.dim).map(|_| rng.normal_f32(spec.separation)).collect())
            .collect();
        let n_directions = (spec.dim / 4).max(1);
        let directions: Vec<Vec<f32>> = (0..n_directions)
            .map(|_| (0..spec.dim).map(|_| rng.normal_f32(1.0)).collect())
            .collect();

        let make = |n: usize, rng: &mut Rng| {
            let mut features = Vec::with_capacity(n * spec.dim);
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                let y = rng.below(spec.classes);
                let mut x: Vec<f32> =
                    centers[y].iter().map(|&c| c + rng.normal_f32(spec.noise)).collect();
                // Strong shared directions → anisotropic covariance.
                for d in &directions {
                    let a = rng.normal_f32(2.0);
                    for (xi, di) in x.iter_mut().zip(d.iter()) {
                        *xi += a * di;
                    }
                }
                let y = if rng.uniform() < spec.label_noise as f64 {
                    rng.below(spec.classes)
                } else {
                    y
                };
                features.extend_from_slice(&x);
                labels.push(y as u32);
            }
            ClusterDataset { dim: spec.dim, classes: spec.classes, features, labels }
        };

        let mut train_rng = rng.fork(1);
        let mut test_rng = rng.fork(2);
        let (mut train, mut test) =
            (make(spec.train, &mut train_rng), make(spec.test, &mut test_rng));

        // Standardize to unit global variance (train statistics applied to
        // both splits): keeps the anisotropic covariance *structure* while
        // keeping gradients at trainable scale.
        let n = train.features.len().max(1);
        let mean: f32 = train.features.iter().sum::<f32>() / n as f32;
        let var: f32 =
            train.features.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        let inv_std = 1.0 / var.sqrt().max(1e-6);
        for v in train.features.iter_mut().chain(test.features.iter_mut()) {
            *v = (*v - mean) * inv_std;
        }
        (train, test)
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Copy batch `indices` into flat buffers.
    pub fn gather(&self, indices: &[usize]) -> (Vec<f32>, Vec<u32>) {
        let mut x = Vec::with_capacity(indices.len() * self.dim);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(&self.features[i * self.dim..(i + 1) * self.dim]);
            y.push(self.labels[i]);
        }
        (x, y)
    }

    /// Sequential batch iterator with reshuffling each epoch.
    pub fn batches(&self, batch: usize, seed: u64) -> BatchIter<'_> {
        let mut order: Vec<usize> = (0..self.len()).collect();
        Rng::new(seed).shuffle(&mut order);
        BatchIter { ds: self, order, batch, pos: 0 }
    }
}

/// Epoch iterator over shuffled batches (drops the ragged tail).
pub struct BatchIter<'a> {
    ds: &'a ClusterDataset,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = (Vec<f32>, Vec<u32>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let idx = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        Some(self.ds.gather(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = ClusterSpec { train: 100, test: 50, ..Default::default() };
        let (a, _) = ClusterDataset::generate(&spec);
        let (b, _) = ClusterDataset::generate(&spec);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn shapes_and_label_range() {
        let spec = ClusterSpec { dim: 16, classes: 5, train: 64, test: 32, ..Default::default() };
        let (tr, te) = ClusterDataset::generate(&spec);
        assert_eq!(tr.features.len(), 64 * 16);
        assert_eq!(te.len(), 32);
        assert!(tr.labels.iter().all(|&y| y < 5));
    }

    #[test]
    fn train_test_differ() {
        let spec = ClusterSpec { train: 64, test: 64, ..Default::default() };
        let (tr, te) = ClusterDataset::generate(&spec);
        assert_ne!(tr.features, te.features);
    }

    #[test]
    fn batches_cover_epoch() {
        let spec = ClusterSpec { train: 100, test: 10, ..Default::default() };
        let (tr, _) = ClusterDataset::generate(&spec);
        let n: usize = tr.batches(32, 7).map(|(_, y)| y.len()).sum();
        assert_eq!(n, 96); // 3 full batches, ragged tail dropped
    }

    #[test]
    fn classes_are_separable_by_a_linear_probe() {
        // Sanity: nearest-centroid on train should beat chance by a lot.
        let spec = ClusterSpec {
            dim: 32,
            classes: 8,
            train: 800,
            test: 200,
            separation: 1.5,
            noise: 0.5,
            label_noise: 0.0,
            ..Default::default()
        };
        let (tr, te) = ClusterDataset::generate(&spec);
        // Class centroids from train.
        let mut centroids = vec![vec![0.0f32; 32]; 8];
        let mut counts = vec![0usize; 8];
        for i in 0..tr.len() {
            let y = tr.labels[i] as usize;
            counts[y] += 1;
            for d in 0..32 {
                centroids[y][d] += tr.features[i * 32 + d];
            }
        }
        for (c, &n) in centroids.iter_mut().zip(counts.iter()) {
            for v in c.iter_mut() {
                *v /= n.max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..te.len() {
            let x = &te.features[i * 32..(i + 1) * 32];
            let mut best = (f32::INFINITY, 0usize);
            for (k, c) in centroids.iter().enumerate() {
                let d: f32 = x.iter().zip(c.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, k);
                }
            }
            if best.1 as u32 == te.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.len() as f64;
        assert!(acc > 0.3, "nearest-centroid acc {acc} vs chance 0.125");
    }
}

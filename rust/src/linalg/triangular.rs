//! Triangular solves (forward/back substitution).

use super::matrix::Matrix;

/// Solve `L · X = B` for lower-triangular `L` (forward substitution),
/// column-by-column over B.
pub fn solve_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let m = b.cols();
    let mut x = b.clone();
    for j in 0..m {
        for i in 0..n {
            let mut s = x[(i, j)] as f64;
            for k in 0..i {
                s -= l[(i, k)] as f64 * x[(k, j)] as f64;
            }
            x[(i, j)] = (s / l[(i, i)] as f64) as f32;
        }
    }
    x
}

/// Solve `Lᵀ · X = B` for lower-triangular `L` (back substitution).
pub fn solve_lower_transpose(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let m = b.cols();
    let mut x = b.clone();
    for j in 0..m {
        for i in (0..n).rev() {
            let mut s = x[(i, j)] as f64;
            for k in (i + 1)..n {
                s -= l[(k, i)] as f64 * x[(k, j)] as f64;
            }
            x[(i, j)] = (s / l[(i, i)] as f64) as f32;
        }
    }
    x
}

/// Inverse of an SPD matrix given its Cholesky factor: `A⁻¹ = L⁻ᵀ·L⁻¹`
/// computed as two triangular solves against the identity.
pub fn spd_inverse_from_cholesky(l: &Matrix) -> Matrix {
    let n = l.rows();
    let y = solve_lower(l, &Matrix::eye(n));
    solve_lower_transpose(l, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky;
    use crate::linalg::matmul::{matmul, syrk};
    use crate::util::rng::Rng;

    #[test]
    fn forward_solve() {
        let l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        let b = Matrix::from_rows(&[&[4.0], &[11.0]]);
        let x = solve_lower(&l, &b);
        assert!((x[(0, 0)] - 2.0).abs() < 1e-6);
        assert!((x[(1, 0)] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn transpose_solve_consistency() {
        let mut rng = Rng::new(1);
        let g = Matrix::randn(10, 14, 1.0, &mut rng);
        let mut a = syrk(&g);
        a.add_diag(0.5);
        let l = cholesky(&a).unwrap();
        let b = Matrix::randn(10, 3, 1.0, &mut rng);
        let x = solve_lower_transpose(&l, &solve_lower(&l, &b));
        // A·x should equal b
        let back = matmul(&a, &x);
        assert!(back.max_abs_diff(&b) < 1e-3);
    }

    #[test]
    fn spd_inverse() {
        let mut rng = Rng::new(2);
        let g = Matrix::randn(8, 12, 1.0, &mut rng);
        let mut a = syrk(&g);
        a.add_diag(1.0);
        let l = cholesky(&a).unwrap();
        let inv = spd_inverse_from_cholesky(&l);
        let prod = matmul(&a, &inv);
        assert!(prod.max_abs_diff(&Matrix::eye(8)) < 1e-3);
    }
}

//! Kronecker product (validation oracle for the vectorized Shampoo update,
//! Eq. (14)–(15): `H_k = D(R̂) ⊗ D(L̂)`).

use super::matrix::Matrix;

/// `A ⊗ B`.
pub fn kron(a: &Matrix, b: &Matrix) -> Matrix {
    let (ar, ac) = (a.rows(), a.cols());
    let (br, bc) = (b.rows(), b.cols());
    let mut out = Matrix::zeros(ar * br, ac * bc);
    for i in 0..ar {
        for j in 0..ac {
            let s = a[(i, j)];
            if s == 0.0 {
                continue;
            }
            for bi in 0..br {
                for bj in 0..bc {
                    out[(i * br + bi, j * bc + bj)] = s * b[(bi, bj)];
                }
            }
        }
    }
    out
}

/// Column-stacking vectorization `Vec(W)` (paper Eq. (14): columns
/// concatenated).
pub fn vec_cols(w: &Matrix) -> Vec<f32> {
    let mut out = Vec::with_capacity(w.rows() * w.cols());
    for j in 0..w.cols() {
        for i in 0..w.rows() {
            out.push(w[(i, j)]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn kron_small() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        let b = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let k = kron(&a, &b);
        assert_eq!(k.rows(), 2);
        assert_eq!(k.cols(), 4);
        assert_eq!(k[(0, 1)], 1.0);
        assert_eq!(k[(0, 3)], 2.0);
    }

    /// The identity the paper's Appendix B vectorization rests on:
    /// Vec(L·G·R) = (Rᵀ ⊗ L)·Vec(G).
    #[test]
    fn kron_vec_identity() {
        let mut rng = Rng::new(1);
        let l = Matrix::randn(3, 3, 1.0, &mut rng);
        let g = Matrix::randn(3, 4, 1.0, &mut rng);
        let r = Matrix::randn(4, 4, 1.0, &mut rng);

        let lgr = matmul(&matmul(&l, &g), &r);
        let lhs = vec_cols(&lgr);

        let k = kron(&r.transpose(), &l);
        let vg = vec_cols(&g);
        let mut rhs = vec![0.0f32; lhs.len()];
        for i in 0..k.rows() {
            rhs[i] = crate::linalg::matmul::dot(k.row(i), &vg);
        }
        for (x, y) in lhs.iter().zip(rhs.iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}

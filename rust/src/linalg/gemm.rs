//! Packed-panel microkernel GEMM — the crate's raw-speed tier.
//!
//! Every dense product in the optimizer funnels into this module through the
//! `linalg::matmul` entry points: the Gram accumulations (`GᵀG`, `G·Gᵀ`),
//! the blocked Cholesky trailing update, the Schur–Newton and eigensolver
//! iterations, and the `L̂·G·R̂` preconditioning itself. The design is the
//! classic GotoBLAS/BLIS decomposition, dependency-free and in pure Rust:
//!
//! ```text
//! for pc in (0..k).step_by(KC)          ← sequential (fixes summation order)
//!   pack A[:, pc..pc+kc]   → MR-row panels, k-major, zero-padded
//!   for jc in (0..n).step_by(NC)        ← parallel_for over jc slabs
//!     pack B[pc.., jc..jc+nc] → NR-col panels, k-major, zero-padded
//!     for ic in (0..m).step_by(MC)      ← L2-resident stripe of packed A
//!       for jr in (jc..).step_by(NR)    ← one packed-B panel (L1)
//!         for ir in (ic..).step_by(MR)  ← one packed-A panel (registers)
//!           microkernel: MR×NR tile += Σ_kc a-panel ⊗ b-panel
//! ```
//!
//! Tall-skinny products (`m ≫ n`, a single jc slab) would starve the
//! column-parallel grain, so the driver switches to `parallel_for` over the
//! `ic` row stripes instead: B is packed once on the calling thread and
//! stripes write disjoint C row ranges (same bit-identity argument).
//!
//! The microkernel computes a full `MR×NR = 6×16` register tile (twelve
//! 8-lane accumulators on AVX2) from two k-major panels; partial edge tiles
//! are handled by zero-padding the packs and copying back only the valid
//! `mr×nr` window, so the kernel itself has no edge cases. Two kernels are
//! compiled: a portable scalar one (fallback on non-x86 targets *and* the
//! correctness oracle the tests pin against) and an AVX2+FMA one selected
//! at runtime via `is_x86_feature_detected!` — no `-C target-cpu` flags or
//! external BLAS needed.
//!
//! ## Determinism contract
//!
//! The summation order of every `C[i][j]` is fixed by the sequential `pc`
//! (KC-slab) loop alone; the parallel grain — `jc` column slabs, or `ic`
//! row stripes on tall-skinny shapes — always partitions C disjointly.
//! Parallel and sequential runs are therefore **bit-identical** for a given
//! microkernel. `Avx2` and `Scalar` differ
//! only in rounding (FMA contraction, 8-lane sub-sums) and are pinned to
//! ≤1e-5 relative Frobenius by `tests/kernel_equivalence.rs`.
//!
//! ## Scratch ownership
//!
//! Packing buffers live in a [`MatmulPlan`] (usually the one owned by
//! `linalg::ScratchArena`): after warm-up they are reused verbatim, so the
//! steady-state refresh pipeline performs zero GEMM allocations —
//! observable via [`MatmulPlan::grows`] and asserted by the scratch-reuse
//! suite.
//!
//! ```
//! use quartz::linalg::gemm::{gemm_with, Microkernel};
//! use quartz::linalg::{MatmulPlan, Matrix};
//!
//! // 2×3 · 3×2 against the hand-computed product (exact in f32).
//! let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
//! let b = Matrix::from_rows(&[&[7.0, 8.0], &[9.0, 10.0], &[11.0, 12.0]]);
//! let mut c = Matrix::zeros(2, 2);
//! let mut plan = MatmulPlan::new();
//! gemm_with(&a, false, &b, false, &mut c, &mut plan, Microkernel::Scalar, 1);
//! assert_eq!(c, Matrix::from_rows(&[&[58.0, 64.0], &[139.0, 154.0]]));
//! ```

use super::matmul::SendPtr;
use super::matrix::Matrix;
use crate::util::pool::{default_threads, parallel_for};
use std::sync::OnceLock;

/// Microkernel tile rows (register-blocking factor over C rows).
pub const MR: usize = 6;
/// Microkernel tile columns: two 8-lane vectors on AVX2.
pub const NR: usize = 16;
/// L2 stripe height of packed A; a multiple of [`MR`].
pub const MC: usize = 96;
/// Depth of one packed slab pair (the sequential accumulation step).
pub const KC: usize = 240;
/// Width of one packed-B slab — the parallel grain; a multiple of [`NR`].
pub const NC: usize = 192;

/// Products with any dimension below this skip packing entirely.
pub const GEMM_SMALL_DIM: usize = 8;
/// Products with fewer total FLOPs than this (`2mnk`) skip packing.
pub const GEMM_SMALL_FLOP: usize = 1 << 16;
/// FLOP threshold below which the driver stays single-threaded.
const PAR_FLOP_THRESHOLD: usize = 1 << 20;

/// Which compiled microkernel drives the packed tier.
///
/// `Scalar` is always available and is the oracle the SIMD path is tested
/// against; `Avx2` requires runtime AVX2+FMA support (see
/// [`avx2_available`]) and falls back to `Scalar` on other architectures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Microkernel {
    /// Portable scalar kernel (fallback and correctness oracle).
    Scalar,
    /// AVX2+FMA register-tiled kernel, selected at runtime on x86_64.
    Avx2,
}

/// Whether the running CPU supports the AVX2+FMA microkernel.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The microkernel the auto-dispatching entry points use (detected once).
pub fn active_microkernel() -> Microkernel {
    static DETECTED: OnceLock<Microkernel> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if avx2_available() {
            Microkernel::Avx2
        } else {
            Microkernel::Scalar
        }
    })
}

/// Reusable packing scratch for repeated products (avoids reallocating the
/// packed-panel buffers inside optimizer loops).
///
/// Plan-audit rule (hot-path discipline): `matmul`/`matmul_into` create a
/// fresh plan per call, which is fine for one-off products but silently
/// re-allocates inside loops. Anything called per refresh step — Shampoo's
/// preconditioning, the Gram updates, the Schur–Newton iteration, the
/// eigensolver fallback — must route through the `*_planned` entry points
/// with a caller-owned plan (typically the one inside
/// `linalg::ScratchArena`).
#[derive(Debug, Default)]
pub struct MatmulPlan {
    packed_a: Vec<f32>,
    packed_b: Vec<f32>,
    grows: usize,
}

impl MatmulPlan {
    pub fn new() -> Self {
        MatmulPlan::default()
    }

    /// Number of times the packing buffers had to grow. Stable across steps
    /// ⇔ the steady-state GEMM pipeline is allocation-free (the packing
    /// half of the scratch-reuse invariant; buffer takes are tracked by
    /// `ScratchArena::misses`).
    pub fn grows(&self) -> usize {
        self.grows
    }

    /// Grow (never shrink) the pack buffers to the given lengths.
    fn ensure(&mut self, a_len: usize, b_len: usize) {
        if self.packed_a.len() < a_len {
            self.grows += 1;
            self.packed_a.resize(a_len, 0.0);
        }
        if self.packed_b.len() < b_len {
            self.grows += 1;
            self.packed_b.resize(b_len, 0.0);
        }
    }
}

/// Read-only strided view: element `(i, j)` lives at `ptr[i·rs + j·cs]`.
/// One shape serves N/T operands and submatrix windows (the Cholesky
/// trailing block) without materializing transposes or copies.
#[derive(Clone, Copy)]
struct View {
    ptr: *const f32,
    rs: usize,
    cs: usize,
}

// Safety: View only reads, and the driver's parallel tasks never write to
// the viewed storage (operand/output disjointness is the caller contract).
unsafe impl Sync for View {}

impl View {
    fn of(m: &Matrix, transposed: bool) -> View {
        let ptr = m.data().as_ptr();
        if transposed {
            View { ptr, rs: 1, cs: m.cols() }
        } else {
            View { ptr, rs: m.cols(), cs: 1 }
        }
    }

    /// # Safety
    /// `(i, j)` must lie inside the viewed matrix.
    #[inline(always)]
    unsafe fn at(&self, i: usize, j: usize) -> f32 {
        *self.ptr.add(i * self.rs + j * self.cs)
    }
}

/// Read-only raw pointer that may cross the scoped-thread boundary (the
/// `*const` sibling of `matmul::SendPtr`).
struct SendConst<T>(*const T);
unsafe impl<T> Sync for SendConst<T> {}
impl<T> SendConst<T> {
    #[inline]
    fn get(&self) -> *const T {
        self.0
    }
}

/// How a computed tile lands in C.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Acc {
    /// Overwrite (first KC slab of a plain product).
    Set,
    /// Accumulate (subsequent KC slabs).
    Add,
    /// Subtract (the Cholesky trailing update `A22 −= L21·L21ᵀ`).
    Sub,
}

fn is_small(m: usize, n: usize, k: usize) -> bool {
    m.min(n).min(k) < GEMM_SMALL_DIM || 2 * m * n * k < GEMM_SMALL_FLOP
}

fn auto_threads(flops: usize) -> usize {
    if flops < PAR_FLOP_THRESHOLD {
        1
    } else {
        default_threads()
    }
}

fn op_shape(m: &Matrix, transposed: bool) -> (usize, usize) {
    if transposed {
        (m.cols(), m.rows())
    } else {
        (m.rows(), m.cols())
    }
}

/// `C = op(A)·op(B)` through the packed-panel tier with an explicit
/// microkernel and thread count — the entry point the equivalence tests and
/// benches use to pin `Avx2` against `Scalar` and parallel against
/// sequential. Unlike the auto-dispatching `matmul_*` wrappers it never
/// takes the small-product shortcut, so edge tiles are exercised even on
/// tiny shapes. `ta`/`tb` select `Aᵀ`/`Bᵀ`.
pub fn gemm_with(
    a: &Matrix,
    ta: bool,
    b: &Matrix,
    tb: bool,
    c: &mut Matrix,
    plan: &mut MatmulPlan,
    kernel: Microkernel,
    threads: usize,
) {
    let (m, n, k) = checked_dims(a, ta, b, tb, c);
    let (av, bv) = (View::of(a, ta), View::of(b, tb));
    let cp = c.data_mut().as_mut_ptr();
    // Safety: `c` is a distinct `&mut Matrix`, so the output window cannot
    // overlap either operand's storage.
    unsafe { driver(m, n, k, av, bv, cp, n, false, false, plan, kernel, threads) };
}

/// Lower-triangle SYRK `C[lower] = A·Aᵀ` through the packed tier with an
/// explicit microkernel and thread count (test/bench entry point; see
/// [`gemm_with`]). The strict upper triangle of `C` is left untouched.
pub fn syrk_lower_with(
    a: &Matrix,
    c: &mut Matrix,
    plan: &mut MatmulPlan,
    kernel: Microkernel,
    threads: usize,
) {
    let m = a.rows();
    let k = a.cols();
    assert_eq!((c.rows(), c.cols()), (m, m), "output shape mismatch");
    let (av, bv) = (View::of(a, false), View::of(a, true));
    let cp = c.data_mut().as_mut_ptr();
    // Safety: `c` is a distinct `&mut Matrix` (no operand overlap).
    unsafe { driver(m, m, k, av, bv, cp, m, true, false, plan, kernel, threads) };
}

/// Auto-dispatching `C = op(A)·op(B)` used by the public `matmul_*` entry
/// points: small products take the plain loop, everything else the packed
/// tier with the detected microkernel.
pub(crate) fn gemm_into(
    a: &Matrix,
    ta: bool,
    b: &Matrix,
    tb: bool,
    c: &mut Matrix,
    plan: &mut MatmulPlan,
) {
    let (m, n, k) = checked_dims(a, ta, b, tb, c);
    let (av, bv) = (View::of(a, ta), View::of(b, tb));
    let cp = c.data_mut().as_mut_ptr();
    // Safety: `c` is a distinct `&mut Matrix` (no operand overlap).
    unsafe {
        if is_small(m, n, k) {
            small_kernel(m, n, k, av, bv, cp, n, false, Acc::Set);
        } else {
            let threads = auto_threads(2 * m * n * k);
            driver(m, n, k, av, bv, cp, n, false, false, plan, active_microkernel(), threads);
        }
    }
}

/// Auto-dispatching lower-triangle SYRK used by the public `syrk*` entry
/// points; the strict upper triangle of `C` is left untouched.
pub(crate) fn syrk_lower(a: &Matrix, c: &mut Matrix, plan: &mut MatmulPlan) {
    let m = a.rows();
    let k = a.cols();
    assert_eq!((c.rows(), c.cols()), (m, m), "output shape mismatch");
    let (av, bv) = (View::of(a, false), View::of(a, true));
    let cp = c.data_mut().as_mut_ptr();
    // Safety: `c` is a distinct `&mut Matrix` (no operand overlap).
    unsafe {
        if is_small(m, m, k) {
            small_kernel(m, m, k, av, bv, cp, m, true, Acc::Set);
        } else {
            let threads = auto_threads(m * m * k);
            driver(m, m, k, av, bv, cp, m, true, false, plan, active_microkernel(), threads);
        }
    }
}

/// Trailing-update entry for the blocked Cholesky: `C −= A·Aᵀ` on the lower
/// triangle only, where `C` (`m×m`) and `A` (`m×k`) are windows into one
/// allocation with row stride `ld`.
///
/// # Safety
/// `c` must point at an `m×m` window and `a` at an `m×k` window, both with
/// row stride `ld ≥` their widths, and the two windows must be disjoint.
pub(crate) unsafe fn syrk_sub_lower_raw(
    c: *mut f32,
    a: *const f32,
    ld: usize,
    m: usize,
    k: usize,
    threads: usize,
    plan: &mut MatmulPlan,
) {
    let av = View { ptr: a, rs: ld, cs: 1 };
    let bv = View { ptr: a, rs: 1, cs: ld };
    if is_small(m, m, k) {
        small_kernel(m, m, k, av, bv, c, ld, true, Acc::Sub);
    } else {
        driver(m, m, k, av, bv, c, ld, true, true, plan, active_microkernel(), threads);
    }
}

fn checked_dims(a: &Matrix, ta: bool, b: &Matrix, tb: bool, c: &Matrix) -> (usize, usize, usize) {
    let (m, ka) = op_shape(a, ta);
    let (kb, n) = op_shape(b, tb);
    assert_eq!(ka, kb, "inner dimension mismatch: {}x{} · {}x{}", m, ka, kb, n);
    assert_eq!((c.rows(), c.cols()), (m, n), "output shape mismatch");
    (m, n, ka)
}

/// The packed-panel driver. `lower` restricts writes to `j ≤ i`; `sub`
/// subtracts the product from C instead of overwriting it.
///
/// # Safety
/// `c` must point at an `m×n` window with row stride `ldc ≥ n` whose
/// storage is disjoint from both operand views.
unsafe fn driver(
    m: usize,
    n: usize,
    k: usize,
    av: View,
    bv: View,
    c: *mut f32,
    ldc: usize,
    lower: bool,
    sub: bool,
    plan: &mut MatmulPlan,
    kernel: Microkernel,
    threads: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // Empty accumulation: Set zero-fills, Sub leaves C unchanged.
        small_kernel(m, n, k, av, bv, c, ldc, lower, if sub { Acc::Sub } else { Acc::Set });
        return;
    }
    let kc_max = KC.min(k);
    let jc_tasks = n.div_ceil(NC);
    plan.ensure(m.div_ceil(MR) * MR * kc_max, jc_tasks * NC * kc_max);

    // Tall-skinny shapes (m ≫ n) have a single jc slab, which starves the
    // column-parallel grain; switch the grain to MC row stripes instead.
    // Stripes write disjoint C row ranges and leave every element's
    // summation order untouched, so this path is bit-identical too.
    let ic_tasks = m.div_ceil(MC);
    let ic_parallel = threads > 1 && jc_tasks == 1 && ic_tasks > 1;

    let mut pc = 0usize;
    while pc < k {
        let kc = KC.min(k - pc);
        pack_a(av, m, pc, kc, &mut plan.packed_a);
        let acc = if sub {
            Acc::Sub
        } else if pc == 0 {
            Acc::Set
        } else {
            Acc::Add
        };
        let pa = SendConst(plan.packed_a.as_ptr());
        let pb = SendPtr(plan.packed_b.as_mut_ptr());
        let cp = SendPtr(c);
        if ic_parallel {
            // Single slab: pack B once on the calling thread, then fan the
            // row stripes out over the pool.
            pack_b(bv, pc, kc, 0, n, pb.get());
            let pbc = SendConst(plan.packed_b.as_ptr());
            parallel_for(ic_tasks, threads, |it| {
                let ic = it * MC;
                let mc = MC.min(m - ic);
                // Safety: stripe it writes only rows [ic, ic+mc) of C —
                // ranges disjoint across tasks; packs are read-only here.
                unsafe {
                    let (p, b) = (pa.get(), pbc.get());
                    stripe_panel(kernel, kc, ic, mc, m, 0, n, p, b, cp.get(), ldc, acc, lower);
                }
            });
        } else {
            parallel_for(jc_tasks, threads, |jt| {
                let col0 = jt * NC;
                let nc = NC.min(n - col0);
                // Safety: task jt owns packed-B slab jt and writes only
                // columns [col0, col0+nc) of C — ranges disjoint across
                // tasks.
                unsafe {
                    let slab = pb.get().add(jt * NC * kc_max);
                    pack_b(bv, pc, kc, col0, nc, slab);
                    macro_panel(kernel, kc, m, col0, nc, pa.get(), slab, cp.get(), ldc, acc, lower);
                }
            });
        }
        pc += kc;
    }
}

/// Pack `A[:, pc..pc+kc]` into MR-row panels, k-major, rows beyond `m`
/// zero-padded: panel `p` holds rows `p·MR..` at `out[p·MR·kc + kk·MR + r]`.
///
/// # Safety
/// The column range `[pc, pc+kc)` must lie inside the viewed matrix.
unsafe fn pack_a(av: View, m: usize, pc: usize, kc: usize, out: &mut [f32]) {
    for p in 0..m.div_ceil(MR) {
        let r0 = p * MR;
        let rows = MR.min(m - r0);
        for kk in 0..kc {
            let dst = &mut out[p * MR * kc + kk * MR..p * MR * kc + (kk + 1) * MR];
            for (r, slot) in dst.iter_mut().enumerate() {
                *slot = if r < rows { av.at(r0 + r, pc + kk) } else { 0.0 };
            }
        }
    }
}

/// Pack `B[pc..pc+kc, col0..col0+nc]` into NR-column panels, k-major,
/// columns beyond the edge zero-padded.
///
/// # Safety
/// The viewed ranges must be in bounds and `out` valid for
/// `nc.div_ceil(NR)·NR·kc` writes.
unsafe fn pack_b(bv: View, pc: usize, kc: usize, col0: usize, nc: usize, out: *mut f32) {
    for q in 0..nc.div_ceil(NR) {
        let c0 = col0 + q * NR;
        let cols = NR.min(col0 + nc - c0);
        for kk in 0..kc {
            let dst = out.add(q * NR * kc + kk * NR);
            for j in 0..NR {
                *dst.add(j) = if j < cols { bv.at(pc + kk, c0 + j) } else { 0.0 };
            }
        }
    }
}

/// One jc-slab's macro loops: MC stripes of packed A × NR panels of the
/// packed-B slab, microkernel per tile, valid window copied back to C.
///
/// # Safety
/// Same window contract as [`driver`]; `pa`/`pb` must hold the packed
/// panels described by [`pack_a`]/[`pack_b`] for this slab.
unsafe fn macro_panel(
    kernel: Microkernel,
    kc: usize,
    m: usize,
    col0: usize,
    nc: usize,
    pa: *const f32,
    pb: *const f32,
    c: *mut f32,
    ldc: usize,
    acc: Acc,
    lower: bool,
) {
    let mut ic = 0;
    while ic < m {
        let mc = MC.min(m - ic);
        stripe_panel(kernel, kc, ic, mc, m, col0, nc, pa, pb, c, ldc, acc, lower);
        ic += MC;
    }
}

/// One MC row stripe of one jc slab: NR panels of packed B × MR panels of
/// the stripe's packed A, microkernel per tile. This is the grain of the
/// tall-skinny ic-parallel path — stripes write disjoint C row ranges, and
/// the per-element summation order (sequential `pc`, fixed tile kernel) is
/// unchanged, so stripe-parallel runs are bit-identical to sequential.
///
/// # Safety
/// Same window contract as [`driver`]; `[ic, ic+mc)` must lie inside
/// `[0, m)` on an MC boundary, and `pa`/`pb` must hold the packed panels
/// described by [`pack_a`]/[`pack_b`].
unsafe fn stripe_panel(
    kernel: Microkernel,
    kc: usize,
    ic: usize,
    mc: usize,
    m: usize,
    col0: usize,
    nc: usize,
    pa: *const f32,
    pb: *const f32,
    c: *mut f32,
    ldc: usize,
    acc: Acc,
    lower: bool,
) {
    for q in 0..nc.div_ceil(NR) {
        let j0 = col0 + q * NR;
        let nr = NR.min(col0 + nc - j0);
        let bpan = pb.add(q * NR * kc);
        let mut ir = ic;
        while ir < ic + mc {
            let mr = MR.min(m - ir);
            // Lower-only: skip tiles strictly above the diagonal.
            if lower && j0 >= ir + mr {
                ir += MR;
                continue;
            }
            let apan = pa.add((ir / MR) * MR * kc);
            let mut tile = [0.0f32; MR * NR];
            run_kernel(kernel, kc, apan, bpan, &mut tile);
            write_tile(c, ldc, ir, j0, mr, nr, &tile, acc, lower);
            ir += MR;
        }
    }
}

#[inline]
unsafe fn run_kernel(
    kernel: Microkernel,
    kc: usize,
    a: *const f32,
    b: *const f32,
    tile: &mut [f32; MR * NR],
) {
    match kernel {
        Microkernel::Scalar => kernel_scalar(kc, a, b, tile),
        #[cfg(target_arch = "x86_64")]
        Microkernel::Avx2 => kernel_avx2(kc, a, b, tile),
        #[cfg(not(target_arch = "x86_64"))]
        Microkernel::Avx2 => kernel_scalar(kc, a, b, tile),
    }
}

/// Portable microkernel: `tile[r][j] = Σ_kk apan[kk][r] · bpan[kk][j]` over
/// one full (zero-padded) MR×NR tile. Fixed NR-wide inner loops
/// auto-vectorize; this is also the oracle the AVX2 kernel is pinned to.
///
/// # Safety
/// `a` must be valid for `kc·MR` reads and `b` for `kc·NR` reads.
unsafe fn kernel_scalar(kc: usize, a: *const f32, b: *const f32, tile: &mut [f32; MR * NR]) {
    for kk in 0..kc {
        let ap = std::slice::from_raw_parts(a.add(kk * MR), MR);
        let bp = std::slice::from_raw_parts(b.add(kk * NR), NR);
        for (r, &avv) in ap.iter().enumerate() {
            let row = &mut tile[r * NR..(r + 1) * NR];
            for (t, &bvv) in row.iter_mut().zip(bp.iter()) {
                *t += avv * bvv;
            }
        }
    }
}

/// AVX2+FMA microkernel: 6 rows × two 8-lane vectors = 12 ymm accumulators
/// (the classic Haswell sgemm shape), one FMA pair per packed A scalar.
///
/// # Safety
/// Caller must ensure AVX2+FMA are available (see [`avx2_available`]);
/// `a` must be valid for `kc·MR` reads and `b` for `kc·NR` reads.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn kernel_avx2(kc: usize, a: *const f32, b: *const f32, tile: &mut [f32; MR * NR]) {
    use std::arch::x86_64::{
        _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };
    let mut acc = [_mm256_setzero_ps(); 2 * MR];
    for kk in 0..kc {
        let b0 = _mm256_loadu_ps(b.add(kk * NR));
        let b1 = _mm256_loadu_ps(b.add(kk * NR + 8));
        for r in 0..MR {
            let avv = _mm256_set1_ps(*a.add(kk * MR + r));
            acc[2 * r] = _mm256_fmadd_ps(avv, b0, acc[2 * r]);
            acc[2 * r + 1] = _mm256_fmadd_ps(avv, b1, acc[2 * r + 1]);
        }
    }
    for r in 0..MR {
        _mm256_storeu_ps(tile.as_mut_ptr().add(r * NR), acc[2 * r]);
        _mm256_storeu_ps(tile.as_mut_ptr().add(r * NR + 8), acc[2 * r + 1]);
    }
}

/// Copy the valid `mr×nr` window of a computed tile into C (clipped to the
/// lower triangle when `lower`).
///
/// # Safety
/// Rows `[i0, i0+mr)` × columns `[j0, j0+nr)` must be in bounds of the `c`
/// window with row stride `ldc`.
unsafe fn write_tile(
    c: *mut f32,
    ldc: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    tile: &[f32; MR * NR],
    acc: Acc,
    lower: bool,
) {
    for r in 0..mr {
        let i = i0 + r;
        let cols = if lower {
            if i < j0 {
                0
            } else {
                nr.min(i - j0 + 1)
            }
        } else {
            nr
        };
        let dst = c.add(i * ldc + j0);
        let src = &tile[r * NR..r * NR + cols];
        match acc {
            Acc::Set => {
                for (j, &v) in src.iter().enumerate() {
                    *dst.add(j) = v;
                }
            }
            Acc::Add => {
                for (j, &v) in src.iter().enumerate() {
                    *dst.add(j) += v;
                }
            }
            Acc::Sub => {
                for (j, &v) in src.iter().enumerate() {
                    *dst.add(j) -= v;
                }
            }
        }
    }
}

/// Plain triple loop for products too small to amortize packing (also the
/// `k = 0` zero-fill path). Sequential, so trivially deterministic.
///
/// # Safety
/// Same window contract as [`driver`].
unsafe fn small_kernel(
    m: usize,
    n: usize,
    k: usize,
    av: View,
    bv: View,
    c: *mut f32,
    ldc: usize,
    lower: bool,
    acc: Acc,
) {
    for i in 0..m {
        let jmax = if lower { n.min(i + 1) } else { n };
        let dst = c.add(i * ldc);
        for j in 0..jmax {
            let mut s = 0.0f32;
            for kk in 0..k {
                s += av.at(i, kk) * bv.at(kk, j);
            }
            match acc {
                Acc::Set => *dst.add(j) = s,
                Acc::Add => *dst.add(j) += s,
                Acc::Sub => *dst.add(j) -= s,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::relative_error;
    use crate::util::rng::Rng;

    /// f64-accumulating reference product.
    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    const SHAPES: [(usize, usize, usize); 7] = [
        (1, 1, 1),
        (5, 3, 2),
        (6, 16, 240),
        (7, 17, 241),
        (64, 64, 64),
        (97, 50, 193),
        (130, 200, 70),
    ];

    #[test]
    fn packed_tier_matches_naive_all_op_combos() {
        let mut rng = Rng::new(11);
        let mut plan = MatmulPlan::new();
        for (m, n, k) in SHAPES {
            for (ta, tb) in [(false, false), (true, false), (false, true), (true, true)] {
                let (ar, ac) = if ta { (k, m) } else { (m, k) };
                let (br, bc) = if tb { (n, k) } else { (k, n) };
                let a = Matrix::randn(ar, ac, 1.0, &mut rng);
                let b = Matrix::randn(br, bc, 1.0, &mut rng);
                let ae = if ta { a.transpose() } else { a.clone() };
                let be = if tb { b.transpose() } else { b.clone() };
                let want = naive(&ae, &be);
                let mut c = Matrix::zeros(m, n);
                gemm_with(&a, ta, &b, tb, &mut c, &mut plan, Microkernel::Scalar, 1);
                let rel = relative_error(&want, &c);
                assert!(rel < 1e-5, "shape {m}x{n}x{k} ta={ta} tb={tb} rel={rel}");
            }
        }
    }

    #[test]
    fn avx2_kernel_matches_scalar_oracle() {
        if !avx2_available() {
            return;
        }
        let mut rng = Rng::new(12);
        let mut plan = MatmulPlan::new();
        for (m, n, k) in SHAPES {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let mut cs = Matrix::zeros(m, n);
            let mut cv = Matrix::zeros(m, n);
            gemm_with(&a, false, &b, false, &mut cs, &mut plan, Microkernel::Scalar, 1);
            gemm_with(&a, false, &b, false, &mut cv, &mut plan, Microkernel::Avx2, 1);
            let rel = relative_error(&cs, &cv);
            assert!(rel < 1e-5, "shape {m}x{n}x{k} rel={rel}");
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(150, 500, 1.0, &mut rng);
        let b = Matrix::randn(500, 410, 1.0, &mut rng);
        let mut plan = MatmulPlan::new();
        let mut c1 = Matrix::zeros(150, 410);
        gemm_with(&a, false, &b, false, &mut c1, &mut plan, Microkernel::Scalar, 1);
        for threads in [2, 4, 7] {
            let mut ct = Matrix::zeros(150, 410);
            gemm_with(&a, false, &b, false, &mut ct, &mut plan, Microkernel::Scalar, threads);
            assert_eq!(c1, ct, "threads={threads}");
        }
    }

    #[test]
    fn tall_skinny_ic_parallel_is_bit_identical_to_sequential() {
        // m ≫ n with n ≤ NC: a single jc slab, so the driver switches the
        // parallel grain to MC row stripes — the result must still match
        // the sequential run bit-for-bit, correct at the edges (m not a
        // multiple of MC), and agree with the reference product.
        let mut rng = Rng::new(21);
        let (m, n, k) = (500, 64, 300);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut plan = MatmulPlan::new();
        let mut c1 = Matrix::zeros(m, n);
        gemm_with(&a, false, &b, false, &mut c1, &mut plan, Microkernel::Scalar, 1);
        assert!(relative_error(&naive(&a, &b), &c1) < 1e-5);
        for threads in [2, 4, 7] {
            let mut ct = Matrix::zeros(m, n);
            gemm_with(&a, false, &b, false, &mut ct, &mut plan, Microkernel::Scalar, threads);
            assert_eq!(c1, ct, "threads={threads}");
        }
        // SYRK of a tall operand exercises the lower-triangle skip with the
        // stripe grain (m×m output from a single-slab m×k·k×m product).
        let tall = Matrix::randn(150, 24, 1.0, &mut rng);
        let mut s1 = Matrix::zeros(150, 150);
        syrk_lower_with(&tall, &mut s1, &mut plan, Microkernel::Scalar, 1);
        let mut s4 = Matrix::zeros(150, 150);
        syrk_lower_with(&tall, &mut s4, &mut plan, Microkernel::Scalar, 4);
        assert_eq!(s1, s4);
    }

    #[test]
    fn syrk_lower_leaves_upper_untouched() {
        let mut rng = Rng::new(14);
        let a = Matrix::randn(37, 29, 1.0, &mut rng);
        let want = naive(&a, &a.transpose());
        let mut c = Matrix::from_fn(37, 37, |_, _| 7.5);
        let mut plan = MatmulPlan::new();
        syrk_lower_with(&a, &mut c, &mut plan, Microkernel::Scalar, 1);
        for i in 0..37 {
            for j in 0..37 {
                if j > i {
                    assert_eq!(c[(i, j)], 7.5, "upper ({i},{j}) must be untouched");
                } else {
                    let d = (c[(i, j)] - want[(i, j)]).abs();
                    assert!(d < 1e-3, "lower ({i},{j}) diff {d}");
                }
            }
        }
    }

    #[test]
    fn syrk_sub_raw_subtracts_in_window() {
        // C −= A·Aᵀ where C and A are windows of one buffer, as in the
        // blocked Cholesky trailing update.
        let mut rng = Rng::new(15);
        let ld = 40;
        let (m, k) = (24, 12);
        let full = Matrix::randn(ld, ld, 1.0, &mut rng);
        let mut buf = full.clone();
        // A window at rows [16, 40), cols [0, 12); C at rows/cols [16, 40).
        let mut a = Matrix::zeros(m, k);
        for i in 0..m {
            for j in 0..k {
                a[(i, j)] = full[(16 + i, j)];
            }
        }
        let prod = naive(&a, &a.transpose());
        let base = buf.data_mut().as_mut_ptr();
        let mut plan = MatmulPlan::new();
        unsafe {
            syrk_sub_lower_raw(base.add(16 * ld + 16), base.add(16 * ld), ld, m, k, 1, &mut plan);
        }
        for i in 0..ld {
            for j in 0..ld {
                let inside = i >= 16 && j >= 16 && j <= i;
                let want = if inside {
                    full[(i, j)] - prod[(i - 16, j - 16)]
                } else {
                    full[(i, j)]
                };
                let d = (buf[(i, j)] - want).abs();
                assert!(d < 1e-4, "({i},{j}) diff {d}");
            }
        }
    }

    #[test]
    fn plan_reuse_does_not_regrow() {
        let mut rng = Rng::new(16);
        let a = Matrix::randn(100, 100, 1.0, &mut rng);
        let b = Matrix::randn(100, 100, 1.0, &mut rng);
        let mut c = Matrix::zeros(100, 100);
        let mut plan = MatmulPlan::new();
        gemm_with(&a, false, &b, false, &mut c, &mut plan, Microkernel::Scalar, 1);
        let warm = plan.grows();
        for _ in 0..5 {
            gemm_with(&a, false, &b, false, &mut c, &mut plan, Microkernel::Scalar, 2);
        }
        // Smaller shapes fit in the warm buffers too.
        let a2 = Matrix::randn(40, 60, 1.0, &mut rng);
        let b2 = Matrix::randn(60, 30, 1.0, &mut rng);
        let mut c2 = Matrix::zeros(40, 30);
        gemm_with(&a2, false, &b2, false, &mut c2, &mut plan, Microkernel::Scalar, 1);
        assert_eq!(plan.grows(), warm, "steady-state packing must not reallocate");
    }

    #[test]
    fn zero_inner_dimension_zero_fills() {
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 3);
        let mut c = Matrix::from_fn(4, 3, |_, _| f32::NAN);
        let mut plan = MatmulPlan::new();
        gemm_with(&a, false, &b, false, &mut c, &mut plan, Microkernel::Scalar, 1);
        assert_eq!(c, Matrix::zeros(4, 3));
    }
}

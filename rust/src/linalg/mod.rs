//! Dense f32 linear algebra substrate.
//!
//! Everything the Shampoo family needs, built from scratch for the offline
//! environment: a row-major [`Matrix`] type, a packed-panel microkernel
//! GEMM tier ([`gemm`]) behind the `matmul`/`syrk` entry points, Cholesky
//! factorization, triangular solves, power iteration for λ_max, the
//! Schur–Newton coupled iteration for inverse p-th roots (Guo & Higham
//! 2006, the method the paper's Eq. (6)/(12) relies on), and a Jacobi
//! symmetric eigensolver used as the exact oracle for tests and for the
//! paper's spectral-error metrics (Tab. 1/10, Fig. 3).

pub mod matrix;
pub mod gemm;
pub mod matmul;
pub mod cholesky;
pub mod triangular;
pub mod power_iter;
pub mod schur_newton;
pub mod eigen;
pub mod norms;
pub mod kron;
pub mod scratch;

pub use cholesky::{
    cholesky, cholesky_into, cholesky_jittered, cholesky_jittered_into,
    cholesky_jittered_into_planned, cholesky_naive, CHOLESKY_BLOCKED_MIN,
};
pub use eigen::{
    eig_sym, eig_sym_with, inverse_pth_root_eig, inverse_pth_root_eig_planned,
    psd_clamped_root_planned, EigWork,
};
pub use gemm::{avx2_available, Microkernel};
pub use kron::kron;
pub use matmul::{
    matmul, matmul_into, matmul_into_planned, matmul_nt, matmul_nt_into, matmul_nt_into_planned,
    matmul_tn, matmul_tn_into, matmul_tn_into_planned, syrk, syrk_into, syrk_into_planned,
    syrk_lower_into, syrk_lower_into_planned, MatmulPlan,
};
pub use matrix::Matrix;
pub use norms::{
    angle_between, diag_dominance_margin, fro_norm, inner, max_abs, off_diag_max_abs,
    relative_error,
};
pub use power_iter::{lambda_max, lambda_max_with};
pub use schur_newton::{inverse_pth_root, inverse_pth_root_scratch};
pub use scratch::{ScratchArena, ScratchStats};
pub use triangular::{solve_lower, solve_lower_transpose};

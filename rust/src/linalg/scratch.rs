//! Reusable scratch arena for the optimizer hot loops.
//!
//! A steady-state Shampoo refresh step performs the same sequence of
//! matrix-shaped temporaries every `T1`/`T2` window: Gram products, codec
//! round-trip buffers, Schur–Newton iterates, preconditioned gradients.
//! [`ScratchArena`] turns those into buffer *reuse* instead of per-step heap
//! allocation: [`take`](ScratchArena::take) hands out a `Matrix` backed by a
//! pooled buffer (allocating only on a pool miss) and
//! [`recycle`](ScratchArena::recycle) returns it for the next taker. After a
//! warm-up step every `take` is a pool hit, so the store/load/root refresh
//! pipeline runs with zero matrix allocations — asserted by the
//! `kernel_equivalence` scratch-reuse suite via [`misses`](ScratchArena::misses).
//!
//! The arena also owns a [`MatmulPlan`], so every planned matmul issued
//! through the same arena reuses one pair of packed-panel buffers (the
//! "caller-owned plan" rule from the perf audit — see `linalg::gemm`).
//! [`stats`](ScratchArena::stats) snapshots all reuse counters at once,
//! including the plan's buffer growths.
//!
//! The arena is deliberately *not* thread-safe: each worker of the parallel
//! per-layer loop borrows its own arena from a pool (`shampoo::Shampoo`
//! keeps a `Mutex<Vec<ScratchArena>>`), so takes/recycles never contend.

use super::gemm::MatmulPlan;
use super::matrix::Matrix;

/// Point-in-time snapshot of an arena's reuse counters (see
/// [`ScratchArena::stats`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScratchStats {
    /// Takes satisfied from the pool.
    pub hits: usize,
    /// Takes that had to allocate.
    pub misses: usize,
    /// Buffers currently parked in the pool.
    pub pooled: usize,
    /// Times the owned [`MatmulPlan`]'s packing buffers grew.
    pub plan_grows: usize,
}

/// Pool of reusable f32 buffers + one shared matmul plan.
///
/// Buffers are shape-agnostic: a `take(r, c)` is satisfied by any pooled
/// buffer whose *capacity* covers `r·c` (best fit wins), so one arena serves
/// mixed layer shapes without growing past the largest temporary.
#[derive(Debug, Default)]
pub struct ScratchArena {
    pool: Vec<Vec<f32>>,
    plan: MatmulPlan,
    hits: usize,
    misses: usize,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// A zeroed `rows × cols` matrix backed by a pooled buffer when one with
    /// enough capacity is available (pool hit), else freshly allocated
    /// (pool miss). Always fully zero-filled, so `take` is a drop-in for
    /// `Matrix::zeros`.
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let need = rows * cols;
        let mut best: Option<usize> = None;
        for (i, buf) in self.pool.iter().enumerate() {
            if buf.capacity() < need {
                continue;
            }
            let better = match best {
                Some(j) => buf.capacity() < self.pool[j].capacity(),
                None => true,
            };
            if better {
                best = Some(i);
            }
        }
        let mut buf = match best {
            Some(i) => {
                self.hits += 1;
                self.pool.swap_remove(i)
            }
            None => {
                self.misses += 1;
                Vec::with_capacity(need)
            }
        };
        buf.clear();
        buf.resize(need, 0.0);
        Matrix::from_vec(rows, cols, buf)
    }

    /// Return a matrix's buffer to the pool for the next [`take`](Self::take).
    pub fn recycle(&mut self, m: Matrix) {
        self.pool.push(m.into_vec());
    }

    /// The arena's matmul plan (packed-panel GEMM scratch shared by every
    /// planned matmul issued through this arena).
    pub fn plan(&mut self) -> &mut MatmulPlan {
        &mut self.plan
    }

    /// Snapshot of every reuse counter: pool hits/misses, parked buffers,
    /// and how often the owned [`MatmulPlan`]'s packing buffers grew. In a
    /// warmed-up steady state `misses` and `plan_grows` are both constant —
    /// the allocation-free-refresh invariant asserted by the
    /// `kernel_equivalence` scratch-reuse suite.
    pub fn stats(&self) -> ScratchStats {
        ScratchStats {
            hits: self.hits,
            misses: self.misses,
            pooled: self.pool.len(),
            plan_grows: self.plan.grows(),
        }
    }

    /// Takes satisfied from the pool.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Takes that had to allocate. Stable across steps ⇔ the steady-state
    /// pipeline is allocation-free (the scratch-reuse invariant).
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_like_matrix_zeros() {
        let mut a = ScratchArena::new();
        let mut m = a.take(3, 4);
        m[(1, 2)] = 7.0;
        a.recycle(m);
        let m2 = a.take(3, 4);
        assert_eq!(m2, Matrix::zeros(3, 4), "recycled buffer must come back zeroed");
    }

    #[test]
    fn steady_state_has_no_misses() {
        let mut a = ScratchArena::new();
        // Warm-up: two concurrent shapes.
        let x = a.take(8, 8);
        let y = a.take(4, 16);
        a.recycle(x);
        a.recycle(y);
        let baseline = a.misses();
        for _ in 0..10 {
            let x = a.take(8, 8);
            let y = a.take(4, 16);
            a.recycle(y);
            a.recycle(x);
        }
        assert_eq!(a.misses(), baseline, "steady state must be allocation-free");
        assert!(a.hits() >= 20);
    }

    #[test]
    fn stats_snapshot_tracks_all_counters() {
        let mut a = ScratchArena::new();
        let m = a.take(6, 6);
        a.recycle(m);
        let _ = a.take(6, 6);
        let s = a.stats();
        assert_eq!(s.hits, a.hits());
        assert_eq!(s.misses, a.misses());
        assert_eq!(s.pooled, a.pooled());
        assert_eq!(s.plan_grows, 0, "no planned matmul issued yet");
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut a = ScratchArena::new();
        let big = a.take(32, 32);
        let small = a.take(4, 4);
        a.recycle(big);
        a.recycle(small);
        // A 4×4 take must grab the 16-capacity buffer, leaving 1024 pooled.
        let m = a.take(4, 4);
        assert!(m.into_vec().capacity() < 32 * 32);
    }

    #[test]
    fn smaller_take_reuses_larger_buffer() {
        let mut a = ScratchArena::new();
        let m = a.take(16, 16);
        a.recycle(m);
        let m2 = a.take(2, 2);
        assert_eq!(a.misses(), 1, "2x2 fits in the pooled 256-cap buffer");
        assert_eq!(m2, Matrix::zeros(2, 2));
    }
}

//! Schur–Newton coupled iteration for the matrix inverse p-th root
//! `A^{-1/p}` (Guo & Higham, SIAM J. Matrix Anal. 2006 — reference [21] of
//! the paper; the same scheme used by production Shampoo implementations).
//!
//! Coupled iteration, for SPD `A` with λ_max scaling:
//! ```text
//!   M₀ = A / λ_max            (spectrum ⊆ (0, 1])
//!   X₀ = λ_max^{-1/p} · I
//!   T_k = ((p+1)·I − M_k) / p
//!   X_{k+1} = X_k · T_k
//!   M_{k+1} = T_k^p · M_k
//! ```
//! `M_k → I` and `X_k → A^{-1/p}`. For Shampoo `p = 4`, so `T^4 = (T²)²`
//! costs two squarings.

use super::matmul::matmul_into_planned;
use super::matrix::Matrix;
use super::power_iter::lambda_max_with;
use super::scratch::ScratchArena;

/// Configuration for the iteration.
#[derive(Clone, Copy, Debug)]
pub struct SchurNewtonConfig {
    /// Root order p (Shampoo uses 4).
    pub p: u32,
    /// Ridge term added as `λ_max·ε·I` before the root (paper Eq. (6)/(12)).
    pub eps: f32,
    /// Convergence tolerance on ‖M − I‖_max.
    pub tol: f32,
    /// Iteration cap (paper notes Schur–Newton runs a limited number of steps).
    pub max_iters: usize,
    /// Power-iteration steps for the λ_max estimate.
    pub power_iters: usize,
}

impl Default for SchurNewtonConfig {
    fn default() -> Self {
        // tol 3e-5 is the practical f32 floor (1e-6 is unreachable and
        // just burns iterations — see EXPERIMENTS.md §Perf).
        SchurNewtonConfig { p: 4, eps: 1e-6, tol: 3e-5, max_iters: 40, power_iters: 16 }
    }
}

/// Result diagnostics.
#[derive(Clone, Copy, Debug)]
pub struct SchurNewtonStats {
    pub iters: usize,
    pub residual: f32,
    pub lambda_max: f32,
}

/// Compute `(A + λ_max·ε·I)^{-1/p}` for symmetric PSD `A`.
///
/// Matches Algorithm 2 step 10–11: λ_max via power iteration, εI ridge,
/// then the coupled Newton iteration. Returns the root and diagnostics.
///
/// Convenience wrapper over [`inverse_pth_root_scratch`] with a throwaway
/// arena; loops that refresh roots every `T2` steps must call the scratch
/// variant with a persistent arena instead (zero steady-state allocation,
/// one shared matmul plan).
pub fn inverse_pth_root(a: &Matrix, cfg: &SchurNewtonConfig) -> (Matrix, SchurNewtonStats) {
    let mut arena = ScratchArena::new();
    inverse_pth_root_scratch(a, cfg, &mut arena)
}

/// [`inverse_pth_root`] with every temporary (power-iteration vectors,
/// `M`/`T` iterates, the `T^p` accumulator, the packed-B matmul buffer)
/// drawn from a caller-owned [`ScratchArena`]. The returned root is backed
/// by an arena buffer — recycle it when done to keep the steady state
/// allocation-free. Bit-identical to the wrapper for the same inputs.
pub fn inverse_pth_root_scratch(
    a: &Matrix,
    cfg: &SchurNewtonConfig,
    arena: &mut ScratchArena,
) -> (Matrix, SchurNewtonStats) {
    assert!(a.is_square());
    let n = a.rows();
    let p = cfg.p.max(1);

    let lam = {
        let mut v = arena.take(1, n);
        let mut w = arena.take(1, n);
        let lam = lambda_max_with(a, cfg.power_iters, v.data_mut(), w.data_mut());
        arena.recycle(v);
        arena.recycle(w);
        lam.max(f32::MIN_POSITIVE)
    };
    let ridge = lam * cfg.eps;
    let mut m = arena.take(n, n);
    m.copy_from(a);
    m.add_diag(ridge);

    // Scale: M0 = (A + ridge) / s with s = λ_max(A + ridge) ≈ lam + ridge.
    let s = lam + ridge;
    m.scale(1.0 / s);
    let x0_scale = (s as f64).powf(-1.0 / p as f64) as f32;
    let mut x = arena.take(n, n);
    x.set_eye_scaled(x0_scale);

    let mut t = arena.take(n, n);
    let mut tmp = arena.take(n, n);
    let mut iters = 0;
    let mut residual = residual_to_identity(&m);

    while iters < cfg.max_iters && residual > cfg.tol {
        // T = ((p+1) I − M) / p
        for i in 0..n {
            for j in 0..n {
                let v = -m[(i, j)] / p as f32;
                t[(i, j)] = if i == j { v + (p as f32 + 1.0) / p as f32 } else { v };
            }
        }
        // X ← X·T
        matmul_into_planned(&x, &t, &mut tmp, arena.plan());
        std::mem::swap(&mut x, &mut tmp);
        // M ← T^p · M  (p = 2^k fast path via repeated squaring)
        let tp = matrix_power(&t, p, arena);
        matmul_into_planned(&tp, &m, &mut tmp, arena.plan());
        arena.recycle(tp);
        std::mem::swap(&mut m, &mut tmp);
        // Guard drift: M must stay symmetric-ish; re-symmetrize cheaply.
        m.symmetrize();

        residual = residual_to_identity(&m);
        iters += 1;
        if !residual.is_finite() {
            break;
        }
    }

    arena.recycle(m);
    arena.recycle(t);
    arena.recycle(tmp);
    // Final symmetrization of the root (X inherits asymmetry from rounding).
    x.symmetrize();
    (x, SchurNewtonStats { iters, residual, lambda_max: lam })
}

fn residual_to_identity(m: &Matrix) -> f32 {
    let n = m.rows();
    let mut r = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            r = r.max((m[(i, j)] - target).abs());
        }
    }
    r
}

/// `T^p` via binary exponentiation, all temporaries arena-backed. The
/// returned matrix is an arena buffer — the caller recycles it.
fn matrix_power(t: &Matrix, p: u32, arena: &mut ScratchArena) -> Matrix {
    debug_assert!(p >= 1);
    let n = t.rows();
    let mut result: Option<Matrix> = None;
    let mut base = arena.take(n, n);
    base.copy_from(t);
    let mut tmp = arena.take(n, n);
    let mut e = p;
    while e > 0 {
        if e & 1 == 1 {
            result = Some(match result {
                None => {
                    let mut r = arena.take(n, n);
                    r.copy_from(&base);
                    r
                }
                Some(r) => {
                    matmul_into_planned(&r, &base, &mut tmp, arena.plan());
                    // The product becomes the accumulator; the old one is
                    // the next multiply's scratch.
                    std::mem::replace(&mut tmp, r)
                }
            });
        }
        e >>= 1;
        if e > 0 {
            matmul_into_planned(&base, &base, &mut tmp, arena.plan());
            std::mem::swap(&mut base, &mut tmp);
        }
    }
    arena.recycle(base);
    arena.recycle(tmp);
    result.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::inverse_pth_root_eig;
    use crate::linalg::matmul::syrk;
    use crate::linalg::norms::relative_error;
    use crate::util::rng::Rng;

    #[test]
    fn matches_eigensolver_p4() {
        let mut rng = Rng::new(1);
        for n in [2, 5, 12, 32] {
            let g = Matrix::randn(n, n + 6, 1.0, &mut rng);
            let mut a = syrk(&g);
            a.add_diag(0.2);
            let cfg = SchurNewtonConfig::default();
            let (x, stats) = inverse_pth_root(&a, &cfg);
            // Oracle on the same ridged matrix.
            let mut ridged = a.clone();
            ridged.add_diag(stats.lambda_max * cfg.eps);
            let want = inverse_pth_root_eig(&ridged, 4.0, 1e-12);
            let err = relative_error(&want, &x);
            assert!(err < 5e-3, "n={n} err={err} iters={}", stats.iters);
        }
    }

    #[test]
    fn p2_inverse_sqrt() {
        let a = Matrix::from_rows(&[&[4.0, 0.0], &[0.0, 16.0]]);
        let cfg = SchurNewtonConfig { p: 2, eps: 0.0, ..Default::default() };
        let (x, _) = inverse_pth_root(&a, &cfg);
        assert!((x[(0, 0)] - 0.5).abs() < 1e-4);
        assert!((x[(1, 1)] - 0.25).abs() < 1e-4);
        assert!(x[(0, 1)].abs() < 1e-5);
    }

    #[test]
    fn handles_ill_conditioned() {
        // Geometric spectrum 1e-3..1e3 (the paper's synthetic setting).
        let n = 16;
        let mut rng = Rng::new(7);
        let g = Matrix::randn(n, n, 1.0, &mut rng);
        // Orthogonalize-ish via QR-free trick: use eigenvectors of g·gᵀ.
        let (_, v) = crate::linalg::eigen::eig_sym(&syrk(&g), 1e-10, 100);
        let mut a = Matrix::zeros(n, n);
        for k in 0..n {
            let lam = 1e-3 * (1e6f64.powf(k as f64 / (n - 1) as f64)) as f32;
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] += lam * v[(i, k)] * v[(j, k)];
                }
            }
        }
        let cfg = SchurNewtonConfig::default();
        let (x, stats) = inverse_pth_root(&a, &cfg);
        assert!(!x.has_non_finite());
        assert!(stats.residual < 1e-2, "residual={}", stats.residual);
    }

    #[test]
    fn identity_root_is_identity() {
        let a = Matrix::eye(8);
        let cfg = SchurNewtonConfig { eps: 0.0, ..Default::default() };
        let (x, _) = inverse_pth_root(&a, &cfg);
        assert!(x.max_abs_diff(&Matrix::eye(8)) < 1e-4);
    }

    #[test]
    fn matrix_power_binary_exp() {
        let t = Matrix::from_rows(&[&[1.0, 1.0], &[0.0, 1.0]]);
        let mut arena = ScratchArena::new();
        let t4 = matrix_power(&t, 4, &mut arena);
        assert_eq!(t4[(0, 1)], 4.0);
        let t1 = matrix_power(&t, 1, &mut arena);
        assert_eq!(t1, t);
    }

    #[test]
    fn scratch_variant_is_bit_identical_and_allocation_free() {
        let mut rng = Rng::new(21);
        let g = Matrix::randn(24, 30, 1.0, &mut rng);
        let mut a = syrk(&g);
        a.add_diag(0.3);
        let cfg = SchurNewtonConfig::default();
        let (want, wstats) = inverse_pth_root(&a, &cfg);

        let mut arena = ScratchArena::new();
        // Warm-up pass populates the pool.
        let (x0, _) = inverse_pth_root_scratch(&a, &cfg, &mut arena);
        arena.recycle(x0);
        let baseline = arena.misses();
        for _ in 0..3 {
            let (x, stats) = inverse_pth_root_scratch(&a, &cfg, &mut arena);
            assert_eq!(x.max_abs_diff(&want), 0.0, "scratch path must be bit-identical");
            assert_eq!(stats.iters, wstats.iters);
            arena.recycle(x);
        }
        assert_eq!(arena.misses(), baseline, "steady-state root refresh must not allocate");
    }
}

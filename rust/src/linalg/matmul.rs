//! Cache-blocked, threaded matrix multiplication.
//!
//! The hot path of both Shampoo's preconditioner math (Gram updates,
//! Schur–Newton iterations, `L̂·G·R̂`) and the profiled L3 benchmarks.
//! Strategy: pack the B operand so the innermost loop is a contiguous
//! dot-product (auto-vectorizes), block over rows, and parallelize row
//! blocks with the in-tree pool.

use super::matrix::Matrix;
use crate::util::pool::parallel_for;
/// Row-block size for the parallel outer loop.
const ROW_BLOCK: usize = 32;
/// Threshold (total FLOPs) below which we stay single-threaded.
const PAR_FLOP_THRESHOLD: usize = 1 << 20;

/// Reusable scratch for repeated products of the same shape (avoids
/// reallocating the packed-B buffer inside optimizer loops).
///
/// Plan-audit rule (hot-path discipline): `matmul`/`matmul_into` create a
/// fresh plan per call, which is fine for one-off products but silently
/// re-allocates inside loops. Anything called per refresh step — Shampoo's
/// preconditioning, the Schur–Newton iteration, the eigensolver fallback —
/// must route through [`matmul_into_planned`] with a caller-owned plan
/// (typically the one inside `linalg::ScratchArena`).
#[derive(Debug, Default)]
pub struct MatmulPlan {
    packed_b: Vec<f32>,
}

impl MatmulPlan {
    pub fn new() -> Self {
        MatmulPlan { packed_b: Vec::new() }
    }
}

/// Raw pointer that may cross the scoped-thread boundary. Every user must
/// write through disjoint index ranges per task (row blocks here; byte
/// ranges in the quant kernels).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// Accessing through a method keeps closure captures on the whole
    /// wrapper (edition-2021 disjoint capture would otherwise grab the raw
    /// field and lose the `Sync` impl).
    #[inline]
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` into an existing output (no allocation beyond pack scratch).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let mut plan = MatmulPlan::new();
    matmul_into_planned(a, b, c, &mut plan);
}

/// `C = A · B` with a caller-owned scratch plan.
pub fn matmul_into_planned(a: &Matrix, b: &Matrix, c: &mut Matrix, plan: &mut MatmulPlan) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k, "inner dimension mismatch: {}x{} · {}x{}", m, k, b.rows(), n);
    assert_eq!((c.rows(), c.cols()), (m, n), "output shape mismatch");

    // Pack B column-major (so each output column is a contiguous dot).
    plan.packed_b.resize(k * n, 0.0);
    for kk in 0..k {
        let brow = b.row(kk);
        for (j, &v) in brow.iter().enumerate() {
            plan.packed_b[j * k + kk] = v;
        }
    }
    let packed = &plan.packed_b;

    let flops = 2 * m * n * k;
    let blocks = m.div_ceil(ROW_BLOCK);
    let threads = if flops < PAR_FLOP_THRESHOLD {
        1
    } else {
        crate::util::pool::default_threads()
    };

    let c_ptr = SendPtr(c.data_mut().as_mut_ptr());
    let a_ref = a;
    parallel_for(blocks, threads, |blk| {
        let r0 = blk * ROW_BLOCK;
        let r1 = (r0 + ROW_BLOCK).min(m);
        // Safety: each block writes a disjoint row range of C.
        let base = c_ptr.get();
        for i in r0..r1 {
            let arow = a_ref.row(i);
            let crow = unsafe { std::slice::from_raw_parts_mut(base.add(i * n), n) };
            for (j, cv) in crow.iter_mut().enumerate() {
                let bcol = &packed[j * k..(j + 1) * k];
                *cv = dot(arow, bcol);
            }
        }
    });
}

/// Contiguous dot product; unrolled by 8 for reliable auto-vectorization.
/// (A 4×8 multi-accumulator variant was tried in the perf pass and measured
/// *slower* on the shared single-vCPU testbed — see EXPERIMENTS.md §Perf.)
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `C = Aᵀ · B` (A is k×m): used for `GᵀG` shapes without materializing Aᵀ.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` into an existing output (`C` is fully overwritten).
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (k, m) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!((c.rows(), c.cols()), (m, n), "output shape mismatch");
    c.data_mut().fill(0.0);
    // C[i][j] = sum_kk A[kk][i] * B[kk][j]  — accumulate row-by-row (streams
    // both operands contiguously).
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// `C = A · Bᵀ` (B is n×k): the `G·Gᵀ` shape with contiguous dots.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.rows());
    matmul_nt_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` into an existing output (`C` is fully overwritten).
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    assert_eq!(b.cols(), k);
    assert_eq!((c.rows(), c.cols()), (m, n), "output shape mismatch");
    let threads = if 2 * m * n * k < PAR_FLOP_THRESHOLD {
        1
    } else {
        crate::util::pool::default_threads()
    };
    let c_ptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for(m, threads, |i| {
        let arow = a.row(i);
        let base = c_ptr.get();
        let crow = unsafe { std::slice::from_raw_parts_mut(base.add(i * n), n) };
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = dot(arow, b.row(j));
        }
    });
}

/// Symmetric rank-k update `C = A · Aᵀ` exploiting symmetry (half the dots).
pub fn syrk(a: &Matrix) -> Matrix {
    let m = a.rows();
    let mut c = Matrix::zeros(m, m);
    syrk_into(a, &mut c);
    c
}

/// `C = A · Aᵀ` into an existing output (both triangles fully overwritten).
pub fn syrk_into(a: &Matrix, c: &mut Matrix) {
    let m = a.rows();
    assert_eq!((c.rows(), c.cols()), (m, m), "output shape mismatch");
    let threads = if m * m * a.cols() < PAR_FLOP_THRESHOLD {
        1
    } else {
        crate::util::pool::default_threads()
    };
    let c_ptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for(m, threads, |i| {
        let arow = a.row(i);
        let base = c_ptr.get();
        for j in 0..=i {
            let v = dot(arow, a.row(j));
            unsafe {
                *base.add(i * m + j) = v;
                *base.add(j * m + i) = v;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 63, 66)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-3 * k as f32, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_large_parallel_path() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(130, 120, 1.0, &mut rng);
        let b = Matrix::randn(120, 140, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let want = naive(&a, &b);
        assert!(c.max_abs_diff(&want) < 1e-2);
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let b = Matrix::randn(20, 15, 1.0, &mut rng);
        let want_tn = naive(&a.transpose(), &b);
        assert!(matmul_tn(&a, &b).max_abs_diff(&want_tn) < 1e-3);

        let c = Matrix::randn(9, 12, 1.0, &mut rng);
        let want_nt = naive(&a, &c.transpose());
        assert!(matmul_nt(&a, &c).max_abs_diff(&want_nt) < 1e-3);
    }

    #[test]
    fn syrk_matches_naive() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(25, 40, 1.0, &mut rng);
        let want = naive(&a, &a.transpose());
        assert!(syrk(&a).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        assert!(matmul(&a, &Matrix::eye(12)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Matrix::eye(12), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn plan_reuse_gives_same_answer() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(30, 20, 1.0, &mut rng);
        let b = Matrix::randn(20, 10, 1.0, &mut rng);
        let mut plan = MatmulPlan::new();
        let mut c1 = Matrix::zeros(30, 10);
        matmul_into_planned(&a, &b, &mut c1, &mut plan);
        let mut c2 = Matrix::zeros(30, 10);
        matmul_into_planned(&a, &b, &mut c2, &mut plan);
        assert_eq!(c1, c2);
    }
}

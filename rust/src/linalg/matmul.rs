//! Matrix-product entry points, routed through the packed-panel GEMM tier.
//!
//! The hot path of both Shampoo's preconditioner math (Gram updates,
//! Schur–Newton iterations, `L̂·G·R̂`) and the profiled L3 benchmarks. The
//! heavy lifting lives in [`linalg::gemm`](super::gemm): these wrappers
//! keep the historical signatures (`matmul`, `matmul_tn_into`,
//! `matmul_nt_into`, `syrk_into`) so every call site — blocked Cholesky,
//! gram refresh, `eig_sym_with`, Schur–Newton — inherits the microkernel
//! win without churn. Small products (below `gemm::GEMM_SMALL_DIM` /
//! `gemm::GEMM_SMALL_FLOP`) skip packing and take a plain loop.
//!
//! Every `*_into` variant fully overwrites its output except
//! [`syrk_lower_into`], which by contract writes only the lower triangle.

use super::gemm;
use super::matrix::Matrix;

pub use super::gemm::MatmulPlan;

/// Raw pointer that may cross the scoped-thread boundary. Every user must
/// write through disjoint index ranges per task (jc column slabs in the
/// GEMM driver; byte ranges in the quant kernels).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// Accessing through a method keeps closure captures on the whole
    /// wrapper (edition-2021 disjoint capture would otherwise grab the raw
    /// field and lose the `Sync` impl).
    #[inline]
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

/// `C = A · B`.
///
/// ```
/// use quartz::linalg::{matmul, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
/// assert_eq!(matmul(&a, &Matrix::eye(2)), a);
/// ```
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` into an existing output (no allocation beyond pack scratch).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let mut plan = MatmulPlan::new();
    matmul_into_planned(a, b, c, &mut plan);
}

/// `C = A · B` with a caller-owned scratch plan (the hot-path variant; see
/// the plan-audit rule on [`MatmulPlan`]).
pub fn matmul_into_planned(a: &Matrix, b: &Matrix, c: &mut Matrix, plan: &mut MatmulPlan) {
    gemm::gemm_into(a, false, b, false, c, plan);
}

/// Contiguous dot product; unrolled by 8 for reliable auto-vectorization.
/// (A 4×8 multi-accumulator variant was tried in the perf pass and measured
/// *slower* on the shared single-vCPU testbed — see EXPERIMENTS.md §Perf.)
/// Still the kernel of power iteration, `kron`, and the Cholesky panel
/// passes; full products go through the packed GEMM tier instead.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `C = Aᵀ · B` (A is k×m): used for `GᵀG` shapes without materializing Aᵀ.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` into an existing output (`C` is fully overwritten).
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let mut plan = MatmulPlan::new();
    matmul_tn_into_planned(a, b, c, &mut plan);
}

/// `C = Aᵀ · B` with a caller-owned scratch plan.
pub fn matmul_tn_into_planned(a: &Matrix, b: &Matrix, c: &mut Matrix, plan: &mut MatmulPlan) {
    gemm::gemm_into(a, true, b, false, c, plan);
}

/// `C = A · Bᵀ` (B is n×k): the `G·Gᵀ` shape without materializing Bᵀ.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.rows());
    matmul_nt_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` into an existing output (`C` is fully overwritten).
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let mut plan = MatmulPlan::new();
    matmul_nt_into_planned(a, b, c, &mut plan);
}

/// `C = A · Bᵀ` with a caller-owned scratch plan.
pub fn matmul_nt_into_planned(a: &Matrix, b: &Matrix, c: &mut Matrix, plan: &mut MatmulPlan) {
    gemm::gemm_into(a, false, b, true, c, plan);
}

/// Symmetric rank-k update `C = A · Aᵀ` exploiting symmetry (the GEMM tier
/// computes only the lower triangle; the upper is mirrored).
pub fn syrk(a: &Matrix) -> Matrix {
    let m = a.rows();
    let mut c = Matrix::zeros(m, m);
    syrk_into(a, &mut c);
    c
}

/// `C = A · Aᵀ` into an existing output (both triangles fully overwritten).
pub fn syrk_into(a: &Matrix, c: &mut Matrix) {
    let mut plan = MatmulPlan::new();
    syrk_into_planned(a, c, &mut plan);
}

/// `C = A · Aᵀ` with a caller-owned scratch plan (both triangles fully
/// overwritten).
pub fn syrk_into_planned(a: &Matrix, c: &mut Matrix, plan: &mut MatmulPlan) {
    gemm::syrk_lower(a, c, plan);
    mirror_lower_to_upper(c);
}

/// `C[lower] = A · Aᵀ`, writing **only** the lower triangle — the GEMM
/// tier's native SYRK shape. The strict upper triangle of `C` is left
/// untouched; use [`syrk_into`] when the full symmetric matrix is needed.
///
/// ```
/// use quartz::linalg::{syrk_lower_into, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
/// let mut c = Matrix::from_fn(2, 2, |_, _| 9.0);
/// syrk_lower_into(&a, &mut c);
/// assert_eq!(c[(0, 0)], 5.0); // 1·1 + 2·2
/// assert_eq!(c[(1, 0)], 11.0); // 3·1 + 4·2
/// assert_eq!(c[(1, 1)], 25.0); // 3·3 + 4·4
/// assert_eq!(c[(0, 1)], 9.0); // upper triangle untouched
/// ```
pub fn syrk_lower_into(a: &Matrix, c: &mut Matrix) {
    let mut plan = MatmulPlan::new();
    syrk_lower_into_planned(a, c, &mut plan);
}

/// [`syrk_lower_into`] with a caller-owned scratch plan.
pub fn syrk_lower_into_planned(a: &Matrix, c: &mut Matrix, plan: &mut MatmulPlan) {
    gemm::syrk_lower(a, c, plan);
}

fn mirror_lower_to_upper(c: &mut Matrix) {
    let n = c.rows();
    let d = c.data_mut();
    for i in 0..n {
        for j in 0..i {
            d[j * n + i] = d[i * n + j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 63, 66)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-3 * k as f32, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_large_parallel_path() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(130, 120, 1.0, &mut rng);
        let b = Matrix::randn(120, 140, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let want = naive(&a, &b);
        assert!(c.max_abs_diff(&want) < 1e-2);
    }

    #[test]
    fn packed_tier_shape_crosses_kc_boundary() {
        // k > KC forces multiple packed slabs (the Acc::Set → Acc::Add
        // hand-off); m, n land on partial edge tiles.
        let mut rng = Rng::new(21);
        let a = Matrix::randn(70, 500, 1.0, &mut rng);
        let b = Matrix::randn(500, 55, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let want = naive(&a, &b);
        let rel = crate::linalg::norms::relative_error(&want, &c);
        assert!(rel < 1e-5, "rel={rel}");
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let b = Matrix::randn(20, 15, 1.0, &mut rng);
        let want_tn = naive(&a.transpose(), &b);
        assert!(matmul_tn(&a, &b).max_abs_diff(&want_tn) < 1e-3);

        let c = Matrix::randn(9, 12, 1.0, &mut rng);
        let want_nt = naive(&a, &c.transpose());
        assert!(matmul_nt(&a, &c).max_abs_diff(&want_nt) < 1e-3);
    }

    #[test]
    fn tn_and_nt_large_shapes_route_through_packed_tier() {
        let mut rng = Rng::new(22);
        let a = Matrix::randn(300, 90, 1.0, &mut rng);
        let b = Matrix::randn(300, 110, 1.0, &mut rng);
        let want_tn = naive(&a.transpose(), &b);
        let got_tn = matmul_tn(&a, &b);
        assert!(crate::linalg::norms::relative_error(&want_tn, &got_tn) < 1e-5);

        let c = Matrix::randn(85, 90, 1.0, &mut rng);
        let want_nt = naive(&a, &c.transpose());
        let got_nt = matmul_nt(&a, &c);
        assert!(crate::linalg::norms::relative_error(&want_nt, &got_nt) < 1e-5);
    }

    #[test]
    fn syrk_matches_naive() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(25, 40, 1.0, &mut rng);
        let want = naive(&a, &a.transpose());
        assert!(syrk(&a).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn syrk_is_exactly_symmetric() {
        // The mirror pass copies lower → upper, so symmetry is bit-exact
        // (codecs that quantize one triangle rely on this).
        let mut rng = Rng::new(23);
        let a = Matrix::randn(120, 64, 1.0, &mut rng);
        let c = syrk(&a);
        for i in 0..120 {
            for j in 0..i {
                assert_eq!(c[(i, j)], c[(j, i)], "({i},{j})");
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        assert!(matmul(&a, &Matrix::eye(12)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Matrix::eye(12), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn plan_reuse_gives_same_answer() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(30, 20, 1.0, &mut rng);
        let b = Matrix::randn(20, 10, 1.0, &mut rng);
        let mut plan = MatmulPlan::new();
        let mut c1 = Matrix::zeros(30, 10);
        matmul_into_planned(&a, &b, &mut c1, &mut plan);
        let mut c2 = Matrix::zeros(30, 10);
        matmul_into_planned(&a, &b, &mut c2, &mut plan);
        assert_eq!(c1, c2);
    }

    #[test]
    fn one_plan_serves_mixed_shapes_and_ops() {
        // The same arena plan is shared by NN/TN/NT/SYRK calls of different
        // shapes inside one refresh step; answers must match fresh plans.
        let mut rng = Rng::new(24);
        let mut plan = MatmulPlan::new();
        let a = Matrix::randn(64, 128, 1.0, &mut rng);
        let b = Matrix::randn(128, 40, 1.0, &mut rng);
        let mut c = Matrix::zeros(64, 40);
        matmul_into_planned(&a, &b, &mut c, &mut plan);
        assert_eq!(c, matmul(&a, &b));

        let mut g = Matrix::zeros(128, 128);
        matmul_tn_into_planned(&a, &a, &mut g, &mut plan);
        assert_eq!(g, matmul_tn(&a, &a));

        let mut s = Matrix::zeros(64, 64);
        syrk_into_planned(&a, &mut s, &mut plan);
        assert_eq!(s, syrk(&a));
    }
}

//! Power iteration for the dominant eigenvalue of a symmetric PSD matrix.
//!
//! Algorithm 2 step 10: Shampoo regularizes with `λ_max·ε·I` before the
//! inverse-root, and Schur–Newton needs `λ_max` for its initial scaling.

use super::matrix::Matrix;
use crate::util::rng::Rng;

/// Estimate λ_max of symmetric PSD `a` via power iteration with a fixed,
/// seeded start vector. Returns 0 for the zero matrix.
pub fn lambda_max(a: &Matrix, iters: usize) -> f32 {
    let n = a.rows();
    let mut v = vec![0.0f32; n];
    let mut w = vec![0.0f32; n];
    lambda_max_with(a, iters, &mut v, &mut w)
}

/// [`lambda_max`] with caller-owned iterate buffers (`v`/`w`, each of
/// length `n`) — the allocation-free variant the Schur–Newton scratch path
/// uses. Contents of the buffers are fully overwritten.
pub fn lambda_max_with(a: &Matrix, iters: usize, v: &mut [f32], w: &mut [f32]) -> f32 {
    assert!(a.is_square());
    let n = a.rows();
    assert_eq!(v.len(), n);
    assert_eq!(w.len(), n);
    if n == 0 {
        return 0.0;
    }
    let mut rng = Rng::new(0x9E1B);
    for vi in v.iter_mut() {
        *vi = rng.normal_f32(1.0);
    }
    normalize(v);
    let mut lam = 0.0f32;
    for _ in 0..iters.max(1) {
        // w = A v
        for i in 0..n {
            w[i] = crate::linalg::matmul::dot(a.row(i), v);
        }
        let norm = w.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32;
        if norm <= f32::MIN_POSITIVE {
            return 0.0;
        }
        lam = norm;
        for (vi, wi) in v.iter_mut().zip(w.iter()) {
            *vi = wi / norm;
        }
    }
    // Rayleigh quotient refinement.
    for i in 0..n {
        w[i] = crate::linalg::matmul::dot(a.row(i), v);
    }
    let rq: f64 = v.iter().zip(w.iter()).map(|(&x, &y)| x as f64 * y as f64).sum();
    if rq.is_finite() && rq as f32 > 0.0 {
        rq as f32
    } else {
        lam
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32;
    if n > f32::MIN_POSITIVE {
        for x in v.iter_mut() {
            *x /= n;
        }
    } else if !v.is_empty() {
        v[0] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::syrk;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_case() {
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 7.0]]);
        let lam = lambda_max(&a, 100);
        assert!((lam - 7.0).abs() < 1e-3, "lam={lam}");
    }

    #[test]
    fn matches_eigensolver_on_random_spd() {
        let mut rng = Rng::new(1);
        let g = Matrix::randn(12, 20, 1.0, &mut rng);
        let a = syrk(&g);
        let lam = lambda_max(&a, 200);
        let (vals, _) = crate::linalg::eigen::eig_sym(&a, 1e-10, 200);
        let lam_exact = vals.iter().cloned().fold(f32::MIN, f32::max);
        assert!((lam - lam_exact).abs() / lam_exact < 1e-3);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(4, 4);
        assert_eq!(lambda_max(&a, 50), 0.0);
    }

    #[test]
    fn with_buffers_matches_allocating_path() {
        let mut rng = Rng::new(2);
        let g = Matrix::randn(10, 14, 1.0, &mut rng);
        let a = syrk(&g);
        let mut v = vec![7.0f32; 10]; // stale contents must not matter
        let mut w = vec![-3.0f32; 10];
        let with = lambda_max_with(&a, 64, &mut v, &mut w);
        assert_eq!(with, lambda_max(&a, 64), "same seed ⇒ bit-identical estimate");
    }
}

//! Power iteration for the dominant eigenvalue of a symmetric PSD matrix.
//!
//! Algorithm 2 step 10: Shampoo regularizes with `λ_max·ε·I` before the
//! inverse-root, and Schur–Newton needs `λ_max` for its initial scaling.

use super::matrix::Matrix;
use crate::util::rng::Rng;

/// Estimate λ_max of symmetric PSD `a` via power iteration with a fixed,
/// seeded start vector. Returns 0 for the zero matrix.
pub fn lambda_max(a: &Matrix, iters: usize) -> f32 {
    assert!(a.is_square());
    let n = a.rows();
    if n == 0 {
        return 0.0;
    }
    let mut rng = Rng::new(0x9E1B);
    let mut v: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    normalize(&mut v);
    let mut lam = 0.0f32;
    let mut w = vec![0.0f32; n];
    for _ in 0..iters.max(1) {
        // w = A v
        for i in 0..n {
            w[i] = crate::linalg::matmul::dot(a.row(i), &v);
        }
        let norm = w.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32;
        if norm <= f32::MIN_POSITIVE {
            return 0.0;
        }
        lam = norm;
        for (vi, wi) in v.iter_mut().zip(w.iter()) {
            *vi = wi / norm;
        }
    }
    // Rayleigh quotient refinement.
    for i in 0..n {
        w[i] = crate::linalg::matmul::dot(a.row(i), &v);
    }
    let rq: f64 = v.iter().zip(w.iter()).map(|(&x, &y)| x as f64 * y as f64).sum();
    if rq.is_finite() && rq as f32 > 0.0 {
        rq as f32
    } else {
        lam
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32;
    if n > f32::MIN_POSITIVE {
        for x in v.iter_mut() {
            *x /= n;
        }
    } else if !v.is_empty() {
        v[0] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::syrk;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_case() {
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 7.0]]);
        let lam = lambda_max(&a, 100);
        assert!((lam - 7.0).abs() < 1e-3, "lam={lam}");
    }

    #[test]
    fn matches_eigensolver_on_random_spd() {
        let mut rng = Rng::new(1);
        let g = Matrix::randn(12, 20, 1.0, &mut rng);
        let a = syrk(&g);
        let lam = lambda_max(&a, 200);
        let (vals, _) = crate::linalg::eigen::eig_sym(&a, 1e-10, 200);
        let lam_exact = vals.iter().cloned().fold(f32::MIN, f32::max);
        assert!((lam - lam_exact).abs() / lam_exact < 1e-3);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(4, 4);
        assert_eq!(lambda_max(&a, 50), 0.0);
    }
}

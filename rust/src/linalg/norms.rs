//! Norms, inner products, and the paper's spectral error metrics (Eq. (9)).

use super::matrix::Matrix;

/// Frobenius norm ‖A‖_F (f64 accumulation).
pub fn fro_norm(a: &Matrix) -> f64 {
    a.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Frobenius inner product ⟨A, B⟩.
pub fn inner(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    a.data()
        .iter()
        .zip(b.data().iter())
        .map(|(&x, &y)| x as f64 * y as f64)
        .sum()
}

/// Largest |entry|.
pub fn max_abs(a: &Matrix) -> f32 {
    a.data().iter().map(|x| x.abs()).fold(0.0, f32::max)
}

/// Largest |off-diagonal entry| (‖·‖_off,max in Proposition 5.1).
pub fn off_diag_max_abs(a: &Matrix) -> f32 {
    let mut m = 0.0f32;
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            if i != j {
                m = m.max(a[(i, j)].abs());
            }
        }
    }
    m
}

/// Frobenius-norm relative error ‖A − B‖_F / ‖A‖_F (NRE numerator of Eq. 9
/// is applied to inverse-4th-roots by the caller).
pub fn relative_error(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    let mut num = 0.0f64;
    for (x, y) in a.data().iter().zip(b.data().iter()) {
        let d = *x as f64 - *y as f64;
        num += d * d;
    }
    num.sqrt() / fro_norm(a).max(f64::MIN_POSITIVE)
}

/// Angle (degrees) between A and B under the Frobenius inner product —
/// the paper's AE metric (Eq. 9).
pub fn angle_between(a: &Matrix, b: &Matrix) -> f64 {
    let cos = inner(a, b) / (fro_norm(a) * fro_norm(b)).max(f64::MIN_POSITIVE);
    cos.clamp(-1.0, 1.0).acos().to_degrees()
}

/// Row-wise diagonal-dominance margin used by Proposition 5.1's PD
/// condition: returns `min_i (|a_ii| − t · Σ_{j≠i} |a_ij|)`. Positive with
/// `t = 1 + 2/(2^b − 1)` certifies `D(Q(A)) ≻ 0` after off-diagonal b-bit
/// quantization.
pub fn diag_dominance_margin(a: &Matrix, t: f64) -> f64 {
    assert!(a.is_square());
    let mut margin = f64::INFINITY;
    for i in 0..a.rows() {
        let mut off = 0.0f64;
        for j in 0..a.cols() {
            if i != j {
                off += a[(i, j)].abs() as f64;
            }
        }
        margin = margin.min(a[(i, i)].abs() as f64 - t * off);
    }
    margin
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fro_and_inner() {
        let a = Matrix::from_rows(&[&[3.0, 4.0]]);
        assert!((fro_norm(&a) - 5.0).abs() < 1e-12);
        let b = Matrix::from_rows(&[&[1.0, 2.0]]);
        assert!((inner(&a, &b) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn angle_identity_is_zero() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert!(angle_between(&a, &a) < 1e-6);
    }

    #[test]
    fn angle_orthogonal_is_ninety() {
        let a = Matrix::from_rows(&[&[1.0, 0.0]]);
        let b = Matrix::from_rows(&[&[0.0, 1.0]]);
        assert!((angle_between(&a, &b) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn off_diag_max_ignores_diagonal() {
        let a = Matrix::from_rows(&[&[100.0, 2.0], &[-3.0, 100.0]]);
        assert_eq!(off_diag_max_abs(&a), 3.0);
    }

    #[test]
    fn dominance_margin() {
        let a = Matrix::from_rows(&[&[10.0, 1.0], &[1.0, 10.0]]);
        assert!(diag_dominance_margin(&a, 1.0) > 0.0);
        let b = Matrix::from_rows(&[&[1.0, 10.0], &[10.0, 1.0]]);
        assert!(diag_dominance_margin(&b, 1.0) < 0.0);
    }

    #[test]
    fn relative_error_zero_for_equal() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        assert_eq!(relative_error(&a, &a), 0.0);
    }
}

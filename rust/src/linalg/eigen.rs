//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! Serves as the *exact oracle*: Schur–Newton results are validated against
//! it, the paper's NRE/AE metrics (Tab. 1/10) need exact `A^{-1/4}`, and
//! Fig. 3's eigenvalue histograms and the Tab. 9 toy example use it
//! directly. Accuracy over speed by design.

use super::matmul::{matmul_into_planned, MatmulPlan};
use super::matrix::Matrix;

/// Reusable f64 workspace for [`eig_sym_with`].
///
/// The Jacobi iteration keeps two `n×n` f64 grids (the rotating copy of `A`
/// and the accumulated eigenvector product) plus the sort permutation.
/// Callers that decompose in a loop — the `ec4` codec re-factors a
/// preconditioner at every refresh — reuse one `EigWork` (per worker
/// thread, NOT per state slot: at `16n²` bytes it would dwarf a quantized
/// slot's persistent state) so the steady state does not reallocate per
/// call.
#[derive(Clone, Debug, Default)]
pub struct EigWork {
    m: Vec<f64>,
    v: Vec<f64>,
    pairs: Vec<(f64, usize)>,
}

/// Eigen-decomposition of symmetric `a`: returns `(eigenvalues, V)` where
/// columns of `V` are the corresponding orthonormal eigenvectors
/// (`A = V·diag(λ)·Vᵀ`). Eigenvalues are sorted ascending.
pub fn eig_sym(a: &Matrix, tol: f64, max_sweeps: usize) -> (Vec<f32>, Matrix) {
    let mut work = EigWork::default();
    let mut vals = Vec::new();
    let mut vecs = Matrix::zeros(a.rows(), a.cols());
    eig_sym_with(a, tol, max_sweeps, &mut work, &mut vals, &mut vecs);
    (vals, vecs)
}

/// [`eig_sym`] writing into caller-owned outputs, with all f64 temporaries
/// drawn from `work` — the allocation-free variant the `ec4` codec drives
/// at every refresh. `vecs` must be `n×n` (fully overwritten); `vals` is
/// cleared and refilled with the ascending eigenvalues.
pub fn eig_sym_with(
    a: &Matrix,
    tol: f64,
    max_sweeps: usize,
    work: &mut EigWork,
    vals: &mut Vec<f32>,
    vecs: &mut Matrix,
) {
    assert!(a.is_square());
    let n = a.rows();
    assert_eq!((vecs.rows(), vecs.cols()), (n, n), "vecs must be n×n");
    // Work in f64 for orthogonality quality.
    let m = &mut work.m;
    m.clear();
    m.extend(a.data().iter().map(|&x| x as f64));
    let v = &mut work.v;
    v.clear();
    v.resize(n * n, 0.0);
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let off = |m: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += m[i * n + j] * m[i * n + j];
            }
        }
        s.sqrt()
    };

    let scale = m.iter().map(|x| x.abs()).fold(0.0f64, f64::max).max(1e-300);
    for _sweep in 0..max_sweeps {
        if off(m) <= tol * scale * n as f64 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of M, and columns of V.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract + sort ascending.
    let pairs = &mut work.pairs;
    pairs.clear();
    pairs.extend((0..n).map(|i| (m[i * n + i], i)));
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    vals.clear();
    vals.extend(pairs.iter().map(|&(l, _)| l as f32));
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vecs[(r, new_col)] = v[r * n + old_col] as f32;
        }
    }
}

/// Exact `A^{-1/p}` via eigendecomposition: `V·diag(λ^{-1/p})·Vᵀ`.
/// Eigenvalues are clamped below at `clamp` to keep the result finite on
/// near-singular inputs (matching the regularized definition in Eq. (6)).
pub fn inverse_pth_root_eig(a: &Matrix, p: f64, clamp: f32) -> Matrix {
    let mut plan = MatmulPlan::new();
    inverse_pth_root_eig_planned(a, p, clamp, &mut plan)
}

/// [`inverse_pth_root_eig`] with a caller-owned matmul plan. Callers that
/// hit this inside a loop (the Shampoo refresh fallback for
/// quantization-broken preconditioners, the NRE/AE analysis sweeps) route
/// their arena's plan here instead of paying a fresh packed-B allocation
/// per call.
pub fn inverse_pth_root_eig_planned(
    a: &Matrix,
    p: f64,
    clamp: f32,
    plan: &mut MatmulPlan,
) -> Matrix {
    let n = a.rows();
    let (vals, v) = eig_sym(a, 1e-12, 100);
    let mut scaled = v.clone();
    for j in 0..n {
        let lam = vals[j].max(clamp);
        let w = (lam as f64).powf(-1.0 / p) as f32;
        for i in 0..n {
            scaled[(i, j)] *= w;
        }
    }
    let mut out = Matrix::zeros(n, n);
    matmul_into_planned(&scaled, &v.transpose(), &mut out, plan);
    out
}

/// PSD-projection rung of the numerical-health fallback ladder: sanitize
/// `a` (non-finite entries → 0), symmetrize, eigendecompose, clamp every
/// eigenvalue below at `clamp` (floored at a strictly positive value so
/// `λ^{-1/p}` stays finite), and return `V·diag(λ^{-1/p})·Vᵀ`.
///
/// Unlike [`inverse_pth_root_eig_planned`], which assumes a well-formed
/// symmetric input, this accepts a gram that quantization or a poisoned
/// gradient has broken outright and still yields a finite root — the
/// guarantee the refresh fallback ladder needs one rung above the diagonal
/// floor. On a finite symmetric input the sanitization is the identity, so
/// the result matches `inverse_pth_root_eig_planned` bit for bit.
pub fn psd_clamped_root_planned(a: &Matrix, p: f64, clamp: f32, plan: &mut MatmulPlan) -> Matrix {
    assert!(a.is_square());
    let n = a.rows();
    let sym = Matrix::from_fn(n, n, |i, j| {
        let x = a[(i, j)];
        let y = a[(j, i)];
        let xf = if x.is_finite() { x } else { 0.0 };
        let yf = if y.is_finite() { y } else { 0.0 };
        0.5 * (xf + yf)
    });
    inverse_pth_root_eig_planned(&sym, p, clamp.max(1e-12), plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, syrk};
    use crate::linalg::norms::fro_norm;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, 5.0]]);
        let (vals, _) = eig_sym(&a, 1e-12, 50);
        assert!((vals[0] - 2.0).abs() < 1e-5);
        assert!((vals[1] - 5.0).abs() < 1e-5);
    }

    #[test]
    fn paper_toy_matrix_eigenvalues() {
        // Appendix C.1: [[10,3],[3,1]] has eigenvalues (10.908, 0.092).
        let a = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0]]);
        let (vals, _) = eig_sym(&a, 1e-12, 50);
        assert!((vals[1] - 10.908).abs() < 1e-3, "λmax={}", vals[1]);
        assert!((vals[0] - 0.092).abs() < 1e-3, "λmin={}", vals[0]);
    }

    #[test]
    fn reconstruction() {
        let mut rng = Rng::new(1);
        let g = Matrix::randn(10, 15, 1.0, &mut rng);
        let a = syrk(&g);
        let (vals, v) = eig_sym(&a, 1e-12, 100);
        // A ≈ V diag(vals) Vᵀ
        let mut lam_vt = v.transpose();
        for i in 0..10 {
            let row = lam_vt.row_mut(i);
            for x in row.iter_mut() {
                *x *= vals[i];
            }
        }
        let recon = matmul(&v, &lam_vt);
        assert!((recon.max_abs_diff(&a) as f64 / fro_norm(&a)) < 1e-4);
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Rng::new(2);
        let g = Matrix::randn(8, 10, 1.0, &mut rng);
        let a = syrk(&g);
        let (_, v) = eig_sym(&a, 1e-12, 100);
        let vtv = matmul(&v.transpose(), &v);
        assert!(vtv.max_abs_diff(&Matrix::eye(8)) < 1e-4);
    }

    #[test]
    fn eig_sym_with_matches_allocating_path_and_reuses_buffers() {
        let mut rng = Rng::new(5);
        let mut work = EigWork::default();
        let mut vals = Vec::new();
        let mut vecs = Matrix::zeros(9, 9);
        for trial in 0..3 {
            let g = Matrix::randn(9, 12, 1.0, &mut rng);
            let a = syrk(&g);
            let (want_vals, want_vecs) = eig_sym(&a, 1e-12, 100);
            eig_sym_with(&a, 1e-12, 100, &mut work, &mut vals, &mut vecs);
            assert_eq!(vals, want_vals, "trial {trial}");
            assert_eq!(vecs.max_abs_diff(&want_vecs), 0.0, "trial {trial}");
        }
    }

    #[test]
    fn psd_clamped_root_survives_non_finite_and_matches_clean_path() {
        let mut plan = MatmulPlan::new();
        // Clean SPD input: identical to the ordinary eig path.
        let mut rng = Rng::new(7);
        let g = Matrix::randn(6, 9, 1.0, &mut rng);
        let mut a = syrk(&g);
        a.add_diag(0.3);
        let want = inverse_pth_root_eig_planned(&a, 4.0, 1e-10, &mut plan);
        let got = psd_clamped_root_planned(&a, 4.0, 1e-10, &mut plan);
        assert_eq!(got.max_abs_diff(&want), 0.0);
        // Poisoned input: NaN and Inf entries, asymmetric damage — the
        // projection must still return a finite root.
        let mut bad = a.clone();
        bad[(0, 1)] = f32::NAN;
        bad[(3, 3)] = f32::INFINITY;
        bad[(5, 2)] = f32::NEG_INFINITY;
        let r = psd_clamped_root_planned(&bad, 4.0, 1e-10, &mut plan);
        assert!(!r.has_non_finite());
        // Even a clamp of zero is floored so λ^{-1/p} cannot blow up.
        let z = Matrix::zeros(4, 4);
        let r = psd_clamped_root_planned(&z, 4.0, 0.0, &mut plan);
        assert!(!r.has_non_finite());
    }

    #[test]
    fn inverse_fourth_root_inverts() {
        let mut rng = Rng::new(3);
        let g = Matrix::randn(6, 9, 1.0, &mut rng);
        let mut a = syrk(&g);
        a.add_diag(0.5);
        let r = inverse_pth_root_eig(&a, 4.0, 1e-12);
        // (A^{-1/4})^4 · A ≈ I
        let r2 = matmul(&r, &r);
        let r4 = matmul(&r2, &r2);
        let prod = matmul(&r4, &a);
        assert!(prod.max_abs_diff(&Matrix::eye(6)) < 5e-3);
    }
}

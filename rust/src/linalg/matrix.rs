//! Row-major dense f32 matrix.

use crate::util::rng::Rng;
use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major `rows × cols` matrix of f32.
///
/// f32 matches the precision the paper trains in (preconditioners are f32
/// before quantization); all quantization targets are built from this type.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Scaled identity `s·I`.
    pub fn eye_scaled(n: usize, s: f32) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = s;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// From nested row slices (tests / toy examples).
    pub fn from_rows(rows: &[&[f32]]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Gaussian random matrix N(0, std²).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Overwrite `self` with `other`'s contents (shapes must match).
    /// The allocation-free sibling of `clone` for scratch-buffer reuse.
    #[inline]
    pub fn copy_from(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "shape mismatch");
        self.data.copy_from_slice(&other.data);
    }

    /// Overwrite `self` (square) with `s·I`.
    pub fn set_eye_scaled(&mut self, s: f32) {
        assert!(self.is_square());
        self.data.fill(0.0);
        for i in 0..self.rows {
            self.data[i * self.cols + i] = s;
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Elementwise `self + s·other`.
    pub fn axpy(&mut self, s: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Scale all entries in place.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// `self ← β·self + (1−β)·other` (the EMA update used by Eq. (2)/(7)).
    pub fn ema(&mut self, beta: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a = beta * *a + (1.0 - beta) * b;
        }
    }

    /// Add `s` to the diagonal (εI regularization).
    pub fn add_diag(&mut self, s: f32) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += s;
        }
    }

    /// Copy of the diagonal.
    pub fn diag(&self) -> Vec<f32> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.data[i * self.cols + i]).collect()
    }

    /// Symmetrize in place: `(A + Aᵀ)/2` (guards drift before Cholesky).
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let m = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = m;
                self[(j, i)] = m;
            }
        }
    }

    /// Maximum |a_ij − b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Extract a sub-block (used by max-order blocking).
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Matrix {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols);
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i)
                .copy_from_slice(&self.row(r0 + i)[c0..c0 + cols]);
        }
        out
    }

    /// Extract a sub-block into an existing buffer (`out`'s shape selects
    /// the block size) — the allocation-free sibling of [`Matrix::block`].
    pub fn block_into(&self, r0: usize, c0: usize, out: &mut Matrix) {
        let (rows, cols) = (out.rows, out.cols);
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(&self.row(r0 + i)[c0..c0 + cols]);
        }
    }

    /// Write a sub-block.
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Matrix) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for i in 0..b.rows {
            let cols = self.cols;
            self.data[(r0 + i) * cols + c0..(r0 + i) * cols + c0 + b.cols]
                .copy_from_slice(b.row(i));
        }
    }

    /// True if any entry is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// Bytes of the payload (f32 storage).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Serialize for checkpointing: shape header + raw IEEE-754 bits, so a
    /// restored matrix is bit-identical to the saved one (NaNs included).
    pub fn write_bytes(&self, w: &mut crate::util::bytes::ByteWriter) {
        w.put_u64(self.rows as u64);
        w.put_u64(self.cols as u64);
        w.put_f32s(&self.data);
    }

    /// Inverse of [`Matrix::write_bytes`]; errors on truncated input or a
    /// shape/payload mismatch.
    pub fn read_bytes(
        r: &mut crate::util::bytes::ByteReader<'_>,
    ) -> crate::util::error::Result<Matrix> {
        let rows = r.get_len()?;
        let cols = r.get_len()?;
        let data = r.get_f32s()?;
        crate::ensure!(
            data.len() == rows * cols,
            "matrix payload {} elems, want {rows}x{cols}",
            data.len()
        );
        Ok(Matrix { rows, cols, data })
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m[(1, 0)], 3.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
    }

    #[test]
    fn eye_and_diag() {
        let m = Matrix::eye_scaled(3, 2.5);
        assert_eq!(m.diag(), vec![2.5, 2.5, 2.5]);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 3, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn ema_blends() {
        let mut a = Matrix::from_rows(&[&[1.0]]);
        let b = Matrix::from_rows(&[&[3.0]]);
        a.ema(0.5, &b);
        assert_eq!(a[(0, 0)], 2.0);
    }

    #[test]
    fn block_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(7, 9, 1.0, &mut rng);
        let b = m.block(2, 3, 4, 5);
        let mut m2 = Matrix::zeros(7, 9);
        m2.set_block(2, 3, &b);
        assert_eq!(m2.block(2, 3, 4, 5), b);
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let mut rng = Rng::new(3);
        let mut m = Matrix::randn(6, 6, 1.0, &mut rng);
        m.symmetrize();
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
    }

    #[test]
    fn non_finite_detection() {
        let mut m = Matrix::zeros(2, 2);
        assert!(!m.has_non_finite());
        m[(1, 1)] = f32::NAN;
        assert!(m.has_non_finite());
    }
}

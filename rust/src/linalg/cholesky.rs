//! Cholesky factorization (the heart of the paper's CQ scheme, Eq. (7)).
//!
//! Two kernels behind one entry point:
//!
//! * [`cholesky_naive`] — the scalar Cholesky–Banachiewicz loop with f64
//!   pivot accumulation. Reference semantics; best below
//!   [`CHOLESKY_BLOCKED_MIN`] where pass overhead beats cache wins.
//! * a blocked **right-looking** factorization (panel factor + triangular
//!   panel solve + rank-`PANEL` trailing update, the `syrk`-shaped O(n³)
//!   part routed through the packed-panel GEMM tier's lower-triangle
//!   subtract kernel — see `linalg::gemm`). This is what every
//!   preconditioner-order factorization (512/1024/2048 blocks) goes
//!   through.
//!
//! [`cholesky`] dispatches on order; the crossover ([`CHOLESKY_BLOCKED_MIN`])
//! was picked where the blocked kernel's trailing update has enough rows to
//! amortize its two extra passes — below ~96 the panel width covers most of
//! the matrix and the naive loop is strictly less work. The blocked factor
//! is pinned to the naive kernel by the `kernel_equivalence` property suite
//! (≤1e-5 relative Frobenius on random SPD, divisible and non-divisible
//! orders).
//!
//! [`cholesky_into`]/[`cholesky_jittered_into_planned`] are the
//! allocation-free variants the refresh hot path uses (factor into a
//! caller/arena-owned buffer, pack into an arena-owned plan; see
//! `linalg::ScratchArena`).

use super::gemm::{self, MatmulPlan};
use super::matrix::Matrix;
use crate::util::pool::default_threads;
use std::fmt;

/// Panel width of the blocked right-looking factorization.
const PANEL: usize = 48;

/// Orders below this use the naive reference kernel (see module docs for
/// the crossover rationale).
pub const CHOLESKY_BLOCKED_MIN: usize = 96;

/// FLOP threshold below which the trailing update stays single-threaded.
const PAR_FLOP_THRESHOLD: usize = 1 << 20;

#[derive(Debug)]
pub enum CholeskyError {
    NotSquare(usize, usize),
    NotPd { index: usize, pivot: f32 },
    NonFinite,
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CholeskyError::NotSquare(r, c) => write!(f, "matrix is not square ({r}x{c})"),
            CholeskyError::NotPd { index, pivot } => {
                write!(f, "matrix is not positive definite (pivot {pivot} at index {index})")
            }
            CholeskyError::NonFinite => {
                write!(f, "non-finite entry encountered during factorization")
            }
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Lower-triangular Cholesky factor `C` with `C·Cᵀ = A`.
///
/// Dispatches to the blocked kernel for `n ≥ CHOLESKY_BLOCKED_MIN`, the
/// naive reference loop below. The strict upper triangle of the result is
/// zero.
pub fn cholesky(a: &Matrix) -> Result<Matrix, CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let mut l = Matrix::zeros(a.rows(), a.cols());
    cholesky_into(a, &mut l)?;
    Ok(l)
}

/// Factor into an existing `n×n` buffer — the allocation-free hot-path
/// variant. On success `out`'s lower triangle holds `C` and its strict
/// upper triangle is zeroed; on error `out`'s contents are unspecified.
pub fn cholesky_into(a: &Matrix, out: &mut Matrix) -> Result<(), CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    assert_eq!((out.rows(), out.cols()), (a.rows(), a.cols()), "output shape mismatch");
    out.copy_from(a);
    let mut plan = MatmulPlan::new();
    factor_in_place(out, &mut plan)?;
    zero_strict_upper(out);
    Ok(())
}

/// The scalar reference kernel (Cholesky–Banachiewicz, f64 accumulation).
/// Kept public as the small-n path and the oracle the blocked kernel is
/// tested against.
pub fn cholesky_naive(a: &Matrix) -> Result<Matrix, CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let mut l = a.clone();
    factor_naive_in_place(&mut l)?;
    zero_strict_upper(&mut l);
    Ok(l)
}

fn factor_in_place(l: &mut Matrix, plan: &mut MatmulPlan) -> Result<(), CholeskyError> {
    if l.rows() < CHOLESKY_BLOCKED_MIN {
        factor_naive_in_place(l)
    } else {
        factor_blocked_in_place(l, plan)
    }
}

fn zero_strict_upper(l: &mut Matrix) {
    let n = l.rows();
    for i in 0..n {
        l.row_mut(i)[i + 1..].fill(0.0);
    }
}

/// In-place Cholesky–Banachiewicz on the lower triangle: cell `(i, j)`
/// still holds `A[i][j]` when it is consumed, so the loop is identical in
/// arithmetic (and bit-for-bit in result) to the classic out-of-place form.
fn factor_naive_in_place(l: &mut Matrix) -> Result<(), CholeskyError> {
    let n = l.rows();
    for i in 0..n {
        for j in 0..=i {
            // dot of rows i and j of L over [0, j)
            let mut s = 0.0f64;
            {
                let li = l.row(i);
                let lj = l.row(j);
                for k in 0..j {
                    s += li[k] as f64 * lj[k] as f64;
                }
            }
            if i == j {
                let pivot = l[(i, i)] as f64 - s;
                if !pivot.is_finite() {
                    return Err(CholeskyError::NonFinite);
                }
                if pivot <= 0.0 {
                    return Err(CholeskyError::NotPd { index: i, pivot: pivot as f32 });
                }
                l[(i, j)] = pivot.sqrt() as f32;
            } else {
                let denom = l[(j, j)] as f64;
                let v = ((l[(i, j)] as f64 - s) / denom) as f32;
                if !v.is_finite() {
                    return Err(CholeskyError::NonFinite);
                }
                l[(i, j)] = v;
            }
        }
    }
    Ok(())
}

/// Blocked right-looking factorization, in place on the lower triangle.
///
/// Per panel `[k0, k1)`: (1) factor the diagonal block (scalar, f64
/// accumulation — prior panels' contributions were already subtracted by
/// their trailing updates); (2) triangular-solve the panel rows below it;
/// (3) rank-`k1−k0` trailing update `A22 −= L21·L21ᵀ` on the lower
/// triangle, through the packed-panel GEMM tier's strided subtract kernel
/// (`gemm::syrk_sub_lower_raw`; one panel is a single KC slab, so the
/// accumulation order is thread-count-independent). Passes 1–2 are
/// O(n²·PANEL) and run sequentially with full finite/PD checks; pass 3 is
/// the O(n³) bulk. The caller-owned `plan` holds the packing buffers —
/// one pair, reused by every panel of the factorization (and across
/// factorizations when the arena plan is threaded through).
fn factor_blocked_in_place(l: &mut Matrix, plan: &mut MatmulPlan) -> Result<(), CholeskyError> {
    let n = l.rows();
    let mut k0 = 0usize;
    while k0 < n {
        let k1 = (k0 + PANEL).min(n);

        // (1) Factor the diagonal block in place.
        for i in k0..k1 {
            for j in k0..=i {
                let mut s = 0.0f64;
                {
                    let li = l.row(i);
                    let lj = l.row(j);
                    for t in k0..j {
                        s += li[t] as f64 * lj[t] as f64;
                    }
                }
                if i == j {
                    let pivot = l[(i, i)] as f64 - s;
                    if !pivot.is_finite() {
                        return Err(CholeskyError::NonFinite);
                    }
                    if pivot <= 0.0 {
                        return Err(CholeskyError::NotPd { index: i, pivot: pivot as f32 });
                    }
                    l[(i, j)] = pivot.sqrt() as f32;
                } else {
                    let denom = l[(j, j)] as f64;
                    let v = ((l[(i, j)] as f64 - s) / denom) as f32;
                    if !v.is_finite() {
                        return Err(CholeskyError::NonFinite);
                    }
                    l[(i, j)] = v;
                }
            }
        }

        // (2) Panel solve: L21 = A21 · L11⁻ᵀ, row by row.
        for i in k1..n {
            for j in k0..k1 {
                let mut s = 0.0f64;
                {
                    let li = l.row(i);
                    let lj = l.row(j);
                    for t in k0..j {
                        s += li[t] as f64 * lj[t] as f64;
                    }
                }
                let denom = l[(j, j)] as f64;
                let v = ((l[(i, j)] as f64 - s) / denom) as f32;
                if !v.is_finite() {
                    return Err(CholeskyError::NonFinite);
                }
                l[(i, j)] = v;
            }
        }

        // (3) Trailing update: A22 −= L21·L21ᵀ (lower triangle only).
        if k1 < n {
            let trailing = n - k1;
            let pw = k1 - k0;
            let threads = if trailing * trailing * pw < PAR_FLOP_THRESHOLD {
                1
            } else {
                default_threads()
            };
            let base = l.data_mut().as_mut_ptr();
            // Safety: the written window (rows ≥ k1, cols ≥ k1) and the
            // read window L21 (rows ≥ k1, cols [k0, k1)) are disjoint
            // column ranges of the same rows of `l`.
            unsafe {
                gemm::syrk_sub_lower_raw(
                    base.add(k1 * n + k1),
                    base.add(k1 * n + k0) as *const f32,
                    n,
                    trailing,
                    pw,
                    threads,
                    plan,
                );
            }
        }

        k0 = k1;
    }
    Ok(())
}

/// Cholesky with escalating diagonal jitter, mirroring the paper's `+εI`
/// regularization (Eq. (7)). The first rung is the documented legacy
/// behavior — exactly `eps`, absolute — so the healthy path is bit-identical
/// to the classic schedule; every later rung escalates **relative to the
/// matrix's scale**, `ε · max_diag · 10^t`, so a huge-scale gram (whose
/// pivots dwarf any absolute ε) and a tiny post-quantization gram both
/// rescue in the same number of rungs. Returns the factor and the jitter
/// actually used.
pub fn cholesky_jittered(
    a: &Matrix,
    eps: f32,
    max_tries: u32,
) -> Result<(Matrix, f32), CholeskyError> {
    let mut out = Matrix::zeros(a.rows(), a.cols());
    cholesky_jittered_into(a, eps, max_tries, &mut out).map(|jitter| (out, jitter))
}

/// Jittered factorization into an existing buffer (no per-try clone — the
/// retry loop re-copies `a` into `out` and re-factors in place). Returns
/// the jitter actually used; on error `out`'s contents are unspecified.
pub fn cholesky_jittered_into(
    a: &Matrix,
    eps: f32,
    max_tries: u32,
    out: &mut Matrix,
) -> Result<f32, CholeskyError> {
    let mut plan = MatmulPlan::new();
    cholesky_jittered_into_planned(a, eps, max_tries, out, &mut plan)
}

/// [`cholesky_jittered_into`] with a caller-owned GEMM plan for the
/// trailing-update packing buffers — the fully allocation-free variant the
/// codec refresh path uses (pass `ScratchArena::plan`).
pub fn cholesky_jittered_into_planned(
    a: &Matrix,
    eps: f32,
    max_tries: u32,
    out: &mut Matrix,
    plan: &mut MatmulPlan,
) -> Result<f32, CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    assert_eq!((out.rows(), out.cols()), (a.rows(), a.cols()), "output shape mismatch");
    // Largest finite positive diagonal entry — the scale the escalating
    // rungs are relative to (1.0 when the diagonal offers no usable scale,
    // which reproduces the legacy absolute schedule exactly).
    let mut scale = 0.0f32;
    for i in 0..a.rows() {
        let d = a[(i, i)];
        if d.is_finite() && d > scale {
            scale = d;
        }
    }
    if scale <= 0.0 {
        scale = 1.0;
    }
    // Rung 0 is exactly `eps` (legacy first rung); rung t ≥ 1 is
    // `eps · scale · 10^t`.
    let mut jitter = eps;
    let mut escalated = eps * scale;
    let mut last_err = None;
    for _ in 0..max_tries {
        out.copy_from(a);
        out.add_diag(jitter);
        match factor_in_place(out, plan) {
            Ok(()) => {
                zero_strict_upper(out);
                return Ok(jitter);
            }
            Err(e) => {
                last_err = Some(e);
                escalated *= 10.0;
                jitter = escalated;
            }
        }
    }
    Err(last_err.unwrap_or(CholeskyError::NonFinite))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_nt, syrk};
    use crate::util::rng::Rng;

    #[test]
    fn factor_known_matrix() {
        // Paper's Appendix C.1 toy matrix [[10,3],[3,1]] + tiny eps is PD.
        let a = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0 + 1e-3]]);
        let l = cholesky(&a).unwrap();
        let recon = matmul_nt(&l, &l);
        assert!(recon.max_abs_diff(&a) < 1e-5);
        assert_eq!(l[(0, 1)], 0.0, "upper triangle zero");
    }

    #[test]
    fn factor_random_spd() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 16, 48] {
            let g = Matrix::randn(n, n + 4, 1.0, &mut rng);
            let mut a = syrk(&g);
            a.add_diag(0.1);
            let l = cholesky(&a).unwrap();
            let recon = matmul_nt(&l, &l);
            assert!(recon.max_abs_diff(&a) < 1e-3 * n as f32, "n={n}");
        }
    }

    #[test]
    fn blocked_path_reconstructs_spd() {
        // Orders above the crossover (incl. panel-non-divisible) go through
        // the blocked kernel and must still satisfy C·Cᵀ = A.
        let mut rng = Rng::new(7);
        for n in [CHOLESKY_BLOCKED_MIN, 130, 193] {
            let g = Matrix::randn(n, n + 8, 1.0, &mut rng);
            let mut a = syrk(&g);
            a.add_diag(1.0);
            let l = cholesky(&a).unwrap();
            let recon = matmul_nt(&l, &l);
            let rel = crate::linalg::norms::relative_error(&a, &recon);
            assert!(rel < 1e-4, "n={n} rel={rel}");
            assert_eq!(l[(0, n - 1)], 0.0, "upper triangle zero");
        }
    }

    #[test]
    fn blocked_matches_naive_kernel() {
        let mut rng = Rng::new(8);
        for n in [96usize, 131] {
            let g = Matrix::randn(n, n + 8, 1.0, &mut rng);
            let mut a = syrk(&g);
            a.add_diag(1.0);
            let fast = cholesky(&a).unwrap();
            let slow = cholesky_naive(&a).unwrap();
            let rel = crate::linalg::norms::relative_error(&slow, &fast);
            assert!(rel < 1e-5, "n={n} rel={rel}");
        }
    }

    #[test]
    fn into_variant_reuses_buffer() {
        let mut rng = Rng::new(9);
        let g = Matrix::randn(12, 16, 1.0, &mut rng);
        let mut a = syrk(&g);
        a.add_diag(0.5);
        let want = cholesky(&a).unwrap();
        let mut out = Matrix::from_fn(12, 12, |_, _| f32::NAN); // stale garbage
        cholesky_into(&a, &mut out).unwrap();
        assert_eq!(out, want, "cholesky_into must fully overwrite its buffer");
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(matches!(cholesky(&a), Err(CholeskyError::NotPd { .. })));
    }

    #[test]
    fn blocked_rejects_indefinite_with_global_pivot_index() {
        // Indefinite direction planted beyond the first panel: the blocked
        // kernel must report the global row index of the failing pivot.
        let n = 120;
        let mut rng = Rng::new(10);
        let g = Matrix::randn(n, n + 8, 1.0, &mut rng);
        let mut a = syrk(&g);
        a.add_diag(0.5);
        let bad = PANEL + 7;
        a[(bad, bad)] = -1e6;
        match cholesky(&a) {
            Err(CholeskyError::NotPd { index, .. }) => assert_eq!(index, bad),
            other => panic!("expected NotPd at {bad}, got {other:?}"),
        }
    }

    #[test]
    fn rejects_non_square() {
        let a = Matrix::zeros(2, 3);
        assert!(matches!(cholesky(&a), Err(CholeskyError::NotSquare(2, 3))));
    }

    #[test]
    fn jitter_rescues_psd() {
        // Singular PSD matrix: rank-1.
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        assert!(cholesky(&a).is_err());
        let (l, jitter) = cholesky_jittered(&a, 1e-6, 12).unwrap();
        assert!(jitter >= 1e-6);
        assert!(!l.has_non_finite());
    }

    #[test]
    fn jitter_schedule_scales_with_matrix_magnitude() {
        // An indefinite matrix at scale s (eigenvalues s·(1 ± 1.1)) needs
        // jitter > 0.1·s to become PD. Under the old absolute ε·10^t
        // schedule the huge-scale case (s = 1e8 → jitter ≥ 1e7) exhausts
        // all 12 rungs starting from 1e-6; the trace-scaled schedule
        // reaches it in a handful of relative rungs, and the tiny-scale
        // case still rescues immediately on the legacy first rung.
        for s in [1e-8f32, 1.0, 1e8] {
            let a = Matrix::from_fn(2, 2, |i, j| if i == j { s } else { 1.1 * s });
            let (l, jitter) = cholesky_jittered(&a, 1e-6, 12)
                .unwrap_or_else(|e| panic!("scale {s} not rescued: {e}"));
            assert!(!l.has_non_finite(), "scale {s}");
            // The rescue jitter stays proportionate: never more than the
            // matrix's own scale (the old schedule had no such bound).
            assert!(jitter <= s.max(1e-6), "scale {s} used jitter {jitter}");
        }
        // The first rung is still the documented legacy behavior: a matrix
        // rescued by +εI reports exactly ε regardless of its scale.
        let tiny = Matrix::from_rows(&[&[1e-9, 1e-9], &[1e-9, 1e-9]]);
        let (_, jitter) = cholesky_jittered(&tiny, 1e-6, 12).unwrap();
        assert_eq!(jitter, 1e-6);
    }

    #[test]
    fn rejects_nan() {
        let mut a = Matrix::eye(3);
        a[(2, 2)] = f32::NAN;
        assert!(cholesky(&a).is_err());
    }
}
